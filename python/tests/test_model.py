"""L2 correctness: decode-module chain == full training forward, plus
primitive-level properties (rotary, rmsnorm, router)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model as m
from compile.config import TEST, ModelConfig

settings.register_profile("ci", deadline=None, max_examples=10)
settings.load_profile("ci")

CFG = TEST


def _params(seed=0, cfg=CFG):
    return m.init_params(jax.random.PRNGKey(seed), cfg)


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def test_rmsnorm_unit_scale():
    x = jnp.array([[3.0, 4.0]])
    y = m.rmsnorm(x, jnp.ones(2), 0.0)
    np.testing.assert_allclose(
        np.asarray(jnp.mean(y**2, -1)), 1.0, rtol=1e-5)


@given(seed=st.integers(0, 1000), pos=st.integers(0, 63))
def test_rope_preserves_norm(seed, pos):
    rng = np.random.default_rng(seed)
    x = jnp.array(rng.standard_normal((1, 2, 16)), jnp.float32)
    cos, sin = m.rope_angles(jnp.array([pos]), 16, 10000.0)
    y = m.apply_rope(x, cos[:, None, :], sin[:, None, :])
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)


def test_rope_position_zero_is_identity():
    x = jnp.ones((1, 2, 16))
    cos, sin = m.rope_angles(jnp.array([0]), 16, 10000.0)
    y = m.apply_rope(x, cos[:, None, :], sin[:, None, :])
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-6)


def test_rope_relative_property():
    """<rope(q,p1), rope(k,p2)> depends only on p1 - p2."""
    rng = np.random.default_rng(0)
    q = jnp.array(rng.standard_normal((1, 1, 16)), jnp.float32)
    k = jnp.array(rng.standard_normal((1, 1, 16)), jnp.float32)

    def dot_at(pq, pk):
        cq, sq = m.rope_angles(jnp.array([pq]), 16, 10000.0)
        ck, sk = m.rope_angles(jnp.array([pk]), 16, 10000.0)
        rq = m.apply_rope(q, cq[:, None, :], sq[:, None, :])
        rk = m.apply_rope(k, ck[:, None, :], sk[:, None, :])
        return float(jnp.sum(rq * rk))

    assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-4


def test_router_probs_sum_to_one():
    params = _params()
    tokens = jnp.arange(12, dtype=jnp.int32)[None]
    _, probs = m.forward_train(params, tokens, CFG)
    np.testing.assert_allclose(np.asarray(probs.sum(-1)), 1.0, rtol=1e-5)


# ---------------------------------------------------------------------------
# decode chain == train forward
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 100))
def test_decode_reference_matches_forward_train(seed):
    params = _params(seed)
    rng = np.random.default_rng(seed)
    tokens = jnp.array(rng.integers(0, CFG.vocab_size, 10), jnp.int32)
    train_logits, _ = m.forward_train(params, tokens[None], CFG)
    decode_logits = m.decode_reference(params, tokens, CFG)
    np.testing.assert_allclose(
        np.asarray(decode_logits), np.asarray(train_logits[0]),
        rtol=2e-3, atol=2e-3)


def test_prefill_attn_matches_sequential_decode():
    """Chunked prefill must produce the same residual + cache as running
    attn_mod token by token."""
    params = _params(3)
    layer = params["layers"][0]
    rng = np.random.default_rng(3)
    C = CFG.prefill_chunk
    xs = jnp.array(rng.standard_normal((C, CFG.d_model)), jnp.float32)

    kc = jnp.zeros((CFG.max_seq, CFG.n_kv_heads, CFG.head_dim))
    vc = jnp.zeros_like(kc)
    outs = []
    for t in range(C):
        y, kc, vc = m.attn_mod(
            xs[t:t+1], layer["attn_ln"], layer["wq"], layer["wk"],
            layer["wv"], layer["wo"], kc, vc, jnp.int32(t), cfg=CFG)
        outs.append(y)
    seq_out = jnp.concatenate(outs)

    kc2 = jnp.zeros_like(kc)
    vc2 = jnp.zeros_like(vc)
    chunk_out, kc2, vc2 = m.prefill_attn_mod(
        xs, layer["attn_ln"], layer["wq"], layer["wk"], layer["wv"],
        layer["wo"], kc2, vc2, jnp.int32(0), cfg=CFG)

    np.testing.assert_allclose(np.asarray(chunk_out), np.asarray(seq_out),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(kc2), np.asarray(kc),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(vc2), np.asarray(vc),
                               rtol=1e-4, atol=1e-4)


def test_prefill_padding_is_harmless():
    """Padded tail tokens must not change valid-position outputs or the
    cache rows that later decoding reads (positions < n_valid)."""
    params = _params(4)
    layer = params["layers"][0]
    rng = np.random.default_rng(4)
    C = CFG.prefill_chunk
    n_valid = C - 3
    xs = jnp.array(rng.standard_normal((C, CFG.d_model)), jnp.float32)
    pad = jnp.array(rng.standard_normal((C, CFG.d_model)), jnp.float32)
    xs_padded = jnp.concatenate([xs[:n_valid], pad[n_valid:]])

    def run(x):
        kc = jnp.zeros((CFG.max_seq, CFG.n_kv_heads, CFG.head_dim))
        vc = jnp.zeros_like(kc)
        return m.prefill_attn_mod(
            x, layer["attn_ln"], layer["wq"], layer["wk"], layer["wv"],
            layer["wo"], kc, vc, jnp.int32(0), cfg=CFG)

    out_a, kc_a, vc_a = run(xs)
    out_b, kc_b, vc_b = run(xs_padded)
    np.testing.assert_allclose(np.asarray(out_a[:n_valid]),
                               np.asarray(out_b[:n_valid]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(kc_a[:n_valid]),
                               np.asarray(kc_b[:n_valid]), atol=1e-6)
    np.testing.assert_allclose(np.asarray(vc_a[:n_valid]),
                               np.asarray(vc_b[:n_valid]), atol=1e-6)


def test_speculative_gate_signal_beats_chance():
    """The paper's §3.2 heuristic: gate_{l+1} applied to layer-l residual
    should predict layer-l+1's top experts much better than chance, even on
    an untrained model (residual-stream continuity is architectural)."""
    cfg = CFG
    params = _params(7)
    rng = np.random.default_rng(7)
    tokens = jnp.array(rng.integers(0, cfg.vocab_size, 24), jnp.int32)[None]

    # speculation from layer l-1's residual must match layer l's actual
    # top-1 expert more often than the 1/E chance rate.
    x = params["embed"][tokens]
    correct = total = 0
    resid = []
    for layer in params["layers"]:
        x = m.attention_full(layer, x, cfg)
        resid.append(x)
        x, probs = m.moe_full(layer, x, cfg)
        if len(resid) >= 2:
            nxt_layer = layer
            spec_logits, _ = m.gate_mod(
                resid[-2][0], nxt_layer["mlp_ln"], nxt_layer["w_gate"], cfg=cfg)
            spec_top = np.asarray(jnp.argmax(spec_logits, -1))
            act_top = np.asarray(jnp.argmax(probs[0], -1))
            correct += (spec_top == act_top).sum()
            total += len(act_top)
    assert total > 0
    assert correct / total > 1.2 / cfg.n_experts, (correct, total)
