"""AOT pipeline checks: every module lowers to parseable-looking HLO text
with the manifest shapes, and quantized/fp expert modules agree numerically
through the lowered path (jit execution of the same jaxprs)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.config import TEST
from compile.kernels import ref


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.build(TEST, out)
    return out, manifest


def test_all_modules_emitted(built):
    out, manifest = built
    expected = {
        "embed", "attn", "prefill_attn", "gate", "prefill_gate",
        "expert", "prefill_expert", "lm_head", "prefill_lm_head",
        "expert_q2", "expert_q3", "expert_q4",
        "prefill_expert_q2", "prefill_expert_q3", "prefill_expert_q4",
    }
    assert set(manifest["modules"]) == expected
    for name, info in manifest["modules"].items():
        path = os.path.join(out, info["file"])
        text = open(path).read()
        assert text.startswith("HloModule"), name
        assert "ROOT" in text, name
        assert len(text) == info["bytes"]


def test_manifest_roundtrips(built):
    out, manifest = built
    loaded = json.load(open(os.path.join(out, "manifest.json")))
    assert loaded == manifest
    assert loaded["config"]["d_model"] == TEST.d_model


def test_manifest_arg_shapes_match_config(built):
    _, manifest = built
    d = TEST.d_model
    attn_args = manifest["modules"]["attn"]["args"]
    assert attn_args[0]["shape"] == [1, d]
    assert attn_args[6]["shape"] == [TEST.max_seq, TEST.n_kv_heads, TEST.head_dim]
    gate_args = manifest["modules"]["gate"]["args"]
    assert gate_args[2]["shape"] == [d, TEST.n_experts]
    eq = manifest["modules"]["expert_q4"]["args"]
    assert eq[1]["dtype"] == "uint8"
    assert eq[2]["shape"] == [d // TEST.group_size, TEST.d_ff]


def test_hlo_is_deterministic(built):
    """Same config -> byte-identical artifacts (hashes must be stable so
    `make artifacts` can skip rebuilds)."""
    out, manifest = built
    again = aot.module_table(TEST)
    name = "gate"
    fn, args = again[name]
    text = aot.to_hlo_text(jax.jit(fn).lower(*args))
    assert manifest["modules"][name]["sha256"] == \
        __import__("hashlib").sha256(text.encode()).hexdigest()[:16]
