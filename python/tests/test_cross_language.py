"""Cross-language pins: values the rust side hard-codes in its tests must
match the python oracles that generated them."""

import json
import os

import numpy as np

from compile.kernels import ref


def test_rust_hqq_fixture_matches():
    """rust/src/quant/hqq.rs::matches_python_oracle_fixture pins these."""
    data = np.array([((i * 7) % 16 - 8) / 4 for i in range(16)], np.float32)
    w = data.reshape(8, 2)
    codes, scale, zero = ref.quantize_group(w, 4, 4)
    assert codes.flatten().tolist() == [
        0, 15, 15, 10, 13, 5, 11, 0, 15, 15, 10, 10, 5, 5, 0, 0,
    ]
    np.testing.assert_allclose(
        scale.flatten(), [0.23333333, 0.1, 0.1, 0.1], rtol=1e-6)
    np.testing.assert_allclose(
        zero.flatten(), [8.571428, 17.5, 15.0, -2.5], rtol=1e-5)


def test_decode_fixture_is_current():
    """artifacts/decode_fixture.json must match the shipped weights — if the
    model is retrained, `make artifacts` must regenerate the fixture that
    rust/tests/engine_numerics.rs replays."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    fixture_path = os.path.join(art, "decode_fixture.json")
    weights_path = os.path.join(art, "weights.npz")
    if not (os.path.exists(fixture_path) and os.path.exists(weights_path)):
        import pytest

        pytest.skip("artifacts not built")

    import jax.numpy as jnp

    from compile import model as model_mod
    from compile.config import TINY
    from compile.train import unflatten_params

    fixture = json.load(open(fixture_path))
    flat = dict(np.load(weights_path))
    params = unflatten_params(flat, TINY)
    tokens = jnp.array(fixture["prompt_tokens"], jnp.int32)
    logits = model_mod.decode_reference(params, tokens, TINY)
    got_argmax = [int(i) for i in jnp.argmax(logits, -1)]
    assert got_argmax == fixture["argmax"], (
        "fixture stale — run `python -m compile.fixtures --out ../artifacts`"
    )
    heads = np.array(fixture["logits_head"], np.float32)
    np.testing.assert_allclose(
        np.asarray(logits)[:, :8], heads, rtol=2e-3, atol=2e-3)
