"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes (within kernel alignment constraints) and
asserts allclose against ref.py — the core correctness signal for the
compile path.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dequant_matmul, expert_mlp, ref

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def _rand(rng, shape, scale=0.2):
    return (rng.standard_normal(shape) * scale).astype(np.float32)


# ---------------------------------------------------------------------------
# plain SwiGLU kernel
# ---------------------------------------------------------------------------

@given(
    t=st.sampled_from([1, 2, 8, 16]),
    d=st.sampled_from([16, 64, 128]),
    ff_mult=st.sampled_from([1, 2, 4]),
    block_pow=st.sampled_from([16, 32, 64, 128]),
    seed=st.integers(0, 2**16),
)
def test_swiglu_matches_ref(t, d, ff_mult, block_pow, seed):
    ff = d * ff_mult
    block_ff = min(block_pow, ff)
    if ff % block_ff != 0:
        block_ff = ff
    rng = np.random.default_rng(seed)
    x = _rand(rng, (t, d), 1.0)
    w1, w3, w2 = _rand(rng, (d, ff)), _rand(rng, (d, ff)), _rand(rng, (ff, d))
    got = expert_mlp.swiglu(jnp.array(x), jnp.array(w1), jnp.array(w3),
                            jnp.array(w2), block_ff=block_ff)
    want = ref.swiglu_ref(x, w1, w3, w2)
    # tolerance sized for tile-accumulation reordering at |y| up to ~20
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_swiglu_rejects_misaligned_block():
    rng = np.random.default_rng(0)
    x = _rand(rng, (1, 16))
    w = _rand(rng, (16, 48))
    w2 = _rand(rng, (48, 16))
    with pytest.raises(AssertionError):
        expert_mlp.swiglu(jnp.array(x), jnp.array(w), jnp.array(w),
                          jnp.array(w2), block_ff=32)


def test_swiglu_zero_input_is_zero():
    rng = np.random.default_rng(1)
    w1, w3 = _rand(rng, (32, 64)), _rand(rng, (32, 64))
    w2 = _rand(rng, (64, 32))
    y = expert_mlp.swiglu(jnp.zeros((1, 32)), jnp.array(w1), jnp.array(w3),
                          jnp.array(w2))
    np.testing.assert_allclose(np.asarray(y), 0.0, atol=1e-7)


# ---------------------------------------------------------------------------
# quantization oracle properties
# ---------------------------------------------------------------------------

@given(
    bits=st.sampled_from([2, 3, 4, 8]),
    g=st.sampled_from([8, 16, 32]),
    n_in_mult=st.integers(1, 4),
    n_out=st.sampled_from([8, 32, 64]),
    seed=st.integers(0, 2**16),
)
def test_quantize_group_roundtrip_bound(bits, g, n_in_mult, n_out, seed):
    """Reconstruction error of affine group quant is bounded by scale/2."""
    rng = np.random.default_rng(seed)
    n_in = g * n_in_mult
    w = _rand(rng, (n_in, n_out), 1.0)
    codes, scale, zero = ref.quantize_group(w, bits, g)
    assert codes.dtype == np.uint8
    assert codes.max() <= 2**bits - 1
    deq = np.asarray(ref.dequant_ref(jnp.array(codes), jnp.array(scale),
                                     jnp.array(zero), g))
    err = np.abs(deq - w).reshape(n_in // g, g, n_out)
    # per-group error bound: half a quantization step (+ float slack)
    bound = scale[:, None, :] / 2 + 1e-4
    assert (err <= bound).all()


def test_quantize_constant_group_is_exact():
    w = np.full((32, 8), 0.37, np.float32)
    codes, scale, zero = ref.quantize_group(w, 2, 16)
    deq = np.asarray(ref.dequant_ref(jnp.array(codes), jnp.array(scale),
                                     jnp.array(zero), 16))
    np.testing.assert_allclose(deq, w, atol=1e-5)


# ---------------------------------------------------------------------------
# fused dequant + SwiGLU kernel
# ---------------------------------------------------------------------------

@given(
    t=st.sampled_from([1, 4, 16]),
    d=st.sampled_from([32, 64, 128]),
    ff=st.sampled_from([64, 128, 256]),
    g=st.sampled_from([16, 32]),
    bits=st.sampled_from([2, 3, 4]),
    seed=st.integers(0, 2**16),
)
def test_dequant_swiglu_matches_ref(t, d, ff, g, bits, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (t, d), 1.0)
    packs = []
    for shape in [(d, ff), (d, ff), (ff, d)]:
        w = _rand(rng, shape)
        packs.append(ref.quantize_group(w, bits, g))
    args = [jnp.array(a) for pack in packs for a in pack]
    got = dequant_matmul.dequant_swiglu(jnp.array(x), *args, group_size=g)
    want = ref.dequant_swiglu_ref(
        x, *[a for pack in packs for a in pack], group_size=g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_dequant_swiglu_equals_fp_when_codes_exact():
    """8-ish-bit-like exactness check: constant weights quantize exactly, so
    the fused kernel must equal the fp32 SwiGLU."""
    d, ff, g = 32, 64, 16
    rng = np.random.default_rng(3)
    x = _rand(rng, (1, d), 1.0)
    w1 = np.full((d, ff), 0.11, np.float32)
    w3 = np.full((d, ff), -0.07, np.float32)
    w2 = np.full((ff, d), 0.05, np.float32)
    args = []
    for w, in [(w1,), (w3,), (w2,)]:
        args.extend(jnp.array(a) for a in ref.quantize_group(w, 2, g))
    got = dequant_matmul.dequant_swiglu(jnp.array(x), *args, group_size=g)
    want = ref.swiglu_ref(x, w1, w3, w2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_vmem_estimates_positive_and_monotone():
    a = expert_mlp.vmem_bytes(4096, 14336, block_ff=128)
    b = expert_mlp.vmem_bytes(4096, 14336, block_ff=256)
    assert 0 < a < b
    q = dequant_matmul.vmem_bytes(4096, 14336, 64, block_ff=128)
    assert 0 < q
    # quantized tiles move fewer HBM bytes but expand in VMEM; the estimate
    # must count both codes and the expanded f32 tile.
    assert q > 3 * 4096 * 128  # at least the codes
