"""Tiny-corpus trainer for the Mixtral-tiny model (build-time only).

Trains the MoE decoder from ``model.py`` on a byte-level corpus for a few
hundred AdamW steps — enough to get a non-degenerate router (the property
the offloading system exploits) and a loss curve for EXPERIMENTS.md. Saves
``artifacts/weights.npz`` (flat name->array map the rust NPZ reader loads)
and ``artifacts/train_log.json``.

Usage: python -m compile.train --steps 600 --out ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import model as model_mod
from .config import TINY, ModelConfig


def flatten_params(params: dict, cfg: ModelConfig) -> dict[str, np.ndarray]:
    """Flatten the pytree into the rust-facing naming scheme."""
    flat = {
        "embed": params["embed"],
        "final_ln": params["final_ln"],
        "lm_head": params["lm_head"],
    }
    for i, layer in enumerate(params["layers"]):
        for key, val in layer.items():
            flat[f"layers.{i}.{key}"] = val
    return {k: np.asarray(v, np.float32) for k, v in flat.items()}


def unflatten_params(flat: dict, cfg: ModelConfig) -> dict:
    params = {
        "embed": jnp.asarray(flat["embed"]),
        "final_ln": jnp.asarray(flat["final_ln"]),
        "lm_head": jnp.asarray(flat["lm_head"]),
        "layers": [],
    }
    for i in range(cfg.n_layers):
        prefix = f"layers.{i}."
        layer = {
            k[len(prefix):]: jnp.asarray(v)
            for k, v in flat.items()
            if k.startswith(prefix)
        }
        params["layers"].append(layer)
    return params


def batches(corpus: np.ndarray, batch: int, seq: int, rng: np.random.Generator):
    """Infinite stream of random [batch, seq+1] windows."""
    n = len(corpus) - seq - 1
    while True:
        idx = rng.integers(0, n, size=batch)
        yield np.stack([corpus[i : i + seq + 1] for i in idx]).astype(np.int32)


def adamw_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.int32(0)}


def adamw_update(params, grads, state, lr, *, b1=0.9, b2=0.95, eps=1e-8, wd=0.01):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree.map(lambda m: m / (1 - b1**t), m)
    vh = jax.tree.map(lambda v: v / (1 - b2**t), v)
    new_params = jax.tree.map(
        lambda p, mh, vh: p - lr * (mh / (jnp.sqrt(vh) + eps) + wd * p),
        params, mh, vh,
    )
    return new_params, {"m": m, "v": v, "t": t}


def train(cfg: ModelConfig, steps: int, batch: int, seq: int, out_dir: str,
          lr: float = 3e-3, seed: int = 0, log_every: int = 20) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    corpus_dir = os.path.join(out_dir, "corpus")
    sizes = data_mod.write_corpora(corpus_dir)
    print(f"corpora: {sizes}")

    prose = np.frombuffer(
        open(os.path.join(corpus_dir, "prose_train.bin"), "rb").read(), np.uint8
    )
    code = np.frombuffer(
        open(os.path.join(corpus_dir, "code_train.bin"), "rb").read(), np.uint8
    )
    # train on the mixture of both domains
    corpus = np.concatenate([prose, code])

    params = model_mod.init_params(jax.random.PRNGKey(seed), cfg)
    opt = adamw_init(params)

    @jax.jit
    def step_fn(params, opt, tokens, lr):
        (loss, aux), grads = jax.value_and_grad(
            lambda p: model_mod.loss_fn(p, tokens, cfg), has_aux=True
        )(params)
        params, opt = adamw_update(params, grads, opt, lr)
        return params, opt, loss, aux

    rng = np.random.default_rng(seed)
    stream = batches(corpus, batch, seq, rng)
    log = []
    t0 = time.time()
    for step in range(steps):
        warm = min(1.0, (step + 1) / 50)
        cos = 0.5 * (1 + np.cos(np.pi * step / steps))
        cur_lr = lr * warm * (0.1 + 0.9 * cos)
        tokens = jnp.asarray(next(stream))
        params, opt, loss, aux = step_fn(params, opt, tokens, cur_lr)
        if step % log_every == 0 or step == steps - 1:
            rec = {
                "step": step,
                "loss": float(loss),
                "nll": float(aux["nll"]),
                "aux": float(aux["aux"]),
                "lr": float(cur_lr),
                "elapsed_s": round(time.time() - t0, 1),
            }
            log.append(rec)
            print(rec, flush=True)

    flat = flatten_params(params, cfg)
    np.savez(os.path.join(out_dir, "weights.npz"), **flat)
    with open(os.path.join(out_dir, "train_log.json"), "w") as f:
        json.dump({"config": json.loads(cfg.to_json()), "log": log,
                   "corpora": sizes}, f, indent=2)
    print(f"saved weights ({sum(v.size for v in flat.values())} params)")
    return {"log": log}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--out", type=str, default="../artifacts")
    args = ap.parse_args()
    train(TINY, args.steps, args.batch, args.seq, args.out, lr=args.lr)


if __name__ == "__main__":
    main()
