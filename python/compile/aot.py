"""AOT pipeline: lower every decode module to HLO *text* artifacts.

HLO text — NOT ``lowered.compiler_ir("hlo")`` protos or ``.serialize()`` —
is the interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids that the rust crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Every module is lowered with ``return_tuple=True`` so the rust side unwraps
a tuple uniformly. Outputs:

    artifacts/
      embed.hlo.txt  attn.hlo.txt  prefill_attn.hlo.txt
      gate.hlo.txt   prefill_gate.hlo.txt
      expert.hlo.txt prefill_expert.hlo.txt
      expert_q{2,3,4}.hlo.txt  prefill_expert_q{2,3,4}.hlo.txt
      lm_head.hlo.txt
      manifest.json   (model config + per-module arg shapes/dtypes)

Usage: python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import functools
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_mod
from .config import TINY, ModelConfig

F32 = jnp.float32
U8 = jnp.uint8
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring).

    CRITICAL: default HLO printing elides large constants as ``{...}``,
    which xla_extension 0.5.1's text parser silently mis-parses (it fills
    the tensor with the first element — rotary-embedding frequency tables
    become all-ones). Print with ``print_large_constants``.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # new-style metadata attributes (source_end_line etc.) are rejected by
    # the 0.5.1 text parser — strip metadata entirely.
    opts.print_metadata = False
    text = comp.get_hlo_module().to_string(opts)
    assert "{...}" not in text, "elided constants survived printing"
    return text


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def module_table(cfg: ModelConfig) -> dict[str, tuple]:
    """(fn, example_args) per artifact. Quantized experts get one module per
    bit-width only because scale/zero *shapes* are bitwidth-independent but
    we keep separate artifacts anyway: the rust side keys executables by
    scheme, and future sub-byte packed layouts would diverge per bit."""
    D, V, E = cfg.d_model, cfg.vocab_size, cfg.n_experts
    FF, S, g = cfg.d_ff, cfg.max_seq, cfg.group_size
    C = cfg.prefill_chunk
    kv_cache = _spec((S, cfg.n_kv_heads, cfg.head_dim))

    def attn_args(t):
        x = _spec((t, D))
        return (
            x, _spec((D,)), _spec((D, cfg.q_dim)), _spec((D, cfg.kv_dim)),
            _spec((D, cfg.kv_dim)), _spec((cfg.q_dim, D)), kv_cache, kv_cache,
            _spec((), I32),
        )

    def expert_args(t):
        return (_spec((t, D)), _spec((D, FF)), _spec((D, FF)), _spec((FF, D)))

    def group_for(bits):
        # paper §4.2: 2-bit uses group size 16; 3/4-bit use the model group
        return min(16, g) if bits == 2 else g

    def expert_q_args(t, bits):
        gb = group_for(bits)
        qup, sup = _spec((D, FF), U8), _spec((D // gb, FF))
        qdn, sdn = _spec((FF, D), U8), _spec((FF // gb, D))
        return (_spec((t, D)), qup, sup, sup, qup, sup, sup, qdn, sdn, sdn)

    mods = {
        "embed": (model_mod.embed_mod, (_spec((1,), I32), _spec((V, D)))),
        "attn": (functools.partial(model_mod.attn_mod, cfg=cfg), attn_args(1)),
        "prefill_attn": (
            functools.partial(model_mod.prefill_attn_mod, cfg=cfg), attn_args(C)),
        "gate": (
            functools.partial(model_mod.gate_mod, cfg=cfg),
            (_spec((1, D)), _spec((D,)), _spec((D, E)))),
        "prefill_gate": (
            functools.partial(model_mod.gate_mod, cfg=cfg),
            (_spec((C, D)), _spec((D,)), _spec((D, E)))),
        "expert": (functools.partial(model_mod.expert_mod, cfg=cfg), expert_args(1)),
        "prefill_expert": (
            functools.partial(model_mod.expert_mod, cfg=cfg), expert_args(C)),
        "lm_head": (
            functools.partial(model_mod.lm_head_mod, cfg=cfg),
            (_spec((1, D)), _spec((D,)), _spec((D, V)))),
        "prefill_lm_head": (
            functools.partial(model_mod.lm_head_mod, cfg=cfg),
            (_spec((C, D)), _spec((D,)), _spec((D, V)))),
    }
    for bits in (2, 3, 4):
        fn = functools.partial(model_mod.expert_q_mod, cfg=cfg, group_size=group_for(bits))
        mods[f"expert_q{bits}"] = (fn, expert_q_args(1, bits))
        mods[f"prefill_expert_q{bits}"] = (fn, expert_q_args(C, bits))
    return mods


def describe(args) -> list[dict]:
    return [{"shape": list(a.shape), "dtype": a.dtype.name} for a in args]


def build(cfg: ModelConfig, out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"config": json.loads(cfg.to_json()), "modules": {}}
    for name, (fn, args) in module_table(cfg).items():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["modules"][name] = {
            "file": f"{name}.hlo.txt",
            "args": describe(args),
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            "bytes": len(text),
        }
        print(f"lowered {name:24s} {len(text):>9d} chars")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", type=str, default="../artifacts")
    args = ap.parse_args()
    build(TINY, args.out)


if __name__ == "__main__":
    main()
