"""Model configuration shared by the trainer, the AOT pipeline and tests.

The rust side reads the JSON emitted into ``artifacts/manifest.json`` — keep
field names stable (they are mirrored by ``rust/src/config/model.rs``).
"""

from __future__ import annotations

import dataclasses
import json


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Mixtral-architecture decoder, scaled to tiny-corpus size.

    Same architecture class as Mixtral-8x7B (GQA + rotary + RMSNorm +
    top-2-of-8 SwiGLU experts); dimensions scaled so the model trains on CPU
    in minutes. The offloading system's behaviour depends on the
    architecture (residual stream, per-layer routing), not on absolute size.
    """

    vocab_size: int = 256          # byte-level tokenizer
    d_model: int = 128
    n_layers: int = 6
    n_heads: int = 4
    n_kv_heads: int = 2            # GQA, like Mixtral
    head_dim: int = 32
    d_ff: int = 256                # per-expert FFN width
    n_experts: int = 8
    top_k: int = 2
    max_seq: int = 512
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    group_size: int = 32           # quantization group size (along input dim)
    prefill_chunk: int = 16        # chunked-prefill module width

    def __post_init__(self) -> None:
        assert self.n_heads % self.n_kv_heads == 0
        assert self.d_model % self.group_size == 0
        assert self.d_ff % self.group_size == 0
        assert self.top_k <= self.n_experts

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2)

    @staticmethod
    def from_json(text: str) -> "ModelConfig":
        return ModelConfig(**json.loads(text))


TINY = ModelConfig()

# An even smaller config for fast property-based tests.
TEST = ModelConfig(
    d_model=64,
    n_layers=2,
    n_heads=2,
    n_kv_heads=1,
    head_dim=16,
    d_ff=64,
    n_experts=4,
    top_k=2,
    max_seq=64,
    group_size=16,
    prefill_chunk=8,
)
