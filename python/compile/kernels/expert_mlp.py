"""L1 Pallas kernel: streaming SwiGLU expert FFN.

The expert FFN is the offloading hot spot — for each routed token the engine
runs ``(silu(x @ w1) * (x @ w3)) @ w2`` against freshly-transferred expert
weights. The kernel streams the FF dimension in tiles so the full [D, FF]
panels never need to be resident at once:

    for each FF tile f:
        h_f  = silu(x @ W1[:, f]) * (x @ W3[:, f])
        y   += h_f @ W2[f, :]

TPU mapping (see DESIGN.md §Hardware-Adaptation): each grid step holds one
``[D, block_ff]`` panel pair plus one ``[block_ff, D]`` down panel in VMEM —
the BlockSpec index maps express the HBM→VMEM schedule that the paper's CUDA
implementation expressed with threadblocks. The two contractions per step
are MXU-shaped ([T, D] x [D, block_ff]); the accumulator stays in VMEM
across steps (output block index map is constant).

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU efficiency is estimated analytically in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_FF = 128


def _swiglu_kernel(x_ref, w1_ref, w3_ref, w2_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    up = x @ w1_ref[...]
    gate = x @ w3_ref[...]
    h = up * jax.nn.sigmoid(up) * gate
    o_ref[...] += h @ w2_ref[...]


@functools.partial(jax.jit, static_argnames=("block_ff",))
def swiglu(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array,
           block_ff: int | None = None) -> jax.Array:
    """Fused SwiGLU FFN. x: [T, D]; w1/w3: [D, FF]; w2: [FF, D] -> [T, D]."""
    t, d = x.shape
    ff = w1.shape[1]
    if block_ff is None:
        block_ff = min(ff, DEFAULT_BLOCK_FF)
    assert ff % block_ff == 0, (ff, block_ff)
    grid = ff // block_ff

    return pl.pallas_call(
        _swiglu_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((t, d), lambda i: (0, 0)),
            pl.BlockSpec((d, block_ff), lambda i: (0, i)),
            pl.BlockSpec((d, block_ff), lambda i: (0, i)),
            pl.BlockSpec((block_ff, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((t, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        interpret=True,
    )(x, w1, w3, w2)


def vmem_bytes(d: int, ff: int, t: int = 1, block_ff: int = DEFAULT_BLOCK_FF,
               weight_bytes: int = 4) -> int:
    """Analytic VMEM footprint of one grid step (perf-model input).

    Two up panels + one down panel + x + accumulator + h tile.
    """
    panels = 3 * d * block_ff * weight_bytes
    act = (t * d + t * d + t * block_ff) * 4
    return panels + act
