"""L1 Pallas kernel: fused group-dequant + SwiGLU expert FFN.

This is the quantized-offloading hot path: expert weights arrive on the
device as uint8 codes plus per-group (scale, zero) — only the codes cross
the host→device link at 2/3/4 logical bits per weight — and are expanded to
f32 *inside the kernel*, one VMEM-resident tile at a time. f32 weights never
exist in HBM, which is exactly the memory-traffic property the paper's HQQ
CUDA kernels provide on GPU.

Group layout: groups of ``group_size`` run along each weight's input
dimension, so a ``[D, block_ff]`` code tile needs a ``[D/g, block_ff]``
scale/zero tile — the BlockSpec index maps keep them aligned.

Dequant is pure VPU work ((c - zero) * scale over a [G, g, bf] view); the
MXU consumes the expanded tile immediately. interpret=True (CPU plugin);
TPU efficiency is estimated analytically in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_FF = 128


def _dequant_tile(codes, scale, zero, group_size: int):
    """Expand a [In, Out] uint8 tile with [In/g, Out] scale/zero to f32."""
    n_in, n_out = codes.shape
    g = n_in // group_size
    c = codes.astype(jnp.float32).reshape(g, group_size, n_out)
    w = (c - zero[:, None, :]) * scale[:, None, :]
    return w.reshape(n_in, n_out)


def _make_kernel(group_size: int):
    def kernel(x_ref, q1_ref, s1_ref, z1_ref, q3_ref, s3_ref, z3_ref,
               q2_ref, s2_ref, z2_ref, o_ref):
        @pl.when(pl.program_id(0) == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        x = x_ref[...]
        w1 = _dequant_tile(q1_ref[...], s1_ref[...], z1_ref[...], group_size)
        w3 = _dequant_tile(q3_ref[...], s3_ref[...], z3_ref[...], group_size)
        w2 = _dequant_tile(q2_ref[...], s2_ref[...], z2_ref[...], group_size)
        up = x @ w1
        gate = x @ w3
        h = up * jax.nn.sigmoid(up) * gate
        o_ref[...] += h @ w2

    return kernel


@functools.partial(jax.jit, static_argnames=("group_size", "block_ff"))
def dequant_swiglu(x, q1, s1, z1, q3, s3, z3, q2, s2, z2, *,
                   group_size: int, block_ff: int | None = None) -> jax.Array:
    """Fused dequant + SwiGLU.

    x: [T, D] f32.
    q1/q3: uint8 [D, FF], s1/z1/s3/z3: f32 [D/g, FF]   (up/gate projections)
    q2:    uint8 [FF, D], s2/z2:       f32 [FF/g, D]   (down projection)
    Returns [T, D] f32.
    """
    t, d = x.shape
    ff = q1.shape[1]
    if block_ff is None:
        block_ff = min(ff, DEFAULT_BLOCK_FF)
    assert ff % block_ff == 0 and block_ff % group_size == 0
    gd = d // group_size
    gbf = block_ff // group_size
    grid = ff // block_ff

    return pl.pallas_call(
        _make_kernel(group_size),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((t, d), lambda i: (0, 0)),
            pl.BlockSpec((d, block_ff), lambda i: (0, i)),       # q1
            pl.BlockSpec((gd, block_ff), lambda i: (0, i)),      # s1
            pl.BlockSpec((gd, block_ff), lambda i: (0, i)),      # z1
            pl.BlockSpec((d, block_ff), lambda i: (0, i)),       # q3
            pl.BlockSpec((gd, block_ff), lambda i: (0, i)),      # s3
            pl.BlockSpec((gd, block_ff), lambda i: (0, i)),      # z3
            pl.BlockSpec((block_ff, d), lambda i: (i, 0)),       # q2
            pl.BlockSpec((gbf, d), lambda i: (i, 0)),            # s2
            pl.BlockSpec((gbf, d), lambda i: (i, 0)),            # z2
        ],
        out_specs=pl.BlockSpec((t, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        interpret=True,
    )(x, q1, s1, z1, q3, s3, z3, q2, s2, z2)


def vmem_bytes(d: int, ff: int, group_size: int, t: int = 1,
               block_ff: int = DEFAULT_BLOCK_FF) -> int:
    """Analytic VMEM footprint of one grid step (perf-model input)."""
    codes = 3 * d * block_ff            # uint8 tiles
    meta = 2 * 3 * (d // group_size) * block_ff * 4
    expanded = 3 * d * block_ff * 4     # dequantized f32 tiles
    act = (2 * t * d + t * block_ff) * 4
    return codes + meta + expanded + act
