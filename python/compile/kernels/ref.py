"""Pure-jnp oracles for the Pallas kernels.

These define the ground-truth numerics; pytest asserts the Pallas kernels
(interpret=True) match them to float32 tolerance. The rust-side quantizer
(``rust/src/quant/hqq.rs``) mirrors ``quantize_group`` bit-for-bit — the
cross-language fixture test pins that down.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def silu(x):
    return x * jax.nn.sigmoid(x)


def swiglu_ref(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array) -> jax.Array:
    """SwiGLU FFN: (silu(x @ w1) * (x @ w3)) @ w2. x: [T, D]."""
    return (silu(x @ w1) * (x @ w3)) @ w2


def dequant_ref(codes: jax.Array, scale: jax.Array, zero: jax.Array, group_size: int) -> jax.Array:
    """Affine group dequantization along the first (input) dimension.

    codes: uint8 [In, Out]; scale/zero: f32 [In // group_size, Out].
    w[i, j] = (codes[i, j] - zero[g, j]) * scale[g, j],  g = i // group_size.
    """
    n_in, n_out = codes.shape
    g = n_in // group_size
    c = codes.astype(jnp.float32).reshape(g, group_size, n_out)
    w = (c - zero[:, None, :]) * scale[:, None, :]
    return w.reshape(n_in, n_out)


def dequant_swiglu_ref(x, q1, s1, z1, q3, s3, z3, q2, s2, z2, group_size: int) -> jax.Array:
    """Oracle for the fused dequant + SwiGLU kernel."""
    w1 = dequant_ref(q1, s1, z1, group_size)
    w3 = dequant_ref(q3, s3, z3, group_size)
    w2 = dequant_ref(q2, s2, z2, group_size)
    return swiglu_ref(x, w1, w3, w2)


def quantize_group(w: np.ndarray, bits: int, group_size: int):
    """Plain affine min/max group quantization (the HQQ starting point).

    Returns (codes uint8 [In, Out], scale f32 [G, Out], zero f32 [G, Out]).
    Groups run along the input (first) dimension, matching how weight panels
    stream through the kernel. The rust HQQ quantizer starts from this exact
    estimate before its half-quadratic refinement.
    """
    n_in, n_out = w.shape
    assert n_in % group_size == 0
    g = n_in // group_size
    wg = w.reshape(g, group_size, n_out).astype(np.float64)
    wmin = wg.min(axis=1)                      # [G, Out]
    wmax = wg.max(axis=1)
    qmax = float(2**bits - 1)
    scale = (wmax - wmin) / qmax
    scale = np.where(scale <= 1e-12, 1.0, scale)
    zero = -wmin / scale
    codes = np.clip(np.round(wg / scale[:, None, :] + zero[:, None, :]), 0, qmax)
    return (
        codes.reshape(n_in, n_out).astype(np.uint8),
        scale.astype(np.float32),
        zero.astype(np.float32),
    )
