"""Corpus construction for the tiny-model trainer and the evaluation suite.

Substitutions (DESIGN.md): the paper evaluates on WikiText2 / C4 perplexity
and OpenAssistant conversations. Without those datasets we build two
disjoint-domain corpora from text that ships with the environment, plus a
deterministic synthetic chat corpus:

* corpus A ("prose")  — English prose: Python's LICENSE/docstring text.
* corpus B ("code")   — Python source code from the standard library.
* chat corpus         — templated multi-turn conversations (OpenAssistant
  stand-in) generated with a seeded RNG.

Everything is byte-level (vocab 256) and fully deterministic.
"""

from __future__ import annotations

import glob
import io
import os
import random
import sysconfig
import tokenize

MAX_PROSE_BYTES = 400_000
MAX_CODE_BYTES = 400_000


def _stdlib_dir() -> str:
    return sysconfig.get_paths()["stdlib"]


def _docstrings_of(path: str) -> list[str]:
    """Extract string/comment tokens from a python file (prose-ish text)."""
    out = []
    try:
        with open(path, "rb") as f:
            for tok in tokenize.tokenize(f.readline):
                if tok.type == tokenize.STRING and len(tok.string) > 80:
                    out.append(tok.string.strip("\"' \n"))
    except Exception:
        pass
    return out


def build_prose_corpus() -> bytes:
    """Corpus A: English prose (LICENSE text + long stdlib docstrings)."""
    parts = []
    lib = _stdlib_dir()
    lic = os.path.join(lib, "LICENSE.txt")
    if os.path.exists(lic):
        parts.append(open(lic, "r", errors="ignore").read())
    for name in sorted(glob.glob(os.path.join(lib, "*.py"))):
        parts.extend(_docstrings_of(name))
        if sum(len(p) for p in parts) > MAX_PROSE_BYTES:
            break
    text = "\n\n".join(parts)
    return _to_bytes(text)[:MAX_PROSE_BYTES]


def build_code_corpus() -> bytes:
    """Corpus B: python source text (different domain than corpus A)."""
    parts = []
    lib = _stdlib_dir()
    for name in sorted(glob.glob(os.path.join(lib, "*.py")), reverse=True):
        try:
            parts.append(open(name, "r", errors="ignore").read())
        except OSError:
            continue
        if sum(len(p) for p in parts) > MAX_CODE_BYTES:
            break
    return _to_bytes("\n".join(parts))[:MAX_CODE_BYTES]


_CHAT_TOPICS = [
    ("how do I sort a list in python", "use the sorted function or the list sort method"),
    ("what is a mixture of experts model", "a sparse model where a gating function picks a few expert layers per token"),
    ("explain how an LRU cache works", "it evicts the least recently used entry when capacity is exceeded"),
    ("why is my program slow", "profile it first, then optimize the hottest function"),
    ("what does quantization do to a neural network", "it stores weights in fewer bits to save memory and bandwidth"),
    ("how does speculative loading help", "it guesses which experts are needed next and fetches them early"),
    ("what is the difference between ram and vram", "ram is host memory while vram sits on the graphics card"),
    ("how large is the mixtral model", "about forty seven billion parameters of which experts are most"),
    ("can I run large models on a laptop", "yes with offloading and aggressive quantization of the experts"),
    ("what is perplexity", "the exponential of the average negative log likelihood per token"),
]


def build_chat_corpus(n_conversations: int = 64, seed: int = 7) -> bytes:
    """Synthetic OpenAssistant stand-in: templated multi-turn chats."""
    rng = random.Random(seed)
    convs = []
    for _ in range(n_conversations):
        turns = []
        for _ in range(rng.randint(2, 5)):
            q, a = rng.choice(_CHAT_TOPICS)
            turns.append(f"<user> {q}?\n<assistant> {a}.\n")
        convs.append("".join(turns))
    return _to_bytes("\n".join(convs))


def _to_bytes(text: str) -> bytes:
    """ASCII-fold so every byte is < 128 (keeps the byte LM well-posed)."""
    return text.encode("ascii", errors="replace")


def train_eval_split(corpus: bytes, eval_frac: float = 0.1) -> tuple[bytes, bytes]:
    cut = int(len(corpus) * (1.0 - eval_frac))
    return corpus[:cut], corpus[cut:]


def write_corpora(out_dir: str) -> dict:
    """Materialise all corpora under ``out_dir``; returns a size manifest."""
    os.makedirs(out_dir, exist_ok=True)
    prose = build_prose_corpus()
    code = build_code_corpus()
    chat = build_chat_corpus()
    prose_train, prose_eval = train_eval_split(prose)
    code_train, code_eval = train_eval_split(code)
    files = {
        "prose_train.bin": prose_train,
        "prose_eval.bin": prose_eval,
        "code_train.bin": code_train,
        "code_eval.bin": code_eval,
        "chat.bin": chat,
    }
    for name, blob in files.items():
        with open(os.path.join(out_dir, name), "wb") as f:
            f.write(blob)
    return {k: len(v) for k, v in files.items()}
