"""Generate cross-language test fixtures: expected decode logits from the
trained weights, via the pure-jnp decode reference. The rust integration
tests (rust/tests/engine_numerics.rs) replay the same tokens through the
full PJRT engine (FP16 schemes) and must match.

Usage: python -m compile.fixtures --out ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax.numpy as jnp
import numpy as np

from . import model as model_mod
from .config import TINY
from .train import unflatten_params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", type=str, default="../artifacts")
    ap.add_argument("--n-tokens", type=int, default=10)
    args = ap.parse_args()

    flat = dict(np.load(os.path.join(args.out, "weights.npz")))
    params = unflatten_params(flat, TINY)

    prompt = "<user> what is a mixture of experts model?\n<assistant> "
    tokens = jnp.array([ord(c) for c in prompt[: args.n_tokens]], jnp.int32)
    logits = model_mod.decode_reference(params, tokens, TINY)  # [T, V]

    fixture = {
        "prompt_tokens": [int(t) for t in tokens],
        "argmax": [int(i) for i in jnp.argmax(logits, -1)],
        # first 8 logits of each position for tight numeric comparison
        "logits_head": [[float(x) for x in row[:8]] for row in np.asarray(logits)],
    }
    path = os.path.join(args.out, "decode_fixture.json")
    with open(path, "w") as f:
        json.dump(fixture, f)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
