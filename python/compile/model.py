"""L2: Mixtral-architecture MoE decoder in pure JAX.

Two views of the same model:

* ``forward_train`` — full-sequence forward used by the trainer and as the
  numerical oracle for the decode modules. Computes every expert densely and
  masks to the top-k so it stays vectorised (fine at tiny scale).
* ``*_mod`` functions — the per-module decode path that ``aot.py`` lowers to
  individual HLO artifacts. Weights are explicit arguments so one compiled
  executable serves every layer / expert. The rust engine chains these,
  owning the expert schedule (that is the paper's contribution).

The expert FFN modules call the Pallas kernels from ``kernels/`` so they
lower into the artifact HLO; everything else is plain jnp.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .kernels import expert_mlp as _expert_kernel
from .kernels import dequant_matmul as _dequant_kernel
from .kernels import ref as _ref


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    """RMSNorm over the last axis."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * w).astype(x.dtype)


def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables for rotary embedding at the given integer positions.

    Returns arrays of shape ``positions.shape + (head_dim // 2,)``.
    """
    inv_freq = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    ang = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Apply rotary embedding. ``x``: [..., n_heads, head_dim]; cos/sin
    broadcast over the head axis (shape [..., 1, head_dim//2])."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def _repeat_kv(x: jax.Array, n_rep: int) -> jax.Array:
    """[..., n_kv, hd] -> [..., n_kv * n_rep, hd] (GQA head sharing)."""
    if n_rep == 1:
        return x
    return jnp.repeat(x, n_rep, axis=-2)


def silu(x: jax.Array) -> jax.Array:
    return x * jax.nn.sigmoid(x)


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def init_params(rng: jax.Array, cfg: ModelConfig) -> dict:
    """Initialise the parameter pytree (all float32)."""

    def dense(key, fan_in, shape):
        return jax.random.normal(key, shape, jnp.float32) / jnp.sqrt(fan_in)

    keys = iter(jax.random.split(rng, 4 + cfg.n_layers * 8))
    params = {
        "embed": jax.random.normal(next(keys), (cfg.vocab_size, cfg.d_model)) * 0.02,
        "final_ln": jnp.ones((cfg.d_model,)),
        "lm_head": dense(next(keys), cfg.d_model, (cfg.d_model, cfg.vocab_size)),
        "layers": [],
    }
    for _ in range(cfg.n_layers):
        layer = {
            "attn_ln": jnp.ones((cfg.d_model,)),
            "wq": dense(next(keys), cfg.d_model, (cfg.d_model, cfg.q_dim)),
            "wk": dense(next(keys), cfg.d_model, (cfg.d_model, cfg.kv_dim)),
            "wv": dense(next(keys), cfg.d_model, (cfg.d_model, cfg.kv_dim)),
            "wo": dense(next(keys), cfg.q_dim, (cfg.q_dim, cfg.d_model)),
            "mlp_ln": jnp.ones((cfg.d_model,)),
            "w_gate": dense(next(keys), cfg.d_model, (cfg.d_model, cfg.n_experts)),
            "w1": dense(next(keys), cfg.d_model, (cfg.n_experts, cfg.d_model, cfg.d_ff)),
            "w3": dense(next(keys), cfg.d_model, (cfg.n_experts, cfg.d_model, cfg.d_ff)),
            "w2": dense(next(keys), cfg.d_ff, (cfg.n_experts, cfg.d_ff, cfg.d_model)),
        }
        params["layers"].append(layer)
    return params


# ---------------------------------------------------------------------------
# training-time forward (full sequence, dense experts masked to top-k)
# ---------------------------------------------------------------------------

def attention_full(layer: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Causal self-attention over a full sequence. x: [B, T, D]."""
    B, T, _ = x.shape
    h = rmsnorm(x, layer["attn_ln"], cfg.norm_eps)
    q = (h @ layer["wq"]).reshape(B, T, cfg.n_heads, cfg.head_dim)
    k = (h @ layer["wk"]).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    v = (h @ layer["wv"]).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)

    pos = jnp.arange(T)
    cos, sin = rope_angles(pos, cfg.head_dim, cfg.rope_theta)
    cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)

    n_rep = cfg.n_heads // cfg.n_kv_heads
    k, v = _repeat_kv(k, n_rep), _repeat_kv(v, n_rep)

    scores = jnp.einsum("bthd,bshd->bhts", q, k) / jnp.sqrt(cfg.head_dim)
    mask = jnp.tril(jnp.ones((T, T), bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhts,bshd->bthd", probs, v).reshape(B, T, cfg.q_dim)
    return x + out @ layer["wo"]


def moe_full(layer: dict, x: jax.Array, cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    """Top-k MoE FFN over a full sequence. Returns (output, router_probs).

    router_probs: full softmax over experts [B, T, E] — used by the
    load-balancing loss and by the activation-trace tooling.
    """
    h = rmsnorm(x, layer["mlp_ln"], cfg.norm_eps)
    logits = h @ layer["w_gate"]                       # [B, T, E]
    probs = jax.nn.softmax(logits, axis=-1)

    # top-k mask, then renormalise over the selected experts (Mixtral style:
    # softmax over the top-k logits == renormalised top-k softmax probs).
    top_vals, _ = jax.lax.top_k(probs, cfg.top_k)
    thresh = top_vals[..., -1:]
    mask = probs >= thresh
    weights = jnp.where(mask, probs, 0.0)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)

    # dense expert compute, masked — vectorised over experts.
    up = jnp.einsum("btd,edf->btef", h, layer["w1"])
    gate = jnp.einsum("btd,edf->btef", h, layer["w3"])
    act = silu(up) * gate
    expert_out = jnp.einsum("btef,efd->bted", act, layer["w2"])
    out = jnp.einsum("bted,bte->btd", expert_out, weights)
    return x + out, probs


def forward_train(params: dict, tokens: jax.Array, cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    """Full forward. tokens: [B, T] int32 -> (logits [B, T, V], router_probs [L, B, T, E])."""
    x = params["embed"][tokens]
    all_probs = []
    for layer in params["layers"]:
        x = attention_full(layer, x, cfg)
        x, probs = moe_full(layer, x, cfg)
        all_probs.append(probs)
    x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    return x @ params["lm_head"], jnp.stack(all_probs)


def loss_fn(params: dict, tokens: jax.Array, cfg: ModelConfig,
            aux_weight: float = 0.01) -> tuple[jax.Array, dict]:
    """Next-token cross-entropy + Switch-style load-balancing auxiliary loss."""
    logits, router_probs = forward_train(params, tokens[:, :-1], cfg)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1).mean()

    # load balancing: fraction of tokens routed to each expert (top-1 proxy)
    # times mean router prob, summed over experts, per layer.
    top1 = jnp.argmax(router_probs, axis=-1)                      # [L, B, T]
    frac = jnp.mean(
        jax.nn.one_hot(top1, cfg.n_experts, dtype=jnp.float32), axis=(1, 2)
    )                                                             # [L, E]
    mean_prob = jnp.mean(router_probs, axis=(1, 2))               # [L, E]
    aux = cfg.n_experts * jnp.sum(frac * mean_prob, axis=-1).mean()
    return nll + aux_weight * aux, {"nll": nll, "aux": aux}


# ---------------------------------------------------------------------------
# decode-path modules (lowered individually by aot.py)
# ---------------------------------------------------------------------------
# Conventions: x is [1, D]; the KV cache is [max_seq, n_kv_heads, head_dim]
# per layer, held by the rust engine and passed/returned each call; ``pos``
# is a scalar int32 giving the index of the token being decoded.

def embed_mod(token: jax.Array, embed: jax.Array) -> jax.Array:
    """(token i32[1], embed [V, D]) -> x [1, D]."""
    return embed[token]


def attn_mod(x, attn_ln, wq, wk, wv, wo, k_cache, v_cache, pos, *, cfg: ModelConfig):
    """Single-token attention block with residual. Returns (x', k', v')."""
    h = rmsnorm(x, attn_ln, cfg.norm_eps)
    q = (h @ wq).reshape(1, cfg.n_heads, cfg.head_dim)
    k = (h @ wk).reshape(1, cfg.n_kv_heads, cfg.head_dim)
    v = (h @ wv).reshape(1, cfg.n_kv_heads, cfg.head_dim)

    cos, sin = rope_angles(pos[None], cfg.head_dim, cfg.rope_theta)
    cos, sin = cos[:, None, :], sin[:, None, :]
    q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)

    k_cache = jax.lax.dynamic_update_slice(k_cache, k, (pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v, (pos, 0, 0))

    n_rep = cfg.n_heads // cfg.n_kv_heads
    ks = _repeat_kv(k_cache, n_rep)                    # [S, H, hd]
    vs = _repeat_kv(v_cache, n_rep)

    scores = jnp.einsum("qhd,shd->hqs", q, ks) / jnp.sqrt(cfg.head_dim)
    valid = jnp.arange(cfg.max_seq) <= pos
    scores = jnp.where(valid[None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("hqs,shd->qhd", probs, vs).reshape(1, cfg.q_dim)
    return x + out @ wo, k_cache, v_cache


def prefill_attn_mod(x, attn_ln, wq, wk, wv, wo, k_cache, v_cache, pos0, *, cfg: ModelConfig):
    """Chunked-prefill attention: x is [C, D], positions pos0..pos0+C-1.

    Padding convention: callers may pad the chunk; padded queries produce
    garbage rows that the engine discards, and padded keys land at positions
    beyond the valid range where the causal/absolute-position mask hides
    them until they are overwritten by the next chunk.
    """
    C = x.shape[0]
    h = rmsnorm(x, attn_ln, cfg.norm_eps)
    q = (h @ wq).reshape(C, cfg.n_heads, cfg.head_dim)
    k = (h @ wk).reshape(C, cfg.n_kv_heads, cfg.head_dim)
    v = (h @ wv).reshape(C, cfg.n_kv_heads, cfg.head_dim)

    positions = pos0 + jnp.arange(C)
    cos, sin = rope_angles(positions, cfg.head_dim, cfg.rope_theta)
    cos, sin = cos[:, None, :], sin[:, None, :]
    q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)

    k_cache = jax.lax.dynamic_update_slice(k_cache, k, (pos0, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v, (pos0, 0, 0))

    n_rep = cfg.n_heads // cfg.n_kv_heads
    ks = _repeat_kv(k_cache, n_rep)
    vs = _repeat_kv(v_cache, n_rep)

    scores = jnp.einsum("qhd,shd->hqs", q, ks) / jnp.sqrt(cfg.head_dim)
    key_pos = jnp.arange(cfg.max_seq)
    mask = key_pos[None, None, :] <= positions[None, :, None]
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("hqs,shd->qhd", probs, vs).reshape(C, cfg.q_dim)
    return x + out @ wo, k_cache, v_cache


def gate_mod(x, mlp_ln, w_gate, *, cfg: ModelConfig):
    """Router logits: (x [T, D]) -> (logits [T, E], h [T, D]).

    Also returns the normed hidden state ``h`` — the engine feeds the same
    ``h`` to the expert modules. For speculative loading (paper §3.2) the
    engine re-invokes this module with the NEXT layer's (mlp_ln, w_gate) on
    the CURRENT layer's residual — residual-stream continuity makes that a
    good guess of the next layer's routing.
    """
    h = rmsnorm(x, mlp_ln, cfg.norm_eps)
    return h @ w_gate, h


def expert_mod(h, w1, w3, w2, *, cfg: ModelConfig) -> jax.Array:
    """One expert's SwiGLU FFN on normed hidden state h [T, D] (Pallas L1)."""
    return _expert_kernel.swiglu(h, w1, w3, w2)


def expert_q_mod(h, q1, s1, z1, q3, s3, z3, q2, s2, z2, *, cfg: ModelConfig,
                 group_size: int | None = None) -> jax.Array:
    """Quantized expert: fused group-dequant + SwiGLU (Pallas L1).

    ``q*`` are uint8 codes; ``s*``/``z*`` are per-group scale/zero with
    groups along each weight's input dimension. ``group_size`` defaults to
    the model's but is overridden per bit-width by the AOT pipeline (the
    paper uses g=16 for 2-bit, g=64 for 3/4-bit).
    """
    g = group_size or cfg.group_size
    return _dequant_kernel.dequant_swiglu(
        h, q1, s1, z1, q3, s3, z3, q2, s2, z2, group_size=g
    )


def lm_head_mod(x, final_ln, lm_head, *, cfg: ModelConfig) -> jax.Array:
    """(x [1, D]) -> logits [1, V]."""
    return rmsnorm(x, final_ln, cfg.norm_eps) @ lm_head


# ---------------------------------------------------------------------------
# reference decode (pure jnp, used by tests to validate the module chain)
# ---------------------------------------------------------------------------

def decode_reference(params: dict, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Token-by-token decode using the *_mod chain with ref expert math.

    Returns logits for every position: [T, V]. Tests compare this against
    ``forward_train`` to prove the decode modules implement the same model.
    """
    T = int(tokens.shape[0])
    caches = [
        (
            jnp.zeros((cfg.max_seq, cfg.n_kv_heads, cfg.head_dim)),
            jnp.zeros((cfg.max_seq, cfg.n_kv_heads, cfg.head_dim)),
        )
        for _ in range(cfg.n_layers)
    ]
    outs = []
    for t in range(T):
        pos = jnp.int32(t)
        x = embed_mod(tokens[t : t + 1], params["embed"])
        for li, layer in enumerate(params["layers"]):
            kc, vc = caches[li]
            x, kc, vc = attn_mod(
                x, layer["attn_ln"], layer["wq"], layer["wk"], layer["wv"],
                layer["wo"], kc, vc, pos, cfg=cfg,
            )
            caches[li] = (kc, vc)
            logits, h = gate_mod(x, layer["mlp_ln"], layer["w_gate"], cfg=cfg)
            probs = jax.nn.softmax(logits, axis=-1)[0]
            top_idx = jnp.argsort(-probs)[: cfg.top_k]
            w = probs[top_idx]
            w = w / w.sum()
            y = jnp.zeros_like(x)
            for j in range(cfg.top_k):
                e = top_idx[j]
                eo = _ref.swiglu_ref(h, layer["w1"][e], layer["w3"][e], layer["w2"][e])
                y = y + w[j] * eo
            x = x + y
        outs.append(lm_head_mod(x, params["final_ln"], params["lm_head"], cfg=cfg)[0])
    return jnp.stack(outs)
