//! End-to-end serving driver (the DESIGN.md validation workload): boot the
//! full stack — artifacts → PJRT runtime → engine → coordinator → TCP
//! server — then fire a batch of chat requests at the socket and report
//! latency/throughput percentiles.
//!
//! ```bash
//! cargo run --release --example e2e_serving
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Instant;

use moe_offload::config::{HardwareProfile, OffloadPolicy, QuantScheme, ServingConfig, SimScale};
use moe_offload::config::Manifest;
use moe_offload::coordinator::{server::Server, Coordinator};
use moe_offload::engine::MoeEngine;
use moe_offload::harness;
use moe_offload::model::ModelWeights;
use moe_offload::util::json::Json;

const PROMPTS: &[&str] = &[
    "what is a mixture of experts model",
    "explain how an LRU cache works",
    "why is my program slow",
    "what does quantization do to a neural network",
    "how does speculative loading help",
    "can I run large models on a laptop",
    "what is the difference between ram and vram",
    "what is perplexity",
];

fn main() -> anyhow::Result<()> {
    let dir = harness::artifacts_dir()?;
    let dir2 = dir.clone();

    // 1. boot the full stack
    let coordinator = Arc::new(Coordinator::new(
        move || -> moe_offload::Result<MoeEngine> {
            let manifest = Manifest::load(&dir2)?;
            let weights = ModelWeights::load(
                &manifest.config,
                &dir2.join("weights.npz"),
                QuantScheme::Hqq { bits: 4 },
                QuantScheme::Hqq { bits: 3 },
            )?;
            let serving = ServingConfig {
                policy: OffloadPolicy::Full { cache_k: 2, spec_n: 2 },
                expert_quant: QuantScheme::Hqq { bits: 3 },
                attn_quant: QuantScheme::Hqq { bits: 4 },
                sim_scale: SimScale::Tiny,
                ..Default::default()
            };
            MoeEngine::new(&manifest, weights, &serving, HardwareProfile::rtx3060())
        },
        99,
    ));
    let server = Server::bind("127.0.0.1:0", Arc::clone(&coordinator))?;
    let addr = server.local_addr()?;
    std::thread::spawn(move || {
        let _ = server.serve(Some(1));
    });
    println!("=== e2e serving: {} requests against {addr} ===\n", PROMPTS.len());

    // 2. drive the socket like a client would
    let mut conn = TcpStream::connect(addr)?;
    let reader = BufReader::new(conn.try_clone()?);
    let mut lines = reader.lines();
    let mut latencies = Vec::new();
    let mut first_token_lats = Vec::new();
    let mut total_new_tokens = 0usize;
    let t_all = Instant::now();

    for prompt in PROMPTS {
        let t0 = Instant::now();
        writeln!(
            conn,
            r#"{{"prompt":"{prompt}","max_tokens":32,"temperature":0.9}}"#
        )?;
        conn.flush()?;
        let mut first_token = None;
        loop {
            let line = lines.next().expect("server closed")?;
            let v = Json::parse(&line)?;
            match v.get("type").and_then(Json::as_str) {
                Some("token") => {
                    first_token.get_or_insert_with(|| t0.elapsed().as_secs_f64());
                }
                Some("done") => {
                    let lat = t0.elapsed().as_secs_f64();
                    let n = v.get("new_tokens").unwrap().as_usize().unwrap();
                    total_new_tokens += n;
                    latencies.push(lat);
                    first_token_lats.push(first_token.unwrap_or(lat));
                    println!(
                        "  {prompt:52} {n:>3} tok  {lat:>6.2}s  ttft {:>5.2}s",
                        first_token.unwrap_or(lat)
                    );
                    break;
                }
                _ => anyhow::bail!("unexpected line: {line}"),
            }
        }
    }
    let wall = t_all.elapsed().as_secs_f64();

    // 3. report
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    first_token_lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |v: &[f64], q: f64| v[((v.len() - 1) as f64 * q) as usize];
    println!(
        "\nthroughput : {:.2} tokens/s end-to-end ({} tokens / {:.1}s wall)\n\
         latency    : p50 {:.2}s  p90 {:.2}s  max {:.2}s\n\
         ttft       : p50 {:.2}s  p90 {:.2}s\n\
         server     : {} ok / {} requests, mean request {:.2}s",
        total_new_tokens as f64 / wall,
        total_new_tokens,
        wall,
        pct(&latencies, 0.5),
        pct(&latencies, 0.9),
        latencies.last().unwrap(),
        pct(&first_token_lats, 0.5),
        pct(&first_token_lats, 0.9),
        coordinator.metrics.counter("requests_ok"),
        coordinator.metrics.counter("requests_started"),
        coordinator.metrics.histogram_mean("request_latency_s"),
    );
    Ok(())
}
