//! End-to-end serving driver: boot the full stack — artifacts → PJRT
//! runtime → engine → coordinator → TCP server — and fire a MIXED
//! workload at the socket: one long-prompt admission against three
//! chatty short-decode clients, concurrently, the head-of-line case the
//! chunked-prefill tick scheduler exists for. The workload runs twice —
//! synchronous admission, then chunked prefill — and reports what each
//! client experiences: time-to-first-token and the decode stalls the
//! long prefill inflicts on its neighbors.
//!
//! ```bash
//! cargo run --release --example e2e_serving
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Instant;

use moe_offload::config::Manifest;
use moe_offload::config::{HardwareProfile, OffloadPolicy, QuantScheme, ServingConfig, SimScale};
use moe_offload::coordinator::{server::Server, Coordinator};
use moe_offload::engine::MoeEngine;
use moe_offload::harness;
use moe_offload::model::ModelWeights;
use moe_offload::util::json::Json;

const SHORT_PROMPTS: &[&str] = &[
    "what is a mixture of experts model",
    "explain how an LRU cache works",
    "what does quantization do to a network",
];
const LONG_PROMPT_TOKENS: usize = 200;
const SHORT_MAX_TOKENS: usize = 24;

/// What one client measured: TTFT plus the wall gaps between its tokens.
struct ClientReport {
    ttft_s: f64,
    gaps_s: Vec<f64>,
    new_tokens: usize,
}

fn drive_client(
    addr: std::net::SocketAddr,
    prompt: &str,
    max_tokens: usize,
) -> anyhow::Result<ClientReport> {
    let mut conn = TcpStream::connect(addr)?;
    let reader = BufReader::new(conn.try_clone()?);
    writeln!(
        conn,
        r#"{{"prompt":"{prompt}","max_tokens":{max_tokens},"temperature":0.9,"chat":false}}"#
    )?;
    conn.flush()?;
    let mut stamps: Vec<Instant> = Vec::new();
    let mut ttft_s = 0.0f64;
    let mut new_tokens = 0usize;
    for line in reader.lines() {
        let line = line?;
        let v = Json::parse(&line)?;
        match v.get("type").and_then(Json::as_str) {
            Some("token") => stamps.push(Instant::now()),
            Some("done") => {
                ttft_s = v.get("ttft_s").and_then(Json::as_f64).unwrap_or(0.0);
                new_tokens = v.get("new_tokens").and_then(Json::as_usize).unwrap_or(0);
                break;
            }
            _ => anyhow::bail!("unexpected line: {line}"),
        }
    }
    let gaps_s = stamps
        .windows(2)
        .map(|w| w[1].duration_since(w[0]).as_secs_f64())
        .collect();
    Ok(ClientReport { ttft_s, gaps_s, new_tokens })
}

/// Boot one full stack and run the mixed workload against the socket.
/// Returns (long ttft, short ttft p50, stall p50, stall p99, tokens/s).
fn run_mode(dir: &std::path::Path, chunked: bool) -> anyhow::Result<(f64, f64, f64, f64, f64)> {
    let dir2 = dir.to_path_buf();
    let coordinator = Arc::new(Coordinator::new(
        move || -> moe_offload::Result<MoeEngine> {
            let manifest = Manifest::load(&dir2)?;
            let weights = ModelWeights::load(
                &manifest.config,
                &dir2.join("weights.npz"),
                QuantScheme::Hqq { bits: 4 },
                QuantScheme::Hqq { bits: 3 },
            )?;
            let serving = ServingConfig {
                policy: OffloadPolicy::Full { cache_k: 2, spec_n: 2 },
                expert_quant: QuantScheme::Hqq { bits: 3 },
                attn_quant: QuantScheme::Hqq { bits: 4 },
                sim_scale: SimScale::Tiny,
                max_concurrent_sessions: 4,
                chunked_prefill: chunked,
                ..Default::default()
            };
            MoeEngine::new(&manifest, weights, &serving, HardwareProfile::rtx3060())
        },
        99,
    ));
    let server = Server::bind("127.0.0.1:0", Arc::clone(&coordinator))?;
    let addr = server.local_addr()?;
    std::thread::spawn(move || {
        let _ = server.serve(Some(SHORT_PROMPTS.len() + 1));
    });

    let t_all = Instant::now();
    // chatty short decoders first, then the long admission they must
    // survive
    let shorts: Vec<_> = SHORT_PROMPTS
        .iter()
        .map(|p| {
            let p = p.to_string();
            std::thread::spawn(move || drive_client(addr, &p, SHORT_MAX_TOKENS))
        })
        .collect();
    let long_prompt = "x".repeat(LONG_PROMPT_TOKENS);
    let long = drive_client(addr, &long_prompt, 4)?;

    let mut short_ttfts: Vec<f64> = Vec::new();
    let mut gaps: Vec<f64> = Vec::new();
    let mut total_tokens = long.new_tokens;
    for h in shorts {
        let r = h.join().expect("client thread")?;
        short_ttfts.push(r.ttft_s);
        gaps.extend(r.gaps_s);
        total_tokens += r.new_tokens;
    }
    let wall = t_all.elapsed().as_secs_f64();
    short_ttfts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    gaps.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |v: &[f64], q: f64| {
        if v.is_empty() {
            0.0
        } else {
            v[((v.len() - 1) as f64 * q) as usize]
        }
    };
    println!(
        "  {} admission: long ttft {:.3}s | short ttft p50 {:.3}s | decode stall \
         p50 {:.4}s p99 {:.4}s | {} mixed ticks | {:.1} tok/s end-to-end",
        if chunked { "chunked   " } else { "synchronous" },
        long.ttft_s,
        pct(&short_ttfts, 0.5),
        pct(&gaps, 0.5),
        pct(&gaps, 0.99),
        coordinator.metrics.gauge("mixed_ticks"),
        total_tokens as f64 / wall,
    );
    Ok((
        long.ttft_s,
        pct(&short_ttfts, 0.5),
        pct(&gaps, 0.5),
        pct(&gaps, 0.99),
        total_tokens as f64 / wall,
    ))
}

fn main() -> anyhow::Result<()> {
    let dir = harness::artifacts_dir()?;
    println!(
        "=== e2e serving: one {LONG_PROMPT_TOKENS}-token admission vs {} chatty \
         decoders, synchronous vs chunked prefill ===\n",
        SHORT_PROMPTS.len()
    );
    let (sync_ttft, _, _, sync_p99, _) = run_mode(&dir, false)?;
    let (ch_ttft, _, _, ch_p99, _) = run_mode(&dir, true)?;
    println!(
        "\nchunked prefill: long ttft {:.2}x of synchronous, neighbor decode-stall \
         p99 {:.2}x",
        ch_ttft / sync_ttft.max(1e-9),
        ch_p99 / sync_p99.max(1e-9),
    );
    println!(
        "(the long admission trades a little TTFT for the neighbors' tail \
         latency — the Sarathi trade the tick planner makes tunable via \
         prefill_chunk_tokens / max_batch_tokens)"
    );
    Ok(())
}
