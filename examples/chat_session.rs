//! Multi-turn chat session — the paper's motivating workload (interactive
//! assistant on consumer hardware). Demonstrates KV-session reuse across
//! turns and how the expert cache stays warm between turns.
//!
//! ```bash
//! cargo run --release --example chat_session
//! ```

use moe_offload::config::{HardwareProfile, OffloadPolicy, QuantScheme, SimScale};
use moe_offload::harness;
use moe_offload::model::{ByteTokenizer, Sampler};

fn main() -> anyhow::Result<()> {
    let dir = harness::artifacts_dir()?;
    let mut engine = harness::build_engine(
        &dir,
        QuantScheme::Hqq { bits: 4 },
        QuantScheme::Hqq { bits: 2 },
        OffloadPolicy::Full { cache_k: 4, spec_n: 2 },
        HardwareProfile::rtx3080_mobile(),
        SimScale::Tiny,
    )?;
    let tokenizer = ByteTokenizer::new();
    let mut sampler = Sampler::new(0.8, 0.95, 7);
    let mut session = engine.new_session()?;

    let turns = [
        "what is a mixture of experts model",
        "explain how an LRU cache works",
        "how does speculative loading help",
    ];

    println!("=== interactive chat (RTX 3080 Mobile profile, 2-bit experts) ===\n");
    for (i, turn) in turns.iter().enumerate() {
        let hits_before: u64 = session.run.tokens.iter().map(|t| t.cache_hits + t.spec_hits).sum();
        let prompt = tokenizer.chat_turn(turn);
        if session.position() + prompt.len() + 48 >= engine.weights.cfg.max_seq {
            session.reset(&engine)?; // context full: new sequence, warm cache
        }
        let reply = engine.generate(&mut session, &prompt, 48, &mut sampler)?;
        let hits_after: u64 = session.run.tokens.iter().map(|t| t.cache_hits + t.spec_hits).sum();
        println!("[turn {}] <user> {turn}?", i + 1);
        println!("         <assistant> {}", tokenizer.decode(&reply).trim_end());
        println!(
            "         ({} expert-cache hits this turn, session pos {})\n",
            hits_after - hits_before,
            session.position()
        );
    }
    println!(
        "session totals: {} decode tokens, {:.2} tok/s simulated, hit ratio {:.1}%",
        session.run.decode_tokens(),
        session.run.tokens_per_s_sim(),
        session.run.hit_ratio() * 100.0
    );
    Ok(())
}
