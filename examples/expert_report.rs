//! Expert-flow observability report: run a real serving workload (width
//! 4, prefix cache, adaptive tiers) with the expert flight recorder on,
//! query the coordinator's `experts` report, verify the counterfactual
//! cache curves against the measured counters, and write the result as
//! `BENCH_10.json` at the repo root.
//!
//! The report answers the capacity-planning question the recorder
//! exists for: what would the hit rate have been at every cache size
//! k = 1..n_experts (LRU), how far is LRU from the clairvoyant OPT
//! bound, and — the anchoring invariant — simulated LRU at the engine's
//! ACTUAL cache_k must reproduce the measured hit/miss counts exactly.
//!
//! ```bash
//! make artifacts && cargo run --release --example expert_report
//! MOE_BENCH_SMOKE=1 cargo run --release --example expert_report  # tiny run
//! ```

use std::sync::Arc;

use moe_offload::config::{HardwareProfile, OffloadPolicy, QuantScheme, ServingConfig, SimScale};
use moe_offload::coordinator::{collect_events, Coordinator, Event, Request};
use moe_offload::engine::MoeEngine;
use moe_offload::harness;
use moe_offload::quant::TierPolicy;
use moe_offload::util::json::Json;

/// Pull `(k, hits, misses)` rows out of a curve array.
fn curve_rows(report: &Json, name: &str) -> anyhow::Result<Vec<(usize, u64, u64)>> {
    let arr = report
        .get("curves")
        .and_then(|c| c.get(name))
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("report missing curves.{name}"))?;
    let mut out = Vec::new();
    for p in arr {
        let k = p.get("k").and_then(Json::as_usize).unwrap_or(0);
        let h = p.get("hits").and_then(Json::as_f64).unwrap_or(-1.0);
        let m = p.get("misses").and_then(Json::as_f64).unwrap_or(-1.0);
        anyhow::ensure!(h >= 0.0 && m >= 0.0, "curves.{name} row missing hits/misses");
        out.push((k, h as u64, m as u64));
    }
    anyhow::ensure!(!out.is_empty(), "curves.{name} is empty");
    Ok(out)
}

fn main() -> anyhow::Result<()> {
    let dir = match harness::artifacts_dir() {
        Ok(d) => d,
        Err(e) => {
            // skip cleanly (and leave BENCH_10.json untouched) so the
            // example is runnable in a checkout without built artifacts
            println!("SKIP: {e}");
            return Ok(());
        }
    };
    let smoke = std::env::var("MOE_BENCH_SMOKE").is_ok();
    let (requests, max_tokens) = if smoke { (4usize, 12usize) } else { (12, 32) };
    const CACHE_K: usize = 2;

    let dir2 = dir.clone();
    let coordinator = Arc::new(Coordinator::new(
        move || -> moe_offload::Result<MoeEngine> {
            let serving = ServingConfig {
                policy: OffloadPolicy::Full { cache_k: CACHE_K, spec_n: 2 },
                expert_quant: QuantScheme::Hqq { bits: 3 },
                attn_quant: QuantScheme::Hqq { bits: 4 },
                sim_scale: SimScale::Tiny,
                max_concurrent_sessions: 4,
                prefix_cache: true,
                expert_tiers: TierPolicy::hot_cold(),
                expert_obs: true,
                ..Default::default()
            };
            // build_engine_with_serving threads expert_tiers into the
            // tiered weight load, so the pool carries per-tier copies
            harness::build_engine_with_serving(&dir2, &serving, HardwareProfile::rtx3060())
        },
        41,
    ));

    // a width-4 workload with shared prefixes (prefix-cache hits) and
    // distinct tails (real routing variety)
    let prompts = [
        "what is a mixture of experts model",
        "what is a mixture of experts model and why offload it",
        "explain how an LRU cache works",
        "explain how speculative expert loading works",
    ];
    println!(
        "serving {requests} requests x {max_tokens} tokens at width 4 with the \
         expert flight recorder on..."
    );
    let mut spec_recall_bp = 0u64;
    let mut spec_precision_bp = 0u64;
    let streams: Vec<_> = (0..requests)
        .map(|i| {
            let mut req = Request::new(prompts[i % prompts.len()]);
            req.max_tokens = max_tokens;
            req.temperature = 0.9;
            coordinator.submit(req)
        })
        .collect();
    for stream in streams {
        for ev in collect_events(stream) {
            match ev {
                Event::Done { spec_recall_bp: r, spec_precision_bp: p, .. } => {
                    spec_recall_bp = r;
                    spec_precision_bp = p;
                }
                Event::Error { message, .. } | Event::Failed { message, .. } => {
                    anyhow::bail!("request failed: {message}")
                }
                Event::Token { .. } => {}
            }
        }
    }

    let report = coordinator.experts()?;
    anyhow::ensure!(
        report.get("enabled").and_then(Json::as_bool) == Some(true),
        "expert_obs was on but the report says disabled"
    );

    // --- the anchoring invariant: simulated LRU at the engine's actual
    // cache_k reproduces the measured per-layer hit/miss counts exactly
    let measured = report
        .get("curves")
        .and_then(|c| c.get("measured"))
        .ok_or_else(|| anyhow::anyhow!("report missing curves.measured"))?;
    anyhow::ensure!(
        measured.get("anchored").and_then(Json::as_bool) == Some(true),
        "cache-curve anchor failed: simulated LRU at cache_k diverged from \
         the measured counters: {measured}"
    );
    let k_measured = measured.get("k").and_then(Json::as_usize).unwrap_or(0);
    anyhow::ensure!(
        k_measured == CACHE_K,
        "measured point sits at k={k_measured}, engine ran cache_k={CACHE_K}"
    );

    // --- curve properties: monotone in k, OPT dominates LRU everywhere
    let lru = curve_rows(&report, "lru")?;
    let opt = curve_rows(&report, "opt")?;
    anyhow::ensure!(lru.len() == opt.len(), "curve lengths differ");
    for w in lru.windows(2) {
        anyhow::ensure!(w[1].1 >= w[0].1, "LRU curve not monotone at k={}", w[1].0);
    }
    for w in opt.windows(2) {
        anyhow::ensure!(w[1].1 >= w[0].1, "OPT curve not monotone at k={}", w[1].0);
    }
    for (l, o) in lru.iter().zip(&opt) {
        anyhow::ensure!(
            o.1 >= l.1,
            "OPT ({}) below LRU ({}) at k={} — clairvoyance can't lose",
            o.1,
            l.1,
            l.0
        );
    }
    // the measured point must sit ON the LRU curve
    let on_curve = lru.iter().find(|(k, _, _)| *k == k_measured).expect("k on curve");
    let sim_hits = measured.get("sim_hits").and_then(Json::as_f64).unwrap_or(-1.0) as u64;
    anyhow::ensure!(
        on_curve.1 == sim_hits,
        "measured point (sim_hits {sim_hits}) is off the LRU curve ({})",
        on_curve.1
    );

    // --- the capacity-planning readout: what cache_k buys 90% hit rate?
    let total = (lru[0].1 + lru[0].2).max(1);
    let k90 = lru.iter().find(|(_, h, _)| *h as f64 / total as f64 >= 0.9);
    match k90 {
        Some((k, h, _)) => println!(
            "LRU reaches 90% hit rate at cache_k = {k} ({h}/{total} demand uses); \
             engine ran cache_k = {CACHE_K}"
        ),
        None => println!(
            "LRU never reaches 90% hit rate on this workload (max {:.1}% at \
             k = {}); engine ran cache_k = {CACHE_K}",
            100.0 * lru.last().unwrap().1 as f64 / total as f64,
            lru.last().unwrap().0
        ),
    }
    println!(
        "prefetch quality: spec_recall {:.1}% spec_precision {:.1}%",
        spec_recall_bp as f64 / 100.0,
        spec_precision_bp as f64 / 100.0
    );

    let doc = Json::obj(vec![
        ("bench", "expert_report".into()),
        ("schema", 1i64.into()),
        ("status", "measured".into()),
        ("sim_scale", "tiny".into()),
        ("smoke", smoke.into()),
        ("cache_k", CACHE_K.into()),
        ("spec_recall_bp", (spec_recall_bp as i64).into()),
        ("spec_precision_bp", (spec_precision_bp as i64).into()),
        ("report", report),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_10.json");
    std::fs::write(path, format!("{doc}\n"))?;
    println!("wrote {path}");
    Ok(())
}
