//! Span-trace export: drive a workload that exercises every [`SpanKind`]
//! — prefill, decode, speculative prefetch, adaptive re-tier reloads, a
//! KV preempt/resume round-trip, a prefix-cache seeded admission, and
//! (via a transient-only fault plan) injected-fault retries — then dump
//! the span ring as Chrome trace-event JSON, with the expert flight
//! recorder's residency/hit-rate counter tracks riding underneath, and
//! print the per-kind time breakdown.
//!
//! ```bash
//! make artifacts && cargo run --release --example trace_export
//! # writes trace.json — load it at https://ui.perfetto.dev
//! TRACE_OUT=/tmp/moe_trace.json cargo run --release --example trace_export
//! ```
//!
//! The exported JSON uses one Perfetto process per resource stream
//! (GPU, PCIe link) and one thread per session, so the lane layout
//! directly shows which session's work each reservation served and how
//! much link time the compute front actually hid.

use moe_offload::config::{HardwareProfile, OffloadPolicy, QuantScheme, ServingConfig, SimScale};
use moe_offload::fault::FaultPlan;
use moe_offload::harness;
use moe_offload::model::{ByteTokenizer, Sampler};
use moe_offload::quant::TierPolicy;
use moe_offload::trace::SpanKind;

fn main() -> anyhow::Result<()> {
    let dir = harness::artifacts_dir()?;

    let serving = ServingConfig {
        policy: OffloadPolicy::Full { cache_k: 2, spec_n: 2 },
        attn_quant: QuantScheme::Hqq { bits: 4 },
        expert_quant: QuantScheme::Hqq { bits: 3 },
        sim_scale: SimScale::Tiny,
        prefix_cache: true,
        // a tiny re-rank interval so the short run trips adaptive
        // re-tiering and the trace shows tier_reload transfers
        expert_tiers: TierPolicy { adapt_interval: 8, ..TierPolicy::hot_cold() },
        trace: true,
        // transient-only faults (recoverable by construction — output
        // stays bit-identical) so the trace shows fault_retry recovery
        // time on the link; the raised failure rate makes the short run
        // trip retries reliably
        faults: FaultPlan { transfer_fail_p: 0.35, ..FaultPlan::transient_smoke(7) },
        // flight recorder on: its residency / hit-rate samples become
        // ph:"C" counter tracks in the exported trace
        expert_obs: true,
        ..Default::default()
    };
    let mut engine =
        harness::build_engine_with_serving(&dir, &serving, HardwareProfile::rtx3060())?;
    let tokenizer = ByteTokenizer::new();
    let prompt = tokenizer.chat_turn("what is a mixture of experts model");
    let mut sampler = Sampler::proportional(7);

    // 1) a full request: prefill (attention / gate / expert_compute /
    //    lm_head + demand_load) then decode (adds embed, spec_prefetch,
    //    and — once the adapt interval trips — tier_reload)
    let mut first = engine.new_session()?;
    let reply = engine.generate(&mut first, &prompt, 32, &mut sampler)?;

    // 2) preempt + resume: the KV pages swap to host and back (kv_resume)
    engine.preempt_session(&mut first)?;
    engine.resume_session(&mut first)?;
    let last = *reply.last().expect("generate returned tokens");
    engine.decode_step(&mut first, last)?;

    // 3) cache the finished stream, then admit a second session on the
    //    same prompt: its prefill seeds from the cache (prefix_seed)
    engine.prefix_insert(&first, &prompt)?;
    let mut second = engine.new_session()?;
    let (_logits, reused) = engine.prefill_cached(&mut second, &prompt)?;
    engine.decode_step(&mut second, last)?;

    println!("{}", engine.tracer.breakdown_table().render());

    let totals = engine.tracer.kind_totals();
    let missing: Vec<&str> = totals
        .iter()
        .filter(|(_, busy)| *busy <= 0.0)
        .map(|(k, _)| k.label())
        .collect();
    if !missing.is_empty() {
        anyhow::bail!("span kinds missing from the trace: {}", missing.join(", "));
    }
    // sanity: the seeded admission actually reused cached positions —
    // otherwise the prefix_seed lane above is measuring nothing
    anyhow::ensure!(reused > 0, "prefix cache did not seed the second session");
    anyhow::ensure!(totals.len() == SpanKind::ALL.len());

    // fold the recorder's pending events and take a final counter
    // sample so the exported tracks cover the whole drive
    engine.obs_tick();
    let counters = engine.obs.chrome_counter_events();
    anyhow::ensure!(!counters.is_empty(), "flight recorder produced no counter samples");

    let out = std::env::var("TRACE_OUT").unwrap_or_else(|_| "trace.json".to_string());
    std::fs::write(
        &out,
        engine.tracer.chrome_trace_with_counters(&counters).to_string(),
    )?;
    println!(
        "wrote {} spans + {} counter samples ({} dropped) to {out} — load it at \
         https://ui.perfetto.dev",
        engine.tracer.len(),
        counters.len(),
        engine.tracer.dropped(),
    );
    Ok(())
}
