//! Chaos harness: replay the bursty workload under a seeded
//! transient-only fault plan (transfer failures, payload corruption,
//! KV-swap faults, link brownouts) and verify the resilience contract:
//! every request still finishes, retries are charged to the virtual
//! link, and the SLO rows absorb the recovery cost. Writes the report
//! as `BENCH_9.json` at the repo root.
//!
//! ```bash
//! make artifacts && cargo run --release --example chaos_harness
//! MOE_BENCH_SMOKE=1 cargo run --release --example chaos_harness  # tiny run
//! ```

use moe_offload::config::HardwareProfile;
use moe_offload::harness;
use moe_offload::load;
use moe_offload::util::json::Json;

fn main() -> anyhow::Result<()> {
    let dir = match harness::artifacts_dir() {
        Ok(d) => d,
        Err(e) => {
            // skip cleanly (and leave BENCH_9.json untouched) so the
            // example is runnable in a checkout without built artifacts
            println!("SKIP: {e}");
            return Ok(());
        }
    };
    let smoke = std::env::var("MOE_BENCH_SMOKE").is_ok();

    let profile = load::chaos(smoke);
    println!(
        "replaying {} under a transient-only fault plan ({} requests, width {}, ~{:.0} req/s)...",
        profile.name, profile.requests, profile.width, profile.arrival_rate_per_s
    );
    let report = load::run_profile(&dir, &profile, HardwareProfile::rtx3060())?;
    println!("  {}", report.summary());
    println!(
        "  faults_injected {} transfer_retries {} deadline_cancellations {}",
        report.faults_injected, report.transfer_retries, report.deadline_cancellations
    );

    // The chaos contract: transient faults are recoverable by
    // construction, so chaos degrades latency but never availability.
    anyhow::ensure!(
        report.requests_failed == 0,
        "chaos: {} requests failed under a transient-only plan",
        report.requests_failed
    );
    anyhow::ensure!(
        report.faults_injected > 0,
        "chaos: fault plan was enabled but injected nothing — plan or seed regressed"
    );
    anyhow::ensure!(
        report.transfer_retries > 0,
        "chaos: no transfer retries recorded — retry path never exercised"
    );

    let doc = Json::obj(vec![
        ("bench", "chaos_harness".into()),
        ("schema", 1i64.into()),
        ("status", "measured".into()),
        ("smoke", smoke.into()),
        ("profiles", Json::arr(vec![report.to_json()])),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_9.json");
    std::fs::write(path, format!("{doc}\n"))?;
    println!("wrote {path}");
    Ok(())
}
