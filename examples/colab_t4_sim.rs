//! "Free-tier Colab" scenario: the paper's headline claim is running
//! Mixtral-8x7B interactively on a T4 at ~2 tokens/s. This example runs
//! the tiny testbed with timing translated to Mixtral-8x7B geometry on
//! the T4 profile and prints a Table-2-style row, comparing the full
//! algorithm against naive offloading.
//!
//! ```bash
//! cargo run --release --example colab_t4_sim
//! ```

use moe_offload::config::{HardwareProfile, OffloadPolicy, QuantScheme, SimScale};
use moe_offload::harness;

fn main() -> anyhow::Result<()> {
    let dir = harness::artifacts_dir()?;
    let tokens = harness::chat_tokens(&dir, 64)?;
    let profile = HardwareProfile::t4_colab();

    println!("=== T4 (free Colab tier) — Mixtral-8x7B geometry, 2-bit experts ===\n");
    let mut results = Vec::new();
    for (label, policy) in [
        ("full algorithm (LRU k=4 + spec 2)", OffloadPolicy::Full { cache_k: 4, spec_n: 2 }),
        ("naive offloading (whole layer)", OffloadPolicy::Naive),
    ] {
        let mut engine = harness::build_engine(
            &dir,
            QuantScheme::Hqq { bits: 4 },
            QuantScheme::Hqq { bits: 2 },
            policy,
            profile.clone(),
            SimScale::Mixtral,
        )?;
        let sess = harness::run_teacher_forced(&mut engine, &tokens)?;
        let tps = sess.run.tokens_per_s_sim();
        println!(
            "{label:38} {tps:.3} tok/s   (hit ratio {:.1}%, {:.1} GB moved/100 tok)",
            sess.run.hit_ratio() * 100.0,
            sess.run.total_bytes() as f64 / 1e9 * (100.0 / tokens.len() as f64),
        );
        results.push(tps);
    }
    println!(
        "\nspeedup: {:.2}x (paper Table 2, T4 2-bit: 2.09 vs 0.66 ≈ 3.2x)\n\
         interactive threshold (~2 tok/s): {}",
        results[0] / results[1],
        if results[0] >= 1.5 { "MET" } else { "NOT MET" }
    );
    Ok(())
}
