//! Trace-replay load harness: replay the three built-in workload
//! profiles (bursty Poisson, multi-turn chat with shared prefixes,
//! long-context RAG) against the serving coordinator, report per-profile
//! TTFT/TPOT percentile SLO attainment plus the span ring's bottleneck
//! attribution and what-if speedup projections, and write the whole
//! report as `BENCH_8.json` at the repo root.
//!
//! ```bash
//! make artifacts && cargo run --release --example load_harness
//! MOE_BENCH_SMOKE=1 cargo run --release --example load_harness  # tiny run
//! ```

use moe_offload::config::HardwareProfile;
use moe_offload::harness;
use moe_offload::load;
use moe_offload::util::json::Json;

fn main() -> anyhow::Result<()> {
    let dir = match harness::artifacts_dir() {
        Ok(d) => d,
        Err(e) => {
            // skip cleanly (and leave BENCH_8.json untouched) so the
            // example is runnable in a checkout without built artifacts
            println!("SKIP: {e}");
            return Ok(());
        }
    };
    let smoke = std::env::var("MOE_BENCH_SMOKE").is_ok();

    let profiles = [load::bursty(smoke), load::chat(smoke), load::rag(smoke)];
    let mut rows = Vec::new();
    for profile in &profiles {
        println!(
            "replaying {} ({} requests, width {}, ~{:.0} req/s)...",
            profile.name, profile.requests, profile.width, profile.arrival_rate_per_s
        );
        let report = load::run_profile(&dir, profile, HardwareProfile::rtx3060())?;
        println!("  {}", report.summary());
        if let Some(whatif) = report.analysis.get("whatif").and_then(Json::as_arr) {
            for row in whatif {
                if let (Some(s), Some(x)) = (
                    row.get("scenario").and_then(Json::as_str),
                    row.get("speedup").and_then(Json::as_f64),
                ) {
                    println!("  what-if {s}: {x:.3}x");
                }
            }
        }
        anyhow::ensure!(
            report.requests_failed == 0,
            "{}: {} requests failed",
            profile.name,
            report.requests_failed
        );
        rows.push(report.to_json());
    }

    let doc = Json::obj(vec![
        ("bench", "load_harness".into()),
        ("schema", 1i64.into()),
        ("status", "measured".into()),
        ("smoke", smoke.into()),
        ("profiles", Json::arr(rows)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_8.json");
    std::fs::write(path, format!("{doc}\n"))?;
    println!("wrote {path}");
    Ok(())
}
