//! Quickstart: load the model, generate a reply to one chat prompt.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use moe_offload::config::{HardwareProfile, OffloadPolicy, QuantScheme, SimScale};
use moe_offload::harness;
use moe_offload::model::{ByteTokenizer, Sampler};

fn main() -> anyhow::Result<()> {
    let dir = harness::artifacts_dir()?;

    // the paper's recommended desktop setup: RTX 3060 (12 GB), 3-bit
    // experts, 4-bit attention, LRU k=2 + speculative pre-loading of 2
    let mut engine = harness::build_engine(
        &dir,
        QuantScheme::Hqq { bits: 4 },
        QuantScheme::Hqq { bits: 3 },
        OffloadPolicy::Full { cache_k: 2, spec_n: 2 },
        HardwareProfile::rtx3060(),
        SimScale::Tiny,
    )?;

    let tokenizer = ByteTokenizer::new();
    let prompt = tokenizer.chat_turn("what is a mixture of experts model");
    let mut sampler = Sampler::proportional(42);

    let mut session = engine.new_session()?;
    let reply = engine.generate(&mut session, &prompt, 64, &mut sampler)?;
    println!("prompt : <user> what is a mixture of experts model?");
    println!("reply  : {}", tokenizer.decode(&reply).trim_end());
    println!(
        "\nstats  : {} tokens | {:.2} tok/s (simulated {}) | {:.2} tok/s (cpu wall)\n\
         cache  : {:.1}% hit ratio | {} speculative hits | {:.1} MiB over the link",
        session.run.decode_tokens(),
        session.run.tokens_per_s_sim(),
        engine.cost.profile.name,
        session.run.tokens_per_s_wall(),
        session.run.hit_ratio() * 100.0,
        session.run.tokens.iter().map(|t| t.spec_hits).sum::<u64>(),
        session.run.total_bytes() as f64 / (1 << 20) as f64,
    );
    Ok(())
}
