//! Minimal benchmark harness (criterion is not in the offline crate set).
//!
//! Measures wall time over adaptive iteration counts, reports mean /
//! median / p95 and throughput. Used by all `cargo bench` targets
//! (`harness = false` bins).

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:<44} {:>10} iters   mean {:>12?}   median {:>12?}   p95 {:>12?}",
            self.name, self.iters, self.mean, self.median, self.p95
        );
    }

    pub fn print_throughput(&self, items: f64, unit: &str) {
        let per_s = items / self.mean.as_secs_f64();
        println!(
            "{:<44} mean {:>12?}   {:>12.1} {unit}/s",
            self.name, self.mean, per_s
        );
    }
}

/// Run `f` repeatedly for ~`budget_ms`, after a warmup, and collect stats.
pub fn bench(name: &str, budget_ms: u64, mut f: impl FnMut()) -> BenchResult {
    // warmup
    for _ in 0..3 {
        f();
    }
    // estimate single-iteration cost
    let t0 = Instant::now();
    f();
    let est = t0.elapsed().max(Duration::from_nanos(50));
    let target = Duration::from_millis(budget_ms);
    let iters = ((target.as_secs_f64() / est.as_secs_f64()).ceil() as u64).clamp(5, 100_000);

    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort();
    let sum: Duration = samples.iter().sum();
    BenchResult {
        name: name.to_string(),
        iters,
        mean: sum / iters as u32,
        median: samples[samples.len() / 2],
        p95: samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)],
    }
}

/// `black_box` stand-in to defeat optimisation of pure computations.
pub fn sink<T>(x: T) -> T {
    std::hint::black_box(x)
}
