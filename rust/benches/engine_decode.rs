//! End-to-end engine benchmarks: per-token decode cost on the CPU testbed
//! across policies and quantization schemes (one per Table 2 row), plus
//! prefill chunk throughput. These drive the §Perf optimization loop.
//!
//! Requires `make artifacts`; exits cleanly otherwise.

#[path = "bench_harness/mod.rs"]
mod bench_harness;

use bench_harness::bench;
use moe_offload::config::{HardwareProfile, OffloadPolicy, QuantScheme, SimScale};
use moe_offload::harness;

fn main() {
    let Ok(dir) = harness::artifacts_dir() else {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    };
    let tokens = harness::chat_tokens(&dir, 512).expect("chat corpus");

    println!("== engine decode benches (real PJRT CPU execution) ==");
    for (name, policy) in [
        ("full_k4_spec2", OffloadPolicy::Full { cache_k: 4, spec_n: 2 }),
        ("lru_only_k4", OffloadPolicy::LruOnly { cache_k: 4 }),
        ("on_demand", OffloadPolicy::OnDemand),
        ("naive", OffloadPolicy::Naive),
    ] {
        let mut engine = harness::build_engine(
            &dir,
            QuantScheme::Hqq { bits: 4 },
            QuantScheme::Hqq { bits: 3 },
            policy,
            HardwareProfile::rtx3060(),
            SimScale::Tiny,
        )
        .unwrap();
        let mut sess = engine.new_session().unwrap();
        let mut i = 0usize;
        let r = bench(&format!("decode_token_{name}_q3"), 2500, || {
            if sess.position() + 1 >= engine.weights.cfg.max_seq {
                sess.reset(&engine).unwrap();
            }
            engine.decode_step(&mut sess, tokens[i % tokens.len()]).unwrap();
            i += 1;
        });
        r.print();
    }

    for bits in [2u8, 4] {
        let mut engine = harness::build_engine(
            &dir,
            QuantScheme::Hqq { bits: 4 },
            QuantScheme::Hqq { bits },
            OffloadPolicy::Full { cache_k: 4, spec_n: 2 },
            HardwareProfile::rtx3060(),
            SimScale::Tiny,
        )
        .unwrap();
        let mut sess = engine.new_session().unwrap();
        let mut i = 0usize;
        let r = bench(&format!("decode_token_full_q{bits}"), 2500, || {
            if sess.position() + 1 >= engine.weights.cfg.max_seq {
                sess.reset(&engine).unwrap();
            }
            engine.decode_step(&mut sess, tokens[i % tokens.len()]).unwrap();
            i += 1;
        });
        r.print();
    }

    // prefill throughput (chunked path)
    let mut engine = harness::build_engine(
        &dir,
        QuantScheme::Hqq { bits: 4 },
        QuantScheme::Hqq { bits: 3 },
        OffloadPolicy::Full { cache_k: 4, spec_n: 2 },
        HardwareProfile::rtx3060(),
        SimScale::Tiny,
    )
    .unwrap();
    let chunk: Vec<u32> = tokens[..64].to_vec();
    let r = bench("prefill_64_tokens_chunked", 4000, || {
        let mut sess = engine.new_session().unwrap();
        engine.prefill(&mut sess, &chunk).unwrap();
    });
    r.print();
    println!(
        "prefill tokens/s (wall): {:.1}",
        64.0 / r.mean.as_secs_f64()
    );

    // host wall-time breakdown per module (perf-pass diagnostics)
    println!("\nper-module host wall time (from the prefill engine):");
    let mut entries: Vec<_> = engine.rt.stats.iter().collect();
    entries.sort_by(|a, b| b.1.wall_s.partial_cmp(&a.1.wall_s).unwrap());
    for (name, s) in entries {
        println!(
            "  {name:24} {:>8} calls  {:>9.3}s total  {:>9.1}µs/call",
            s.calls,
            s.wall_s,
            s.wall_s / s.calls.max(1) as f64 * 1e6
        );
    }
}
