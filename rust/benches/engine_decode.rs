//! End-to-end engine benchmarks: per-token decode cost on the CPU testbed
//! across policies and quantization schemes (one per Table 2 row), plus
//! prefill chunk throughput. These drive the §Perf optimization loop.
//!
//! Requires `make artifacts`; exits cleanly otherwise.

#[path = "bench_harness/mod.rs"]
mod bench_harness;

use bench_harness::bench;
use moe_offload::config::{
    HardwareProfile, OffloadPolicy, QuantScheme, ServingConfig, SimScale,
};
use moe_offload::coordinator::{Coordinator, Event, Request};
use moe_offload::harness;
use moe_offload::quant::TierPolicy;
use moe_offload::Error;

fn main() {
    let Ok(dir) = harness::artifacts_dir() else {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    };
    let tokens = harness::chat_tokens(&dir, 512).expect("chat corpus");
    // MOE_BENCH_SMOKE=1 (CI) shrinks budgets/tick counts so the bench
    // binary is exercised end to end without burning minutes; unset,
    // empty or "0" means a full measured run
    let smoke = std::env::var("MOE_BENCH_SMOKE")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    let token_budget_ms: u64 = if smoke { 100 } else { 2500 };
    let prefill_budget_ms: u64 = if smoke { 200 } else { 4000 };

    println!("== engine decode benches (real PJRT CPU execution) ==");
    for (name, policy) in [
        ("full_k4_spec2", OffloadPolicy::Full { cache_k: 4, spec_n: 2 }),
        ("lru_only_k4", OffloadPolicy::LruOnly { cache_k: 4 }),
        ("on_demand", OffloadPolicy::OnDemand),
        ("naive", OffloadPolicy::Naive),
    ] {
        let mut engine = harness::build_engine(
            &dir,
            QuantScheme::Hqq { bits: 4 },
            QuantScheme::Hqq { bits: 3 },
            policy,
            HardwareProfile::rtx3060(),
            SimScale::Tiny,
        )
        .unwrap();
        let mut sess = engine.new_session().unwrap();
        let mut i = 0usize;
        let r = bench(&format!("decode_token_{name}_q3"), token_budget_ms, || {
            if sess.position() + 1 >= engine.weights.cfg.max_seq {
                sess.reset();
            }
            engine.decode_step(&mut sess, tokens[i % tokens.len()]).unwrap();
            i += 1;
        });
        r.print();
    }

    for bits in [2u8, 4] {
        let mut engine = harness::build_engine(
            &dir,
            QuantScheme::Hqq { bits: 4 },
            QuantScheme::Hqq { bits },
            OffloadPolicy::Full { cache_k: 4, spec_n: 2 },
            HardwareProfile::rtx3060(),
            SimScale::Tiny,
        )
        .unwrap();
        let mut sess = engine.new_session().unwrap();
        let mut i = 0usize;
        let r = bench(&format!("decode_token_full_q{bits}"), token_budget_ms, || {
            if sess.position() + 1 >= engine.weights.cfg.max_seq {
                sess.reset();
            }
            engine.decode_step(&mut sess, tokens[i % tokens.len()]).unwrap();
            i += 1;
        });
        r.print();
    }

    // prefill throughput (chunked path)
    let mut engine = harness::build_engine(
        &dir,
        QuantScheme::Hqq { bits: 4 },
        QuantScheme::Hqq { bits: 3 },
        OffloadPolicy::Full { cache_k: 4, spec_n: 2 },
        HardwareProfile::rtx3060(),
        SimScale::Tiny,
    )
    .unwrap();
    let chunk: Vec<u32> = tokens[..64].to_vec();
    let r = bench("prefill_64_tokens_chunked", prefill_budget_ms, || {
        let mut sess = engine.new_session().unwrap();
        engine.prefill(&mut sess, &chunk).unwrap();
    });
    r.print();
    println!(
        "prefill tokens/s (wall): {:.1}",
        64.0 / r.mean.as_secs_f64()
    );

    // paged-KV admission: how many concurrent sessions fit a FIXED VRAM
    // budget. The pool is sized to exactly the bytes the pre-paging
    // engine reserved statically for `static_sessions` full sequences;
    // paged admission then packs short streams into the same budget.
    let max_seq = engine.weights.cfg.max_seq;
    let static_sessions = 2usize;
    let prompt_len = 32usize;
    let serving = ServingConfig {
        policy: OffloadPolicy::Full { cache_k: 4, spec_n: 2 },
        expert_quant: QuantScheme::Hqq { bits: 3 },
        attn_quant: QuantScheme::Hqq { bits: 4 },
        sim_scale: SimScale::Tiny,
        max_concurrent_sessions: 256,
        kv_block_tokens: 32,
        kv_pool_tokens: Some(static_sessions * max_seq),
        ..Default::default()
    };
    let mut paged = harness::build_engine_with_serving(&dir, &serving, HardwareProfile::rtx3060())
        .unwrap();
    let prompt: Vec<u32> = tokens[..prompt_len].to_vec();
    let t0 = std::time::Instant::now();
    let mut admitted = Vec::new();
    loop {
        let mut sess = match paged.new_session() {
            Ok(s) => s,
            Err(_) => break, // width cap — should not bind before the pool
        };
        match paged.prefill(&mut sess, &prompt) {
            Ok(_) => admitted.push(sess),
            Err(Error::KvPoolExhausted(_)) => break,
            Err(e) => panic!("unexpected admission failure: {e}"),
        }
    }
    let st = paged.kv_pool.stats();
    println!(
        "\nkv_admission @ fixed VRAM ({} KV tokens, {} blocks of {}): \
         static reservation {} sessions vs paged {} sessions of {}-token prompts \
         ({} blocks in use, {:.3}s to admit)",
        static_sessions * max_seq,
        st.total_blocks,
        paged.kv_pool.block_tokens(),
        static_sessions,
        admitted.len(),
        prompt_len,
        st.in_use_blocks,
        t0.elapsed().as_secs_f64(),
    );
    assert!(
        admitted.len() > static_sessions,
        "paged admission must beat static reservation at the same budget"
    );
    drop(admitted);

    // prefix reuse: N requests sharing an 80% prefix — time-to-first-
    // token (prefill wall time) and prefill tokens skipped, cache on vs
    // off. Mirrors the paper's motif: never recompute what you can cache.
    let prompt_len = (max_seq / 2).min(120).max(40);
    let shared_len = prompt_len * 4 / 5; // 80% shared prefix
    let n_requests = 8usize;
    let prompts: Vec<Vec<u32>> = (0..n_requests)
        .map(|i| {
            let mut p = tokens[..shared_len].to_vec();
            // deterministic per-request tails so every request diverges
            // from every other after the shared prefix
            p.extend(
                (0..prompt_len - shared_len).map(|j| ((i * 37 + j * 11 + 1) % 256) as u32),
            );
            p
        })
        .collect();
    let mk_serving = |prefix_cache: bool| ServingConfig {
        policy: OffloadPolicy::Full { cache_k: 4, spec_n: 2 },
        expert_quant: QuantScheme::Hqq { bits: 3 },
        attn_quant: QuantScheme::Hqq { bits: 4 },
        sim_scale: SimScale::Tiny,
        max_concurrent_sessions: 1,
        kv_block_tokens: 16,
        kv_pool_tokens: Some(4 * max_seq),
        prefix_cache,
        ..Default::default()
    };
    let mut results = Vec::new();
    for cache_on in [false, true] {
        let mut e =
            harness::build_engine_with_serving(&dir, &mk_serving(cache_on), HardwareProfile::rtx3060())
                .unwrap();
        let mut prefill_s = 0.0f64;
        let mut first_ttft_s = 0.0f64;
        let mut skipped = 0usize;
        for (i, prompt) in prompts.iter().enumerate() {
            let mut sess = e.new_session().unwrap();
            let t0 = std::time::Instant::now();
            let (_, reused) = e.prefill_cached(&mut sess, prompt).unwrap();
            let dt = t0.elapsed().as_secs_f64();
            if i == 0 {
                first_ttft_s = dt;
            } else {
                prefill_s += dt;
                skipped += reused;
            }
            e.prefix_insert(&sess, prompt).unwrap();
        }
        results.push((cache_on, first_ttft_s, prefill_s / (n_requests - 1) as f64, skipped));
    }
    println!(
        "\nprefix_reuse ({n_requests} requests of {prompt_len} tokens, {shared_len} shared):"
    );
    for (cache_on, cold_s, warm_mean_s, skipped) in &results {
        println!(
            "  cache {}: first prefill {:.4}s, later prefills mean {:.4}s, \
             prefill tokens skipped {}",
            if *cache_on { "on " } else { "off" },
            cold_s,
            warm_mean_s,
            skipped,
        );
    }
    let (_, _, off_mean, off_skipped) = results[0];
    let (_, _, on_mean, on_skipped) = results[1];
    assert_eq!(off_skipped, 0, "cache off must never skip prefill");
    assert!(
        on_skipped > 0,
        "requests sharing a prefix must skip prefill tokens with the cache on"
    );
    println!(
        "  => warm TTFT {:.2}x of cold, {} of {} later-request prefill tokens skipped",
        on_mean / off_mean.max(1e-12),
        on_skipped,
        (n_requests - 1) * prompt_len,
    );

    // batched decode: expert loads per tick and sim throughput, batched
    // layer-lockstep vs sequential round-robin, over a SHARED workload
    // of per-session streams drawn from the chat corpus at staggered
    // offsets. Emits the machine-readable perf trajectory to
    // ../BENCH_4.json (repo root).
    let ticks = if smoke { 8 } else { 64 };
    println!("\nbatched_decode ({ticks} ticks per run, full_k2_spec2):");
    let mut json_rows: Vec<String> = Vec::new();
    for width in [1usize, 4, 8] {
        let streams: Vec<Vec<u32>> = (0..width)
            .map(|i| (0..ticks).map(|t| tokens[(i * 97 + t) % tokens.len()]).collect())
            .collect();
        // (sim tokens/s, expert loads per tick, loads deduped, kernel calls)
        let run = |batched: bool| -> (f64, f64, u64, u64) {
            let serving = ServingConfig {
                policy: OffloadPolicy::Full { cache_k: 2, spec_n: 2 },
                expert_quant: QuantScheme::Hqq { bits: 3 },
                attn_quant: QuantScheme::Hqq { bits: 4 },
                sim_scale: SimScale::Tiny,
                max_concurrent_sessions: width,
                ..Default::default()
            };
            let mut engine =
                harness::build_engine_with_serving(&dir, &serving, HardwareProfile::rtx3060())
                    .unwrap();
            let mut sessions: Vec<moe_offload::engine::Session> =
                (0..width).map(|_| engine.new_session().unwrap()).collect();
            let sim0 = engine.timeline.now();
            for t in 0..ticks {
                if batched {
                    let tick_toks: Vec<u32> =
                        (0..width).map(|i| streams[i][t]).collect();
                    let mut refs: Vec<&mut moe_offload::engine::Session> =
                        sessions.iter_mut().collect();
                    for slot in engine.decode_batch(&mut refs, &tick_toks).unwrap() {
                        slot.unwrap();
                    }
                } else {
                    for (i, sess) in sessions.iter_mut().enumerate() {
                        engine.decode_step(sess, streams[i][t]).unwrap();
                    }
                }
            }
            let sim_s = engine.cost.scale_token_time(engine.timeline.now() - sim0);
            let loads: u64 = sessions.iter().map(|s| s.run.total_misses()).sum();
            (
                (width * ticks) as f64 / sim_s.max(1e-12),
                loads as f64 / ticks as f64,
                engine.batch.loads_deduped,
                engine.batch.kernel_calls,
            )
        };
        let (seq_tps, seq_loads, _, _) = run(false);
        let (bat_tps, bat_loads, deduped, kernel_calls) = run(true);
        println!(
            "  width {width}: sequential {seq_loads:.2} loads/tick {seq_tps:.1} tok/s(sim)  \
             batched {bat_loads:.2} loads/tick {bat_tps:.1} tok/s(sim)  \
             ({deduped} stagings deduped, {kernel_calls} kernel calls)"
        );
        if width >= 4 {
            assert!(
                bat_loads < seq_loads,
                "batched decode must stage strictly fewer experts per tick than \
                 sequential at width {width} ({bat_loads:.2} vs {seq_loads:.2})"
            );
        }
        json_rows.push(format!(
            concat!(
                "{{\"width\":{},",
                "\"sequential\":{{\"sim_tokens_per_s\":{:.3},\"expert_loads_per_tick\":{:.4}}},",
                "\"batched\":{{\"sim_tokens_per_s\":{:.3},\"expert_loads_per_tick\":{:.4},",
                "\"expert_loads_deduped\":{},\"batched_kernel_calls\":{}}}}}"
            ),
            width, seq_tps, seq_loads, bat_tps, bat_loads, deduped, kernel_calls
        ));
    }
    let bench_json = format!(
        concat!(
            "{{\"bench\":\"batched_decode\",\"schema\":1,\"status\":\"measured\",",
            "\"policy\":\"full_k2_spec2\",\"sim_scale\":\"tiny\",\"ticks\":{},",
            "\"smoke\":{},\"widths\":[{}]}}\n"
        ),
        ticks,
        smoke,
        json_rows.join(",")
    );
    let bench_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_4.json");
    match std::fs::write(bench_path, &bench_json) {
        Ok(()) => println!("  wrote {bench_path}"),
        Err(e) => eprintln!("  could not write {bench_path}: {e}"),
    }

    // chunked prefill: TTFT of a long admission and the decode stall it
    // inflicts on chatty neighbors, chunked vs synchronous, at width 4.
    // Decode stall = wall gap between consecutive streamed tokens of the
    // short requests (the p99 is what a synchronous prefill wrecks).
    // Emits the machine-readable trajectory to ../BENCH_5.json.
    let long_len = if smoke { 80 } else { 200 };
    let short_budget = if smoke { 8 } else { 24 };
    println!(
        "\nchunked_prefill (width 4: one {long_len}-token admission vs 3 chatty \
         {short_budget}-token decoders):"
    );
    // (long ttft_s, stall p50, stall p99, mixed ticks)
    let run_mixed_workload = |chunked: bool| -> (f64, f64, f64, u64) {
        let dir2 = dir.clone();
        let serving = ServingConfig {
            policy: OffloadPolicy::Full { cache_k: 2, spec_n: 2 },
            expert_quant: QuantScheme::Hqq { bits: 3 },
            attn_quant: QuantScheme::Hqq { bits: 4 },
            sim_scale: SimScale::Tiny,
            max_concurrent_sessions: 4,
            chunked_prefill: chunked,
            // budget-only stopping: identical stream lengths either mode
            stop_suffix: String::new(),
            ..Default::default()
        };
        let coord = Coordinator::new(
            move || {
                harness::build_engine_with_serving(&dir2, &serving, HardwareProfile::rtx3060())
            },
            11,
        );
        let shorts: Vec<_> = (0..3)
            .map(|i| {
                let mut r = Request::new(format!("chatty stream number {i} says hi"));
                r.chat = false;
                r.max_tokens = short_budget;
                coord.submit(r)
            })
            .collect();
        let mut long_req = Request::new("x".repeat(long_len));
        long_req.chat = false;
        long_req.max_tokens = 4;
        let long_stream = coord.submit(long_req);

        // drain every short stream on its own thread, timestamping tokens
        let collectors: Vec<_> = shorts
            .into_iter()
            .map(|s| {
                std::thread::spawn(move || {
                    let mut stamps = Vec::new();
                    for ev in s.events.iter() {
                        match ev {
                            Event::Token { .. } => stamps.push(std::time::Instant::now()),
                            Event::Done { .. } | Event::Error { .. } | Event::Failed { .. } => {
                                break
                            }
                        }
                    }
                    stamps
                })
            })
            .collect();
        // the long request's TTFT comes straight from its done event
        let mut long_ttft = 0.0f64;
        for ev in long_stream.events.iter() {
            match ev {
                Event::Done { ttft_s, .. } => {
                    long_ttft = ttft_s;
                    break;
                }
                Event::Error { message, .. } | Event::Failed { message, .. } => {
                    panic!("long request failed: {message}")
                }
                Event::Token { .. } => {}
            }
        }
        let mut gaps: Vec<f64> = Vec::new();
        for c in collectors {
            let stamps = c.join().expect("collector thread");
            for w in stamps.windows(2) {
                gaps.push(w[1].duration_since(w[0]).as_secs_f64());
            }
        }
        gaps.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |q: f64| -> f64 {
            if gaps.is_empty() {
                0.0
            } else {
                gaps[((gaps.len() - 1) as f64 * q) as usize]
            }
        };
        (long_ttft, pct(0.5), pct(0.99), coord.metrics.gauge("mixed_ticks"))
    };
    let (sync_ttft, sync_p50, sync_p99, sync_mixed) = run_mixed_workload(false);
    let (ch_ttft, ch_p50, ch_p99, ch_mixed) = run_mixed_workload(true);
    println!(
        "  synchronous: long ttft {sync_ttft:.4}s  decode stall p50 {sync_p50:.4}s \
         p99 {sync_p99:.4}s"
    );
    println!(
        "  chunked    : long ttft {ch_ttft:.4}s  decode stall p50 {ch_p50:.4}s \
         p99 {ch_p99:.4}s  ({ch_mixed} mixed ticks)"
    );
    assert_eq!(sync_mixed, 0, "synchronous admission must never run a mixed tick");
    assert!(ch_mixed >= 1, "chunked admission must fuse at least one mixed tick");
    let bench5 = format!(
        concat!(
            "{{\"bench\":\"chunked_prefill\",\"schema\":1,\"status\":\"measured\",",
            "\"policy\":\"full_k2_spec2\",\"sim_scale\":\"tiny\",\"width\":4,",
            "\"long_prompt_tokens\":{},\"short_decode_tokens\":{},\"smoke\":{},",
            "\"modes\":[",
            "{{\"chunked\":false,\"long_ttft_s\":{:.6},\"decode_stall_p50_s\":{:.6},",
            "\"decode_stall_p99_s\":{:.6},\"mixed_ticks\":{}}},",
            "{{\"chunked\":true,\"long_ttft_s\":{:.6},\"decode_stall_p50_s\":{:.6},",
            "\"decode_stall_p99_s\":{:.6},\"mixed_ticks\":{}}}]}}\n"
        ),
        long_len, short_budget, smoke,
        sync_ttft, sync_p50, sync_p99, sync_mixed,
        ch_ttft, ch_p50, ch_p99, ch_mixed
    );
    let bench5_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_5.json");
    match std::fs::write(bench5_path, &bench5) {
        Ok(()) => println!("  wrote {bench5_path}"),
        Err(e) => eprintln!("  could not write {bench5_path}: {e}"),
    }

    // quantization tiers: link bytes per decoded token and sim
    // throughput, uniform base scheme vs hotness-tiered precision at the
    // SAME cache budget (full_k2_spec2, base 3-bit HQQ). The conservative
    // point (hot at base, cold at 2 bits) can only remove link bytes —
    // that's the asserted win; the grid point (4/3/2) additionally
    // spends bytes on hot experts and is reported unasserted. Emits the
    // machine-readable trajectory to ../BENCH_6.json.
    let tier_tokens = if smoke { 48 } else { 384 };
    println!("\nquant_tiers ({tier_tokens} decoded tokens, full_k2_spec2, base q3):");
    // (link bytes/token, sim tokens/s, hot hits, promotions, bytes saved)
    let run_tiers = |tiers: TierPolicy| -> (f64, f64, u64, u64, u64) {
        let serving = ServingConfig {
            policy: OffloadPolicy::Full { cache_k: 2, spec_n: 2 },
            expert_quant: QuantScheme::Hqq { bits: 3 },
            attn_quant: QuantScheme::Hqq { bits: 4 },
            sim_scale: SimScale::Tiny,
            expert_tiers: tiers,
            ..Default::default()
        };
        let mut engine =
            harness::build_engine_with_serving(&dir, &serving, HardwareProfile::rtx3060())
                .unwrap();
        let mut sess = engine.new_session().unwrap();
        let sim0 = engine.timeline.now();
        for t in 0..tier_tokens {
            if sess.position() + 1 >= engine.weights.cfg.max_seq {
                sess.reset();
            }
            engine.decode_step(&mut sess, tokens[t % tokens.len()]).unwrap();
        }
        let sim_s = engine.cost.scale_token_time(engine.timeline.now() - sim0);
        (
            sess.run.total_bytes() as f64 / tier_tokens as f64,
            tier_tokens as f64 / sim_s.max(1e-12),
            engine.tiers.hot_hits,
            engine.tiers.promotions,
            engine.tiers.bytes_saved(),
        )
    };
    let hot3_cold2 = TierPolicy {
        enabled: true,
        hot: QuantScheme::Hqq { bits: 3 },
        cold: QuantScheme::Hqq { bits: 2 },
        hot_fraction: 0.25,
        cold_fraction: 0.5,
        ..TierPolicy::hot_cold()
    };
    let (uni_bpt, uni_tps, _, _, _) = run_tiers(TierPolicy::default());
    let (t32_bpt, t32_tps, t32_hot, t32_promo, t32_saved) = run_tiers(hot3_cold2);
    let (t432_bpt, t432_tps, t432_hot, t432_promo, t432_saved) =
        run_tiers(TierPolicy::hot_cold());
    println!("  uniform q3   : {uni_bpt:.0} link bytes/token  {uni_tps:.1} tok/s(sim)");
    println!(
        "  hot3/cold2   : {t32_bpt:.0} link bytes/token  {t32_tps:.1} tok/s(sim)  \
         ({t32_hot} hot hits, {t32_promo} promotions, {t32_saved} bytes saved)"
    );
    println!(
        "  hot4/warm3/cold2: {t432_bpt:.0} link bytes/token  {t432_tps:.1} tok/s(sim)  \
         ({t432_hot} hot hits, {t432_promo} promotions, {t432_saved} bytes saved)"
    );
    assert!(
        t32_bpt < uni_bpt,
        "a cold tier below the base scheme must ship strictly fewer link \
         bytes per token ({t32_bpt:.0} vs uniform {uni_bpt:.0})"
    );
    let bench6 = format!(
        concat!(
            "{{\"bench\":\"quant_tiers\",\"schema\":1,\"status\":\"measured\",",
            "\"policy\":\"full_k2_spec2\",\"sim_scale\":\"tiny\",\"base_bits\":3,",
            "\"decode_tokens\":{},\"smoke\":{},\"modes\":[",
            "{{\"tiers\":\"uniform\",\"link_bytes_per_token\":{:.1},",
            "\"sim_tokens_per_s\":{:.3}}},",
            "{{\"tiers\":\"hot3_cold2\",\"hot_bits\":3,\"cold_bits\":2,",
            "\"link_bytes_per_token\":{:.1},\"sim_tokens_per_s\":{:.3},",
            "\"expert_hot_hits\":{},\"tier_promotions\":{},\"link_bytes_saved\":{}}},",
            "{{\"tiers\":\"hot4_cold2\",\"hot_bits\":4,\"cold_bits\":2,",
            "\"link_bytes_per_token\":{:.1},\"sim_tokens_per_s\":{:.3},",
            "\"expert_hot_hits\":{},\"tier_promotions\":{},\"link_bytes_saved\":{}}}]}}\n"
        ),
        tier_tokens, smoke,
        uni_bpt, uni_tps,
        t32_bpt, t32_tps, t32_hot, t32_promo, t32_saved,
        t432_bpt, t432_tps, t432_hot, t432_promo, t432_saved
    );
    let bench6_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_6.json");
    match std::fs::write(bench6_path, &bench6) {
        Ok(()) => println!("  wrote {bench6_path}"),
        Err(e) => eprintln!("  could not write {bench6_path}: {e}"),
    }

    // span tracing: overhead of the tracer on the hot decode path and
    // proof that tracing is observation-only — identical logits bits and
    // an identical virtual timeline with the tracer on vs off. Emits the
    // machine-readable trajectory to ../BENCH_7.json.
    let trace_tokens = if smoke { 48 } else { 256 };
    println!("\ntrace_overhead ({trace_tokens} decoded tokens, full_k2_spec2):");
    // (wall seconds, logits bit-stream, final virtual now, spans recorded)
    let run_traced = |trace: bool| -> (f64, Vec<u32>, f64, usize) {
        let serving = ServingConfig {
            policy: OffloadPolicy::Full { cache_k: 2, spec_n: 2 },
            expert_quant: QuantScheme::Hqq { bits: 3 },
            attn_quant: QuantScheme::Hqq { bits: 4 },
            sim_scale: SimScale::Tiny,
            trace,
            ..Default::default()
        };
        let mut engine =
            harness::build_engine_with_serving(&dir, &serving, HardwareProfile::rtx3060())
                .unwrap();
        let mut sess = engine.new_session().unwrap();
        let mut bits: Vec<u32> = Vec::new();
        let t0 = std::time::Instant::now();
        for t in 0..trace_tokens {
            if sess.position() + 1 >= engine.weights.cfg.max_seq {
                sess.reset();
            }
            let logits = engine.decode_step(&mut sess, tokens[t % tokens.len()]).unwrap();
            bits.extend(logits.iter().map(|v| v.to_bits()));
        }
        (
            t0.elapsed().as_secs_f64(),
            bits,
            engine.timeline.now(),
            engine.tracer.len(),
        )
    };
    let (off_wall, off_bits, off_now, off_spans) = run_traced(false);
    let (on_wall, on_bits, on_now, on_spans) = run_traced(true);
    assert_eq!(off_spans, 0, "tracing off must record no spans");
    assert!(on_spans > 0, "tracing on must record spans");
    assert_eq!(off_bits, on_bits, "tracing must not change a single logit bit");
    assert_eq!(
        off_now.to_bits(),
        on_now.to_bits(),
        "tracing must not move the virtual timeline"
    );
    let overhead_pct = (on_wall / off_wall.max(1e-12) - 1.0) * 100.0;
    println!(
        "  trace off: {off_wall:.4}s   trace on: {on_wall:.4}s  \
         ({overhead_pct:+.2}% wall, {on_spans} spans recorded, byte-identical output)"
    );
    let bench7 = format!(
        concat!(
            "{{\"bench\":\"trace_overhead\",\"schema\":1,\"status\":\"measured\",",
            "\"policy\":\"full_k2_spec2\",\"sim_scale\":\"tiny\",\"decode_tokens\":{},",
            "\"smoke\":{},\"byte_identical\":true,\"wall_overhead_pct\":{:.3},",
            "\"modes\":[{{\"trace\":false,\"wall_s\":{:.6},\"spans\":{}}},",
            "{{\"trace\":true,\"wall_s\":{:.6},\"spans\":{}}}]}}\n"
        ),
        trace_tokens, smoke, overhead_pct, off_wall, off_spans, on_wall, on_spans
    );
    let bench7_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_7.json");
    match std::fs::write(bench7_path, &bench7) {
        Ok(()) => println!("  wrote {bench7_path}"),
        Err(e) => eprintln!("  could not write {bench7_path}: {e}"),
    }

    // host wall-time breakdown per module (perf-pass diagnostics)
    println!("\nper-module host wall time (from the prefill engine):");
    let mut entries: Vec<_> = engine.rt.stats.iter().collect();
    entries.sort_by(|a, b| b.1.wall_s.partial_cmp(&a.1.wall_s).unwrap());
    for (name, s) in entries {
        println!(
            "  {name:24} {:>8} calls  {:>9.3}s total  {:>9.1}µs/call",
            s.calls,
            s.wall_s,
            s.wall_s / s.calls.max(1) as f64 * 1e6
        );
    }
}
