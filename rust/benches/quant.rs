//! Benchmarks for the quantization substrate: HQQ fitting, bit-packing and
//! dequantization — the host-side work on the expert transfer path.

#[path = "bench_harness/mod.rs"]
mod bench_harness;

use bench_harness::{bench, sink};
use moe_offload::quant::bitpack;
use moe_offload::quant::hqq::{self, HqqConfig};
use moe_offload::tensor::Tensor;
use moe_offload::util::rng::Rng;

fn random_weight(rng: &mut Rng, n_in: usize, n_out: usize) -> Tensor {
    Tensor::new(
        (0..n_in * n_out).map(|_| rng.normal() as f32 * 0.2).collect(),
        vec![n_in, n_out],
    )
    .unwrap()
}

fn main() {
    println!("== quant benches (tiny-model expert matrix 128x256) ==");
    let mut rng = Rng::new(1);
    let w = random_weight(&mut rng, 128, 256);

    for bits in [2u8, 3, 4] {
        let r = bench(&format!("hqq_quantize_{bits}bit_refined"), 300, || {
            sink(hqq::quantize(&w, &HqqConfig::new(bits, 32)).unwrap());
        });
        r.print();
        let r = bench(&format!("hqq_quantize_{bits}bit_plain"), 300, || {
            sink(hqq::quantize(&w, &HqqConfig::plain(bits, 32)).unwrap());
        });
        r.print();
    }

    let q3 = hqq::quantize(&w, &HqqConfig::plain(3, 32)).unwrap();
    let n = 128 * 256;
    let codes = q3.unpack_codes().unwrap();

    let r = bench("bitpack_pack_3bit_32k_codes", 300, || {
        sink(bitpack::pack(&codes, 3).unwrap());
    });
    r.print_throughput(n as f64, "codes");

    let mut buf = Vec::new();
    let r = bench("bitpack_unpack_into_3bit_32k_codes", 300, || {
        bitpack::unpack_into(&q3.packed, n, 3, &mut buf).unwrap();
        sink(buf.len());
    });
    r.print_throughput(n as f64, "codes");

    let r = bench("dequantize_full_matrix_3bit", 300, || {
        sink(q3.dequantize().unwrap());
    });
    r.print_throughput(n as f64, "weights");
}
