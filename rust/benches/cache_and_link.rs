//! Benchmarks for the offloading control plane: LRU operations, cache
//! manager decisions, the virtual timeline, and the copy engine. These are
//! L3 hot-loop costs — they must be negligible against even the fastest
//! simulated transfer (~100 µs).

#[path = "bench_harness/mod.rs"]
mod bench_harness;

use std::sync::Arc;

use bench_harness::{bench, sink};
use moe_offload::cache::lru::LruSet;
use moe_offload::cache::manager::CacheManager;
use moe_offload::clock::Timeline;
use moe_offload::config::{ModelConfig, QuantScheme};
use moe_offload::memory::copy_engine::CopyEngine;
use moe_offload::memory::device::{DeviceExpert, DeviceMemory};
use moe_offload::memory::host::{ExpertId, HostExpertPool};
use moe_offload::tensor::Tensor;
use moe_offload::util::rng::Rng;

fn main() {
    println!("== cache / link / copy-engine benches ==");

    // LRU touch at paper-typical k
    let mut lru: LruSet<u16> = LruSet::new(4);
    let mut i = 0u16;
    let r = bench("lru_touch_k4", 200, || {
        i = (i + 3) % 8;
        sink(lru.touch(i));
    });
    r.print();

    // cache manager full decision cycle
    let mut mgr = CacheManager::new(6, 4, 4, DeviceMemory::new(u64::MAX, 0, 1));
    let mut t = 0usize;
    let r = bench("cache_manager_use+insert", 200, || {
        t += 1;
        let id = ExpertId::new(t % 6, (t * 5) % 8);
        if matches!(
            mgr.on_demand_use(id),
            moe_offload::cache::manager::CacheEvent::Miss(_)
        ) {
            mgr.insert_loaded(
                id,
                DeviceExpert::Fp {
                    w1: Tensor::zeros(vec![1, 1]),
                    w3: Tensor::zeros(vec![1, 1]),
                    w2: Tensor::zeros(vec![1, 1]),
                },
            )
            .unwrap();
        }
    });
    r.print();

    // virtual timeline reservations
    let mut tl = Timeline::new();
    let r = bench("timeline_compute+transfer", 200, || {
        tl.compute(1e-5, 0.0);
        sink(tl.transfer(1e-4, 0.0));
    });
    r.print();

    // copy engine round trip (stage a real tiny expert)
    let mut cfg = ModelConfig::tiny();
    cfg.n_layers = 1;
    cfg.n_experts = 2;
    let mut rng = Rng::new(5);
    let pool = Arc::new(
        HostExpertPool::build(&cfg, QuantScheme::Hqq { bits: 3 }, |_, _| {
            let mut t = |shape: Vec<usize>| {
                let n: usize = shape.iter().product();
                Tensor::new((0..n).map(|_| rng.normal() as f32).collect(), shape).unwrap()
            };
            Ok((
                t(vec![cfg.d_model, cfg.d_ff]),
                t(vec![cfg.d_model, cfg.d_ff]),
                t(vec![cfg.d_ff, cfg.d_model]),
            ))
        })
        .unwrap(),
    );
    let mut ce = CopyEngine::new(pool, 4, 2);
    let r = bench("copy_engine_stage_expert_roundtrip", 400, || {
        let ticket = ce.submit(ExpertId::new(0, 0));
        sink(ce.wait(ticket).unwrap());
    });
    r.print();
}
