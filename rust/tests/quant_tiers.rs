//! Integration: adaptive per-expert quantization tiers against the real
//! engine. Requires `make artifacts` (skips cleanly otherwise); the
//! tier-assignment and pool-packing contracts are also covered by
//! always-on unit + property tests in `rust/src/quant/tier.rs` and
//! `rust/src/memory/host.rs`.
//!
//! Covers the subsystem's contracts:
//! * a tier policy whose hot/cold schemes EQUAL the base scheme decodes
//!   BIT-IDENTICALLY to the policy-off engine, byte for byte on the
//!   link — tiering is a pure re-pricing, not a behavior change;
//! * a cold tier below the base scheme strictly reduces staged link
//!   bytes, and every staged expert lands at exactly its tier's bits;
//! * online adaptation (promotion/demotion) never leaves a resident
//!   copy at a stale tier's precision;
//! * tiered serving at width 4 matches width-1 text, stays stream-stable
//!   across preempt/resume with the prefix cache on, and surfaces the
//!   tier gauges end to end.

use std::path::{Path, PathBuf};

use moe_offload::config::{
    HardwareProfile, OffloadPolicy, QuantScheme, ServingConfig, SimScale,
};
use moe_offload::coordinator::{collect_events, Coordinator, Event, Request};
use moe_offload::engine::MoeEngine;
use moe_offload::harness;
use moe_offload::memory::host::ExpertId;
use moe_offload::quant::{Tier, TierPolicy};
use moe_offload::Result;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() && dir.join("weights.npz").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

const BASE: QuantScheme = QuantScheme::Hqq { bits: 3 };

fn make_engine(
    dir: &Path,
    tiers: TierPolicy,
    policy: OffloadPolicy,
    sessions: usize,
    prefix_cache: bool,
) -> Result<MoeEngine> {
    let serving = ServingConfig {
        policy,
        expert_quant: BASE,
        attn_quant: QuantScheme::Hqq { bits: 4 },
        sim_scale: SimScale::Tiny,
        max_concurrent_sessions: sessions,
        kv_block_tokens: 16,
        kv_pool_tokens: Some(256),
        prefix_cache,
        expert_tiers: tiers,
        ..Default::default()
    };
    harness::build_engine_with_serving(dir, &serving, HardwareProfile::rtx3060())
}

fn full_policy() -> OffloadPolicy {
    OffloadPolicy::Full { cache_k: 2, spec_n: 2 }
}

/// Cold tier below the base scheme, hot tier AT the base scheme: every
/// staging costs at most the uniform bytes, so savings are guaranteed
/// as soon as one cold expert ships.
fn savings_policy(adaptive: bool) -> TierPolicy {
    TierPolicy {
        enabled: true,
        hot: BASE,
        cold: QuantScheme::Hqq { bits: 2 },
        hot_fraction: 0.25,
        cold_fraction: 0.5,
        adaptive,
        adapt_interval: 64,
    }
}

fn bits(logits: &[Vec<f32>]) -> Vec<Vec<u32>> {
    logits.iter().map(|row| row.iter().map(|x| x.to_bits()).collect()).collect()
}

/// 44 prompt tokens and a decoded continuation, as the prefix-cache
/// suite uses.
fn workload() -> (Vec<u32>, Vec<u32>) {
    let prompt: Vec<u32> = "please summarize the mixture of experts paper"
        .bytes()
        .take(44)
        .map(|b| b as u32)
        .collect();
    let cont: Vec<u32> = "briefly".bytes().map(|b| b as u32).collect();
    (prompt, cont)
}

/// Every expert id of the engine's executed geometry.
fn all_experts(engine: &MoeEngine) -> Vec<ExpertId> {
    let cfg = &engine.weights.cfg;
    (0..cfg.n_layers)
        .flat_map(|l| (0..cfg.n_experts).map(move |e| ExpertId::new(l, e)))
        .collect()
}

#[test]
fn uniform_scheme_tiers_are_bit_identical_to_disabled() {
    let Some(dir) = artifacts_dir() else { return };
    let (prompt, cont) = workload();

    // reference: tier policy off entirely (the uniform deployment)
    let mut plain = make_engine(&dir, TierPolicy::default(), full_policy(), 1, false).unwrap();
    let mut ps = plain.new_session().unwrap();
    let plain_prefill = plain.prefill(&mut ps, &prompt).unwrap();
    let plain_cont: Vec<Vec<f32>> =
        cont.iter().map(|&t| plain.decode_step(&mut ps, t).unwrap()).collect();

    // subject: tiers ENABLED (seeding, adaptation and per-tier pricing
    // all live) but every tier packs at the base scheme — aggressive
    // adapt_interval so re-ranks actually fire during the run
    let uniform = TierPolicy {
        enabled: true,
        hot: BASE,
        cold: BASE,
        hot_fraction: 0.25,
        cold_fraction: 0.25,
        adaptive: true,
        adapt_interval: 4,
    };
    let mut tiered = make_engine(&dir, uniform, full_policy(), 1, false).unwrap();
    let mut ts = tiered.new_session().unwrap();
    let tiered_prefill = tiered.prefill(&mut ts, &prompt).unwrap();
    let tiered_cont: Vec<Vec<f32>> =
        cont.iter().map(|&t| tiered.decode_step(&mut ts, t).unwrap()).collect();

    for t in 0..prompt.len() {
        assert_eq!(
            bits(&[plain_prefill.row(t).to_vec()]),
            bits(&[tiered_prefill.row(t).to_vec()]),
            "prefill position {t} diverged under a uniform-scheme tier policy"
        );
    }
    assert_eq!(
        bits(&plain_cont),
        bits(&tiered_cont),
        "decode must be bit-identical when every tier uses the base scheme"
    );
    // byte-identical on the link, not just numerically identical
    assert_eq!(ps.run.total_bytes(), ts.run.total_bytes());
    assert_eq!(tiered.tiers.bytes_saved(), 0, "same scheme ships same bytes");
    assert_eq!(
        tiered.tiers.uniform_bytes, tiered.tiers.actual_bytes,
        "per-tier pricing must collapse to uniform pricing"
    );
}

#[test]
fn cold_tier_strictly_reduces_staged_link_bytes() {
    let Some(dir) = artifacts_dir() else { return };
    let (prompt, cont) = workload();

    let mut eng = make_engine(&dir, savings_policy(false), full_policy(), 1, false).unwrap();
    let mut sess = eng.new_session().unwrap();
    eng.prefill(&mut sess, &prompt).unwrap();
    for &t in &cont {
        eng.decode_step(&mut sess, t).unwrap();
    }

    // half of each layer is Cold at 2 bits vs the 3-bit base: the
    // prompt routes through (and stages) cold experts, so the tiered
    // byte counter must run strictly under the uniform counter
    assert!(eng.tiers.uniform_bytes > 0, "the run must stage experts");
    assert!(
        eng.tiers.actual_bytes < eng.tiers.uniform_bytes,
        "cold-tier stagings must ship fewer bytes ({} vs uniform {})",
        eng.tiers.actual_bytes,
        eng.tiers.uniform_bytes
    );
    assert_eq!(
        eng.tiers.bytes_saved(),
        eng.tiers.uniform_bytes - eng.tiers.actual_bytes
    );

    // staged-tier invariant: whatever is resident is packed at exactly
    // its tier's precision (spec transfers included — the policy is
    // static here, so nothing can arrive at a stale tier)
    let mut seen_cold = false;
    for id in all_experts(&eng) {
        let tier = eng.weights.experts.tier_of(id);
        let want = eng.weights.experts.scheme_of_tier(tier).bits() as u8;
        if let Some(have) = eng.cache.resident_bits_of(id) {
            assert_eq!(have, want, "expert {id} resident at {have} bits, tier wants {want}");
            seen_cold |= tier == Tier::Cold;
        }
    }
    assert!(seen_cold, "with half of each layer Cold, some cold expert stays resident");
}

#[test]
fn adaptation_never_leaves_a_stale_tier_resident() {
    let Some(dir) = artifacts_dir() else { return };

    // spec_n = 0: every staging is synchronous, so after the run the
    // residency invariant is exact (speculative arrivals are instead
    // self-healed lazily on first access)
    let policy = OffloadPolicy::Full { cache_k: 4, spec_n: 0 };
    let tiers = TierPolicy {
        enabled: true,
        hot: QuantScheme::Hqq { bits: 4 },
        cold: QuantScheme::Hqq { bits: 2 },
        hot_fraction: 0.25,
        cold_fraction: 0.25,
        adaptive: true,
        adapt_interval: 4, // re-rank constantly
    };
    let mut eng = make_engine(&dir, tiers, policy, 1, false).unwrap();
    let mut sess = eng.new_session().unwrap();
    // a varied token stream so route counters move tiers around
    let stream: Vec<u32> = (0..96u32).map(|i| (i * 37 + 11) % 251).collect();
    for &t in &stream {
        eng.decode_step(&mut sess, t).unwrap();
    }

    for id in all_experts(&eng) {
        let want = eng
            .weights
            .experts
            .scheme_of_tier(eng.weights.experts.tier_of(id))
            .bits() as u8;
        if let Some(have) = eng.cache.resident_bits_of(id) {
            assert_eq!(
                have, want,
                "expert {id} resident at {have} bits after adaptation, tier wants {want}"
            );
        }
    }
    // with hot at 4 bits > base, both directions of re-pricing ran
    assert!(eng.tiers.uniform_bytes > 0);
}

#[test]
fn tiered_preempt_resume_stays_bit_exact_with_prefix_cache_on() {
    let Some(dir) = artifacts_dir() else { return };
    let (prompt, cont) = workload();
    let (head, tail) = cont.split_at(3);
    let tiers = savings_policy(true);

    // reference: uninterrupted tiered stream
    let mut a = make_engine(&dir, tiers, full_policy(), 1, true).unwrap();
    let mut sa = a.new_session().unwrap();
    a.prefill_cached(&mut sa, &prompt).unwrap();
    for &t in head {
        a.decode_step(&mut sa, t).unwrap();
    }
    let ref_tail: Vec<Vec<f32>> =
        tail.iter().map(|&t| a.decode_step(&mut sa, t).unwrap()).collect();

    // subject: same tiered config, preempted and resumed mid-stream
    let mut b = make_engine(&dir, tiers, full_policy(), 1, true).unwrap();
    let mut sb = b.new_session().unwrap();
    b.prefill_cached(&mut sb, &prompt).unwrap();
    for &t in head {
        b.decode_step(&mut sb, t).unwrap();
    }
    b.preempt_session(&mut sb).unwrap();
    b.resume_session(&mut sb).unwrap();
    let got_tail: Vec<Vec<f32>> =
        tail.iter().map(|&t| b.decode_step(&mut sb, t).unwrap()).collect();
    assert_eq!(
        bits(&ref_tail),
        bits(&got_tail),
        "preempt+resume of a tiered session must continue bit-identically"
    );
    assert!(b.tiers.bytes_saved() > 0, "the tiered run must have saved link bytes");
}

#[test]
fn width4_tiered_serving_matches_width1_and_surfaces_tier_gauges() {
    let Some(dir) = artifacts_dir() else { return };
    let tiers = savings_policy(true);
    let mk = |i: usize| {
        let mut r = Request::new(format!("expert tier request number {i}"));
        r.chat = false;
        r.max_tokens = 6;
        r.temperature = 0.0; // greedy: text depends only on logits
        r
    };
    let texts = |coord: &Coordinator, n: usize| -> Vec<String> {
        let streams: Vec<_> = (0..n).map(|i| coord.submit(mk(i))).collect();
        streams
            .into_iter()
            .map(|s| {
                collect_events(s)
                    .iter()
                    .find_map(|ev| match ev {
                        Event::Done { text, link_bytes_saved, .. } => {
                            assert!(
                                *link_bytes_saved > 0,
                                "done event must carry the tier savings"
                            );
                            Some(text.clone())
                        }
                        _ => None,
                    })
                    .expect("request must finish, not error")
            })
            .collect()
    };

    let d1 = dir.clone();
    let w1 = Coordinator::new(move || make_engine(&d1, tiers, full_policy(), 1, true), 7);
    let ref_texts = texts(&w1, 4);
    w1.shutdown();

    let d4 = dir.clone();
    let w4 = Coordinator::new(move || make_engine(&d4, tiers, full_policy(), 4, true), 7);
    let got_texts = texts(&w4, 4);
    assert_eq!(
        ref_texts, got_texts,
        "width-4 tiered decode must stream the same text as width 1"
    );
    assert!(w4.metrics.gauge("link_bytes_saved") > 0);
    // hot experts exist in every layer; across 4 prefills + decodes at
    // cache_k = 2 at least one of their touches must be a cache hit
    assert!(w4.metrics.gauge("expert_hot_hits") > 0);
    w4.shutdown();
}
