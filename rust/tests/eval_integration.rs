//! Integration: quality evaluation (perplexity + cloze) across
//! quantization schemes — the machinery behind Table 1.
//! Requires `make artifacts` (skips cleanly otherwise).

use std::path::{Path, PathBuf};

use moe_offload::config::{
    HardwareProfile, Manifest, OffloadPolicy, QuantScheme, ServingConfig, SimScale,
};
use moe_offload::engine::MoeEngine;
use moe_offload::eval;
use moe_offload::model::ModelWeights;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists()
        && dir.join("weights.npz").exists()
        && dir.join("corpus/prose_eval.bin").exists()
    {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/corpora not built (run `make artifacts`)");
        None
    }
}

fn engine(dir: &Path, attn: QuantScheme, expert: QuantScheme) -> MoeEngine {
    let manifest = Manifest::load(dir).unwrap();
    let weights =
        ModelWeights::load(&manifest.config, &dir.join("weights.npz"), attn, expert).unwrap();
    let serving = ServingConfig {
        policy: OffloadPolicy::Full { cache_k: 4, spec_n: 2 },
        expert_quant: expert,
        attn_quant: attn,
        sim_scale: SimScale::Tiny,
        ..Default::default()
    };
    MoeEngine::new(&manifest, weights, &serving, HardwareProfile::a100_80gb()).unwrap()
}

#[test]
fn quantization_degrades_ppl_monotonically() {
    let Some(dir) = artifacts_dir() else { return };
    let corpus = eval::load_corpus(&dir.join("corpus/prose_eval.bin")).unwrap();

    let ppl = |expert: QuantScheme| -> f64 {
        let mut e = engine(&dir, QuantScheme::Fp16, expert);
        eval::perplexity(&mut e, &corpus, 96, 2).unwrap()
    };
    let fp = ppl(QuantScheme::Fp16);
    let q4 = ppl(QuantScheme::Hqq { bits: 4 });
    let q2 = ppl(QuantScheme::Hqq { bits: 2 });
    // Table 1's qualitative shape: fp16 <= 4-bit < 2-bit (small slack for
    // eval noise at tiny scale)
    assert!(fp > 1.0 && fp < 30.0, "fp ppl {fp}");
    assert!(q4 < q2, "4-bit {q4} should beat 2-bit {q2}");
    assert!(fp <= q4 * 1.05, "fp {fp} should be <= 4-bit {q4}");
}

#[test]
fn domain_shift_shows_in_ppl() {
    let Some(dir) = artifacts_dir() else { return };
    let prose = eval::load_corpus(&dir.join("corpus/prose_eval.bin")).unwrap();
    let code = eval::load_corpus(&dir.join("corpus/code_eval.bin")).unwrap();
    let mut e = engine(&dir, QuantScheme::Fp16, QuantScheme::Fp16);
    let p1 = eval::perplexity(&mut e, &prose, 96, 2).unwrap();
    let mut e = engine(&dir, QuantScheme::Fp16, QuantScheme::Fp16);
    let p2 = eval::perplexity(&mut e, &code, 96, 2).unwrap();
    // both trained domains: finite, plausible, distinct corpora score
    assert!(p1 > 1.0 && p1.is_finite());
    assert!(p2 > 1.0 && p2.is_finite());
}

#[test]
fn cloze_beats_chance_on_fp16() {
    let Some(dir) = artifacts_dir() else { return };
    let corpus = eval::load_corpus(&dir.join("corpus/prose_eval.bin")).unwrap();
    let mut e = engine(&dir, QuantScheme::Fp16, QuantScheme::Fp16);
    let acc = eval::cloze_accuracy(&mut e, &corpus, 12, 48, 16, 3).unwrap();
    // trained model should pick the true continuation well above 0.25
    assert!(acc > 0.4, "cloze accuracy {acc}");
}

#[test]
fn eval_rejects_undersized_corpus() {
    let Some(dir) = artifacts_dir() else { return };
    let mut e = engine(&dir, QuantScheme::Fp16, QuantScheme::Fp16);
    assert!(eval::perplexity(&mut e, &[1, 2, 3], 96, 2).is_err());
    assert!(eval::cloze_accuracy(&mut e, &[1, 2, 3], 2, 48, 16, 0).is_err());
}
