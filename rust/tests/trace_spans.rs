//! Integration: virtual-timeline span tracing against the real engine.
//! Requires `make artifacts` (skips cleanly otherwise).
//!
//! Tracing is observation-only, so the contracts are equivalences and
//! accounting identities:
//! * tracing on produces bit-identical logits and an identical virtual
//!   timeline to tracing off, at width 1 and width 4 (batched);
//! * the attributed GPU spans plus the recorded stall time tile a
//!   request's virtual wall time exactly — no unattributed gaps, no
//!   double-counted overlap;
//! * the Chrome trace export round-trips through the JSON parser with
//!   demand loads distinguishable from speculative prefetches;
//! * the coordinator's done event carries the per-request breakdown
//!   exactly when tracing is on.

use std::path::{Path, PathBuf};

use moe_offload::config::{
    HardwareProfile, OffloadPolicy, QuantScheme, ServingConfig, SimScale,
};
use moe_offload::coordinator::{collect_events, Coordinator, Event, Request};
use moe_offload::engine::{MoeEngine, Session};
use moe_offload::harness;
use moe_offload::trace::analysis::{attribution, critical_paths};
use moe_offload::util::json::Json;
use moe_offload::util::rng::Rng;
use moe_offload::Result;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() && dir.join("weights.npz").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

fn serving(sessions: usize, trace: bool) -> ServingConfig {
    ServingConfig {
        policy: OffloadPolicy::Full { cache_k: 2, spec_n: 2 },
        expert_quant: QuantScheme::Hqq { bits: 3 },
        attn_quant: QuantScheme::Hqq { bits: 4 },
        sim_scale: SimScale::Tiny,
        max_concurrent_sessions: sessions,
        trace,
        ..Default::default()
    }
}

fn make_engine(dir: &Path, sessions: usize, trace: bool) -> Result<MoeEngine> {
    harness::build_engine_with_serving(dir, &serving(sessions, trace), HardwareProfile::rtx3060())
}

fn toks(s: &str) -> Vec<u32> {
    s.bytes().map(|b| b as u32).collect()
}

fn row_bits(row: &[f32]) -> Vec<u32> {
    row.iter().map(|x| x.to_bits()).collect()
}

/// Prefill + decode a fixed stream on a fresh session; return every
/// logits row's bit pattern, the final virtual time, and the session.
fn drive_one(
    engine: &mut MoeEngine,
    prompt: &[u32],
    stream: &[u32],
) -> (Vec<Vec<u32>>, u64, Session) {
    let mut sess = engine.new_session().unwrap();
    let logits = engine.prefill(&mut sess, prompt).unwrap();
    let mut out = vec![row_bits(logits.row(prompt.len() - 1))];
    for &t in stream {
        out.push(row_bits(&engine.decode_step(&mut sess, t).unwrap()));
    }
    (out, engine.timeline.now().to_bits(), sess)
}

#[test]
fn tracing_is_byte_identical_at_width_1() {
    let Some(dir) = artifacts_dir() else { return };
    let prompt = toks("what is a mixture of experts model");
    let stream = toks("tracing must not change it");

    let mut off = make_engine(&dir, 1, false).unwrap();
    let (off_bits, off_now, _off_sess) = drive_one(&mut off, &prompt, &stream);
    assert!(off.tracer.is_empty(), "a disabled tracer must record nothing");

    let mut on = make_engine(&dir, 1, true).unwrap();
    let (on_bits, on_now, _on_sess) = drive_one(&mut on, &prompt, &stream);
    assert!(!on.tracer.is_empty(), "an enabled tracer must record spans");

    assert_eq!(off_bits, on_bits, "tracing changed logits bits");
    assert_eq!(off_now, on_now, "tracing moved the virtual timeline");
}

#[test]
fn tracing_is_byte_identical_at_width_4_batched() {
    let Some(dir) = artifacts_dir() else { return };
    let streams: Vec<Vec<u32>> = [
        "four decode streams in layer",
        "lockstep through the engine s",
        "batched tick so the tracer se",
        "es shared and per session wor",
    ]
    .iter()
    .map(|s| toks(s))
    .collect();
    let ticks = streams[0].len();

    let run = |trace: bool| -> (Vec<Vec<Vec<u32>>>, u64) {
        let mut engine = make_engine(&dir, 4, trace).unwrap();
        let mut sessions: Vec<Session> =
            (0..4).map(|_| engine.new_session().unwrap()).collect();
        let mut out = vec![Vec::new(); 4];
        for t in 0..ticks {
            let tick_toks: Vec<u32> = (0..4).map(|i| streams[i][t]).collect();
            let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
            for (i, slot) in engine
                .decode_batch(&mut refs, &tick_toks)
                .unwrap()
                .into_iter()
                .enumerate()
            {
                out[i].push(row_bits(&slot.unwrap()));
            }
        }
        (out, engine.timeline.now().to_bits())
    };

    let (off_bits, off_now) = run(false);
    let (on_bits, on_now) = run(true);
    assert_eq!(off_bits, on_bits, "tracing changed batched logits bits");
    assert_eq!(off_now, on_now, "tracing moved the batched virtual timeline");
}

#[test]
fn attributed_spans_tile_request_virtual_time() {
    let Some(dir) = artifacts_dir() else { return };
    let prompt = toks("attribute every virtual second");
    let stream = toks("to compute or to a stall");

    let mut engine = make_engine(&dir, 1, true).unwrap();
    let (_bits, _now, sess) = drive_one(&mut engine, &prompt, &stream);

    // every span this single-session run produced belongs to the session
    for s in engine.tracer.spans() {
        assert_eq!(s.session, sess.id, "unattributed span: {:?}", s.kind);
        assert!(s.end_s > s.start_s, "empty span survived: {:?}", s.kind);
    }

    // the decode/prefill front advances only by GPU compute and by
    // stalling on transfers, so attributed GPU span time + recorded
    // stall time must tile the request's virtual wall time exactly
    let gpu_s: f64 = engine
        .tracer
        .spans()
        .filter(|s| !s.kind.is_transfer())
        .map(|s| s.dur_s())
        .sum();
    let stall_s: f64 = sess.run.prefill_stall_s
        + sess.run.tokens.iter().map(|t| t.stall_s).sum::<f64>();
    let wall_s: f64 =
        sess.run.prefill_sim_s + sess.run.tokens.iter().map(|t| t.sim_s).sum::<f64>();
    assert!(
        (gpu_s + stall_s - wall_s).abs() <= 1e-9 * wall_s.max(1.0),
        "attribution gap: gpu {gpu_s} + stall {stall_s} != wall {wall_s}"
    );

    // transfers overlap compute, so the full transfer time is at least
    // the stalled share of it
    let transfer_s: f64 = sess.run.prefill_transfer_s
        + sess.run.tokens.iter().map(|t| t.transfer_s).sum::<f64>();
    assert!(
        transfer_s + 1e-12 >= stall_s,
        "stall {stall_s} exceeds issued transfer time {transfer_s}"
    );
}

#[test]
fn chrome_trace_round_trips_and_distinguishes_transfer_causes() {
    let Some(dir) = artifacts_dir() else { return };
    let prompt = toks("export the ring as a chrome trace");
    let stream = toks("with spec prefetch and demand loads");

    let mut engine = make_engine(&dir, 1, true).unwrap();
    let _ = drive_one(&mut engine, &prompt, &stream);

    let text = engine.tracer.chrome_trace().to_string();
    let doc = Json::parse(&text).expect("exported trace must re-parse");
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents array");

    let mut names = Vec::new();
    for ev in events {
        let ph = ev.get("ph").and_then(Json::as_str).unwrap();
        if ph != "X" {
            continue;
        }
        let pid = ev.get("pid").and_then(Json::as_usize).unwrap();
        assert!(pid == 1 || pid == 2, "unknown resource stream pid {pid}");
        let cat = ev.get("cat").and_then(Json::as_str).unwrap();
        assert_eq!(cat == "transfer", pid == 2, "cat/pid stream mismatch");
        assert!(ev.get("dur").and_then(Json::as_f64).unwrap() > 0.0);
        names.push(ev.get("name").and_then(Json::as_str).unwrap().to_string());
    }
    // the whole point of cause attribution: a blocking demand load and a
    // hidden speculative prefetch are different lanes, not one blob
    assert!(names.iter().any(|n| n == "demand_load"), "no demand_load spans");
    assert!(names.iter().any(|n| n == "spec_prefetch"), "no spec_prefetch spans");
    assert!(names.iter().any(|n| n == "attention"), "no attention spans");
}

#[test]
fn critical_paths_bounded_by_wall_under_random_knobs() {
    let Some(dir) = artifacts_dir() else { return };

    // the analysis contract must hold for ANY scheduler shape, not just
    // the configs the other tests pin: randomize width, offload policy,
    // and batched-vs-sequential decode, then check that every session's
    // critical path fits inside its own virtual wall time and that the
    // aggregate attribution fractions tile exactly
    for case in 0..4u64 {
        let mut r = Rng::new(0xc4a7 + case);
        let width = 1 + r.below(4);
        let policy = match r.below(3) {
            0 => OffloadPolicy::Full { cache_k: 2, spec_n: 2 },
            1 => OffloadPolicy::LruOnly { cache_k: 2 },
            _ => OffloadPolicy::OnDemand,
        };
        let batched = r.below(2) == 0;
        let serving = ServingConfig {
            policy,
            expert_quant: QuantScheme::Hqq { bits: 3 },
            attn_quant: QuantScheme::Hqq { bits: 4 },
            sim_scale: SimScale::Tiny,
            max_concurrent_sessions: width,
            batched_decode: batched,
            trace: true,
            ..Default::default()
        };
        let mut engine =
            harness::build_engine_with_serving(&dir, &serving, HardwareProfile::rtx3060())
                .unwrap();

        let mut sessions: Vec<Session> =
            (0..width).map(|_| engine.new_session().unwrap()).collect();
        for (i, sess) in sessions.iter_mut().enumerate() {
            let prompt = toks(&format!("random knobs case {case} session {i}"));
            engine.prefill(sess, &prompt).unwrap();
        }
        let ticks = 6;
        let streams: Vec<Vec<u32>> = (0..width)
            .map(|i| toks(&format!("decode stream {i} tokens"))[..ticks].to_vec())
            .collect();
        if batched && width >= 2 {
            for t in 0..ticks {
                let tick_toks: Vec<u32> = (0..width).map(|i| streams[i][t]).collect();
                let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
                engine.decode_batch(&mut refs, &tick_toks).unwrap();
            }
        } else {
            for t in 0..ticks {
                for (i, sess) in sessions.iter_mut().enumerate() {
                    engine.decode_step(sess, streams[i][t]).unwrap();
                }
            }
        }

        let spans: Vec<_> = engine.tracer.spans().copied().collect();
        assert!(!spans.is_empty(), "case {case}: traced run recorded no spans");
        let paths = critical_paths(&spans);
        assert_eq!(
            paths.len(),
            width,
            "case {case}: every session must get a critical path"
        );
        for p in &paths {
            let sess = sessions
                .iter()
                .find(|s| s.id == p.session)
                .unwrap_or_else(|| panic!("case {case}: path for unknown session {}", p.session));
            let wall: f64 = sess.run.prefill_sim_s
                + sess.run.tokens.iter().map(|t| t.sim_s).sum::<f64>();
            assert!(
                p.path_s <= p.window_s * (1.0 + 1e-9) + 1e-12,
                "case {case} session {}: path {} exceeds window {}",
                p.session,
                p.path_s,
                p.window_s
            );
            assert!(
                p.path_s <= wall * (1.0 + 1e-9) + 1e-12,
                "case {case} session {} (width {width}, batched {batched}): \
                 critical path {} exceeds virtual wall {}",
                p.session,
                p.path_s,
                wall
            );
        }
        let a = attribution(&paths);
        assert!(
            (a.sum() - 1.0).abs() < 1e-9,
            "case {case}: attribution fractions sum to {} != 1",
            a.sum()
        );
    }
}

#[test]
fn breakdown_rides_the_done_event_only_when_tracing() {
    let Some(dir) = artifacts_dir() else { return };

    let run = |trace: bool| -> Event {
        let dir = dir.clone();
        let coord = Coordinator::new(
            move || {
                harness::build_engine_with_serving(
                    &dir,
                    &serving(2, trace),
                    HardwareProfile::rtx3060(),
                )
            },
            7,
        );
        let mut req = Request::new("trace this request end to end");
        req.chat = false;
        req.max_tokens = 8;
        let events = collect_events(coord.submit(req));
        events
            .into_iter()
            .find(|e| matches!(e, Event::Done { .. } | Event::Error { .. }))
            .expect("request must finish")
    };

    match run(false) {
        Event::Done { breakdown, .. } => {
            assert!(breakdown.is_none(), "untraced done event grew a breakdown");
        }
        other => panic!("expected done, got {other:?}"),
    }

    match run(true) {
        Event::Done { breakdown, queue_wait_s, .. } => {
            let b = breakdown.expect("traced done event must carry a breakdown");
            assert!((b.queue_s - queue_wait_s).abs() < 1e-12);
            assert!(b.prefill_compute_s > 0.0, "prefill compute must be attributed");
            assert!(b.decode_compute_s > 0.0, "decode compute must be attributed");
            assert!(b.stall_s >= 0.0 && b.transfer_s >= 0.0);
            assert!(
                b.transfer_hidden_s <= b.transfer_s + 1e-12,
                "hidden transfer time cannot exceed issued transfer time"
            );
        }
        other => panic!("expected done, got {other:?}"),
    }
}
