//! Integration: coordinator + TCP server over the real engine.
//! Requires `make artifacts` (skips cleanly otherwise).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use moe_offload::config::{
    HardwareProfile, Manifest, OffloadPolicy, QuantScheme, ServingConfig, SimScale,
};
use moe_offload::coordinator::{server::Server, Coordinator, Event, Request};
use moe_offload::engine::MoeEngine;
use moe_offload::model::ModelWeights;
use moe_offload::util::json::Json;
use moe_offload::Result;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() && dir.join("weights.npz").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

fn make_engine(dir: &Path) -> Result<MoeEngine> {
    let manifest = Manifest::load(dir)?;
    let weights = ModelWeights::load(
        &manifest.config,
        &dir.join("weights.npz"),
        QuantScheme::Hqq { bits: 4 },
        QuantScheme::Hqq { bits: 3 },
    )?;
    let serving = ServingConfig {
        policy: OffloadPolicy::Full { cache_k: 2, spec_n: 2 },
        expert_quant: QuantScheme::Hqq { bits: 3 },
        attn_quant: QuantScheme::Hqq { bits: 4 },
        sim_scale: SimScale::Tiny,
        ..Default::default()
    };
    MoeEngine::new(&manifest, weights, &serving, HardwareProfile::t4_colab())
}

#[test]
fn coordinator_serves_sequential_requests() {
    let Some(dir) = artifacts_dir() else { return };
    let coord = Coordinator::new(move || make_engine(&dir), 7);

    let mut req = Request::new("what is perplexity");
    req.max_tokens = 12;
    let stream1 = coord.submit(req.clone());
    let stream2 = coord.submit(req);

    let text1 = stream1.wait_text().unwrap();
    let text2 = stream2.wait_text().unwrap();
    assert!(!text1.is_empty());
    assert!(!text2.is_empty());
    assert_eq!(coord.metrics.counter("requests_ok"), 2);
    assert!(coord.metrics.counter("tokens_generated") >= 2);
}

#[test]
fn coordinator_streams_token_events() {
    let Some(dir) = artifacts_dir() else { return };
    let coord = Coordinator::new(move || make_engine(&dir), 3);
    let mut req = Request::new("hello");
    req.max_tokens = 6;
    let stream = coord.submit(req);
    let mut token_events = 0;
    let mut saw_done = false;
    for ev in stream.events.iter() {
        match ev {
            Event::Token { .. } => token_events += 1,
            Event::Done { new_tokens, tokens_per_s_wall, .. } => {
                assert!(new_tokens >= 1);
                assert!(tokens_per_s_wall > 0.0);
                saw_done = true;
                break;
            }
            Event::Error { message, .. } | Event::Failed { message, .. } => {
                panic!("unexpected error: {message}")
            }
        }
    }
    assert!(saw_done);
    assert!(token_events >= 1);
}

#[test]
fn engine_init_failure_reports_error() {
    let coord = Coordinator::new(|| Err(moe_offload::Error::Serving("boom".into())), 0);
    let stream = coord.submit(Request::new("hi"));
    let err = stream.wait_text().unwrap_err();
    assert!(err.to_string().contains("boom"));
}

#[test]
fn tcp_server_round_trip() {
    let Some(dir) = artifacts_dir() else { return };
    let coord = Arc::new(Coordinator::new(move || make_engine(&dir), 11));
    let server = Server::bind("127.0.0.1:0", Arc::clone(&coord)).unwrap();
    let addr = server.local_addr().unwrap();
    std::thread::spawn(move || {
        let _ = server.serve(Some(1));
    });

    let mut conn = TcpStream::connect(addr).unwrap();
    writeln!(conn, r#"{{"prompt":"what is a mixture of experts model","max_tokens":8}}"#)
        .unwrap();
    conn.flush().unwrap();

    let reader = BufReader::new(conn.try_clone().unwrap());
    let mut done = None;
    for line in reader.lines() {
        let line = line.unwrap();
        let v = Json::parse(&line).unwrap();
        match v.get("type").and_then(Json::as_str) {
            Some("token") => {}
            Some("done") => {
                done = Some(v);
                break;
            }
            other => panic!("unexpected event type {other:?}: {line}"),
        }
    }
    let done = done.expect("no done event");
    assert!(done.get("new_tokens").unwrap().as_usize().unwrap() >= 1);
    assert!(done.get("tokens_per_s_sim").unwrap().as_f64().unwrap() > 0.0);
}

#[test]
fn tcp_server_rejects_bad_request() {
    let Some(dir) = artifacts_dir() else { return };
    let coord = Arc::new(Coordinator::new(move || make_engine(&dir), 0));
    let server = Server::bind("127.0.0.1:0", coord).unwrap();
    let addr = server.local_addr().unwrap();
    std::thread::spawn(move || {
        let _ = server.serve(Some(1));
    });

    let mut conn = TcpStream::connect(addr).unwrap();
    writeln!(conn, "this is not json").unwrap();
    conn.flush().unwrap();
    let mut reader = BufReader::new(conn);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = Json::parse(&line).unwrap();
    assert_eq!(v.get("type").and_then(Json::as_str), Some("error"));
}
