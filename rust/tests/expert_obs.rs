//! Integration: expert-flow observability against the real engine.
//! Requires `make artifacts` (skips cleanly otherwise).
//!
//! The flight recorder is observation-only, so the contracts are
//! equivalences and exact replay identities:
//! * `expert_obs` on produces bit-identical logits and an identical
//!   virtual timeline to off, at width 1 and width 4 (batched), with
//!   transient faults AND adaptive tiers enabled — the recorder rides
//!   the hardest path without perturbing it;
//! * the anchoring invariant: replaying the recorded per-layer expert
//!   access stream through simulated LRU at the engine's ACTUAL
//!   `cache_k` reproduces the measured per-layer hit/miss counts
//!   exactly, on a real width-4 serving run with prefix cache and
//!   tiers on;
//! * the counterfactual curves are monotone in k and the clairvoyant
//!   OPT bound dominates LRU at every size;
//! * the coordinator's `experts` report degrades to an explicit
//!   disabled object when the knob is off.

use std::path::{Path, PathBuf};

use moe_offload::config::{
    HardwareProfile, OffloadPolicy, QuantScheme, ServingConfig, SimScale,
};
use moe_offload::coordinator::{collect_events, Coordinator, Event, Request};
use moe_offload::engine::{MoeEngine, Session};
use moe_offload::fault::FaultPlan;
use moe_offload::harness;
use moe_offload::quant::TierPolicy;
use moe_offload::util::json::Json;
use moe_offload::Result;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() && dir.join("weights.npz").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

/// The hard path: faults and adaptive tiers on, so the recorder sees
/// retries, re-stages and exogenous tier drops — and must not perturb
/// any of them.
fn serving(sessions: usize, expert_obs: bool) -> ServingConfig {
    ServingConfig {
        policy: OffloadPolicy::Full { cache_k: 2, spec_n: 2 },
        expert_quant: QuantScheme::Hqq { bits: 3 },
        attn_quant: QuantScheme::Hqq { bits: 4 },
        sim_scale: SimScale::Tiny,
        max_concurrent_sessions: sessions,
        expert_tiers: TierPolicy { adapt_interval: 8, ..TierPolicy::hot_cold() },
        faults: FaultPlan::transient_smoke(11),
        expert_obs,
        ..Default::default()
    }
}

fn make_engine(dir: &Path, sessions: usize, expert_obs: bool) -> Result<MoeEngine> {
    harness::build_engine_with_serving(
        dir,
        &serving(sessions, expert_obs),
        HardwareProfile::rtx3060(),
    )
}

fn toks(s: &str) -> Vec<u32> {
    s.bytes().map(|b| b as u32).collect()
}

fn row_bits(row: &[f32]) -> Vec<u32> {
    row.iter().map(|x| x.to_bits()).collect()
}

fn drive_one(
    engine: &mut MoeEngine,
    prompt: &[u32],
    stream: &[u32],
) -> (Vec<Vec<u32>>, u64) {
    let mut sess = engine.new_session().unwrap();
    let logits = engine.prefill(&mut sess, prompt).unwrap();
    let mut out = vec![row_bits(logits.row(prompt.len() - 1))];
    for &t in stream {
        out.push(row_bits(&engine.decode_step(&mut sess, t).unwrap()));
    }
    (out, engine.timeline.now().to_bits())
}

#[test]
fn expert_obs_is_byte_identical_at_width_1() {
    let Some(dir) = artifacts_dir() else { return };
    let prompt = toks("what is a mixture of experts model");
    let stream = toks("the recorder must not change it");

    let mut off = make_engine(&dir, 1, false).unwrap();
    let (off_bits, off_now) = drive_one(&mut off, &prompt, &stream);
    assert!(!off.obs.is_enabled(), "obs off must stay disabled");
    assert_eq!(off.obs.stream_dropped(), 0);

    let mut on = make_engine(&dir, 1, true).unwrap();
    let (on_bits, on_now) = drive_one(&mut on, &prompt, &stream);
    assert!(on.obs.is_enabled());
    on.obs_tick();
    assert!(
        on.obs.streams().iter().any(|s| !s.is_empty()),
        "an enabled recorder must capture access streams"
    );

    assert_eq!(off_bits, on_bits, "expert_obs changed logits bits");
    assert_eq!(off_now, on_now, "expert_obs moved the virtual timeline");
}

#[test]
fn expert_obs_is_byte_identical_at_width_4_batched() {
    let Some(dir) = artifacts_dir() else { return };
    let streams: Vec<Vec<u32>> = [
        "four decode streams in layer",
        "lockstep through the engine s",
        "batched tick so the recorder ",
        "sees shared and pinned expert",
    ]
    .iter()
    .map(|s| toks(s))
    .collect();
    let ticks = streams[0].len();

    let run = |expert_obs: bool| -> (Vec<Vec<Vec<u32>>>, u64) {
        let mut engine = make_engine(&dir, 4, expert_obs).unwrap();
        let mut sessions: Vec<Session> =
            (0..4).map(|_| engine.new_session().unwrap()).collect();
        let mut out = vec![Vec::new(); 4];
        for t in 0..ticks {
            let tick_toks: Vec<u32> = (0..4).map(|i| streams[i][t]).collect();
            let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
            for (i, slot) in engine
                .decode_batch(&mut refs, &tick_toks)
                .unwrap()
                .into_iter()
                .enumerate()
            {
                out[i].push(row_bits(&slot.unwrap()));
            }
            engine.obs_tick(); // a no-op branch with obs off
        }
        (out, engine.timeline.now().to_bits())
    };

    let (off_bits, off_now) = run(false);
    let (on_bits, on_now) = run(true);
    assert_eq!(off_bits, on_bits, "expert_obs changed batched logits bits");
    assert_eq!(off_now, on_now, "expert_obs moved the batched virtual timeline");
}

fn curve_hits(report: &Json, name: &str) -> Vec<(usize, u64, u64)> {
    report
        .get("curves")
        .and_then(|c| c.get(name))
        .and_then(Json::as_arr)
        .unwrap_or_else(|| panic!("report missing curves.{name}"))
        .iter()
        .map(|p| {
            (
                p.get("k").and_then(Json::as_usize).unwrap(),
                p.get("hits").and_then(Json::as_f64).unwrap() as u64,
                p.get("misses").and_then(Json::as_f64).unwrap() as u64,
            )
        })
        .collect()
}

/// The tentpole invariant on a REAL serving run: width 4, prefix cache,
/// adaptive tiers and transient faults all on — simulated LRU at the
/// engine's actual cache_k must reproduce the measured counters exactly,
/// and the counterfactual curves must be monotone with OPT dominating.
#[test]
fn cache_curves_anchor_to_measured_counters_on_real_serving() {
    let Some(dir) = artifacts_dir() else { return };
    let dir2 = dir.clone();
    let coord = Coordinator::new(
        move || -> Result<MoeEngine> {
            let mut cfg = serving(4, true);
            cfg.prefix_cache = true;
            harness::build_engine_with_serving(&dir2, &cfg, HardwareProfile::rtx3060())
        },
        13,
    );

    let prompts = [
        "what is a mixture of experts model",
        "what is a mixture of experts model and why offload",
        "explain how an LRU cache works",
        "explain how speculative loading works",
        "what is a mixture of experts model", // prefix-cache warm repeat
        "explain how an LRU cache works",
    ];
    let streams: Vec<_> = prompts
        .iter()
        .map(|p| {
            let mut req = Request::new(*p);
            req.max_tokens = 12;
            req.temperature = 0.9;
            coord.submit(req)
        })
        .collect();
    let mut done_spec = None;
    for stream in streams {
        for ev in collect_events(stream) {
            match ev {
                Event::Done { spec_recall_bp, spec_precision_bp, .. } => {
                    done_spec = Some((spec_recall_bp, spec_precision_bp));
                }
                Event::Error { message, .. } | Event::Failed { message, .. } => {
                    panic!("request failed under transient-only faults: {message}")
                }
                Event::Token { .. } => {}
            }
        }
    }

    let report = coord.experts().unwrap();
    assert_eq!(report.get("type").and_then(Json::as_str), Some("experts"));
    assert_eq!(report.get("enabled").and_then(Json::as_bool), Some(true));
    assert!(
        !report.get("experts").and_then(Json::as_arr).unwrap().is_empty(),
        "flight recorder saw no expert activity"
    );
    assert_eq!(
        report.get("stream_dropped").and_then(Json::as_f64),
        Some(0.0),
        "event stream overflowed — anchor would be vacuous"
    );

    // --- the anchor: simulated == measured, exactly
    let measured = report.get("curves").and_then(|c| c.get("measured")).unwrap();
    assert_eq!(
        measured.get("anchored").and_then(Json::as_bool),
        Some(true),
        "simulated LRU at cache_k diverged from measured counters: {measured}"
    );
    let k = measured.get("k").and_then(Json::as_usize).unwrap();
    assert_eq!(k, 2, "engine ran cache_k=2");
    assert_eq!(
        measured.get("sim_hits").and_then(Json::as_f64),
        measured.get("hits").and_then(Json::as_f64),
    );
    assert_eq!(
        measured.get("sim_misses").and_then(Json::as_f64),
        measured.get("misses").and_then(Json::as_f64),
    );

    // --- curve properties on the real stream
    let lru = curve_hits(&report, "lru");
    let opt = curve_hits(&report, "opt");
    assert_eq!(lru.len(), opt.len());
    assert!(!lru.is_empty());
    for w in lru.windows(2) {
        assert!(w[1].1 >= w[0].1, "LRU curve not monotone at k={}", w[1].0);
    }
    for w in opt.windows(2) {
        assert!(w[1].1 >= w[0].1, "OPT curve not monotone at k={}", w[1].0);
    }
    for (l, o) in lru.iter().zip(&opt) {
        assert!(o.1 >= l.1, "OPT below LRU at k={}", l.0);
        assert_eq!(l.1 + l.2, o.1 + o.2, "curves disagree on total uses at k={}", l.0);
    }
    // the measured point sits ON the LRU curve
    let point = lru.iter().find(|(pk, _, _)| *pk == k).unwrap();
    assert_eq!(
        Some(point.1 as f64),
        measured.get("sim_hits").and_then(Json::as_f64)
    );

    // --- per-layer prefetch-quality gauges surfaced everywhere: report,
    // done event, and the metrics registry agree on the aggregate
    let per_layer = report.get("per_layer").and_then(Json::as_arr).unwrap();
    assert!(!per_layer.is_empty());
    for row in per_layer {
        assert!(row.get("spec_recall_bp").is_some());
        assert!(row.get("spec_precision_bp").is_some());
    }
    let (recall_bp, precision_bp) = done_spec.expect("a done event");
    assert_eq!(coord.metrics.gauge("spec_recall_bp"), recall_bp);
    assert_eq!(coord.metrics.gauge("spec_precision_bp"), precision_bp);
    assert!(recall_bp <= 10_000 && precision_bp <= 10_000);

    // the report round-trips through the line protocol
    let parsed = Json::parse(&report.to_string()).unwrap();
    assert_eq!(parsed.get("enabled").and_then(Json::as_bool), Some(true));
}

#[test]
fn experts_report_degrades_explicitly_when_disabled() {
    let Some(dir) = artifacts_dir() else { return };
    let dir2 = dir.clone();
    let coord = Coordinator::new(move || make_engine(&dir2, 1, false), 17);
    let mut req = Request::new("one tiny request");
    req.max_tokens = 4;
    collect_events(coord.submit(req));

    let report = coord.experts().unwrap();
    assert_eq!(report.get("type").and_then(Json::as_str), Some("experts"));
    assert_eq!(report.get("enabled").and_then(Json::as_bool), Some(false));
    assert!(
        report
            .get("error")
            .and_then(Json::as_str)
            .unwrap()
            .contains("disabled"),
        "disabled report must say why"
    );
}
