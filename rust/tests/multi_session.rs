//! Integration: multi-session engine behavior and the coordinator's
//! continuous-batching scheduler. Requires `make artifacts` (skips
//! cleanly otherwise).
//!
//! Covers the refactor's contracts:
//! * interleaving sessions never changes numerics (per-session KV);
//! * concurrent sessions share the warm expert cache (higher hit rate
//!   than back-to-back cold runs);
//! * a failing session does not poison its neighbors;
//! * `max_concurrent_sessions = 1` reproduces the sequential serving
//!   path token for token and sim-second for sim-second;
//! * concurrent TCP connections stream interleaved generations.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use moe_offload::config::{
    HardwareProfile, Manifest, OffloadPolicy, QuantScheme, ServingConfig, SimScale,
};
use moe_offload::coordinator::{collect_events, server::Server, Coordinator, Event, Request};
use moe_offload::engine::{MoeEngine, Session};
use moe_offload::model::ByteTokenizer;
use moe_offload::util::json::Json;
use moe_offload::Result;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() && dir.join("weights.npz").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

fn make_engine(dir: &Path, policy: OffloadPolicy, sessions: usize) -> Result<MoeEngine> {
    let manifest = Manifest::load(dir)?;
    let weights = moe_offload::model::ModelWeights::load(
        &manifest.config,
        &dir.join("weights.npz"),
        QuantScheme::Hqq { bits: 4 },
        QuantScheme::Hqq { bits: 3 },
    )?;
    let serving = ServingConfig {
        policy,
        expert_quant: QuantScheme::Hqq { bits: 3 },
        attn_quant: QuantScheme::Hqq { bits: 4 },
        sim_scale: SimScale::Tiny,
        max_concurrent_sessions: sessions,
        ..Default::default()
    };
    MoeEngine::new(&manifest, weights, &serving, HardwareProfile::rtx3060())
}

/// Teacher-force `tokens` through one session, returning per-step logits.
fn drive(engine: &mut MoeEngine, sess: &mut Session, tokens: &[u32]) -> Vec<Vec<f32>> {
    tokens.iter().map(|&t| engine.decode_step(sess, t).unwrap()).collect()
}

fn max_abs_diff(a: &[Vec<f32>], b: &[Vec<f32>]) -> f32 {
    a.iter()
        .zip(b)
        .flat_map(|(x, y)| x.iter().zip(y).map(|(p, q)| (p - q).abs()))
        .fold(0.0f32, f32::max)
}

#[test]
fn interleaved_sessions_match_sequential_numerics() {
    let Some(dir) = artifacts_dir() else { return };
    let t1: Vec<u32> = "the quick brown fox".bytes().map(|b| b as u32).collect();
    let t2: Vec<u32> = "an lru cache evicts".bytes().map(|b| b as u32).collect();

    // sequential reference: run each stream to completion, one after the
    // other, on one engine
    let mut es = make_engine(&dir, OffloadPolicy::Full { cache_k: 2, spec_n: 2 }, 2).unwrap();
    let mut sa = es.new_session().unwrap();
    let ref1 = drive(&mut es, &mut sa, &t1);
    let mut sb = es.new_session().unwrap();
    let ref2 = drive(&mut es, &mut sb, &t2);

    // interleaved: alternate one decode step per stream per tick
    let mut ei = make_engine(&dir, OffloadPolicy::Full { cache_k: 2, spec_n: 2 }, 2).unwrap();
    let mut s1 = ei.new_session().unwrap();
    let mut s2 = ei.new_session().unwrap();
    let mut got1 = Vec::new();
    let mut got2 = Vec::new();
    for i in 0..t1.len().max(t2.len()) {
        if i < t1.len() {
            got1.push(ei.decode_step(&mut s1, t1[i]).unwrap());
        }
        if i < t2.len() {
            got2.push(ei.decode_step(&mut s2, t2[i]).unwrap());
        }
    }

    // per-session KV isolation: cache warmth may differ, logits may not
    assert!(max_abs_diff(&ref1, &got1) < 1e-4, "stream 1 diverged under interleaving");
    assert!(max_abs_diff(&ref2, &got2) < 1e-4, "stream 2 diverged under interleaving");
}

#[test]
fn concurrent_sessions_share_warm_expert_cache() {
    let Some(dir) = artifacts_dir() else { return };
    let tokens: Vec<u32> = "<user> what is a mixture of experts model?\n<assistant> "
        .bytes()
        .map(|b| b as u32)
        .collect();
    let policy = OffloadPolicy::LruOnly { cache_k: 4 };

    let ratio = |runs: &[&moe_offload::engine::stats::RunStats]| -> f64 {
        let hits: u64 = runs.iter().map(|r| r.total_hits()).sum();
        let misses: u64 = runs.iter().map(|r| r.total_misses()).sum();
        hits as f64 / (hits + misses).max(1) as f64
    };

    // back-to-back cold: each request gets a fresh engine (cold cache)
    let mut cold_runs = Vec::new();
    for _ in 0..2 {
        let mut e = make_engine(&dir, policy, 1).unwrap();
        let mut s = e.new_session().unwrap();
        drive(&mut e, &mut s, &tokens);
        cold_runs.push(s.run.clone());
    }
    let cold = ratio(&cold_runs.iter().collect::<Vec<_>>());

    // concurrent: two sessions interleaved on ONE warm engine
    let mut e = make_engine(&dir, policy, 2).unwrap();
    let mut s1 = e.new_session().unwrap();
    let mut s2 = e.new_session().unwrap();
    for &t in &tokens {
        e.decode_step(&mut s1, t).unwrap();
        e.decode_step(&mut s2, t).unwrap();
    }
    let warm = ratio(&[&s1.run, &s2.run]);

    assert!(
        warm > cold,
        "interleaved sessions should share hot experts: warm {warm:.3} vs cold {cold:.3}"
    );
}

#[test]
fn session_error_does_not_poison_neighbors() {
    let Some(dir) = artifacts_dir() else { return };
    let mut e = make_engine(&dir, OffloadPolicy::Full { cache_k: 2, spec_n: 2 }, 2).unwrap();
    let max = e.weights.cfg.max_seq;

    // neighbor mid-generation
    let mut good = e.new_session().unwrap();
    e.decode_step(&mut good, 65).unwrap();

    // fill a second session to the context limit so its next decode fails
    let mut bad = e.new_session().unwrap();
    let long: Vec<u32> = (0..max).map(|i| (i % 64 + 32) as u32).collect();
    e.prefill(&mut bad, &long).unwrap();
    assert!(e.decode_step(&mut bad, 1).is_err());
    drop(bad);

    // the neighbor keeps decoding, numerically healthy
    let logits = e.decode_step(&mut good, 66).unwrap();
    assert!(logits.iter().all(|x| x.is_finite()));
    assert_eq!(good.position(), 2);
}

#[test]
fn session_pool_is_bounded_by_config() {
    let Some(dir) = artifacts_dir() else { return };
    // KV device memory is reserved per configured session — opening more
    // must refuse rather than silently oversubscribe the modeled VRAM
    let e = make_engine(&dir, OffloadPolicy::Full { cache_k: 2, spec_n: 2 }, 1).unwrap();
    let s1 = e.new_session().unwrap();
    assert_eq!(e.live_session_count(), 1);
    let err = e.new_session().err().expect("pool should be exhausted");
    assert!(err.to_string().contains("session pool exhausted"), "{err}");
    drop(s1);
    assert_eq!(e.live_session_count(), 0);
    assert!(e.new_session().is_ok());
}

#[test]
fn admission_error_leaves_concurrent_request_unharmed() {
    let Some(dir) = artifacts_dir() else { return };
    let coord = Coordinator::new(
        move || make_engine(&dir, OffloadPolicy::Full { cache_k: 2, spec_n: 2 }, 2),
        11,
    );
    let mut ok_req = Request::new("what is perplexity");
    ok_req.max_tokens = 12;
    let ok_stream = coord.submit(ok_req);
    let mut bad_req = Request::new("");
    bad_req.chat = false; // empty raw prompt → admission error
    let bad_stream = coord.submit(bad_req);

    assert!(bad_stream.wait_text().is_err());
    let text = ok_stream.wait_text().unwrap();
    assert!(!text.is_empty());
    assert_eq!(coord.metrics.counter("requests_ok"), 1);
    assert_eq!(coord.metrics.counter("requests_failed"), 1);
}

#[test]
fn single_session_scheduler_matches_direct_engine() {
    let Some(dir) = artifacts_dir() else { return };
    let base_seed = 7u64;
    let dir2 = dir.clone();
    let coord = Coordinator::new(
        move || make_engine(&dir2, OffloadPolicy::Full { cache_k: 2, spec_n: 2 }, 1),
        base_seed,
    );
    let mut req = Request::new("what is perplexity");
    req.max_tokens = 12;
    let events = collect_events(coord.submit(req));
    assert!(coord.is_running(), "worker should stay alive between requests");
    let done = events
        .iter()
        .find_map(|ev| match ev {
            Event::Done { text, new_tokens, tokens_per_s_sim, queue_wait_s, active_sessions, .. } => {
                Some((text.clone(), *new_tokens, *tokens_per_s_sim, *queue_wait_s, *active_sessions))
            }
            _ => None,
        })
        .expect("no done event");

    // replicate the request against a bare engine: same engine build, same
    // request-id-derived seed, same budget/stop rules
    let mut e = make_engine(&dir, OffloadPolicy::Full { cache_k: 2, spec_n: 2 }, 1).unwrap();
    let tokenizer = ByteTokenizer::new();
    let prompt = tokenizer.chat_turn("what is perplexity");
    let mut sess = Session::with_seed(&e, base_seed.wrapping_add(1)).unwrap();
    let mut sampler = sess.sampler(1.0, 1.0);
    let budget = 12usize.min(e.weights.cfg.max_seq - prompt.len() - 1);
    let logits = e.prefill(&mut sess, &prompt).unwrap();
    let mut next = sampler.sample(logits.row(prompt.len() - 1)) as u32;
    let mut text = tokenizer.decode(&[next]);
    let mut generated = 1usize;
    while generated < budget {
        let logits = e.decode_step(&mut sess, next).unwrap();
        next = sampler.sample(&logits) as u32;
        generated += 1;
        text.push_str(&tokenizer.decode(&[next]));
        if generated > 4 && text.ends_with(".\n") {
            break;
        }
    }

    assert_eq!(done.0, text, "scheduler at width 1 must reproduce sequential tokens");
    assert_eq!(done.1, generated);
    // sim-clock identity: both engines started cold, so per-token virtual
    // seconds are identical
    let sim_tps = sess.run.tokens.len() as f64 / sess.run.sim_total_scaled_s;
    assert!(
        (done.2 - sim_tps).abs() <= 1e-9 * sim_tps.abs(),
        "sim throughput {} != {}",
        done.2,
        sim_tps
    );
    assert!(done.3 >= 0.0);
    assert_eq!(done.4, 1, "width-1 scheduler reports one active session");
}

#[test]
fn concurrent_tcp_connections_interleave() {
    let Some(dir) = artifacts_dir() else { return };

    // one attempt: serve two concurrent TCP requests, return the max
    // active_sessions either done event reports
    let attempt = |dir: PathBuf| -> usize {
        let coord = Arc::new(Coordinator::new(
            move || make_engine(&dir, OffloadPolicy::Full { cache_k: 2, spec_n: 2 }, 2),
            5,
        ));
        let server = Server::bind("127.0.0.1:0", Arc::clone(&coord)).unwrap();
        let addr = server.local_addr().unwrap();
        std::thread::spawn(move || {
            let _ = server.serve(Some(2));
        });

        let fire = |conn: &mut TcpStream| {
            writeln!(
                conn,
                r#"{{"prompt":"what is a mixture of experts model","max_tokens":32,"temperature":0}}"#
            )
            .unwrap();
            conn.flush().unwrap();
        };
        let mut c1 = TcpStream::connect(addr).unwrap();
        let mut c2 = TcpStream::connect(addr).unwrap();
        fire(&mut c1);
        fire(&mut c2);

        let read_done = |conn: TcpStream| -> (usize, Json) {
            let reader = BufReader::new(conn);
            let mut tokens = 0usize;
            for line in reader.lines() {
                let v = Json::parse(&line.unwrap()).unwrap();
                match v.get("type").and_then(Json::as_str) {
                    Some("token") => tokens += 1,
                    Some("done") => return (tokens, v),
                    other => panic!("unexpected event {other:?}"),
                }
            }
            panic!("stream closed without done");
        };
        let (tok1, done1) = read_done(c1);
        let (tok2, done2) = read_done(c2);

        assert!(tok1 >= 1 && tok2 >= 1);
        assert_eq!(coord.metrics.counter("requests_ok"), 2);
        assert!(coord.metrics.counter("scheduler_ticks") >= 1);
        let active1 = done1.get("active_sessions").unwrap().as_usize().unwrap();
        let active2 = done2.get("active_sessions").unwrap().as_usize().unwrap();
        active1.max(active2)
    };

    // with a width-2 scheduler both requests are live together, so the
    // first one to finish sees two active sessions. The second request
    // races request 1's (short) generation through the TCP stack, so
    // allow a few attempts before declaring the scheduler serial.
    for round in 0..3 {
        if attempt(dir.clone()) >= 2 {
            return;
        }
        eprintln!("round {round}: requests were not observed concurrently, retrying");
    }
    panic!("width-2 scheduler never interleaved two TCP requests in 3 attempts");
}

#[test]
fn concurrent_serving_beats_cold_backtoback_hit_rate() {
    let Some(dir) = artifacts_dir() else { return };
    let prompt = "what is a mixture of experts model";
    let hit_ratio = |m: &moe_offload::telemetry::Metrics| -> f64 {
        let h = m.counter("expert_cache_hits") as f64;
        let mi = m.counter("expert_cache_misses") as f64;
        h / (h + mi).max(1.0)
    };

    // two identical greedy requests served CONCURRENTLY on one engine
    let dir2 = dir.clone();
    let coord = Coordinator::new(
        move || make_engine(&dir2, OffloadPolicy::LruOnly { cache_k: 4 }, 2),
        3,
    );
    let mut req = Request::new(prompt);
    req.max_tokens = 16;
    req.temperature = 0.0; // greedy → identical tokens in every scenario
    let s1 = coord.submit(req.clone());
    let s2 = coord.submit(req.clone());
    s1.wait_text().unwrap();
    s2.wait_text().unwrap();
    let warm = hit_ratio(&coord.metrics);

    // the same two requests back-to-back on COLD engines
    let mut cold_hits = 0u64;
    let mut cold_misses = 0u64;
    for _ in 0..2 {
        let dir3 = dir.clone();
        let coord = Coordinator::new(
            move || make_engine(&dir3, OffloadPolicy::LruOnly { cache_k: 4 }, 1),
            3,
        );
        let mut req = Request::new(prompt);
        req.max_tokens = 16;
        req.temperature = 0.0;
        coord.submit(req).wait_text().unwrap();
        cold_hits += coord.metrics.counter("expert_cache_hits");
        cold_misses += coord.metrics.counter("expert_cache_misses");
    }
    let cold = cold_hits as f64 / (cold_hits + cold_misses).max(1) as f64;

    assert!(
        warm > cold,
        "concurrent serving should strictly beat cold back-to-back: {warm:.3} vs {cold:.3}"
    );
}
