//! Integration: the prefix-cache subsystem against the real engine.
//! Requires `make artifacts` (skips cleanly otherwise); the tree/manager
//! contracts are also covered by always-on unit + property tests in
//! `rust/src/prefix/`.
//!
//! Covers the subsystem's contracts:
//! * a session admitted on a warm prefix prefills only the uncached tail
//!   and decodes BIT-IDENTICALLY to the same prompt cold-prefilled;
//! * preempt→resume of a seeded session stays bit-exact;
//! * the coordinator surfaces hits/reuse in the done event and produces
//!   byte-identical greedy text warm vs. cold;
//! * under pool pressure, cold cached prefixes are evicted before any
//!   live session is preempted.

use std::path::{Path, PathBuf};

use moe_offload::config::{
    HardwareProfile, OffloadPolicy, QuantScheme, ServingConfig, SimScale,
};
use moe_offload::coordinator::{collect_events, Coordinator, Event, Request};
use moe_offload::engine::MoeEngine;
use moe_offload::harness;
use moe_offload::Result;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() && dir.join("weights.npz").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

fn make_engine(
    dir: &Path,
    sessions: usize,
    kv_pool_tokens: Option<usize>,
    prefix_cache: bool,
) -> Result<MoeEngine> {
    let serving = ServingConfig {
        policy: OffloadPolicy::Full { cache_k: 2, spec_n: 2 },
        expert_quant: QuantScheme::Hqq { bits: 3 },
        attn_quant: QuantScheme::Hqq { bits: 4 },
        sim_scale: SimScale::Tiny,
        max_concurrent_sessions: sessions,
        kv_block_tokens: 16,
        kv_pool_tokens,
        prefix_cache,
        ..Default::default()
    };
    harness::build_engine_with_serving(dir, &serving, HardwareProfile::rtx3060())
}

fn bits(logits: &[Vec<f32>]) -> Vec<Vec<u32>> {
    logits.iter().map(|row| row.iter().map(|x| x.to_bits()).collect()).collect()
}

fn row_bits(row: &[f32]) -> Vec<u32> {
    row.iter().map(|x| x.to_bits()).collect()
}

/// 44 prompt tokens (2 full 16-token blocks + a partial tail) and a
/// continuation decoded on top of them.
fn workload() -> (Vec<u32>, Vec<u32>) {
    let prompt: Vec<u32> = "please summarize the mixture of experts paper"
        .bytes()
        .take(44)
        .map(|b| b as u32)
        .collect();
    let cont: Vec<u32> = "briefly".bytes().map(|b| b as u32).collect();
    assert_eq!(prompt.len(), 44);
    (prompt, cont)
}

#[test]
fn warm_prefix_admission_is_bit_identical_to_cold_prefill() {
    let Some(dir) = artifacts_dir() else { return };
    let (prompt, cont) = workload();

    // cold reference: prefix cache off entirely
    let mut cold = make_engine(&dir, 1, Some(256), false).unwrap();
    assert!(cold.prefix.is_none(), "cache must be strictly opt-in");
    let mut cs = cold.new_session().unwrap();
    let cold_logits = cold.prefill(&mut cs, &prompt).unwrap();
    let cold_cont: Vec<Vec<f32>> =
        cont.iter().map(|&t| cold.decode_step(&mut cs, t).unwrap()).collect();

    // warm path: first request populates the cache, second one seeds
    let mut warm = make_engine(&dir, 1, Some(256), true).unwrap();
    let mut s1 = warm.new_session().unwrap();
    let (first_logits, reused1) = warm.prefill_cached(&mut s1, &prompt).unwrap();
    assert_eq!(reused1, 0, "empty cache cannot seed");
    assert_eq!(
        bits(&[first_logits.row(prompt.len() - 1).to_vec()]),
        bits(&[cold_logits.row(prompt.len() - 1).to_vec()]),
        "cache-on cold prefill must equal cache-off prefill"
    );
    let inserted = warm.prefix_insert(&s1, &prompt).unwrap();
    assert_eq!(inserted, 2, "44 tokens cache as 2 full 16-token blocks");
    drop(s1);

    let mut s2 = warm.new_session().unwrap();
    let (tail_logits, reused2) = warm.prefill_cached(&mut s2, &prompt).unwrap();
    assert_eq!(reused2, 32, "longest block-aligned cached prefix");
    assert_eq!(s2.position(), prompt.len(), "seed + tail covers the prompt");
    assert_eq!(tail_logits.shape[0], prompt.len() - 32, "logits cover the tail only");
    // every tail position must match the cold prefill bit for bit...
    for t in 0..prompt.len() - 32 {
        assert_eq!(
            row_bits(tail_logits.row(t)),
            row_bits(cold_logits.row(32 + t)),
            "tail prefill position {t} diverged from cold prefill"
        );
    }
    // ...and so must every decoded continuation token
    let warm_cont: Vec<Vec<f32>> =
        cont.iter().map(|&t| warm.decode_step(&mut s2, t).unwrap()).collect();
    assert_eq!(
        bits(&cold_cont),
        bits(&warm_cont),
        "a warm-admitted session must decode bit-identically to a cold one"
    );
    // accounting: the seeded blocks are shared between tree and session
    assert_eq!(s2.kv.mapped_blocks(), warm.kv_pool.blocks_for(s2.position() + cont.len()));
    assert!(warm.kv_pool.stats().shared_blocks >= 2);
}

#[test]
fn preempt_resume_of_a_seeded_session_stays_bit_exact() {
    let Some(dir) = artifacts_dir() else { return };
    let (prompt, cont) = workload();
    let (head, tail) = cont.split_at(3);

    // reference: uninterrupted cold stream
    let mut cold = make_engine(&dir, 1, Some(256), false).unwrap();
    let mut cs = cold.new_session().unwrap();
    cold.prefill(&mut cs, &prompt).unwrap();
    for &t in head {
        cold.decode_step(&mut cs, t).unwrap();
    }
    let ref_tail: Vec<Vec<f32>> =
        tail.iter().map(|&t| cold.decode_step(&mut cs, t).unwrap()).collect();

    // warm + preempted stream
    let mut warm = make_engine(&dir, 1, Some(256), true).unwrap();
    let mut s1 = warm.new_session().unwrap();
    warm.prefill_cached(&mut s1, &prompt).unwrap();
    warm.prefix_insert(&s1, &prompt).unwrap();
    drop(s1);
    let mut s2 = warm.new_session().unwrap();
    let (_, reused) = warm.prefill_cached(&mut s2, &prompt).unwrap();
    assert_eq!(reused, 32);
    for &t in head {
        warm.decode_step(&mut s2, t).unwrap();
    }
    let shared_before = warm.kv_pool.stats().shared_blocks;
    assert!(shared_before >= 2, "seeded prefix blocks are shared pre-preemption");
    warm.preempt_session(&mut s2).unwrap();
    assert_eq!(
        warm.kv_pool.stats().shared_blocks,
        0,
        "preemption releases the session's share; the tree keeps its own"
    );
    assert_eq!(warm.prefix.as_ref().unwrap().cached_blocks(), 2);
    warm.resume_session(&mut s2).unwrap();
    let got_tail: Vec<Vec<f32>> =
        tail.iter().map(|&t| warm.decode_step(&mut s2, t).unwrap()).collect();
    assert_eq!(
        bits(&ref_tail),
        bits(&got_tail),
        "preempt+resume of a seeded session must continue bit-identically"
    );
}

#[test]
fn coordinator_repeated_prompt_hits_the_cache_with_identical_text() {
    let Some(dir) = artifacts_dir() else { return };
    let mk = |prompt: &str| {
        let mut r = Request::new(prompt.to_string());
        r.chat = false;
        r.max_tokens = 6;
        r.temperature = 0.0; // greedy: text depends only on logits
        r
    };
    let done = |evs: &[Event]| -> (String, bool, u64) {
        evs.iter()
            .find_map(|ev| match ev {
                Event::Done { text, prefix_hit, prefix_tokens_reused, .. } => {
                    Some((text.clone(), *prefix_hit, *prefix_tokens_reused))
                }
                _ => None,
            })
            .expect("request must finish, not error")
    };
    let prompt = "w".repeat(40);

    // cache off: the stateless baseline text
    let dir2 = dir.clone();
    let coord_off = Coordinator::new(move || make_engine(&dir2, 1, Some(256), false), 7);
    let (cold_text, hit, reused) = done(&collect_events(coord_off.submit(mk(&prompt))));
    assert!(!hit && reused == 0, "cache-off path must never report reuse");
    coord_off.shutdown();

    // cache on: first request inserts, second seeds
    let dir2 = dir.clone();
    let coord = Coordinator::new(move || make_engine(&dir2, 1, Some(256), true), 7);
    let (first_text, first_hit, _) = done(&collect_events(coord.submit(mk(&prompt))));
    assert!(!first_hit, "nothing cached yet");
    assert_eq!(first_text, cold_text, "cache-on cold request matches cache-off");
    let (second_text, second_hit, second_reused) =
        done(&collect_events(coord.submit(mk(&prompt))));
    assert!(second_hit, "repeated prompt must hit the prefix cache");
    assert_eq!(second_reused, 32, "40-token prompt reuses 2 full 16-token blocks");
    assert_eq!(second_text, cold_text, "warm text must equal cold text under greedy");
    assert!(coord.metrics.gauge("prefix_hits") >= 1);
    assert!(coord.metrics.gauge("prefix_tokens_reused") >= 32);
    assert!(coord.metrics.gauge("prefix_cache_blocks") >= 2);
    coord.shutdown();
}

#[test]
fn cold_prefixes_are_evicted_before_any_session_is_preempted() {
    let Some(dir) = artifacts_dir() else { return };
    // pool of 6 blocks × 16 tokens. Request A (64-token prompt) caches 4
    // blocks on completion, leaving 2 free; request B (disjoint 64-token
    // prompt) then needs 4+ blocks — the engine must reclaim A's cold
    // prefix instead of failing or preempting anyone.
    let dir2 = dir.clone();
    let coord = Coordinator::new(move || make_engine(&dir2, 2, Some(96), true), 7);
    let mk = |prompt: String| {
        let mut r = Request::new(prompt);
        r.chat = false;
        r.max_tokens = 4;
        r.temperature = 0.0;
        r
    };
    let ea = collect_events(coord.submit(mk("a".repeat(64))));
    assert!(
        ea.iter().any(|e| matches!(e, Event::Done { .. })),
        "request A must finish"
    );
    let eb = collect_events(coord.submit(mk("b".repeat(64))));
    let evicted = eb
        .iter()
        .find_map(|ev| match ev {
            Event::Done { prefix_evicted_blocks, .. } => Some(*prefix_evicted_blocks),
            _ => None,
        })
        .expect("request B must finish, not error");
    assert!(evicted >= 1, "B's admission must have reclaimed A's cold prefix");
    assert_eq!(coord.metrics.counter("requests_failed"), 0);
    assert_eq!(
        coord.metrics.gauge("kv_preemptions"),
        0,
        "eviction must come BEFORE preemption"
    );
    coord.shutdown();
}
