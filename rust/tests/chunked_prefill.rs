//! Integration: chunked-prefill tick scheduling against the real engine.
//! Requires `make artifacts` (skips cleanly otherwise); the planner's
//! pure scheduling policy is covered by always-on unit tests in
//! `rust/src/sched/`, the config knobs in `rust/src/config/serving.rs`.
//!
//! Chunked prefill is a pure execution-order optimization for the
//! emitted streams, so the contracts are equivalences plus one strict
//! inequality:
//! * chunked OFF is byte-identical to the synchronous-admission
//!   scheduler (the knobs are inert behind the switch);
//! * chunked ON emits bit-identical per-session token streams at widths
//!   1 and 4, on both the fused (batched) path and the sequential
//!   fallback, with the prefix cache on, and across preempt/resume
//!   mid-prefill — only tick boundaries move;
//! * a mixed tick performs strictly fewer expert loads than the same
//!   tick's prefill chunk and decode batch run separately (the merged
//!   union dedup — the reason to fuse at all).

use std::path::{Path, PathBuf};

use moe_offload::config::{
    HardwareProfile, OffloadPolicy, QuantScheme, ServingConfig, SimScale,
};
use moe_offload::coordinator::{collect_events, Coordinator, Event, Request};
use moe_offload::engine::{MoeEngine, PrefillChunk, Session};
use moe_offload::harness;
use moe_offload::Result;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() && dir.join("weights.npz").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

fn serving(width: usize) -> ServingConfig {
    ServingConfig {
        policy: OffloadPolicy::Full { cache_k: 2, spec_n: 2 },
        expert_quant: QuantScheme::Hqq { bits: 3 },
        attn_quant: QuantScheme::Hqq { bits: 4 },
        sim_scale: SimScale::Tiny,
        max_concurrent_sessions: width,
        ..Default::default()
    }
}

fn make_engine(dir: &Path, s: &ServingConfig) -> Result<MoeEngine> {
    harness::build_engine_with_serving(dir, s, HardwareProfile::rtx3060())
}

fn toks(s: &str) -> Vec<u32> {
    s.bytes().map(|b| b as u32).collect()
}

fn bits(rows: &[Vec<f32>]) -> Vec<Vec<u32>> {
    rows.iter().map(|r| r.iter().map(|x| x.to_bits()).collect()).collect()
}

/// Run `requests` through a coordinator built from `cfg`, collecting the
/// final text of each (in submit order) plus a few metric readings.
fn run_workload(
    dir: &Path,
    cfg: ServingConfig,
    requests: Vec<Request>,
) -> (Vec<String>, u64, u64, u64) {
    let dir2 = dir.to_path_buf();
    let coord = Coordinator::new(
        move || harness::build_engine_with_serving(&dir2, &cfg, HardwareProfile::rtx3060()),
        7,
    );
    let streams: Vec<_> = requests.into_iter().map(|r| coord.submit(r)).collect();
    let texts: Vec<String> = streams
        .into_iter()
        .map(|s| {
            collect_events(s)
                .iter()
                .find_map(|ev| match ev {
                    Event::Done { text, .. } => Some(text.clone()),
                    Event::Error { message, .. } => panic!("request failed: {message}"),
                    _ => None,
                })
                .expect("request must finish")
        })
        .collect();
    let failed = coord.metrics.counter("requests_failed");
    let mixed = coord.metrics.gauge("mixed_ticks");
    let preempted = coord.metrics.gauge("kv_preemptions");
    (texts, failed, mixed, preempted)
}

fn mk(prompt: String, max_tokens: usize) -> Request {
    let mut r = Request::new(prompt);
    r.chat = false;
    r.max_tokens = max_tokens;
    r
}

/// A mixed workload: three chatty decoders plus one long admission that
/// spans several prefill chunks.
fn mixed_requests() -> Vec<Request> {
    vec![
        mk("what is a mixture of experts?".into(), 16),
        mk("explain lru caching briefly..".into(), 16),
        mk("why is my program slow today?".into(), 16),
        mk("x".repeat(60), 8),
    ]
}

#[test]
fn chunked_off_is_byte_identical_and_knobs_are_inert() {
    let Some(dir) = artifacts_dir() else { return };
    // the synchronous path must not depend on the (inert) chunk knobs
    let base = serving(4);
    let weird = ServingConfig {
        prefill_chunk_tokens: 7,
        max_batch_tokens: Some(5),
        ..serving(4)
    };
    let (t0, f0, m0, _) = run_workload(&dir, base, mixed_requests());
    let (t1, f1, m1, _) = run_workload(&dir, weird, mixed_requests());
    assert_eq!(f0 + f1, 0);
    assert_eq!(m0, 0, "chunked off must never run a mixed tick");
    assert_eq!(m1, 0);
    assert_eq!(t0, t1, "inert knobs must not change any stream");
}

#[test]
fn chunked_on_streams_are_bit_identical_at_width_4() {
    let Some(dir) = artifacts_dir() else { return };
    let off = serving(4);
    let on = ServingConfig { chunked_prefill: true, ..serving(4) };
    let (t_off, f_off, _, _) = run_workload(&dir, off, mixed_requests());
    let (t_on, f_on, mixed, _) = run_workload(&dir, on, mixed_requests());
    assert_eq!(f_off + f_on, 0);
    assert_eq!(
        t_off, t_on,
        "chunked admission must not change any request's token stream"
    );
    assert!(
        mixed >= 1,
        "the long admission must have fused at least one chunk with live decodes"
    );
}

#[test]
fn chunked_on_streams_are_bit_identical_at_width_1() {
    let Some(dir) = artifacts_dir() else { return };
    let reqs = || vec![mk("y".repeat(50), 8), mk("tell me about vram".into(), 8)];
    let off = serving(1);
    let on = ServingConfig { chunked_prefill: true, ..serving(1) };
    let (t_off, f_off, _, _) = run_workload(&dir, off, reqs());
    let (t_on, f_on, _, _) = run_workload(&dir, on, reqs());
    assert_eq!(f_off + f_on, 0);
    assert_eq!(t_off, t_on, "width-1 chunked prefill must be stream-identical");
}

#[test]
fn chunked_on_sequential_fallback_is_bit_identical() {
    let Some(dir) = artifacts_dir() else { return };
    let off = ServingConfig { batched_decode: false, ..serving(4) };
    let on = ServingConfig {
        batched_decode: false,
        chunked_prefill: true,
        // a tight budget exercises chunk deferral under live decodes
        max_batch_tokens: Some(8),
        ..serving(4)
    };
    let (t_off, f_off, _, _) = run_workload(&dir, off, mixed_requests());
    let (t_on, f_on, mixed, _) = run_workload(&dir, on, mixed_requests());
    assert_eq!(f_off + f_on, 0);
    assert_eq!(t_off, t_on, "the sequential fallback must be stream-identical");
    assert_eq!(mixed, 0, "sequential ticks never fuse (no step_mixed)");
}

/// Prefix-cache seeding composes with tail chunking, and a session
/// preempted MID-PREFILL resumes bit-identically: the paged-KV pool is
/// sized so the older stream's decode growth forces a preemption while
/// the younger admission is still feeding its prompt.
#[test]
fn chunked_on_with_prefix_cache_and_mid_prefill_preemption() {
    let Some(dir) = artifacts_dir() else { return };
    let base = ServingConfig {
        max_concurrent_sessions: 2,
        kv_block_tokens: 16,
        kv_pool_tokens: Some(128),
        prefix_cache: true,
        // budget-only stopping makes every stream's length — and so the
        // engineered pool pressure — deterministic
        stop_suffix: String::new(),
        ..serving(2)
    };
    let on = ServingConfig { chunked_prefill: true, ..base.clone() };
    // A (62-token prompt, 4 blocks) transitions to decode after 4 chunks
    // and crosses position 64 (needing a 5th block) while B's 60-token
    // prompt is still chunk-feeding; B's own 4th block then finds the
    // pool dry — the youngest (B, MID-PREFILL) is swapped out, resumed
    // once A finishes. C repeats A's prompt and seeds from the prefix
    // cache over the same pressured pool.
    let reqs = || {
        vec![
            mk("a".repeat(62), 12),
            mk("b".repeat(60), 8),
            mk("a".repeat(62), 8),
        ]
    };
    let (t_off, f_off, _, _) = run_workload(&dir, base, reqs());
    let (t_on, f_on, _, preempted) = run_workload(&dir, on, reqs());
    assert_eq!(f_off + f_on, 0);
    assert_eq!(
        t_off, t_on,
        "prefix seeding + tail chunking + mid-prefill preemption must not \
         change any stream"
    );
    assert!(
        preempted >= 1,
        "the workload is sized to force at least one preemption"
    );
}

/// Engine-level bit-identity: (a) decode logits are unchanged by a
/// prefill chunk riding the tick, and (b) the chunk's logits equal a
/// monolithic prefill of the same prompt, chunk boundaries and all.
#[test]
fn step_mixed_is_bit_identical_to_unfused_execution() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = serving(4);
    let short = toks("the quick brown!");
    let long = toks("an lru cache evicts the coldest expert when a new one arrives!!!");
    assert_eq!(long.len(), 64);
    let streams: Vec<Vec<u32>> = (0..3)
        .map(|i| (0..4).map(|t| short[(i * 5 + t) % short.len()]).collect())
        .collect();

    // reference 1: decode-only ticks, no chunk anywhere
    let mut e1 = make_engine(&dir, &cfg).unwrap();
    let mut d1: Vec<Session> = (0..3)
        .map(|i| {
            let mut s = e1.new_session().unwrap();
            e1.prefill(&mut s, &short[..8 + i]).unwrap();
            s
        })
        .collect();
    let mut ref_logits: Vec<Vec<Vec<f32>>> = vec![Vec::new(); 3];
    for t in 0..4 {
        let tick: Vec<u32> = (0..3).map(|i| streams[i][t]).collect();
        let mut refs: Vec<&mut Session> = d1.iter_mut().collect();
        for (i, slot) in e1.decode_batch(&mut refs, &tick).unwrap().into_iter().enumerate() {
            ref_logits[i].push(slot.unwrap());
        }
    }

    // reference 2: the long prompt through one monolithic prefill
    let mut e3 = make_engine(&dir, &cfg).unwrap();
    let mut p3 = e3.new_session().unwrap();
    let mono = e3.prefill(&mut p3, &long).unwrap();

    // mixed: the same decode ticks with 16-token chunks riding along
    let mut e2 = make_engine(&dir, &cfg).unwrap();
    let mut d2: Vec<Session> = (0..3)
        .map(|i| {
            let mut s = e2.new_session().unwrap();
            e2.prefill(&mut s, &short[..8 + i]).unwrap();
            s
        })
        .collect();
    let mut chunk_sess = e2.new_session().unwrap();
    let mut got_logits: Vec<Vec<Vec<f32>>> = vec![Vec::new(); 3];
    let mut chunk_rows: Vec<Vec<f32>> = Vec::new();
    for t in 0..4 {
        let tick: Vec<u32> = (0..3).map(|i| streams[i][t]).collect();
        let fed = t * 16;
        let chunk = &long[fed..fed + 16];
        let (slots, cslot) = {
            let mut refs: Vec<&mut Session> = d2.iter_mut().collect();
            e2.step_mixed(
                &mut refs,
                &tick,
                Some(PrefillChunk { sess: &mut chunk_sess, tokens: chunk }),
            )
            .unwrap()
        };
        for (i, slot) in slots.into_iter().enumerate() {
            got_logits[i].push(slot.unwrap());
        }
        let clog = cslot.expect("chunk submitted").unwrap();
        assert_eq!(clog.shape[0], 16);
        for r in 0..16 {
            chunk_rows.push(clog.row(r).to_vec());
        }
    }

    for i in 0..3 {
        assert_eq!(
            bits(&ref_logits[i]),
            bits(&got_logits[i]),
            "decode session {i} diverged when a prefill chunk rode its ticks"
        );
    }
    let mono_rows: Vec<Vec<f32>> = (0..64).map(|r| mono.row(r).to_vec()).collect();
    assert_eq!(
        bits(&mono_rows),
        bits(&chunk_rows),
        "chunked prefill logits must equal the monolithic prefill bitwise"
    );
    assert_eq!(chunk_sess.position(), 64);
    assert_eq!(e2.batch.mixed_ticks, 4);
    assert!(e2.batch.prefill_rows == 64 && e2.batch.loads_deduped > 0);
}

/// Preemption in the middle of a chunked prefill round-trips bit-exactly:
/// swap out after some chunks, resume, finish, and match the monolithic
/// prefill logits row for row.
#[test]
fn mid_prefill_preempt_resume_is_bit_exact() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = serving(2);
    let long = toks("speculative loading hides the pcie latency behind compute..");

    let mut e1 = make_engine(&dir, &cfg).unwrap();
    let mut s1 = e1.new_session().unwrap();
    let mono = e1.prefill(&mut s1, &long).unwrap();

    let mut e2 = make_engine(&dir, &cfg).unwrap();
    let mut s2 = e2.new_session().unwrap();
    let mut rows: Vec<Vec<f32>> = Vec::new();
    let first = e2.prefill(&mut s2, &long[..16]).unwrap();
    for r in 0..16 {
        rows.push(first.row(r).to_vec());
    }
    e2.preempt_session(&mut s2).unwrap();
    e2.resume_session(&mut s2).unwrap();
    let rest = e2.prefill(&mut s2, &long[16..]).unwrap();
    for r in 0..long.len() - 16 {
        rows.push(rest.row(r).to_vec());
    }

    let mono_rows: Vec<Vec<f32>> = (0..long.len()).map(|r| mono.row(r).to_vec()).collect();
    assert_eq!(
        bits(&mono_rows),
        bits(&rows),
        "a prefill interrupted by preempt/resume must stay bit-identical"
    );
}

/// The point of fusing: one mixed tick stages strictly fewer experts
/// than the same tick's prefill chunk and decode batch run separately.
/// OnDemand placement makes the count exact — every demand load is a
/// cache miss, nothing is retained between stagings.
#[test]
fn mixed_tick_stages_strictly_fewer_expert_loads_than_split_execution() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = ServingConfig {
        policy: OffloadPolicy::OnDemand,
        ..serving(4)
    };
    let short = toks("the quick brown!");
    let chunk = toks("the quick brown fox jumps over t");
    assert_eq!(chunk.len(), 32);
    let chunk = &chunk[..16];
    let tick: Vec<u32> = (0..3).map(|i| short[i]).collect();

    let setup = |engine: &mut MoeEngine| -> (Vec<Session>, Session) {
        let decoders: Vec<Session> = (0..3)
            .map(|i| {
                let mut s = engine.new_session().unwrap();
                engine.prefill(&mut s, &short[..8 + i]).unwrap();
                s
            })
            .collect();
        let chunk_sess = engine.new_session().unwrap();
        (decoders, chunk_sess)
    };

    // fused: one mixed tick
    let mut ea = make_engine(&dir, &cfg).unwrap();
    let (mut da, mut ca) = setup(&mut ea);
    let before = ea.cache.stats.misses;
    let (slots_a, cslot_a) = {
        let mut refs: Vec<&mut Session> = da.iter_mut().collect();
        ea.step_mixed(&mut refs, &tick, Some(PrefillChunk { sess: &mut ca, tokens: chunk }))
            .unwrap()
    };
    let fused_loads = ea.cache.stats.misses - before;
    let logits_a: Vec<Vec<f32>> = slots_a.into_iter().map(|s| s.unwrap()).collect();
    cslot_a.expect("chunk submitted").unwrap();

    // split: the same chunk, then the same decode batch, separately
    let mut eb = make_engine(&dir, &cfg).unwrap();
    let (mut db, mut cb) = setup(&mut eb);
    let before = eb.cache.stats.misses;
    eb.prefill(&mut cb, chunk).unwrap();
    let slots_b = {
        let mut refs: Vec<&mut Session> = db.iter_mut().collect();
        eb.decode_batch(&mut refs, &tick).unwrap()
    };
    let split_loads = eb.cache.stats.misses - before;
    let logits_b: Vec<Vec<f32>> = slots_b.into_iter().map(|s| s.unwrap()).collect();

    assert!(
        fused_loads < split_loads,
        "a mixed tick must stage strictly fewer experts than the split \
         execution ({fused_loads} vs {split_loads}) — the merged union dedup"
    );
    assert_eq!(
        bits(&logits_a),
        bits(&logits_b),
        "fusing must not change the decode logits"
    );
    assert_eq!(ea.batch.mixed_ticks, 1);
    assert!(ea.batch.loads_deduped > 0, "the overlap is what the dedup counter counts");
}
