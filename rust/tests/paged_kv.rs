//! Integration: the paged KV-cache subsystem against the real engine.
//! Requires `make artifacts` (skips cleanly otherwise); the accounting-
//! only contracts are also covered by always-on unit tests in
//! `rust/src/kv/`.
//!
//! Covers the subsystem's contracts:
//! * width-1 paged decode is bit-identical to the contiguous KV path
//!   (one block spanning max_seq ≙ the old static reservation);
//! * preemption→resume round-trips preserve the stream bit-exactly;
//! * at a fixed VRAM budget the paged pool admits strictly more
//!   concurrent sessions than static reservation;
//! * the coordinator finishes every request under KV pressure (preempting
//!   rather than failing) and surfaces pool telemetry in done events.

use std::path::{Path, PathBuf};

use moe_offload::config::{
    HardwareProfile, Manifest, OffloadPolicy, QuantScheme, ServingConfig, SimScale,
};
use moe_offload::coordinator::{collect_events, Coordinator, Event, Request};
use moe_offload::engine::MoeEngine;
use moe_offload::harness;
use moe_offload::{Error, Result};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() && dir.join("weights.npz").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

fn make_engine(
    dir: &Path,
    sessions: usize,
    kv_block_tokens: usize,
    kv_pool_tokens: Option<usize>,
) -> Result<MoeEngine> {
    let serving = ServingConfig {
        policy: OffloadPolicy::Full { cache_k: 2, spec_n: 2 },
        expert_quant: QuantScheme::Hqq { bits: 3 },
        attn_quant: QuantScheme::Hqq { bits: 4 },
        sim_scale: SimScale::Tiny,
        max_concurrent_sessions: sessions,
        kv_block_tokens,
        kv_pool_tokens,
        ..Default::default()
    };
    harness::build_engine_with_serving(dir, &serving, HardwareProfile::rtx3060())
}

fn bits(logits: &[Vec<f32>]) -> Vec<Vec<u32>> {
    logits.iter().map(|row| row.iter().map(|x| x.to_bits()).collect()).collect()
}

#[test]
fn paged_width1_decode_is_bit_identical_to_contiguous() {
    let Some(dir) = artifacts_dir() else { return };
    let tokens: Vec<u32> = "the quick brown fox jumps over the lazy dog"
        .bytes()
        .map(|b| b as u32)
        .collect();

    // contiguous reference: one block spans the whole sequence, i.e. the
    // old static full-sequence reservation expressed in pool terms
    let max_seq = Manifest::load(&dir).unwrap().config.max_seq;
    let mut contig = make_engine(&dir, 1, max_seq, None).unwrap();
    assert_eq!(
        contig.kv_pool.block_tokens(),
        max_seq,
        "block size clamps to max_seq — one block = contiguous"
    );
    let mut cs = contig.new_session().unwrap();
    let ref_logits: Vec<Vec<f32>> =
        tokens.iter().map(|&t| contig.decode_step(&mut cs, t).unwrap()).collect();

    // paged: small blocks, committed on demand as decode advances
    let mut paged = make_engine(&dir, 1, 8, None).unwrap();
    let mut ps = paged.new_session().unwrap();
    let paged_logits: Vec<Vec<f32>> =
        tokens.iter().map(|&t| paged.decode_step(&mut ps, t).unwrap()).collect();

    assert_eq!(
        bits(&ref_logits),
        bits(&paged_logits),
        "block size must never change numerics"
    );
    // and the paged session really did page: several blocks, on demand
    assert_eq!(ps.kv.mapped_blocks(), tokens.len().div_ceil(8));
    assert_eq!(cs.kv.mapped_blocks(), 1);
}

#[test]
fn preempt_resume_roundtrip_is_bit_exact() {
    let Some(dir) = artifacts_dir() else { return };
    let prefix: Vec<u32> = "a mixture of experts ".bytes().map(|b| b as u32).collect();
    let cont: Vec<u32> = "routes tokens".bytes().map(|b| b as u32).collect();

    // reference: one uninterrupted stream
    let mut e1 = make_engine(&dir, 1, 16, None).unwrap();
    let mut s1 = e1.new_session().unwrap();
    for &t in &prefix {
        e1.decode_step(&mut s1, t).unwrap();
    }
    let ref_cont: Vec<Vec<f32>> =
        cont.iter().map(|&t| e1.decode_step(&mut s1, t).unwrap()).collect();

    // preempted stream: swap out to host mid-decode, resume, continue
    let mut e2 = make_engine(&dir, 1, 16, None).unwrap();
    let mut s2 = e2.new_session().unwrap();
    for &t in &prefix {
        e2.decode_step(&mut s2, t).unwrap();
    }
    let pos_before = s2.position();
    let held_before = s2.kv.mapped_blocks();
    assert!(held_before > 0);

    e2.preempt_session(&mut s2).unwrap();
    assert!(s2.kv.is_swapped());
    assert_eq!(s2.kv.mapped_blocks(), 0);
    assert_eq!(e2.kv_pool.stats().in_use_blocks, 0, "preemption frees every block");
    assert_eq!(e2.kv_pool.stats().preemptions, 1);
    assert_eq!(s2.position(), pos_before, "position survives the swap");
    assert!(
        e2.decode_step(&mut s2, cont[0]).is_err(),
        "decoding a swapped-out session must refuse"
    );

    e2.resume_session(&mut s2).unwrap();
    assert!(!s2.kv.is_swapped());
    assert_eq!(s2.kv.mapped_blocks(), held_before);
    let got_cont: Vec<Vec<f32>> =
        cont.iter().map(|&t| e2.decode_step(&mut s2, t).unwrap()).collect();

    assert_eq!(
        bits(&ref_cont),
        bits(&got_cont),
        "a preempted+resumed stream must continue bit-identically"
    );
}

#[test]
fn paged_pool_admits_more_sessions_than_static_at_fixed_vram() {
    let Some(dir) = artifacts_dir() else { return };
    // pool sized to EXACTLY one static full-sequence reservation
    let max_seq = Manifest::load(&dir).unwrap().config.max_seq;
    let static_sessions = 1usize;
    let prompt_len = 64usize;
    let mut e = make_engine(&dir, 64, 16, Some(static_sessions * max_seq)).unwrap();
    let prompt: Vec<u32> = (0..prompt_len).map(|i| (i % 64 + 32) as u32).collect();

    let mut admitted = Vec::new();
    loop {
        let mut sess = e.new_session().unwrap();
        match e.prefill(&mut sess, &prompt) {
            Ok(_) => admitted.push(sess),
            Err(Error::KvPoolExhausted(_)) => break,
            Err(other) => panic!("unexpected admission failure: {other}"),
        }
    }
    let expected = (static_sessions * max_seq) / prompt_len;
    assert_eq!(admitted.len(), expected, "pool should pack short prompts densely");
    assert!(
        admitted.len() > static_sessions,
        "paged admission ({}) must strictly beat static reservation ({static_sessions})",
        admitted.len()
    );
    // and freeing one session makes room again
    drop(admitted.pop());
    let mut late = e.new_session().unwrap();
    e.prefill(&mut late, &prompt).unwrap();
}

#[test]
fn coordinator_preempts_instead_of_failing_under_kv_pressure() {
    let Some(dir) = artifacts_dir() else { return };
    // 6 blocks of 16 tokens. Request A prefills 64 tokens (4 blocks) and
    // B prefills 30 (2 blocks); A's first decode crosses a block boundary
    // with the pool dry, forcing B's preemption. Both must still finish.
    let dir2 = dir.clone();
    let coord = Coordinator::new(
        move || make_engine(&dir2, 2, 16, Some(96)),
        7,
    );
    let mk = |prompt: String, max_tokens: usize| {
        let mut r = Request::new(prompt);
        r.chat = false;
        r.max_tokens = max_tokens;
        r
    };
    // submitted back-to-back while the worker is still building the
    // engine, so both are admitted in the same scheduling pass
    let sa = coord.submit(mk("a".repeat(64), 4));
    let sb = coord.submit(mk("b".repeat(30), 4));
    let ea = collect_events(sa);
    let eb = collect_events(sb);

    let done = |evs: &[Event]| -> (String, u64) {
        evs.iter()
            .find_map(|ev| match ev {
                Event::Done { text, kv_preemptions, .. } => {
                    Some((text.clone(), *kv_preemptions))
                }
                _ => None,
            })
            .expect("request must finish, not error")
    };
    let (ta, _) = done(&ea);
    let (tb, preemptions_b) = done(&eb);
    assert!(!ta.is_empty() && !tb.is_empty());
    assert_eq!(coord.metrics.counter("requests_ok"), 2);
    assert_eq!(coord.metrics.counter("requests_failed"), 0);
    assert!(
        coord.metrics.gauge("kv_preemptions") >= 1,
        "the pool was sized to force at least one preemption"
    );
    assert!(coord.metrics.counter("kv_resumes") >= 1);
    assert!(preemptions_b >= 1, "done JSON surfaces the preemption counter");
    // pool telemetry gauges are live and consistent
    let total = coord.metrics.gauge("kv_blocks_total");
    assert_eq!(total, 6);
    assert_eq!(
        coord.metrics.gauge("kv_blocks_free") + coord.metrics.gauge("kv_blocks_in_use"),
        total
    );
}

#[test]
fn budget_is_clamped_to_pool_capacity_instead_of_erroring_midstream() {
    let Some(dir) = artifacts_dir() else { return };
    let dir2 = dir.clone();
    // pool of 2 blocks × 16 = 32 tokens; prompt 20 fits, but 20 more
    // generated tokens would not — the budget must clamp to 12 so the
    // stream finishes cleanly at the capacity wall
    let coord = Coordinator::new(move || make_engine(&dir2, 1, 16, Some(32)), 3);
    let mut req = Request::new("y".repeat(20));
    req.chat = false;
    req.max_tokens = 20;
    let events = collect_events(coord.submit(req));
    let new_tokens = events
        .iter()
        .find_map(|ev| match ev {
            Event::Done { new_tokens, .. } => Some(*new_tokens),
            _ => None,
        })
        .expect("capacity-clamped request must finish, not error");
    assert!(new_tokens <= 12, "budget must clamp to capacity - prompt, got {new_tokens}");
    assert_eq!(coord.metrics.counter("requests_failed"), 0);
}

#[test]
fn oversized_prompt_fails_fast_instead_of_queueing_forever() {
    let Some(dir) = artifacts_dir() else { return };
    let dir2 = dir.clone();
    // pool of 2 blocks × 16 tokens = 32 tokens total
    let coord = Coordinator::new(move || make_engine(&dir2, 2, 16, Some(32)), 3);
    let mut req = Request::new("x".repeat(40));
    req.chat = false;
    req.max_tokens = 4;
    let events = collect_events(coord.submit(req));
    let msg = events
        .iter()
        .find_map(|ev| match ev {
            Event::Error { message, .. } => Some(message.clone()),
            _ => None,
        })
        .expect("a prompt larger than the whole pool must fail fast");
    assert!(msg.contains("kv pool capacity"), "{msg}");
    assert_eq!(coord.metrics.counter("requests_failed"), 1);
}
