//! Integration: layer-lockstep batched decode against the real engine.
//! Requires `make artifacts` (skips cleanly otherwise); the pinning and
//! accounting contracts are also covered by always-on unit tests in
//! `rust/src/cache/manager.rs`.
//!
//! Batched decode is a pure execution-order/dedup optimization, so the
//! contracts are equivalences:
//! * a width-1 batch delegates to the sequential step — bit-identical
//!   to the seed path, stats included;
//! * width-N batched produces bit-identical per-session logits to
//!   width-N sequential round-robin, while staging each distinct
//!   routed expert once per layer-tick (strictly fewer expert loads
//!   than sequential when sessions collide under a small cache — the
//!   case that also exercises the mid-tick pinning hazard);
//! * the equivalence survives preemption/resume mid-stream;
//! * end to end, a batched coordinator emits the same per-request text
//!   as a sequential one — including under KV pressure (preemption)
//!   and with the prefix cache on.

use std::path::{Path, PathBuf};

use moe_offload::config::{
    HardwareProfile, OffloadPolicy, QuantScheme, ServingConfig, SimScale,
};
use moe_offload::coordinator::{collect_events, Coordinator, Event, Request};
use moe_offload::engine::{MoeEngine, Session};
use moe_offload::harness;
use moe_offload::Result;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() && dir.join("weights.npz").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

fn make_engine(dir: &Path, sessions: usize, policy: OffloadPolicy) -> Result<MoeEngine> {
    let serving = ServingConfig {
        policy,
        expert_quant: QuantScheme::Hqq { bits: 3 },
        attn_quant: QuantScheme::Hqq { bits: 4 },
        sim_scale: SimScale::Tiny,
        max_concurrent_sessions: sessions,
        ..Default::default()
    };
    harness::build_engine_with_serving(dir, &serving, HardwareProfile::rtx3060())
}

fn bits(logits: &[Vec<f32>]) -> Vec<Vec<u32>> {
    logits.iter().map(|row| row.iter().map(|x| x.to_bits()).collect()).collect()
}

fn toks(s: &str) -> Vec<u32> {
    s.bytes().map(|b| b as u32).collect()
}

/// Width-N sequential reference: one round-robin decode_step per session
/// per tick (exactly the pre-batching scheduler's order). Returns
/// per-session, per-tick logits.
fn drive_sequential(
    engine: &mut MoeEngine,
    sessions: &mut [Session],
    streams: &[Vec<u32>],
    ticks: usize,
) -> Vec<Vec<Vec<f32>>> {
    let mut out = vec![Vec::new(); sessions.len()];
    for t in 0..ticks {
        for (i, sess) in sessions.iter_mut().enumerate() {
            out[i].push(engine.decode_step(sess, streams[i][t]).unwrap());
        }
    }
    out
}

/// Width-N batched: one decode_batch tick over all sessions.
fn drive_batched(
    engine: &mut MoeEngine,
    sessions: &mut [Session],
    streams: &[Vec<u32>],
    ticks: usize,
) -> Vec<Vec<Vec<f32>>> {
    let mut out = vec![Vec::new(); sessions.len()];
    for t in 0..ticks {
        let tick_toks: Vec<u32> = (0..sessions.len()).map(|i| streams[i][t]).collect();
        let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
        let results = engine.decode_batch(&mut refs, &tick_toks).unwrap();
        for (i, slot) in results.into_iter().enumerate() {
            out[i].push(slot.unwrap());
        }
    }
    out
}

#[test]
fn width1_batch_is_bit_identical_to_sequential_step() {
    let Some(dir) = artifacts_dir() else { return };
    let stream = toks("the quick brown fox jumps");
    let policy = OffloadPolicy::Full { cache_k: 2, spec_n: 2 };

    let mut es = make_engine(&dir, 1, policy).unwrap();
    let mut ss = es.new_session().unwrap();
    let mut ref_logits = Vec::new();
    for &t in &stream {
        ref_logits.push(es.decode_step(&mut ss, t).unwrap());
    }

    let mut eb = make_engine(&dir, 1, policy).unwrap();
    let mut sb = eb.new_session().unwrap();
    let mut got_logits = Vec::new();
    for &t in &stream {
        let mut refs: Vec<&mut Session> = vec![&mut sb];
        let r = eb.decode_batch(&mut refs, &[t]).unwrap();
        got_logits.push(r.into_iter().next().unwrap().unwrap());
    }

    assert_eq!(
        bits(&ref_logits),
        bits(&got_logits),
        "a width-1 batch must be bit-identical to the sequential step"
    );
    assert_eq!(
        eb.batch.ticks, 0,
        "width-1 delegates — it is not a batched tick"
    );
    // stats delegate too: same per-token accounting, to the bit
    assert_eq!(ss.run.total_misses(), sb.run.total_misses());
    assert_eq!(ss.run.total_hits(), sb.run.total_hits());
    assert_eq!(
        ss.run.sim_total_scaled_s.to_bits(),
        sb.run.sim_total_scaled_s.to_bits(),
        "width-1 timeline accounting must not change"
    );
}

#[test]
fn width4_batched_logits_match_width4_sequential_bitwise() {
    let Some(dir) = artifacts_dir() else { return };
    // four streams sharing a head (guaranteed routing collisions early
    // on) that diverge into distinct tails
    let streams: Vec<Vec<u32>> = [
        "the quick brown fox jumps",
        "the quick brown lazy dogs",
        "the quick brown lru cache",
        "the quick brown mixtures!",
    ]
    .iter()
    .map(|s| toks(s))
    .collect();
    let ticks = streams[0].len();
    let policy = OffloadPolicy::Full { cache_k: 2, spec_n: 2 };

    let mut es = make_engine(&dir, 4, policy).unwrap();
    let mut seq: Vec<Session> = (0..4).map(|_| es.new_session().unwrap()).collect();
    let ref_logits = drive_sequential(&mut es, &mut seq, &streams, ticks);

    let mut eb = make_engine(&dir, 4, policy).unwrap();
    let mut bat: Vec<Session> = (0..4).map(|_| eb.new_session().unwrap()).collect();
    let got_logits = drive_batched(&mut eb, &mut bat, &streams, ticks);

    for i in 0..4 {
        assert_eq!(
            bits(&ref_logits[i]),
            bits(&got_logits[i]),
            "session {i} diverged between batched and sequential decode"
        );
    }
    assert_eq!(eb.batch.ticks, ticks as u64);
    assert_eq!(eb.batch.rows, 4 * ticks as u64);
    assert_eq!(eb.batch.last_occupancy, 4);
    assert!(eb.batch.kernel_calls > 0);
    assert!(
        eb.batch.loads_deduped > 0,
        "a shared stream head must produce routing collisions to dedup"
    );
}

#[test]
fn colliding_batch_stages_strictly_fewer_expert_loads() {
    let Some(dir) = artifacts_dir() else { return };
    // IDENTICAL streams + cache_k = 1 < top_k = 2: the worst thrash
    // case — sequentially, loading a session's second expert evicts its
    // first, so every session re-stages both every layer (8 loads per
    // layer-tick at width 4); the batched tick resolves the union once
    // (≤ 2 loads) and runs each expert for ALL routed rows before the
    // next staging could evict it. Identical streams also force the
    // stacked kernel through the multi-row path, so this doubles as the
    // row-stability check for the one-kernel-per-expert call.
    let stream = toks("an lru cache evicts expert");
    let streams: Vec<Vec<u32>> = (0..4).map(|_| stream.clone()).collect();
    let ticks = stream.len();
    let policy = OffloadPolicy::LruOnly { cache_k: 1 };

    let mut es = make_engine(&dir, 4, policy).unwrap();
    let mut seq: Vec<Session> = (0..4).map(|_| es.new_session().unwrap()).collect();
    let ref_logits = drive_sequential(&mut es, &mut seq, &streams, ticks);

    let mut eb = make_engine(&dir, 4, policy).unwrap();
    let mut bat: Vec<Session> = (0..4).map(|_| eb.new_session().unwrap()).collect();
    let got_logits = drive_batched(&mut eb, &mut bat, &streams, ticks);

    for i in 0..4 {
        assert_eq!(
            bits(&ref_logits[i]),
            bits(&got_logits[i]),
            "session {i} diverged under expert-cache thrash"
        );
    }
    let seq_misses: u64 = seq.iter().map(|s| s.run.total_misses()).sum();
    let bat_misses: u64 = bat.iter().map(|s| s.run.total_misses()).sum();
    assert!(
        bat_misses < seq_misses,
        "batched union staging must transfer strictly less than sequential \
         thrash ({bat_misses} vs {seq_misses})"
    );
    // identical routing across 4 sessions: 8 routed pairs collapse to 2
    // distinct experts per layer-tick
    assert!(eb.batch.loads_deduped >= eb.batch.experts_resolved * 3);
    // hit accounting stays conserved: every routed pair is a miss, a
    // hit, or a batch-shared consume
    let bat_hits: u64 = bat.iter().map(|s| s.run.total_hits()).sum();
    assert_eq!(bat_hits + bat_misses, seq_misses + seq.iter().map(|s| s.run.total_hits()).sum::<u64>());
}

#[test]
fn batched_decode_is_bit_exact_across_preempt_resume() {
    let Some(dir) = artifacts_dir() else { return };
    let streams: Vec<Vec<u32>> = vec![
        toks("a stream that keeps running"),
        toks("a stream that gets swapped"),
    ];
    let policy = OffloadPolicy::Full { cache_k: 2, spec_n: 2 };
    let split = 8usize;
    let solo = 4usize;

    // reference: sequential schedule with B preempted for `solo` ticks
    let mut es = make_engine(&dir, 2, policy).unwrap();
    let mut sa = es.new_session().unwrap();
    let mut sb = es.new_session().unwrap();
    let mut ref_a = Vec::new();
    let mut ref_b = Vec::new();
    for t in 0..split {
        ref_a.push(es.decode_step(&mut sa, streams[0][t]).unwrap());
        ref_b.push(es.decode_step(&mut sb, streams[1][t]).unwrap());
    }
    es.preempt_session(&mut sb).unwrap();
    for t in split..split + solo {
        ref_a.push(es.decode_step(&mut sa, streams[0][t]).unwrap());
    }
    es.resume_session(&mut sb).unwrap();
    for t in split + solo..streams[0].len() {
        ref_a.push(es.decode_step(&mut sa, streams[0][t]).unwrap());
        ref_b.push(es.decode_step(&mut sb, streams[1][t - solo]).unwrap());
    }

    // batched: same schedule through decode_batch (width drops to 1
    // while B is swapped out, then returns to 2)
    let mut eb = make_engine(&dir, 2, policy).unwrap();
    let mut ba = eb.new_session().unwrap();
    let mut bb = eb.new_session().unwrap();
    let mut got_a = Vec::new();
    let mut got_b = Vec::new();
    for t in 0..split {
        let mut refs: Vec<&mut Session> = vec![&mut ba, &mut bb];
        let r = eb.decode_batch(&mut refs, &[streams[0][t], streams[1][t]]).unwrap();
        let mut it = r.into_iter();
        got_a.push(it.next().unwrap().unwrap());
        got_b.push(it.next().unwrap().unwrap());
    }
    eb.preempt_session(&mut bb).unwrap();
    for t in split..split + solo {
        let mut refs: Vec<&mut Session> = vec![&mut ba];
        let r = eb.decode_batch(&mut refs, &[streams[0][t]]).unwrap();
        got_a.push(r.into_iter().next().unwrap().unwrap());
    }
    eb.resume_session(&mut bb).unwrap();
    for t in split + solo..streams[0].len() {
        let mut refs: Vec<&mut Session> = vec![&mut ba, &mut bb];
        let r = eb
            .decode_batch(&mut refs, &[streams[0][t], streams[1][t - solo]])
            .unwrap();
        let mut it = r.into_iter();
        got_a.push(it.next().unwrap().unwrap());
        got_b.push(it.next().unwrap().unwrap());
    }

    assert_eq!(bits(&ref_a), bits(&got_a), "uninterrupted stream diverged");
    assert_eq!(
        bits(&ref_b),
        bits(&got_b),
        "preempted+resumed stream must continue bit-identically under batching"
    );
}

/// End-to-end scheduler equivalence: same requests, batched on vs off,
/// must stream the same per-request text — here under KV pressure
/// (forced preemption) AND with the prefix cache on, the two subsystems
/// the batched tick has to degrade gracefully around.
#[test]
fn coordinator_texts_identical_batched_vs_sequential() {
    let Some(dir) = artifacts_dir() else { return };
    let run = |batched: bool| {
        let dir2 = dir.clone();
        let coord = Coordinator::new(
            move || {
                let serving = ServingConfig {
                    policy: OffloadPolicy::Full { cache_k: 2, spec_n: 2 },
                    expert_quant: QuantScheme::Hqq { bits: 3 },
                    attn_quant: QuantScheme::Hqq { bits: 4 },
                    sim_scale: SimScale::Tiny,
                    max_concurrent_sessions: 2,
                    kv_block_tokens: 16,
                    kv_pool_tokens: Some(96),
                    prefix_cache: true,
                    batched_decode: batched,
                    ..Default::default()
                };
                harness::build_engine_with_serving(
                    &dir2,
                    &serving,
                    HardwareProfile::rtx3060(),
                )
            },
            7,
        );
        let mk = |prompt: String, max_tokens: usize| {
            let mut r = Request::new(prompt);
            r.chat = false;
            r.max_tokens = max_tokens;
            r
        };
        // paged-KV pressure workload: A (60 tokens = 4 blocks with 4
        // free positions) and B (30 tokens = 2 blocks with 2 free
        // positions) fill the 6-block pool at admission, decode a few
        // lockstep ticks together, then B's third decode crosses a
        // block boundary with the pool dry — forcing a preemption mid-
        // stream. The third request repeats A's prompt so it can seed
        // from the prefix cache once a slot frees up.
        let sa = coord.submit(mk("a".repeat(60), 8));
        let sb = coord.submit(mk("b".repeat(30), 8));
        let sc = coord.submit(mk("a".repeat(60), 8));
        let texts: Vec<String> = [sa, sb, sc]
            .into_iter()
            .map(|s| {
                collect_events(s)
                    .iter()
                    .find_map(|ev| match ev {
                        Event::Done { text, .. } => Some(text.clone()),
                        _ => None,
                    })
                    .expect("request must finish, not error")
            })
            .collect();
        let failed = coord.metrics.counter("requests_failed");
        let batched_ticks = coord.metrics.gauge("batched_ticks");
        let occupancy = coord.metrics.gauge("batch_occupancy");
        (texts, failed, batched_ticks, occupancy)
    };

    let (seq_texts, seq_failed, seq_ticks, _) = run(false);
    let (bat_texts, bat_failed, bat_ticks, bat_occ) = run(true);
    assert_eq!(seq_failed, 0);
    assert_eq!(bat_failed, 0);
    assert_eq!(
        seq_texts, bat_texts,
        "batched scheduling must not change any request's text"
    );
    assert_eq!(seq_ticks, 0, "sequential mode must never run a batched tick");
    assert!(bat_ticks >= 1, "two live sessions must have batched at least once");
    // the gauge holds the LAST batched tick's width, which can be 1 when
    // a neighbor went KV-dry — only assert it was recorded
    assert!(bat_occ >= 1, "batch occupancy gauge records the lockstep width");
}

/// Width-1 serving is the paper's batch-1 path: the batched_decode knob
/// must be inert there, token for token.
#[test]
fn width1_coordinator_is_unaffected_by_batched_knob() {
    let Some(dir) = artifacts_dir() else { return };
    let run = |batched: bool| {
        let dir2 = dir.clone();
        let coord = Coordinator::new(
            move || {
                let serving = ServingConfig {
                    policy: OffloadPolicy::Full { cache_k: 2, spec_n: 2 },
                    expert_quant: QuantScheme::Hqq { bits: 3 },
                    attn_quant: QuantScheme::Hqq { bits: 4 },
                    sim_scale: SimScale::Tiny,
                    max_concurrent_sessions: 1,
                    batched_decode: batched,
                    ..Default::default()
                };
                harness::build_engine_with_serving(
                    &dir2,
                    &serving,
                    HardwareProfile::rtx3060(),
                )
            },
            42,
        );
        let mut req = Request::new("what is a mixture of experts?".to_string());
        req.max_tokens = 12;
        let events = collect_events(coord.submit(req));
        let (text, ticks) = events
            .iter()
            .find_map(|ev| match ev {
                Event::Done { text, .. } => {
                    Some((text.clone(), coord.metrics.gauge("batched_ticks")))
                }
                _ => None,
            })
            .expect("request must finish");
        (text, ticks)
    };
    let (t_off, _) = run(false);
    let (t_on, ticks_on) = run(true);
    assert_eq!(t_off, t_on, "width-1 output must not depend on the knob");
    assert_eq!(ticks_on, 0, "width 1 never enters the batched path");
}
