//! Integration: the full rust PJRT engine must reproduce the python
//! decode reference numerically (FP16 path) and behave sanely on the
//! quantized paths. Requires `make artifacts` (skips cleanly otherwise).

use std::path::{Path, PathBuf};

use moe_offload::config::{Manifest, OffloadPolicy, QuantScheme, ServingConfig, SimScale};
use moe_offload::config::HardwareProfile;
use moe_offload::engine::MoeEngine;
use moe_offload::model::ModelWeights;
use moe_offload::util::json::Json;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() && dir.join("weights.npz").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

fn engine_with(
    dir: &Path,
    attn: QuantScheme,
    expert: QuantScheme,
    policy: OffloadPolicy,
) -> MoeEngine {
    engine_scaled(dir, attn, expert, policy, SimScale::Tiny)
}

fn engine_scaled(
    dir: &Path,
    attn: QuantScheme,
    expert: QuantScheme,
    policy: OffloadPolicy,
    scale: SimScale,
) -> MoeEngine {
    let manifest = Manifest::load(dir).unwrap();
    let weights =
        ModelWeights::load(&manifest.config, &dir.join("weights.npz"), attn, expert).unwrap();
    let serving = ServingConfig {
        policy,
        expert_quant: expert,
        attn_quant: attn,
        sim_scale: scale,
        ..Default::default()
    };
    MoeEngine::new(&manifest, weights, &serving, HardwareProfile::rtx3060()).unwrap()
}

#[test]
fn fp16_decode_matches_python_reference() {
    let Some(dir) = artifacts_dir() else { return };
    let fixture: Json = Json::parse(
        &std::fs::read_to_string(dir.join("decode_fixture.json")).expect("run compile.fixtures"),
    )
    .unwrap();
    let tokens: Vec<u32> = fixture
        .get("prompt_tokens")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|t| t.as_usize().unwrap() as u32)
        .collect();
    let expected_argmax: Vec<usize> = fixture
        .get("argmax")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|t| t.as_usize().unwrap())
        .collect();
    let heads = fixture.get("logits_head").unwrap().as_arr().unwrap();

    let mut engine = engine_with(
        &dir,
        QuantScheme::Fp16,
        QuantScheme::Fp16,
        OffloadPolicy::Full { cache_k: 2, spec_n: 2 },
    );

    let mut sess = engine.new_session().unwrap();
    for (t, &tok) in tokens.iter().enumerate() {
        let logits = engine.decode_step(&mut sess, tok).unwrap();
        let argmax = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(argmax, expected_argmax[t], "argmax diverged at position {t}");
        let head = heads[t].as_arr().unwrap();
        for (i, want) in head.iter().enumerate() {
            let want = want.as_f64().unwrap() as f32;
            let got = logits[i];
            assert!(
                (got - want).abs() < 2e-3 + 2e-3 * want.abs(),
                "logit[{t}][{i}]: got {got}, want {want}"
            );
        }
    }
}

#[test]
fn prefill_matches_decode_path() {
    let Some(dir) = artifacts_dir() else { return };
    let tokens: Vec<u32> = "the quick brown fox".bytes().map(|b| b as u32).collect();

    let mut e1 = engine_with(
        &dir,
        QuantScheme::Fp16,
        QuantScheme::Fp16,
        OffloadPolicy::Full { cache_k: 4, spec_n: 2 },
    );
    let mut s1 = e1.new_session().unwrap();
    let prefill_logits = e1.prefill(&mut s1, &tokens).unwrap();

    let mut e2 = engine_with(
        &dir,
        QuantScheme::Fp16,
        QuantScheme::Fp16,
        OffloadPolicy::Full { cache_k: 4, spec_n: 2 },
    );
    let mut s2 = e2.new_session().unwrap();
    for (t, &tok) in tokens.iter().enumerate() {
        let decode_logits = e2.decode_step(&mut s2, tok).unwrap();
        let row = prefill_logits.row(t);
        let max_diff = decode_logits
            .iter()
            .zip(row)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 5e-3, "position {t}: prefill vs decode diff {max_diff}");
    }
}

#[test]
fn quantized_paths_run_and_degrade_gracefully() {
    let Some(dir) = artifacts_dir() else { return };
    let tokens: Vec<u32> = "<user> hello".bytes().map(|b| b as u32).collect();

    let mut ref_logits = Vec::new();
    for scheme in [
        QuantScheme::Fp16,
        QuantScheme::Hqq { bits: 4 },
        QuantScheme::Hqq { bits: 2 },
    ] {
        let mut e = engine_with(
            &dir,
            QuantScheme::Fp16,
            scheme,
            OffloadPolicy::Full { cache_k: 2, spec_n: 2 },
        );
        let mut sess = e.new_session().unwrap();
        let mut last = Vec::new();
        for &t in &tokens {
            last = e.decode_step(&mut sess, t).unwrap();
        }
        assert!(last.iter().all(|x| x.is_finite()), "{scheme:?} produced NaN");
        ref_logits.push(last);
    }
    // 4-bit stays closer to fp16 than 2-bit does
    let dist = |a: &[f32], b: &[f32]| -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum::<f32>().sqrt()
    };
    let d4 = dist(&ref_logits[0], &ref_logits[1]);
    let d2 = dist(&ref_logits[0], &ref_logits[2]);
    assert!(d4 < d2, "4-bit ({d4}) should be closer to fp16 than 2-bit ({d2})");
}

#[test]
fn cache_policies_order_as_expected() {
    let Some(dir) = artifacts_dir() else { return };
    let tokens: Vec<u32> = "<user> explain how an LRU cache works?\n<assistant> "
        .bytes()
        .map(|b| b as u32)
        .collect();

    let mut throughput = Vec::new();
    for policy in [
        OffloadPolicy::Full { cache_k: 4, spec_n: 2 },
        OffloadPolicy::LruOnly { cache_k: 4 },
        OffloadPolicy::OnDemand,
        OffloadPolicy::Naive,
    ] {
        // Mixtral geometry: at tiny geometry the simulated transfers are
        // negligible against dispatch overheads and policies tie.
        let mut e = engine_scaled(
            &dir,
            QuantScheme::Hqq { bits: 4 },
            QuantScheme::Hqq { bits: 2 },
            policy,
            SimScale::Mixtral,
        );
        let mut sess = e.new_session().unwrap();
        for &t in &tokens {
            e.decode_step(&mut sess, t).unwrap();
        }
        throughput.push((policy.label(), sess.run.tokens_per_s_sim()));
    }
    // paper Table 2 ordering: full >= lru-only >= on-demand > naive
    assert!(
        throughput[0].1 >= throughput[1].1 * 0.98,
        "{throughput:?}"
    );
    assert!(throughput[1].1 > throughput[2].1, "{throughput:?}");
    assert!(throughput[2].1 > throughput[3].1, "{throughput:?}");
}

#[test]
fn placement_policy_never_changes_numerics() {
    // The paper's point in §3.2: offloading strategy affects LATENCY only
    // — predictions must be identical under every policy.
    let Some(dir) = artifacts_dir() else { return };
    let tokens: Vec<u32> = "expert placement".bytes().map(|b| b as u32).collect();
    let mut reference: Option<Vec<f32>> = None;
    for policy in [
        OffloadPolicy::Full { cache_k: 4, spec_n: 2 },
        OffloadPolicy::Full { cache_k: 1, spec_n: 4 },
        OffloadPolicy::LruOnly { cache_k: 2 },
        OffloadPolicy::OnDemand,
        OffloadPolicy::Naive,
    ] {
        let mut e = engine_with(
            &dir,
            QuantScheme::Hqq { bits: 4 },
            QuantScheme::Hqq { bits: 3 },
            policy,
        );
        let mut sess = e.new_session().unwrap();
        let mut last = Vec::new();
        for &t in &tokens {
            last = e.decode_step(&mut sess, t).unwrap();
        }
        match &reference {
            None => reference = Some(last),
            Some(want) => {
                let max_diff = last
                    .iter()
                    .zip(want)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f32, f32::max);
                assert!(
                    max_diff < 1e-4,
                    "{} diverged from reference by {max_diff}",
                    policy.label()
                );
            }
        }
    }
}

#[test]
fn generation_is_deterministic_given_seed() {
    let Some(dir) = artifacts_dir() else { return };
    let gen = || {
        let mut e = engine_with(
            &dir,
            QuantScheme::Hqq { bits: 4 },
            QuantScheme::Hqq { bits: 3 },
            OffloadPolicy::Full { cache_k: 2, spec_n: 2 },
        );
        let prompt: Vec<u32> = "<user> hi?\n<assistant> ".bytes().map(|b| b as u32).collect();
        let mut sampler = moe_offload::model::Sampler::proportional(1234);
        let mut sess = e.new_session().unwrap();
        e.generate(&mut sess, &prompt, 24, &mut sampler).unwrap()
    };
    assert_eq!(gen(), gen());
}

#[test]
fn session_reset_preserves_then_clears_cache() {
    let Some(dir) = artifacts_dir() else { return };
    let mut e = engine_with(
        &dir,
        QuantScheme::Hqq { bits: 4 },
        QuantScheme::Hqq { bits: 3 },
        OffloadPolicy::LruOnly { cache_k: 4 },
    );
    let mut sess = e.new_session().unwrap();
    for &t in "warm the cache up".as_bytes() {
        e.decode_step(&mut sess, t as u32).unwrap();
    }
    assert!(e.cache.device.resident_count() > 0);
    // warm restart: the session rewinds, the shared expert cache stays
    sess.reset();
    assert!(e.cache.device.resident_count() > 0);
    assert_eq!(sess.position(), 0);
    // cold restart: the expert cache is dropped, sessions unaffected
    e.drop_expert_cache();
    assert_eq!(e.cache.device.resident_count(), 0);
    // and the engine still works afterwards
    let logits = e.decode_step(&mut sess, 65).unwrap();
    assert!(logits.iter().all(|x| x.is_finite()));
}

#[test]
fn sequence_overflow_is_an_error_not_a_crash() {
    let Some(dir) = artifacts_dir() else { return };
    let mut e = engine_with(
        &dir,
        QuantScheme::Fp16,
        QuantScheme::Hqq { bits: 4 },
        OffloadPolicy::LruOnly { cache_k: 2 },
    );
    let max = e.weights.cfg.max_seq;
    // prefill right up to the limit, then decode must refuse
    let long: Vec<u32> = (0..max).map(|i| (i % 64 + 32) as u32).collect();
    let mut sess = e.new_session().unwrap();
    e.prefill(&mut sess, &long).unwrap();
    assert!(e.decode_step(&mut sess, 1).is_err());
    // prompts longer than the window are rejected up front
    let mut e2 = engine_with(
        &dir,
        QuantScheme::Fp16,
        QuantScheme::Hqq { bits: 4 },
        OffloadPolicy::LruOnly { cache_k: 2 },
    );
    let too_long: Vec<u32> = (0..max + 1).map(|_| 65u32).collect();
    let mut s2 = e2.new_session().unwrap();
    assert!(e2.prefill(&mut s2, &too_long).is_err());
}

#[test]
fn speculative_loading_produces_spec_hits() {
    let Some(dir) = artifacts_dir() else { return };
    let tokens: Vec<u32> = "<user> why is my program slow?\n<assistant> profile it"
        .bytes()
        .map(|b| b as u32)
        .collect();
    let mut e = engine_with(
        &dir,
        QuantScheme::Hqq { bits: 4 },
        QuantScheme::Hqq { bits: 3 },
        OffloadPolicy::Full { cache_k: 2, spec_n: 2 },
    );
    let mut sess = e.new_session().unwrap();
    for &t in &tokens {
        e.decode_step(&mut sess, t).unwrap();
    }
    let spec_hits: u64 = sess.run.tokens.iter().map(|t| t.spec_hits).sum();
    assert!(spec_hits > 0, "speculation never hit: {:?}", e.cache.stats.spec);
    // and the engine stays numerically healthy
    assert!(sess.run.hit_ratio() > 0.0);
}

#[test]
fn trace_recorder_captures_activations() {
    let Some(dir) = artifacts_dir() else { return };
    let mut e = engine_with(
        &dir,
        QuantScheme::Fp16,
        QuantScheme::Hqq { bits: 3 },
        OffloadPolicy::LruOnly { cache_k: 2 },
    );
    e.trace.enabled = true;
    let mut sess = e.new_session().unwrap();
    for &t in "hello world".as_bytes() {
        e.decode_step(&mut sess, t as u32).unwrap();
    }
    let n_layers = e.weights.cfg.n_layers;
    assert_eq!(e.trace.records.len(), 11 * n_layers);
    let heat = e.trace.layer_heatmap(0);
    assert_eq!(heat.len(), 11);
    assert_eq!(heat[0].len(), e.weights.cfg.n_experts);
    // probs are a distribution
    let sum: f32 = heat[0].iter().sum();
    assert!((sum - 1.0).abs() < 1e-4);
}

#[test]
fn scoring_gives_reasonable_perplexity() {
    let Some(dir) = artifacts_dir() else { return };
    let corpus_path = dir.join("corpus/prose_eval.bin");
    if !corpus_path.exists() {
        eprintln!("SKIP: corpus not built");
        return;
    }
    let corpus = moe_offload::eval::load_corpus(&corpus_path).unwrap();
    let mut e = engine_with(
        &dir,
        QuantScheme::Fp16,
        QuantScheme::Fp16,
        OffloadPolicy::Full { cache_k: 4, spec_n: 2 },
    );
    let ppl = moe_offload::eval::perplexity(&mut e, &corpus, 96, 3).unwrap();
    // trained byte model: should be way below uniform (256) and above 1
    assert!(ppl > 1.5 && ppl < 30.0, "byte ppl {ppl}");
}
