//! Integration: deterministic fault injection + resilient serving
//! against the real engine. Requires `make artifacts` (skips cleanly
//! otherwise); the injector's pure draw/backoff logic is covered by
//! always-on unit tests in `rust/src/fault/`, the plan knobs in
//! `rust/src/config/serving.rs`.
//!
//! The contracts here are the chaos properties the tentpole promises:
//! * faults OFF (the default, and an explicitly disabled plan carrying
//!   garbage rates) is byte-identical to the fault-free scheduler —
//!   token bits AND virtual timeline, at widths 1 and 4;
//! * a transient-only plan recovers invisibly: per-session output is
//!   bit-identical to the fault-free run while `transfer_retries` and
//!   `faults_injected` climb (recovery is charged to the timeline, not
//!   to the bytes);
//! * a fatal fault fails exactly one request with a typed event — no
//!   panic, no batch poisoning, other sessions' outputs unchanged;
//! * a missed deadline cancels that request, typed, and counts it — and
//!   an oversized wire deadline (finite but past `Duration` range)
//!   degrades to "no deadline" instead of panicking the worker;
//! * a client disconnect mid-stream cancels the session and returns its
//!   KV blocks to the pool.

use std::path::{Path, PathBuf};

use moe_offload::config::{
    HardwareProfile, OffloadPolicy, QuantScheme, ServingConfig, SimScale,
};
use moe_offload::coordinator::{collect_events, Coordinator, Event, Request};
use moe_offload::fault::FaultPlan;
use moe_offload::harness;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() && dir.join("weights.npz").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

fn serving(width: usize) -> ServingConfig {
    ServingConfig {
        policy: OffloadPolicy::Full { cache_k: 2, spec_n: 2 },
        expert_quant: QuantScheme::Hqq { bits: 3 },
        attn_quant: QuantScheme::Hqq { bits: 4 },
        sim_scale: SimScale::Tiny,
        max_concurrent_sessions: width,
        ..Default::default()
    }
}

fn mk(prompt: &str, max_tokens: usize) -> Request {
    let mut r = Request::new(prompt);
    r.chat = false;
    r.max_tokens = max_tokens;
    r
}

/// Per-request outcome of one workload replay: the final text and the
/// virtual-timeline throughput (bit-exact f64), or the typed failure.
type Outcome = std::result::Result<(String, u64), String>;

/// Run `requests` through a fresh coordinator built from `cfg`; returns
/// one [`Outcome`] per request (submit order) plus the coordinator for
/// metric assertions.
fn run_workload(
    dir: &Path,
    cfg: ServingConfig,
    requests: Vec<Request>,
) -> (Vec<Outcome>, Coordinator) {
    let dir2 = dir.to_path_buf();
    let coord = Coordinator::new(
        move || harness::build_engine_with_serving(&dir2, &cfg, HardwareProfile::rtx3060()),
        7,
    );
    let streams: Vec<_> = requests.into_iter().map(|r| coord.submit(r)).collect();
    let outcomes = streams
        .into_iter()
        .map(|s| {
            collect_events(s)
                .iter()
                .find_map(|ev| match ev {
                    Event::Done { text, tokens_per_s_sim, .. } => {
                        Some(Ok((text.clone(), tokens_per_s_sim.to_bits())))
                    }
                    Event::Error { message, .. } | Event::Failed { message, .. } => {
                        Some(Err(message.clone()))
                    }
                    _ => None,
                })
                .expect("stream must terminate")
        })
        .collect();
    (outcomes, coord)
}

fn prompts() -> Vec<Request> {
    vec![
        mk("what is a mixture of experts model", 10),
        mk("explain expert offloading", 12),
        mk("how does speculative expert loading work", 8),
        mk("what is perplexity", 10),
    ]
}

#[test]
fn faults_off_is_byte_identical_at_widths_1_and_4() {
    let Some(dir) = artifacts_dir() else { return };
    for width in [1usize, 4] {
        let (baseline, _) = run_workload(&dir, serving(width), prompts());

        // a DISABLED plan carrying aggressive rates must be inert: the
        // master switch gates every draw, so bits and timeline match
        let mut noisy = serving(width);
        noisy.faults = FaultPlan {
            enabled: false,
            transfer_fail_p: 0.9,
            corrupt_p: 0.9,
            kv_fail_p: 0.9,
            brownout_p: 0.9,
            ..FaultPlan::default()
        };
        let (gated, coord) = run_workload(&dir, noisy, prompts());

        assert_eq!(baseline, gated, "disabled plan changed bits or timeline (width {width})");
        assert_eq!(coord.metrics.gauge("faults_injected"), 0);
        assert_eq!(coord.metrics.gauge("transfer_retries"), 0);
        assert_eq!(coord.metrics.counter("requests_failed"), 0);
    }
}

#[test]
fn transient_only_plan_is_bit_transparent_with_retries() {
    let Some(dir) = artifacts_dir() else { return };
    let width = 4;
    let (clean, _) = run_workload(&dir, serving(width), prompts());

    let mut chaotic = serving(width);
    chaotic.faults = FaultPlan::transient_smoke(0xC4A05);
    let (faulted, coord) = run_workload(&dir, chaotic, prompts());

    // transient recovery is charged to the virtual link, never to the
    // bytes: texts match bit-for-bit even though the timeline moved
    let texts = |outcomes: &[Outcome], what: &str| -> Vec<String> {
        outcomes
            .iter()
            .map(|o| o.as_ref().unwrap_or_else(|e| panic!("{what} run failed: {e}")).0.clone())
            .collect()
    };
    let clean_texts = texts(&clean, "clean");
    let fault_texts = texts(&faulted, "transient-only");
    assert_eq!(clean_texts, fault_texts, "transient faults leaked into token bits");

    assert!(coord.metrics.gauge("faults_injected") > 0, "plan enabled but injected nothing");
    assert!(coord.metrics.gauge("transfer_retries") > 0, "retry path never exercised");
    assert_eq!(coord.metrics.counter("requests_failed"), 0, "transient-only plan failed a request");
}

#[test]
fn fatal_fault_fails_exactly_one_request() {
    let Some(dir) = artifacts_dir() else { return };
    // width 1 pins the victim: the first admitted session owns gate #0
    let (clean, _) = run_workload(&dir, serving(1), prompts());

    let mut cfg = serving(1);
    cfg.faults = FaultPlan {
        enabled: true,
        fatal_at_gate: Some(0),
        ..FaultPlan::default()
    };
    let (outcomes, coord) = run_workload(&dir, cfg, prompts());

    let failed: Vec<_> = outcomes.iter().enumerate().filter(|(_, o)| o.is_err()).collect();
    assert_eq!(failed.len(), 1, "fatal fault must fail exactly one request: {outcomes:?}");
    assert_eq!(failed[0].0, 0, "gate #0 belongs to the first admitted session");
    assert_eq!(coord.metrics.counter("requests_failed"), 1);

    // survivors are untouched — same bits as the fault-free run
    for (i, (c, f)) in clean.iter().zip(outcomes.iter()).enumerate().skip(1) {
        let c = c.as_ref().expect("clean run must succeed");
        let f = f.as_ref().unwrap_or_else(|e| panic!("survivor {i} failed: {e}"));
        assert_eq!(c.0, f.0, "survivor {i} text changed");
    }
}

#[test]
fn missed_deadline_cancels_typed_and_counted() {
    let Some(dir) = artifacts_dir() else { return };
    // per-request deadline overrides the (unset) config default; a
    // nanosecond budget has always expired by the first tick boundary
    let mut doomed = mk("what is a mixture of experts model", 10);
    doomed.deadline_s = Some(1e-9);
    let fine = mk("explain expert offloading", 8);

    let (outcomes, coord) = run_workload(&dir, serving(2), vec![doomed, fine]);

    let err = outcomes[0].as_ref().expect_err("nanosecond deadline must cancel");
    assert!(err.contains("deadline"), "failure must name the deadline: {err}");
    let ok = outcomes[1].as_ref().expect("undeadlined request must finish");
    assert!(!ok.0.is_empty());

    let cancelled = coord.metrics.counter("deadline_cancellations");
    assert!(cancelled >= 1);
    assert!(coord.metrics.counter("requests_failed") >= cancelled);
    // counters only — a same-named gauge mirror would render duplicate
    // metric lines (see telemetry::failure_counters_have_no_gauge_mirrors)
    assert_eq!(coord.metrics.gauge("deadline_cancellations"), 0);
}

#[test]
fn oversized_deadline_degrades_to_no_deadline_not_a_panic() {
    let Some(dir) = artifacts_dir() else { return };
    // finite, positive, passes the sign/finiteness sanitization — but
    // overflows Duration::from_secs_f64 (~1.8e19 s) and Instant + Duration
    // well before that. A hostile client can put this on the wire
    // verbatim; it must behave as "no deadline", not crash the worker.
    let mut huge = mk("what is a mixture of experts model", 10);
    huge.deadline_s = Some(1e20);
    let fine = mk("explain expert offloading", 8);

    let (outcomes, coord) = run_workload(&dir, serving(2), vec![huge, fine]);

    for (i, o) in outcomes.iter().enumerate() {
        let ok = o.as_ref().unwrap_or_else(|e| panic!("request {i} failed: {e}"));
        assert!(!ok.0.is_empty(), "request {i} produced no text");
    }
    assert!(coord.is_running(), "engine worker died on an oversized deadline");
    assert_eq!(coord.metrics.counter("deadline_cancellations"), 0);
    assert_eq!(coord.metrics.counter("requests_failed"), 0);
}

#[test]
fn client_disconnect_mid_stream_cancels_and_returns_kv() {
    let Some(dir) = artifacts_dir() else { return };
    // no early stop: the abandoned request would otherwise finish on
    // its own before the dropped stream is noticed
    let mut cfg = serving(2);
    cfg.stop_suffix = String::new();

    // reference: the probe request alone — its Done carries the pool
    // occupancy at finish, i.e. just its own live blocks (stop_suffix
    // is off, so the probe always generates exactly max_tokens and its
    // block count is independent of which token bits it sampled)
    let dir1 = dir.to_path_buf();
    let cfg1 = cfg.clone();
    let coord1 = Coordinator::new(
        move || harness::build_engine_with_serving(&dir1, &cfg1, HardwareProfile::rtx3060()),
        7,
    );
    let clean_in_use = collect_events(coord1.submit(mk("what is perplexity", 8)))
        .iter()
        .find_map(|ev| match ev {
            Event::Done { kv_blocks_in_use, .. } => Some(*kv_blocks_in_use),
            _ => None,
        })
        .expect("clean probe must finish");

    let dir2 = dir.to_path_buf();
    let coord = Coordinator::new(
        move || harness::build_engine_with_serving(&dir2, &cfg, HardwareProfile::rtx3060()),
        7,
    );
    // abandon a long request immediately: every token send fails, the
    // scheduler reclaims the slot at its next step
    let abandoned = coord.submit(mk("explain expert offloading", 64));
    drop(abandoned);
    let probe = coord.submit(mk("what is perplexity", 8));

    let (text, in_use) = collect_events(probe)
        .iter()
        .find_map(|ev| match ev {
            Event::Done { text, kv_blocks_in_use, .. } => Some((text.clone(), *kv_blocks_in_use)),
            Event::Error { message, .. } | Event::Failed { message, .. } => {
                panic!("probe failed: {message}")
            }
            _ => None,
        })
        .expect("probe must finish");

    assert_eq!(coord.metrics.counter("requests_cancelled"), 1, "disconnect must cancel");
    assert_eq!(coord.metrics.counter("requests_failed"), 0, "disconnect is not a failure");
    assert!(!text.is_empty());
    // the cancelled session's KV blocks are back in the pool: the probe
    // sees exactly the occupancy it sees when it runs alone
    assert_eq!(in_use, clean_in_use, "cancelled session leaked KV blocks");
}
