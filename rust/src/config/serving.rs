//! Serving / offloading policy configuration.

use crate::error::{Error, Result};
use crate::fault::FaultPlan;
use crate::quant::tier::TierPolicy;

/// Weight quantization scheme (per weight class).
///
/// `Fp16` stores weights unquantized (we hold f32 in host memory but
/// account 2 bytes/param for size/transfer, matching the paper's fp16
/// baselines). `Hqq{bits}` is HQQ group quantization; group sizes follow
/// the paper's §4.2 table (4-bit: g=64, 3-bit: g=64, 2-bit: g=16), scaled
/// down proportionally for the tiny model where needed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum QuantScheme {
    Fp16,
    Hqq { bits: u8 },
}

impl QuantScheme {
    pub fn parse(text: &str) -> Result<Self> {
        match text.to_lowercase().as_str() {
            "fp16" | "f16" | "16" => Ok(QuantScheme::Fp16),
            "4" | "4bit" | "q4" | "hqq4" => Ok(QuantScheme::Hqq { bits: 4 }),
            "3" | "3bit" | "q3" | "hqq3" => Ok(QuantScheme::Hqq { bits: 3 }),
            "2" | "2bit" | "q2" | "hqq2" => Ok(QuantScheme::Hqq { bits: 2 }),
            other => Err(Error::Config(format!("unknown quant scheme {other:?}"))),
        }
    }

    pub fn label(&self) -> String {
        match self {
            QuantScheme::Fp16 => "FP16".to_string(),
            QuantScheme::Hqq { bits } => format!("{bits}-bit"),
        }
    }

    pub fn bits(&self) -> u32 {
        match self {
            QuantScheme::Fp16 => 16,
            QuantScheme::Hqq { bits } => *bits as u32,
        }
    }

    /// Paper §4.2 group sizes, scaled by the model's group_size field for
    /// the tiny testbed (which uses g=32 everywhere).
    pub fn group_size(&self, model_group: usize) -> usize {
        match self {
            QuantScheme::Fp16 => model_group,
            QuantScheme::Hqq { bits: 2 } => model_group.min(16),
            QuantScheme::Hqq { .. } => model_group,
        }
    }

    /// Stored/transferred bytes for `n` weights quantized with this scheme
    /// in groups of `g`: packed codes + scale & zero per group. HQQ
    /// deployments second-level-quantize group metadata to 8 bit (the
    /// paper's "scale group size"), so we account 1 byte each.
    pub fn bytes_for(&self, n: usize, g: usize) -> u64 {
        match self {
            QuantScheme::Fp16 => (n * 2) as u64,
            QuantScheme::Hqq { bits } => {
                let code_bytes = (n * (*bits as usize) + 7) / 8;
                let groups = n.div_ceil(g);
                (code_bytes + groups * 2) as u64 // u8 scale + u8 zero
            }
        }
    }

    /// Effective bits per parameter including group metadata.
    pub fn effective_bits(&self, g: usize) -> f64 {
        self.bytes_for(g * 1024, g) as f64 * 8.0 / (g * 1024) as f64
    }
}

/// Which offloading algorithm variant to run — the Table 2 grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OffloadPolicy {
    /// LRU cache + speculative expert pre-loading (the paper's algorithm).
    Full { cache_k: usize, spec_n: usize },
    /// LRU cache only ("W/o expert pre-loading").
    LruOnly { cache_k: usize },
    /// Load active experts on demand, no cache, no speculation
    /// ("W/o LRU cache & pre-loading").
    OnDemand,
    /// Accelerate-style whole-layer offloading: every expert of a MoE layer
    /// is transferred when the layer runs ("Naive offloading").
    Naive,
}

impl OffloadPolicy {
    pub fn label(&self) -> &'static str {
        match self {
            OffloadPolicy::Full { .. } => "Full algorithm",
            OffloadPolicy::LruOnly { .. } => "W/o expert pre-loading",
            OffloadPolicy::OnDemand => "W/o LRU cache & pre-loading",
            OffloadPolicy::Naive => "Naive offloading (accelerate)",
        }
    }

    pub fn cache_k(&self) -> usize {
        match self {
            OffloadPolicy::Full { cache_k, .. } | OffloadPolicy::LruOnly { cache_k } => *cache_k,
            _ => 0,
        }
    }

    pub fn spec_n(&self) -> usize {
        match self {
            OffloadPolicy::Full { spec_n, .. } => *spec_n,
            _ => 0,
        }
    }
}

/// Whether timing is reported at the tiny testbed's own scale or translated
/// to Mixtral-8x7B geometry (routing decisions always come from the real
/// tiny-model execution; only byte/flop accounting changes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimScale {
    Tiny,
    Mixtral,
}

/// Upper bound on [`ServingConfig::request_timeout_s`] (one day). An
/// operator value above ~1.8e19 s would panic `Duration::from_secs_f64`
/// on the client-facing thread; anything past a day is a config typo
/// anyway, so validation rejects it long before the panic range.
pub const MAX_REQUEST_TIMEOUT_S: f64 = 86_400.0;

#[derive(Debug, Clone)]
pub struct ServingConfig {
    pub policy: OffloadPolicy,
    pub expert_quant: QuantScheme,
    pub attn_quant: QuantScheme,
    /// Number of shared staging buffers for async copies (paper: b = 4).
    pub staging_buffers: usize,
    pub sim_scale: SimScale,
    pub max_new_tokens: usize,
    pub temperature: f32,
    pub seed: u64,
    /// Continuous-batching width: how many live sessions the coordinator's
    /// scheduler interleaves (round-robin, one decode step per session per
    /// tick). Also sizes the KV block pool when `kv_pool_tokens` is None
    /// (one full sequence per session, matching the old static
    /// reservation byte for byte). 1 reproduces the paper's batch-1
    /// serving exactly.
    pub max_concurrent_sessions: usize,
    /// Sequence positions per KV block (all layers, K and V). Smaller
    /// blocks waste less memory on short streams but grow the page
    /// tables; clamped to `max_seq` by the engine. Block size never
    /// affects numerics — width-1 decode is bit-identical at any value.
    pub kv_block_tokens: usize,
    /// Total KV pool capacity in sequence positions. `None` (default)
    /// sizes it as `max_concurrent_sessions * max_seq` — exactly the
    /// bytes the pre-paging engine reserved statically. Setting it
    /// smaller admits sessions by free-block accounting and relies on
    /// preemption when the pool runs dry mid-decode.
    pub kv_pool_tokens: Option<usize>,
    /// Enable the prefix cache (see [`crate::prefix`]): completed
    /// prompts become reusable KV, and admissions sharing a cached
    /// prefix skip its prefill. Off by default — the cache-less path is
    /// byte-identical to a stateless scheduler.
    pub prefix_cache: bool,
    /// Cap on cached prefix positions. `None` bounds the cache only by
    /// the KV pool itself (cold prefixes are evicted leaf-first under
    /// pool pressure, before any live session is preempted).
    pub prefix_cache_tokens: Option<usize>,
    /// Layer-lockstep batched decode (see [`crate::engine::MoeEngine::decode_batch`]):
    /// the scheduler advances all live sessions through each layer
    /// together, resolves the union of routed experts against the cache
    /// once per layer-tick, and runs one expert kernel over the stacked
    /// rows. A pure execution-order/dedup optimization — per-session
    /// output is bit-identical to the sequential round-robin path. On by
    /// default; `false` (or width 1) is byte-identical to the sequential
    /// scheduler.
    pub batched_decode: bool,
    /// Generation stops once the decoded text ends with this suffix
    /// (after `min_tokens` tokens). The scheduler checks it against the
    /// incrementally maintained text tail, so it must stay short (≤ 64
    /// bytes, enforced by [`Self::validate`]). Empty disables suffix
    /// stopping — only the token budget ends the stream.
    pub stop_suffix: String,
    /// Tokens that must be generated before `stop_suffix` can end the
    /// stream (guards against stopping on a degenerate first token).
    /// Interacts with the token-budget clamp only one way: a stream
    /// whose (pool-clamped) budget is smaller than `min_tokens` simply
    /// ends at the budget with the suffix check never armed — the knob
    /// is a floor for suffix stopping, never a promised length, so the
    /// combination is valid and needs no validation coupling.
    pub min_tokens: usize,
    /// Chunked-prefill admission (see [`crate::sched`]): instead of
    /// prefilling a prompt synchronously at admission — stalling every
    /// live decode stream for the whole prefill — the scheduler feeds
    /// the prompt in `prefill_chunk_tokens`-sized chunks, at most one
    /// chunk per tick, fused into the batched decode lockstep
    /// ([`crate::engine::MoeEngine::step_mixed`]: one cache resolve and
    /// one stacked kernel per distinct expert per layer-tick, decode
    /// rows riding the experts the chunk loads anyway). A pure
    /// execution-order optimization for the emitted streams: per-session
    /// tokens are bit-identical, only tick boundaries move. Off by
    /// default — off is byte-identical to the synchronous-admission
    /// scheduler.
    pub chunked_prefill: bool,
    /// Prompt positions fed per scheduling tick while an admission is
    /// prefilling (chunked prefill only). Fused mixed ticks additionally
    /// clamp the chunk to the compiled prefill module width
    /// (`ModelConfig::prefill_chunk`); larger values only affect the
    /// sequential (`batched_decode = false`) fallback, which sub-chunks
    /// internally. Inert while `chunked_prefill` is off.
    pub prefill_chunk_tokens: usize,
    /// Token budget for one mixed tick: each decoding session costs one
    /// token and the prefill chunk costs its length. Decode rows are
    /// never budgeted out — the budget only shrinks (or defers) the
    /// chunk, bounding how much prefill work a tick may add on top of
    /// the live decodes. `None` bounds the chunk only by
    /// `prefill_chunk_tokens`. Inert while `chunked_prefill` is off.
    pub max_batch_tokens: Option<usize>,
    /// Per-expert precision tiers (see [`crate::quant::tier`]): hot
    /// experts keep more bits, cold experts ship fewer bytes per miss,
    /// warm experts stay at `expert_quant`. Disabled by default — off is
    /// byte-identical to the uniform deployment (every expert Warm at
    /// the base scheme, same packed bytes, same transfer pricing).
    pub expert_tiers: TierPolicy,
    /// Span tracing (see [`crate::trace`]): tag every timeline
    /// reservation with a typed kind + session/layer/tick ids into a
    /// bounded ring buffer, surface per-request time breakdowns in the
    /// coordinator's `done` event and `Metrics` histograms, and enable
    /// Chrome trace-event export. Off by default — tracing never changes
    /// timing or tokens, so off is byte-identical AND on is
    /// token/timing-identical; only observability differs.
    pub trace: bool,
    /// Ring capacity in spans while `trace` is on; the oldest spans are
    /// dropped (and counted) once full. Inert while `trace` is off.
    pub trace_span_capacity: usize,
    /// Deterministic fault injection (see [`crate::fault`]): seeded
    /// transient transfer failures, link brownouts, corrupt expert
    /// payloads and KV-swap faults at the virtual-hardware seams, with
    /// bounded-backoff recovery charged to the timeline. Disabled by
    /// default — off is byte-identical serving, and the plan's other
    /// fields are inert (never validated) while off.
    pub faults: FaultPlan,
    /// How long a client-facing control wait (e.g. the `analyze`
    /// command's reply) may block before surfacing a typed
    /// [`Error::Timeout`]. Replaces the historical hard-coded 120 s;
    /// always validated (finite, in (0, [`MAX_REQUEST_TIMEOUT_S`]]) —
    /// there is no off switch, a serving thread must never wait
    /// forever, and the cap keeps the value convertible to a
    /// `Duration` without panicking.
    pub request_timeout_s: f64,
    /// Default per-request deadline in wall seconds, measured from
    /// enqueue. The scheduler checks it at tick boundaries and cancels
    /// over-deadline requests with a typed `Event::Failed`; a request's
    /// own `deadline_s` overrides this default. `None` (default) means
    /// no deadline.
    pub deadline_s: Option<f64>,
    /// Expert-flow observability (see [`crate::obs`]): a per-(layer,
    /// expert) flight recorder fed from the cache manager and copy
    /// engine — routed uses, hits/misses, demand vs speculative loads,
    /// prefetches used/wasted, evictions, virtual-time-weighted
    /// residency, wire bytes per quant tier — plus the recorded access
    /// stream the counterfactual cache-curve simulator replays. Off by
    /// default — a disabled recorder never allocates and every record
    /// call is a branch on a bool, so off is byte-identical serving
    /// (same inertness contract as `trace`).
    pub expert_obs: bool,
    /// Per-layer cap on recorded access-stream events while
    /// `expert_obs` is on; once a layer's stream is full, further
    /// events are dropped (and counted) and the simulator's exact
    /// anchor guarantee is withdrawn for that run. Inert while
    /// `expert_obs` is off.
    pub expert_obs_event_capacity: usize,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            policy: OffloadPolicy::Full { cache_k: 2, spec_n: 2 },
            expert_quant: QuantScheme::Hqq { bits: 3 },
            attn_quant: QuantScheme::Hqq { bits: 4 },
            staging_buffers: 4,
            sim_scale: SimScale::Tiny,
            max_new_tokens: 128,
            temperature: 1.0,
            seed: 0,
            max_concurrent_sessions: 1,
            kv_block_tokens: 32,
            kv_pool_tokens: None,
            prefix_cache: false,
            prefix_cache_tokens: None,
            batched_decode: true,
            // defaults preserve the scheduler's historical hard-coded
            // stop heuristic (`generated > 4 && text.ends_with(".\n")`)
            stop_suffix: ".\n".to_string(),
            min_tokens: 4,
            chunked_prefill: false,
            // matches the tiny testbed's compiled prefill module width, so
            // a fused mixed tick feeds exactly one module call per layer
            prefill_chunk_tokens: 16,
            max_batch_tokens: None,
            expert_tiers: TierPolicy::default(),
            trace: false,
            // ~64 spans/token at tiny geometry -> roughly a 1k-token window
            trace_span_capacity: 65536,
            faults: FaultPlan::default(),
            // preserves the coordinator's historical hard-coded wait
            request_timeout_s: 120.0,
            deadline_s: None,
            expert_obs: false,
            // ~24 bytes/event resident; 1M events per layer covers far
            // more decode steps than any testbed run issues
            expert_obs_event_capacity: 1 << 20,
        }
    }
}

impl ServingConfig {
    /// Cheap structural validation, called by the engine constructor.
    pub fn validate(&self) -> Result<()> {
        if self.max_concurrent_sessions == 0 {
            return Err(Error::Config(
                "max_concurrent_sessions must be >= 1".into(),
            ));
        }
        if self.max_concurrent_sessions > 256 {
            return Err(Error::Config(format!(
                "max_concurrent_sessions {} is unreasonably large (KV memory \
                 is reserved per session; limit 256)",
                self.max_concurrent_sessions
            )));
        }
        if self.staging_buffers == 0 {
            return Err(Error::Config("staging_buffers must be >= 1".into()));
        }
        if self.kv_block_tokens == 0 {
            return Err(Error::Config("kv_block_tokens must be >= 1".into()));
        }
        if self.kv_block_tokens > 8192 {
            return Err(Error::Config(format!(
                "kv_block_tokens {} is unreasonably large (a block should be \
                 a small fraction of the sequence; limit 8192)",
                self.kv_block_tokens
            )));
        }
        if let Some(pool) = self.kv_pool_tokens {
            if pool < self.kv_block_tokens {
                return Err(Error::Config(format!(
                    "kv_pool_tokens {} is smaller than one block ({} tokens) — \
                     the pool could never admit a session",
                    pool, self.kv_block_tokens
                )));
            }
        }
        if self.stop_suffix.len() > 64 {
            return Err(Error::Config(format!(
                "stop_suffix of {} bytes is unreasonably long (the stop check \
                 runs against the text tail every token; limit 64)",
                self.stop_suffix.len()
            )));
        }
        if self.min_tokens > 1 << 20 {
            return Err(Error::Config(format!(
                "min_tokens {} is unreasonably large (no stream generates \
                 that many tokens; limit {})",
                self.min_tokens,
                1 << 20
            )));
        }
        // the cap is inert while the cache is off — don't reject a config
        // for a knob that builds nothing
        if self.prefix_cache {
            if let Some(cap) = self.prefix_cache_tokens {
                if cap < self.kv_block_tokens {
                    return Err(Error::Config(format!(
                        "prefix_cache_tokens {} is smaller than one block ({} tokens) — \
                         the cache could never hold a prefix",
                        cap, self.kv_block_tokens
                    )));
                }
            }
        }
        // same inertness rule for the chunked-prefill knobs: they gate
        // nothing while the scheduler admits synchronously
        if self.chunked_prefill {
            if self.prefill_chunk_tokens == 0 {
                return Err(Error::Config(
                    "prefill_chunk_tokens must be >= 1 with chunked_prefill on \
                     (a zero-token chunk can never finish a prompt)"
                        .into(),
                ));
            }
            if self.prefill_chunk_tokens > 8192 {
                return Err(Error::Config(format!(
                    "prefill_chunk_tokens {} is unreasonably large (a chunk should \
                     be a small fraction of the sequence; limit 8192)",
                    self.prefill_chunk_tokens
                )));
            }
            if let Some(budget) = self.max_batch_tokens {
                if budget == 0 {
                    return Err(Error::Config(
                        "max_batch_tokens must be >= 1 with chunked_prefill on — a \
                         zero budget could never feed a prefill chunk"
                            .into(),
                    ));
                }
                if budget > 1 << 20 {
                    return Err(Error::Config(format!(
                        "max_batch_tokens {} is unreasonably large (no tick batches \
                         that many tokens; limit {})",
                        budget,
                        1 << 20
                    )));
                }
            }
        }
        // tier knobs follow the same inertness rule: TierPolicy::validate
        // is a no-op while the policy is disabled
        self.expert_tiers.validate()?;
        // trace knobs are inert while tracing is off
        if self.trace {
            if self.trace_span_capacity == 0 {
                return Err(Error::Config(
                    "trace_span_capacity must be >= 1 with trace on — a \
                     zero-span ring could never hold a span"
                        .into(),
                ));
            }
            if self.trace_span_capacity > 1 << 24 {
                return Err(Error::Config(format!(
                    "trace_span_capacity {} is unreasonably large (each span \
                     is ~64 bytes resident; limit {})",
                    self.trace_span_capacity,
                    1 << 24
                )));
            }
        }
        // expert-observability knobs are inert while the recorder is off
        if self.expert_obs {
            if self.expert_obs_event_capacity == 0 {
                return Err(Error::Config(
                    "expert_obs_event_capacity must be >= 1 with expert_obs on — a \
                     zero-event stream could never anchor the simulator"
                        .into(),
                ));
            }
            if self.expert_obs_event_capacity > 1 << 24 {
                return Err(Error::Config(format!(
                    "expert_obs_event_capacity {} is unreasonably large (each \
                     event is ~24 bytes resident per layer; limit {})",
                    self.expert_obs_event_capacity,
                    1 << 24
                )));
            }
        }
        // fault knobs follow the tier idiom: FaultPlan::validate is a
        // no-op while the plan is disabled
        self.faults.validate()?;
        // the control-wait timeout has no off switch: a serving thread
        // must never be configured to wait forever (or not at all). The
        // upper bound keeps the value safely inside Duration::from_secs_f64
        // range (which panics around 1.8e19 s) with a day as the sane cap.
        if !self.request_timeout_s.is_finite()
            || self.request_timeout_s <= 0.0
            || self.request_timeout_s > MAX_REQUEST_TIMEOUT_S
        {
            return Err(Error::Config(format!(
                "request_timeout_s must be finite and in (0, {MAX_REQUEST_TIMEOUT_S}], got {}",
                self.request_timeout_s
            )));
        }
        if let Some(d) = self.deadline_s {
            if !d.is_finite() || d <= 0.0 {
                return Err(Error::Config(format!(
                    "deadline_s must be finite and > 0 when set, got {d}"
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_parse() {
        assert_eq!(QuantScheme::parse("fp16").unwrap(), QuantScheme::Fp16);
        assert_eq!(QuantScheme::parse("2bit").unwrap(), QuantScheme::Hqq { bits: 2 });
        assert!(QuantScheme::parse("5bit").is_err());
    }

    #[test]
    fn bytes_ordering() {
        // fewer bits => fewer bytes, fp16 largest
        let n = 128 * 256;
        let b2 = QuantScheme::Hqq { bits: 2 }.bytes_for(n, 16);
        let b3 = QuantScheme::Hqq { bits: 3 }.bytes_for(n, 32);
        let b4 = QuantScheme::Hqq { bits: 4 }.bytes_for(n, 32);
        let bf = QuantScheme::Fp16.bytes_for(n, 32);
        assert!(b2 < b3 && b3 < b4 && b4 < bf);
    }

    #[test]
    fn effective_bits_match_paper_ballpark() {
        // paper: 2-bit @ g=16 reports ~2.6 effective bits; our 8-bit-meta
        // accounting lands at 2 + 16/16 = 3.0 (we skip their second-level
        // scale sharing). Assert the ballpark + ordering.
        let e2 = QuantScheme::Hqq { bits: 2 }.effective_bits(16);
        assert!(e2 > 2.0 && e2 < 3.2, "{e2}");
        let e4 = QuantScheme::Hqq { bits: 4 }.effective_bits(64);
        assert!(e4 > 4.0 && e4 < 4.5, "{e4}");
    }

    #[test]
    fn serving_config_validation() {
        assert!(ServingConfig::default().validate().is_ok());
        let zero = ServingConfig { max_concurrent_sessions: 0, ..Default::default() };
        assert!(zero.validate().is_err());
        let huge = ServingConfig { max_concurrent_sessions: 1000, ..Default::default() };
        assert!(huge.validate().is_err());
        let no_staging = ServingConfig { staging_buffers: 0, ..Default::default() };
        assert!(no_staging.validate().is_err());
        let pool = ServingConfig { max_concurrent_sessions: 8, ..Default::default() };
        assert!(pool.validate().is_ok());
    }

    #[test]
    fn kv_knob_validation() {
        let zero_block = ServingConfig { kv_block_tokens: 0, ..Default::default() };
        assert!(zero_block.validate().is_err());
        let huge_block = ServingConfig { kv_block_tokens: 10_000, ..Default::default() };
        assert!(huge_block.validate().is_err());
        let sub_block_pool = ServingConfig {
            kv_block_tokens: 32,
            kv_pool_tokens: Some(16),
            ..Default::default()
        };
        assert!(sub_block_pool.validate().is_err());
        let ok = ServingConfig {
            kv_block_tokens: 16,
            kv_pool_tokens: Some(256),
            ..Default::default()
        };
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn prefix_cache_knob_validation() {
        assert!(!ServingConfig::default().prefix_cache, "cache is opt-in");
        let sub_block_cap = ServingConfig {
            prefix_cache: true,
            kv_block_tokens: 32,
            prefix_cache_tokens: Some(8),
            ..Default::default()
        };
        assert!(sub_block_cap.validate().is_err());
        let inert_cap = ServingConfig { prefix_cache: false, ..sub_block_cap };
        assert!(
            inert_cap.validate().is_ok(),
            "an inert cap must not block a cache-off deployment"
        );
        let ok = ServingConfig {
            prefix_cache: true,
            kv_block_tokens: 16,
            prefix_cache_tokens: Some(128),
            ..Default::default()
        };
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn stop_knob_defaults_preserve_legacy_heuristic() {
        // the scheduler's historical hard-coded stop condition was
        // `generated > 4 && text.ends_with(".\n")` — the knobs must
        // default to exactly that
        let c = ServingConfig::default();
        assert_eq!(c.stop_suffix, ".\n");
        assert_eq!(c.min_tokens, 4);
        assert!(c.batched_decode, "batched decode is on by default");
    }

    #[test]
    fn stop_knob_validation() {
        let long = ServingConfig { stop_suffix: "x".repeat(65), ..Default::default() };
        assert!(long.validate().is_err());
        let max_len = ServingConfig { stop_suffix: "x".repeat(64), ..Default::default() };
        assert!(max_len.validate().is_ok());
        let empty = ServingConfig { stop_suffix: String::new(), ..Default::default() };
        assert!(empty.validate().is_ok(), "empty suffix just disables suffix stopping");
        let huge_min = ServingConfig { min_tokens: (1 << 20) + 1, ..Default::default() };
        assert!(huge_min.validate().is_err());
        let zero_min = ServingConfig { min_tokens: 0, ..Default::default() };
        assert!(zero_min.validate().is_ok());
    }

    #[test]
    fn empty_stop_suffix_composes_with_min_tokens() {
        // an empty suffix disables suffix stopping entirely; min_tokens
        // is then inert but must not be rejected (the knob pair is
        // common when callers want budget-only streams)
        let c = ServingConfig {
            stop_suffix: String::new(),
            min_tokens: 1 << 20,
            ..Default::default()
        };
        assert!(c.validate().is_ok());
    }

    #[test]
    fn min_tokens_beyond_the_token_budget_is_valid() {
        // min_tokens is a floor for SUFFIX stopping, not a promised
        // stream length: a budget (max_new_tokens, or the KV pool clamp
        // applied at admission) smaller than min_tokens simply ends the
        // stream at the budget with the suffix check never armed. The
        // combination therefore validates — rejecting it would couple a
        // per-request clamp to a global knob.
        let c = ServingConfig {
            min_tokens: 1000,
            max_new_tokens: 4,
            ..Default::default()
        };
        assert!(c.validate().is_ok());
    }

    #[test]
    fn chunked_prefill_knob_defaults_and_validation() {
        // opt-in, with defaults that never reject
        let d = ServingConfig::default();
        assert!(!d.chunked_prefill, "chunked prefill is opt-in");
        assert_eq!(d.prefill_chunk_tokens, 16);
        assert_eq!(d.max_batch_tokens, None);

        let zero_chunk = ServingConfig {
            chunked_prefill: true,
            prefill_chunk_tokens: 0,
            ..Default::default()
        };
        assert!(zero_chunk.validate().is_err());
        let huge_chunk = ServingConfig {
            chunked_prefill: true,
            prefill_chunk_tokens: 10_000,
            ..Default::default()
        };
        assert!(huge_chunk.validate().is_err());
        let zero_budget = ServingConfig {
            chunked_prefill: true,
            max_batch_tokens: Some(0),
            ..Default::default()
        };
        assert!(zero_budget.validate().is_err());
        let huge_budget = ServingConfig {
            chunked_prefill: true,
            max_batch_tokens: Some((1 << 20) + 1),
            ..Default::default()
        };
        assert!(huge_budget.validate().is_err());
        // a budget smaller than the chunk knob only shrinks chunks — valid
        let small_budget = ServingConfig {
            chunked_prefill: true,
            prefill_chunk_tokens: 16,
            max_batch_tokens: Some(4),
            ..Default::default()
        };
        assert!(small_budget.validate().is_ok());
        let ok = ServingConfig {
            chunked_prefill: true,
            prefill_chunk_tokens: 32,
            max_batch_tokens: Some(64),
            ..Default::default()
        };
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn chunked_prefill_knobs_are_inert_when_off() {
        // invalid values behind the off switch must not reject the
        // config (same rule prefix_cache_tokens follows)
        let inert = ServingConfig {
            chunked_prefill: false,
            prefill_chunk_tokens: 0,
            max_batch_tokens: Some(0),
            ..Default::default()
        };
        assert!(
            inert.validate().is_ok(),
            "inert chunked-prefill knobs must not block a chunked-off deployment"
        );
    }

    #[test]
    fn tier_knob_defaults_and_validation() {
        // opt-in, uniform by default
        let d = ServingConfig::default();
        assert!(!d.expert_tiers.enabled, "tiers are opt-in");

        let bad = ServingConfig {
            expert_tiers: TierPolicy { hot_fraction: 2.0, ..TierPolicy::hot_cold() },
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        let ok = ServingConfig { expert_tiers: TierPolicy::hot_cold(), ..Default::default() };
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn tier_knobs_are_inert_when_off() {
        // invalid values behind the off switch must not reject the
        // config (same rule the chunked-prefill knobs follow)
        let inert = ServingConfig {
            expert_tiers: TierPolicy {
                enabled: false,
                hot_fraction: 9.0,
                adapt_interval: 0,
                ..TierPolicy::default()
            },
            ..Default::default()
        };
        assert!(
            inert.validate().is_ok(),
            "inert tier knobs must not block a tiers-off deployment"
        );
    }

    #[test]
    fn trace_knob_defaults_and_validation() {
        let d = ServingConfig::default();
        assert!(!d.trace, "tracing is opt-in");
        assert!(d.trace_span_capacity > 0);

        let zero_ring = ServingConfig {
            trace: true,
            trace_span_capacity: 0,
            ..Default::default()
        };
        assert!(zero_ring.validate().is_err());
        let huge_ring = ServingConfig {
            trace: true,
            trace_span_capacity: (1 << 24) + 1,
            ..Default::default()
        };
        assert!(huge_ring.validate().is_err());
        let ok = ServingConfig { trace: true, ..Default::default() };
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn trace_knobs_are_inert_when_off() {
        // invalid values behind the off switch must not reject the
        // config (same rule every opt-in knob family follows)
        let inert = ServingConfig {
            trace: false,
            trace_span_capacity: 0,
            ..Default::default()
        };
        assert!(
            inert.validate().is_ok(),
            "inert trace knobs must not block a trace-off deployment"
        );
    }

    #[test]
    fn expert_obs_knob_defaults_and_validation() {
        let d = ServingConfig::default();
        assert!(!d.expert_obs, "expert observability is opt-in");
        assert!(d.expert_obs_event_capacity > 0);

        let zero_stream = ServingConfig {
            expert_obs: true,
            expert_obs_event_capacity: 0,
            ..Default::default()
        };
        assert!(zero_stream.validate().is_err());
        let huge_stream = ServingConfig {
            expert_obs: true,
            expert_obs_event_capacity: (1 << 24) + 1,
            ..Default::default()
        };
        assert!(huge_stream.validate().is_err());
        let ok = ServingConfig { expert_obs: true, ..Default::default() };
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn expert_obs_knobs_are_inert_when_off() {
        // invalid values behind the off switch must not reject the
        // config (same rule every opt-in knob family follows)
        let inert = ServingConfig {
            expert_obs: false,
            expert_obs_event_capacity: 0,
            ..Default::default()
        };
        assert!(
            inert.validate().is_ok(),
            "inert expert-obs knobs must not block an obs-off deployment"
        );
    }

    #[test]
    fn fault_knob_defaults_and_validation() {
        let d = ServingConfig::default();
        assert!(!d.faults.enabled, "fault injection is opt-in");

        let bad = ServingConfig {
            faults: FaultPlan { transfer_fail_p: 2.0, ..FaultPlan::transient_smoke(1) },
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        let ok = ServingConfig {
            faults: FaultPlan::transient_smoke(1),
            ..Default::default()
        };
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn fault_knobs_are_inert_when_off() {
        // invalid values behind the off switch must not reject the
        // config (same rule every opt-in knob family follows)
        let inert = ServingConfig {
            faults: FaultPlan {
                enabled: false,
                transfer_fail_p: f64::NAN,
                max_retries: 0,
                backoff_base_s: -1.0,
                ..FaultPlan::default()
            },
            ..Default::default()
        };
        assert!(
            inert.validate().is_ok(),
            "inert fault knobs must not block a faults-off deployment"
        );
    }

    #[test]
    fn timeout_and_deadline_knob_validation() {
        let d = ServingConfig::default();
        assert_eq!(d.request_timeout_s, 120.0, "default preserves the legacy wait");
        assert_eq!(d.deadline_s, None, "no deadline by default");

        for bad in [0.0, -5.0, f64::NAN, f64::INFINITY] {
            let c = ServingConfig { request_timeout_s: bad, ..Default::default() };
            assert!(c.validate().is_err(), "request_timeout_s {bad} must reject");
            let c = ServingConfig { deadline_s: Some(bad), ..Default::default() };
            assert!(c.validate().is_err(), "deadline_s {bad} must reject");
        }
        // finite-but-huge values overflow Duration::from_secs_f64 — the
        // validator's cap must catch them before the conversion can panic
        let c = ServingConfig { request_timeout_s: 1e20, ..Default::default() };
        assert!(c.validate().is_err(), "request_timeout_s past the cap must reject");
        let c = ServingConfig {
            request_timeout_s: MAX_REQUEST_TIMEOUT_S,
            ..Default::default()
        };
        assert!(c.validate().is_ok(), "the cap itself is a legal value");
        let ok = ServingConfig {
            request_timeout_s: 1.5,
            deadline_s: Some(30.0),
            ..Default::default()
        };
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn policy_labels_match_table2_rows() {
        assert_eq!(
            OffloadPolicy::Full { cache_k: 4, spec_n: 2 }.label(),
            "Full algorithm"
        );
        assert_eq!(OffloadPolicy::Naive.label(), "Naive offloading (accelerate)");
    }
}
