//! Model geometry + artifact manifest, parsed from `artifacts/manifest.json`
//! (written by `python/compile/aot.py`). Field names mirror
//! `python/compile/config.py::ModelConfig`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub max_seq: usize,
    pub rope_theta: f64,
    pub norm_eps: f64,
    pub group_size: usize,
    pub prefill_chunk: usize,
}

impl ModelConfig {
    pub fn q_dim(&self) -> usize {
        self.n_heads * self.head_dim
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    /// f32 parameters per expert (w1 + w3 + w2).
    pub fn params_per_expert(&self) -> usize {
        3 * self.d_model * self.d_ff
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let get = |k: &str| -> Result<usize> {
            v.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| Error::Config(format!("manifest config missing {k}")))
        };
        let getf = |k: &str| -> Result<f64> {
            v.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| Error::Config(format!("manifest config missing {k}")))
        };
        let cfg = ModelConfig {
            vocab_size: get("vocab_size")?,
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            n_kv_heads: get("n_kv_heads")?,
            head_dim: get("head_dim")?,
            d_ff: get("d_ff")?,
            n_experts: get("n_experts")?,
            top_k: get("top_k")?,
            max_seq: get("max_seq")?,
            rope_theta: getf("rope_theta")?,
            norm_eps: getf("norm_eps")?,
            group_size: get("group_size")?,
            prefill_chunk: get("prefill_chunk")?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        let check = |cond: bool, msg: &str| -> Result<()> {
            if cond {
                Ok(())
            } else {
                Err(Error::Config(msg.to_string()))
            }
        };
        check(self.n_heads % self.n_kv_heads == 0, "n_heads % n_kv_heads != 0")?;
        check(self.d_model % self.group_size == 0, "d_model % group_size != 0")?;
        check(self.d_ff % self.group_size == 0, "d_ff % group_size != 0")?;
        check(self.top_k <= self.n_experts, "top_k > n_experts")?;
        check(self.top_k >= 1, "top_k < 1")?;
        check(self.max_seq >= self.prefill_chunk, "max_seq < prefill_chunk")?;
        Ok(())
    }

    /// The tiny config the default artifacts are built with (tests only —
    /// real runs always read the manifest).
    pub fn tiny() -> Self {
        ModelConfig {
            vocab_size: 256,
            d_model: 128,
            n_layers: 6,
            n_heads: 4,
            n_kv_heads: 2,
            head_dim: 32,
            d_ff: 256,
            n_experts: 8,
            top_k: 2,
            max_seq: 512,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
            group_size: 32,
            prefill_chunk: 16,
        }
    }

    /// Mixtral-8x7B geometry — used by the timing model to translate the
    /// tiny testbed's routing behaviour into paper-scale byte counts.
    pub fn mixtral_8x7b() -> Self {
        ModelConfig {
            vocab_size: 32000,
            d_model: 4096,
            n_layers: 32,
            n_heads: 32,
            n_kv_heads: 8,
            head_dim: 128,
            d_ff: 14336,
            n_experts: 8,
            top_k: 2,
            max_seq: 4096,
            rope_theta: 1e6,
            norm_eps: 1e-5,
            group_size: 64,
            prefill_chunk: 16,
        }
    }
}

#[derive(Debug, Clone)]
pub struct ModuleInfo {
    pub file: String,
    pub arg_shapes: Vec<Vec<usize>>,
    pub arg_dtypes: Vec<String>,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config: ModelConfig,
    pub modules: BTreeMap<String, ModuleInfo>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        let v = Json::parse(&text)?;
        let config = ModelConfig::from_json(
            v.get("config")
                .ok_or_else(|| Error::Artifact("manifest missing 'config'".into()))?,
        )?;
        let mut modules = BTreeMap::new();
        let mods = v
            .get("modules")
            .and_then(Json::as_obj)
            .ok_or_else(|| Error::Artifact("manifest missing 'modules'".into()))?;
        for (name, m) in mods {
            let file = m
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::Artifact(format!("module {name} missing file")))?
                .to_string();
            let mut arg_shapes = Vec::new();
            let mut arg_dtypes = Vec::new();
            for arg in m.get("args").and_then(Json::as_arr).unwrap_or(&[]) {
                let shape = arg
                    .get("shape")
                    .and_then(Json::as_arr)
                    .map(|xs| xs.iter().filter_map(Json::as_usize).collect())
                    .unwrap_or_default();
                arg_shapes.push(shape);
                arg_dtypes.push(
                    arg.get("dtype")
                        .and_then(Json::as_str)
                        .unwrap_or("float32")
                        .to_string(),
                );
            }
            modules.insert(name.clone(), ModuleInfo { file, arg_shapes, arg_dtypes });
        }
        Ok(Manifest { dir: dir.to_path_buf(), config, modules })
    }

    pub fn module(&self, name: &str) -> Result<&ModuleInfo> {
        self.modules
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("manifest has no module '{name}'")))
    }

    pub fn module_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.module(name)?.file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_config_is_valid() {
        ModelConfig::tiny().validate().unwrap();
        ModelConfig::mixtral_8x7b().validate().unwrap();
    }

    #[test]
    fn from_json_roundtrip() {
        let cfg = ModelConfig::tiny();
        let text = format!(
            r#"{{"vocab_size":{},"d_model":{},"n_layers":{},"n_heads":{},
                "n_kv_heads":{},"head_dim":{},"d_ff":{},"n_experts":{},
                "top_k":{},"max_seq":{},"rope_theta":{},"norm_eps":{},
                "group_size":{},"prefill_chunk":{}}}"#,
            cfg.vocab_size, cfg.d_model, cfg.n_layers, cfg.n_heads,
            cfg.n_kv_heads, cfg.head_dim, cfg.d_ff, cfg.n_experts,
            cfg.top_k, cfg.max_seq, cfg.rope_theta, cfg.norm_eps,
            cfg.group_size, cfg.prefill_chunk,
        );
        let parsed = ModelConfig::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(parsed, cfg);
    }

    #[test]
    fn invalid_config_rejected() {
        let mut cfg = ModelConfig::tiny();
        cfg.top_k = 100;
        assert!(cfg.validate().is_err());
        let mut cfg = ModelConfig::tiny();
        cfg.group_size = 7;
        assert!(cfg.validate().is_err());
    }
}
