//! Hardware profiles for the discrete-event timing model.
//!
//! The paper benchmarks four setups (Table 2): free-tier Colab T4, RTX 3080
//! Mobile laptop, RTX 3060 desktop, and an A100-80GB server. We model each
//! as (device memory budget, host→device link, device memory bandwidth,
//! per-kernel launch overhead). Link numbers are *effective* bandwidths —
//! PCIe Gen3 x16 sustains ~11-12 GB/s of its 16 GB/s line rate with pinned
//! buffers, Gen4 roughly double; Colab's virtualised T4 link measures
//! slower in practice, which is visible in the paper's T4 rows.

#[derive(Debug, Clone, PartialEq)]
pub struct HardwareProfile {
    pub name: &'static str,
    /// Device (GPU) memory budget available for experts, bytes.
    pub vram_bytes: u64,
    /// Effective host→device bandwidth, bytes/s (pinned buffers).
    pub h2d_bytes_per_s: f64,
    /// Per-transfer fixed latency, seconds (DMA setup + driver).
    pub h2d_latency_s: f64,
    /// Pageable (non-pinned) transfers run at this fraction of pinned BW.
    pub pageable_factor: f64,
    /// Device memory (HBM/GDDR) bandwidth, bytes/s — batch-1 GEMV compute
    /// time is weight-bytes / this (memory-bound roofline).
    pub hbm_bytes_per_s: f64,
    /// Fixed per-kernel dispatch overhead, seconds. Calibrated to the
    /// paper's *reference implementation* (PyTorch eager + HQQ dequant
    /// glue, weak Colab host CPUs), not to an ideal CUDA-graphs stack —
    /// this is what speculative pre-loading overlaps, so it matters for
    /// Table 2's ablation gaps.
    pub launch_overhead_s: f64,
    /// LRU cache size per layer the paper chose for this GPU.
    pub paper_cache_k: usize,
}

impl HardwareProfile {
    pub const fn t4_colab() -> Self {
        HardwareProfile {
            name: "T4 (Colab)",
            vram_bytes: 16 << 30,
            h2d_bytes_per_s: 10.5e9,
            h2d_latency_s: 100e-6,
            pageable_factor: 0.45,
            hbm_bytes_per_s: 300.0e9,
            // Colab's weak host CPU: python dispatch + HQQ dequant glue
            // dominate per-kernel cost in the reference implementation
            launch_overhead_s: 800e-6,
            paper_cache_k: 4,
        }
    }

    pub const fn rtx3060() -> Self {
        HardwareProfile {
            name: "RTX 3060",
            vram_bytes: 12 << 30,
            h2d_bytes_per_s: 11.0e9, // PCIe Gen3 x16, pinned
            h2d_latency_s: 50e-6,
            pageable_factor: 0.5,
            hbm_bytes_per_s: 360.0e9,
            launch_overhead_s: 600e-6,
            paper_cache_k: 2, // 12 GB card -> smaller cache (paper §3.3)
        }
    }

    pub const fn rtx3080_mobile() -> Self {
        HardwareProfile {
            name: "RTX 3080 Mobile",
            vram_bytes: 16 << 30,
            h2d_bytes_per_s: 13.5e9, // Gen4 link but laptop power limits
            h2d_latency_s: 50e-6,
            pageable_factor: 0.5,
            hbm_bytes_per_s: 448.0e9,
            launch_overhead_s: 550e-6,
            paper_cache_k: 4,
        }
    }

    pub const fn a100_80gb() -> Self {
        HardwareProfile {
            name: "A100-80GB",
            vram_bytes: 80 << 30,
            h2d_bytes_per_s: 22.0e9, // PCIe Gen4 x16 server, pinned
            h2d_latency_s: 30e-6,
            pageable_factor: 0.55,
            hbm_bytes_per_s: 2000.0e9,
            launch_overhead_s: 500e-6,
            paper_cache_k: 4,
        }
    }

    /// The four Table-2 setups, fastest link last to match the paper's
    /// column order (A100, 3080M, 3060, T4).
    pub fn table2_profiles() -> Vec<HardwareProfile> {
        vec![
            Self::a100_80gb(),
            Self::rtx3080_mobile(),
            Self::rtx3060(),
            Self::t4_colab(),
        ]
    }

    pub fn by_name(name: &str) -> Option<HardwareProfile> {
        let norm = name.to_lowercase().replace([' ', '-', '_'], "");
        match norm.as_str() {
            "t4" | "t4colab" | "colab" => Some(Self::t4_colab()),
            "rtx3060" | "3060" => Some(Self::rtx3060()),
            "rtx3080mobile" | "3080mobile" | "3080m" => Some(Self::rtx3080_mobile()),
            "a100" | "a10080gb" => Some(Self::a100_80gb()),
            _ => None,
        }
    }

    /// Time to move `bytes` host→device (pinned).
    pub fn h2d_time(&self, bytes: u64) -> f64 {
        self.h2d_latency_s + bytes as f64 / self.h2d_bytes_per_s
    }

    /// Batch-1 compute time for a kernel that reads `bytes` of weights.
    pub fn gemv_time(&self, bytes: u64) -> f64 {
        self.launch_overhead_s + bytes as f64 / self.hbm_bytes_per_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_by_name() {
        assert_eq!(HardwareProfile::by_name("T4").unwrap().name, "T4 (Colab)");
        assert_eq!(HardwareProfile::by_name("rtx-3060").unwrap().name, "RTX 3060");
        assert_eq!(
            HardwareProfile::by_name("3080 mobile").unwrap().name,
            "RTX 3080 Mobile"
        );
        assert!(HardwareProfile::by_name("h100").is_none());
    }

    #[test]
    fn transfer_time_scales_with_bytes() {
        let p = HardwareProfile::rtx3060();
        let t1 = p.h2d_time(1 << 20);
        let t2 = p.h2d_time(2 << 20);
        assert!(t2 > t1);
        // latency dominates tiny transfers
        let tiny = p.h2d_time(64);
        assert!(tiny < 2.0 * p.h2d_latency_s);
    }

    #[test]
    fn link_ordering_matches_paper() {
        // paper Table 2: A100 fastest, then 3080M, 3060, T4 slowest.
        let ps = HardwareProfile::table2_profiles();
        let bw: Vec<f64> = ps.iter().map(|p| p.h2d_bytes_per_s).collect();
        assert!(bw[0] > bw[1] && bw[1] > bw[2] && bw[2] > bw[3]);
    }

    #[test]
    fn compute_is_much_faster_than_transfer() {
        // the regime the paper exploits: moving an expert costs far more
        // than running it once.
        let p = HardwareProfile::t4_colab();
        let expert_bytes = 57 << 20; // ~2-bit Mixtral expert
        assert!(p.h2d_time(expert_bytes) > 5.0 * p.gemv_time(expert_bytes));
    }
}
