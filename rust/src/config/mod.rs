//! Configuration: model geometry (mirrors `python/compile/config.py`),
//! hardware profiles for the timing model, and serving/offloading policy.

pub mod hardware;
pub mod model;
pub mod serving;

pub use hardware::HardwareProfile;
pub use model::{Manifest, ModelConfig};
pub use serving::{OffloadPolicy, QuantScheme, ServingConfig, SimScale};
