//! Per-request decode state, split out of the engine core so many
//! requests can share one warm engine.
//!
//! A [`Session`] owns everything that belongs to ONE generation stream:
//! the per-layer KV cache, the sequence position, the trace token
//! counter, the run statistics, and the sampler seed. The engine core
//! ([`super::MoeEngine`]) owns everything shareable — runtime,
//! weights/literals, the expert LRU cache, the copy engine, the cost
//! model and the virtual timeline. Any number of sessions can be decoded
//! against one engine (interleaved by the coordinator's scheduler); they
//! are numerically independent but share the warm expert cache, which is
//! exactly the cross-request reuse the paper's offloading algorithm
//! benefits from.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use xla::Literal;

use crate::engine::stats::RunStats;
use crate::engine::MoeEngine;
use crate::error::{Error, Result};
use crate::model::Sampler;

/// Process-wide session id source, so activation-trace records from
/// interleaved sessions remain attributable to their stream.
static NEXT_SESSION_ID: AtomicU64 = AtomicU64::new(1);

/// All per-request mutable state of one generation stream.
pub struct Session {
    /// Unique (process-wide) session id, stamped into trace records.
    pub id: u64,
    /// Per-layer KV caches as opaque literals (§Perf opt 3: no host
    /// round-trips between attention calls).
    pub(super) kv: Vec<Option<(Literal, Literal)>>,
    /// Next sequence position to be written.
    pub(super) pos: usize,
    /// Tokens pushed through this session (trace indexing).
    pub(super) token_counter: usize,
    /// Per-session generation statistics (decode + prefill timing,
    /// cache hit/miss/stall accounting).
    pub run: RunStats,
    /// Sampler seed associated with this session (the coordinator derives
    /// it from the request id so replays are order-independent).
    pub seed: u64,
    /// Live-session counter of the owning engine; decremented on drop.
    pool: Arc<AtomicUsize>,
}

impl Session {
    /// Fresh session against `engine`: zeroed KV, position 0, empty
    /// stats. Errors when the engine's session pool is exhausted — KV
    /// device memory is reserved for `max_concurrent_sessions`, so more
    /// live sessions would silently oversubscribe the modeled VRAM.
    pub fn new(engine: &MoeEngine) -> Result<Self> {
        // reserve the pool slot BEFORE allocating KV, so a rejected open
        // never performs the very allocation the pool bounds
        let max = engine.max_concurrent_sessions.max(1);
        let pool = Arc::clone(&engine.live_sessions);
        if pool
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| {
                if n < max {
                    Some(n + 1)
                } else {
                    None
                }
            })
            .is_err()
        {
            return Err(Error::Engine(format!(
                "session pool exhausted: {max} live session(s) already open \
                 (raise ServingConfig::max_concurrent_sessions)"
            )));
        }
        let n_layers = engine.weights.cfg.n_layers;
        let mut kv = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            match engine.rt.zero_kv() {
                Ok(z) => kv.push(Some(z)),
                Err(e) => {
                    // release the reserved slot before propagating
                    pool.fetch_sub(1, Ordering::SeqCst);
                    return Err(e);
                }
            }
        }
        Ok(Session {
            id: NEXT_SESSION_ID.fetch_add(1, Ordering::Relaxed),
            kv,
            pos: 0,
            token_counter: 0,
            run: RunStats::default(),
            seed: 0,
            pool,
        })
    }

    /// Fresh session with a sampler seed attached.
    pub fn with_seed(engine: &MoeEngine, seed: u64) -> Result<Self> {
        let mut s = Session::new(engine)?;
        s.seed = seed;
        Ok(s)
    }

    /// Current sequence position (tokens already in the KV cache).
    pub fn position(&self) -> usize {
        self.pos
    }

    /// Tokens pushed through this session (decode + prefill).
    pub fn tokens_seen(&self) -> usize {
        self.token_counter
    }

    /// Restart the sequence in place: zero the KV cache and position but
    /// KEEP the accumulated run statistics (the old warm
    /// `reset_session(false)` semantics — the engine's expert cache is
    /// untouched and stays warm).
    pub fn reset(&mut self, engine: &MoeEngine) -> Result<()> {
        for slot in &mut self.kv {
            *slot = Some(engine.rt.zero_kv()?);
        }
        self.pos = 0;
        self.token_counter = 0;
        Ok(())
    }

    /// A sampler seeded from this session.
    pub fn sampler(&self, temperature: f32, top_p: f32) -> Sampler {
        Sampler::new(temperature, top_p, self.seed)
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        self.pool.fetch_sub(1, Ordering::SeqCst);
    }
}
