//! Per-request decode state, split out of the engine core so many
//! requests can share one warm engine.
//!
//! A [`Session`] owns everything that belongs to ONE generation stream:
//! the paged per-layer KV store, the sequence position, the trace token
//! counter, the run statistics, and the sampler seed. The engine core
//! ([`super::MoeEngine`]) owns everything shareable — runtime,
//! weights/literals, the expert LRU cache, the copy engine, the cost
//! model, the virtual timeline and the shared KV block pool. Any number
//! of sessions can be decoded against one engine (interleaved by the
//! coordinator's scheduler); they are numerically independent but share
//! the warm expert cache, which is exactly the cross-request reuse the
//! paper's offloading algorithm benefits from.
//!
//! KV memory is paged (see [`crate::kv`]): opening a session commits no
//! device memory at all — blocks are drawn from the engine's pool on
//! demand as decode advances, returned on [`Session::reset`]/drop, and
//! swapped to host when the scheduler preempts the stream.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::engine::stats::RunStats;
use crate::engine::MoeEngine;
use crate::error::{Error, Result};
use crate::kv::PagedKv;
use crate::model::Sampler;

/// Process-wide session id source, so activation-trace records from
/// interleaved sessions remain attributable to their stream.
static NEXT_SESSION_ID: AtomicU64 = AtomicU64::new(1);

/// All per-request mutable state of one generation stream.
pub struct Session {
    /// Unique (process-wide) session id, stamped into trace records.
    pub id: u64,
    /// Paged per-layer KV store: device literals (§Perf opt 3: no host
    /// round-trips between attention calls) backed block-by-block by the
    /// engine's shared [`crate::kv::KvPool`].
    pub kv: PagedKv,
    /// Next sequence position to be written.
    pub(super) pos: usize,
    /// Tokens pushed through this session (trace indexing).
    pub(super) token_counter: usize,
    /// Per-session generation statistics (decode + prefill timing,
    /// cache hit/miss/stall accounting).
    pub run: RunStats,
    /// Sampler seed associated with this session (the coordinator derives
    /// it from the request id so replays are order-independent).
    pub seed: u64,
    /// Live-session counter of the owning engine; decremented on drop.
    pool: Arc<AtomicUsize>,
}

impl Session {
    /// Fresh session against `engine`: virgin KV (zero blocks mapped),
    /// position 0, empty stats. O(1) — device memory is only committed
    /// as decode advances. Errors when `max_concurrent_sessions` sessions
    /// are already open: the scheduler is provisioned for that width, and
    /// unbounded opens would defeat the KV pool's admission accounting.
    pub fn new(engine: &MoeEngine) -> Result<Self> {
        // reserve the width slot BEFORE constructing state, so a rejected
        // open never touches the pool
        let max = engine.max_concurrent_sessions.max(1);
        let pool = Arc::clone(&engine.live_sessions);
        if pool
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| {
                if n < max {
                    Some(n + 1)
                } else {
                    None
                }
            })
            .is_err()
        {
            return Err(Error::Engine(format!(
                "session pool exhausted: {max} live session(s) already open \
                 (raise ServingConfig::max_concurrent_sessions)"
            )));
        }
        Ok(Session {
            id: NEXT_SESSION_ID.fetch_add(1, Ordering::Relaxed),
            kv: PagedKv::new(engine.weights.cfg.n_layers, Arc::clone(&engine.kv_pool)),
            pos: 0,
            token_counter: 0,
            run: RunStats::default(),
            seed: 0,
            pool,
        })
    }

    /// Fresh session with a sampler seed attached.
    pub fn with_seed(engine: &MoeEngine, seed: u64) -> Result<Self> {
        let mut s = Session::new(engine)?;
        s.seed = seed;
        Ok(s)
    }

    /// Current sequence position (tokens already in the KV cache).
    pub fn position(&self) -> usize {
        self.pos
    }

    /// Tokens pushed through this session (decode + prefill).
    pub fn tokens_seen(&self) -> usize {
        self.token_counter
    }

    /// Restart the sequence in place: return every KV block to the pool
    /// and rewind the position, but KEEP the accumulated run statistics
    /// (the old warm `reset_session(false)` semantics — the engine's
    /// expert cache is untouched and stays warm). No literal is
    /// reallocated: layers drop back to virgin and the next attention
    /// call reads the engine's shared zero template, which is bit-
    /// identical to freshly zeroed caches because the position mask hides
    /// everything at and beyond `pos`.
    pub fn reset(&mut self) {
        self.kv.release();
        self.pos = 0;
        self.token_counter = 0;
    }

    /// A sampler seeded from this session.
    pub fn sampler(&self, temperature: f32, top_p: f32) -> Sampler {
        Sampler::new(temperature, top_p, self.seed)
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        // KV blocks return to the shared pool via PagedKv's own Drop
        self.pool.fetch_sub(1, Ordering::SeqCst);
    }
}
