//! Cost model: translates engine events into virtual durations on the
//! [`crate::clock::Timeline`].
//!
//! Routing decisions always come from the *real* tiny-model execution; the
//! cost model decides what those events would cost on the target hardware,
//! at either the tiny model's own geometry or translated to Mixtral-8x7B
//! geometry (`SimScale::Mixtral`) so Table 2 lands in the paper's units.
//!
//! Batch-1 decode is memory-bound everywhere, so compute costs are modeled
//! as weight-bytes-read / HBM-bandwidth + launch overhead (the GEMV
//! roofline), and transfer costs as bytes / link-bandwidth + latency.

use crate::config::{HardwareProfile, ModelConfig, QuantScheme, SimScale};

#[derive(Debug, Clone)]
pub struct CostModel {
    pub profile: HardwareProfile,
    pub scale: SimScale,
    /// Bytes of one expert on the wire (quantized) at accounting scale.
    pub expert_wire_bytes: u64,
    /// Bytes one expert kernel reads from device memory.
    pub expert_hbm_bytes: u64,
    /// Activation bytes one extra routed row streams through an expert
    /// kernel (read the hidden row + write the output row, fp16
    /// accounting). The batched-decode FFN reads the expert's weights
    /// from HBM once for the whole batch; only this term scales with the
    /// number of stacked rows.
    pub expert_act_row_bytes: u64,
    /// Attention weight bytes read per token per layer.
    pub attn_bytes: u64,
    pub gate_bytes: u64,
    pub lm_head_bytes: u64,
    /// Ratio of accounting-model layers to executed (tiny) layers: the
    /// executed per-layer schedule repeats, so reported times scale by it.
    pub layer_ratio: f64,
    // accounting-scale expert geometry, kept so per-tier schemes price
    // consistently with `expert_wire_bytes`
    acc_expert_params: usize,
    acc_group_size: usize,
}

impl CostModel {
    pub fn new(
        profile: HardwareProfile,
        exec_cfg: &ModelConfig,
        scale: SimScale,
        attn_quant: QuantScheme,
        expert_quant: QuantScheme,
    ) -> Self {
        let acc_cfg = match scale {
            SimScale::Tiny => exec_cfg.clone(),
            SimScale::Mixtral => ModelConfig::mixtral_8x7b(),
        };
        let eg = expert_quant.group_size(acc_cfg.group_size);
        let ag = attn_quant.group_size(acc_cfg.group_size);
        let expert_params = acc_cfg.params_per_expert();
        let attn_params =
            acc_cfg.d_model * acc_cfg.q_dim() * 2 + acc_cfg.d_model * acc_cfg.kv_dim() * 2;
        let expert_wire = expert_quant.bytes_for(expert_params, eg);
        CostModel {
            profile,
            scale,
            expert_wire_bytes: expert_wire,
            // fused kernel reads codes + metadata from HBM (that's the
            // point of on-the-fly dequant)
            expert_hbm_bytes: expert_wire,
            expert_act_row_bytes: (2 * acc_cfg.d_model * 2) as u64,
            attn_bytes: attn_quant.bytes_for(attn_params, ag),
            gate_bytes: (acc_cfg.d_model * acc_cfg.n_experts * 2) as u64,
            lm_head_bytes: (acc_cfg.d_model * acc_cfg.vocab_size * 2) as u64,
            layer_ratio: acc_cfg.n_layers as f64 / exec_cfg.n_layers as f64,
            acc_expert_params: expert_params,
            acc_group_size: acc_cfg.group_size,
        }
    }

    /// Wire bytes one expert would occupy packed at `scheme`, at the
    /// accounting scale — the per-tier pricing hook.
    /// `wire_bytes_of(expert_quant) == expert_wire_bytes`.
    pub fn wire_bytes_of(&self, scheme: QuantScheme) -> u64 {
        scheme.bytes_for(self.acc_expert_params, scheme.group_size(self.acc_group_size))
    }

    /// Host→device time for an arbitrary transfer size. Tiered staging
    /// prices each expert at its ACTUAL tier bytes;
    /// `transfer_s_for(expert_wire_bytes) == expert_transfer_s()`.
    pub fn transfer_s_for(&self, bytes: u64) -> f64 {
        self.profile.h2d_time(bytes)
    }

    // kernel dispatches per module in the reference implementation
    // (qkv+rope+sdpa+o for attention; dequant+gemv chain per expert) —
    // each pays the profile's dispatch overhead.
    const ATTN_KERNELS: f64 = 5.0;
    const GATE_KERNELS: f64 = 1.0;
    const EXPERT_KERNELS: f64 = 3.0;
    const LM_HEAD_KERNELS: f64 = 2.0;

    pub fn expert_transfer_s(&self) -> f64 {
        self.profile.h2d_time(self.expert_wire_bytes)
    }

    /// KV page swap for preemption/resume: moving `bytes` of mapped KV
    /// blocks across the pinned link (symmetric either direction in the
    /// model).
    pub fn kv_swap_s(&self, bytes: u64) -> f64 {
        self.profile.h2d_time(bytes)
    }

    pub fn expert_compute_s(&self) -> f64 {
        (Self::EXPERT_KERNELS - 1.0) * self.profile.launch_overhead_s
            + self.profile.gemv_time(self.expert_hbm_bytes)
    }

    /// Batched expert FFN over `rows` stacked token rows (the batched
    /// decode path's one-kernel-per-expert-per-layer-tick call). Decode
    /// is memory-bound: the kernel reads the expert's weights from HBM
    /// once regardless of how many rows ride through it, so the batched
    /// cost is the single-row cost plus only the extra rows' activation
    /// traffic — the GEMV→GEMM roofline win that makes expert dedup pay
    /// twice (no repeat transfer AND no repeat weight read).
    /// `rows = 1` is exactly [`Self::expert_compute_s`].
    pub fn expert_compute_batched_s(&self, rows: usize) -> f64 {
        let extra = rows.saturating_sub(1) as u64 * self.expert_act_row_bytes;
        (Self::EXPERT_KERNELS - 1.0) * self.profile.launch_overhead_s
            + self.profile.gemv_time(self.expert_hbm_bytes + extra)
    }

    /// Mixed-tick expert FFN: a prefill chunk's routed rows and the
    /// decode batch's routed rows stacked into ONE kernel call. The
    /// expert's (quantized) weights are read from HBM once for the whole
    /// stack — the same weight read the chunk alone would have paid — so
    /// the decode rows riding along add only their activation traffic,
    /// and vice versa. `expert_compute_mixed_s(0, n)` is exactly the
    /// batched decode cost and `(n, 0)` the chunk-only cost: fusing the
    /// two is strictly cheaper than the sum of running them separately
    /// (one weight read instead of two), which is the cost-model side of
    /// the mixed tick's load dedup.
    pub fn expert_compute_mixed_s(&self, chunk_rows: usize, decode_rows: usize) -> f64 {
        self.expert_compute_batched_s(chunk_rows + decode_rows)
    }

    pub fn attn_compute_s(&self) -> f64 {
        (Self::ATTN_KERNELS - 1.0) * self.profile.launch_overhead_s
            + self.profile.gemv_time(self.attn_bytes)
    }

    pub fn gate_compute_s(&self) -> f64 {
        (Self::GATE_KERNELS - 1.0) * self.profile.launch_overhead_s
            + self.profile.gemv_time(self.gate_bytes)
    }

    pub fn lm_head_compute_s(&self) -> f64 {
        (Self::LM_HEAD_KERNELS - 1.0) * self.profile.launch_overhead_s
            + self.profile.gemv_time(self.lm_head_bytes)
    }

    /// Scale a raw timeline duration to the accounting geometry: per-layer
    /// work repeats layer_ratio times in the full-size model.
    pub fn scale_token_time(&self, raw_s: f64) -> f64 {
        raw_s * self.layer_ratio
    }

    /// Re-price a recorded transfer duration under a different link
    /// bandwidth — `h2d_time` run backwards, for the trace-analysis
    /// what-if replays. Only the bytes term scales; the fixed DMA/driver
    /// latency does not, so a duration at or below the latency floor is
    /// returned unchanged (a tiny transfer is latency-bound and a faster
    /// link buys it nothing).
    pub fn rescale_transfer_s(&self, dur_s: f64, bandwidth_factor: f64) -> f64 {
        let lat = self.profile.h2d_latency_s;
        if dur_s <= lat || bandwidth_factor <= 0.0 {
            return dur_s;
        }
        lat + (dur_s - lat) / bandwidth_factor
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> ModelConfig {
        ModelConfig::tiny()
    }

    #[test]
    fn mixtral_scale_matches_paper_arithmetic() {
        // ~2-bit Mixtral expert ≈ 176M params -> ~50-70 MB on the wire
        let cm = CostModel::new(
            HardwareProfile::t4_colab(),
            &model(),
            SimScale::Mixtral,
            QuantScheme::Hqq { bits: 4 },
            QuantScheme::Hqq { bits: 2 },
        );
        let mb = cm.expert_wire_bytes as f64 / (1 << 20) as f64;
        assert!(mb > 40.0 && mb < 80.0, "expert wire size {mb} MB");
        // transfer still costs more than running the expert once — the
        // regime offloading labours under
        assert!(cm.expert_transfer_s() > 1.5 * cm.expert_compute_s());
        // 6 executed layers stand in for 32
        assert!((cm.layer_ratio - 32.0 / 6.0).abs() < 1e-9);
    }

    #[test]
    fn tiny_scale_has_unit_layer_ratio() {
        let cm = CostModel::new(
            HardwareProfile::rtx3060(),
            &model(),
            SimScale::Tiny,
            QuantScheme::Fp16,
            QuantScheme::Hqq { bits: 3 },
        );
        assert_eq!(cm.layer_ratio, 1.0);
    }

    #[test]
    fn lower_bits_transfer_faster() {
        let mk = |bits| {
            CostModel::new(
                HardwareProfile::t4_colab(),
                &model(),
                SimScale::Mixtral,
                QuantScheme::Hqq { bits: 4 },
                QuantScheme::Hqq { bits },
            )
            .expert_transfer_s()
        };
        assert!(mk(2) < mk(3) && mk(3) < mk(4));
    }

    #[test]
    fn batched_expert_cost_sublinear_in_rows() {
        let cm = CostModel::new(
            HardwareProfile::t4_colab(),
            &model(),
            SimScale::Mixtral,
            QuantScheme::Hqq { bits: 4 },
            QuantScheme::Hqq { bits: 2 },
        );
        // one row through the batched path costs exactly the single path
        assert_eq!(cm.expert_compute_batched_s(1), cm.expert_compute_s());
        // more rows cost more than one...
        assert!(cm.expert_compute_batched_s(4) > cm.expert_compute_s());
        // ...but far less than running the kernel once per row — the
        // weights are read from HBM once for the whole batch
        assert!(cm.expert_compute_batched_s(4) < 2.0 * cm.expert_compute_s());
        assert!(cm.expert_compute_batched_s(8) < 4.0 * cm.expert_compute_s());
    }

    #[test]
    fn mixed_tick_expert_cost_beats_split_execution() {
        let cm = CostModel::new(
            HardwareProfile::t4_colab(),
            &model(),
            SimScale::Mixtral,
            QuantScheme::Hqq { bits: 4 },
            QuantScheme::Hqq { bits: 2 },
        );
        // degenerate mixes collapse to the existing terms
        assert_eq!(cm.expert_compute_mixed_s(0, 4), cm.expert_compute_batched_s(4));
        assert_eq!(cm.expert_compute_mixed_s(4, 0), cm.expert_compute_batched_s(4));
        // one fused call reads the weights once; running the chunk and
        // the decode batch separately reads them twice
        let fused = cm.expert_compute_mixed_s(16, 4);
        let split = cm.expert_compute_batched_s(16) + cm.expert_compute_batched_s(4);
        assert!(fused < split, "fused {fused} vs split {split}");
    }

    #[test]
    fn tier_pricing_agrees_with_uniform_accounting() {
        let cm = CostModel::new(
            HardwareProfile::t4_colab(),
            &model(),
            SimScale::Mixtral,
            QuantScheme::Hqq { bits: 4 },
            QuantScheme::Hqq { bits: 3 },
        );
        // the base scheme re-priced through the tier hook is exactly the
        // uniform wire size — uniform tiers charge uniform bytes
        assert_eq!(cm.wire_bytes_of(QuantScheme::Hqq { bits: 3 }), cm.expert_wire_bytes);
        assert_eq!(cm.transfer_s_for(cm.expert_wire_bytes), cm.expert_transfer_s());
        // tier bytes order by bits
        let b2 = cm.wire_bytes_of(QuantScheme::Hqq { bits: 2 });
        let b4 = cm.wire_bytes_of(QuantScheme::Hqq { bits: 4 });
        assert!(b2 < cm.expert_wire_bytes && cm.expert_wire_bytes < b4);
        assert!(cm.transfer_s_for(b2) < cm.transfer_s_for(b4));
    }

    #[test]
    fn rescale_splits_latency_from_bandwidth() {
        let cm = CostModel::new(
            HardwareProfile::t4_colab(),
            &model(),
            SimScale::Mixtral,
            QuantScheme::Hqq { bits: 4 },
            QuantScheme::Hqq { bits: 2 },
        );
        let lat = cm.profile.h2d_latency_s;
        let dur = cm.expert_transfer_s();
        // doubling the bandwidth halves exactly the bytes term — the
        // result is the transfer's own cost priced on a 2× link
        let want = lat + (dur - lat) / 2.0;
        assert!((cm.rescale_transfer_s(dur, 2.0) - want).abs() < 1e-15);
        assert!(cm.rescale_transfer_s(dur, 2.0) > dur / 2.0, "latency floor holds");
        // factor 1 is the identity; latency-bound transfers don't move
        assert_eq!(cm.rescale_transfer_s(dur, 1.0), dur);
        assert_eq!(cm.rescale_transfer_s(lat * 0.5, 2.0), lat * 0.5);
    }

    #[test]
    fn faster_link_transfers_faster() {
        let mk = |p| {
            CostModel::new(
                p,
                &model(),
                SimScale::Mixtral,
                QuantScheme::Hqq { bits: 4 },
                QuantScheme::Hqq { bits: 2 },
            )
            .expert_transfer_s()
        };
        assert!(mk(HardwareProfile::a100_80gb()) < mk(HardwareProfile::t4_colab()));
    }
}
