//! The MoE generation engine — the paper's offloading algorithm driving
//! real model execution through PJRT.
//!
//! The engine is split in two:
//!
//! * [`MoeEngine`] is the shared core: PJRT runtime, weights and their
//!   pre-marshalled literals, the per-layer expert LRU cache, the copy
//!   engine, the cost model and the virtual [`Timeline`]. It holds no
//!   per-request state and can serve any number of generation streams.
//! * [`Session`] owns one request's state: the paged per-layer KV store
//!   (device literals backed block-by-block by the engine's shared
//!   [`crate::kv::KvPool`]), the sequence position, the trace token
//!   counter, per-session [`stats::RunStats`] and the sampler seed.
//!   `decode_step`/`prefill`/`generate`/`score`
//!   take a `&mut Session`, so the coordinator's scheduler can interleave
//!   decode steps of concurrent sessions against one warm expert cache.
//!
//! Per decoded token, per MoE layer the engine:
//! 1. runs attention + router (device-resident weights);
//! 2. looks the routed experts up in the per-layer LRU cache (§3.1),
//!    claiming any that a speculative transfer already fetched;
//! 3. demand-loads misses over the (virtual-clock) link, blocking the
//!    decode front for the remaining transfer time;
//! 4. after the current layer's experts are loaded, applies the NEXT
//!    layer's gate to the current residual and prefetches the top guesses
//!    (§3.2) — those transfers overlap the current layer's expert compute;
//! 5. runs the expert kernels (fused dequant+SwiGLU for quantized paths)
//!    and mixes outputs by the renormalised top-k router weights.
//!
//! Timing is tracked on a virtual [`Timeline`] (costs from [`CostModel`]):
//! routing/caching behaviour is real, reported seconds are the modeled
//! hardware's. Wall time is tracked too for the CPU testbed numbers.

pub mod cost;
pub mod session;
pub mod stats;
pub mod trace;

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::cache::manager::{CacheEvent, CacheManager};
use crate::clock::Timeline;
use crate::config::{HardwareProfile, Manifest, OffloadPolicy, ServingConfig};
use crate::error::{Error, Result};
use crate::fault::{FaultInjector, FaultStats};
use crate::kv::KvPool;
use crate::memory::copy_engine::{CopyEngine, TransferTicket};
use crate::memory::device::{DeviceExpert, DeviceMemory};
use crate::memory::host::ExpertId;
use crate::model::{ModelWeights, Sampler};
use crate::prefix::PrefixCache;
use crate::quant::tier::{assign_tiers, Tier, TierPolicy};
use crate::runtime::{ExpertLits, Runtime, StaticLits};
use crate::tensor::{softmax, top_k, Tensor};
use crate::trace::{SpanKind, Tracer};
use cost::CostModel;
pub use session::Session;
use stats::TokenStats;
use trace::{ActivationRecord, TraceRecorder};

#[derive(Debug, Clone, Copy)]
struct InFlight {
    ticket: TransferTicket,
    ready_at: f64,
}

/// Lifetime counters for the layer-lockstep batched decode path
/// ([`MoeEngine::decode_batch`]) — the coordinator surfaces these as the
/// `batch_occupancy` / `batched_kernel_calls` / `expert_loads_deduped`
/// gauges and done-JSON fields.
#[derive(Debug, Clone, Copy, Default)]
pub struct BatchStats {
    /// Batched layer-lockstep ticks executed (width ≥ 2; width-1 calls
    /// delegate to the sequential step and are not counted).
    pub ticks: u64,
    /// Token rows advanced by batched ticks (Σ batch width).
    pub rows: u64,
    /// Expert kernel invocations issued by the batched path (one per
    /// resident expert per layer-tick, more only when a batch outgrows
    /// the compiled chunk width).
    pub kernel_calls: u64,
    /// Distinct experts resolved against the cache by batched
    /// layer-ticks (one staging per distinct expert per tick).
    pub experts_resolved: u64,
    /// Redundant per-session expert stagings avoided by union dedup:
    /// Σ routed (session, expert) pairs − Σ distinct experts resolved.
    /// Mixed ticks add the prefill chunk's per-layer needed set to the
    /// routed units, so the counter also covers decode rows riding
    /// chunk-staged experts (and vice versa).
    pub loads_deduped: u64,
    /// Batch width of the most recent batched tick.
    pub last_occupancy: u64,
    /// Mixed ticks executed ([`MoeEngine::step_mixed`] with ≥ 1 decode
    /// row AND a prefill chunk fused into one layer-lockstep walk).
    pub mixed_ticks: u64,
    /// Prefill chunk positions advanced by mixed ticks.
    pub prefill_rows: u64,
}

/// Lifetime counters for the adaptive per-expert quantization tiers
/// (see [`crate::quant::tier`]) — the coordinator surfaces these as the
/// `expert_hot_hits` / `tier_promotions` / `link_bytes_saved` gauges and
/// done-JSON fields. All zero for uniform (tiers-off) deployments.
#[derive(Debug, Clone, Copy, Default)]
pub struct TierStats {
    /// Cache hits on experts holding the Hot tier at hit time — the
    /// "hot experts are usually resident anyway" claim, measured.
    pub hot_hits: u64,
    /// Adaptive re-ranks that RAISED an expert's tier (toward more
    /// bits). Static seeding at construction is not counted.
    pub promotions: u64,
    /// Link bytes the executed stagings would have cost at the uniform
    /// base scheme.
    pub uniform_bytes: u64,
    /// Link bytes actually charged (each staging priced at the staged
    /// expert's tier scheme).
    pub actual_bytes: u64,
}

impl TierStats {
    /// Net link bytes the tier policy saved vs the uniform deployment.
    /// Saturating: a hot-heavy miss mix that *costs* bytes reads 0 here
    /// (the signed story is visible in the two raw byte counters).
    pub fn bytes_saved(&self) -> u64 {
        self.uniform_bytes.saturating_sub(self.actual_bytes)
    }
}

/// One session's slot in a batched tick's result: next-token logits, or
/// the per-session refusal ([`Error::KvPoolExhausted`] ⇒ the scheduler
/// preempts/retries that session; anything else fails it alone).
pub type BatchSlot = Result<Vec<f32>>;

/// One session's prefill chunk riding a mixed tick (see
/// [`MoeEngine::step_mixed`]): the session being admitted plus the next
/// `tokens` of its prompt (the positions `sess.pos..sess.pos + len`).
pub struct PrefillChunk<'a> {
    pub sess: &'a mut Session,
    pub tokens: &'a [u32],
}

/// The chunk's slot in a mixed tick's result: logits for the chunk's
/// positions (`[chunk_len, vocab]`), or the chunk's own refusal —
/// [`Error::KvPoolExhausted`] means the chunk's blocks could not be
/// committed and nothing was fed (the scheduler preempts/retries the
/// prefilling session exactly like a KV-dry decode slot).
pub type ChunkSlot = Result<Tensor>;

/// Row provenance inside a mixed tick's stacked expert kernel: a prefill
/// chunk position or a decode session (index into the tick's live set).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum MixedRow {
    Chunk(usize),
    Decode(usize),
}

/// Offline probe for Figure 2 (right): record the speculative router
/// distribution gate_{l+a}(h_l) at every layer without affecting the
/// schedule or the virtual clock. Single-session instrumentation: drive
/// one session while the probe is installed (the fig2 binary does).
#[derive(Debug, Default)]
pub struct SpecProbe {
    pub aheads: Vec<usize>,
    /// (token, layer, ahead, speculative probs over experts)
    pub records: Vec<(usize, usize, usize, Vec<f32>)>,
}

/// The shared engine core. Per-request state lives in [`Session`].
pub struct MoeEngine {
    pub rt: Runtime,
    pub weights: ModelWeights,
    /// Static weights pre-marshalled as PJRT literals (§Perf opt 2).
    lits: StaticLits,
    pub cache: CacheManager,
    copy: CopyEngine,
    pub timeline: Timeline,
    pub cost: CostModel,
    pub policy: OffloadPolicy,
    pub trace: TraceRecorder,
    pub spec_probe: Option<SpecProbe>,
    /// Literal cache for device-resident experts (§Perf opt 4).
    expert_lits: HashMap<ExpertId, ExpertLits>,
    in_flight: HashMap<ExpertId, InFlight>,
    spec_queue: VecDeque<ExpertId>,
    staging_buffers: usize,
    /// Scheduler concurrency the engine was provisioned for. KV memory is
    /// no longer reserved per session — it comes from the paged block
    /// pool — but this still bounds how many sessions may be open at once
    /// (and sizes the pool when `kv_pool_tokens` is unset).
    pub max_concurrent_sessions: usize,
    /// Shared paged-KV block pool (see [`crate::kv`]): the KV byte budget
    /// carved out of device memory, drawn on block-by-block as sessions
    /// decode. Sessions hold an `Arc` so drops return blocks directly.
    pub kv_pool: Arc<KvPool>,
    /// Prefix cache (see [`crate::prefix`]) — `None` unless
    /// `ServingConfig::prefix_cache` opted the deployment in. Holds cold
    /// prefixes as refcounted KV blocks; admissions seed from it and
    /// completions insert into it via the coordinator.
    pub prefix: Option<PrefixCache>,
    /// Live [`Session`] count — [`Session::new`] refuses to exceed the
    /// provisioned pool, [`Session`]'s `Drop` releases the slot.
    live_sessions: Arc<AtomicUsize>,
    /// Whether the coordinator's scheduler should tick live sessions
    /// through [`Self::decode_batch`] (layer-lockstep, expert-deduped)
    /// instead of one sequential [`Self::decode_step`] each. Pure
    /// execution-order optimization — per-session output is identical.
    pub batched_decode: bool,
    /// Scheduler stop condition: generation ends once the decoded text
    /// ends with this suffix (empty = budget-only stopping)...
    pub stop_suffix: String,
    /// ...but only after this many tokens were generated.
    pub min_tokens: usize,
    /// Tick planner for chunked-prefill admission (see [`crate::sched`]):
    /// carries the `chunked_prefill` / `prefill_chunk_tokens` /
    /// `max_batch_tokens` knobs and plans each tick's decode rows + at
    /// most one prefill chunk. With `chunked_prefill` off the planner
    /// never schedules a chunk and the coordinator admits synchronously.
    pub planner: crate::sched::TickPlanner,
    /// Lifetime batched-decode counters (see [`BatchStats`]).
    pub batch: BatchStats,
    /// Lifetime adaptive-tier counters (see [`TierStats`]).
    pub tiers: TierStats,
    /// The expert pool's tier policy, mirrored at construction (`None`
    /// = uniform pool / disabled policy — every tier path in the engine
    /// short-circuits to the pre-tier constants).
    tier_policy: Option<TierPolicy>,
    /// Device slot size per resident expert: the LARGEST tier variant's
    /// wire bytes, so VRAM capacity accounting stays safe whatever mix
    /// of tiers is resident. Equals `cost.expert_wire_bytes` for
    /// uniform pools.
    expert_slot_bytes: u64,
    /// Routed-use total as of the last tier adaptation pass.
    tier_adapted_at_uses: u64,
    /// Span tracer (see [`crate::trace`]) — a bounded ring of typed,
    /// attributed timeline reservations. Disabled (a no-op) unless
    /// `ServingConfig::trace` opted the deployment in; tracing never
    /// changes timing or tokens, only what is observable.
    pub tracer: Tracer,
    /// Expert-flow flight recorder (see [`crate::obs`]): per-(layer,
    /// expert) counters + the replayable access stream behind the
    /// counterfactual cache curves. Disabled (a no-op, and the cache
    /// manager's log stays off) unless `ServingConfig::expert_obs`
    /// opted the deployment in; recording never changes timing or
    /// tokens, only what is observable.
    pub obs: crate::obs::ExpertObs,
    /// Engine-lifetime tick counter for span attribution: one tick per
    /// `decode_step` / batched / mixed tick / prefill call.
    tick: u64,
    /// Session id spans are currently attributed to. Per-session code
    /// paths set it from the session they hold; shared batch work is
    /// attributed to its stats owner (the first routed participant,
    /// matching the TokenStats convention).
    span_sess: u64,
    /// Experts whose resident copy was dropped by an adaptive re-tier:
    /// their next demand staging is a [`SpanKind::TierReload`], not a
    /// plain demand-load. Entries clear on the next staging or hit.
    tier_reload_pending: HashSet<ExpertId>,
    /// Deterministic fault injector (see [`crate::fault`]) — seeded from
    /// `ServingConfig::faults`. Inert (every call is a branch on a bool)
    /// unless the plan is enabled; the scheduler consults
    /// [`Self::fault_gate`] at tick boundaries and the staging / KV-swap
    /// seams charge recovery to the timeline themselves.
    faults: FaultInjector,
    /// `ServingConfig::request_timeout_s`, mirrored here so the
    /// coordinator's client-facing waits can bound themselves without
    /// re-threading the whole serving config.
    pub request_timeout_s: f64,
    /// `ServingConfig::deadline_s`: the default per-request completion
    /// deadline the scheduler enforces when a request carries none of
    /// its own. `None` (the default) disables enforcement.
    pub default_deadline_s: Option<f64>,
}

impl MoeEngine {
    /// Assemble the engine from loaded artifacts + weights.
    pub fn new(
        manifest: &Manifest,
        weights: ModelWeights,
        serving: &ServingConfig,
        profile: HardwareProfile,
    ) -> Result<Self> {
        let rt = Runtime::load(manifest)?;
        Self::with_runtime(rt, weights, serving, profile)
    }

    pub fn with_runtime(
        rt: Runtime,
        weights: ModelWeights,
        serving: &ServingConfig,
        profile: HardwareProfile,
    ) -> Result<Self> {
        serving.validate()?;
        let cfg = weights.cfg.clone();
        let cost = CostModel::new(
            profile,
            &cfg,
            serving.sim_scale,
            weights.attn_quant,
            serving.expert_quant,
        );
        // device budget at accounting scale: VRAM minus shared weights,
        // the paged KV block pool and staging buffers. The pool is carved
        // out of the budget as whole blocks: per-token KV bytes come from
        // the accounting geometry (full-sequence bytes spread over the
        // executed model's max_seq positions, since block indices live in
        // the executed model's position space), block size from the
        // serving config, capacity from kv_pool_tokens — defaulting to
        // one full sequence per configured session, i.e. byte-for-byte
        // the old static reservation.
        let kv_per_session = match serving.sim_scale {
            crate::config::SimScale::Tiny => {
                (2 * cfg.n_layers * cfg.max_seq * cfg.kv_dim() * 2) as u64
            }
            crate::config::SimScale::Mixtral => {
                let m = crate::config::ModelConfig::mixtral_8x7b();
                (2 * m.n_layers * m.max_seq * m.kv_dim() * 2) as u64
            }
        };
        let kv_token_bytes = kv_per_session.div_ceil(cfg.max_seq as u64);
        let block_tokens = serving.kv_block_tokens.clamp(1, cfg.max_seq);
        let pool_tokens = serving
            .kv_pool_tokens
            .unwrap_or(serving.max_concurrent_sessions * cfg.max_seq);
        let n_blocks = pool_tokens.div_ceil(block_tokens);
        let block_bytes = kv_token_bytes * block_tokens as u64;
        let kv_pool_bytes = n_blocks as u64 * block_bytes;
        let shared = cost.lm_head_bytes * 2
            + (cost.attn_bytes + cost.gate_bytes) * ((cfg.n_layers as f64 * cost.layer_ratio) as u64);
        // tiered pools stage experts of up to three byte sizes; one
        // device/staging slot must fit the LARGEST so residency
        // accounting can stay per-slot uniform (uniform pools: exactly
        // the base wire bytes, unchanged)
        let tier_policy = weights.experts.tier_policy().copied();
        let expert_slot_bytes = match tier_policy {
            Some(p) => cost
                .expert_wire_bytes
                .max(cost.wire_bytes_of(p.hot))
                .max(cost.wire_bytes_of(p.cold)),
            None => cost.expert_wire_bytes,
        };
        let staging = serving.staging_buffers as u64 * expert_slot_bytes;
        let reserved = shared + staging;
        // a KV pool that outgrows the modeled VRAM must fail loudly —
        // clamping the device up (the width-1 tiny-testbed fallback
        // below) would simulate a GPU that doesn't exist
        if (serving.max_concurrent_sessions > 1 || serving.kv_pool_tokens.is_some())
            && reserved + kv_pool_bytes + expert_slot_bytes > cost.profile.vram_bytes
        {
            return Err(Error::Config(format!(
                "KV pool of {pool_tokens} tokens ({} blocks) reserves {} MiB \
                 (KV pool + shared + staging), which exceeds {}'s {} MiB VRAM — \
                 lower max_concurrent_sessions or kv_pool_tokens",
                n_blocks,
                (reserved + kv_pool_bytes) / (1 << 20),
                cost.profile.name,
                cost.profile.vram_bytes / (1 << 20),
            )));
        }
        let device = DeviceMemory::with_kv_pool(
            cost.profile
                .vram_bytes
                .max(reserved + kv_pool_bytes + expert_slot_bytes),
            reserved,
            kv_pool_bytes,
            expert_slot_bytes,
        );
        let kv_pool = Arc::new(KvPool::carve(
            kv_pool_bytes,
            block_tokens,
            block_bytes,
            vec![cfg.max_seq, cfg.n_kv_heads, cfg.head_dim],
        ));
        let prefix = serving.prefix_cache.then(|| {
            PrefixCache::new(
                Arc::clone(&kv_pool),
                cfg.n_layers,
                cfg.max_seq,
                cfg.n_kv_heads * cfg.head_dim,
                serving.prefix_cache_tokens,
            )
        });
        let mut cache = CacheManager::new(
            cfg.n_layers,
            serving.policy.cache_k(),
            serving.staging_buffers,
            device,
        );
        cache.set_obs_log(serving.expert_obs);
        let copy = CopyEngine::new(Arc::clone(&weights.experts), serving.staging_buffers, 2);
        let lits = StaticLits::new(&weights)?;
        // static tier seeding from gate statistics: layer l's router
        // column ‖w_gate[:, e]‖² is a pre-run proxy for how much mass
        // the gate sends expert e (the online adapter then refines the
        // ranking from real route counts — see maybe_adapt_tiers)
        if let Some(p) = tier_policy {
            for (l, lw) in weights.layers.iter().enumerate() {
                let mut scores = vec![0.0f64; cfg.n_experts];
                for r in 0..cfg.d_model {
                    for (s, w) in scores.iter_mut().zip(lw.w_gate.row(r)) {
                        *s += (*w as f64) * (*w as f64);
                    }
                }
                for (e, t) in assign_tiers(&scores, p.hot_fraction, p.cold_fraction)
                    .into_iter()
                    .enumerate()
                {
                    weights.experts.set_tier(ExpertId::new(l, e), t);
                }
            }
        }
        Ok(MoeEngine {
            rt,
            weights,
            lits,
            cache,
            copy,
            timeline: Timeline::new(),
            cost,
            policy: serving.policy,
            trace: TraceRecorder::new(false),
            spec_probe: None,
            expert_lits: HashMap::new(),
            in_flight: HashMap::new(),
            spec_queue: VecDeque::new(),
            staging_buffers: serving.staging_buffers,
            max_concurrent_sessions: serving.max_concurrent_sessions,
            kv_pool,
            prefix,
            live_sessions: Arc::new(AtomicUsize::new(0)),
            batched_decode: serving.batched_decode,
            stop_suffix: serving.stop_suffix.clone(),
            min_tokens: serving.min_tokens,
            planner: crate::sched::TickPlanner::from_serving(serving),
            batch: BatchStats::default(),
            tiers: TierStats::default(),
            tier_policy,
            expert_slot_bytes,
            tier_adapted_at_uses: 0,
            tracer: if serving.trace {
                Tracer::enabled(serving.trace_span_capacity)
            } else {
                Tracer::disabled()
            },
            obs: if serving.expert_obs {
                crate::obs::ExpertObs::enabled(
                    cfg.n_layers,
                    cfg.n_experts,
                    serving.expert_obs_event_capacity,
                )
            } else {
                crate::obs::ExpertObs::disabled()
            },
            tick: 0,
            span_sess: 0,
            tier_reload_pending: HashSet::new(),
            faults: FaultInjector::new(&serving.faults),
            request_timeout_s: serving.request_timeout_s,
            default_deadline_s: serving.deadline_s,
        })
    }

    /// The scheduler tick most recently begun (span attribution).
    pub fn current_tick(&self) -> u64 {
        self.tick
    }

    /// Lifetime fault-injection counters (all zero with faults off).
    pub fn fault_stats(&self) -> FaultStats {
        self.faults.stats()
    }

    /// Tick-boundary fault pre-gate for `session` (see
    /// [`FaultInjector::gate`]). The scheduler calls this once per live
    /// session per tick, BEFORE the session's step touches any shared
    /// state: [`Error::FaultTransient`] degrades the session through the
    /// existing preempt/requeue path, [`Error::FaultFatal`] fails exactly
    /// that request. Always `None` with faults off.
    pub fn fault_gate(&mut self, session: u64) -> Option<Error> {
        self.faults.gate(session)
    }

    /// Open a fresh session (virgin paged KV — zero blocks committed —
    /// position 0, empty stats). The expert cache is shared with every
    /// other session and stays warm.
    /// Errors when `max_concurrent_sessions` sessions are already live.
    pub fn new_session(&self) -> Result<Session> {
        Session::new(self)
    }

    /// Sessions currently open against this engine.
    pub fn live_session_count(&self) -> usize {
        self.live_sessions.load(Ordering::SeqCst)
    }

    /// Drop the warm expert cache (cold restart of the offloading state).
    /// Sessions are unaffected — their KV caches live in [`Session`].
    pub fn drop_expert_cache(&mut self) {
        self.drain_in_flight();
        // fold any pending flight-recorder entries before the manager
        // (and its log) is replaced
        self.obs_drain();
        // non-expert bytes = reserved + the KV pool carve; split the
        // carve back out so the rebuilt device keeps it pinned
        let non_expert = self.cache.device.used_bytes()
            - self.cache.device.resident_count() as u64 * self.expert_slot_bytes;
        let kv_pool_bytes = self.cache.device.kv_pool_bytes();
        let reserved = non_expert - kv_pool_bytes;
        self.cache = CacheManager::new(
            self.weights.cfg.n_layers,
            self.cache.cache_k(),
            self.staging_buffers,
            DeviceMemory::with_kv_pool(
                self.cost
                    .profile
                    .vram_bytes
                    .max(non_expert + self.expert_slot_bytes),
                reserved,
                kv_pool_bytes,
                self.expert_slot_bytes,
            ),
        );
        // the rebuilt manager starts with logging off — restore it, and
        // mark the measured-counter restart in the recorded streams so
        // the simulator's anchor survives the cold restart
        self.cache.set_obs_log(self.obs.is_enabled());
        self.obs.on_cache_reset(self.timeline.now());
        self.expert_lits.clear();
    }

    /// Fold the cache manager's pending flight-recorder log into the
    /// expert observer (no-op with `expert_obs` off — the manager's log
    /// is off too, so there is never anything to drain).
    fn obs_drain(&mut self) {
        if self.obs.is_enabled() {
            let log = self.cache.take_obs_log();
            self.obs.apply_log(&log, self.timeline.now());
        }
    }

    /// Scheduler-tick hook: drain pending flight-recorder events and
    /// record one counter-track sample (expert residency + cumulative
    /// hit rate) at the current virtual time. No-op with `expert_obs`
    /// off.
    pub fn obs_tick(&mut self) {
        if !self.obs.is_enabled() {
            return;
        }
        self.obs_drain();
        let resident = self.cache.device.resident_count();
        let (h, m) = (self.cache.stats.hits, self.cache.stats.misses);
        self.obs.sample(self.timeline.now(), resident, h, m);
    }

    /// The `experts` TCP command's payload: the per-(layer, expert)
    /// flight recorder, per-layer prefetch quality and the
    /// counterfactual cache curves — or the explicit `disabled`
    /// degradation when `expert_obs` is off.
    pub fn experts_report(&mut self) -> crate::util::json::Json {
        use crate::util::json::Json;
        if !self.obs.is_enabled() {
            return Json::obj(vec![
                ("type", "experts".into()),
                ("enabled", false.into()),
                ("error", "expert observability disabled".into()),
            ]);
        }
        self.obs_drain();
        self.obs.report(
            &self.cache.stats,
            self.cache.cache_k(),
            self.timeline.now(),
            (self.copy.staged_jobs, self.copy.demand_jobs, self.copy.spec_jobs),
        )
    }

    fn drain_in_flight(&mut self) {
        for (_, inf) in self.in_flight.drain() {
            let _ = self.copy.wait(inf.ticket);
        }
        self.spec_queue.clear();
    }

    // ---------------------------------------------------------------------
    // KV preemption (scheduler support)
    // ---------------------------------------------------------------------

    /// Preempt `sess`: swap its KV images to host memory and return every
    /// block to the pool so older sessions can finish. The modeled D2H
    /// transfer of the mapped blocks occupies the link and blocks the
    /// decode front like any demand load. The session's position, stats
    /// and generated state are untouched — [`Self::resume_session`]
    /// continues it bit-identically.
    pub fn preempt_session(&mut self, sess: &mut Session) -> Result<()> {
        let bytes = sess.kv.swap_out()?;
        if bytes > 0 {
            let swap_s = self.cost.kv_swap_s(bytes);
            self.charge_kv_swap_faults(swap_s, sess.id);
            let span = self.timeline.transfer(swap_s, self.timeline.now());
            self.tracer
                .record(SpanKind::KvResume, span, sess.id, None, self.tick);
            self.timeline.wait_until(span.end);
        }
        self.kv_pool.note_preemption();
        Ok(())
    }

    /// Resume a preempted session: re-acquire blocks for its written
    /// positions and restore the KV images from host, bit-exactly. Cold
    /// cached prefixes are reclaimed first when the pool is dry; errors
    /// with [`Error::KvPoolExhausted`] only when even that cannot back
    /// the stream (the scheduler retries later).
    pub fn resume_session(&mut self, sess: &mut Session) -> Result<()> {
        let bytes = match sess.kv.swap_in(sess.pos) {
            Ok(b) => b,
            Err(Error::KvPoolExhausted(msg)) => {
                let needed = self.kv_pool.blocks_for(sess.pos);
                if self.prefix.as_mut().map_or(0, |c| c.reclaim(needed)) == 0 {
                    return Err(Error::KvPoolExhausted(msg));
                }
                sess.kv.swap_in(sess.pos)?
            }
            Err(e) => return Err(e),
        };
        if bytes > 0 {
            let swap_s = self.cost.kv_swap_s(bytes);
            self.charge_kv_swap_faults(swap_s, sess.id);
            let span = self.timeline.transfer(swap_s, self.timeline.now());
            self.tracer
                .record(SpanKind::KvResume, span, sess.id, None, self.tick);
            self.timeline.wait_until(span.end);
        }
        Ok(())
    }

    /// Charge any injected KV swap/resume failures ahead of a
    /// `swap_s`-second swap: the retry run (failed attempts + backoff
    /// from [`FaultInjector::kv_swap`]) burns link time as a
    /// [`SpanKind::FaultRetry`] span, and the real swap transfer then
    /// queues behind it — so `wait_until` on the swap's own span stalls
    /// the session through the recovery too. No-op with faults off.
    fn charge_kv_swap_faults(&mut self, swap_s: f64, sess: u64) {
        if !self.faults.enabled() {
            return;
        }
        let extra = self.faults.kv_swap(swap_s);
        if extra > 0.0 {
            let span = self.timeline.transfer(extra, self.timeline.now());
            self.tracer
                .record(SpanKind::FaultRetry, span, sess, None, self.tick);
        }
    }

    // ---------------------------------------------------------------------
    // prefix cache (see crate::prefix)
    // ---------------------------------------------------------------------

    /// Commit KV blocks for `sess` up to `tokens` positions, reclaiming
    /// cold cached prefixes when the pool runs dry. Only when the cache
    /// cannot help either does [`Error::KvPoolExhausted`] surface — so
    /// the scheduler preempts a LIVE session only after every DEAD
    /// prefix lost its blocks first.
    fn ensure_kv(&mut self, sess: &mut Session, tokens: usize) -> Result<()> {
        match sess.kv.ensure_tokens(tokens) {
            Err(Error::KvPoolExhausted(msg)) => {
                let needed = self
                    .kv_pool
                    .blocks_for(tokens)
                    .saturating_sub(sess.kv.mapped_blocks());
                if self.prefix.as_mut().map_or(0, |c| c.reclaim(needed)) == 0 {
                    return Err(Error::KvPoolExhausted(msg));
                }
                sess.kv.ensure_tokens(tokens)
            }
            r => r,
        }
    }

    /// Admission gate with eviction ordering: would `tokens` positions
    /// fit the free blocks plus what prefix-cache reclaim could free?
    /// (With the cache off this is exactly `kv_pool.can_admit`.)
    pub fn kv_can_admit(&self, tokens: usize) -> bool {
        self.kv_can_admit_reserving(tokens, 0)
    }

    /// [`Self::kv_can_admit`] minus `reserved_blocks` of capacity
    /// already promised elsewhere. The coordinator reserves the unfed
    /// remainder of in-flight CHUNKED prefills (their blocks commit
    /// chunk-by-chunk, so the free list overstates what a new admission
    /// may take — without the reserve the gate over-admits and forces
    /// mid-prefill preemption churn the synchronous path never had).
    pub fn kv_can_admit_reserving(&self, tokens: usize, reserved_blocks: usize) -> bool {
        let free = self.kv_pool.stats().free_blocks;
        let reclaimable = self.prefix.as_ref().map_or(0, |c| c.reclaimable_blocks());
        self.kv_pool.blocks_for(tokens) + reserved_blocks <= free + reclaimable
    }

    /// Prefix-aware admission gate for a tokenized prompt: blocks the
    /// cache would SEED (retained from the tree, never allocated) don't
    /// count against free capacity, so a warm request whose trunk is
    /// shared with a live session is not deferred as if it were cold.
    /// The seeded blocks are subtracted from the reclaimable pool too —
    /// a seed pins its own trunk, so those blocks cannot also be counted
    /// as evictable headroom (if they are already session-shared they
    /// were never reclaimable, and the subtraction only makes the gate
    /// more conservative). Admission itself still does the precise
    /// all-or-nothing commit and requeues transiently.
    pub fn kv_can_admit_prompt(&self, tokens: &[u32]) -> bool {
        self.kv_can_admit_prompt_reserving(tokens, 0)
    }

    /// [`Self::kv_can_admit_prompt`] minus `reserved_blocks` of
    /// capacity already promised elsewhere (see
    /// [`Self::kv_can_admit_reserving`]).
    pub fn kv_can_admit_prompt_reserving(&self, tokens: &[u32], reserved_blocks: usize) -> bool {
        let seeded = self.prefix.as_ref().map_or(0, |c| {
            c.peek_match_blocks(tokens, tokens.len().saturating_sub(1))
        });
        let free = self.kv_pool.stats().free_blocks;
        let reclaimable = self
            .prefix
            .as_ref()
            .map_or(0, |c| c.reclaimable_blocks())
            .saturating_sub(seeded);
        let needed = self.kv_pool.blocks_for(tokens.len() + 1).saturating_sub(seeded);
        needed + reserved_blocks <= free + reclaimable
    }

    /// Prefill with prefix reuse: seed a virgin session from the longest
    /// cached prefix of `tokens` (when the cache is on and hits), then
    /// prefill only the uncached tail. Returns the tail's logits —
    /// `[tokens.len() - reused, vocab]` — plus the reused position count
    /// (0 = plain prefill, byte-identical to the cache-less path).
    pub fn prefill_cached(
        &mut self,
        sess: &mut Session,
        tokens: &[u32],
    ) -> Result<(Tensor, usize)> {
        let reused = self.seed_from_prefix(sess, tokens)?;
        let logits = self.prefill(sess, &tokens[reused..])?;
        Ok((logits, reused))
    }

    /// Begin a CHUNKED admission: seed the virgin session from the
    /// prefix cache (when enabled and hitting) but run no prefill —
    /// the prompt tail enters the engine chunk-by-chunk afterwards,
    /// via [`Self::step_mixed`] mixed ticks (or plain [`Self::prefill`]
    /// calls on the sequential fallback), so seeding and tail-chunking
    /// compose. Returns the reused position count; `prefill_cached`
    /// is exactly `prefill_start` + one `prefill` of the whole tail.
    pub fn prefill_start(&mut self, sess: &mut Session, tokens: &[u32]) -> Result<usize> {
        self.seed_from_prefix(sess, tokens)
    }

    /// Seed `sess` from the prefix cache. The match is capped one short
    /// of the full prompt so prefill always has at least one position to
    /// produce first-token logits from. The seeded H2D copy is charged
    /// to the timeline like a session resume of the same byte count.
    fn seed_from_prefix(&mut self, sess: &mut Session, tokens: &[u32]) -> Result<usize> {
        if sess.pos != 0 || tokens.len() < 2 {
            return Ok(0);
        }
        let Some(cache) = self.prefix.as_mut() else { return Ok(0) };
        let Some(seed) = cache.lookup(tokens, tokens.len() - 1) else { return Ok(0) };
        let matched = seed.matched;
        let bytes = sess.kv.seed(seed.layers, seed.blocks)?;
        sess.pos = matched;
        // trace indexing stays aligned with sequence positions
        sess.token_counter = matched;
        sess.run.prefix_reused_tokens += matched;
        if bytes > 0 {
            let span = self
                .timeline
                .transfer(self.cost.kv_swap_s(bytes), self.timeline.now());
            self.tracer
                .record(SpanKind::PrefixSeed, span, sess.id, None, self.tick);
            self.timeline.wait_until(span.end);
        }
        Ok(matched)
    }

    /// Insert a finished stream into the prefix cache: `tokens` must be
    /// the tokens actually fed (prompt + sampled-and-fed), i.e. the
    /// sequence the session's KV positions were written from. The tree
    /// RETAINS the session's own page-table blocks for every new chunk —
    /// when the session drops a moment later, its blocks survive as
    /// cache instead of dying, so inserting costs no free pool capacity.
    /// Best effort — the token cap just caches less. Returns the number
    /// of blocks admitted.
    pub fn prefix_insert(&mut self, sess: &Session, tokens: &[u32]) -> Result<usize> {
        if self.prefix.is_none() || sess.kv.is_swapped() {
            return Ok(0);
        }
        let n = tokens.len().min(sess.pos);
        let bt = self.kv_pool.block_tokens();
        // the session's blocks, in position order, one per full chunk
        let Some(blocks) = (0..n / bt)
            .map(|ci| sess.kv.page_table().block_of(ci * bt))
            .collect::<Option<Vec<_>>>()
        else {
            return Ok(0); // defensive: positions without blocks — skip
        };
        let cfg = &self.weights.cfg;
        let image_len = cfg.max_seq * cfg.n_kv_heads * cfg.head_dim;
        let cache = self.prefix.as_mut().expect("checked above");
        cache.insert(&tokens[..n], &blocks, |l| match sess.kv.layer(l)? {
            Some((k, v)) => Ok((k.to_vec::<f32>()?, v.to_vec::<f32>()?)),
            None => Ok((vec![0.0; image_len], vec![0.0; image_len])),
        })
    }

    // ---------------------------------------------------------------------
    // decode
    // ---------------------------------------------------------------------

    /// Decode one token for `sess`: returns next-token logits.
    pub fn decode_step(&mut self, sess: &mut Session, token: u32) -> Result<Vec<f32>> {
        // tick boundary: no pins held, nothing staged mid-layer
        self.maybe_adapt_tiers();
        if sess.pos >= self.weights.cfg.max_seq {
            return Err(Error::Engine(format!(
                "sequence length {} exceeds max_seq {}",
                sess.pos, self.weights.cfg.max_seq
            )));
        }
        // commit KV blocks for the new position up front (all layers
        // advance in lockstep, one page table covers them all), evicting
        // cold cached prefixes first when the pool is dry. On a truly dry
        // pool this fails BEFORE any compute or state change, so the
        // scheduler can preempt a session and retry the step cleanly.
        self.ensure_kv(sess, sess.pos + 1)?;
        self.tick += 1;
        self.span_sess = sess.id;
        let sim_start = self.timeline.now();
        let wall_start = Instant::now();
        let mut tstats = TokenStats::default();

        // embed (device-resident; gather cost ~ launch overhead)
        let span = self.timeline.compute(self.cost.profile.launch_overhead_s, 0.0);
        self.tracer.record(SpanKind::Embed, span, sess.id, None, self.tick);
        let mut x = self.rt.embed(token, &self.lits.embed)?;

        for l in 0..self.weights.cfg.n_layers {
            x = self.layer_step(sess, l, x, &mut tstats)?;
        }

        // lm head
        let span = self.timeline.compute(self.cost.lm_head_compute_s(), 0.0);
        self.tracer.record(SpanKind::LmHead, span, sess.id, None, self.tick);
        let logits = self.rt.lm_head(&x, &self.lits.final_ln, &self.lits.lm_head)?;

        sess.pos += 1;
        sess.token_counter += 1;
        tstats.sim_s = self.timeline.now() - sim_start;
        tstats.wall_s = wall_start.elapsed().as_secs_f64();
        sess.run.sim_total_scaled_s += self.cost.scale_token_time(tstats.sim_s);
        sess.run.wall_total_s += tstats.wall_s;
        sess.run.tokens.push(tstats);
        Ok(logits.data)
    }

    /// Layer-lockstep batched decode: advance ALL given sessions one
    /// token in a single tick. Per layer, every session runs attention +
    /// routing (the same T = 1 kernels as [`Self::decode_step`]), then
    /// the **union** of routed experts is resolved against the cache
    /// once — one LRU lookup and at most one transfer per distinct
    /// expert per layer-tick — and each resident expert runs ONE kernel
    /// over its stacked routed rows. When the union fits the layer cache
    /// it is staged up front and *pinned* (see [`CacheManager::pin`]) so
    /// staging a neighbor's expert can never evict one that other
    /// sessions still need; a union that outgrows the cache is loaded
    /// and consumed one expert at a time instead (the sequential path's
    /// interleave). Speculation fires once per layer-tick on the
    /// batch-aggregated gate distribution.
    ///
    /// This is a pure execution-order/dedup optimization: each session's
    /// logits are bit-identical to what sequential `decode_step` calls
    /// would produce (attention, routing and the row-parallel expert FFN
    /// depend only on that session's own state; see
    /// [`crate::runtime::Runtime::expert_rows_with_lits`] for why
    /// stacking is bit-safe).
    ///
    /// Returns one slot per input session, in order. A slot is `Err` for
    /// a per-session refusal decided BEFORE any compute — KV-dry
    /// ([`Error::KvPoolExhausted`]: nothing was fed, the scheduler can
    /// preempt/retry that session without poisoning the batch) or an
    /// exhausted context window. The outer `Err` is reserved for engine
    /// failures mid-tick, after which the participating sessions' state
    /// is indeterminate.
    ///
    /// Width 1 delegates to [`Self::decode_step`] verbatim, so a batch
    /// of one is bit- and stats-identical to the sequential path. The
    /// single-session Fig-2 [`SpecProbe`] instrumentation is not
    /// consulted here (the probe's drivers decode through `decode_step`).
    pub fn decode_batch(
        &mut self,
        sessions: &mut [&mut Session],
        tokens: &[u32],
    ) -> Result<Vec<BatchSlot>> {
        // tick boundary: no pins held, nothing staged mid-layer
        self.maybe_adapt_tiers();
        if sessions.len() != tokens.len() {
            return Err(Error::Engine(format!(
                "decode_batch: {} sessions but {} tokens",
                sessions.len(),
                tokens.len()
            )));
        }
        if sessions.is_empty() {
            return Ok(Vec::new());
        }
        if sessions.len() == 1 {
            return Ok(vec![self.decode_step(&mut *sessions[0], tokens[0])]);
        }
        let max_seq = self.weights.cfg.max_seq;
        let mut results: Vec<Option<BatchSlot>> =
            (0..sessions.len()).map(|_| None).collect();
        // per-session guards + KV block commit, all BEFORE any compute or
        // state change: a session refused here is untouched this tick
        let mut live: Vec<usize> = Vec::with_capacity(sessions.len());
        for i in 0..sessions.len() {
            let sess = &mut *sessions[i];
            if sess.pos >= max_seq {
                results[i] = Some(Err(Error::Engine(format!(
                    "sequence length {} exceeds max_seq {max_seq}",
                    sess.pos
                ))));
                continue;
            }
            let next = sess.pos + 1;
            match self.ensure_kv(sess, next) {
                Ok(()) => live.push(i),
                Err(e) => results[i] = Some(Err(e)),
            }
        }
        if live.is_empty() {
            return Ok(results.into_iter().map(|r| r.expect("all slots filled")).collect());
        }

        self.tick += 1;
        let sim_start = self.timeline.now();
        let wall_start = Instant::now();
        self.batch.ticks += 1;
        self.batch.rows += live.len() as u64;
        self.batch.last_occupancy = live.len() as u64;
        let mut tstats: Vec<TokenStats> = vec![TokenStats::default(); live.len()];

        // embed every live session's token
        let mut xs: Vec<Tensor> = Vec::with_capacity(live.len());
        for &i in &live {
            let sid = sessions[i].id;
            let span = self.timeline.compute(self.cost.profile.launch_overhead_s, 0.0);
            self.tracer.record(SpanKind::Embed, span, sid, None, self.tick);
            xs.push(self.rt.embed(tokens[i], &self.lits.embed)?);
        }

        for l in 0..self.weights.cfg.n_layers {
            self.batch_layer_step(sessions, &live, l, &mut xs, &mut tstats)?;
        }

        // lm head + per-session finalization. Every token in the tick
        // completed together, so the tick's span is each token's latency
        // (see TokenStats::sim_s).
        let mut logits: Vec<Vec<f32>> = Vec::with_capacity(live.len());
        for (j, x) in xs.iter().enumerate() {
            let sid = sessions[live[j]].id;
            let span = self.timeline.compute(self.cost.lm_head_compute_s(), 0.0);
            self.tracer.record(SpanKind::LmHead, span, sid, None, self.tick);
            logits.push(self.rt.lm_head(x, &self.lits.final_ln, &self.lits.lm_head)?.data);
        }
        let sim_s = self.timeline.now() - sim_start;
        let wall_s = wall_start.elapsed().as_secs_f64();
        for ((&i, mut ts), row) in live.iter().zip(tstats).zip(logits) {
            let sess = &mut *sessions[i];
            sess.pos += 1;
            sess.token_counter += 1;
            ts.sim_s = sim_s;
            ts.wall_s = wall_s;
            sess.run.sim_total_scaled_s += self.cost.scale_token_time(sim_s);
            sess.run.wall_total_s += wall_s;
            sess.run.tokens.push(ts);
            results[i] = Some(Ok(row));
        }
        Ok(results.into_iter().map(|r| r.expect("all slots filled")).collect())
    }

    /// One transformer layer of a batched tick: per-session attention +
    /// router (the same shared helper the sequential path uses), union
    /// expert resolve (one staging per distinct expert), once-per-tick
    /// speculation, one stacked kernel per expert, weighted accumulation
    /// back into each session's residual in that session's OWN selection
    /// order (f32 addition is order-sensitive — summing in union order
    /// would break bit-identity for top_k ≥ 3).
    ///
    /// Placement mirrors the sequential path's two modes: when the whole
    /// union fits the layer cache it is staged up front and pinned,
    /// letting speculation overlap the expert compute; when the union
    /// outgrows the cache — or the policy caches nothing (`OnDemand`,
    /// k = 0) — each expert is loaded and run in turn (every routed row
    /// in its one kernel call) with cache-less transients freed right
    /// after their kernel, so nothing must outlive its own staging and
    /// the device never holds more expert residency than the sequential
    /// path would.
    fn batch_layer_step(
        &mut self,
        sessions: &mut [&mut Session],
        live: &[usize],
        l: usize,
        xs: &mut [Tensor],
        tstats: &mut [TokenStats],
    ) -> Result<()> {
        let d = self.weights.cfg.d_model;
        let e_count = self.weights.cfg.n_experts;
        let n_live = live.len();
        // live-order session ids for span attribution of shared work
        let sids: Vec<u64> = live.iter().map(|&i| sessions[i].id).collect();

        // 1) attention + router per session — T = 1 kernels on the
        // session's own KV and residual, bit-identical to layer_step
        let mut hs: Vec<Tensor> = Vec::with_capacity(n_live);
        let mut sels: Vec<Vec<usize>> = Vec::with_capacity(n_live);
        let mut ws: Vec<Vec<f32>> = Vec::with_capacity(n_live);
        for (j, &i) in live.iter().enumerate() {
            let sess = &mut *sessions[i];
            let (x, h, selected, sel_w) = self.attn_route(sess, l, &xs[j])?;
            xs[j] = x;
            hs.push(h);
            sels.push(selected);
            ws.push(sel_w);
        }
        // shared tick work (naive streams, stacked kernels, batch
        // speculation) is attributed to the first participant, matching
        // the TokenStats convention; stage_for_batch refines per staging
        self.span_sess = sids[0];

        // 2) the union of routed experts, in first-appearance (batch)
        // order — the tick's dedup ledger
        let mut union: Vec<ExpertId> = Vec::new();
        let mut routed_pairs = 0u64;
        for sel in &sels {
            for &e in sel {
                routed_pairs += 1;
                let id = ExpertId::new(l, e);
                if !union.contains(&id) {
                    union.push(id);
                }
            }
        }
        self.batch.experts_resolved += union.len() as u64;
        self.batch.loads_deduped += routed_pairs - union.len() as u64;

        // 3) placement + one stacked kernel per expert. `outs[u]` holds
        // the union's u-th expert output rows and which sessions they
        // belong to; accumulation into residuals happens afterwards, per
        // session, in selection order.
        let mut outs: Vec<(Tensor, Vec<usize>)> = Vec::with_capacity(union.len());
        let routed_of = |sels: &[Vec<usize>], e: usize| -> Vec<usize> {
            (0..n_live).filter(|&j| sels[j].contains(&e)).collect()
        };
        if matches!(self.policy, OffloadPolicy::Naive) {
            // accelerate-style whole-layer streaming — once per TICK
            // instead of once per session (the dedup also applies to the
            // naive baseline; attribution to the first participant, as
            // for every shared event)
            self.stream_layer_naive(l, &mut tstats[0])?;
            for &id in &union {
                let routed = routed_of(&sels, id.expert as usize);
                self.span_sess = sids[routed[0]];
                let out = self.run_expert_stacked(id, &hs, &routed)?;
                outs.push((out, routed));
            }
        } else if !matches!(self.policy, OffloadPolicy::OnDemand)
            && self.cache.cache_k() >= union.len()
        {
            // the whole union fits the layer cache: stage it up front —
            // PINNED, so nothing staged in this tick can be evicted
            // before a batch neighbor has consumed it — and let
            // speculation overlap the expert compute (paper §3.3)
            for &id in &union {
                self.stage_for_batch(id, &sels, &sids, tstats, true)?;
            }
            if matches!(self.policy, OffloadPolicy::Full { .. }) {
                self.span_sess = sids[0];
                self.speculate_batch(l, xs, tstats)?;
            }
            for &id in &union {
                let routed = routed_of(&sels, id.expert as usize);
                self.span_sess = sids[routed[0]];
                let out = self.run_expert_stacked(id, &hs, &routed)?;
                outs.push((out, routed));
            }
        } else {
            // union outgrows the cache (or the policy caches nothing):
            // load-then-use one expert at a time — each expert is
            // consumed by ALL its routed rows before the next staging
            // could displace it, so no pin (and no deferred device copy)
            // is needed. Cache-less transients are released immediately
            // after their kernel, so the device never holds more of the
            // union than the sequential path would (at most one
            // transient at a time vs. sequential's top_k). Speculation
            // fires post-compute, as sequential does in this mode.
            for &id in &union {
                self.stage_for_batch(id, &sels, &sids, tstats, false)?;
                let routed = routed_of(&sels, id.expert as usize);
                self.span_sess = sids[routed[0]];
                let out = self.run_expert_stacked(id, &hs, &routed)?;
                outs.push((out, routed));
                self.cache.release_transient(id);
            }
            if matches!(self.policy, OffloadPolicy::Full { .. }) {
                self.span_sess = sids[0];
                self.speculate_batch(l, xs, tstats)?;
            }
        }

        // tick over: release pins (settling deferred evictions) and the
        // k = 0 / naive transients
        self.cache.unpin_all();
        for e in 0..e_count {
            self.cache.release_transient(ExpertId::new(l, e));
        }

        // 4) weighted accumulation per session, in ITS selection order —
        // the exact f32 summation order of sequential layer_step
        for (j, x) in xs.iter_mut().enumerate() {
            let mut y = vec![0.0f32; d];
            for (&e, &w) in sels[j].iter().zip(&ws[j]) {
                let u = union
                    .iter()
                    .position(|id| id.expert as usize == e)
                    .expect("selected expert is in the union");
                let (out, routed) = &outs[u];
                let r = routed
                    .iter()
                    .position(|&s| s == j)
                    .expect("session is routed to its own selection");
                for (acc, v) in y.iter_mut().zip(out.row(r)) {
                    *acc += w * v;
                }
            }
            for (xi, yi) in x.data.iter_mut().zip(&y) {
                *xi += yi;
            }
        }
        Ok(())
    }

    /// Stage one distinct expert for a batched layer-tick and attribute
    /// the cache event: the first session (batch order) that routed to
    /// it gets the hit/spec-hit/miss, every other routed session records
    /// a shared consume ([`TokenStats::batch_shared_hits`]). `pin` makes
    /// the staging survive any eviction until
    /// [`CacheManager::unpin_all`] — the enforced invariant behind the
    /// staged-union mode (placement already guarantees staged experts
    /// aren't LRU victims while the union fits the cache; the pin keeps
    /// that true against future placement or eviction-path changes).
    fn stage_for_batch(
        &mut self,
        id: ExpertId,
        sels: &[Vec<usize>],
        sids: &[u64],
        tstats: &mut [TokenStats],
        pin: bool,
    ) -> Result<()> {
        let e = id.expert as usize;
        let owner = sels
            .iter()
            .position(|sel| sel.contains(&e))
            .expect("union member is routed by some session");
        self.span_sess = sids[owner];
        self.ensure_expert(id, &mut tstats[owner])?;
        if pin {
            self.cache.pin(id);
        }
        for (j, sel) in sels.iter().enumerate() {
            if j != owner && sel.contains(&e) {
                tstats[j].batch_shared_hits += 1;
            }
        }
        Ok(())
    }

    /// Run one resident expert over every routed row in a single kernel
    /// call (`routed` indexes into `hs`), charging the batched compute
    /// cost and counting the call.
    fn run_expert_stacked(
        &mut self,
        id: ExpertId,
        hs: &[Tensor],
        routed: &[usize],
    ) -> Result<Tensor> {
        let d = self.weights.cfg.d_model;
        let span = self
            .timeline
            .compute(self.cost.expert_compute_batched_s(routed.len()), 0.0);
        self.tracer.record(
            SpanKind::ExpertCompute,
            span,
            self.span_sess,
            Some(id.layer as usize),
            self.tick,
        );
        let (out, calls) = if routed.len() == 1 {
            (self.run_expert(id, &hs[routed[0]])?, 1)
        } else {
            let mut stacked = Vec::with_capacity(routed.len() * d);
            for &j in routed {
                stacked.extend_from_slice(hs[j].row(0));
            }
            let stacked = Tensor::new(stacked, vec![routed.len(), d])?;
            self.run_expert_rows(id, &stacked)?
        };
        self.batch.kernel_calls += calls;
        Ok(out)
    }

    // ---------------------------------------------------------------------
    // mixed ticks: prefill chunk fused into the batched decode lockstep
    // ---------------------------------------------------------------------

    /// One MIXED tick: advance every given decode session one token AND
    /// feed one prefill chunk of an admission-in-progress through the
    /// same layer-lockstep walk. Per layer, the chunk's needed experts
    /// and the decode batch's routed union are merged into ONE dedup
    /// ledger — one cache resolve and at most one transfer per distinct
    /// expert per layer-tick, and one stacked kernel per resident expert
    /// over the chunk's routed rows plus the decode rows together, so
    /// the decode rows ride the experts the chunk was going to load
    /// anyway (and vice versa). This is the scheduling move that removes
    /// synchronous prefill's head-of-line blocking without paying the
    /// chunk's expert traffic twice.
    ///
    /// Like [`Self::decode_batch`] this is a pure execution-order/dedup
    /// optimization: decode logits are bit-identical to a chunk-less
    /// tick (attention, routing and the row-parallel expert FFN depend
    /// only on each session's own state), and the chunk's logits/KV are
    /// bit-identical to a monolithic [`Self::prefill`] of the same
    /// positions (prefill is already chunk-reorderable for the same
    /// reason; the chunk's rows keep prefill's exact accumulation
    /// order). Only tick boundaries — and the virtual clock — move.
    ///
    /// Returns one [`BatchSlot`] per decode session plus the
    /// [`ChunkSlot`] when a chunk was submitted. The chunk's KV blocks
    /// are committed incrementally (this chunk's positions only), BEFORE
    /// any compute: a KV-dry chunk is refused with nothing fed and the
    /// decode batch proceeds alone that tick. `chunk: None` delegates to
    /// [`Self::decode_batch`] verbatim; an empty decode set runs the
    /// chunk as a plain resumable prefill step. The chunk length must
    /// not exceed the compiled prefill module width
    /// (`ModelConfig::prefill_chunk`) — the coordinator's planner clamps
    /// to it.
    pub fn step_mixed(
        &mut self,
        sessions: &mut [&mut Session],
        tokens: &[u32],
        chunk: Option<PrefillChunk<'_>>,
    ) -> Result<(Vec<BatchSlot>, Option<ChunkSlot>)> {
        // tick boundary: no pins held, nothing staged mid-layer (the
        // chunk-less delegate re-checks harmlessly — threshold-gated)
        self.maybe_adapt_tiers();
        let Some(PrefillChunk { sess: csess, tokens: ctoks }) = chunk else {
            return Ok((self.decode_batch(sessions, tokens)?, None));
        };
        if sessions.len() != tokens.len() {
            return Err(Error::Engine(format!(
                "step_mixed: {} sessions but {} tokens",
                sessions.len(),
                tokens.len()
            )));
        }
        let max_seq = self.weights.cfg.max_seq;
        let c = self.weights.cfg.prefill_chunk;
        // stateless chunk shape guards — a malformed chunk is refused
        // before anything commits, and the decode batch proceeds alone
        let shape_refusal = if ctoks.is_empty() {
            Some(Error::Engine("step_mixed: empty prefill chunk".into()))
        } else if ctoks.len() > c {
            Some(Error::Engine(format!(
                "prefill chunk of {} tokens exceeds the compiled chunk width {c}",
                ctoks.len()
            )))
        } else if csess.pos + ctoks.len() > max_seq {
            Some(Error::Engine("prompt exceeds max_seq".into()))
        } else {
            None
        };
        if let Some(e) = shape_refusal {
            let slots = self.decode_batch(sessions, tokens)?;
            return Ok((slots, Some(Err(e))));
        }
        if sessions.is_empty() {
            // nothing to fuse with: the chunk is a plain prefill step
            return Ok((Vec::new(), Some(self.prefill(csess, ctoks))));
        }

        // per-decode-session guards + KV commit FIRST (same as
        // decode_batch): under pool pressure the decode rows take their
        // blocks before the chunk may claim any — decode rows are never
        // starved to feed a prefill (the planner's contract)
        let mut results: Vec<Option<BatchSlot>> =
            (0..sessions.len()).map(|_| None).collect();
        let mut live: Vec<usize> = Vec::with_capacity(sessions.len());
        for i in 0..sessions.len() {
            let sess = &mut *sessions[i];
            if sess.pos >= max_seq {
                results[i] = Some(Err(Error::Engine(format!(
                    "sequence length {} exceeds max_seq {max_seq}",
                    sess.pos
                ))));
                continue;
            }
            let next = sess.pos + 1;
            match self.ensure_kv(sess, next) {
                Ok(()) => live.push(i),
                Err(e) => results[i] = Some(Err(e)),
            }
        }
        // the chunk's incremental KV commit comes AFTER the decode rows
        // took theirs; a KV-dry chunk is refused with nothing fed and
        // the decode batch proceeds alone this tick
        if let Err(e) = self.ensure_kv(csess, csess.pos + ctoks.len()) {
            if live.is_empty() {
                let slots = results
                    .into_iter()
                    .map(|r| r.expect("all slots filled"))
                    .collect();
                return Ok((slots, Some(Err(e))));
            }
            // the already-committed decode blocks make this re-run of
            // the guards a no-op — decode_batch produces the same slots
            let slots = self.decode_batch(sessions, tokens)?;
            return Ok((slots, Some(Err(e))));
        }
        if live.is_empty() {
            // every decode slot refused pre-compute; the chunk still runs
            let slots = results
                .into_iter()
                .map(|r| r.expect("all slots filled"))
                .collect();
            return Ok((slots, Some(self.prefill(csess, ctoks))));
        }

        self.tick += 1;
        let sim_start = self.timeline.now();
        let wall_start = Instant::now();
        let n_valid = ctoks.len();
        self.batch.mixed_ticks += 1;
        self.batch.rows += live.len() as u64;
        self.batch.prefill_rows += n_valid as u64;
        self.batch.last_occupancy = live.len() as u64;
        let mut tstats: Vec<TokenStats> = vec![TokenStats::default(); live.len()];
        // the chunk's cache events follow prefill's convention: they move
        // the virtual clock but are not pushed into per-token run stats
        let mut cstats = TokenStats::default();

        // decode embeds (charged per row, as decode_batch does)
        let mut xs: Vec<Tensor> = Vec::with_capacity(live.len());
        for &i in &live {
            let sid = sessions[i].id;
            let span = self.timeline.compute(self.cost.profile.launch_overhead_s, 0.0);
            self.tracer.record(SpanKind::Embed, span, sid, None, self.tick);
            xs.push(self.rt.embed(tokens[i], &self.lits.embed)?);
        }
        // chunk embed: host-side gather padded with token 0, exactly as
        // prefill's (uncharged there, uncharged here)
        let d = self.weights.cfg.d_model;
        let mut xdata = vec![0.0f32; c * d];
        for t in 0..c {
            let tok = if t < n_valid { ctoks[t] as usize } else { 0 };
            xdata[t * d..(t + 1) * d].copy_from_slice(self.weights.embed.row(tok));
        }
        let mut cx = Tensor::new(xdata, vec![c, d])?;

        for l in 0..self.weights.cfg.n_layers {
            cx = self.mixed_layer_step(
                sessions, &live, l, &mut xs, &mut tstats, csess, cx, n_valid, &mut cstats,
            )?;
        }

        // decode lm heads + finalization (as decode_batch)
        let mut logits: Vec<Vec<f32>> = Vec::with_capacity(live.len());
        for (j, x) in xs.iter().enumerate() {
            let sid = sessions[live[j]].id;
            let span = self.timeline.compute(self.cost.lm_head_compute_s(), 0.0);
            self.tracer.record(SpanKind::LmHead, span, sid, None, self.tick);
            logits.push(self.rt.lm_head(x, &self.lits.final_ln, &self.lits.lm_head)?.data);
        }
        // chunk lm head over the whole padded chunk (as prefill)
        let span = self.timeline.compute(self.cost.lm_head_compute_s(), 0.0);
        self.tracer
            .record(SpanKind::LmHead, span, csess.id, None, self.tick);
        let clog = self.rt.lm_head(&cx, &self.lits.final_ln, &self.lits.lm_head)?;
        let vocab = self.weights.cfg.vocab_size;
        let mut chunk_logits: Vec<f32> = Vec::with_capacity(n_valid * vocab);
        for t in 0..n_valid {
            chunk_logits.extend_from_slice(clog.row(t));
        }

        let sim_s = self.timeline.now() - sim_start;
        let wall_s = wall_start.elapsed().as_secs_f64();
        for ((&i, mut ts), row) in live.iter().zip(tstats).zip(logits) {
            let sess = &mut *sessions[i];
            sess.pos += 1;
            sess.token_counter += 1;
            ts.sim_s = sim_s;
            ts.wall_s = wall_s;
            sess.run.sim_total_scaled_s += self.cost.scale_token_time(sim_s);
            sess.run.wall_total_s += wall_s;
            sess.run.tokens.push(ts);
            results[i] = Some(Ok(row));
        }
        // chunk finalization (as prefill: position, trace counter, the
        // prefill share of run stats — the tick completes together, so
        // the tick's span is the chunk's latency too)
        csess.pos += n_valid;
        csess.token_counter += n_valid;
        csess.run.prefill_sim_s += sim_s;
        csess.run.prefill_tokens += n_valid;
        // the chunk's cache events moved the clock without entering
        // per-token stats; their stall/transfer share still belongs to
        // the admission's prefill breakdown
        csess.run.prefill_stall_s += cstats.stall_s;
        csess.run.prefill_transfer_s += cstats.transfer_s;
        let slots = results
            .into_iter()
            .map(|r| r.expect("all slots filled"))
            .collect();
        Ok((
            slots,
            Some(Tensor::new(chunk_logits, vec![n_valid, vocab])),
        ))
    }

    /// One transformer layer of a mixed tick: per-decode-session
    /// attention + routing and the chunk's prefill attention + per-row
    /// routing (both via the exact code paths the unfused walks use),
    /// then ONE merged dedup ledger — the decode union plus the chunk's
    /// needed set — resolved against the cache once per distinct expert,
    /// and one stacked kernel per resident expert over chunk rows +
    /// decode rows together. Accumulation preserves each path's own f32
    /// summation order (chunk rows: ascending expert id, as
    /// `prefill_layer`; decode rows: the session's own top-k order, as
    /// `batch_layer_step`), which is what keeps both bit-identity
    /// contracts intact. Placement mirrors the batched tick's two modes
    /// (staged-and-pinned union vs load-then-use interleave); the
    /// chunk's wide needed set usually forces the interleave, exactly
    /// like a standalone prefill layer. Speculation stays decode-only
    /// (prefill never speculates), fired once per layer-tick on the
    /// batch-aggregated gate distribution.
    #[allow(clippy::too_many_arguments)]
    fn mixed_layer_step(
        &mut self,
        sessions: &mut [&mut Session],
        live: &[usize],
        l: usize,
        xs: &mut [Tensor],
        tstats: &mut [TokenStats],
        csess: &mut Session,
        cx: Tensor,
        n_valid: usize,
        cstats: &mut TokenStats,
    ) -> Result<Tensor> {
        let d = self.weights.cfg.d_model;
        let e_count = self.weights.cfg.n_experts;
        let n_live = live.len();
        // live-order session ids for span attribution of shared work
        let sids: Vec<u64> = live.iter().map(|&i| sessions[i].id).collect();

        // 1) decode attention + routing — bit-identical to batch_layer_step
        let mut hs: Vec<Tensor> = Vec::with_capacity(n_live);
        let mut sels: Vec<Vec<usize>> = Vec::with_capacity(n_live);
        let mut ws: Vec<Vec<f32>> = Vec::with_capacity(n_live);
        for (j, &i) in live.iter().enumerate() {
            let sess = &mut *sessions[i];
            let (x, h, selected, sel_w) = self.attn_route(sess, l, &xs[j])?;
            xs[j] = x;
            hs.push(h);
            sels.push(selected);
            ws.push(sel_w);
        }

        // 2) chunk attention + per-row routing — bit-identical to
        // prefill_layer's front half
        let span = self.timeline.compute(self.cost.attn_compute_s(), 0.0);
        self.tracer
            .record(SpanKind::Attention, span, csess.id, Some(l), self.tick);
        let (cx, kc, vc) = {
            let (k_ref, v_ref) = csess.kv.layer_or(l, &self.lits.zero_kv)?;
            self.rt.prefill_attn(&cx, &self.lits.layers[l], k_ref, v_ref, csess.pos)?
        };
        csess.kv.set_layer(l, kc, vc)?;
        let span = self.timeline.compute(self.cost.gate_compute_s(), 0.0);
        self.tracer
            .record(SpanKind::Gate, span, csess.id, Some(l), self.tick);
        let (gate_logits, ch) = self.rt.gate(&cx, &self.lits.layers[l])?;
        let mut cweights = vec![0.0f32; cx.shape[0] * e_count];
        let mut needed: Vec<usize> = Vec::new();
        for t in 0..n_valid {
            let mut probs = gate_logits.row(t).to_vec();
            softmax(&mut probs);
            let sel = top_k(&probs, self.weights.cfg.top_k);
            let wsum: f32 = sel.iter().map(|&e| probs[e]).sum();
            for &e in &sel {
                cweights[t * e_count + e] = probs[e] / wsum.max(1e-12);
                if !needed.contains(&e) {
                    needed.push(e);
                }
            }
            self.trace.record(ActivationRecord {
                session: csess.id,
                token_index: csess.token_counter + t,
                layer: l,
                probs,
                selected: sel,
                cached_before: self.cache.cached_of_layer(l),
            });
        }
        needed.sort();

        // 3) the tick's merged dedup ledger: decode (session, expert)
        // pairs in batch order, then the chunk's needed set — each
        // distinct expert is resolved against the cache exactly once
        let mut union: Vec<ExpertId> = Vec::new();
        let mut routed_units = 0u64;
        for sel in &sels {
            for &e in sel {
                routed_units += 1;
                let id = ExpertId::new(l, e);
                if !union.contains(&id) {
                    union.push(id);
                }
            }
        }
        for &e in &needed {
            routed_units += 1;
            let id = ExpertId::new(l, e);
            if !union.contains(&id) {
                union.push(id);
            }
        }
        self.batch.experts_resolved += union.len() as u64;
        self.batch.loads_deduped += routed_units - union.len() as u64;

        // the stacked row set of one expert: the chunk's routed rows
        // (ascending position), then the decode rows (batch order)
        let stacked_rows = |cweights: &[f32], sels: &[Vec<usize>], e: usize| -> Vec<MixedRow> {
            let mut rows: Vec<MixedRow> = (0..n_valid)
                .filter(|&t| cweights[t * e_count + e] > 0.0)
                .map(MixedRow::Chunk)
                .collect();
            rows.extend(
                (0..n_live)
                    .filter(|&j| sels[j].contains(&e))
                    .map(MixedRow::Decode),
            );
            rows
        };

        // 4) placement + one stacked kernel per distinct expert —
        // the batched tick's two modes, chunk rows riding along
        // span attribution for a shared mixed kernel: the first stacked
        // row's owner (chunk rows lead, so a chunk-routed expert's
        // kernel lands on the admission's track)
        let kernel_owner = |rows: &[MixedRow], sids: &[u64], csid: u64| match rows.first() {
            Some(MixedRow::Chunk(_)) | None => csid,
            Some(MixedRow::Decode(j)) => sids[*j],
        };
        let mut outs: Vec<(Tensor, Vec<MixedRow>)> = Vec::with_capacity(union.len());
        if matches!(self.policy, OffloadPolicy::Naive) {
            // whole-layer streaming once per TICK (chunk included)
            self.span_sess = sids[0];
            self.stream_layer_naive(l, &mut tstats[0])?;
            for &id in &union {
                let rows = stacked_rows(&cweights, &sels, id.expert as usize);
                self.span_sess = kernel_owner(&rows, &sids, csess.id);
                let out = self.run_expert_mixed(id, &ch, &hs, &rows)?;
                outs.push((out, rows));
            }
        } else if !matches!(self.policy, OffloadPolicy::OnDemand)
            && self.cache.cache_k() >= union.len()
        {
            // the whole merged union fits the layer cache: stage it up
            // front PINNED, speculation overlaps the expert compute
            for &id in &union {
                self.stage_for_mixed(id, &needed, &sels, &sids, csess.id, tstats, cstats, true)?;
            }
            if matches!(self.policy, OffloadPolicy::Full { .. }) {
                self.span_sess = sids[0];
                self.speculate_batch(l, xs, tstats)?;
            }
            for &id in &union {
                let rows = stacked_rows(&cweights, &sels, id.expert as usize);
                self.span_sess = kernel_owner(&rows, &sids, csess.id);
                let out = self.run_expert_mixed(id, &ch, &hs, &rows)?;
                outs.push((out, rows));
            }
        } else {
            // union outgrows the cache (the common case — a chunk's
            // needed set is wide): load-then-use one expert at a time,
            // every routed row in its one kernel call, transients freed
            // right after — the standalone prefill layer's interleave,
            // now shared with the decode rows
            for &id in &union {
                self.stage_for_mixed(id, &needed, &sels, &sids, csess.id, tstats, cstats, false)?;
                let rows = stacked_rows(&cweights, &sels, id.expert as usize);
                self.span_sess = kernel_owner(&rows, &sids, csess.id);
                let out = self.run_expert_mixed(id, &ch, &hs, &rows)?;
                outs.push((out, rows));
                self.cache.release_transient(id);
            }
            if matches!(self.policy, OffloadPolicy::Full { .. }) {
                self.span_sess = sids[0];
                self.speculate_batch(l, xs, tstats)?;
            }
        }
        self.cache.unpin_all();
        for e in 0..e_count {
            self.cache.release_transient(ExpertId::new(l, e));
        }

        // 5) chunk accumulation — prefill_layer's exact f32 order:
        // experts ascending, each adding its weighted rows
        let mut cy = vec![0.0f32; cx.shape[0] * d];
        for &e in &needed {
            let u = union
                .iter()
                .position(|id| id.expert as usize == e)
                .expect("needed expert is in the union");
            let (out, rows) = &outs[u];
            for t in 0..n_valid {
                let w = cweights[t * e_count + e];
                if w > 0.0 {
                    let r = rows
                        .iter()
                        .position(|&row| row == MixedRow::Chunk(t))
                        .expect("routed chunk row is stacked");
                    let orow = out.row(r);
                    for i in 0..d {
                        cy[t * d + i] += w * orow[i];
                    }
                }
            }
        }
        // 6) decode accumulation — each session in ITS selection order
        for (j, x) in xs.iter_mut().enumerate() {
            let mut y = vec![0.0f32; d];
            for (&e, &w) in sels[j].iter().zip(&ws[j]) {
                let u = union
                    .iter()
                    .position(|id| id.expert as usize == e)
                    .expect("selected expert is in the union");
                let (out, rows) = &outs[u];
                let r = rows
                    .iter()
                    .position(|&row| row == MixedRow::Decode(j))
                    .expect("session is routed to its own selection");
                for (acc, v) in y.iter_mut().zip(out.row(r)) {
                    *acc += w * v;
                }
            }
            for (xi, yi) in x.data.iter_mut().zip(&y) {
                *xi += yi;
            }
        }
        // 7) chunk residual (padded rows stay untouched, as prefill)
        let mut out_cx = cx;
        for (xi, yi) in out_cx.data.iter_mut().zip(&cy) {
            *xi += yi;
        }
        Ok(out_cx)
    }

    /// Stage one distinct expert for a mixed layer-tick. Ownership runs
    /// chunk-first — the narrative of the mixed tick is decode rows
    /// riding the experts the chunk was going to load anyway — so when
    /// the chunk needs the expert, the cache event lands in the chunk's
    /// (prefill-convention, clock-only) stats and every routed decode
    /// session records a shared consume; an expert only decode rows
    /// need is attributed like a plain batched staging.
    #[allow(clippy::too_many_arguments)]
    fn stage_for_mixed(
        &mut self,
        id: ExpertId,
        needed: &[usize],
        sels: &[Vec<usize>],
        sids: &[u64],
        csid: u64,
        tstats: &mut [TokenStats],
        cstats: &mut TokenStats,
        pin: bool,
    ) -> Result<()> {
        let e = id.expert as usize;
        let chunk_owns = needed.contains(&e);
        let dec_owner = if chunk_owns {
            None
        } else {
            sels.iter().position(|sel| sel.contains(&e))
        };
        {
            self.span_sess = match dec_owner {
                Some(j) => sids[j],
                None => csid,
            };
            let owner: &mut TokenStats = match dec_owner {
                Some(j) => &mut tstats[j],
                None => cstats,
            };
            self.ensure_expert(id, owner)?;
        }
        if pin {
            self.cache.pin(id);
        }
        for (j, sel) in sels.iter().enumerate() {
            if dec_owner != Some(j) && sel.contains(&e) {
                tstats[j].batch_shared_hits += 1;
            }
        }
        Ok(())
    }

    /// Run one resident expert over a mixed tick's stacked rows — chunk
    /// rows drawn from the chunk's normed hidden state `ch: [C, D]`,
    /// decode rows from the per-session `hs` — in ONE kernel call,
    /// charging the mixed-tick compute term (weights read once for the
    /// whole stack).
    fn run_expert_mixed(
        &mut self,
        id: ExpertId,
        ch: &Tensor,
        hs: &[Tensor],
        rows: &[MixedRow],
    ) -> Result<Tensor> {
        let d = self.weights.cfg.d_model;
        let n_chunk = rows
            .iter()
            .filter(|r| matches!(r, MixedRow::Chunk(_)))
            .count();
        let span = self.timeline.compute(
            self.cost.expert_compute_mixed_s(n_chunk, rows.len() - n_chunk),
            0.0,
        );
        self.tracer.record(
            SpanKind::ExpertCompute,
            span,
            self.span_sess,
            Some(id.layer as usize),
            self.tick,
        );
        let (out, calls) = match rows {
            [MixedRow::Decode(j)] => (self.run_expert(id, &hs[*j])?, 1),
            [MixedRow::Chunk(t)] => {
                let h = Tensor::new(ch.row(*t).to_vec(), vec![1, d])?;
                (self.run_expert(id, &h)?, 1)
            }
            _ => {
                let mut stacked = Vec::with_capacity(rows.len() * d);
                for row in rows {
                    match *row {
                        MixedRow::Chunk(t) => stacked.extend_from_slice(ch.row(t)),
                        MixedRow::Decode(j) => stacked.extend_from_slice(hs[j].row(0)),
                    }
                }
                let stacked = Tensor::new(stacked, vec![rows.len(), d])?;
                self.run_expert_rows(id, &stacked)?
            }
        };
        self.batch.kernel_calls += calls;
        Ok(out)
    }

    /// Attention + router for ONE session at layer `l` on a [1, D]
    /// residual — the shared front half of both the sequential
    /// [`Self::layer_step`] and the batched [`Self::batch_layer_step`],
    /// extracted so the two paths cannot drift apart numerically (the
    /// batched path's bit-identity contract rides on this block being
    /// the same code). Returns the post-attention residual, the normed
    /// hidden state, the selected experts and their renormalized top-k
    /// weights, and records the activation trace.
    ///
    /// Attention weights are borrowed in place — no per-layer copies on
    /// the hot path (see EXPERIMENTS.md §Perf). Virgin layers read the
    /// shared zero template — bit-identical to a freshly zeroed cache
    /// since the position mask hides everything at and beyond pos.
    fn attn_route(
        &mut self,
        sess: &mut Session,
        l: usize,
        x: &Tensor,
    ) -> Result<(Tensor, Tensor, Vec<usize>, Vec<f32>)> {
        self.span_sess = sess.id;
        let span = self.timeline.compute(self.cost.attn_compute_s(), 0.0);
        self.tracer
            .record(SpanKind::Attention, span, sess.id, Some(l), self.tick);
        let (x, kc, vc) = {
            let (k_ref, v_ref) = sess.kv.layer_or(l, &self.lits.zero_kv)?;
            self.rt.attn(x, &self.lits.layers[l], k_ref, v_ref, sess.pos)?
        };
        sess.kv.set_layer(l, kc, vc)?;

        // router
        let span = self.timeline.compute(self.cost.gate_compute_s(), 0.0);
        self.tracer
            .record(SpanKind::Gate, span, sess.id, Some(l), self.tick);
        let (gate_logits, h) = self.rt.gate(&x, &self.lits.layers[l])?;
        let mut probs = gate_logits.row(0).to_vec();
        softmax(&mut probs);
        let selected = top_k(&probs, self.weights.cfg.top_k);
        let mut sel_w: Vec<f32> = selected.iter().map(|&e| probs[e]).collect();
        let wsum: f32 = sel_w.iter().sum();
        for w in &mut sel_w {
            *w /= wsum.max(1e-12);
        }

        self.trace.record(ActivationRecord {
            session: sess.id,
            token_index: sess.token_counter,
            layer: l,
            probs,
            selected: selected.clone(),
            cached_before: self.cache.cached_of_layer(l),
        });
        Ok((x, h, selected, sel_w))
    }

    /// One transformer layer on a [1, D] residual.
    fn layer_step(
        &mut self,
        sess: &mut Session,
        l: usize,
        x: Tensor,
        tstats: &mut TokenStats,
    ) -> Result<Tensor> {
        let (x, h, selected, sel_w) = self.attn_route(sess, l, &x)?;

        // Fig2R probe: speculative gate distributions at several
        // look-aheads (measurement only — no timeline cost)
        if let Some(probe) = self.spec_probe.take() {
            let mut probe = probe;
            for &a in &probe.aheads.clone() {
                if l + a < self.weights.cfg.n_layers {
                    let (sl, _) = self.rt.gate(&x, &self.lits.layers[l + a])?;
                    let mut sp = sl.row(0).to_vec();
                    softmax(&mut sp);
                    probe.records.push((sess.token_counter, l, a, sp));
                }
            }
            self.spec_probe = Some(probe);
        }

        // expert placement per policy
        let ids: Vec<ExpertId> = selected.iter().map(|&e| ExpertId::new(l, e)).collect();
        match self.policy {
            OffloadPolicy::Naive => {
                // accelerate-style: synchronously stream the WHOLE MoE
                // layer through the device, then compute.
                self.stream_layer_naive(l, tstats)?;
            }
            _ => {
                // with k >= top_k the whole selection fits the layer cache,
                // so load everything first (lets speculation overlap the
                // expert compute, as in the paper). With smaller k, loading
                // expert B could evict expert A before it runs — interleave
                // load/use instead (speculation then fires post-compute).
                if self.cache.cache_k() >= ids.len()
                    || matches!(self.policy, OffloadPolicy::OnDemand)
                {
                    for &id in &ids {
                        self.ensure_expert(id, tstats)?;
                    }
                    // speculative pre-loading fires after the current
                    // layer's experts finished loading (paper §3.3)
                    if matches!(self.policy, OffloadPolicy::Full { .. }) {
                        self.speculate(l, &x, tstats)?;
                    }
                }
            }
        }

        // expert compute + mix
        let interleaved = !matches!(self.policy, OffloadPolicy::Naive | OffloadPolicy::OnDemand)
            && self.cache.cache_k() < ids.len();
        let mut y = vec![0.0f32; self.weights.cfg.d_model];
        for (&e, &w) in selected.iter().zip(&sel_w) {
            let id = ExpertId::new(l, e);
            if interleaved {
                self.ensure_expert(id, tstats)?;
            }
            let span = self.timeline.compute(self.cost.expert_compute_s(), 0.0);
            self.tracer
                .record(SpanKind::ExpertCompute, span, sess.id, Some(l), self.tick);
            let out = self.run_expert(id, &h)?;
            for (acc, v) in y.iter_mut().zip(&out.data) {
                *acc += w * v;
            }
        }
        if interleaved && matches!(self.policy, OffloadPolicy::Full { .. }) {
            self.speculate(l, &x, tstats)?;
        }
        // transient release (k = 0 policies) — selected + naive extras
        for e in 0..self.weights.cfg.n_experts {
            self.cache.release_transient(ExpertId::new(l, e));
        }

        let mut out = x;
        for (xi, yi) in out.data.iter_mut().zip(&y) {
            *xi += yi;
        }
        Ok(out)
    }

    /// Naive-offloading transfer pass: synchronously stream EVERY expert
    /// of layer `l` through the device (accelerate-style), charging the
    /// link and the caller's stats. Shared by the sequential Naive arm
    /// (once per session) and the batched tick (once per tick).
    fn stream_layer_naive(&mut self, l: usize, tstats: &mut TokenStats) -> Result<()> {
        for e in 0..self.weights.cfg.n_experts {
            let id = ExpertId::new(l, e);
            let (t_s, t_bytes) = self.expert_stage_cost(id);
            if self.obs.is_enabled() {
                let tier = self.weights.experts.tier_of(id);
                self.obs.on_wire(id, tier, t_bytes);
            }
            let t_s = self.fault_transfer_s(t_s, l);
            let span = self.timeline.transfer(t_s, self.timeline.now());
            self.tracer
                .record(SpanKind::DemandLoad, span, self.span_sess, Some(l), self.tick);
            let before = self.timeline.now();
            self.timeline.wait_until(span.end);
            tstats.stall_s += self.timeline.now() - before;
            tstats.transfer_s += t_s;
            tstats.bytes_transferred += t_bytes;
            let de = self.stage_verified(id, t_s, l)?;
            self.cache.insert_loaded(id, de)?;
            tstats.misses += 1;
        }
        self.obs_drain();
        Ok(())
    }

    /// Link price of staging `id` RIGHT NOW: (seconds, bytes) at the
    /// expert's current tier. Uniform pools short-circuit to the
    /// pre-tier constants. Also accrues the tier byte accounting — call
    /// exactly once per transfer actually issued.
    fn expert_stage_cost(&mut self, id: ExpertId) -> (f64, u64) {
        let (t_s, t_bytes) = match self.tier_policy {
            None => (self.cost.expert_transfer_s(), self.cost.expert_wire_bytes),
            Some(_) => {
                let scheme = self
                    .weights
                    .experts
                    .scheme_of_tier(self.weights.experts.tier_of(id));
                let bytes = self.cost.wire_bytes_of(scheme);
                (self.cost.transfer_s_for(bytes), bytes)
            }
        };
        self.tiers.uniform_bytes += self.cost.expert_wire_bytes;
        self.tiers.actual_bytes += t_bytes;
        (t_s, t_bytes)
    }

    /// Apply the fault plan to one expert-staging transfer of `t_s`
    /// seconds: the retry run from [`FaultInjector::transfer`] (failed
    /// attempts + exponential backoff) burns link time ahead of the real
    /// copy as a [`SpanKind::FaultRetry`] span — the real transfer then
    /// queues behind it, so a blocking demand load stalls through the
    /// recovery too while a speculative prefetch merely lands later.
    /// Returns the duration of the eventually-successful attempt
    /// (brownout episodes stretch it). With faults off: `t_s`, no draws.
    fn fault_transfer_s(&mut self, t_s: f64, layer: usize) -> f64 {
        if !self.faults.enabled() {
            return t_s;
        }
        let out = self.faults.transfer(t_s);
        if out.extra_s > 0.0 {
            let span = self.timeline.transfer(out.extra_s, self.timeline.now());
            self.tracer
                .record(SpanKind::FaultRetry, span, self.span_sess, Some(layer), self.tick);
        }
        t_s * out.slowdown
    }

    /// Run `id` through the copy engine and, when faults are enabled,
    /// verify the staged payload against the pool's build-time checksum
    /// — with the injector deciding whether this copy "read" corrupt. A
    /// corrupt read re-stages (the host-side source is intact, so the
    /// re-read comes back clean), charging the re-copy + backoff to the
    /// link as a [`SpanKind::FaultRetry`] span that blocks the demand
    /// front. The loop is bounded by the retry budget purely as a
    /// belt-and-braces against `corrupt_p = 1` plans.
    fn stage_verified(&mut self, id: ExpertId, t_s: f64, layer: usize) -> Result<DeviceExpert> {
        let ticket = self.copy.submit(id)?;
        let (_, mut de) = self.copy.wait(ticket)?;
        if !self.faults.enabled() {
            return Ok(de);
        }
        let mut restage = 0;
        while restage < self.faults.max_retries() && !self.staged_copy_clean(id) {
            let cost = self.faults.restage_cost_s(t_s, restage);
            let span = self.timeline.transfer(cost, self.timeline.now());
            self.tracer
                .record(SpanKind::FaultRetry, span, self.span_sess, Some(layer), self.tick);
            self.timeline.wait_until(span.end);
            let ticket = self.copy.submit(id)?;
            de = self.copy.wait(ticket)?.1;
            restage += 1;
        }
        Ok(de)
    }

    /// Post-copy checksum verification: recompute the staged payload's
    /// checksum against the pool's build-time value, with the injector
    /// deciding whether this particular copy "read" corrupt. The injected
    /// draw happens FIRST so the fault stream advances identically
    /// whatever the real comparison says.
    fn staged_copy_clean(&mut self, id: ExpertId) -> bool {
        let injected = self.faults.corrupt();
        let pool = &self.weights.experts;
        let verified = match (pool.expected_checksum(id), pool.get(id)) {
            (Ok(want), Ok(host)) => host.payload_checksum() == want,
            _ => false,
        };
        !injected && verified
    }

    /// Online tier adaptation (see [`crate::quant::tier`]): every
    /// `adapt_interval` routed expert-uses, re-rank each layer's experts
    /// by their lifetime route counts and re-assign hot/cold tiers. A
    /// re-tiered expert whose resident copy holds a now-stale precision
    /// loses it immediately, so its next use re-stages at the new tier
    /// ([`Self::ensure_expert`]'s self-heal backstops in-flight
    /// speculative arrivals). Called at tick boundaries only — no pins
    /// are held there. No-op for uniform pools and `adaptive: false`.
    fn maybe_adapt_tiers(&mut self) {
        let Some(p) = self.tier_policy else { return };
        if !p.adaptive {
            return;
        }
        let counters = self.cache.expert_counters();
        let total: u64 = counters.iter().map(|(_, _, uses)| uses).sum();
        if total < self.tier_adapted_at_uses + p.adapt_interval {
            return;
        }
        self.tier_adapted_at_uses = total;
        let e_count = self.weights.cfg.n_experts;
        for l in 0..self.weights.cfg.n_layers {
            let mut scores = vec![0.0f64; e_count];
            for (id, _, uses) in &counters {
                if id.layer as usize == l {
                    scores[id.expert as usize] = *uses as f64;
                }
            }
            for (e, t) in assign_tiers(&scores, p.hot_fraction, p.cold_fraction)
                .into_iter()
                .enumerate()
            {
                let id = ExpertId::new(l, e);
                let prev = self.weights.experts.set_tier(id, t);
                if t == prev {
                    continue;
                }
                if t > prev {
                    self.tiers.promotions += 1;
                }
                // drop a resident copy only when its staged PRECISION
                // went stale — tier moves between same-scheme tiers
                // (e.g. hot scheme == base) change nothing on device,
                // and evicting would perturb behavior a uniform-scheme
                // policy must keep byte-identical to tiers-off
                let want = self.weights.experts.scheme_of_tier(t).bits() as u8;
                if self
                    .cache
                    .resident_bits_of(id)
                    .is_some_and(|have| have != want)
                {
                    self.cache.drop_expert(id);
                    self.expert_lits.remove(&id);
                    // the next miss on this expert is a re-tier reload,
                    // not a routing-driven demand load — tag it so
                    self.tier_reload_pending.insert(id);
                }
            }
        }
        self.obs_drain();
    }

    /// Make `id` resident, classifying hit / spec-hit / miss and advancing
    /// the virtual clock for any wait.
    fn ensure_expert(&mut self, id: ExpertId, tstats: &mut TokenStats) -> Result<()> {
        // claim an in-flight speculative transfer first
        if let Some(inf) = self.in_flight.remove(&id) {
            self.spec_queue.retain(|x| *x != id);
            let before = self.timeline.now();
            self.timeline.wait_until(inf.ready_at);
            tstats.stall_s += self.timeline.now() - before;
            let (_, de) = self.copy.wait(inf.ticket)?;
            self.cache.insert_speculative(id, de)?;
        }
        // tier self-heal: a copy staged BEFORE a re-tier (including the
        // speculative arrival claimed just above) is resident at a stale
        // precision — drop it so the use below re-stages at the
        // expert's current tier
        if self.tier_policy.is_some() {
            let want = self
                .weights
                .experts
                .scheme_of_tier(self.weights.experts.tier_of(id))
                .bits() as u8;
            if self
                .cache
                .resident_bits_of(id)
                .is_some_and(|have| have != want)
            {
                self.cache.drop_expert(id);
                self.expert_lits.remove(&id);
                self.tier_reload_pending.insert(id);
            }
        }
        match self.cache.on_demand_use(id) {
            CacheEvent::Hit(_) => {
                self.tier_reload_pending.remove(&id);
                tstats.cache_hits += 1;
                if self.tier_policy.is_some()
                    && self.weights.experts.tier_of(id) == Tier::Hot
                {
                    self.tiers.hot_hits += 1;
                }
            }
            CacheEvent::SpecHit(_) => {
                self.tier_reload_pending.remove(&id);
                tstats.spec_hits += 1;
            }
            CacheEvent::Miss(_) => {
                let reload = self.tier_reload_pending.remove(&id);
                let (t_s, t_bytes) = self.expert_stage_cost(id);
                if self.obs.is_enabled() {
                    let tier = self.weights.experts.tier_of(id);
                    self.obs.on_wire(id, tier, t_bytes);
                }
                let t_s = self.fault_transfer_s(t_s, id.layer as usize);
                let span = self.timeline.transfer(t_s, self.timeline.now());
                self.tracer.record(
                    if reload { SpanKind::TierReload } else { SpanKind::DemandLoad },
                    span,
                    self.span_sess,
                    Some(id.layer as usize),
                    self.tick,
                );
                let before = self.timeline.now();
                self.timeline.wait_until(span.end);
                tstats.stall_s += self.timeline.now() - before;
                tstats.transfer_s += t_s;
                tstats.bytes_transferred += t_bytes;
                tstats.misses += 1;
                let de = self.stage_verified(id, t_s, id.layer as usize)?;
                self.cache.insert_loaded(id, de)?;
            }
        }
        self.obs_drain();
        Ok(())
    }

    /// Marshal (and cache) a resident expert's literals on first use
    /// after each transfer.
    fn ensure_expert_lits(&mut self, id: ExpertId) -> Result<()> {
        if !self.expert_lits.contains_key(&id) {
            let de = self
                .cache
                .device
                .get(id)
                .ok_or_else(|| Error::Engine(format!("expert {id} not resident")))?;
            self.expert_lits.insert(id, ExpertLits::new(de)?);
            // prune entries whose experts were evicted since last sweep
            if self.expert_lits.len() > 2 * self.cache.device.resident_count() + 8 {
                let device = &self.cache.device;
                self.expert_lits.retain(|k, _| device.contains(*k));
            }
        }
        Ok(())
    }

    /// Run a resident expert on `h`.
    fn run_expert(&mut self, id: ExpertId, h: &Tensor) -> Result<Tensor> {
        self.ensure_expert_lits(id)?;
        let lits = &self.expert_lits[&id];
        self.rt.expert_with_lits(h, lits)
    }

    /// Run a resident expert once over stacked rows `h: [n, D]` (batched
    /// decode). Returns the `[n, D]` outputs and the kernel-call count.
    fn run_expert_rows(&mut self, id: ExpertId, h: &Tensor) -> Result<(Tensor, u64)> {
        self.ensure_expert_lits(id)?;
        let lits = &self.expert_lits[&id];
        self.rt.expert_rows_with_lits(h, lits)
    }

    /// §3.2: apply layer l+1's gate to layer l's (pre-MoE) hidden state and
    /// prefetch the best guesses.
    fn speculate(&mut self, l: usize, x: &Tensor, tstats: &mut TokenStats) -> Result<()> {
        let spec_n = self.policy.spec_n();
        if spec_n == 0 || l + 1 >= self.weights.cfg.n_layers {
            return Ok(());
        }
        // the extra gate evaluation costs GPU time
        let span = self.timeline.compute(self.cost.gate_compute_s(), 0.0);
        self.tracer
            .record(SpanKind::Gate, span, self.span_sess, Some(l + 1), self.tick);
        let (spec_logits, _) = self.rt.gate(x, &self.lits.layers[l + 1])?;
        let mut probs = spec_logits.row(0).to_vec();
        softmax(&mut probs);
        self.prefetch_top(l + 1, &probs, spec_n, tstats)
    }

    /// Issue speculative transfers for the top `spec_n` experts of
    /// `layer` under `probs` (shared by the sequential per-session
    /// [`Self::speculate`] and the batched once-per-tick
    /// [`Self::speculate_batch`]).
    fn prefetch_top(
        &mut self,
        layer: usize,
        probs: &[f32],
        spec_n: usize,
        tstats: &mut TokenStats,
    ) -> Result<()> {
        for &e in top_k(probs, spec_n).iter() {
            let id = ExpertId::new(layer, e);
            if self.in_flight.contains_key(&id)
                || self.cache.lookup(id) != crate::cache::manager::Lookup::Absent
            {
                continue;
            }
            // recycle the oldest unclaimed speculative buffer if full
            while self.spec_queue.len() >= self.staging_buffers {
                if let Some(old) = self.spec_queue.pop_front() {
                    if let Some(inf) = self.in_flight.remove(&old) {
                        let (_, de) = self.copy.wait(inf.ticket)?;
                        // arrived: park it in the manager's spec buffers
                        self.cache.insert_speculative(old, de)?;
                    }
                }
            }
            let (t_s, t_bytes) = self.expert_stage_cost(id);
            if self.obs.is_enabled() {
                let tier = self.weights.experts.tier_of(id);
                self.obs.on_wire(id, tier, t_bytes);
            }
            // speculative transfers ride the same faulty link: the retry
            // run delays this (and every later) transfer but never blocks
            // the decode front — the claim site waits on `span.end`
            let t_s = self.fault_transfer_s(t_s, layer);
            let span = self.timeline.transfer(t_s, self.timeline.now());
            // a speculative issue supersedes any pending re-tier reload
            self.tier_reload_pending.remove(&id);
            self.tracer.record(
                SpanKind::SpecPrefetch,
                span,
                self.span_sess,
                Some(layer),
                self.tick,
            );
            tstats.transfer_s += t_s;
            tstats.bytes_transferred += t_bytes;
            let ticket = self.copy.submit_speculative(id)?;
            self.in_flight.insert(id, InFlight { ticket, ready_at: span.end });
            self.spec_queue.push_back(id);
        }
        self.obs_drain();
        Ok(())
    }

    /// Batched speculation: ONE prefetch decision per layer-tick, on the
    /// batch-aggregated gate distribution, instead of one per session.
    /// Each session's l+1 gate is still evaluated (and charged) like the
    /// sequential path; their softmaxed distributions are averaged and
    /// the union prefetch is issued once — speculative link bandwidth
    /// follows the batch's consensus instead of being re-spent per
    /// stream. Transfer bytes are attributed to the batch's first
    /// participant (the transfers serve the whole batch; splitting them
    /// across stats rows would misread as N separate prefetches).
    fn speculate_batch(
        &mut self,
        l: usize,
        xs: &[Tensor],
        tstats: &mut [TokenStats],
    ) -> Result<()> {
        let spec_n = self.policy.spec_n();
        if spec_n == 0 || l + 1 >= self.weights.cfg.n_layers || xs.is_empty() {
            return Ok(());
        }
        let e_count = self.weights.cfg.n_experts;
        let mut agg = vec![0.0f32; e_count];
        for x in xs {
            let span = self.timeline.compute(self.cost.gate_compute_s(), 0.0);
            self.tracer
                .record(SpanKind::Gate, span, self.span_sess, Some(l + 1), self.tick);
            let (spec_logits, _) = self.rt.gate(x, &self.lits.layers[l + 1])?;
            let mut probs = spec_logits.row(0).to_vec();
            softmax(&mut probs);
            for (a, p) in agg.iter_mut().zip(&probs) {
                *a += p;
            }
        }
        for a in &mut agg {
            *a /= xs.len() as f32;
        }
        self.prefetch_top(l + 1, &agg, spec_n, &mut tstats[0])
    }

    // ---------------------------------------------------------------------
    // prefill
    // ---------------------------------------------------------------------

    /// Encode a prompt with chunked prefill; returns logits for every
    /// prompt position ([T, V]) for scoring / sampling the first token.
    pub fn prefill(&mut self, sess: &mut Session, tokens: &[u32]) -> Result<Tensor> {
        if tokens.is_empty() {
            return Err(Error::Engine("empty prompt".into()));
        }
        if sess.pos + tokens.len() > self.weights.cfg.max_seq {
            return Err(Error::Engine("prompt exceeds max_seq".into()));
        }
        // whole-prompt block commit, all-or-nothing (cold cached prefixes
        // are evicted first): a refused admission holds no blocks and the
        // request can be requeued untouched
        self.ensure_kv(sess, sess.pos + tokens.len())?;
        self.tick += 1;
        self.span_sess = sess.id;
        let sim_start = self.timeline.now();
        let c = self.weights.cfg.prefill_chunk;
        let d = self.weights.cfg.d_model;
        let mut all_logits: Vec<f32> = Vec::with_capacity(tokens.len() * self.weights.cfg.vocab_size);

        let mut done = 0;
        while done < tokens.len() {
            let n_valid = (tokens.len() - done).min(c);
            // embed chunk (gather in rust; pad with token 0)
            let mut xdata = vec![0.0f32; c * d];
            for t in 0..c {
                let tok = if t < n_valid { tokens[done + t] as usize } else { 0 };
                xdata[t * d..(t + 1) * d].copy_from_slice(self.weights.embed.row(tok));
            }
            let mut x = Tensor::new(xdata, vec![c, d])?;

            for l in 0..self.weights.cfg.n_layers {
                x = self.prefill_layer(sess, l, x, n_valid)?;
            }

            let span = self.timeline.compute(self.cost.lm_head_compute_s(), 0.0);
            self.tracer
                .record(SpanKind::LmHead, span, sess.id, None, self.tick);
            let logits = self.rt.lm_head(&x, &self.lits.final_ln, &self.lits.lm_head)?;
            for t in 0..n_valid {
                all_logits.extend_from_slice(logits.row(t));
            }
            sess.pos += n_valid;
            done += n_valid;
        }
        sess.run.prefill_sim_s += self.timeline.now() - sim_start;
        sess.run.prefill_tokens += tokens.len();
        Tensor::new(all_logits, vec![tokens.len(), self.weights.cfg.vocab_size])
    }

    fn prefill_layer(
        &mut self,
        sess: &mut Session,
        l: usize,
        x: Tensor,
        n_valid: usize,
    ) -> Result<Tensor> {
        let c = x.shape[0];
        let d = self.weights.cfg.d_model;

        let span = self.timeline.compute(self.cost.attn_compute_s(), 0.0);
        self.tracer
            .record(SpanKind::Attention, span, sess.id, Some(l), self.tick);
        let (x, kc, vc) = {
            let (k_ref, v_ref) = sess.kv.layer_or(l, &self.lits.zero_kv)?;
            self.rt.prefill_attn(&x, &self.lits.layers[l], k_ref, v_ref, sess.pos)?
        };
        sess.kv.set_layer(l, kc, vc)?;

        let span = self.timeline.compute(self.cost.gate_compute_s(), 0.0);
        self.tracer
            .record(SpanKind::Gate, span, sess.id, Some(l), self.tick);
        let (gate_logits, h) = self.rt.gate(&x, &self.lits.layers[l])?;

        // per-token routing; prefill loads each needed expert once
        let e_count = self.weights.cfg.n_experts;
        let mut weights = vec![0.0f32; c * e_count];
        let mut needed: Vec<usize> = Vec::new();
        for t in 0..n_valid {
            let mut probs = gate_logits.row(t).to_vec();
            softmax(&mut probs);
            let sel = top_k(&probs, self.weights.cfg.top_k);
            let wsum: f32 = sel.iter().map(|&e| probs[e]).sum();
            for &e in &sel {
                weights[t * e_count + e] = probs[e] / wsum.max(1e-12);
                if !needed.contains(&e) {
                    needed.push(e);
                }
            }
            self.trace.record(ActivationRecord {
                session: sess.id,
                token_index: sess.token_counter + t,
                layer: l,
                probs,
                selected: sel,
                cached_before: self.cache.cached_of_layer(l),
            });
        }
        needed.sort();

        // load-then-use one expert at a time: with small k, loading the
        // whole union first could evict an expert before it runs.
        let mut tstats = TokenStats::default();
        let mut y = vec![0.0f32; c * d];
        for &e in &needed {
            let id = ExpertId::new(l, e);
            self.ensure_expert(id, &mut tstats)?;
            let span = self.timeline.compute(self.cost.expert_compute_s(), 0.0);
            self.tracer
                .record(SpanKind::ExpertCompute, span, sess.id, Some(l), self.tick);
            let out = self.run_expert(id, &h)?;
            for t in 0..n_valid {
                let w = weights[t * e_count + e];
                if w > 0.0 {
                    for i in 0..d {
                        y[t * d + i] += w * out.data[t * d + i];
                    }
                }
            }
            self.cache.release_transient(id);
        }
        // roll the layer's expert staging costs into the request-level
        // prefill breakdown (the local tstats is otherwise discarded)
        sess.run.prefill_stall_s += tstats.stall_s;
        sess.run.prefill_transfer_s += tstats.transfer_s;

        let mut out = x;
        for (xi, yi) in out.data.iter_mut().zip(&y) {
            *xi += yi;
        }
        // advance token counter for trace indexing
        if l == self.weights.cfg.n_layers - 1 {
            sess.token_counter += n_valid;
        }
        Ok(out)
    }

    // ---------------------------------------------------------------------
    // generation
    // ---------------------------------------------------------------------

    /// Prefill the prompt, then sample `max_new` tokens.
    pub fn generate(
        &mut self,
        sess: &mut Session,
        prompt: &[u32],
        max_new: usize,
        sampler: &mut Sampler,
    ) -> Result<Vec<u32>> {
        let logits = self.prefill(sess, prompt)?;
        let mut next = sampler.sample(logits.row(prompt.len() - 1)) as u32;
        let mut out = vec![next];
        for _ in 1..max_new {
            if sess.pos >= self.weights.cfg.max_seq {
                break;
            }
            let logits = self.decode_step(sess, next)?;
            next = sampler.sample(&logits) as u32;
            out.push(next);
        }
        Ok(out)
    }

    /// Teacher-forced scoring: per-position log-prob of the actual next
    /// token (perplexity evaluation). Uses the prefill fast path.
    pub fn score(&mut self, sess: &mut Session, tokens: &[u32]) -> Result<Vec<f32>> {
        let logits = self.prefill(sess, tokens)?;
        let mut lps = Vec::with_capacity(tokens.len() - 1);
        for t in 0..tokens.len() - 1 {
            lps.push(crate::tensor::log_softmax_at(
                logits.row(t),
                tokens[t + 1] as usize,
            ));
        }
        Ok(lps)
    }
}
