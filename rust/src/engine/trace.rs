//! Expert-activation trace recorder — the data behind Figure 1 (activation
//! heatmap with LRU overlay) and the offline cache/speculation evaluations
//! (Figure 2).

use crate::util::json::Json;

/// One MoE-layer visit during decode/prefill of one token.
#[derive(Debug, Clone)]
pub struct ActivationRecord {
    /// Session the token belongs to — interleaved sessions share one
    /// recorder, so `token_index` is only meaningful per session.
    pub session: u64,
    pub token_index: usize,
    pub layer: usize,
    /// Full router softmax over experts.
    pub probs: Vec<f32>,
    /// Selected top-k experts (indices into probs).
    pub selected: Vec<usize>,
    /// Cache contents (expert indices, MRU first) *before* this token's
    /// demand loads — the gray squares of Fig 1.
    pub cached_before: Vec<u16>,
}

#[derive(Debug, Default)]
pub struct TraceRecorder {
    pub records: Vec<ActivationRecord>,
    pub enabled: bool,
}

impl TraceRecorder {
    pub fn new(enabled: bool) -> Self {
        TraceRecorder { records: Vec::new(), enabled }
    }

    pub fn record(&mut self, rec: ActivationRecord) {
        if self.enabled {
            self.records.push(rec);
        }
    }

    /// Router probability matrix for one layer: rows = tokens, cols =
    /// experts (Fig 1 heatmap data).
    pub fn layer_heatmap(&self, layer: usize) -> Vec<Vec<f32>> {
        self.records
            .iter()
            .filter(|r| r.layer == layer)
            .map(|r| r.probs.clone())
            .collect()
    }

    /// Sequence of selected expert sets for one layer, in token order
    /// (drives the offline LRU / speculation replays).
    pub fn layer_selections(&self, layer: usize) -> Vec<Vec<usize>> {
        self.records
            .iter()
            .filter(|r| r.layer == layer)
            .map(|r| r.selected.clone())
            .collect()
    }

    pub fn to_json(&self) -> Json {
        Json::arr(self.records.iter().map(|r| {
            Json::obj(vec![
                ("session", (r.session as usize).into()),
                ("token", r.token_index.into()),
                ("layer", r.layer.into()),
                (
                    "probs",
                    Json::arr(r.probs.iter().map(|&p| Json::Num(p as f64))),
                ),
                (
                    "selected",
                    Json::arr(r.selected.iter().map(|&e| Json::from(e))),
                ),
                (
                    "cached",
                    Json::arr(r.cached_before.iter().map(|&e| Json::from(e as usize))),
                ),
            ])
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(token: usize, layer: usize, sel: Vec<usize>) -> ActivationRecord {
        ActivationRecord {
            session: 1,
            token_index: token,
            layer,
            probs: vec![0.1; 4],
            selected: sel,
            cached_before: vec![0],
        }
    }

    #[test]
    fn disabled_recorder_drops() {
        let mut t = TraceRecorder::new(false);
        t.record(rec(0, 0, vec![1]));
        assert!(t.records.is_empty());
    }

    #[test]
    fn heatmap_filters_by_layer() {
        let mut t = TraceRecorder::new(true);
        t.record(rec(0, 0, vec![1]));
        t.record(rec(0, 1, vec![2]));
        t.record(rec(1, 0, vec![3]));
        assert_eq!(t.layer_heatmap(0).len(), 2);
        assert_eq!(t.layer_selections(1), vec![vec![2]]);
    }

    #[test]
    fn json_shape() {
        let mut t = TraceRecorder::new(true);
        t.record(rec(0, 2, vec![1, 3]));
        let j = t.to_json();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("layer").unwrap().as_usize(), Some(2));
    }
}
