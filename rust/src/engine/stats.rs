//! Per-token and per-run generation statistics: the simulated-time
//! breakdown Table 2 reports, plus wall-clock for the real CPU testbed.

#[derive(Debug, Clone, Default)]
pub struct TokenStats {
    /// Virtual seconds this token took (timeline delta, unscaled). For a
    /// token decoded by a batched layer-lockstep tick this is the WHOLE
    /// tick's span — every token in the batch completes together, so the
    /// tick duration is each token's latency.
    pub sim_s: f64,
    /// Host wall seconds (real PJRT execution on this machine). Batched
    /// ticks attribute the tick's wall span to every participating token
    /// (same rationale as `sim_s`).
    pub wall_s: f64,
    pub cache_hits: u64,
    pub spec_hits: u64,
    pub misses: u64,
    /// Expert stagings this token shared with batch neighbors: the
    /// expert was already resolved for this layer-tick by an earlier
    /// session in the batch, so this session consumed it without its own
    /// cache lookup or transfer. Counts toward [`RunStats::total_hits`]
    /// (the expert was resident when consumed); the staging session's
    /// own hit/miss is recorded in ITS stats, so summing misses across a
    /// batch still equals actual transfers.
    pub batch_shared_hits: u64,
    pub bytes_transferred: u64,
    /// Virtual seconds the decode front spent stalled on transfers.
    pub stall_s: f64,
    /// Virtual LINK seconds of expert transfers issued on this token's
    /// behalf (demand loads, re-tier reloads, and speculative prefetches
    /// it triggered). Unlike `stall_s` this counts the transfer's full
    /// duration whether or not compute hid it — `transfer_s - stall_s`
    /// is the overlap speculative loading won.
    pub transfer_s: f64,
}

#[derive(Debug, Clone, Default)]
pub struct RunStats {
    pub tokens: Vec<TokenStats>,
    /// layer_ratio-scaled total virtual seconds (accounting geometry).
    pub sim_total_scaled_s: f64,
    pub wall_total_s: f64,
    pub prefill_sim_s: f64,
    pub prefill_tokens: usize,
    /// Prefill positions skipped by seeding from the prefix cache.
    pub prefix_reused_tokens: usize,
    /// Virtual seconds the prefill front spent stalled on expert
    /// transfers (the stalled share of `prefill_sim_s`).
    pub prefill_stall_s: f64,
    /// Virtual link seconds of expert transfers issued during prefill
    /// (full durations, hidden or not — see [`TokenStats::transfer_s`]).
    pub prefill_transfer_s: f64,
}

impl RunStats {
    pub fn decode_tokens(&self) -> usize {
        self.tokens.len()
    }

    /// Decode throughput in the accounting geometry (Table 2's metric).
    pub fn tokens_per_s_sim(&self) -> f64 {
        if self.sim_total_scaled_s <= 0.0 {
            0.0
        } else {
            self.tokens.len() as f64 / self.sim_total_scaled_s
        }
    }

    pub fn tokens_per_s_wall(&self) -> f64 {
        if self.wall_total_s <= 0.0 {
            0.0
        } else {
            self.tokens.len() as f64 / self.wall_total_s
        }
    }

    pub fn total_bytes(&self) -> u64 {
        self.tokens.iter().map(|t| t.bytes_transferred).sum()
    }

    /// Demand + speculative + batch-shared hits across the run.
    pub fn total_hits(&self) -> u64 {
        self.tokens
            .iter()
            .map(|t| t.cache_hits + t.spec_hits + t.batch_shared_hits)
            .sum()
    }

    pub fn total_misses(&self) -> u64 {
        self.tokens.iter().map(|t| t.misses).sum()
    }

    pub fn hit_ratio(&self) -> f64 {
        let hits = self.total_hits();
        let total = hits + self.total_misses();
        if total == 0 {
            0.0
        } else {
            hits as f64 / total as f64
        }
    }

    pub fn mean_stall_s(&self) -> f64 {
        if self.tokens.is_empty() {
            return 0.0;
        }
        self.tokens.iter().map(|t| t.stall_s).sum::<f64>() / self.tokens.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let mut rs = RunStats::default();
        rs.tokens = vec![TokenStats::default(); 10];
        rs.sim_total_scaled_s = 5.0;
        rs.wall_total_s = 2.0;
        assert!((rs.tokens_per_s_sim() - 2.0).abs() < 1e-12);
        assert!((rs.tokens_per_s_wall() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn empty_run_is_zero() {
        let rs = RunStats::default();
        assert_eq!(rs.tokens_per_s_sim(), 0.0);
        assert_eq!(rs.hit_ratio(), 0.0);
        assert_eq!(rs.mean_stall_s(), 0.0);
    }

    #[test]
    fn hit_ratio_combines_cache_and_spec() {
        let mut rs = RunStats::default();
        rs.tokens = vec![
            TokenStats { cache_hits: 1, spec_hits: 1, misses: 2, ..Default::default() },
        ];
        assert!((rs.hit_ratio() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn batch_shared_hits_count_as_hits() {
        // a batch neighbor consuming an expert another session staged in
        // the same layer-tick had it resident — a hit for ratio purposes
        let mut rs = RunStats::default();
        rs.tokens = vec![
            TokenStats { batch_shared_hits: 3, misses: 1, ..Default::default() },
        ];
        assert_eq!(rs.total_hits(), 3);
        assert!((rs.hit_ratio() - 0.75).abs() < 1e-12);
    }
}
