//! Library-wide error type.

use thiserror::Error;

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Error, Debug)]
pub enum Error {
    #[error("shape error: {0}")]
    Shape(String),

    #[error("npz/npy format error: {0}")]
    Npz(String),

    #[error("config error: {0}")]
    Config(String),

    #[error("artifact error: {0}")]
    Artifact(String),

    #[error("runtime error: {0}")]
    Runtime(String),

    #[error("quantization error: {0}")]
    Quant(String),

    #[error("engine error: {0}")]
    Engine(String),

    /// The paged KV block pool is dry. Typed (unlike the string errors)
    /// because the scheduler reacts to it structurally: preempt the
    /// youngest session / defer admission instead of failing the request.
    #[error("kv pool exhausted: {0}")]
    KvPoolExhausted(String),

    #[error("serving error: {0}")]
    Serving(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("json error: {0}")]
    Json(#[from] crate::util::json::JsonError),

    #[error("xla error: {0}")]
    Xla(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl From<zip::result::ZipError> for Error {
    fn from(e: zip::result::ZipError) -> Self {
        Error::Npz(e.to_string())
    }
}
