//! Library-wide error type.

use thiserror::Error;

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Error, Debug)]
pub enum Error {
    #[error("shape error: {0}")]
    Shape(String),

    #[error("npz/npy format error: {0}")]
    Npz(String),

    #[error("config error: {0}")]
    Config(String),

    #[error("artifact error: {0}")]
    Artifact(String),

    #[error("runtime error: {0}")]
    Runtime(String),

    #[error("quantization error: {0}")]
    Quant(String),

    #[error("engine error: {0}")]
    Engine(String),

    /// The paged KV block pool is dry. Typed (unlike the string errors)
    /// because the scheduler reacts to it structurally: preempt the
    /// youngest session / defer admission instead of failing the request.
    #[error("kv pool exhausted: {0}")]
    KvPoolExhausted(String),

    /// An injected (or, in principle, real) fault whose bounded retries
    /// were exhausted but which does not poison the session's state.
    /// The scheduler reacts structurally, like `KvPoolExhausted`: the
    /// affected session is preempted and requeued, the rest of the
    /// batched tick proceeds untouched.
    #[error("transient fault (retries exhausted): {0}")]
    FaultTransient(String),

    /// An injected unrecoverable fault. The scheduler fails exactly the
    /// affected request with a typed `Event::Failed` — never a panic,
    /// never the whole batch.
    #[error("fatal fault: {0}")]
    FaultFatal(String),

    /// The request exceeded its deadline (`Request::deadline_s`
    /// or the `ServingConfig::deadline_s` default). Enforced by the
    /// scheduler at tick boundaries; cancels only the late request.
    #[error("deadline exceeded: {0}")]
    DeadlineExceeded(String),

    /// A client-facing wait (e.g. the `analyze` command's reply
    /// channel) outran `ServingConfig::request_timeout_s`.
    #[error("timeout: {0}")]
    Timeout(String),

    #[error("serving error: {0}")]
    Serving(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("json error: {0}")]
    Json(#[from] crate::util::json::JsonError),

    #[error("xla error: {0}")]
    Xla(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl From<zip::result::ZipError> for Error {
    fn from(e: zip::result::ZipError) -> Self {
        Error::Npz(e.to_string())
    }
}
