//! TCP line-protocol server over the coordinator.
//!
//! Protocol: one JSON object per line in, streamed JSON lines out:
//!
//! ```text
//! -> {"prompt": "what is perplexity", "max_tokens": 48}
//! <- {"type":"token","text":"t"}
//! <- {"type":"done","text":"...","tokens_per_s_wall":...,"queue_wait_s":...,"active_sessions":...,
//!     "kv_blocks_in_use":...,"kv_blocks_free":...,"kv_preemptions":...,"kv_resumes":...,
//!     "prefix_hit":...,"prefix_tokens_reused":...,"prefix_evicted_blocks":...,
//!     "expert_loads_deduped":...,"batched_kernel_calls":...,"batch_occupancy":...}
//! ```
//!
//! Each connection gets its own handler thread; the coordinator's
//! scheduler interleaves up to `max_concurrent_sessions` requests, so
//! concurrent connections stream tokens concurrently (beyond that they
//! queue, which shows up as `queue_wait_s` in the done event).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use crate::coordinator::{Coordinator, Event, Request};
use crate::error::{Error, Result};
use crate::util::json::Json;

pub struct Server {
    listener: TcpListener,
    coordinator: Arc<Coordinator>,
}

impl Server {
    pub fn bind(addr: &str, coordinator: Arc<Coordinator>) -> Result<Self> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| Error::Serving(format!("bind {addr}: {e}")))?;
        Ok(Server { listener, coordinator })
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve `max_conns` connections (None = forever). Blocking.
    pub fn serve(&self, max_conns: Option<usize>) -> Result<()> {
        let mut served = 0usize;
        for stream in self.listener.incoming() {
            let stream = stream?;
            let coord = Arc::clone(&self.coordinator);
            // one thread per connection; engine access serializes in the
            // coordinator queue
            std::thread::spawn(move || {
                let _ = handle_conn(stream, &coord);
            });
            served += 1;
            if let Some(m) = max_conns {
                if served >= m {
                    break;
                }
            }
        }
        Ok(())
    }
}

pub fn parse_request(line: &str) -> Result<Request> {
    let v = Json::parse(line)?;
    let prompt = v
        .get("prompt")
        .and_then(Json::as_str)
        .ok_or_else(|| Error::Serving("missing 'prompt'".into()))?
        .to_string();
    let mut req = Request::new(prompt);
    if let Some(m) = v.get("max_tokens").and_then(Json::as_usize) {
        req.max_tokens = m;
    }
    if let Some(t) = v.get("temperature").and_then(Json::as_f64) {
        req.temperature = t as f32;
    }
    if let Some(p) = v.get("top_p").and_then(Json::as_f64) {
        req.top_p = p as f32;
    }
    if let Some(c) = v.get("chat").and_then(Json::as_bool) {
        req.chat = c;
    }
    Ok(req)
}

pub fn event_to_json(ev: &Event) -> Json {
    match ev {
        Event::Token { text, .. } => Json::obj(vec![
            ("type", "token".into()),
            ("text", Json::str(text.clone())),
        ]),
        Event::Done {
            text,
            prompt_tokens,
            new_tokens,
            wall_s,
            tokens_per_s_wall,
            tokens_per_s_sim,
            queue_wait_s,
            active_sessions,
            kv_blocks_in_use,
            kv_blocks_free,
            kv_preemptions,
            kv_resumes,
            prefix_hit,
            prefix_tokens_reused,
            prefix_evicted_blocks,
            expert_loads_deduped,
            batched_kernel_calls,
            batch_occupancy,
            ..
        } => Json::obj(vec![
            ("type", "done".into()),
            ("text", Json::str(text.clone())),
            ("prompt_tokens", (*prompt_tokens).into()),
            ("new_tokens", (*new_tokens).into()),
            ("wall_s", (*wall_s).into()),
            ("tokens_per_s_wall", (*tokens_per_s_wall).into()),
            ("tokens_per_s_sim", (*tokens_per_s_sim).into()),
            ("queue_wait_s", (*queue_wait_s).into()),
            ("active_sessions", (*active_sessions as usize).into()),
            ("kv_blocks_in_use", (*kv_blocks_in_use as usize).into()),
            ("kv_blocks_free", (*kv_blocks_free as usize).into()),
            ("kv_preemptions", (*kv_preemptions as usize).into()),
            ("kv_resumes", (*kv_resumes as usize).into()),
            ("prefix_hit", (*prefix_hit).into()),
            ("prefix_tokens_reused", (*prefix_tokens_reused as usize).into()),
            ("prefix_evicted_blocks", (*prefix_evicted_blocks as usize).into()),
            ("expert_loads_deduped", (*expert_loads_deduped as usize).into()),
            ("batched_kernel_calls", (*batched_kernel_calls as usize).into()),
            ("batch_occupancy", (*batch_occupancy as usize).into()),
        ]),
        Event::Error { message, .. } => Json::obj(vec![
            ("type", "error".into()),
            ("message", Json::str(message.clone())),
        ]),
    }
}

fn handle_conn(stream: TcpStream, coord: &Coordinator) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        match parse_request(&line) {
            Ok(req) => {
                let resp = coord.submit(req);
                for ev in resp.events.iter() {
                    let done = matches!(ev, Event::Done { .. } | Event::Error { .. });
                    writeln!(writer, "{}", event_to_json(&ev))?;
                    if done {
                        break;
                    }
                }
            }
            Err(e) => {
                writeln!(
                    writer,
                    "{}",
                    Json::obj(vec![
                        ("type", "error".into()),
                        ("message", Json::str(e.to_string())),
                    ])
                )?;
            }
        }
        writer.flush()?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_happy_path() {
        let r = parse_request(r#"{"prompt":"hi","max_tokens":8,"temperature":0.5}"#).unwrap();
        assert_eq!(r.prompt, "hi");
        assert_eq!(r.max_tokens, 8);
        assert!((r.temperature - 0.5).abs() < 1e-6);
        assert!(r.chat);
    }

    #[test]
    fn parse_request_requires_prompt() {
        assert!(parse_request(r#"{"max_tokens":8}"#).is_err());
        assert!(parse_request("not json").is_err());
    }

    #[test]
    fn event_json_roundtrip_fields() {
        let ev = Event::Done {
            request_id: 1,
            text: "abc".into(),
            prompt_tokens: 3,
            new_tokens: 5,
            wall_s: 0.5,
            tokens_per_s_wall: 10.0,
            tokens_per_s_sim: 2.5,
            queue_wait_s: 0.25,
            active_sessions: 2,
            kv_blocks_in_use: 7,
            kv_blocks_free: 9,
            kv_preemptions: 1,
            kv_resumes: 1,
            prefix_hit: true,
            prefix_tokens_reused: 32,
            prefix_evicted_blocks: 4,
            expert_loads_deduped: 12,
            batched_kernel_calls: 48,
            batch_occupancy: 3,
        };
        let j = event_to_json(&ev);
        assert_eq!(j.get("type").unwrap().as_str(), Some("done"));
        assert_eq!(j.get("new_tokens").unwrap().as_usize(), Some(5));
        assert_eq!(j.get("active_sessions").unwrap().as_usize(), Some(2));
        assert!((j.get("queue_wait_s").unwrap().as_f64().unwrap() - 0.25).abs() < 1e-9);
        // KV pool telemetry rides along next to active_sessions
        assert_eq!(j.get("kv_blocks_in_use").unwrap().as_usize(), Some(7));
        assert_eq!(j.get("kv_blocks_free").unwrap().as_usize(), Some(9));
        assert_eq!(j.get("kv_preemptions").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("kv_resumes").unwrap().as_usize(), Some(1));
        // ...and so do the prefix-cache hit/reuse/eviction metrics
        assert_eq!(j.get("prefix_hit").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("prefix_tokens_reused").unwrap().as_usize(), Some(32));
        assert_eq!(j.get("prefix_evicted_blocks").unwrap().as_usize(), Some(4));
        // ...and the batched-decode dedup metrics
        assert_eq!(j.get("expert_loads_deduped").unwrap().as_usize(), Some(12));
        assert_eq!(j.get("batched_kernel_calls").unwrap().as_usize(), Some(48));
        assert_eq!(j.get("batch_occupancy").unwrap().as_usize(), Some(3));
    }
}
