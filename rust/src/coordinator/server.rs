//! TCP line-protocol server over the coordinator.
//!
//! Protocol: one JSON object per line in, streamed JSON lines out:
//!
//! ```text
//! -> {"prompt": "what is perplexity", "max_tokens": 48}
//! <- {"type":"token","text":"t"}
//! <- {"type":"done","text":"...","tokens_per_s_wall":...,"queue_wait_s":...,"ttft_s":...,
//!     "active_sessions":...,"kv_blocks_total":...,"kv_blocks_in_use":...,"kv_blocks_free":...,
//!     "kv_preemptions":...,"kv_resumes":...,"prefix_hit":...,"prefix_tokens_reused":...,
//!     "prefix_cache_blocks":...,"prefix_cache_tokens":...,"prefix_hits":...,"prefix_misses":...,
//!     "prefix_inserted_blocks":...,"prefix_evicted_blocks":...,"expert_loads_deduped":...,
//!     "batched_kernel_calls":...,"batched_ticks":...,"mixed_ticks":...,"batch_occupancy":...,
//!     "expert_hot_hits":...,"tier_promotions":...,"link_bytes_saved":...,
//!     "trace_spans_dropped":...,"faults_injected":...,"transfer_retries":...,
//!     "requests_failed":...,"deadline_cancellations":...}
//! ```
//!
//! The done event carries a field for EVERY gauge the scheduler records
//! (see [`GAUGE_DONE_FIELDS`]) — the parity test below fails the build
//! when a gauge is added without its done-JSON counterpart, the drift
//! that silently dropped `kv_resumes` in PR 2. With span tracing on
//! (`ServingConfig::trace`) the done event additionally carries the
//! per-request time breakdown (`queue_s`, `prefill_compute_s`,
//! `decode_compute_s`, `transfer_s`, `transfer_hidden_s`, `stall_s`),
//! locked to the `req_*` breakdown histograms by the same discipline
//! ([`BREAKDOWN_DONE_FIELDS`]); tracing off, those fields are absent
//! and the output is byte-identical to a tracing-less build.
//!
//! Besides request objects, a line consisting of the bare word
//! `metrics` returns the coordinator's full metrics registry as
//! `{"type":"metrics","metrics":"<rendered text>"}` — a scrapeable
//! surface (counters, gauges, histogram mean/p50/p99/count per line) —
//! and a line consisting of the bare word `analyze` returns the span
//! ring's analysis report (`crate::trace::analysis`): per-window
//! GPU/link utilization, per-request critical paths, aggregate
//! bottleneck attribution and what-if speedup projections, or an
//! explicit `{"enabled":false,"error":"tracing disabled"}` when
//! `ServingConfig::trace` is off. A bare `experts` line returns the
//! expert flight recorder's report (`crate::obs`): per-(layer, expert)
//! use/hit/load/eviction counters, virtual-time-weighted residency,
//! wire bytes by tier, per-layer prefetch quality, and counterfactual
//! LRU/OPT cache curves — or the same explicit
//! `{"enabled":false,"error":"expert observability disabled"}`
//! degradation when `ServingConfig::expert_obs` is off.
//!
//! Each connection gets its own handler thread; the coordinator's
//! scheduler interleaves up to `max_concurrent_sessions` requests, so
//! concurrent connections stream tokens concurrently (beyond that they
//! queue, which shows up as `queue_wait_s` in the done event).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use crate::coordinator::{Coordinator, Event, Request};
use crate::error::{Error, Result};
use crate::telemetry::Metrics;
use crate::util::json::Json;

pub struct Server {
    listener: TcpListener,
    coordinator: Arc<Coordinator>,
}

impl Server {
    pub fn bind(addr: &str, coordinator: Arc<Coordinator>) -> Result<Self> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| Error::Serving(format!("bind {addr}: {e}")))?;
        Ok(Server { listener, coordinator })
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve `max_conns` connections (None = forever). Blocking.
    pub fn serve(&self, max_conns: Option<usize>) -> Result<()> {
        let mut served = 0usize;
        for stream in self.listener.incoming() {
            let stream = stream?;
            let coord = Arc::clone(&self.coordinator);
            // one thread per connection; engine access serializes in the
            // coordinator queue
            std::thread::spawn(move || {
                let _ = handle_conn(stream, &coord);
            });
            served += 1;
            if let Some(m) = max_conns {
                if served >= m {
                    break;
                }
            }
        }
        Ok(())
    }
}

pub fn parse_request(line: &str) -> Result<Request> {
    let v = Json::parse(line)?;
    let prompt = v
        .get("prompt")
        .and_then(Json::as_str)
        .ok_or_else(|| Error::Serving("missing 'prompt'".into()))?
        .to_string();
    let mut req = Request::new(prompt);
    if let Some(m) = v.get("max_tokens").and_then(Json::as_usize) {
        req.max_tokens = m;
    }
    if let Some(t) = v.get("temperature").and_then(Json::as_f64) {
        req.temperature = t as f32;
    }
    if let Some(p) = v.get("top_p").and_then(Json::as_f64) {
        req.top_p = p as f32;
    }
    if let Some(c) = v.get("chat").and_then(Json::as_bool) {
        req.chat = c;
    }
    if let Some(d) = v.get("deadline_s").and_then(Json::as_f64) {
        // sanitized again at the scheduler (non-finite, non-positive,
        // and Duration-overflowing values are all ignored there), so a
        // hostile value can't panic the worker
        req.deadline_s = Some(d);
    }
    Ok(req)
}

/// Every gauge the scheduler records, paired with the `done`-event JSON
/// field that surfaces it. The parity test enumerates the recorded
/// gauges and demands a mapping AND a serialized field for each, so a
/// new gauge cannot ship without its done-JSON counterpart (the drift
/// class that silently dropped `kv_resumes` in PR 2 until PR 3 caught
/// it). Names are mostly 1:1; keep them that way for new gauges.
pub const GAUGE_DONE_FIELDS: &[(&str, &str)] = &[
    ("active_sessions", "active_sessions"),
    ("kv_blocks_total", "kv_blocks_total"),
    ("kv_blocks_free", "kv_blocks_free"),
    ("kv_blocks_in_use", "kv_blocks_in_use"),
    ("kv_preemptions", "kv_preemptions"),
    ("prefix_cache_blocks", "prefix_cache_blocks"),
    ("prefix_cache_tokens", "prefix_cache_tokens"),
    ("prefix_hits", "prefix_hits"),
    ("prefix_misses", "prefix_misses"),
    ("prefix_tokens_reused", "prefix_tokens_reused"),
    ("prefix_inserted_blocks", "prefix_inserted_blocks"),
    ("prefix_evicted_blocks", "prefix_evicted_blocks"),
    ("batch_occupancy", "batch_occupancy"),
    ("batched_ticks", "batched_ticks"),
    ("batched_kernel_calls", "batched_kernel_calls"),
    ("expert_loads_deduped", "expert_loads_deduped"),
    ("mixed_ticks", "mixed_ticks"),
    ("expert_hot_hits", "expert_hot_hits"),
    ("tier_promotions", "tier_promotions"),
    ("link_bytes_saved", "link_bytes_saved"),
    ("trace_spans_dropped", "trace_spans_dropped"),
    ("faults_injected", "faults_injected"),
    ("transfer_retries", "transfer_retries"),
    ("spec_recall_bp", "spec_recall_bp"),
    ("spec_precision_bp", "spec_precision_bp"),
    // requests_failed / deadline_cancellations are counters, not gauges
    // (a same-named gauge mirror would duplicate their render() lines);
    // the done event reads them straight off the counters, so they are
    // pinned by the done-JSON roundtrip test instead of this table
];

/// Every per-request breakdown histogram the scheduler observes (span
/// tracing on), paired with the `done`-event JSON field that surfaces
/// the same request's value. Same parity discipline as
/// [`GAUGE_DONE_FIELDS`]: the test below drives the histogram-recording
/// path and demands a mapping AND a serialized field for each, so a new
/// breakdown component cannot ship scrapeable but invisible per-request
/// (or vice versa).
pub const BREAKDOWN_DONE_FIELDS: &[(&str, &str)] = &[
    ("req_queue_s", "queue_s"),
    ("req_prefill_compute_s", "prefill_compute_s"),
    ("req_decode_compute_s", "decode_compute_s"),
    ("req_transfer_s", "transfer_s"),
    ("req_transfer_hidden_s", "transfer_hidden_s"),
    ("req_stall_s", "stall_s"),
];

pub fn event_to_json(ev: &Event) -> Json {
    match ev {
        Event::Token { text, .. } => Json::obj(vec![
            ("type", "token".into()),
            ("text", Json::str(text.clone())),
        ]),
        Event::Done {
            text,
            prompt_tokens,
            new_tokens,
            wall_s,
            tokens_per_s_wall,
            tokens_per_s_sim,
            queue_wait_s,
            ttft_s,
            active_sessions,
            kv_blocks_total,
            kv_blocks_in_use,
            kv_blocks_free,
            kv_preemptions,
            kv_resumes,
            prefix_hit,
            prefix_tokens_reused,
            prefix_cache_blocks,
            prefix_cache_tokens,
            prefix_hits,
            prefix_misses,
            prefix_inserted_blocks,
            prefix_evicted_blocks,
            expert_loads_deduped,
            batched_kernel_calls,
            batched_ticks,
            mixed_ticks,
            batch_occupancy,
            expert_hot_hits,
            tier_promotions,
            link_bytes_saved,
            trace_spans_dropped,
            faults_injected,
            transfer_retries,
            requests_failed,
            deadline_cancellations,
            spec_recall_bp,
            spec_precision_bp,
            breakdown,
            ..
        } => {
            let mut fields = vec![
                ("type", "done".into()),
                ("text", Json::str(text.clone())),
                ("prompt_tokens", (*prompt_tokens).into()),
                ("new_tokens", (*new_tokens).into()),
                ("wall_s", (*wall_s).into()),
                ("tokens_per_s_wall", (*tokens_per_s_wall).into()),
                ("tokens_per_s_sim", (*tokens_per_s_sim).into()),
                ("queue_wait_s", (*queue_wait_s).into()),
                ("ttft_s", (*ttft_s).into()),
                ("active_sessions", (*active_sessions as usize).into()),
                ("kv_blocks_total", (*kv_blocks_total as usize).into()),
                ("kv_blocks_in_use", (*kv_blocks_in_use as usize).into()),
                ("kv_blocks_free", (*kv_blocks_free as usize).into()),
                ("kv_preemptions", (*kv_preemptions as usize).into()),
                ("kv_resumes", (*kv_resumes as usize).into()),
                ("prefix_hit", (*prefix_hit).into()),
                ("prefix_tokens_reused", (*prefix_tokens_reused as usize).into()),
                ("prefix_cache_blocks", (*prefix_cache_blocks as usize).into()),
                ("prefix_cache_tokens", (*prefix_cache_tokens as usize).into()),
                ("prefix_hits", (*prefix_hits as usize).into()),
                ("prefix_misses", (*prefix_misses as usize).into()),
                ("prefix_inserted_blocks", (*prefix_inserted_blocks as usize).into()),
                ("prefix_evicted_blocks", (*prefix_evicted_blocks as usize).into()),
                ("expert_loads_deduped", (*expert_loads_deduped as usize).into()),
                ("batched_kernel_calls", (*batched_kernel_calls as usize).into()),
                ("batched_ticks", (*batched_ticks as usize).into()),
                ("mixed_ticks", (*mixed_ticks as usize).into()),
                ("batch_occupancy", (*batch_occupancy as usize).into()),
                ("expert_hot_hits", (*expert_hot_hits as usize).into()),
                ("tier_promotions", (*tier_promotions as usize).into()),
                ("link_bytes_saved", (*link_bytes_saved as usize).into()),
                ("trace_spans_dropped", (*trace_spans_dropped as usize).into()),
                ("faults_injected", (*faults_injected as usize).into()),
                ("transfer_retries", (*transfer_retries as usize).into()),
                ("requests_failed", (*requests_failed as usize).into()),
                ("deadline_cancellations", (*deadline_cancellations as usize).into()),
                ("spec_recall_bp", (*spec_recall_bp as usize).into()),
                ("spec_precision_bp", (*spec_precision_bp as usize).into()),
            ];
            // breakdown fields ride the trace knob: absent (not zeroed)
            // when tracing is off, keeping the off-path byte-identical
            if let Some(b) = breakdown {
                fields.push(("queue_s", b.queue_s.into()));
                fields.push(("prefill_compute_s", b.prefill_compute_s.into()));
                fields.push(("decode_compute_s", b.decode_compute_s.into()));
                fields.push(("transfer_s", b.transfer_s.into()));
                fields.push(("transfer_hidden_s", b.transfer_hidden_s.into()));
                fields.push(("stall_s", b.stall_s.into()));
            }
            Json::obj(fields)
        }
        Event::Error { message, .. } => Json::obj(vec![
            ("type", "error".into()),
            ("message", Json::str(message.clone())),
        ]),
        // typed terminal failure (injected fatal fault, exhausted
        // degradation, or deadline cancellation) — distinct from "error"
        // so clients can tell policy-failed requests from malformed ones
        Event::Failed { message, .. } => Json::obj(vec![
            ("type", "failed".into()),
            ("message", Json::str(message.clone())),
        ]),
    }
}

/// The `metrics` command's response: the coordinator's full registry
/// rendered as scrape text (one `name value` line per counter/gauge,
/// `_mean/_p50/_p99/_count` lines per histogram), wrapped in a JSON
/// envelope for the line protocol.
pub fn metrics_json(m: &Metrics) -> Json {
    Json::obj(vec![
        ("type", "metrics".into()),
        ("metrics", Json::str(m.render())),
    ])
}

fn handle_conn(stream: TcpStream, coord: &Coordinator) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        if line.trim() == "metrics" {
            writeln!(writer, "{}", metrics_json(&coord.metrics))?;
            writer.flush()?;
            continue;
        }
        if line.trim() == "analyze" {
            let reply = match coord.analyze() {
                Ok(report) => report,
                Err(e) => Json::obj(vec![
                    ("type", "error".into()),
                    ("message", Json::str(e.to_string())),
                ]),
            };
            writeln!(writer, "{reply}")?;
            writer.flush()?;
            continue;
        }
        if line.trim() == "experts" {
            let reply = match coord.experts() {
                Ok(report) => report,
                Err(e) => Json::obj(vec![
                    ("type", "error".into()),
                    ("message", Json::str(e.to_string())),
                ]),
            };
            writeln!(writer, "{reply}")?;
            writer.flush()?;
            continue;
        }
        match parse_request(&line) {
            Ok(req) => {
                let resp = coord.submit(req);
                for ev in resp.events.iter() {
                    let done = matches!(
                        ev,
                        Event::Done { .. } | Event::Error { .. } | Event::Failed { .. }
                    );
                    writeln!(writer, "{}", event_to_json(&ev))?;
                    if done {
                        break;
                    }
                }
            }
            Err(e) => {
                writeln!(
                    writer,
                    "{}",
                    Json::obj(vec![
                        ("type", "error".into()),
                        ("message", Json::str(e.to_string())),
                    ])
                )?;
            }
        }
        writer.flush()?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_happy_path() {
        let r = parse_request(r#"{"prompt":"hi","max_tokens":8,"temperature":0.5}"#).unwrap();
        assert_eq!(r.prompt, "hi");
        assert_eq!(r.max_tokens, 8);
        assert!((r.temperature - 0.5).abs() < 1e-6);
        assert!(r.chat);
    }

    #[test]
    fn parse_request_requires_prompt() {
        assert!(parse_request(r#"{"max_tokens":8}"#).is_err());
        assert!(parse_request("not json").is_err());
    }

    fn sample_done() -> Event {
        Event::Done {
            request_id: 1,
            text: "abc".into(),
            prompt_tokens: 3,
            new_tokens: 5,
            wall_s: 0.5,
            tokens_per_s_wall: 10.0,
            tokens_per_s_sim: 2.5,
            queue_wait_s: 0.25,
            ttft_s: 0.125,
            active_sessions: 2,
            kv_blocks_total: 16,
            kv_blocks_in_use: 7,
            kv_blocks_free: 9,
            kv_preemptions: 1,
            kv_resumes: 1,
            prefix_hit: true,
            prefix_tokens_reused: 32,
            prefix_cache_blocks: 6,
            prefix_cache_tokens: 96,
            prefix_hits: 2,
            prefix_misses: 5,
            prefix_inserted_blocks: 8,
            prefix_evicted_blocks: 4,
            expert_loads_deduped: 12,
            batched_kernel_calls: 48,
            batched_ticks: 20,
            mixed_ticks: 6,
            batch_occupancy: 3,
            expert_hot_hits: 14,
            tier_promotions: 2,
            link_bytes_saved: 4096,
            trace_spans_dropped: 3,
            faults_injected: 7,
            transfer_retries: 4,
            requests_failed: 1,
            deadline_cancellations: 1,
            spec_recall_bp: 7500,
            spec_precision_bp: 6000,
            breakdown: None,
        }
    }

    fn sample_breakdown() -> crate::coordinator::Breakdown {
        crate::coordinator::Breakdown {
            queue_s: 0.25,
            prefill_compute_s: 0.5,
            decode_compute_s: 1.5,
            transfer_s: 0.75,
            transfer_hidden_s: 0.5,
            stall_s: 0.25,
        }
    }

    #[test]
    fn event_json_roundtrip_fields() {
        let j = event_to_json(&sample_done());
        assert_eq!(j.get("type").unwrap().as_str(), Some("done"));
        assert_eq!(j.get("new_tokens").unwrap().as_usize(), Some(5));
        assert_eq!(j.get("active_sessions").unwrap().as_usize(), Some(2));
        assert!((j.get("queue_wait_s").unwrap().as_f64().unwrap() - 0.25).abs() < 1e-9);
        // per-request time-to-first-token (the chunked-prefill metric)
        assert!((j.get("ttft_s").unwrap().as_f64().unwrap() - 0.125).abs() < 1e-9);
        // KV pool telemetry rides along next to active_sessions
        assert_eq!(j.get("kv_blocks_total").unwrap().as_usize(), Some(16));
        assert_eq!(j.get("kv_blocks_in_use").unwrap().as_usize(), Some(7));
        assert_eq!(j.get("kv_blocks_free").unwrap().as_usize(), Some(9));
        assert_eq!(j.get("kv_preemptions").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("kv_resumes").unwrap().as_usize(), Some(1));
        // ...and so do the prefix-cache hit/reuse/eviction metrics
        assert_eq!(j.get("prefix_hit").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("prefix_tokens_reused").unwrap().as_usize(), Some(32));
        assert_eq!(j.get("prefix_cache_blocks").unwrap().as_usize(), Some(6));
        assert_eq!(j.get("prefix_cache_tokens").unwrap().as_usize(), Some(96));
        assert_eq!(j.get("prefix_hits").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("prefix_misses").unwrap().as_usize(), Some(5));
        assert_eq!(j.get("prefix_inserted_blocks").unwrap().as_usize(), Some(8));
        assert_eq!(j.get("prefix_evicted_blocks").unwrap().as_usize(), Some(4));
        // ...and the batched/mixed-tick dedup metrics
        assert_eq!(j.get("expert_loads_deduped").unwrap().as_usize(), Some(12));
        assert_eq!(j.get("batched_kernel_calls").unwrap().as_usize(), Some(48));
        assert_eq!(j.get("batched_ticks").unwrap().as_usize(), Some(20));
        assert_eq!(j.get("mixed_ticks").unwrap().as_usize(), Some(6));
        assert_eq!(j.get("batch_occupancy").unwrap().as_usize(), Some(3));
        // ...and the quantization-tier savings metrics
        assert_eq!(j.get("expert_hot_hits").unwrap().as_usize(), Some(14));
        assert_eq!(j.get("tier_promotions").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("link_bytes_saved").unwrap().as_usize(), Some(4096));
        // ...and trace-ring overflow visibility
        assert_eq!(j.get("trace_spans_dropped").unwrap().as_usize(), Some(3));
        // ...and the fault-injection / resilience counters
        assert_eq!(j.get("faults_injected").unwrap().as_usize(), Some(7));
        assert_eq!(j.get("transfer_retries").unwrap().as_usize(), Some(4));
        assert_eq!(j.get("requests_failed").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("deadline_cancellations").unwrap().as_usize(), Some(1));
        // ...and the prefetch-quality gauges (paper Fig. 2)
        assert_eq!(j.get("spec_recall_bp").unwrap().as_usize(), Some(7500));
        assert_eq!(j.get("spec_precision_bp").unwrap().as_usize(), Some(6000));
    }

    #[test]
    fn failed_event_serializes_typed() {
        let j = event_to_json(&Event::Failed {
            request_id: 3,
            message: "request deadline exceeded".into(),
        });
        assert_eq!(j.get("type").unwrap().as_str(), Some("failed"));
        assert!(j.get("message").unwrap().as_str().unwrap().contains("deadline"));
    }

    #[test]
    fn parse_request_reads_deadline() {
        let r = parse_request(r#"{"prompt":"hi","deadline_s":2.5}"#).unwrap();
        assert_eq!(r.deadline_s, Some(2.5));
        assert_eq!(parse_request(r#"{"prompt":"hi"}"#).unwrap().deadline_s, None);
    }

    /// Gauge / done-JSON parity: drive every gauge-recording path the
    /// scheduler uses, then demand that each recorded gauge (a) has an
    /// entry in [`GAUGE_DONE_FIELDS`] and (b) that entry's field is
    /// actually serialized in the done event. A gauge added to a
    /// `record_*` helper without wiring it through the done schema now
    /// fails here instead of shipping (`kv_resumes` — a counter, the
    /// sibling drift — went missing in PR 2 the same way; counters
    /// surfaced in the done event are pinned by the roundtrip test
    /// above, and this drive block must mirror the scheduler's
    /// gauge-recording calls when one is added).
    #[test]
    fn every_recorded_gauge_surfaces_in_the_done_event() {
        use crate::telemetry::Metrics;
        let m = Metrics::new();
        // the scheduler's full set of gauge-recording calls — extend in
        // lockstep with scheduler_loop/batched_tick/mixed_tick
        m.set_gauge("active_sessions", 1);
        m.record_kv_pool(1, 1, 1, 1);
        m.record_prefix(1, 1, 1, 1, 1, 1, 1);
        m.record_batch(1, 1, 1, 1, 1);
        m.record_tiers(1, 1, 1);
        m.set_gauge("trace_spans_dropped", 1);
        m.record_faults(1, 1);
        m.record_spec(1, 1);
        let names = m.gauge_names();
        assert!(!names.is_empty());
        let j = event_to_json(&sample_done());
        for name in names {
            let field = GAUGE_DONE_FIELDS
                .iter()
                .find(|(gauge, _)| *gauge == name.as_str())
                .unwrap_or_else(|| {
                    panic!("gauge {name:?} has no done-event mapping in GAUGE_DONE_FIELDS")
                })
                .1;
            assert!(
                j.get(field).is_some(),
                "done event is missing field {field:?} (mapped from gauge {name:?})"
            );
        }
        // the mapping itself must not point at fields the schema lost
        for (gauge, field) in GAUGE_DONE_FIELDS {
            assert!(
                j.get(field).is_some(),
                "GAUGE_DONE_FIELDS maps gauge {gauge:?} to missing done field {field:?}"
            );
        }
    }

    #[test]
    fn breakdown_fields_absent_without_tracing() {
        // trace off ⇒ breakdown is None ⇒ the fields are ABSENT (not
        // zeroed) — the byte-identity contract for tracing-off serving
        let j = event_to_json(&sample_done());
        for (_, field) in BREAKDOWN_DONE_FIELDS {
            assert!(
                j.get(field).is_none(),
                "done event must not carry {field:?} with tracing off"
            );
        }
    }

    /// Breakdown-histogram / done-JSON parity, mirroring the gauge test:
    /// drive the scheduler's breakdown observation path (the six
    /// `req_*` sim-time histograms `finish()` records with tracing on),
    /// then demand each recorded histogram has a mapping AND that its
    /// field is serialized in a traced done event. A new breakdown
    /// component can't ship scrapeable but invisible per-request, or
    /// vice versa.
    #[test]
    fn every_breakdown_histogram_surfaces_in_the_traced_done_event() {
        use crate::telemetry::Histogram;
        let m = Metrics::new();
        // mirror finish()'s observe_with calls — extend in lockstep
        m.observe_with("req_queue_s", 0.1, Histogram::sim_time);
        m.observe_with("req_prefill_compute_s", 0.1, Histogram::sim_time);
        m.observe_with("req_decode_compute_s", 0.1, Histogram::sim_time);
        m.observe_with("req_transfer_s", 0.1, Histogram::sim_time);
        m.observe_with("req_transfer_hidden_s", 0.1, Histogram::sim_time);
        m.observe_with("req_stall_s", 0.1, Histogram::sim_time);
        let mut done = sample_done();
        if let Event::Done { breakdown, .. } = &mut done {
            *breakdown = Some(sample_breakdown());
        }
        let j = event_to_json(&done);
        for name in m.histogram_names() {
            if !name.starts_with("req_") {
                continue; // other histograms (latency etc.) are not per-request
            }
            let field = BREAKDOWN_DONE_FIELDS
                .iter()
                .find(|(hist, _)| *hist == name.as_str())
                .unwrap_or_else(|| {
                    panic!("histogram {name:?} has no done-event mapping in BREAKDOWN_DONE_FIELDS")
                })
                .1;
            assert!(
                j.get(field).is_some(),
                "traced done event is missing field {field:?} (mapped from {name:?})"
            );
        }
        // the mapping itself must not point at fields the schema lost
        for (hist, field) in BREAKDOWN_DONE_FIELDS {
            assert!(
                j.get(field).is_some(),
                "BREAKDOWN_DONE_FIELDS maps {hist:?} to missing done field {field:?}"
            );
        }
        // spot-check values flow through
        assert!((j.get("stall_s").unwrap().as_f64().unwrap() - 0.25).abs() < 1e-12);
        assert!((j.get("transfer_hidden_s").unwrap().as_f64().unwrap() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn metrics_command_renders_registry() {
        let m = Metrics::new();
        m.inc("requests_ok", 3);
        m.set_gauge("active_sessions", 2);
        m.observe("request_latency_s", 0.5);
        let j = metrics_json(&m);
        assert_eq!(j.get("type").unwrap().as_str(), Some("metrics"));
        let text = j.get("metrics").unwrap().as_str().unwrap();
        assert!(text.contains("requests_ok 3"));
        assert!(text.contains("active_sessions 2"));
        assert!(text.contains("request_latency_s_count 1"));
        // the envelope itself must survive the line protocol
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("type").unwrap().as_str(), Some("metrics"));
    }
}
