//! Serving coordinator: request router + engine worker + TCP line server.
//!
//! The paper targets interactive batch-1 inference, so the coordinator is
//! a single engine worker fed by a FIFO request queue (std mpsc; tokio is
//! not in the offline crate set and one CPU-bound worker needs no
//! reactor). Each request is a prompt + generation params; responses
//! stream token chunks back over a bounded channel so callers can render
//! incrementally — the property offloading labors to preserve.

pub mod server;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::time::Instant;

use crate::engine::MoeEngine;
use crate::error::{Error, Result};
use crate::model::{ByteTokenizer, Sampler};
use crate::telemetry::Metrics;

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub max_tokens: usize,
    pub temperature: f32,
    pub top_p: f32,
    /// Chat-format the prompt with the training template.
    pub chat: bool,
}

impl Request {
    pub fn new(prompt: impl Into<String>) -> Self {
        Request {
            id: 0,
            prompt: prompt.into(),
            max_tokens: 64,
            temperature: 1.0,
            top_p: 1.0,
            chat: true,
        }
    }
}

#[derive(Debug, Clone)]
pub enum Event {
    /// A decoded text fragment.
    Token { request_id: u64, text: String },
    /// Generation finished.
    Done {
        request_id: u64,
        text: String,
        prompt_tokens: usize,
        new_tokens: usize,
        wall_s: f64,
        tokens_per_s_wall: f64,
        tokens_per_s_sim: f64,
    },
    Error { request_id: u64, message: String },
}

/// Handle returned to submitters: stream of events for their request.
pub struct ResponseStream {
    pub request_id: u64,
    pub events: Receiver<Event>,
}

impl ResponseStream {
    /// Collect the final text (blocking).
    pub fn wait_text(self) -> Result<String> {
        for ev in self.events.iter() {
            match ev {
                Event::Done { text, .. } => return Ok(text),
                Event::Error { message, .. } => return Err(Error::Serving(message)),
                Event::Token { .. } => {}
            }
        }
        Err(Error::Serving("worker dropped".into()))
    }
}

enum Work {
    Run(Request, Sender<Event>),
    Shutdown,
}

/// The coordinator: owns the engine worker thread.
pub struct Coordinator {
    work_tx: Sender<Work>,
    next_id: AtomicU64,
    pub metrics: Arc<Metrics>,
    running: Arc<AtomicBool>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// `make_engine` runs on the worker thread — PJRT handles are not
    /// `Send`, so the engine must be *built* where it lives.
    pub fn new<F>(make_engine: F, seed: u64) -> Self
    where
        F: FnOnce() -> Result<MoeEngine> + Send + 'static,
    {
        let (work_tx, work_rx) = channel::<Work>();
        let metrics = Arc::new(Metrics::new());
        let running = Arc::new(AtomicBool::new(true));
        let m = Arc::clone(&metrics);
        let r = Arc::clone(&running);
        let worker = std::thread::spawn(move || {
            let mut engine = match make_engine() {
                Ok(e) => e,
                Err(e) => {
                    // fail every queued request with the build error
                    while let Ok(work) = work_rx.recv() {
                        if let Work::Run(req, tx) = work {
                            let _ = tx.send(Event::Error {
                                request_id: req.id,
                                message: format!("engine init failed: {e}"),
                            });
                        } else {
                            break;
                        }
                    }
                    r.store(false, Ordering::SeqCst);
                    return;
                }
            };
            let tokenizer = ByteTokenizer::new();
            let mut req_seed = seed;
            while let Ok(work) = work_rx.recv() {
                let (req, tx) = match work {
                    Work::Run(req, tx) => (req, tx),
                    Work::Shutdown => break,
                };
                m.inc("requests_started", 1);
                let t0 = Instant::now();
                req_seed = req_seed.wrapping_add(1);
                match run_request(&mut engine, &tokenizer, &req, req_seed, &tx) {
                    Ok((text, prompt_tokens, new_tokens, sim_tps)) => {
                        let wall = t0.elapsed().as_secs_f64();
                        m.inc("requests_ok", 1);
                        m.inc("tokens_generated", new_tokens as u64);
                        m.observe("request_latency_s", wall);
                        let _ = tx.send(Event::Done {
                            request_id: req.id,
                            text,
                            prompt_tokens,
                            new_tokens,
                            wall_s: wall,
                            tokens_per_s_wall: new_tokens as f64 / wall.max(1e-9),
                            tokens_per_s_sim: sim_tps,
                        });
                    }
                    Err(e) => {
                        m.inc("requests_failed", 1);
                        let _ = tx.send(Event::Error {
                            request_id: req.id,
                            message: e.to_string(),
                        });
                    }
                }
            }
            r.store(false, Ordering::SeqCst);
        });
        Coordinator {
            work_tx,
            next_id: AtomicU64::new(1),
            metrics,
            running,
            worker: Some(worker),
        }
    }

    /// Enqueue a request; returns a stream of events.
    pub fn submit(&self, mut req: Request) -> ResponseStream {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        req.id = id;
        let (tx, rx) = channel();
        self.metrics.inc("requests_enqueued", 1);
        let _ = self.work_tx.send(Work::Run(req, tx));
        ResponseStream { request_id: id, events: rx }
    }

    pub fn shutdown(mut self) {
        let _ = self.work_tx.send(Work::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.work_tx.send(Work::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn run_request(
    engine: &mut MoeEngine,
    tokenizer: &ByteTokenizer,
    req: &Request,
    seed: u64,
    tx: &Sender<Event>,
) -> Result<(String, usize, usize, f64)> {
    let prompt_tokens = if req.chat {
        tokenizer.chat_turn(&req.prompt)
    } else {
        tokenizer.encode(&req.prompt)
    };
    if prompt_tokens.is_empty() {
        return Err(Error::Serving("empty prompt".into()));
    }
    engine.reset_session(false);
    let sim_before = engine.run.sim_total_scaled_s;
    let tokens_before = engine.run.tokens.len();

    let mut sampler = Sampler::new(req.temperature, req.top_p, seed);
    let budget = req
        .max_tokens
        .min(engine.weights.cfg.max_seq.saturating_sub(prompt_tokens.len()).saturating_sub(1));
    if budget == 0 {
        return Err(Error::Serving("prompt exceeds context window".into()));
    }

    let logits = engine.prefill(&prompt_tokens)?;
    let mut next = sampler.sample(logits.row(prompt_tokens.len() - 1)) as u32;
    let mut generated = vec![next];
    let _ = tx.send(Event::Token {
        request_id: req.id,
        text: tokenizer.decode(&[next]),
    });
    for _ in 1..budget {
        let logits = engine.decode_step(next)?;
        next = sampler.sample(&logits) as u32;
        generated.push(next);
        let _ = tx.send(Event::Token {
            request_id: req.id,
            text: tokenizer.decode(&[next]),
        });
        // stop at end-of-turn marker (newline after assistant text)
        if generated.len() > 4 && tokenizer.decode(&generated).ends_with(".\n") {
            break;
        }
    }
    let sim_s = engine.run.sim_total_scaled_s - sim_before;
    let n_new = engine.run.tokens.len() - tokens_before;
    let sim_tps = if sim_s > 0.0 { n_new as f64 / sim_s } else { 0.0 };
    Ok((tokenizer.decode(&generated), prompt_tokens.len(), generated.len(), sim_tps))
}

/// Drain helper for tests / examples: iterate a stream's token events.
pub fn collect_events(stream: ResponseStream) -> Vec<Event> {
    let mut out = Vec::new();
    loop {
        match stream.events.try_recv() {
            Ok(ev) => {
                let done = matches!(ev, Event::Done { .. } | Event::Error { .. });
                out.push(ev);
                if done {
                    break;
                }
            }
            Err(TryRecvError::Empty) => std::thread::sleep(std::time::Duration::from_millis(1)),
            Err(TryRecvError::Disconnected) => break,
        }
    }
    out
}
