//! Serving coordinator: request router + engine worker + TCP line server.
//!
//! The engine worker runs a continuous-batching scheduler. Requests queue
//! FIFO (std mpsc; tokio is not in the offline crate set and one
//! CPU-bound worker needs no reactor); the worker admits up to
//! `max_concurrent_sessions` of them into live [`Session`]s and gives
//! every live session ONE decode step per scheduling tick. With
//! `ServingConfig::batched_decode` (default on) and two or more live
//! sessions, the tick runs them through
//! [`MoeEngine::decode_batch`] in layer lockstep: one expert staging and
//! one stacked kernel call per DISTINCT routed expert per layer-tick,
//! instead of each session paying its own lookups, transfers and
//! per-token kernel calls. With the knob off — or at width 1 — the tick
//! round-robin interleaves sequential `decode_step` calls, byte-
//! identical to the pre-batching scheduler; either way per-session
//! output is the same, since batching is a pure execution-order/dedup
//! optimization. Every live session shares the engine's warm expert
//! LRU cache and amortizes speculative transfers — the cross-request
//! reuse that makes offloading pay off under load — while keeping its own
//! KV cache, sampler and token budget, so streams stay numerically
//! independent. With `max_concurrent_sessions = 1` the schedule degrades
//! to the paper's batch-1 serving, token for token.
//!
//! With `ServingConfig::chunked_prefill` (default off, see
//! [`crate::sched`]), admission stops prefilling synchronously: an
//! admitted request enters a `Prefilling` phase and its prompt is fed in
//! `prefill_chunk_tokens`-sized chunks — at most one chunk per tick,
//! token-budgeted by `max_batch_tokens` — fused into the batched decode
//! lockstep via [`MoeEngine::step_mixed`] (one cache resolve and one
//! stacked kernel per distinct expert per layer-tick, decode rows riding
//! the experts the chunk loads anyway). A long prompt therefore no
//! longer stalls every live decode for its whole prefill; per-session
//! token streams are bit-identical either way, only tick boundaries
//! move. Prefilling sessions are preempt/resume-safe mid-prompt (their
//! partial KV swaps to host like any other session's) and prefix-cache
//! seeding composes with tail chunking. Off, admission is byte-identical
//! to the synchronous scheduler.
//!
//! Admission is memory-elastic (see [`crate::kv`]): beyond the width cap,
//! a request is admitted only when the paged KV pool has free blocks for
//! its prompt — and if the pool runs dry *mid-decode*, the scheduler
//! preempts the youngest live session (its KV blocks swap to host and it
//! joins a requeue list, resumed bit-identically once blocks free up)
//! instead of failing anyone. Requests whose prompt exceeds the whole
//! pool fail up front; everything else eventually runs.
//!
//! With `ServingConfig::prefix_cache` on (see [`crate::prefix`]),
//! admission first looks the prompt up in the prefix cache: a warm match
//! seeds the session's KV from cached blocks and prefill resumes at the
//! first uncached token, and completed streams are inserted back on
//! finish. Cold cached prefixes count as admissible memory — the engine
//! evicts them (leaf-first LRU) when the pool runs dry, so preemption of
//! a live session is always the LAST resort, after every dead prefix
//! already gave its blocks back.
//!
//! Responses stream token chunks back over a bounded channel so callers
//! can render incrementally — the property offloading labors to preserve.
//!
//! Fairness: the round-robin tick gives every live session exactly one
//! decode step per pass, so a long generation cannot starve its
//! neighbors; admission is FIFO, preempted sessions resume before new
//! requests are admitted, and `queue_wait_s` records time spent waiting
//! for a session slot or KV blocks.

pub mod server;

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::engine::{MoeEngine, Session};
use crate::error::{Error, Result};
use crate::model::{ByteTokenizer, Sampler};
use crate::telemetry::{Histogram, Metrics};
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub max_tokens: usize,
    pub temperature: f32,
    pub top_p: f32,
    /// Chat-format the prompt with the training template.
    pub chat: bool,
    /// Wall-clock completion deadline in seconds, measured from enqueue
    /// (it covers queue wait AND serving). `None` falls back to the
    /// `ServingConfig::deadline_s` default; both `None` disables
    /// enforcement. The scheduler checks at tick boundaries and cancels
    /// an over-deadline request with a typed [`Event::Failed`].
    pub deadline_s: Option<f64>,
}

impl Request {
    pub fn new(prompt: impl Into<String>) -> Self {
        Request {
            id: 0,
            prompt: prompt.into(),
            max_tokens: 64,
            temperature: 1.0,
            top_p: 1.0,
            chat: true,
            deadline_s: None,
        }
    }
}

/// Per-request virtual-time breakdown, derived from the engine's
/// per-token accounting when span tracing is on. The four virtual
/// components obey an exact identity: `prefill_compute_s +
/// decode_compute_s + stall_s == prefill virtual time + Σ decode
/// virtual time` — the decode front only ever advances through compute
/// reservations and transfer waits. `transfer_s` counts full transfer
/// durations whether hidden or not, so `transfer_hidden_s = transfer_s
/// - stall_s` is the link time speculative loading kept off the
/// critical path.
#[derive(Debug, Clone, PartialEq)]
pub struct Breakdown {
    /// Wall seconds waiting in the queue before admission.
    pub queue_s: f64,
    /// Virtual seconds of prefill the GPU actually computed.
    pub prefill_compute_s: f64,
    /// Virtual seconds of decode the GPU actually computed.
    pub decode_compute_s: f64,
    /// Virtual link seconds of expert transfers issued for this request
    /// (demand loads, tier reloads, and the speculative prefetches it
    /// triggered), hidden or not.
    pub transfer_s: f64,
    /// The share of `transfer_s` that overlapped compute (never stalled
    /// the decode front).
    pub transfer_hidden_s: f64,
    /// Virtual seconds the request's prefill/decode fronts stalled
    /// waiting on transfers.
    pub stall_s: f64,
}

#[derive(Debug, Clone)]
pub enum Event {
    /// A decoded text fragment.
    Token { request_id: u64, text: String },
    /// Generation finished.
    Done {
        request_id: u64,
        text: String,
        prompt_tokens: usize,
        new_tokens: usize,
        wall_s: f64,
        tokens_per_s_wall: f64,
        tokens_per_s_sim: f64,
        /// Seconds the request waited in the queue before admission.
        queue_wait_s: f64,
        /// Seconds from admission (prefill start) to the first emitted
        /// token — the time-to-first-token the chunked-prefill scheduler
        /// trades against decode stall.
        ttft_s: f64,
        /// Live sessions (including this one) when the request finished.
        active_sessions: u64,
        /// KV pool size in blocks (fixed at engine construction).
        kv_blocks_total: u64,
        /// KV pool occupancy when the request finished (this session's
        /// blocks still counted — they free on drop).
        kv_blocks_in_use: u64,
        kv_blocks_free: u64,
        /// Total KV preemptions (swap-outs to host) since engine start.
        kv_preemptions: u64,
        /// Total preempted-session resumes since engine start.
        kv_resumes: u64,
        /// Whether this request seeded from the prefix cache.
        prefix_hit: bool,
        /// Prefill positions this request skipped via the prefix cache.
        prefix_tokens_reused: u64,
        /// Prefix-cache footprint when the request finished.
        prefix_cache_blocks: u64,
        prefix_cache_tokens: u64,
        /// Total prefix-cache lookup hits / misses since engine start.
        prefix_hits: u64,
        prefix_misses: u64,
        /// Total prefix-cache blocks inserted since engine start.
        prefix_inserted_blocks: u64,
        /// Total prefix-cache blocks evicted since engine start.
        prefix_evicted_blocks: u64,
        /// Total redundant expert stagings avoided by batched-tick union
        /// dedup since engine start (0 with batched decode off).
        expert_loads_deduped: u64,
        /// Total expert kernel invocations issued by the batched decode
        /// path since engine start.
        batched_kernel_calls: u64,
        /// Total batched layer-lockstep ticks since engine start.
        batched_ticks: u64,
        /// Total mixed (prefill-chunk + decode) ticks since engine start
        /// (0 with chunked prefill off).
        mixed_ticks: u64,
        /// Batch width of the most recent batched tick when the request
        /// finished (0 = scheduler has been running sequentially).
        batch_occupancy: u64,
        /// Total cache hits on Hot-tier experts since engine start (0
        /// with tiered quantization off).
        expert_hot_hits: u64,
        /// Total adaptive tier promotions (re-ranks that raised an
        /// expert's precision) since engine start.
        tier_promotions: u64,
        /// Link bytes saved versus staging every transfer at the uniform
        /// base scheme, since engine start.
        link_bytes_saved: u64,
        /// Spans the bounded trace ring dropped since engine start —
        /// non-zero means every span-derived analysis is working from a
        /// truncated record. Always 0 with tracing off.
        trace_spans_dropped: u64,
        /// Total injected faults since engine start (all types; 0 with
        /// `ServingConfig::faults` off).
        faults_injected: u64,
        /// Total transient expert-transfer retries (failed attempts that
        /// recovered via backoff) since engine start.
        transfer_retries: u64,
        /// Total requests that terminated with an error or a typed
        /// failure since engine start.
        requests_failed: u64,
        /// Total requests cancelled for exceeding their deadline since
        /// engine start (a subset of `requests_failed`).
        deadline_cancellations: u64,
        /// Aggregate speculative-prefetch recall in basis points since
        /// engine start: the share of routed experts speculation had
        /// already staged (paper Fig. 2). 0 until anything was routed.
        spec_recall_bp: u64,
        /// Aggregate speculative-prefetch precision in basis points
        /// since engine start: the share of issued prefetches that were
        /// actually used. 0 until anything was issued.
        spec_precision_bp: u64,
        /// Per-request time breakdown — `Some` only when span tracing is
        /// on (`ServingConfig::trace`), so tracing-off serving output
        /// stays byte-identical.
        breakdown: Option<Breakdown>,
    },
    Error { request_id: u64, message: String },
    /// Typed terminal failure: an injected fatal fault, a fault-degraded
    /// session that could not recover, or a deadline cancellation.
    /// Exactly one request fails per event — neighbors in the same
    /// batched tick are untouched — and the client sees a structured
    /// terminal instead of a dropped stream or a panic.
    Failed { request_id: u64, message: String },
}

/// Handle returned to submitters: stream of events for their request.
pub struct ResponseStream {
    pub request_id: u64,
    pub events: Receiver<Event>,
}

impl ResponseStream {
    /// Collect the final text (blocking).
    pub fn wait_text(self) -> Result<String> {
        for ev in self.events.iter() {
            match ev {
                Event::Done { text, .. } => return Ok(text),
                Event::Error { message, .. } => return Err(Error::Serving(message)),
                Event::Failed { message, .. } => return Err(Error::Serving(message)),
                Event::Token { .. } => {}
            }
        }
        Err(Error::Serving("worker dropped".into()))
    }
}

enum Work {
    Run(Request, Sender<Event>, Instant),
    /// Trace-analysis request: the worker answers with the span ring's
    /// critical-path/attribution/what-if report (see
    /// [`crate::trace::analysis`]) on the provided channel.
    Analyze(Sender<Json>),
    /// Expert flight-recorder request: the worker answers with the
    /// per-(layer, expert) counters, prefetch-quality gauges and
    /// counterfactual cache curves (see [`crate::obs`]).
    Experts(Sender<Json>),
    Shutdown,
}

/// A request pulled off the channel but not yet admitted.
struct Pending {
    req: Request,
    tx: Sender<Event>,
    enqueued: Instant,
    /// Prompt tokenized at most once: the admission pre-gate fills this
    /// lazily (it needs token ids for the prefix-aware check) and
    /// `admit` consumes it, so a deferred head is not re-tokenized every
    /// scheduler tick.
    tokens: Option<Vec<u32>>,
}

/// Where a live session is in its lifecycle. With chunked prefill a
/// session is admitted BEFORE its prompt ran: it stays `Prefilling`
/// across ticks (preempt/resume-safe — `fed` counts the positions
/// already written to its KV, prefix-cache seed included) until the
/// last chunk lands, then samples its first token and decodes.
enum Phase {
    /// Prompt still being fed chunk-by-chunk: `prompt[fed..]` remains.
    Prefilling { prompt: Vec<u32>, fed: usize },
    /// Prompt complete; one sampled token per tick.
    Decoding,
}

/// One admitted request: its engine session plus streaming state.
struct LiveSession {
    id: u64,
    tx: Sender<Event>,
    sess: Session,
    sampler: Sampler,
    /// Admission lifecycle: synchronous admission starts `Decoding`;
    /// chunked admission starts `Prefilling` and transitions when the
    /// last prompt chunk lands.
    phase: Phase,
    /// Last sampled token (input to the next decode step).
    next: u32,
    /// Incrementally decoded generation text — also the stop-condition
    /// tail, so the end-of-turn check is O(1) per token instead of
    /// re-decoding the whole generation.
    text: String,
    /// Tokens emitted so far (first one comes from prefill).
    generated: usize,
    /// Per-session token budget (max_tokens capped by the context window).
    budget: usize,
    prompt_tokens: usize,
    /// Every token actually FED through the engine (prompt + sampled
    /// tokens that went through a decode step) — exactly the sequence
    /// the session's KV positions were written from, which is what the
    /// prefix cache indexes on completion.
    fed_tokens: Vec<u32>,
    /// Prefill positions seeded from the prefix cache at admission.
    prefix_reused: usize,
    started: Instant,
    queue_wait_s: f64,
    /// Admission → first emitted token, set when that token is sent
    /// (at admission for synchronous prefill; at the final chunk for
    /// chunked prefill).
    ttft_s: f64,
    /// Admission order (monotone): preemption always picks the youngest.
    admit_seq: u64,
    /// How many times this session has been swapped out (runaway guard).
    preempt_count: u32,
    /// Wall-clock instant this request must finish by (enqueue time +
    /// its effective deadline), `None` when no deadline applies. Checked
    /// at tick boundaries; preempted sessions keep theirs.
    deadline_at: Option<Instant>,
}

/// The coordinator: owns the engine worker thread.
pub struct Coordinator {
    work_tx: Sender<Work>,
    next_id: AtomicU64,
    pub metrics: Arc<Metrics>,
    running: Arc<AtomicBool>,
    /// `ServingConfig::request_timeout_s` as `f64` bits, published by
    /// the worker once the engine is built — it bounds client-facing
    /// waits like [`Coordinator::analyze`]. Until the engine exists,
    /// readers see the config default (120 s).
    request_timeout_s: Arc<AtomicU64>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// `make_engine` runs on the worker thread — PJRT handles are not
    /// `Send`, so the engine must be *built* where it lives. The
    /// scheduler's concurrency comes from the engine's
    /// `max_concurrent_sessions` (set via [`crate::config::ServingConfig`]).
    pub fn new<F>(make_engine: F, seed: u64) -> Self
    where
        F: FnOnce() -> Result<MoeEngine> + Send + 'static,
    {
        let (work_tx, work_rx) = channel::<Work>();
        let metrics = Arc::new(Metrics::new());
        let running = Arc::new(AtomicBool::new(true));
        let request_timeout_s = Arc::new(AtomicU64::new(120.0f64.to_bits()));
        let m = Arc::clone(&metrics);
        let r = Arc::clone(&running);
        let t = Arc::clone(&request_timeout_s);
        let worker = std::thread::spawn(move || {
            let mut engine = match make_engine() {
                Ok(e) => e,
                Err(e) => {
                    // fail every queued request with the build error
                    while let Ok(work) = work_rx.recv() {
                        match work {
                            Work::Run(req, tx, _) => {
                                let _ = tx.send(Event::Error {
                                    request_id: req.id,
                                    message: format!("engine init failed: {e}"),
                                });
                            }
                            // dropping the sender fails the analyze()/
                            // experts() call explicitly instead of
                            // hanging it
                            Work::Analyze(_) => {}
                            Work::Experts(_) => {}
                            Work::Shutdown => break,
                        }
                    }
                    r.store(false, Ordering::SeqCst);
                    return;
                }
            };
            t.store(engine.request_timeout_s.to_bits(), Ordering::SeqCst);
            scheduler_loop(&mut engine, &work_rx, seed, &m);
            r.store(false, Ordering::SeqCst);
        });
        Coordinator {
            work_tx,
            next_id: AtomicU64::new(1),
            metrics,
            running,
            request_timeout_s,
            worker: Some(worker),
        }
    }

    /// Enqueue a request; returns a stream of events.
    pub fn submit(&self, mut req: Request) -> ResponseStream {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        req.id = id;
        let (tx, rx) = channel();
        self.metrics.inc("requests_enqueued", 1);
        let _ = self.work_tx.send(Work::Run(req, tx, Instant::now()));
        ResponseStream { request_id: id, events: rx }
    }

    /// Ask the worker for the span ring's analysis report: per-window
    /// utilization, per-request critical paths, aggregate bottleneck
    /// attribution, and what-if projections (see
    /// [`crate::trace::analysis::analyze_response`]). Answered between
    /// scheduling ticks, so the report is always a consistent snapshot.
    /// With tracing off the response degrades to an explicit
    /// `{"enabled": false, "error": "tracing disabled"}` object.
    pub fn analyze(&self) -> Result<Json> {
        let (tx, rx) = channel();
        self.work_tx
            .send(Work::Analyze(tx))
            .map_err(|_| Error::Serving("engine worker is gone".into()))?;
        // the wait is bounded by ServingConfig::request_timeout_s (not a
        // hard-coded constant): validate() guarantees it finite, > 0 and
        // ≤ MAX_REQUEST_TIMEOUT_S; the fallible conversion is belt-and-
        // braces so an unvalidated value still can't panic this thread
        let timeout_s = f64::from_bits(self.request_timeout_s.load(Ordering::SeqCst));
        let timeout = Duration::try_from_secs_f64(timeout_s)
            .unwrap_or(Duration::from_secs(86_400));
        rx.recv_timeout(timeout).map_err(|_| {
            Error::Timeout(format!(
                "analyze request got no answer within {timeout_s}s \
                 (ServingConfig::request_timeout_s)"
            ))
        })
    }

    /// Ask the worker for the expert flight recorder's report:
    /// per-(layer, expert) use/hit/load/eviction counters,
    /// virtual-time-weighted residency, wire bytes by tier, per-layer
    /// prefetch-quality gauges, and the counterfactual LRU/OPT cache
    /// curves (see [`crate::obs`]). Answered between scheduling ticks,
    /// so the snapshot is consistent. With `ServingConfig::expert_obs`
    /// off the response degrades to an explicit `{"enabled": false,
    /// "error": "expert observability disabled"}` object.
    pub fn experts(&self) -> Result<Json> {
        let (tx, rx) = channel();
        self.work_tx
            .send(Work::Experts(tx))
            .map_err(|_| Error::Serving("engine worker is gone".into()))?;
        let timeout_s = f64::from_bits(self.request_timeout_s.load(Ordering::SeqCst));
        let timeout = Duration::try_from_secs_f64(timeout_s)
            .unwrap_or(Duration::from_secs(86_400));
        rx.recv_timeout(timeout).map_err(|_| {
            Error::Timeout(format!(
                "experts request got no answer within {timeout_s}s \
                 (ServingConfig::request_timeout_s)"
            ))
        })
    }

    /// Whether the engine worker is still alive.
    pub fn is_running(&self) -> bool {
        self.running.load(Ordering::SeqCst)
    }

    pub fn shutdown(mut self) {
        let _ = self.work_tx.send(Work::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.work_tx.send(Work::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// The continuous-batching loop: pull requests into a local FIFO, resume
/// preempted sessions, admit new ones while the width cap and the KV
/// block pool allow, then give every live session one decode step per
/// tick — preempting the youngest session when the pool runs dry.
fn scheduler_loop(
    engine: &mut MoeEngine,
    work_rx: &Receiver<Work>,
    seed: u64,
    m: &Metrics,
) {
    let max_sessions = engine.max_concurrent_sessions.max(1);
    let tokenizer = ByteTokenizer::new();
    let mut active: VecDeque<LiveSession> = VecDeque::new();
    // sessions swapped out to host, oldest first (FIFO resume)
    let mut preempted: VecDeque<LiveSession> = VecDeque::new();
    // requests pulled off the channel but not yet admitted; a request
    // refused for lack of KV blocks goes back to the FRONT, so FIFO
    // order survives deferral
    let mut pending: VecDeque<Pending> = VecDeque::new();
    let mut accepting = true;
    let mut next_admit_seq: u64 = 0;

    loop {
        // 1) drain the channel into the local queue. Block only when
        // fully idle; with live or deferred work we poll so decode flows.
        loop {
            let idle =
                active.is_empty() && preempted.is_empty() && pending.is_empty();
            let work = if idle && accepting {
                match work_rx.recv() {
                    Ok(w) => w,
                    Err(_) => {
                        accepting = false;
                        break;
                    }
                }
            } else {
                match work_rx.try_recv() {
                    Ok(w) => w,
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        accepting = false;
                        break;
                    }
                }
            };
            match work {
                Work::Run(req, tx, enqueued) => {
                    pending.push_back(Pending { req, tx, enqueued, tokens: None })
                }
                Work::Analyze(tx) => {
                    // the per-tick gauge mirror (step 5) runs AFTER this
                    // drain, so refresh the fault gauges first — a caller
                    // reading gauges once analyze() returns must not miss
                    // the final tick's injected/retry counts
                    let fs = engine.fault_stats();
                    m.record_faults(fs.injected, fs.transfer_retries);
                    let _ = tx.send(crate::trace::analysis::analyze_response(
                        &engine.tracer,
                        &engine.cost,
                    ));
                }
                Work::Experts(tx) => {
                    // same freshness rule as analyze(): refresh the
                    // prefetch-quality gauges before answering, so a
                    // caller reading gauges once experts() returns sees
                    // the final tick's recall/precision
                    m.record_spec(
                        crate::obs::to_bp(engine.cache.stats.spec.recall()),
                        crate::obs::to_bp(engine.cache.stats.spec.precision()),
                    );
                    // experts_report drains the manager's pending log
                    // first, so the snapshot includes everything up to
                    // the last completed tick
                    let _ = tx.send(engine.experts_report());
                }
                Work::Shutdown => {
                    // finish live sessions, drop anything still queued
                    accepting = false;
                    pending.clear();
                    break;
                }
            }
        }

        // 2) resume preempted sessions FIRST (oldest first) — they were
        // admitted before anything still pending, and starving them would
        // let new work steal the blocks they are waiting for.
        while !preempted.is_empty() && active.len() < max_sessions {
            // don't bother restoring a stream the pool can't even give a
            // next decode step — it would be re-preempted immediately
            // (free blocks + cold cached prefixes count as available:
            // resume_session reclaims the latter before giving up)
            let next_tokens = preempted.front().unwrap().sess.position() + 1;
            if !engine.kv_can_admit(next_tokens) {
                if active.is_empty() {
                    // whole pool is free and still too small: permanent
                    let live = preempted.pop_front().unwrap();
                    m.inc("requests_failed", 1);
                    let _ = live.tx.send(Event::Error {
                        request_id: live.id,
                        message: format!(
                            "kv pool of {} tokens cannot resume a session at \
                             position {}",
                            engine.kv_pool.capacity_tokens(),
                            next_tokens - 1
                        ),
                    });
                    continue;
                }
                break;
            }
            let mut live = preempted.pop_front().unwrap();
            match engine.resume_session(&mut live.sess) {
                Ok(()) => {
                    m.inc("kv_resumes", 1);
                    active.push_back(live);
                }
                Err(Error::KvPoolExhausted(msg)) => {
                    if active.is_empty() {
                        // nothing left to free blocks — the pool can never
                        // back this stream again
                        m.inc("requests_failed", 1);
                        let _ = live.tx.send(Event::Error {
                            request_id: live.id,
                            message: format!("kv pool cannot resume session: {msg}"),
                        });
                    } else {
                        preempted.push_front(live);
                        break;
                    }
                }
                Err(e) => {
                    m.inc("requests_failed", 1);
                    let _ = live.tx.send(Event::Error {
                        request_id: live.id,
                        message: e.to_string(),
                    });
                }
            }
        }

        // 3) fail queued requests whose deadline already passed — an
        // over-deadline request must not consume a width slot and a
        // prefill just to be cancelled at its first tick. One rotation
        // through the deque preserves FIFO order; with no deadlines
        // configured every entry falls through untouched.
        for _ in 0..pending.len() {
            let p = pending.pop_front().unwrap();
            let over = effective_deadline_s(engine, &p.req)
                .is_some_and(|d| p.enqueued.elapsed().as_secs_f64() >= d);
            if over {
                m.inc("requests_failed", 1);
                m.inc("deadline_cancellations", 1);
                let _ = p.tx.send(Event::Failed {
                    request_id: p.req.id,
                    message: "deadline exceeded before admission".into(),
                });
            } else {
                pending.push_back(p);
            }
        }

        // 4) admit new requests while a width slot and KV blocks allow
        while !pending.is_empty() && preempted.is_empty() && active.len() < max_sessions {
            // coarse pre-gate: the byte tokenizer yields at least
            // prompt.len() tokens, so when the pool clearly can't take
            // the queue head yet, skip the whole admit path (session
            // open + prefill setup) instead of re-running it every tick.
            // With the prefix cache on, the head is tokenized (once —
            // the Pending entry caches it) so blocks its cached trunk
            // would seed (retained, not allocated) don't count against
            // free capacity: a warm request must not wait behind
            // capacity its own prefix already covers. With nothing live
            // the gate is bypassed so an impossible request still fails
            // permanently in admit().
            // chunked prefill commits blocks chunk-by-chunk, so the
            // free list overstates what a NEW admission may take:
            // reserve the unfed remainder of every in-flight prefilling
            // session (zero with chunked off — no session ever parks in
            // Prefilling there, keeping the gate byte-identical)
            let reserved_blocks: usize = active
                .iter()
                .filter_map(|l| match &l.phase {
                    Phase::Prefilling { prompt, .. } => Some(
                        engine
                            .kv_pool
                            .blocks_for(prompt.len() + 1)
                            .saturating_sub(l.sess.kv.mapped_blocks()),
                    ),
                    Phase::Decoding => None,
                })
                .sum();
            let gate_open = {
                let head = pending.front_mut().unwrap();
                if engine.prefix.is_some() {
                    if head.tokens.is_none() {
                        head.tokens = Some(if head.req.chat {
                            tokenizer.chat_turn(&head.req.prompt)
                        } else {
                            tokenizer.encode(&head.req.prompt)
                        });
                    }
                    engine.kv_can_admit_prompt_reserving(
                        head.tokens.as_ref().expect("just filled"),
                        reserved_blocks,
                    )
                } else {
                    engine.kv_can_admit_reserving(head.req.prompt.len() + 1, reserved_blocks)
                }
            };
            if !gate_open && !(active.is_empty() && preempted.is_empty()) {
                break;
            }
            let head = pending.pop_front().unwrap();
            let (tx, enqueued, tokens) = (head.tx, head.enqueued, head.tokens);
            let queue_wait_s = enqueued.elapsed().as_secs_f64();
            // chunked admission opens the session (and seeds it from the
            // prefix cache) but feeds the prompt across ticks instead of
            // stalling every live decode on a synchronous prefill
            let seq = next_admit_seq;
            let outcome = if engine.planner.chunked_prefill {
                admit_chunked(engine, &tokenizer, head.req, tokens, seed, tx, queue_wait_s, seq)
            } else {
                admit(engine, &tokenizer, head.req, tokens, seed, tx, queue_wait_s, seq)
            };
            match outcome {
                Ok(Some(live)) => {
                    next_admit_seq += 1;
                    m.inc("requests_started", 1);
                    m.observe("queue_wait_s", queue_wait_s);
                    if matches!(live.phase, Phase::Decoding) {
                        // synchronous prefill already emitted the first
                        // token; chunked admissions record TTFT at their
                        // final chunk instead
                        m.observe("ttft_s", live.ttft_s);
                    }
                    if live.generated >= live.budget {
                        // single-token budget: finished at prefill
                        finish(m, engine, live, active.len() as u64 + 1);
                    } else {
                        active.push_back(live);
                    }
                }
                Ok(None) => {
                    m.inc("requests_cancelled", 1);
                }
                Err((req, toks, tx, e)) => {
                    let transient = matches!(e, Error::KvPoolExhausted(_))
                        && !(active.is_empty() && preempted.is_empty());
                    if transient {
                        // live sessions will free blocks as they finish —
                        // defer, preserving FIFO order and the already-
                        // tokenized prompt
                        pending.push_front(Pending { req, tx, enqueued, tokens: Some(toks) });
                        break;
                    }
                    m.inc("requests_started", 1);
                    m.observe("queue_wait_s", queue_wait_s);
                    m.inc("requests_failed", 1);
                    let _ = tx.send(Event::Error { request_id: req.id, message: e.to_string() });
                }
            }
        }

        // 5) tick-boundary robustness pass, BEFORE the tick dispatch
        // touches any shared state: cancel over-deadline sessions with a
        // typed Failed event, then consult the fault injector's
        // per-session pre-gate — a degraded or failed session simply
        // drops out of this tick's batch, never poisoning it. Both
        // checks are no-ops in a default (no-deadline, faults-off) build.
        for _ in 0..preempted.len() {
            let live = preempted.pop_front().unwrap();
            if deadline_passed(&live) {
                fail_deadline(m, live);
            } else {
                preempted.push_back(live);
            }
        }
        for _ in 0..active.len() {
            let mut live = active.pop_front().unwrap();
            if deadline_passed(&live) {
                // the session (and its KV blocks) free on drop;
                // neighbors keep decoding undisturbed
                fail_deadline(m, live);
                continue;
            }
            match engine.fault_gate(live.id) {
                None => active.push_back(live),
                Some(Error::FaultTransient(msg)) => {
                    // retry budget exhausted: degrade through the
                    // existing preempt/requeue path — the session swaps
                    // out and resumes bit-identically once re-admitted
                    if live.preempt_count >= MAX_PREEMPTIONS_PER_SESSION {
                        m.inc("requests_failed", 1);
                        let _ = live.tx.send(Event::Failed {
                            request_id: live.id,
                            message: format!(
                                "session degraded {MAX_PREEMPTIONS_PER_SESSION} \
                                 times without completing: {msg}"
                            ),
                        });
                        continue;
                    }
                    match engine.preempt_session(&mut live.sess) {
                        Ok(()) => {
                            live.preempt_count += 1;
                            preempted.push_back(live);
                        }
                        Err(e) => {
                            m.inc("requests_failed", 1);
                            let _ = live.tx.send(Event::Failed {
                                request_id: live.id,
                                message: format!("fault degradation failed: {e}"),
                            });
                        }
                    }
                }
                Some(e) => {
                    // fatal injected fault: exactly this request fails,
                    // with a typed event instead of a panic
                    m.inc("requests_failed", 1);
                    let _ = live.tx.send(Event::Failed {
                        request_id: live.id,
                        message: e.to_string(),
                    });
                }
            }
        }

        m.set_gauge("active_sessions", active.len() as u64);
        let kv = engine.kv_pool.stats();
        m.record_kv_pool(
            kv.total_blocks as u64,
            kv.free_blocks as u64,
            kv.in_use_blocks as u64,
            kv.preemptions,
        );
        m.record_tiers(
            engine.tiers.hot_hits,
            engine.tiers.promotions,
            engine.tiers.bytes_saved(),
        );
        let fs = engine.fault_stats();
        m.record_faults(fs.injected, fs.transfer_retries);
        // prefetch quality (paper Fig 2): recall = share of routed
        // experts speculation had staged, precision = share of issued
        // prefetches that were used. Recorded unconditionally — both
        // read 0 until speculation has issued/used anything.
        m.record_spec(
            crate::obs::to_bp(engine.cache.stats.spec.recall()),
            crate::obs::to_bp(engine.cache.stats.spec.precision()),
        );
        // flight-recorder tick: fold the manager's event log and sample
        // the residency/hit-rate counter tracks (branch-on-a-bool when
        // expert_obs is off)
        engine.obs_tick();
        // ring overflow visibility: spans silently aged out of the trace
        // ring bias every downstream analysis, so operators must see the
        // count (0 whenever tracing is off or the ring kept up)
        m.set_gauge("trace_spans_dropped", engine.tracer.dropped());
        if let Some(cache) = engine.prefix.as_ref() {
            let s = cache.stats();
            m.record_prefix(
                cache.cached_blocks() as u64,
                cache.cached_tokens() as u64,
                s.hits,
                s.misses,
                s.tokens_reused,
                s.inserted_blocks,
                s.evicted_blocks,
            );
        }

        if active.is_empty() {
            if preempted.is_empty() && pending.is_empty() && !accepting {
                break;
            }
            continue;
        }

        // 6) one scheduling tick: exactly one decode step per live
        // decoding session, plus — with chunked prefill — at most one
        // prompt chunk of the oldest admission still prefilling.
        // Batched mode advances them together through decode_batch /
        // step_mixed (layer lockstep, expert-deduped); sequential mode
        // round-robins decode_step in admission order. Per-session
        // output is identical either way.
        m.inc("scheduler_ticks", 1);
        let has_prefilling = active
            .iter()
            .any(|l| matches!(l.phase, Phase::Prefilling { .. }));
        if has_prefilling {
            // only reachable with chunked_prefill on — the synchronous
            // admission path never parks a Prefilling session
            mixed_tick(engine, &tokenizer, m, &mut active, &mut preempted);
        } else if engine.batched_decode && active.len() >= 2 {
            batched_tick(engine, &tokenizer, m, &mut active, &mut preempted);
        } else {
            let n = active.len();
            for _ in 0..n {
                let mut live = active.pop_front().unwrap();
                match step(engine, &tokenizer, &mut live) {
                    Ok(StepOutcome::Continue) => active.push_back(live),
                    Ok(StepOutcome::Finished) => {
                        finish(m, engine, live, active.len() as u64 + 1)
                    }
                    Ok(StepOutcome::Cancelled) => {
                        // client went away: free the slot instead of decoding
                        // the rest of the budget into a dropped channel
                        m.inc("requests_cancelled", 1);
                    }
                    Err(Error::KvPoolExhausted(msg)) => {
                        // pool dry mid-decode: swap the youngest session's KV
                        // to host and requeue it so older streams finish.
                        // decode_step commits blocks before any state change,
                        // so `live` retries its step cleanly next tick.
                        preempt_youngest(engine, m, &mut active, &mut preempted, live, &msg);
                    }
                    Err(e) => {
                        // the failing session is dropped; its neighbors keep
                        // their own KV state and continue undisturbed
                        m.inc("requests_failed", 1);
                        let _ = live.tx.send(Event::Error {
                            request_id: live.id,
                            message: e.to_string(),
                        });
                    }
                }
            }
        }
        m.set_gauge("active_sessions", active.len() as u64);
    }
}

/// One batched scheduling tick: all live sessions advance one token
/// through [`MoeEngine::decode_batch`] in layer lockstep. Per-session
/// outcomes mirror the sequential loop's: a KV-dry slot degrades that
/// session to the preempt/retry path (its step didn't run — nothing was
/// fed, so the retry is clean) WITHOUT poisoning the rest of the batch,
/// and a failed slot drops only its own session.
fn batched_tick(
    engine: &mut MoeEngine,
    tokenizer: &ByteTokenizer,
    m: &Metrics,
    active: &mut VecDeque<LiveSession>,
    preempted: &mut VecDeque<LiveSession>,
) {
    let mut lives: Vec<LiveSession> = active.drain(..).collect();
    let toks: Vec<u32> = lives.iter().map(|l| l.next).collect();
    let results = {
        let mut refs: Vec<&mut Session> =
            lives.iter_mut().map(|l| &mut l.sess).collect();
        engine.decode_batch(&mut refs, &toks)
    };
    let results = match results {
        Ok(r) => r,
        Err(e) => {
            // engine failure mid-tick: the participants' KV/position
            // state is indeterminate — fail them all loudly rather than
            // continue decoding garbage
            for live in lives {
                m.inc("requests_failed", 1);
                let _ = live.tx.send(Event::Error {
                    request_id: live.id,
                    message: e.to_string(),
                });
            }
            return;
        }
    };
    let b = engine.batch;
    m.record_batch(b.last_occupancy, b.ticks, b.kernel_calls, b.loads_deduped, b.mixed_ticks);

    // KV-dry sessions are collected and handled AFTER the survivors
    // rejoin `active`, so the youngest-victim policy sees the same
    // candidate set the sequential loop would. They are in batch order,
    // which is admission order.
    let n_slots = results.len();
    let mut dry: Vec<(LiveSession, String)> = Vec::new();
    for (k, (slot, mut live)) in results.into_iter().zip(lives).enumerate() {
        match slot {
            Ok(logits) => match advance(engine, tokenizer, &mut live, logits) {
                StepOutcome::Continue => active.push_back(live),
                StepOutcome::Finished => {
                    // count every session still live at this moment, as
                    // the sequential loop would see them in `active`:
                    // survivors so far, dry ones awaiting retry, and the
                    // not-yet-processed rest of the batch
                    let others = active.len() + dry.len() + (n_slots - k - 1);
                    finish(m, engine, live, others as u64 + 1)
                }
                StepOutcome::Cancelled => {
                    m.inc("requests_cancelled", 1);
                }
            },
            Err(Error::KvPoolExhausted(msg)) => dry.push((live, msg)),
            Err(e) => {
                m.inc("requests_failed", 1);
                let _ = live.tx.send(Event::Error {
                    request_id: live.id,
                    message: e.to_string(),
                });
            }
        }
    }
    // A dry session is still live — it couldn't take a block this tick
    // and retries next tick. Resolve pool pressure for the OLDEST dry
    // session now; the younger dry ones rejoin `active` FIRST so the
    // youngest-victim policy can pick one of them (exactly what the
    // sequential loop does when every live session hits the dry pool in
    // one pass — preempting the youngest, never failing the oldest).
    // If the pool stays dry their own retries drive further preemptions.
    let mut dry = dry.into_iter();
    if let Some((live, msg)) = dry.next() {
        for (younger, _) in dry {
            active.push_back(younger);
        }
        preempt_youngest(engine, m, active, preempted, live, &msg);
    }
}

/// One MIXED scheduling tick (chunked prefill on, ≥ 1 session still
/// prefilling): plan the tick — every decoding session gets its one
/// decode step, and the oldest prefilling session gets at most one
/// token-budgeted prompt chunk — then execute it fused through
/// [`MoeEngine::step_mixed`] (batched mode) or interleaved (sequential
/// fallback). Slot outcomes map to the same handling as the plain
/// batched tick: KV-dry slots degrade to preempt/retry (a dry CHUNK
/// preempts too — typically the prefilling session itself, which is the
/// youngest; it resumes mid-prompt bit-identically), failures drop only
/// their own session.
fn mixed_tick(
    engine: &mut MoeEngine,
    tokenizer: &ByteTokenizer,
    m: &Metrics,
    active: &mut VecDeque<LiveSession>,
    preempted: &mut VecDeque<LiveSession>,
) {
    // plan over the live set in ADMISSION order: `active` is only
    // approximately admission-ordered (resume and dry-requeue append at
    // the back), and the chunk contract is the OLDEST pending admission
    // — a resumed older prefill must not lose its turn to a younger one
    let mut order: Vec<usize> = (0..active.len()).collect();
    order.sort_by_key(|&i| active[i].admit_seq);
    let items: Vec<crate::sched::WorkItem> = order
        .iter()
        .map(|&i| match &active[i].phase {
            Phase::Decoding => crate::sched::WorkItem::Decode,
            Phase::Prefilling { prompt, fed } => {
                crate::sched::WorkItem::Prefill { remaining: prompt.len() - fed }
            }
        })
        .collect();
    let plan = engine.planner.plan(&items);
    // translate the plan's chunk target back to `active` indexing
    let chunk_plan: Option<(usize, usize)> =
        plan.chunk.map(|cp| (order[cp.idx], cp.tokens));
    if !engine.batched_decode {
        let chunk = chunk_plan.map(|(i, n)| (active[i].admit_seq, n));
        mixed_tick_sequential(engine, tokenizer, m, active, preempted, chunk);
        return;
    }

    let mut lives: Vec<LiveSession> = active.drain(..).collect();
    // pull the chunk's session out of the vec so the borrow checker sees
    // disjoint &mut Sessions; fused ticks feed at most one compiled
    // prefill module call per layer, so clamp to that width
    let chunk_cap = engine.weights.cfg.prefill_chunk;
    let mut chunk_live: Option<(LiveSession, usize)> =
        chunk_plan.map(|(idx, tokens)| (lives.remove(idx), tokens.min(chunk_cap)));
    let toks: Vec<u32> = lives
        .iter()
        .filter(|l| matches!(l.phase, Phase::Decoding))
        .map(|l| l.next)
        .collect();
    let outcome = {
        let mut refs: Vec<&mut Session> = lives
            .iter_mut()
            .filter(|l| matches!(l.phase, Phase::Decoding))
            .map(|l| &mut l.sess)
            .collect();
        let chunk = chunk_live.as_mut().map(|(cl, n)| {
            let Phase::Prefilling { prompt, fed } = &cl.phase else {
                unreachable!("the planner only schedules Prefilling sessions")
            };
            let end = (*fed + *n).min(prompt.len());
            chunk_of(&mut cl.sess, &prompt[*fed..end])
        });
        engine.step_mixed(&mut refs, &toks, chunk)
    };
    let (results, chunk_slot) = match outcome {
        Ok(r) => r,
        Err(e) => {
            // engine failure mid-tick: the PARTICIPANTS' state is
            // indeterminate — fail them loudly (as batched_tick). Idle
            // prefilling sessions never entered the tick (the chunk's
            // session was extracted from `lives`, so any Prefilling
            // session still there sat this tick out): their state is
            // untouched and they simply survive to the next one.
            for live in lives {
                if matches!(live.phase, Phase::Prefilling { .. }) {
                    active.push_back(live);
                    continue;
                }
                m.inc("requests_failed", 1);
                let _ = live.tx.send(Event::Error {
                    request_id: live.id,
                    message: e.to_string(),
                });
            }
            if let Some((cl, _)) = chunk_live {
                m.inc("requests_failed", 1);
                let _ = cl.tx.send(Event::Error {
                    request_id: cl.id,
                    message: e.to_string(),
                });
            }
            return;
        }
    };
    let b = engine.batch;
    m.record_batch(b.last_occupancy, b.ticks, b.kernel_calls, b.loads_deduped, b.mixed_ticks);

    // process outcomes; survivors re-queue in admission order afterwards
    let mut survivors: Vec<LiveSession> = Vec::new();
    let mut dry: Vec<(LiveSession, String)> = Vec::new();
    let mut finished: Vec<LiveSession> = Vec::new();
    let mut slots = results.into_iter();
    for mut live in lives {
        if !matches!(live.phase, Phase::Decoding) {
            // a prefilling session not scheduled this tick idles
            survivors.push(live);
            continue;
        }
        let slot = slots.next().expect("one slot per decoding session");
        match slot {
            Ok(logits) => match advance(engine, tokenizer, &mut live, logits) {
                StepOutcome::Continue => survivors.push(live),
                StepOutcome::Finished => finished.push(live),
                StepOutcome::Cancelled => {
                    m.inc("requests_cancelled", 1);
                }
            },
            Err(Error::KvPoolExhausted(msg)) => dry.push((live, msg)),
            Err(e) => {
                m.inc("requests_failed", 1);
                let _ = live.tx.send(Event::Error {
                    request_id: live.id,
                    message: e.to_string(),
                });
            }
        }
    }
    if let Some((mut cl, _)) = chunk_live {
        match chunk_slot.expect("a submitted chunk always yields a slot") {
            Ok(logits) => {
                let fed_now = logits.shape[0];
                match advance_prefill(m, tokenizer, &mut cl, fed_now, &logits) {
                    StepOutcome::Continue => survivors.push(cl),
                    StepOutcome::Finished => finished.push(cl),
                    StepOutcome::Cancelled => {
                        m.inc("requests_cancelled", 1);
                    }
                }
            }
            Err(Error::KvPoolExhausted(msg)) => dry.push((cl, msg)),
            Err(e) => {
                m.inc("requests_failed", 1);
                let _ = cl.tx.send(Event::Error { request_id: cl.id, message: e.to_string() });
            }
        }
    }

    // re-queue in admission order (mixed processing visits decode slots
    // before idle/chunk sessions, which can interleave arbitrarily)
    survivors.sort_by_key(|l| l.admit_seq);
    active.extend(survivors);
    // as in batched_tick, a finishing session counts its co-finishers
    // that have not been emitted yet as still live
    let n_finished = finished.len();
    for (k, live) in finished.into_iter().enumerate() {
        let others = active.len() + dry.len() + (n_finished - k - 1);
        finish(m, engine, live, others as u64 + 1);
    }
    // resolve pool pressure for the OLDEST dry session; younger dry ones
    // rejoin first so the youngest-victim policy can pick one of them
    // (exactly as batched_tick)
    let mut dry = dry.into_iter();
    if let Some((live, msg)) = dry.next() {
        for (younger, _) in dry {
            active.push_back(younger);
        }
        preempt_youngest(engine, m, active, preempted, live, &msg);
    }
}

/// Borrow helper: a [`crate::engine::PrefillChunk`] over one live
/// session's next prompt span (split borrows of disjoint `LiveSession`
/// fields).
fn chunk_of<'a>(sess: &'a mut Session, tokens: &'a [u32]) -> crate::engine::PrefillChunk<'a> {
    crate::engine::PrefillChunk { sess, tokens }
}

/// The sequential fallback of a mixed tick (`batched_decode = false`):
/// round-robin one decode step per decoding session, and feed the
/// planned chunk — `(admit_seq of the target, tokens)`, matched by seq
/// because rotation order is not admission order after preempt/resume —
/// via a plain resumable [`MoeEngine::prefill`] call. No expert-union
/// fusion, but the same chunked admission semantics (and the same
/// bit-identical streams).
fn mixed_tick_sequential(
    engine: &mut MoeEngine,
    tokenizer: &ByteTokenizer,
    m: &Metrics,
    active: &mut VecDeque<LiveSession>,
    preempted: &mut VecDeque<LiveSession>,
    chunk: Option<(u64, usize)>,
) {
    let n = active.len();
    let mut chunk = chunk;
    for _ in 0..n {
        let mut live = active.pop_front().unwrap();
        if let Phase::Prefilling { .. } = live.phase {
            let scheduled = matches!(chunk, Some((seq, _)) if seq == live.admit_seq);
            if !scheduled {
                active.push_back(live);
                continue;
            }
            let n_tok = chunk.take().expect("matched above").1;
            let (fed_now, result) = {
                let Phase::Prefilling { prompt, fed } = &live.phase else {
                    unreachable!("checked above")
                };
                let end = (*fed + n_tok).min(prompt.len());
                let chunk = &prompt[*fed..end];
                (chunk.len(), engine.prefill(&mut live.sess, chunk))
            };
            match result {
                Ok(logits) => {
                    match advance_prefill(m, tokenizer, &mut live, fed_now, &logits) {
                        StepOutcome::Continue => active.push_back(live),
                        StepOutcome::Finished => {
                            finish(m, engine, live, active.len() as u64 + 1)
                        }
                        StepOutcome::Cancelled => {
                            m.inc("requests_cancelled", 1);
                        }
                    }
                }
                Err(Error::KvPoolExhausted(msg)) => {
                    // prefill commits blocks all-or-nothing before any
                    // compute, so the chunk retries cleanly after a
                    // preemption frees memory
                    preempt_youngest(engine, m, active, preempted, live, &msg);
                }
                Err(e) => {
                    m.inc("requests_failed", 1);
                    let _ = live.tx.send(Event::Error {
                        request_id: live.id,
                        message: e.to_string(),
                    });
                }
            }
        } else {
            match step(engine, tokenizer, &mut live) {
                Ok(StepOutcome::Continue) => active.push_back(live),
                Ok(StepOutcome::Finished) => {
                    finish(m, engine, live, active.len() as u64 + 1)
                }
                Ok(StepOutcome::Cancelled) => {
                    m.inc("requests_cancelled", 1);
                }
                Err(Error::KvPoolExhausted(msg)) => {
                    preempt_youngest(engine, m, active, preempted, live, &msg);
                }
                Err(e) => {
                    m.inc("requests_failed", 1);
                    let _ = live.tx.send(Event::Error {
                        request_id: live.id,
                        message: e.to_string(),
                    });
                }
            }
        }
    }
}

/// Advance a `Prefilling` session by one successfully fed chunk. While
/// prompt remains the session just keeps waiting its turn; the FINAL
/// chunk samples the first token from its last logits row (bit-identical
/// to synchronous admission's sample — same position, same sampler
/// state), emits it (TTFT), and flips the session to `Decoding`.
fn advance_prefill(
    m: &Metrics,
    tokenizer: &ByteTokenizer,
    live: &mut LiveSession,
    fed_now: usize,
    logits: &crate::tensor::Tensor,
) -> StepOutcome {
    let Phase::Prefilling { prompt, fed } = &mut live.phase else {
        unreachable!("advance_prefill is only called on Prefilling sessions")
    };
    *fed += fed_now;
    if *fed < prompt.len() {
        return StepOutcome::Continue;
    }
    // last chunk: first token, exactly as synchronous admission emits it
    live.next = live.sampler.sample(logits.row(fed_now - 1)) as u32;
    let piece = tokenizer.decode(&[live.next]);
    live.fed_tokens = std::mem::take(prompt);
    live.phase = Phase::Decoding;
    live.generated = 1;
    live.text = piece.clone();
    live.ttft_s = live.started.elapsed().as_secs_f64();
    if live
        .tx
        .send(Event::Token { request_id: live.id, text: piece })
        .is_err()
    {
        // client went away while the prompt was feeding — don't let the
        // dead request's (idle-inflated) TTFT skew the histogram; the
        // synchronous path likewise records nothing for a dropped stream
        return StepOutcome::Cancelled;
    }
    m.observe("ttft_s", live.ttft_s);
    if live.generated >= live.budget {
        StepOutcome::Finished
    } else {
        StepOutcome::Continue
    }
}

/// The deadline that applies to `req`, in wall seconds from its enqueue
/// time: the request's own `deadline_s` wins over the
/// `ServingConfig::deadline_s` default. Client-supplied values are
/// sanitized here (non-finite or non-positive ⇒ ignored) — `Request`
/// fields arrive from the wire unvalidated, and
/// `Duration::from_secs_f64` panics on garbage.
fn effective_deadline_s(engine: &MoeEngine, req: &Request) -> Option<f64> {
    req.deadline_s
        .filter(|d| d.is_finite() && *d > 0.0)
        .or(engine.default_deadline_s)
}

/// The wall-clock instant an admitted request must finish by. `started`
/// is the admission instant and `queue_wait_s` what the request already
/// spent queued, so the deadline is anchored at ENQUEUE time — a request
/// cannot buy more lifetime by waiting longer. Finite-but-huge wire
/// values (e.g. 1e20, which passes the sign/finiteness sanitization)
/// overflow `Duration`/`Instant` arithmetic, so they degrade to "no
/// deadline" here instead of panicking the engine worker.
fn deadline_at(
    engine: &MoeEngine,
    req: &Request,
    started: Instant,
    queue_wait_s: f64,
) -> Option<Instant> {
    let d = effective_deadline_s(engine, req)?;
    let dur = Duration::try_from_secs_f64((d - queue_wait_s).max(0.0)).ok()?;
    started.checked_add(dur)
}

fn deadline_passed(live: &LiveSession) -> bool {
    live.deadline_at.is_some_and(|d| Instant::now() >= d)
}

/// Cancel an over-deadline session with a typed [`Event::Failed`]. The
/// session — and its KV blocks — free on drop; nothing else is touched.
fn fail_deadline(m: &Metrics, live: LiveSession) {
    m.inc("requests_failed", 1);
    m.inc("deadline_cancellations", 1);
    let _ = live.tx.send(Event::Failed {
        request_id: live.id,
        message: "request deadline exceeded".into(),
    });
}

/// How often one session may be swapped out before the scheduler gives up
/// on it — a pure runaway guard; normal preemption churn stays far below.
const MAX_PREEMPTIONS_PER_SESSION: u32 = 64;

/// Preemption policy: among the stepping session and all its live
/// neighbors, the YOUNGEST (latest admitted) is swapped out — oldest
/// streams keep their progress, which bounds total wasted work.
fn preempt_youngest(
    engine: &mut MoeEngine,
    m: &Metrics,
    active: &mut VecDeque<LiveSession>,
    preempted: &mut VecDeque<LiveSession>,
    live: LiveSession,
    why: &str,
) {
    if active.is_empty() {
        // `live` is alone and still cannot get blocks: nothing to preempt
        m.inc("requests_failed", 1);
        let _ = live.tx.send(Event::Error {
            request_id: live.id,
            message: format!("kv pool exhausted with no session to preempt: {why}"),
        });
        return;
    }
    let (vi, vseq) = active
        .iter()
        .enumerate()
        .max_by_key(|(_, s)| s.admit_seq)
        .map(|(i, s)| (i, s.admit_seq))
        .expect("active is non-empty");
    let mut victim = if live.admit_seq > vseq {
        live
    } else {
        let v = active.remove(vi).expect("index from enumerate");
        active.push_back(live);
        v
    };
    if victim.preempt_count >= MAX_PREEMPTIONS_PER_SESSION {
        m.inc("requests_failed", 1);
        let _ = victim.tx.send(Event::Error {
            request_id: victim.id,
            message: format!(
                "session preempted {MAX_PREEMPTIONS_PER_SESSION} times without \
                 completing — kv pool is thrashing: {why}"
            ),
        });
        return;
    }
    match engine.preempt_session(&mut victim.sess) {
        Ok(()) => {
            // no counter here: the engine-side KvPool tally is the single
            // source, surfaced as the `kv_preemptions` gauge each pass
            victim.preempt_count += 1;
            preempted.push_back(victim);
        }
        Err(e) => {
            m.inc("requests_failed", 1);
            let _ = victim.tx.send(Event::Error {
                request_id: victim.id,
                message: e.to_string(),
            });
        }
    }
}

/// Shared admission prologue for BOTH admission paths: tokenize
/// (reusing the pre-gate's cached tokens), validate against the context
/// window, permanently fail prompts the pool can never hold, clamp the
/// token budget to pool capacity, and open the session + its sampler.
/// One copy means synchronous and chunked admission can never drift
/// apart on request validation or budgeting. Errors hand the tokenized
/// prompt back so the caller's requeue path never re-tokenizes.
fn open_session(
    engine: &mut MoeEngine,
    tokenizer: &ByteTokenizer,
    req: &Request,
    tokens: Option<Vec<u32>>,
    base_seed: u64,
) -> std::result::Result<(Vec<u32>, usize, Session, Sampler), (Vec<u32>, Error)> {
    // the pre-gate may already have tokenized the prompt
    let prompt_tokens = match tokens {
        Some(t) => t,
        None if req.chat => tokenizer.chat_turn(&req.prompt),
        None => tokenizer.encode(&req.prompt),
    };
    if prompt_tokens.is_empty() {
        return Err((prompt_tokens, Error::Serving("empty prompt".into())));
    }
    let budget = req
        .max_tokens
        .min(engine.weights.cfg.max_seq.saturating_sub(prompt_tokens.len()).saturating_sub(1));
    if budget == 0 {
        return Err((prompt_tokens, Error::Serving("prompt exceeds context window".into())));
    }
    // a prompt bigger than the ENTIRE pool can never be served — fail it
    // permanently instead of deferring it forever at the queue head
    if !engine.kv_pool.fits(prompt_tokens.len() + 1) {
        let e = Error::Serving(format!(
            "prompt of {} tokens exceeds the kv pool capacity of {} tokens",
            prompt_tokens.len(),
            engine.kv_pool.capacity_tokens()
        ));
        return Err((prompt_tokens, e));
    }
    // ...and clamp the token budget to what the pool can EVER back, so a
    // generation finishes at the capacity wall instead of erroring after
    // tokens were already streamed (fits() above guarantees this is ≥ 1)
    let budget = budget.min(
        engine
            .kv_pool
            .capacity_tokens()
            .saturating_sub(prompt_tokens.len()),
    );
    // request-id-derived seed: independent of admission order, and equal
    // to the old sequential derivation when requests are served one at a
    // time in submit order.
    let sess = match Session::with_seed(engine, base_seed.wrapping_add(req.id)) {
        Ok(s) => s,
        Err(e) => return Err((prompt_tokens, e)),
    };
    let sampler = sess.sampler(req.temperature, req.top_p);
    Ok((prompt_tokens, budget, sess, sampler))
}

/// Synchronous admission: tokenize, budget and prefill a request into a
/// live session, emitting its first token. `Ok(None)` means the
/// submitter already dropped its stream; on failure the request, its
/// tokenized prompt AND the channel are handed back so the caller can
/// either requeue (transient [`Error::KvPoolExhausted`], without
/// re-tokenizing on retry) or report the error. The prompt's KV blocks
/// are committed all-or-nothing before any compute, so a refused
/// admission leaves no residue.
#[allow(clippy::too_many_arguments)]
fn admit(
    engine: &mut MoeEngine,
    tokenizer: &ByteTokenizer,
    req: Request,
    tokens: Option<Vec<u32>>,
    base_seed: u64,
    tx: Sender<Event>,
    queue_wait_s: f64,
    admit_seq: u64,
) -> std::result::Result<Option<LiveSession>, AdmitRefusal> {
    let started = Instant::now();
    let deadline = deadline_at(engine, &req, started, queue_wait_s);
    let (prompt_tokens, budget, mut sess, mut sampler) =
        match open_session(engine, tokenizer, &req, tokens, base_seed) {
            Ok(x) => x,
            Err((toks, e)) => return Err((req, toks, tx, e)),
        };
    // prefix-cache admission lookup: a warm prefix seeds the session's
    // KV and prefill resumes at the first uncached token (reused = 0 and
    // plain prefill when the cache is off or misses)
    let (logits, reused) = match engine.prefill_cached(&mut sess, &prompt_tokens) {
        Ok(x) => x,
        Err(e) => return Err((req, prompt_tokens, tx, e)),
    };
    // logits cover only the prefilled tail: [prompt - reused, vocab]
    let next = sampler.sample(logits.row(prompt_tokens.len() - reused - 1)) as u32;
    let piece = tokenizer.decode(&[next]);
    let ttft_s = started.elapsed().as_secs_f64();
    if tx.send(Event::Token { request_id: req.id, text: piece.clone() }).is_err() {
        // client dropped its stream while queued — don't occupy a slot
        return Ok(None);
    }
    Ok(Some(LiveSession {
        id: req.id,
        tx,
        sess,
        sampler,
        phase: Phase::Decoding,
        next,
        text: piece,
        generated: 1,
        budget,
        prompt_tokens: prompt_tokens.len(),
        fed_tokens: prompt_tokens,
        prefix_reused: reused,
        started,
        queue_wait_s,
        ttft_s,
        admit_seq,
        preempt_count: 0,
        deadline_at: deadline,
    }))
}

/// Chunked admission (`ServingConfig::chunked_prefill`): the same
/// request validation and budgeting as [`admit`], but instead of
/// prefilling the prompt synchronously the session is opened, seeded
/// from the prefix cache (tail chunking composes with the seed), and
/// parked in the `Prefilling` phase — the scheduler's mixed ticks feed
/// the prompt chunk-by-chunk and the first token is sampled when the
/// last chunk lands, bit-identical to the synchronous path's. No KV
/// blocks are committed here: each chunk commits its own positions
/// incrementally, so a long prompt's memory footprint ramps with its
/// progress instead of being claimed up front.
#[allow(clippy::too_many_arguments)]
fn admit_chunked(
    engine: &mut MoeEngine,
    tokenizer: &ByteTokenizer,
    req: Request,
    tokens: Option<Vec<u32>>,
    base_seed: u64,
    tx: Sender<Event>,
    queue_wait_s: f64,
    admit_seq: u64,
) -> std::result::Result<Option<LiveSession>, AdmitRefusal> {
    let started = Instant::now();
    let deadline = deadline_at(engine, &req, started, queue_wait_s);
    let (prompt_tokens, budget, mut sess, sampler) =
        match open_session(engine, tokenizer, &req, tokens, base_seed) {
            Ok(x) => x,
            Err((toks, e)) => return Err((req, toks, tx, e)),
        };
    // prefix-cache seed only — the uncached tail enters the engine in
    // planner-sized chunks across the following ticks
    let reused = match engine.prefill_start(&mut sess, &prompt_tokens) {
        Ok(r) => r,
        Err(e) => return Err((req, prompt_tokens, tx, e)),
    };
    Ok(Some(LiveSession {
        id: req.id,
        tx,
        sess,
        sampler,
        next: 0,
        text: String::new(),
        generated: 0,
        budget,
        prompt_tokens: prompt_tokens.len(),
        fed_tokens: Vec::new(),
        prefix_reused: reused,
        started,
        queue_wait_s,
        ttft_s: 0.0,
        admit_seq,
        preempt_count: 0,
        deadline_at: deadline,
        phase: Phase::Prefilling { prompt: prompt_tokens, fed: reused },
    }))
}

/// A refused admission: the request, its tokenized prompt (so a
/// transient requeue never re-tokenizes), the response channel, and why.
type AdmitRefusal = (Request, Vec<u32>, Sender<Event>, Error);

enum StepOutcome {
    Continue,
    /// Budget exhausted or end-of-turn marker reached.
    Finished,
    /// The submitter dropped its stream; the session slot is reclaimed.
    Cancelled,
}

/// One decode step for one live session (sequential tick path).
fn step(
    engine: &mut MoeEngine,
    tokenizer: &ByteTokenizer,
    live: &mut LiveSession,
) -> Result<StepOutcome> {
    let logits = engine.decode_step(&mut live.sess, live.next)?;
    Ok(advance(engine, tokenizer, live, logits))
}

/// Post-decode bookkeeping shared by the sequential and batched tick
/// paths: commit the fed token, sample the next one, stream it, and
/// apply the stop condition. Runs only after a SUCCESSFUL decode — on a
/// pool-dry error nothing was fed and the retry re-pushes the token.
fn advance(
    engine: &MoeEngine,
    tokenizer: &ByteTokenizer,
    live: &mut LiveSession,
    logits: Vec<f32>,
) -> StepOutcome {
    live.fed_tokens.push(live.next);
    live.next = live.sampler.sample(&logits) as u32;
    live.generated += 1;
    let piece = tokenizer.decode(&[live.next]);
    live.text.push_str(&piece);
    if live.tx.send(Event::Token { request_id: live.id, text: piece }).is_err() {
        return StepOutcome::Cancelled;
    }
    // stop at the configured end-of-turn suffix (ServingConfig::
    // stop_suffix / min_tokens; defaults reproduce the historical
    // `.\n` + 4-token heuristic) — the incrementally-maintained text
    // makes this O(1) per token, which validate() preserves by bounding
    // the suffix length
    let stopped = live.generated > engine.min_tokens
        && !engine.stop_suffix.is_empty()
        && live.text.ends_with(&engine.stop_suffix);
    if stopped || live.generated >= live.budget {
        StepOutcome::Finished
    } else {
        StepOutcome::Continue
    }
}

/// Emit the Done event and final accounting for a finished session —
/// and hand the completed stream to the prefix cache first, so the NEXT
/// request sharing this prefix skips its prefill (insert-on-completion;
/// a no-op with the cache off).
fn finish(m: &Metrics, engine: &mut MoeEngine, live: LiveSession, active_sessions: u64) {
    // insert errors (a failed literal D2H read) only mean nothing was
    // cached; the request itself already finished
    let _ = engine.prefix_insert(&live.sess, &live.fed_tokens);
    let wall = live.started.elapsed().as_secs_f64();
    let sim_tps = live.sess.run.tokens_per_s_sim();
    let hits = live.sess.run.total_hits();
    let misses = live.sess.run.total_misses();
    let kv = engine.kv_pool.stats();
    let (pblocks, ptokens, phits, pmisses, pinserted, pevicted) =
        engine.prefix.as_ref().map_or((0, 0, 0, 0, 0, 0), |c| {
            let s = c.stats();
            (
                c.cached_blocks() as u64,
                c.cached_tokens() as u64,
                s.hits,
                s.misses,
                s.inserted_blocks,
                s.evicted_blocks,
            )
        });
    m.inc("requests_ok", 1);
    m.inc("tokens_generated", live.generated as u64);
    m.inc("expert_cache_hits", hits);
    m.inc("expert_cache_misses", misses);
    m.observe("request_latency_s", wall);
    // time-breakdown attribution rides the trace knob: off, the done
    // event (and its JSON) is byte-identical to a tracing-less build
    let breakdown = if engine.tracer.is_enabled() {
        let run = &live.sess.run;
        let decode_sim: f64 = run.tokens.iter().map(|t| t.sim_s).sum();
        let decode_stall: f64 = run.tokens.iter().map(|t| t.stall_s).sum();
        let decode_transfer: f64 = run.tokens.iter().map(|t| t.transfer_s).sum();
        let stall_s = run.prefill_stall_s + decode_stall;
        let transfer_s = run.prefill_transfer_s + decode_transfer;
        let b = Breakdown {
            queue_s: live.queue_wait_s,
            prefill_compute_s: (run.prefill_sim_s - run.prefill_stall_s).max(0.0),
            decode_compute_s: (decode_sim - decode_stall).max(0.0),
            transfer_s,
            transfer_hidden_s: (transfer_s - stall_s).max(0.0),
            stall_s,
        };
        m.observe_with("req_queue_s", b.queue_s, Histogram::sim_time);
        m.observe_with("req_prefill_compute_s", b.prefill_compute_s, Histogram::sim_time);
        m.observe_with("req_decode_compute_s", b.decode_compute_s, Histogram::sim_time);
        m.observe_with("req_transfer_s", b.transfer_s, Histogram::sim_time);
        m.observe_with("req_transfer_hidden_s", b.transfer_hidden_s, Histogram::sim_time);
        m.observe_with("req_stall_s", b.stall_s, Histogram::sim_time);
        Some(b)
    } else {
        None
    };
    let _ = live.tx.send(Event::Done {
        request_id: live.id,
        text: live.text,
        prompt_tokens: live.prompt_tokens,
        new_tokens: live.generated,
        wall_s: wall,
        tokens_per_s_wall: live.generated as f64 / wall.max(1e-9),
        tokens_per_s_sim: sim_tps,
        queue_wait_s: live.queue_wait_s,
        ttft_s: live.ttft_s,
        active_sessions,
        kv_blocks_total: kv.total_blocks as u64,
        kv_blocks_in_use: kv.in_use_blocks as u64,
        kv_blocks_free: kv.free_blocks as u64,
        kv_preemptions: kv.preemptions,
        kv_resumes: m.counter("kv_resumes"),
        prefix_hit: live.prefix_reused > 0,
        prefix_tokens_reused: live.prefix_reused as u64,
        prefix_cache_blocks: pblocks,
        prefix_cache_tokens: ptokens,
        prefix_hits: phits,
        prefix_misses: pmisses,
        prefix_inserted_blocks: pinserted,
        prefix_evicted_blocks: pevicted,
        expert_loads_deduped: engine.batch.loads_deduped,
        batched_kernel_calls: engine.batch.kernel_calls,
        batched_ticks: engine.batch.ticks,
        mixed_ticks: engine.batch.mixed_ticks,
        batch_occupancy: engine.batch.last_occupancy,
        expert_hot_hits: engine.tiers.hot_hits,
        tier_promotions: engine.tiers.promotions,
        link_bytes_saved: engine.tiers.bytes_saved(),
        trace_spans_dropped: engine.tracer.dropped(),
        faults_injected: engine.fault_stats().injected,
        transfer_retries: engine.fault_stats().transfer_retries,
        requests_failed: m.counter("requests_failed"),
        deadline_cancellations: m.counter("deadline_cancellations"),
        spec_recall_bp: crate::obs::to_bp(engine.cache.stats.spec.recall()),
        spec_precision_bp: crate::obs::to_bp(engine.cache.stats.spec.precision()),
        breakdown,
    });
}

/// Drain helper for tests / examples: iterate a stream's token events,
/// blocking until the stream finishes or `timeout` elapses.
pub fn collect_events_timeout(stream: &ResponseStream, timeout: Duration) -> Vec<Event> {
    let deadline = Instant::now() + timeout;
    let mut out = Vec::new();
    loop {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match stream.events.recv_timeout(deadline - now) {
            Ok(ev) => {
                let done = matches!(
                    ev,
                    Event::Done { .. } | Event::Error { .. } | Event::Failed { .. }
                );
                out.push(ev);
                if done {
                    break;
                }
            }
            Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    out
}

/// Drain a stream to completion (blocking `recv`, generous timeout — no
/// spin-waiting).
pub fn collect_events(stream: ResponseStream) -> Vec<Event> {
    collect_events_timeout(&stream, Duration::from_secs(600))
}
