//! # moe-offload
//!
//! Fast inference of Mixture-of-Experts language models with offloading —
//! a rust + JAX + Pallas reproduction of Eliseev & Mazur (2023).
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//!
//! * **L1** (`python/compile/kernels/`): Pallas kernels — fused
//!   group-dequant + SwiGLU expert FFN (the offloading hot spot).
//! * **L2** (`python/compile/model.py`): the Mixtral-architecture decoder
//!   in JAX, lowered per-module to HLO-text artifacts at build time.
//! * **L3** (this crate): loads those artifacts via PJRT and owns
//!   everything the paper contributes — the expert LRU cache, speculative
//!   expert loading, mixed HQQ quantization, the two-tier memory system,
//!   and the serving coordinator. Python never runs on the request path.
//!
//! Start at [`engine::MoeEngine`] for generation, [`coordinator`] for
//! serving, and `rust/src/bin/` for the paper's tables and figures.
//!
//! ## Architecture: engine core vs. sessions vs. scheduler
//!
//! Serving is split across three pieces:
//!
//! * **Engine core** ([`engine::MoeEngine`]) — the shared, stateless-per-
//!   request machinery: PJRT runtime + compiled modules, weights and
//!   pre-marshalled literals, the per-layer expert LRU cache, the copy
//!   engine, the cost model and the virtual timeline. One engine serves
//!   any number of generation streams; its warm expert cache and
//!   speculative transfers are shared by all of them.
//! * **Sessions** ([`engine::Session`]) — everything owned by ONE
//!   request: the paged per-layer KV store, sequence position, trace
//!   token counter, per-session run statistics and the sampler seed.
//!   `decode_step`/`prefill`/`generate`/`score` take `&mut Session`;
//!   dropping the session ends the request, `Session::reset` rewinds it
//!   in place with the expert cache still warm.
//! * **Paged KV** ([`kv`]) — the KV byte budget is carved out of device
//!   memory into fixed-size token blocks ([`kv::BlockAllocator`]); each
//!   session maps its positions onto blocks through a [`kv::PageTable`]
//!   and commits them on demand as decode advances. Opening a session
//!   costs no device memory; reset/drop return blocks instantly; and
//!   when the pool runs dry mid-decode the scheduler preempts the
//!   youngest session (KV swaps to host, resumed bit-identically later)
//!   instead of failing anyone. Block size never changes numerics —
//!   width-1 decode is bit-identical to a contiguous reservation.
//! * **Prefix cache** ([`prefix`], opt-in via
//!   [`config::ServingConfig::prefix_cache`]) — completed prompts become
//!   reusable KV: a radix tree keyed on block-sized token chunks whose
//!   nodes hold refcounted pool blocks and per-layer host KV rows. A new
//!   request sharing a cached prefix seeds its session from the tree and
//!   prefills only the uncached tail (bit-identical to a cold prefill);
//!   finished streams are inserted back, inheriting the dying session's
//!   blocks. Cold prefixes are evicted LRU leaf-first under pool
//!   pressure BEFORE any live session is preempted.
//! * **Scheduler** ([`coordinator::Coordinator`]) — a continuous-batching
//!   loop on the engine worker thread. Queued requests are admitted into
//!   up to `max_concurrent_sessions` live sessions
//!   ([`config::ServingConfig::max_concurrent_sessions`], default 1)
//!   *and* as the KV pool's free blocks allow (free-block accounting
//!   instead of static per-session reservation — a pool sized for N full
//!   sequences admits strictly more than N short streams);
//!   each scheduling tick gives every live session exactly one decode
//!   step, streaming tokens out per session as they decode. With
//!   [`config::ServingConfig::batched_decode`] (default on) and 2+ live
//!   sessions the tick runs layer-lockstep through
//!   [`engine::MoeEngine::decode_batch`]: the union of routed experts is
//!   staged once per layer-tick (pinned against mid-tick eviction) and
//!   each expert runs one kernel over its stacked routed rows —
//!   bit-identical per-session output, strictly less expert traffic.
//! * **Tick planner** ([`sched`], opt-in via
//!   [`config::ServingConfig::chunked_prefill`]) — admission stops
//!   prefilling synchronously: prompts are fed in
//!   `prefill_chunk_tokens`-sized chunks, at most one chunk per tick,
//!   under a `max_batch_tokens` token budget. A chunk fuses into the
//!   batched lockstep through [`engine::MoeEngine::step_mixed`]: the
//!   chunk's per-layer expert union merges with the decode union — one
//!   cache resolve and one stacked kernel per distinct expert per
//!   layer-tick, with decode rows riding the experts the chunk was
//!   going to load anyway — so a long prompt no longer stalls live
//!   decodes for its whole prefill, and TTFT/decode-stall both improve.
//!   Per-session token streams stay bit-identical; only tick boundaries
//!   move.
//!   Queue wait, time-to-first-token, live-session counts, KV-pool
//!   pressure and batch dedup are recorded in [`telemetry::Metrics`]
//!   (`queue_wait_s`, `ttft_s`, `active_sessions`, `kv_blocks_*`,
//!   `kv_preemptions`, `batch_occupancy`, `expert_loads_deduped`,
//!   `mixed_ticks`) and surfaced in the server's `done` event. Width 1
//!   reproduces the paper's batch-1 serving exactly; width ≥ 2 lets
//!   concurrent requests share hot experts, which is where offloading
//!   wins under load.
//! * **Span tracing** ([`trace`], opt-in via
//!   [`config::ServingConfig::trace`]) — every timeline reservation the
//!   engine makes is tagged with a typed [`trace::SpanKind`] (attention /
//!   gate / expert-compute / LM-head compute; expert transfers attributed
//!   as demand-load vs speculative-prefetch vs KV-resume vs prefix-seed
//!   vs tier-reload) plus session, layer and tick ids, into a bounded
//!   ring buffer exportable as Chrome trace-event JSON (Perfetto-
//!   loadable). The coordinator aggregates per-request time breakdowns
//!   (`queue_s`, `prefill_compute_s`, `decode_compute_s`, `transfer_s`,
//!   `transfer_hidden_s`, `stall_s`) into the `done` event and
//!   [`telemetry::Metrics`] histograms, and the TCP server answers a
//!   `metrics` line with the rendered registry. Off by default —
//!   tracing-off output is byte-identical.
//! * **Trace analysis + load harness** ([`trace::analysis`], [`load`]) —
//!   the span ring turned into answers: per-window GPU/link utilization,
//!   per-request critical paths, aggregate bottleneck attribution
//!   (blocked on demand loads vs compute vs queue vs KV resume), and
//!   counterfactual what-if replays through the cost model (2× link
//!   bandwidth, infinite expert cache, speculation off) with projected
//!   speedups — served over TCP as the `analyze` command. The [`load`]
//!   module replays declarative workload profiles (bursty Poisson,
//!   multi-turn chat with shared prefixes, long-context RAG) against the
//!   coordinator and reports TTFT/TPOT percentile SLO attainment beside
//!   that analysis (`examples/load_harness.rs` → `BENCH_8.json`).

pub mod cache;
pub mod clock;
pub mod config;
pub mod engine;
pub mod error;
pub mod eval;
pub mod fault;
pub mod harness;
pub mod kv;
pub mod load;
pub mod memory;
pub mod model;
pub mod npz;
pub mod obs;
pub mod prefix;
pub mod quant;
pub mod runtime;
pub mod sched;
pub mod telemetry;
pub mod tensor;
pub mod trace;
pub mod util;
pub mod coordinator;

pub use error::{Error, Result};
