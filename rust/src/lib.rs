//! # moe-offload
//!
//! Fast inference of Mixture-of-Experts language models with offloading —
//! a rust + JAX + Pallas reproduction of Eliseev & Mazur (2023).
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//!
//! * **L1** (`python/compile/kernels/`): Pallas kernels — fused
//!   group-dequant + SwiGLU expert FFN (the offloading hot spot).
//! * **L2** (`python/compile/model.py`): the Mixtral-architecture decoder
//!   in JAX, lowered per-module to HLO-text artifacts at build time.
//! * **L3** (this crate): loads those artifacts via PJRT and owns
//!   everything the paper contributes — the expert LRU cache, speculative
//!   expert loading, mixed HQQ quantization, the two-tier memory system,
//!   and the serving coordinator. Python never runs on the request path.
//!
//! Start at [`engine::MoeEngine`] for generation, [`coordinator`] for
//! serving, and `rust/src/bin/` for the paper's tables and figures.

pub mod cache;
pub mod clock;
pub mod config;
pub mod engine;
pub mod error;
pub mod eval;
pub mod harness;
pub mod memory;
pub mod model;
pub mod npz;
pub mod quant;
pub mod runtime;
pub mod telemetry;
pub mod tensor;
pub mod util;
pub mod coordinator;

pub use error::{Error, Result};
