//! `moe-offload` CLI: generate text, serve requests, and inspect the
//! offloading system. The experiment binaries (fig1/fig2/table1/table2)
//! live in `rust/src/bin/`.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use moe_offload::config::{
    HardwareProfile, Manifest, OffloadPolicy, QuantScheme, ServingConfig, SimScale,
};
use moe_offload::coordinator::{server::Server, Coordinator, Event, Request};
use moe_offload::engine::MoeEngine;
use moe_offload::model::{ByteTokenizer, ModelWeights, Sampler};
use moe_offload::util::cli::Cli;

fn main() {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = if args.is_empty() { "help".to_string() } else { args.remove(0) };
    let result = match cmd.as_str() {
        "generate" => cmd_generate(args),
        "serve" => cmd_serve(args),
        "info" => cmd_info(args),
        _ => {
            eprintln!(
                "moe-offload — MoE inference with expert offloading\n\n\
                 Commands:\n  \
                 generate  --prompt <text> [--max-tokens N] [--policy full|lru|ondemand|naive]\n            \
                 [--expert-quant 2|3|4|fp16] [--attn-quant ...] [--hardware t4|3060|3080m|a100]\n  \
                 serve     --addr 127.0.0.1:7777 [--policy ...] (JSON line protocol)\n  \
                 info      prints artifact + model + size information\n\n\
                 Run any command with --help for details."
            );
            return;
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

pub fn parse_policy(text: &str, cache_k: usize, spec_n: usize) -> anyhow::Result<OffloadPolicy> {
    Ok(match text {
        "full" => OffloadPolicy::Full { cache_k, spec_n },
        "lru" => OffloadPolicy::LruOnly { cache_k },
        "ondemand" => OffloadPolicy::OnDemand,
        "naive" => OffloadPolicy::Naive,
        other => anyhow::bail!("unknown policy {other:?} (full|lru|ondemand|naive)"),
    })
}

struct Setup {
    manifest: Manifest,
    serving: ServingConfig,
    profile: HardwareProfile,
    artifacts: PathBuf,
}

fn common_setup(a: &moe_offload::util::cli::Args) -> anyhow::Result<Setup> {
    let artifacts = PathBuf::from(a.get("artifacts"));
    let manifest = Manifest::load(&artifacts)?;
    let profile = HardwareProfile::by_name(a.get("hardware"))
        .ok_or_else(|| anyhow::anyhow!("unknown hardware profile"))?;
    let cache_k = a.get_usize("cache-k");
    let policy = parse_policy(a.get("policy"), cache_k, a.get_usize("spec-n"))?;
    let serving = ServingConfig {
        policy,
        expert_quant: QuantScheme::parse(a.get("expert-quant"))?,
        attn_quant: QuantScheme::parse(a.get("attn-quant"))?,
        sim_scale: if a.has("mixtral-scale") { SimScale::Mixtral } else { SimScale::Tiny },
        max_new_tokens: a.get_usize("max-tokens"),
        temperature: a.get_f64("temperature") as f32,
        seed: a.get_usize("seed") as u64,
        max_concurrent_sessions: a.get_usize("max-sessions"),
        ..Default::default()
    };
    Ok(Setup { manifest, serving, profile, artifacts })
}

fn build_engine(s: &Setup) -> anyhow::Result<MoeEngine> {
    let weights = ModelWeights::load(
        &s.manifest.config,
        &s.artifacts.join("weights.npz"),
        s.serving.attn_quant,
        s.serving.expert_quant,
    )?;
    Ok(MoeEngine::new(&s.manifest, weights, &s.serving, s.profile.clone())?)
}

fn base_cli(bin: &'static str, about: &'static str) -> Cli {
    Cli::new(bin, about)
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("policy", "full", "offloading policy: full|lru|ondemand|naive")
        .opt("cache-k", "2", "LRU cache size per layer")
        .opt("spec-n", "2", "experts to prefetch speculatively")
        .opt("expert-quant", "3", "expert quantization: 2|3|4|fp16")
        .opt("attn-quant", "4", "attention quantization: 2|3|4|fp16")
        .opt("hardware", "3060", "hardware profile: t4|3060|3080m|a100")
        .opt("max-tokens", "64", "max new tokens")
        .opt("temperature", "1.0", "sampling temperature")
        .opt("seed", "0", "random seed")
        .opt("max-sessions", "1", "concurrent sessions the serve scheduler interleaves")
        .flag("mixtral-scale", "report timing at Mixtral-8x7B geometry")
}

fn cmd_generate(argv: Vec<String>) -> anyhow::Result<()> {
    let cli = base_cli("moe-offload generate", "generate text from a prompt")
        .opt("prompt", "what is a mixture of experts model", "prompt text")
        .flag("raw", "skip the chat template");
    let a = cli.parse_from(argv).map_err(|e| anyhow::anyhow!(e))?;
    let setup = common_setup(&a)?;
    let mut engine = build_engine(&setup)?;
    let tokenizer = ByteTokenizer::new();
    let prompt = if a.has("raw") {
        tokenizer.encode(a.get("prompt"))
    } else {
        tokenizer.chat_turn(a.get("prompt"))
    };
    let mut sampler = Sampler::new(setup.serving.temperature, 1.0, setup.serving.seed);
    let mut session = engine.new_session()?;
    let out = engine.generate(&mut session, &prompt, setup.serving.max_new_tokens, &mut sampler)?;
    println!("{}", tokenizer.decode(&out));
    eprintln!(
        "\n[{} | {} | experts {} | attn {}]\n\
         decode: {} tokens, {:.2} tok/s simulated ({}), {:.2} tok/s wall (cpu testbed)\n\
         cache: {:.1}% hit ratio, {} spec hits, {} MiB transferred",
        setup.profile.name,
        setup.serving.policy.label(),
        setup.serving.expert_quant.label(),
        setup.serving.attn_quant.label(),
        session.run.decode_tokens(),
        session.run.tokens_per_s_sim(),
        if a.has("mixtral-scale") { "Mixtral-8x7B scale" } else { "tiny scale" },
        session.run.tokens_per_s_wall(),
        session.run.hit_ratio() * 100.0,
        session.run.tokens.iter().map(|t| t.spec_hits).sum::<u64>(),
        session.run.total_bytes() / (1 << 20),
    );
    Ok(())
}

fn cmd_serve(argv: Vec<String>) -> anyhow::Result<()> {
    let cli = base_cli("moe-offload serve", "serve requests over TCP (JSON lines)")
        .opt("addr", "127.0.0.1:7777", "listen address");
    let a = cli.parse_from(argv).map_err(|e| anyhow::anyhow!(e))?;
    let setup = common_setup(&a)?;
    let seed = setup.serving.seed;
    let coordinator = Arc::new(Coordinator::new(move || build_engine(&setup).map_err(into_moe), seed));
    let server = Server::bind(a.get("addr"), Arc::clone(&coordinator))?;
    eprintln!("serving on {}", server.local_addr()?);
    server.serve(None)?;
    Ok(())
}

fn into_moe(e: anyhow::Error) -> moe_offload::Error {
    moe_offload::Error::Serving(e.to_string())
}

fn cmd_info(argv: Vec<String>) -> anyhow::Result<()> {
    let cli = base_cli("moe-offload info", "artifact + model + size info");
    let a = cli.parse_from(argv).map_err(|e| anyhow::anyhow!(e))?;
    let setup = common_setup(&a)?;
    let cfg = &setup.manifest.config;
    println!(
        "model: {} layers, {} experts/layer (top-{}), d_model {}, d_ff {}, vocab {}",
        cfg.n_layers, cfg.n_experts, cfg.top_k, cfg.d_model, cfg.d_ff, cfg.vocab_size
    );
    println!("modules:");
    for (name, m) in &setup.manifest.modules {
        println!("  {name:24} {} args  ({})", m.arg_shapes.len(), m.file);
    }
    let weights_path = setup.artifacts.join("weights.npz");
    if weights_path.exists() {
        let weights = ModelWeights::load(
            cfg,
            &weights_path,
            setup.serving.attn_quant,
            setup.serving.expert_quant,
        )?;
        println!(
            "weights: total {:.2} MiB (shared {:.2} MiB + experts {:.2} MiB) \
             [attn {}, experts {}]",
            weights.total_bytes() as f64 / (1 << 20) as f64,
            weights.shared_bytes() as f64 / (1 << 20) as f64,
            weights.experts.total_bytes() as f64 / (1 << 20) as f64,
            setup.serving.attn_quant.label(),
            setup.serving.expert_quant.label(),
        );
        println!(
            "per-expert wire size: {:.1} KiB",
            weights.experts.expert_transfer_bytes() as f64 / 1024.0
        );
    } else {
        println!("weights.npz not present (run `make artifacts`)");
    }
    let _ = Event::Token { request_id: 0, text: String::new() }; // keep import used
    let _ = Request::new("");
    Ok(())
}
