//! Minimal NPY/NPZ reader (+ NPY writer) — the weights interchange between
//! the python trainer (`np.savez`) and the rust coordinator.
//!
//! Supports the subset numpy actually emits for our payloads: NPY v1/v2,
//! little-endian `<f4`/`<f8`/`<i4`/`<i8`/`|u1`, C order. NPZ is a zip
//! archive of `.npy` members (stored or deflated — the `zip` crate handles
//! both).

use std::collections::BTreeMap;
use std::io::Read;
use std::path::Path;

use crate::error::{Error, Result};
use crate::tensor::{Tensor, TensorU8};

const MAGIC: &[u8] = b"\x93NUMPY";

#[derive(Debug, Clone, PartialEq)]
pub enum Array {
    F32(Tensor),
    U8(TensorU8),
}

impl Array {
    pub fn shape(&self) -> &[usize] {
        match self {
            Array::F32(t) => &t.shape,
            Array::U8(t) => &t.shape,
        }
    }

    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            Array::F32(t) => Ok(t),
            Array::U8(_) => Err(Error::Npz("expected f32 array, got u8".into())),
        }
    }

    pub fn as_u8(&self) -> Result<&TensorU8> {
        match self {
            Array::U8(t) => Ok(t),
            Array::F32(_) => Err(Error::Npz("expected u8 array, got f32".into())),
        }
    }
}

/// Parse a `.npy` byte buffer.
pub fn parse_npy(bytes: &[u8]) -> Result<Array> {
    if bytes.len() < 10 || &bytes[..6] != MAGIC {
        return Err(Error::Npz("bad npy magic".into()));
    }
    let major = bytes[6];
    let (header_len, header_start) = match major {
        1 => {
            let n = u16::from_le_bytes([bytes[8], bytes[9]]) as usize;
            (n, 10)
        }
        2 | 3 => {
            if bytes.len() < 12 {
                return Err(Error::Npz("truncated npy v2 header".into()));
            }
            let n = u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]) as usize;
            (n, 12)
        }
        v => return Err(Error::Npz(format!("unsupported npy version {v}"))),
    };
    let header_end = header_start + header_len;
    if bytes.len() < header_end {
        return Err(Error::Npz("truncated npy header".into()));
    }
    let header = std::str::from_utf8(&bytes[header_start..header_end])
        .map_err(|_| Error::Npz("non-utf8 npy header".into()))?;

    let descr = dict_value(header, "descr")?;
    let fortran = dict_value(header, "fortran_order")?;
    let shape_text = dict_value(header, "shape")?;
    if fortran.trim() != "False" {
        return Err(Error::Npz("fortran_order arrays unsupported".into()));
    }
    let shape = parse_shape(&shape_text)?;
    let n: usize = shape.iter().product();
    let payload = &bytes[header_end..];

    let descr = descr.trim_matches(['\'', '"']);
    match descr {
        "<f4" => {
            expect_len(payload, n * 4)?;
            let data = payload
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            Ok(Array::F32(Tensor::new(data, shape)?))
        }
        "<f8" => {
            expect_len(payload, n * 8)?;
            let data = payload
                .chunks_exact(8)
                .map(|c| {
                    f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]) as f32
                })
                .collect();
            Ok(Array::F32(Tensor::new(data, shape)?))
        }
        "<i4" => {
            expect_len(payload, n * 4)?;
            let data = payload
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]) as f32)
                .collect();
            Ok(Array::F32(Tensor::new(data, shape)?))
        }
        "<i8" => {
            expect_len(payload, n * 8)?;
            let data = payload
                .chunks_exact(8)
                .map(|c| {
                    i64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]) as f32
                })
                .collect();
            Ok(Array::F32(Tensor::new(data, shape)?))
        }
        "|u1" | "<u1" => {
            expect_len(payload, n)?;
            Ok(Array::U8(TensorU8::new(payload[..n].to_vec(), shape)?))
        }
        other => Err(Error::Npz(format!("unsupported dtype descr {other:?}"))),
    }
}

fn expect_len(payload: &[u8], want: usize) -> Result<()> {
    if payload.len() < want {
        return Err(Error::Npz(format!(
            "payload too short: {} < {}",
            payload.len(),
            want
        )));
    }
    Ok(())
}

/// Extract the raw text of a key's value from the python-dict header.
fn dict_value(header: &str, key: &str) -> Result<String> {
    let pat = format!("'{key}':");
    let start = header
        .find(&pat)
        .ok_or_else(|| Error::Npz(format!("missing header key {key}")))?
        + pat.len();
    let rest = &header[start..];
    // value ends at the next top-level comma or closing brace
    let mut depth = 0usize;
    let mut in_str = false;
    for (i, c) in rest.char_indices() {
        match c {
            '\'' | '"' => in_str = !in_str,
            '(' | '[' if !in_str => depth += 1,
            ')' | ']' if !in_str => depth = depth.saturating_sub(1),
            ',' | '}' if !in_str && depth == 0 => {
                return Ok(rest[..i].trim().to_string());
            }
            _ => {}
        }
    }
    Err(Error::Npz(format!("unterminated header value for {key}")))
}

fn parse_shape(text: &str) -> Result<Vec<usize>> {
    let inner = text
        .trim()
        .strip_prefix('(')
        .and_then(|s| s.strip_suffix(')'))
        .ok_or_else(|| Error::Npz(format!("bad shape {text:?}")))?;
    let mut shape = Vec::new();
    for part in inner.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        shape.push(
            part.parse()
                .map_err(|_| Error::Npz(format!("bad shape dim {part:?}")))?,
        );
    }
    Ok(shape)
}

/// Serialize a Tensor as NPY v1 (`<f4`, C order) — used by tests and by the
/// trace tooling to hand data back to python plotting.
pub fn write_npy_f32(t: &Tensor) -> Vec<u8> {
    let shape = t
        .shape
        .iter()
        .map(|d| d.to_string())
        .collect::<Vec<_>>()
        .join(", ");
    let trailing = if t.shape.len() == 1 { "," } else { "" };
    let mut header = format!(
        "{{'descr': '<f4', 'fortran_order': False, 'shape': ({shape}{trailing}), }}"
    );
    // pad so that (10 + len) % 64 == 0, ending in \n
    let total = 10 + header.len() + 1;
    let pad = (64 - total % 64) % 64;
    header.push_str(&" ".repeat(pad));
    header.push('\n');

    let mut out = Vec::with_capacity(10 + header.len() + t.data.len() * 4);
    out.extend_from_slice(MAGIC);
    out.push(1);
    out.push(0);
    out.extend_from_slice(&(header.len() as u16).to_le_bytes());
    out.extend_from_slice(header.as_bytes());
    for x in &t.data {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Load every member of an `.npz` archive.
pub fn load_npz(path: &Path) -> Result<BTreeMap<String, Array>> {
    let file = std::fs::File::open(path)?;
    let mut archive = zip::ZipArchive::new(file)?;
    let mut out = BTreeMap::new();
    for i in 0..archive.len() {
        let mut entry = archive.by_index(i)?;
        let name = entry
            .name()
            .strip_suffix(".npy")
            .unwrap_or(entry.name())
            .to_string();
        let mut bytes = Vec::with_capacity(entry.size() as usize);
        entry.read_to_end(&mut bytes)?;
        out.insert(name, parse_npy(&bytes)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn npy_f32_roundtrip() {
        let t = Tensor::new(vec![1.5, -2.0, 0.0, 3.25, 7.0, -0.5], vec![2, 3]).unwrap();
        let bytes = write_npy_f32(&t);
        match parse_npy(&bytes).unwrap() {
            Array::F32(got) => assert_eq!(got, t),
            _ => panic!("wrong dtype"),
        }
    }

    #[test]
    fn npy_1d_roundtrip() {
        let t = Tensor::new(vec![9.0; 5], vec![5]).unwrap();
        let parsed = parse_npy(&write_npy_f32(&t)).unwrap();
        assert_eq!(parsed.shape(), &[5]);
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(parse_npy(b"NOTNUMPYxxxxxxxxxxxx").is_err());
    }

    #[test]
    fn rejects_truncated_payload() {
        let t = Tensor::new(vec![1.0; 4], vec![4]).unwrap();
        let mut bytes = write_npy_f32(&t);
        bytes.truncate(bytes.len() - 8);
        assert!(parse_npy(&bytes).is_err());
    }

    #[test]
    fn header_dict_parser_handles_nested_tuples() {
        let h = "{'descr': '<f4', 'fortran_order': False, 'shape': (2, 3), }";
        assert_eq!(dict_value(h, "descr").unwrap(), "'<f4'");
        assert_eq!(dict_value(h, "shape").unwrap(), "(2, 3)");
        assert_eq!(parse_shape("(2, 3)").unwrap(), vec![2, 3]);
        assert_eq!(parse_shape("(7,)").unwrap(), vec![7]);
        assert_eq!(parse_shape("()").unwrap(), Vec::<usize>::new());
    }
}
