//! Byte-level tokenizer + chat template.
//!
//! The tiny model is trained on raw bytes (vocab 256, ASCII-folded), so
//! tokenization is identity over bytes. The chat template matches the
//! synthetic OpenAssistant stand-in corpus the trainer used
//! (`python/compile/data.py::build_chat_corpus`).

#[derive(Debug, Clone, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub fn new() -> Self {
        ByteTokenizer
    }

    pub fn vocab_size(&self) -> usize {
        256
    }

    /// Encode text to token ids (ASCII-folding non-ASCII like the corpus).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.chars()
            .map(|c| if c.is_ascii() { c as u32 } else { b'?' as u32 })
            .collect()
    }

    pub fn decode(&self, tokens: &[u32]) -> String {
        tokens
            .iter()
            .map(|&t| {
                let b = (t & 0xff) as u8;
                if b.is_ascii_graphic() || b == b' ' || b == b'\n' || b == b'\t' {
                    b as char
                } else {
                    '\u{fffd}'
                }
            })
            .collect()
    }

    /// Wrap a user turn in the chat format the model was trained on.
    pub fn chat_turn(&self, user: &str) -> Vec<u32> {
        self.encode(&format!("<user> {user}?\n<assistant> "))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = ByteTokenizer::new();
        let ids = t.encode("hello, world\n");
        assert_eq!(ids.len(), 13);
        assert_eq!(t.decode(&ids), "hello, world\n");
    }

    #[test]
    fn folds_non_ascii() {
        let t = ByteTokenizer::new();
        let ids = t.encode("héllo");
        assert_eq!(ids, t.encode("h?llo"));
        assert!(ids.iter().all(|&i| i < 256));
    }

    #[test]
    fn chat_template_shape() {
        let t = ByteTokenizer::new();
        let ids = t.chat_turn("what is perplexity");
        let text = t.decode(&ids);
        assert!(text.starts_with("<user> "));
        assert!(text.ends_with("<assistant> "));
    }

    #[test]
    fn decode_masks_control_bytes() {
        let t = ByteTokenizer::new();
        assert_eq!(t.decode(&[7]), "\u{fffd}");
    }
}
