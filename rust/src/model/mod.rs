//! Model-side substrates: weight loading (npz → structured layers, with
//! attention-side quantization applied), the byte-level tokenizer + chat
//! template, and the sampler.

pub mod sampler;
pub mod tokenizer;
pub mod weights;

pub use sampler::Sampler;
pub use tokenizer::ByteTokenizer;
pub use weights::ModelWeights;
