//! Token sampling.
//!
//! The paper's evaluation samples *proportionally to the predicted
//! probabilities* (no temperature/nucleus) — that is `Sampler::default()`.
//! Temperature, nucleus (top-p) and greedy modes are provided for the
//! serving examples.

use crate::tensor::softmax;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct Sampler {
    pub temperature: f32,
    /// top-p nucleus threshold; 1.0 disables.
    pub top_p: f32,
    rng: Rng,
}

impl Sampler {
    pub fn new(temperature: f32, top_p: f32, seed: u64) -> Self {
        Sampler { temperature, top_p, rng: Rng::new(seed) }
    }

    /// Paper-default: proportional sampling.
    pub fn proportional(seed: u64) -> Self {
        Self::new(1.0, 1.0, seed)
    }

    pub fn greedy() -> Self {
        Self::new(0.0, 1.0, 0)
    }

    /// Sample a token id from raw logits.
    pub fn sample(&mut self, logits: &[f32]) -> usize {
        if self.temperature <= 0.0 {
            // greedy
            return logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
                .map(|(i, _)| i)
                .unwrap_or(0);
        }
        let mut probs: Vec<f32> = logits.iter().map(|&x| x / self.temperature).collect();
        softmax(&mut probs);
        if self.top_p < 1.0 {
            nucleus_filter(&mut probs, self.top_p);
        }
        self.rng.categorical(&probs)
    }
}

/// Zero out everything outside the smallest set of tokens whose cumulative
/// probability reaches `top_p` (keeps at least one token).
fn nucleus_filter(probs: &mut [f32], top_p: f32) {
    let mut order: Vec<usize> = (0..probs.len()).collect();
    order.sort_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap_or(std::cmp::Ordering::Equal));
    let mut cum = 0.0f32;
    let mut keep = vec![false; probs.len()];
    for &i in &order {
        keep[i] = true;
        cum += probs[i];
        if cum >= top_p {
            break;
        }
    }
    for (i, p) in probs.iter_mut().enumerate() {
        if !keep[i] {
            *p = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let mut s = Sampler::greedy();
        assert_eq!(s.sample(&[0.1, 3.0, -1.0]), 1);
    }

    #[test]
    fn proportional_matches_distribution() {
        let mut s = Sampler::proportional(5);
        let logits = vec![0.0, (3.0f32).ln()]; // probs 0.25 / 0.75
        let n = 20_000;
        let ones = (0..n).filter(|_| s.sample(&logits) == 1).count();
        let frac = ones as f64 / n as f64;
        assert!((frac - 0.75).abs() < 0.02, "{frac}");
    }

    #[test]
    fn low_temperature_sharpens() {
        let mut hot = Sampler::new(2.0, 1.0, 3);
        let mut cold = Sampler::new(0.2, 1.0, 3);
        let logits = vec![0.0, 1.0];
        let n = 5_000;
        let hot_top = (0..n).filter(|_| hot.sample(&logits) == 1).count();
        let cold_top = (0..n).filter(|_| cold.sample(&logits) == 1).count();
        assert!(cold_top > hot_top);
    }

    #[test]
    fn nucleus_drops_tail() {
        let mut probs = vec![0.5, 0.3, 0.15, 0.05];
        nucleus_filter(&mut probs, 0.7);
        assert!(probs[0] > 0.0 && probs[1] > 0.0);
        assert_eq!(probs[2], 0.0);
        assert_eq!(probs[3], 0.0);
    }

    #[test]
    fn nucleus_keeps_at_least_one() {
        let mut probs = vec![0.9, 0.1];
        nucleus_filter(&mut probs, 0.01);
        assert!(probs[0] > 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let logits = vec![0.3, 0.5, 0.2, 1.0];
        let a: Vec<usize> = {
            let mut s = Sampler::proportional(9);
            (0..20).map(|_| s.sample(&logits)).collect()
        };
        let mut s = Sampler::proportional(9);
        let b: Vec<usize> = (0..20).map(|_| s.sample(&logits)).collect();
        assert_eq!(a, b);
    }
}
