//! Model weights: loading `artifacts/weights.npz` (written by the python
//! trainer) into the structured form the engine feeds to PJRT modules.
//!
//! Mixed quantization (paper §3.3): the *attention* (shared) weights are
//! quantized per `attn_quant` and dequantized once at load — they stay
//! device-resident, so only their quality effect matters, and an affine
//! quant→dequant round-trip reproduces exactly what the GPU kernel would
//! compute. The *expert* weights go into the [`HostExpertPool`] in their
//! quantized wire format — those are the bytes that stream over the link.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use crate::config::{ModelConfig, QuantScheme};
use crate::error::{Error, Result};
use crate::memory::host::HostExpertPool;
use crate::npz::{self, Array};
use crate::quant::hqq::{self, HqqConfig};
use crate::quant::tier::TierPolicy;
use crate::tensor::Tensor;

/// Per-layer non-expert weights (device-resident, f32 after dequant).
#[derive(Debug, Clone)]
pub struct LayerWeights {
    pub attn_ln: Tensor,
    pub wq: Tensor,
    pub wk: Tensor,
    pub wv: Tensor,
    pub wo: Tensor,
    pub mlp_ln: Tensor,
    pub w_gate: Tensor,
}

/// The full model: shared weights structured, experts pooled.
pub struct ModelWeights {
    pub cfg: ModelConfig,
    pub embed: Tensor,
    pub final_ln: Tensor,
    pub lm_head: Tensor,
    pub layers: Vec<LayerWeights>,
    pub experts: Arc<HostExpertPool>,
    pub attn_quant: QuantScheme,
}

impl ModelWeights {
    /// Load weights.npz and apply the mixed-quantization scheme.
    pub fn load(
        cfg: &ModelConfig,
        path: &Path,
        attn_quant: QuantScheme,
        expert_quant: QuantScheme,
    ) -> Result<Self> {
        Self::load_tiered(cfg, path, attn_quant, expert_quant, &TierPolicy::default())
    }

    /// [`Self::load`] plus a per-expert tier policy: with `tiers.enabled`
    /// the expert pool carries one packed copy per distinct tier scheme
    /// (see [`HostExpertPool::build_tiered`]); disabled is byte-identical
    /// to the uniform load.
    pub fn load_tiered(
        cfg: &ModelConfig,
        path: &Path,
        attn_quant: QuantScheme,
        expert_quant: QuantScheme,
        tiers: &TierPolicy,
    ) -> Result<Self> {
        let arrays = npz::load_npz(path)?;
        Self::from_arrays_tiered(cfg, &arrays, attn_quant, expert_quant, tiers)
    }

    pub fn from_arrays(
        cfg: &ModelConfig,
        arrays: &BTreeMap<String, Array>,
        attn_quant: QuantScheme,
        expert_quant: QuantScheme,
    ) -> Result<Self> {
        Self::from_arrays_tiered(cfg, arrays, attn_quant, expert_quant, &TierPolicy::default())
    }

    pub fn from_arrays_tiered(
        cfg: &ModelConfig,
        arrays: &BTreeMap<String, Array>,
        attn_quant: QuantScheme,
        expert_quant: QuantScheme,
        tiers: &TierPolicy,
    ) -> Result<Self> {
        let get = |name: &str| -> Result<Tensor> {
            arrays
                .get(name)
                .ok_or_else(|| Error::Npz(format!("weights.npz missing '{name}'")))?
                .as_f32()
                .cloned()
        };

        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let g = |suffix: &str| get(&format!("layers.{i}.{suffix}"));
            layers.push(LayerWeights {
                attn_ln: g("attn_ln")?,
                wq: maybe_quantize(g("wq")?, attn_quant, cfg)?,
                wk: maybe_quantize(g("wk")?, attn_quant, cfg)?,
                wv: maybe_quantize(g("wv")?, attn_quant, cfg)?,
                wo: maybe_quantize(g("wo")?, attn_quant, cfg)?,
                mlp_ln: g("mlp_ln")?,
                // the router gate stays 16-bit (paper keeps gates high
                // precision — they steer everything)
                w_gate: g("w_gate")?,
            });
        }

        // expert pool: quantized wire-format host copies (per-tier
        // variants included when the policy is on)
        let experts = HostExpertPool::build_tiered(cfg, expert_quant, tiers, |layer, expert| {
            let w1 = get(&format!("layers.{layer}.w1"))?;
            let w3 = get(&format!("layers.{layer}.w3"))?;
            let w2 = get(&format!("layers.{layer}.w2"))?;
            Ok((slice_expert(&w1, expert)?, slice_expert(&w3, expert)?, slice_expert(&w2, expert)?))
        })?;

        let mw = ModelWeights {
            cfg: cfg.clone(),
            embed: get("embed")?,
            final_ln: get("final_ln")?,
            lm_head: get("lm_head")?,
            layers,
            experts: Arc::new(experts),
            attn_quant,
        };
        mw.validate()?;
        Ok(mw)
    }

    fn validate(&self) -> Result<()> {
        let c = &self.cfg;
        let want = |t: &Tensor, shape: &[usize], name: &str| -> Result<()> {
            if t.shape != shape {
                return Err(Error::Shape(format!(
                    "{name}: expected {shape:?}, got {:?}",
                    t.shape
                )));
            }
            Ok(())
        };
        want(&self.embed, &[c.vocab_size, c.d_model], "embed")?;
        want(&self.lm_head, &[c.d_model, c.vocab_size], "lm_head")?;
        want(&self.final_ln, &[c.d_model], "final_ln")?;
        for (i, l) in self.layers.iter().enumerate() {
            want(&l.wq, &[c.d_model, c.q_dim()], &format!("layers.{i}.wq"))?;
            want(&l.wk, &[c.d_model, c.kv_dim()], &format!("layers.{i}.wk"))?;
            want(&l.wv, &[c.d_model, c.kv_dim()], &format!("layers.{i}.wv"))?;
            want(&l.wo, &[c.q_dim(), c.d_model], &format!("layers.{i}.wo"))?;
            want(&l.w_gate, &[c.d_model, c.n_experts], &format!("layers.{i}.w_gate"))?;
        }
        Ok(())
    }

    /// Non-expert parameter bytes resident on the device (size accounting).
    pub fn shared_bytes(&self) -> u64 {
        let mut n = self.embed.len() + self.final_ln.len() + self.lm_head.len();
        for l in &self.layers {
            n += l.attn_ln.len() + l.mlp_ln.len() + l.w_gate.len();
        }
        let mut b = (n * 2) as u64; // embeddings/norms/gates at 16 bit
        for l in &self.layers {
            let attn_n = l.wq.len() + l.wk.len() + l.wv.len() + l.wo.len();
            let g = self.attn_quant.group_size(self.cfg.group_size);
            b += self.attn_quant.bytes_for(attn_n, g);
        }
        b
    }

    /// Total model bytes (shared + experts) under the current schemes —
    /// the "Model size, GB" column of Table 1.
    pub fn total_bytes(&self) -> u64 {
        self.shared_bytes() + self.experts.total_bytes()
    }
}

/// Quantize + dequantize a shared weight (identity for Fp16: 16-bit round
/// trip is numerically negligible for our value ranges and the paper keeps
/// fp16 as the uncompressed reference).
fn maybe_quantize(w: Tensor, scheme: QuantScheme, cfg: &ModelConfig) -> Result<Tensor> {
    match scheme {
        QuantScheme::Fp16 => Ok(w),
        QuantScheme::Hqq { bits } => {
            let g = scheme.group_size(cfg.group_size);
            let q = hqq::quantize(&w, &HqqConfig::new(bits, g))?;
            q.dequantize()
        }
    }
}

/// Slice expert `e` out of a stacked [E, a, b] tensor.
fn slice_expert(stacked: &Tensor, e: usize) -> Result<Tensor> {
    if stacked.rank() != 3 {
        return Err(Error::Shape(format!(
            "expected stacked expert tensor, got {:?}",
            stacked.shape
        )));
    }
    Ok(stacked.index0(e))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny() -> ModelConfig {
        let mut c = ModelConfig::tiny();
        c.n_layers = 2;
        c.d_model = 32;
        c.d_ff = 64;
        c.n_experts = 2;
        c.n_heads = 2;
        c.n_kv_heads = 1;
        c.head_dim = 16;
        c.group_size = 16;
        c
    }

    pub fn synth_arrays(cfg: &ModelConfig, seed: u64) -> BTreeMap<String, Array> {
        let mut rng = Rng::new(seed);
        let mut m = BTreeMap::new();
        let mut put = |name: String, shape: Vec<usize>, rng: &mut Rng| {
            let n: usize = shape.iter().product();
            let t = Tensor::new(
                (0..n).map(|_| rng.normal() as f32 * 0.1).collect(),
                shape,
            )
            .unwrap();
            m.insert(name, Array::F32(t));
        };
        put("embed".into(), vec![cfg.vocab_size, cfg.d_model], &mut rng);
        put("final_ln".into(), vec![cfg.d_model], &mut rng);
        put("lm_head".into(), vec![cfg.d_model, cfg.vocab_size], &mut rng);
        for i in 0..cfg.n_layers {
            put(format!("layers.{i}.attn_ln"), vec![cfg.d_model], &mut rng);
            put(format!("layers.{i}.wq"), vec![cfg.d_model, cfg.q_dim()], &mut rng);
            put(format!("layers.{i}.wk"), vec![cfg.d_model, cfg.kv_dim()], &mut rng);
            put(format!("layers.{i}.wv"), vec![cfg.d_model, cfg.kv_dim()], &mut rng);
            put(format!("layers.{i}.wo"), vec![cfg.q_dim(), cfg.d_model], &mut rng);
            put(format!("layers.{i}.mlp_ln"), vec![cfg.d_model], &mut rng);
            put(format!("layers.{i}.w_gate"), vec![cfg.d_model, cfg.n_experts], &mut rng);
            put(format!("layers.{i}.w1"), vec![cfg.n_experts, cfg.d_model, cfg.d_ff], &mut rng);
            put(format!("layers.{i}.w3"), vec![cfg.n_experts, cfg.d_model, cfg.d_ff], &mut rng);
            put(format!("layers.{i}.w2"), vec![cfg.n_experts, cfg.d_ff, cfg.d_model], &mut rng);
        }
        m
    }

    #[test]
    fn loads_and_validates() {
        let cfg = tiny();
        let arrays = synth_arrays(&cfg, 1);
        let mw = ModelWeights::from_arrays(
            &cfg,
            &arrays,
            QuantScheme::Fp16,
            QuantScheme::Hqq { bits: 3 },
        )
        .unwrap();
        assert_eq!(mw.layers.len(), 2);
        assert_eq!(mw.experts.experts.len(), 4);
    }

    #[test]
    fn missing_tensor_is_reported() {
        let cfg = tiny();
        let mut arrays = synth_arrays(&cfg, 1);
        arrays.remove("layers.1.wq");
        let err = match ModelWeights::from_arrays(
            &cfg,
            &arrays,
            QuantScheme::Fp16,
            QuantScheme::Fp16,
        ) {
            Err(e) => e,
            Ok(_) => panic!("expected missing-tensor error"),
        };
        assert!(err.to_string().contains("layers.1.wq"));
    }

    #[test]
    fn attn_quant_perturbs_but_preserves_scale() {
        let cfg = tiny();
        let arrays = synth_arrays(&cfg, 2);
        let fp = ModelWeights::from_arrays(&cfg, &arrays, QuantScheme::Fp16, QuantScheme::Fp16)
            .unwrap();
        let q2 = ModelWeights::from_arrays(
            &cfg,
            &arrays,
            QuantScheme::Hqq { bits: 2 },
            QuantScheme::Fp16,
        )
        .unwrap();
        let diff = fp.layers[0].wq.max_abs_diff(&q2.layers[0].wq);
        assert!(diff > 0.0, "2-bit quant must perturb weights");
        assert!(diff < 0.2, "but not destroy them (diff={diff})");
    }

    #[test]
    fn tiered_load_builds_tiered_pool() {
        let cfg = tiny();
        let arrays = synth_arrays(&cfg, 4);
        let eq = QuantScheme::Hqq { bits: 3 };
        let uni = ModelWeights::from_arrays(&cfg, &arrays, QuantScheme::Fp16, eq).unwrap();
        assert!(!uni.experts.tiered());
        let tiered = ModelWeights::from_arrays_tiered(
            &cfg,
            &arrays,
            QuantScheme::Fp16,
            eq,
            &TierPolicy::hot_cold(),
        )
        .unwrap();
        assert!(tiered.experts.tiered());
        // Table 1 size accounting counts base copies only — the extra
        // tier variants are host-RAM duplicates, not model size
        assert_eq!(uni.total_bytes(), tiered.total_bytes());
    }

    #[test]
    fn size_accounting_orders_schemes() {
        let cfg = tiny();
        let arrays = synth_arrays(&cfg, 3);
        let size = |aq, eq| {
            ModelWeights::from_arrays(&cfg, &arrays, aq, eq)
                .unwrap()
                .total_bytes()
        };
        let fp = size(QuantScheme::Fp16, QuantScheme::Fp16);
        let e4 = size(QuantScheme::Fp16, QuantScheme::Hqq { bits: 4 });
        let e2 = size(QuantScheme::Fp16, QuantScheme::Hqq { bits: 2 });
        let both2 = size(QuantScheme::Hqq { bits: 2 }, QuantScheme::Hqq { bits: 2 });
        assert!(fp > e4 && e4 > e2 && e2 > both2);
    }
}
