//! Small deterministic RNG (xoshiro256**) — `rand` is not in the offline
//! crate set. Used for sampling, workload generation and property tests.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed
        let mut x = seed.wrapping_add(0x9e3779b97f4a7c15);
        let mut next = || {
            x = x.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Sample an index proportionally to the (non-negative) weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|&w| w.max(0.0) as f64).sum();
        if total <= 0.0 {
            return self.below(weights.len());
        }
        let mut r = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            r -= w.max(0.0) as f64;
            if r <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(7);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let frac2 = counts[2] as f64 / 30_000.0;
        assert!((frac2 - 0.7).abs() < 0.03, "{frac2}");
    }

    #[test]
    fn categorical_handles_zero_total() {
        let mut r = Rng::new(3);
        let i = r.categorical(&[0.0, 0.0]);
        assert!(i < 2);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
