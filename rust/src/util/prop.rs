//! Tiny property-testing harness (proptest is not in the offline crate set).
//!
//! `check` runs a property over `n` random cases; on failure it reports the
//! seed so the case can be replayed. Generators are just closures over
//! [`Rng`] — composable enough for the invariants this crate tests (LRU
//! behaviour, bit-pack round-trips, HQQ error bounds, timeline monotonicity).

use super::rng::Rng;

pub const DEFAULT_CASES: u64 = 200;

/// Run `prop` on `cases` random inputs drawn via `gen`. Panics with the
/// failing seed on the first violation.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: u64,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let base = env_seed();
    for case in 0..cases {
        let seed = base.wrapping_add(case);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed (replay with PROP_SEED={seed}):\n  \
                 input: {input:?}\n  violation: {msg}"
            );
        }
    }
}

fn env_seed() -> u64 {
    std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5eed_0000)
}

/// Convenience assertion helpers for property bodies.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

pub fn approx_eq(a: f64, b: f64, tol: f64) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{a} !~ {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("sum-commutes", 50, |r| (r.below(100), r.below(100)), |&(a, b)| {
            ensure(a + b == b + a, "addition must commute")
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn reports_failures() {
        check("always-fails", 5, |r| r.below(10), |_| Err("nope".into()));
    }

    #[test]
    fn approx_eq_tolerates_scale() {
        assert!(approx_eq(1000.0, 1000.1, 1e-3).is_ok());
        assert!(approx_eq(1.0, 2.0, 1e-3).is_err());
    }
}
