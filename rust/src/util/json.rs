//! Minimal JSON parser/writer (serde_json is not in the offline crate set).
//!
//! Supports the full JSON grammar minus exotic number forms; numbers are
//! parsed as f64 (with an i64 fast path preserved for round-tripping).
//! Used for `artifacts/manifest.json`, config files, experiment outputs and
//! the coordinator's line protocol.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Int(i64),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            Json::Int(x) => Some(*x as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(x) => Some(*x),
            Json::Num(x) if x.fract() == 0.0 => Some(*x as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|x| usize::try_from(x).ok())
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // -- builders ----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Int(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Int(x as i64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Self {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Self {
        Json::Str(x.to_string())
    }
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub at: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), at: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            // surrogate pairs not needed for our payloads;
                            // map unpaired surrogates to the replacement char.
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {
                    // collect UTF-8 continuation bytes verbatim
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        return Err(self.err("bad utf8"));
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        if !text.contains(['.', 'e', 'E']) {
            if let Ok(x) = text.parse::<i64>() {
                return Ok(Json::Int(x));
            }
        }
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Int(x) => write!(f, "{x}"),
            Json::Num(x) => {
                if x.is_finite() {
                    write!(f, "{x}")
                } else {
                    write!(f, "null") // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-42").unwrap(), Json::Int(-42));
        assert_eq!(Json::parse("2.5e1").unwrap(), Json::Num(25.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_i64(), Some(1));
        assert_eq!(arr[1].get("b"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_display_parse() {
        let v = Json::obj(vec![
            ("name", "tab\there".into()),
            ("xs", Json::arr([1i64.into(), 2i64.into()])),
            ("f", 0.5.into()),
            ("flag", true.into()),
        ]);
        let text = v.to_string();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn unicode_roundtrip() {
        let v = Json::parse("\"héllo \\u00e9\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo é"));
    }
}
