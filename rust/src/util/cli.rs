//! Minimal CLI flag parser (clap is not in the offline crate set).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and positional
//! arguments. Each binary declares its options up front so `--help` output
//! stays accurate.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

#[derive(Debug)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

pub struct Cli {
    bin: &'static str,
    about: &'static str,
    opts: Vec<OptSpec>,
}

impl Cli {
    pub fn new(bin: &'static str, about: &'static str) -> Self {
        Cli { bin, about, opts: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: Some(default), is_flag: false });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.bin, self.about);
        for o in &self.opts {
            let kind = if o.is_flag { "" } else { " <value>" };
            let def = match o.default {
                Some(d) if !o.is_flag => format!(" [default: {d}]"),
                _ => String::new(),
            };
            s.push_str(&format!("  --{}{kind}\t{}{def}\n", o.name, o.help));
        }
        s
    }

    /// Parse process args (skipping argv[0]); exits on --help or bad input.
    pub fn parse(self) -> Args {
        match self.parse_from(std::env::args().skip(1)) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("{e}\n\n{}", self.usage());
                std::process::exit(2);
            }
        }
    }

    pub fn parse_from(
        &self,
        args: impl IntoIterator<Item = String>,
    ) -> Result<Args, String> {
        let mut values = BTreeMap::new();
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        let mut it = args.into_iter().peekable();
        while let Some(arg) = it.next() {
            if arg == "--help" || arg == "-h" {
                println!("{}", self.usage());
                std::process::exit(0);
            }
            if let Some(name) = arg.strip_prefix("--") {
                let (name, inline) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| format!("unknown option --{name}"))?;
                if spec.is_flag {
                    if inline.is_some() {
                        return Err(format!("--{name} takes no value"));
                    }
                    flags.push(name.to_string());
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("--{name} needs a value"))?,
                    };
                    values.insert(name.to_string(), v);
                }
            } else {
                positional.push(arg);
            }
        }
        // fill defaults
        for o in &self.opts {
            if let Some(d) = o.default {
                values.entry(o.name.to_string()).or_insert_with(|| d.to_string());
            }
        }
        // required present?
        for o in &self.opts {
            if !o.is_flag && o.default.is_none() && !values.contains_key(o.name) {
                return Err(format!("missing required option --{}", o.name));
            }
        }
        Ok(Args { values, flags, positional })
    }
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option --{name} not declared"))
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be an integer"))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be a number"))
    }

    pub fn has(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("steps", "10", "number of steps")
            .req("path", "input path")
            .flag("verbose", "noisy output")
    }

    fn parse(args: &[&str]) -> Result<Args, String> {
        cli().parse_from(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_values_and_defaults() {
        let a = parse(&["--path", "/tmp/x", "--steps=20", "pos1"]).unwrap();
        assert_eq!(a.get("path"), "/tmp/x");
        assert_eq!(a.get_usize("steps"), 20);
        assert_eq!(a.positional, vec!["pos1"]);
        assert!(!a.has("verbose"));
    }

    #[test]
    fn default_applies() {
        let a = parse(&["--path", "p"]).unwrap();
        assert_eq!(a.get_usize("steps"), 10);
    }

    #[test]
    fn missing_required_errors() {
        assert!(parse(&["--steps", "5"]).is_err());
    }

    #[test]
    fn unknown_option_errors() {
        assert!(parse(&["--path", "p", "--bogus", "1"]).is_err());
    }

    #[test]
    fn flag_with_value_errors() {
        assert!(parse(&["--path", "p", "--verbose=yes"]).is_err());
    }
}
