//! In-tree replacements for crates missing from the offline cache:
//! JSON (serde_json), CLI parsing (clap), deterministic RNG (rand) and a
//! property-test runner (proptest).

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
