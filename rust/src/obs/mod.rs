//! Expert-flow observability: the per-(layer, expert) flight recorder
//! and the counterfactual cache-curve simulator.
//!
//! The paper's two load-bearing claims — LRU expert caching works
//! because consecutive tokens reuse experts (§3.1), and speculative
//! prefetch works because layer `l` hidden states predict layer `l+1`
//! routing (§3.2) — were previously observable only as aggregate
//! totals. This module records *which experts* caused the traffic:
//!
//! * [`ExpertObs`] keeps one [`ExpertCell`] per (layer, expert) —
//!   routed uses, hits, demand vs speculative loads, prefetches
//!   used/wasted, evictions, virtual-time-weighted residency, and wire
//!   bytes shipped per precision tier — fed from the cache manager's
//!   flag-gated [`CacheLog`] plus the engine's transfer sites.
//! * Each layer's recorded access stream ([`StreamEvent`]) replays
//!   offline through [`simulate_lru`] at every cache size and a
//!   Belady/OPT clairvoyant bound ([`simulate_opt`]), producing
//!   hit-rate-vs-cache-budget curves from one recorded run
//!   ([`cache_curves`]). The anchoring invariant: simulated LRU at the
//!   engine's *actual* `cache_k` reproduces the measured per-layer
//!   hit/miss counters exactly (asserted in `rust/tests/expert_obs.rs`
//!   and surfaced as `curves.measured.anchored` in the report).
//!
//! Everything is gated by `ServingConfig::expert_obs` (default off): a
//! disabled recorder never allocates, every record call is a branch on
//! a bool, and serving output is byte-identical with the recorder on or
//! off — the same inertness contract `trace` honors.
//!
//! Why the stream records *events*, not raw accesses: a speculative
//! promotion counts as a measured hit but enters the layer LRU through
//! `LruSet::insert`, and an adaptive re-tier force-drops residents
//! mid-stream. Replaying `Use { spec }` + `Drop` through an LRU of size
//! `k` therefore reproduces the manager's exact bookkeeping at
//! `k = cache_k` (`LruSet::insert` and `touch` share the same recency
//! behavior), while at other `k` it answers the counterfactual "same
//! routing, same speculation, same tier decisions — different cache
//! budget". LRU-victim evictions are deliberately NOT in the stream:
//! they are a consequence of the measured cache size and each simulated
//! size derives its own.

use std::collections::VecDeque;

use crate::cache::manager::{CacheEvent, CacheLog, CacheStats};
use crate::memory::host::ExpertId;
use crate::quant::tier::Tier;
use crate::util::json::Json;

/// Counter-track samples retained for Chrome-trace export (oldest
/// dropped first, mirroring the span ring's most-recent-window policy).
const SAMPLE_CAP: usize = 8192;

/// Fraction → basis points, the integer encoding the `spec_recall_bp` /
/// `spec_precision_bp` gauges and done-JSON fields use.
pub fn to_bp(x: f64) -> u64 {
    (x * 10_000.0).round().max(0.0) as u64
}

/// One (layer, expert) flight-recorder cell. Counters are engine-lifetime
/// (reset only by a cache cold restart); `resident_s` weights residency
/// by virtual time on the [`crate::clock::Timeline`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ExpertCell {
    /// Routed demand uses (every `on_demand_use`, any outcome).
    pub routed_uses: u64,
    /// Uses served from residency (cache hits + speculative hits).
    pub hits: u64,
    /// Subset of `hits` served from the speculative buffer (the
    /// prefetches that paid off).
    pub spec_hits: u64,
    /// Uses that missed and forced a blocking demand load.
    pub demand_loads: u64,
    /// Speculative prefetches that established residency (redundant
    /// inserts excluded — the manager never stores those).
    pub spec_loads: u64,
    /// Prefetches evicted or dropped before any use claimed them.
    pub prefetch_wasted: u64,
    /// Times this expert's residency was torn down (LRU victim, spec
    /// shed, transient free, or forced drop).
    pub evictions: u64,
    /// Virtual seconds this expert spent device-resident.
    pub resident_s: f64,
    /// Wire bytes shipped to (re)stage this expert, split by the
    /// precision tier it was shipped at: `[hot, warm, cold]`.
    pub wire_bytes: [u64; 3],
}

impl ExpertCell {
    fn is_zero(&self) -> bool {
        self.routed_uses == 0
            && self.hits == 0
            && self.spec_hits == 0
            && self.demand_loads == 0
            && self.spec_loads == 0
            && self.prefetch_wasted == 0
            && self.evictions == 0
            && self.resident_s == 0.0
            && self.wire_bytes == [0, 0, 0]
    }
}

/// Device-residency state of one cell, for virtual-time weighting and
/// wasted-prefetch attribution.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Residency {
    Absent,
    /// Resident via an unclaimed speculative load since `since` (virtual s).
    Spec { since: f64 },
    /// Resident in the layer cache since `since` (virtual s).
    Cached { since: f64 },
}

/// One recorded per-layer access-stream event — the simulator's input.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamEvent {
    /// A routed demand use. `spec` = the measured run satisfied it from
    /// the speculative buffer (a free hit at ANY cache size, followed by
    /// promotion into the layer cache).
    Use { expert: u16, spec: bool },
    /// Exogenous forced drop (adaptive re-tier invalidated the resident
    /// precision) — replayed at every cache size.
    Drop { expert: u16 },
    /// Cache cold restart: the manager's bookkeeping AND its measured
    /// counters reset together, so the simulators restart here too.
    Reset,
}

/// Periodic counter-track sample (one per scheduler tick), exported as
/// Chrome-trace `ph:"C"` events next to the span lanes.
#[derive(Debug, Clone, Copy)]
pub struct CounterSample {
    pub t_s: f64,
    /// Device-resident expert count.
    pub resident: usize,
    /// Cumulative cache hit rate in basis points.
    pub hit_rate_bp: u64,
}

/// The flight recorder. Owned by the engine beside the [`crate::trace::Tracer`],
/// fed by draining the cache manager's [`CacheLog`] and the engine's
/// transfer sites, snapshotted into telemetry each tick and rendered as
/// the `experts` TCP command's JSON.
#[derive(Debug)]
pub struct ExpertObs {
    enabled: bool,
    n_layers: usize,
    n_experts: usize,
    event_capacity: usize,
    cells: Vec<ExpertCell>,
    res: Vec<Residency>,
    streams: Vec<Vec<StreamEvent>>,
    stream_dropped: u64,
    samples: VecDeque<CounterSample>,
}

impl ExpertObs {
    /// The no-op recorder: nothing allocates, every record call is a
    /// branch on a bool.
    pub fn disabled() -> Self {
        ExpertObs {
            enabled: false,
            n_layers: 0,
            n_experts: 0,
            event_capacity: 0,
            cells: Vec::new(),
            res: Vec::new(),
            streams: Vec::new(),
            stream_dropped: 0,
            samples: VecDeque::new(),
        }
    }

    pub fn enabled(n_layers: usize, n_experts: usize, event_capacity: usize) -> Self {
        ExpertObs {
            enabled: true,
            n_layers,
            n_experts,
            event_capacity: event_capacity.max(1),
            cells: vec![ExpertCell::default(); n_layers * n_experts],
            res: vec![Residency::Absent; n_layers * n_experts],
            streams: (0..n_layers).map(|_| Vec::new()).collect(),
            stream_dropped: 0,
            samples: VecDeque::new(),
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    pub fn cell(&self, layer: usize, expert: usize) -> &ExpertCell {
        &self.cells[layer * self.n_experts + expert]
    }

    pub fn streams(&self) -> &[Vec<StreamEvent>] {
        &self.streams
    }

    /// Stream events dropped by the per-layer capacity bound. Non-zero
    /// withdraws the simulator's exact-anchor guarantee for this run.
    pub fn stream_dropped(&self) -> u64 {
        self.stream_dropped
    }

    pub fn samples(&self) -> impl Iterator<Item = &CounterSample> {
        self.samples.iter()
    }

    fn idx(&self, id: ExpertId) -> usize {
        id.layer as usize * self.n_experts + id.expert as usize
    }

    fn push_stream(&mut self, layer: usize, ev: StreamEvent) {
        let s = &mut self.streams[layer];
        if s.len() >= self.event_capacity {
            self.stream_dropped += 1;
        } else {
            s.push(ev);
        }
    }

    /// End `id`'s residency interval at `now`, accruing virtual-time
    /// residency and attributing a wasted prefetch if the copy was still
    /// an unclaimed speculative load.
    fn end_residency(&mut self, id: ExpertId, now: f64) {
        let i = self.idx(id);
        match self.res[i] {
            Residency::Absent => {}
            Residency::Spec { since } => {
                self.cells[i].resident_s += (now - since).max(0.0);
                self.cells[i].prefetch_wasted += 1;
            }
            Residency::Cached { since } => {
                self.cells[i].resident_s += (now - since).max(0.0);
            }
        }
        self.res[i] = Residency::Absent;
    }

    /// Fold a drained [`CacheLog`] batch into the recorder. `now` is the
    /// timeline clock at drain time — residency weighting is exact up to
    /// the drain granularity (the engine drains at every cache-touching
    /// choke point, so the skew is sub-layer-step).
    pub fn apply_log(&mut self, log: &[CacheLog], now: f64) {
        if !self.enabled {
            return;
        }
        for ev in log {
            match *ev {
                CacheLog::Use(CacheEvent::Hit(id)) => {
                    let i = self.idx(id);
                    self.cells[i].routed_uses += 1;
                    self.cells[i].hits += 1;
                    self.push_stream(
                        id.layer as usize,
                        StreamEvent::Use { expert: id.expert, spec: false },
                    );
                }
                CacheLog::Use(CacheEvent::SpecHit(id)) => {
                    let i = self.idx(id);
                    self.cells[i].routed_uses += 1;
                    self.cells[i].hits += 1;
                    self.cells[i].spec_hits += 1;
                    // promotion: same device copy, now owned by the layer
                    // cache — the residency interval continues
                    if let Residency::Spec { since } = self.res[i] {
                        self.res[i] = Residency::Cached { since };
                    }
                    self.push_stream(
                        id.layer as usize,
                        StreamEvent::Use { expert: id.expert, spec: true },
                    );
                }
                CacheLog::Use(CacheEvent::Miss(id)) => {
                    let i = self.idx(id);
                    self.cells[i].routed_uses += 1;
                    self.cells[i].demand_loads += 1;
                    self.push_stream(
                        id.layer as usize,
                        StreamEvent::Use { expert: id.expert, spec: false },
                    );
                }
                CacheLog::Insert(id) => {
                    let i = self.idx(id);
                    if self.res[i] == Residency::Absent {
                        self.res[i] = Residency::Cached { since: now };
                    }
                }
                CacheLog::SpecInsert(id) => {
                    let i = self.idx(id);
                    self.cells[i].spec_loads += 1;
                    if self.res[i] == Residency::Absent {
                        self.res[i] = Residency::Spec { since: now };
                    }
                }
                CacheLog::Evict(id) => {
                    self.cells[self.idx(id)].evictions += 1;
                    self.end_residency(id, now);
                }
                CacheLog::Drop(id) => {
                    self.cells[self.idx(id)].evictions += 1;
                    self.end_residency(id, now);
                    self.push_stream(id.layer as usize, StreamEvent::Drop { expert: id.expert });
                }
            }
        }
    }

    /// Attribute wire bytes shipped to (re)stage `id` at precision tier
    /// `tier`. Called at the engine's transfer-issue sites (demand
    /// loads, speculative prefetches, naive layer streams) — bytes count
    /// even when the manager later discards the copy as redundant,
    /// because the link shipped them regardless.
    pub fn on_wire(&mut self, id: ExpertId, tier: Tier, bytes: u64) {
        if !self.enabled {
            return;
        }
        let t = match tier {
            Tier::Hot => 0,
            Tier::Warm => 1,
            Tier::Cold => 2,
        };
        let i = self.idx(id);
        self.cells[i].wire_bytes[t] += bytes;
    }

    /// Cache cold restart (`MoeEngine::drop_expert_cache`): every
    /// residency interval ends, unclaimed prefetches count as wasted,
    /// and a [`StreamEvent::Reset`] marks the point where the manager's
    /// measured counters restarted — the simulators replay only the
    /// post-reset window so the anchor stays exact.
    pub fn on_cache_reset(&mut self, now: f64) {
        if !self.enabled {
            return;
        }
        for li in 0..self.n_layers {
            for e in 0..self.n_experts {
                self.end_residency(ExpertId::new(li, e), now);
            }
            self.push_stream(li, StreamEvent::Reset);
        }
    }

    /// Record one counter-track sample (one per scheduler tick).
    pub fn sample(&mut self, t_s: f64, resident: usize, hits: u64, misses: u64) {
        if !self.enabled {
            return;
        }
        let total = hits + misses;
        let hit_rate_bp = if total == 0 {
            0
        } else {
            to_bp(hits as f64 / total as f64)
        };
        if self.samples.len() == SAMPLE_CAP {
            self.samples.pop_front();
        }
        self.samples.push_back(CounterSample { t_s, resident, hit_rate_bp });
    }

    /// The counter samples as Chrome-trace `ph:"C"` events (pid 2, the
    /// PCIe-link process, so Perfetto draws expert churn and hit rate
    /// directly under the transfer lanes). Merged into the span export
    /// by [`crate::trace::Tracer::chrome_trace_with_counters`].
    pub fn chrome_counter_events(&self) -> Vec<Json> {
        let mut out = Vec::with_capacity(self.samples.len() * 2);
        for s in &self.samples {
            out.push(Json::obj(vec![
                ("ph", "C".into()),
                ("pid", 2usize.into()),
                ("name", "expert_residency".into()),
                ("ts", (s.t_s * 1e6).into()),
                ("args", Json::obj(vec![("resident", s.resident.into())])),
            ]));
            out.push(Json::obj(vec![
                ("ph", "C".into()),
                ("pid", 2usize.into()),
                ("name", "expert_hit_rate_bp".into()),
                ("ts", (s.t_s * 1e6).into()),
                ("args", Json::obj(vec![("bp", (s.hit_rate_bp as usize).into())])),
            ]));
        }
        out
    }

    /// The `experts` command's JSON body. `stats` is the live cache
    /// manager's counter block, `cache_k` its actual per-layer capacity,
    /// `now_s` the timeline clock (open residency intervals accrue up to
    /// it), `copy_jobs` the copy engine's `(staged, demand, spec)`
    /// lifetime job counts.
    pub fn report(
        &self,
        stats: &CacheStats,
        cache_k: usize,
        now_s: f64,
        copy_jobs: (u64, u64, u64),
    ) -> Json {
        let mut cells = Vec::new();
        for li in 0..self.n_layers {
            for e in 0..self.n_experts {
                let i = li * self.n_experts + e;
                let mut c = self.cells[i];
                // accrue the open residency interval up to the snapshot
                match self.res[i] {
                    Residency::Absent => {}
                    Residency::Spec { since } | Residency::Cached { since } => {
                        c.resident_s += (now_s - since).max(0.0);
                    }
                }
                if c.is_zero() {
                    continue;
                }
                cells.push(Json::obj(vec![
                    ("layer", li.into()),
                    ("expert", e.into()),
                    ("routed_uses", (c.routed_uses as f64).into()),
                    ("hits", (c.hits as f64).into()),
                    ("spec_hits", (c.spec_hits as f64).into()),
                    ("demand_loads", (c.demand_loads as f64).into()),
                    ("spec_loads", (c.spec_loads as f64).into()),
                    ("prefetch_wasted", (c.prefetch_wasted as f64).into()),
                    ("evictions", (c.evictions as f64).into()),
                    ("resident_s", c.resident_s.into()),
                    (
                        "wire_bytes",
                        Json::obj(vec![
                            ("hot", (c.wire_bytes[0] as f64).into()),
                            ("warm", (c.wire_bytes[1] as f64).into()),
                            ("cold", (c.wire_bytes[2] as f64).into()),
                        ]),
                    ),
                ]));
            }
        }

        let mut per_layer = Vec::new();
        for (li, &(hits, uses)) in stats.per_layer.iter().enumerate() {
            let spec = stats.spec_per_layer.get(li).cloned().unwrap_or_default();
            per_layer.push(Json::obj(vec![
                ("layer", li.into()),
                ("uses", (uses as f64).into()),
                ("hits", (hits as f64).into()),
                ("spec_recall_bp", (to_bp(spec.recall()) as f64).into()),
                ("spec_precision_bp", (to_bp(spec.precision()) as f64).into()),
            ]));
        }

        // counterfactual curves + the anchoring invariant: simulated LRU
        // at the actual cache_k must reproduce the measured per-layer
        // counters exactly (unless the stream overflowed)
        let (lru, opt) = cache_curves(&self.streams, self.n_experts);
        let measured_hits: u64 = stats.per_layer.iter().map(|&(h, _)| h).sum();
        let measured_uses: u64 = stats.per_layer.iter().map(|&(_, u)| u).sum();
        let mut anchored = self.stream_dropped == 0;
        let mut sim_hits = 0u64;
        let mut sim_misses = 0u64;
        for (li, stream) in self.streams.iter().enumerate() {
            let (h, m) = simulate_lru(stream, cache_k);
            sim_hits += h;
            sim_misses += m;
            if let Some(&(mh, mu)) = stats.per_layer.get(li) {
                anchored &= h == mh && h + m == mu;
            }
        }
        let curve_json = |pts: &[CurvePoint]| {
            Json::arr(pts.iter().map(|p| {
                let total = p.hits + p.misses;
                let rate = if total == 0 { 0.0 } else { p.hits as f64 / total as f64 };
                Json::obj(vec![
                    ("k", p.k.into()),
                    ("hits", (p.hits as f64).into()),
                    ("misses", (p.misses as f64).into()),
                    ("hit_rate", rate.into()),
                ])
            }))
        };
        let curves = Json::obj(vec![
            ("lru", curve_json(&lru)),
            ("opt", curve_json(&opt)),
            (
                "measured",
                Json::obj(vec![
                    ("k", cache_k.into()),
                    ("hits", (measured_hits as f64).into()),
                    ("misses", ((measured_uses - measured_hits) as f64).into()),
                    ("sim_hits", (sim_hits as f64).into()),
                    ("sim_misses", (sim_misses as f64).into()),
                    ("anchored", anchored.into()),
                ]),
            ),
        ]);

        let stream_events: usize = self.streams.iter().map(Vec::len).sum();
        Json::obj(vec![
            ("type", "experts".into()),
            ("enabled", true.into()),
            ("cache_k", cache_k.into()),
            ("n_layers", self.n_layers.into()),
            ("n_experts", self.n_experts.into()),
            ("experts", Json::Arr(cells)),
            ("per_layer", Json::Arr(per_layer)),
            ("curves", curves),
            ("stream_events", stream_events.into()),
            ("stream_dropped", (self.stream_dropped as f64).into()),
            (
                "copy_engine",
                Json::obj(vec![
                    ("staged_jobs", (copy_jobs.0 as f64).into()),
                    ("demand_jobs", (copy_jobs.1 as f64).into()),
                    ("spec_jobs", (copy_jobs.2 as f64).into()),
                ]),
            ),
        ])
    }
}

// ---------------------------------------------------------------------
// counterfactual cache-curve simulation
// ---------------------------------------------------------------------

/// One point of a hit-rate-vs-cache-budget curve (aggregated over all
/// layers at cache size `k`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CurvePoint {
    pub k: usize,
    pub hits: u64,
    pub misses: u64,
}

/// The window of `stream` after the last [`StreamEvent::Reset`] — the
/// only part the live manager's counters still describe.
fn post_reset(stream: &[StreamEvent]) -> &[StreamEvent] {
    match stream.iter().rposition(|e| *e == StreamEvent::Reset) {
        Some(i) => &stream[i + 1..],
        None => stream,
    }
}

/// Replay one layer's recorded stream through a k-way LRU, reproducing
/// [`crate::cache::manager::CacheManager`]'s per-layer bookkeeping
/// exactly at the measured `cache_k` (the anchor) and counterfactually
/// at any other size.
///
/// Semantics, matching the manager one-to-one:
/// * `Use { spec: true }` — a hit at ANY size (the speculative buffer
///   satisfied it before the layer cache was consulted), then inserted
///   at MRU (promotion; `LruSet::insert` and `touch` share recency
///   behavior), evicting the LRU entry if the set overflows.
/// * `Use { spec: false }` — hit iff resident (moved to MRU), else a
///   miss followed by the demand fill's insert at MRU. The manager
///   performs the fill immediately after the miss (`ensure_expert` is
///   the sole `on_demand_use` caller and loads before returning), so
///   fusing miss + insert preserves event order.
/// * `Drop` — removed if present (forced drop; no counter change).
/// * `k = 0` never stores (the cache-less ablation): every demand use
///   misses, speculative uses still hit.
pub fn simulate_lru(stream: &[StreamEvent], k: usize) -> (u64, u64) {
    let mut cache: Vec<u16> = Vec::new(); // MRU first
    let (mut hits, mut misses) = (0u64, 0u64);
    for ev in post_reset(stream) {
        match *ev {
            StreamEvent::Use { expert, spec } => {
                let pos = cache.iter().position(|&x| x == expert);
                if spec || pos.is_some() {
                    hits += 1;
                } else {
                    misses += 1;
                }
                if let Some(p) = pos {
                    cache.remove(p);
                }
                if k > 0 {
                    cache.insert(0, expert);
                    if cache.len() > k {
                        cache.pop();
                    }
                }
            }
            StreamEvent::Drop { expert } => {
                if let Some(p) = cache.iter().position(|&x| x == expert) {
                    cache.remove(p);
                }
            }
            StreamEvent::Reset => unreachable!("post_reset strips Reset events"),
        }
    }
    (hits, misses)
}

/// Clairvoyant (Belady/OPT-style) replay of one layer's stream at cache
/// size `k`: on every insertion that needs a victim, evict the candidate
/// whose next *demand* use is farthest in the future — treating the
/// distance as infinite when a `Drop` or a free speculative re-entry
/// precedes it (evicting such an entry costs nothing). Bypass is
/// allowed: the incoming expert itself is a victim candidate, so the
/// cache never degrades itself for a single-use expert.
///
/// This is an upper bound achievable by a clairvoyant policy under the
/// same stream semantics; [`cache_curves`] additionally takes the max
/// with the LRU replay (a clairvoyant scheduler can always emulate
/// LRU) and enforces monotonicity in `k` (a larger clairvoyant cache
/// can emulate a smaller one by leaving slots empty), so the published
/// OPT curve structurally dominates LRU and never decreases.
pub fn simulate_opt(stream: &[StreamEvent], k: usize) -> (u64, u64) {
    let seg = post_reset(stream);
    // per-expert positions of future events that matter for eviction:
    // (position, is_demand_use)
    let mut future: std::collections::BTreeMap<u16, Vec<(usize, bool)>> =
        std::collections::BTreeMap::new();
    for (i, ev) in seg.iter().enumerate() {
        match *ev {
            StreamEvent::Use { expert, spec } => {
                future.entry(expert).or_default().push((i, !spec));
            }
            StreamEvent::Drop { expert } => {
                future.entry(expert).or_default().push((i, false));
            }
            StreamEvent::Reset => unreachable!("post_reset strips Reset events"),
        }
    }
    // effective next-demand distance of `expert` strictly after position
    // `i`: the next demand use, unless a drop or free re-entry comes
    // first (then eviction is free => infinite distance)
    let eff_next = |expert: u16, i: usize| -> usize {
        let evs = match future.get(&expert) {
            Some(v) => v,
            None => return usize::MAX,
        };
        let at = evs.partition_point(|&(p, _)| p <= i);
        match evs.get(at) {
            Some(&(p, true)) => p,
            _ => usize::MAX,
        }
    };
    let mut cache: Vec<u16> = Vec::new();
    let (mut hits, mut misses) = (0u64, 0u64);
    for (i, ev) in seg.iter().enumerate() {
        match *ev {
            StreamEvent::Use { expert, spec } => {
                let resident = cache.contains(&expert);
                if spec || resident {
                    hits += 1;
                } else {
                    misses += 1;
                }
                if !resident && k > 0 {
                    if cache.len() < k {
                        cache.push(expert);
                    } else {
                        // farthest-future victim, incoming included (bypass)
                        let mut victim = expert;
                        let mut worst = eff_next(expert, i);
                        for &r in &cache {
                            let d = eff_next(r, i);
                            if d > worst {
                                worst = d;
                                victim = r;
                            }
                        }
                        if victim != expert {
                            cache.retain(|&x| x != victim);
                            cache.push(expert);
                        }
                    }
                }
            }
            StreamEvent::Drop { expert } => {
                cache.retain(|&x| x != expert);
            }
            StreamEvent::Reset => unreachable!("post_reset strips Reset events"),
        }
    }
    (hits, misses)
}

/// Hit-rate-vs-cache-budget curves aggregated over all layers, for
/// `k = 1..=n_experts`: the LRU replay and the clairvoyant OPT bound.
/// OPT is clamped per layer to at least the LRU replay (clairvoyance
/// can emulate LRU) and made monotone in `k` (a larger clairvoyant
/// cache can emulate a smaller one), keeping the published bound honest
/// AND structurally dominant.
pub fn cache_curves(
    streams: &[Vec<StreamEvent>],
    n_experts: usize,
) -> (Vec<CurvePoint>, Vec<CurvePoint>) {
    let mut lru = Vec::with_capacity(n_experts);
    let mut opt = Vec::with_capacity(n_experts);
    let mut prev_opt_hits = 0u64;
    for k in 1..=n_experts {
        let mut lh = 0u64;
        let mut lm = 0u64;
        let mut oh = 0u64;
        for s in streams {
            let (h, m) = simulate_lru(s, k);
            lh += h;
            lm += m;
            let (h2, _) = simulate_opt(s, k);
            oh += h2.max(h);
        }
        let total = lh + lm;
        let oh = oh.max(prev_opt_hits).min(total);
        prev_opt_hits = oh;
        lru.push(CurvePoint { k, hits: lh, misses: lm });
        opt.push(CurvePoint { k, hits: oh, misses: total - oh });
    }
    (lru, opt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::manager::CacheManager;
    use crate::memory::device::{DeviceExpert, DeviceMemory};
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn id(l: usize, e: usize) -> ExpertId {
        ExpertId::new(l, e)
    }

    fn use_ev(e: u16, spec: bool) -> StreamEvent {
        StreamEvent::Use { expert: e, spec }
    }

    #[test]
    fn disabled_recorder_is_inert() {
        let mut o = ExpertObs::disabled();
        o.apply_log(&[CacheLog::Use(CacheEvent::Miss(id(0, 1)))], 1.0);
        o.on_wire(id(0, 1), Tier::Warm, 100);
        o.on_cache_reset(2.0);
        o.sample(3.0, 4, 1, 1);
        assert!(!o.is_enabled());
        assert_eq!(o.stream_dropped(), 0);
        assert!(o.streams().is_empty());
        assert_eq!(o.samples().count(), 0);
        assert!(o.chrome_counter_events().is_empty());
    }

    #[test]
    fn flight_recorder_counts_cells() {
        let mut o = ExpertObs::enabled(2, 4, 64);
        o.apply_log(
            &[
                CacheLog::Use(CacheEvent::Miss(id(0, 1))),
                CacheLog::Insert(id(0, 1)),
                CacheLog::Use(CacheEvent::Hit(id(0, 1))),
                CacheLog::SpecInsert(id(0, 2)),
                CacheLog::Use(CacheEvent::SpecHit(id(0, 2))),
                CacheLog::Use(CacheEvent::Miss(id(1, 3))),
            ],
            0.0,
        );
        o.on_wire(id(0, 1), Tier::Warm, 100);
        o.on_wire(id(0, 2), Tier::Cold, 40);
        o.on_wire(id(0, 2), Tier::Hot, 7);
        let c01 = o.cell(0, 1);
        assert_eq!(c01.routed_uses, 2);
        assert_eq!(c01.hits, 1);
        assert_eq!(c01.demand_loads, 1);
        assert_eq!(c01.wire_bytes, [0, 100, 0]);
        let c02 = o.cell(0, 2);
        assert_eq!(c02.spec_loads, 1);
        assert_eq!(c02.spec_hits, 1);
        assert_eq!(c02.hits, 1);
        assert_eq!(c02.wire_bytes, [7, 0, 40]);
        assert_eq!(o.cell(1, 3).demand_loads, 1);
        assert_eq!(
            o.streams()[0],
            vec![use_ev(1, false), use_ev(1, false), use_ev(2, true)]
        );
        assert_eq!(o.streams()[1], vec![use_ev(3, false)]);
    }

    #[test]
    fn residency_is_virtual_time_weighted() {
        let mut o = ExpertObs::enabled(1, 4, 64);
        o.apply_log(&[CacheLog::Insert(id(0, 1))], 1.0);
        o.apply_log(&[CacheLog::Evict(id(0, 1))], 3.5);
        assert!((o.cell(0, 1).resident_s - 2.5).abs() < 1e-12);
        assert_eq!(o.cell(0, 1).evictions, 1);
        // a speculative promotion preserves the interval start
        o.apply_log(&[CacheLog::SpecInsert(id(0, 2))], 4.0);
        o.apply_log(&[CacheLog::Use(CacheEvent::SpecHit(id(0, 2)))], 5.0);
        o.apply_log(&[CacheLog::Drop(id(0, 2))], 6.0);
        assert!((o.cell(0, 2).resident_s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn unclaimed_prefetches_count_as_wasted() {
        let mut o = ExpertObs::enabled(1, 4, 64);
        o.apply_log(
            &[CacheLog::SpecInsert(id(0, 1)), CacheLog::Evict(id(0, 1))],
            0.0,
        );
        assert_eq!(o.cell(0, 1).prefetch_wasted, 1);
        // a claimed prefetch is not wasted even when later evicted
        o.apply_log(
            &[
                CacheLog::SpecInsert(id(0, 2)),
                CacheLog::Use(CacheEvent::SpecHit(id(0, 2))),
                CacheLog::Evict(id(0, 2)),
            ],
            1.0,
        );
        assert_eq!(o.cell(0, 2).prefetch_wasted, 0);
        assert_eq!(o.cell(0, 2).spec_hits, 1);
    }

    #[test]
    fn stream_capacity_drops_and_counts() {
        let mut o = ExpertObs::enabled(1, 4, 2);
        for _ in 0..5 {
            o.apply_log(&[CacheLog::Use(CacheEvent::Miss(id(0, 1)))], 0.0);
        }
        assert_eq!(o.streams()[0].len(), 2);
        assert_eq!(o.stream_dropped(), 3);
    }

    #[test]
    fn lru_replay_hand_scenario() {
        // k=2 over experts 1,2,3: classic LRU churn
        let stream = vec![
            use_ev(1, false), // miss, cache [1]
            use_ev(2, false), // miss, [2,1]
            use_ev(1, false), // hit,  [1,2]
            use_ev(3, false), // miss, evicts 2 -> [3,1]
            use_ev(2, false), // miss, evicts 1 -> [2,3]
            use_ev(3, false), // hit
        ];
        assert_eq!(simulate_lru(&stream, 2), (2, 4));
        assert_eq!(simulate_lru(&stream, 3), (3, 3));
        // spec uses hit at any size, even k=0
        let spec_stream = vec![use_ev(1, true), use_ev(1, false)];
        assert_eq!(simulate_lru(&spec_stream, 0), (1, 1));
        assert_eq!(simulate_lru(&spec_stream, 1), (2, 0));
        // a drop forces the next demand use to miss
        let drop_stream = vec![
            use_ev(1, false),
            StreamEvent::Drop { expert: 1 },
            use_ev(1, false),
        ];
        assert_eq!(simulate_lru(&drop_stream, 4), (0, 2));
    }

    #[test]
    fn reset_replays_only_the_final_window() {
        let stream = vec![
            use_ev(1, false),
            use_ev(1, false),
            StreamEvent::Reset,
            use_ev(2, false),
            use_ev(2, false),
        ];
        assert_eq!(simulate_lru(&stream, 2), (1, 1));
        assert_eq!(simulate_opt(&stream, 2), (1, 1));
    }

    #[test]
    fn opt_beats_lru_on_a_scan() {
        // cyclic scan over 3 experts at k=2: LRU gets zero hits, Belady
        // keeps one pinned
        let mut stream = Vec::new();
        for _ in 0..6 {
            for e in 1..=3u16 {
                stream.push(use_ev(e, false));
            }
        }
        let (lh, _) = simulate_lru(&stream, 2);
        let (oh, _) = simulate_opt(&stream, 2);
        assert_eq!(lh, 0, "cyclic scan defeats LRU");
        assert!(oh > lh, "clairvoyance must win on a scan: {oh} vs {lh}");
    }

    fn dummy() -> DeviceExpert {
        DeviceExpert::Fp {
            w1: Tensor::zeros(vec![1, 1]),
            w3: Tensor::zeros(vec![1, 1]),
            w2: Tensor::zeros(vec![1, 1]),
        }
    }

    #[test]
    fn anchor_matches_real_manager_on_random_workloads() {
        // drive a REAL CacheManager (spec inserts, promotions, forced
        // drops, tight device budgets) with the obs log on, replay the
        // recorded stream at the manager's own cache_k, and require the
        // per-layer counters to match exactly — the tentpole invariant.
        for seed in 0..20u64 {
            let mut rng = Rng::new(seed);
            let n_layers = 2;
            let n_experts = 6;
            let cache_k = 1 + (seed as usize % 3);
            let device = DeviceMemory::new(100_000, 0, 100);
            let mut m = CacheManager::new(n_layers, cache_k, 3, device);
            m.set_obs_log(true);
            let mut obs = ExpertObs::enabled(n_layers, n_experts, 1 << 12);
            for step in 0..400 {
                let l = rng.below(n_layers);
                let e = rng.below(n_experts);
                let r = rng.f64();
                if r < 0.6 {
                    if let CacheEvent::Miss(x) = m.on_demand_use(id(l, e)) {
                        m.insert_loaded(x, dummy()).unwrap();
                    }
                } else if r < 0.9 {
                    m.insert_speculative(id(l, e), dummy()).unwrap();
                } else {
                    m.drop_expert(id(l, e));
                }
                obs.apply_log(&m.take_obs_log(), step as f64);
            }
            assert_eq!(obs.stream_dropped(), 0);
            for li in 0..n_layers {
                let (h, miss) = simulate_lru(&obs.streams()[li], cache_k);
                let (mh, mu) = m.stats.per_layer[li];
                assert_eq!(h, mh, "seed {seed} layer {li}: sim hits != measured");
                assert_eq!(h + miss, mu, "seed {seed} layer {li}: sim uses != measured");
            }
        }
    }

    #[test]
    fn curves_are_monotone_and_opt_dominates() {
        // random streams with speculation, drops and resets: every curve
        // must be monotone non-decreasing in k and OPT >= LRU pointwise
        for seed in 0..30u64 {
            let mut rng = Rng::new(1000 + seed);
            let n_experts = 8;
            let mut streams = vec![Vec::new(), Vec::new()];
            for s in streams.iter_mut() {
                for _ in 0..300 {
                    let e = rng.below(n_experts) as u16;
                    let r = rng.f64();
                    if r < 0.75 {
                        s.push(use_ev(e, false));
                    } else if r < 0.92 {
                        s.push(use_ev(e, true));
                    } else if r < 0.99 {
                        s.push(StreamEvent::Drop { expert: e });
                    } else {
                        s.push(StreamEvent::Reset);
                    }
                }
            }
            let (lru, opt) = cache_curves(&streams, n_experts);
            assert_eq!(lru.len(), n_experts);
            assert_eq!(opt.len(), n_experts);
            for i in 0..n_experts {
                assert!(
                    opt[i].hits >= lru[i].hits,
                    "seed {seed} k={}: OPT {} < LRU {}",
                    i + 1,
                    opt[i].hits,
                    lru[i].hits
                );
                assert_eq!(
                    opt[i].hits + opt[i].misses,
                    lru[i].hits + lru[i].misses,
                    "curves must describe the same access total"
                );
                if i > 0 {
                    assert!(
                        lru[i].hits >= lru[i - 1].hits,
                        "seed {seed}: LRU curve must be monotone in k"
                    );
                    assert!(
                        opt[i].hits >= opt[i - 1].hits,
                        "seed {seed}: OPT curve must be monotone in k"
                    );
                }
            }
        }
    }

    #[test]
    fn raw_opt_dominates_lru_without_clamping() {
        // the farthest-future-with-bypass replay should beat or match
        // LRU on its own on demand-only streams (the clamp in
        // cache_curves is belt and braces, not load-bearing)
        for seed in 0..30u64 {
            let mut rng = Rng::new(2000 + seed);
            let mut stream = Vec::new();
            for _ in 0..400 {
                stream.push(use_ev(rng.below(8) as u16, false));
            }
            for k in 1..=8 {
                let (lh, _) = simulate_lru(&stream, k);
                let (oh, _) = simulate_opt(&stream, k);
                assert!(oh >= lh, "seed {seed} k={k}: raw OPT {oh} < LRU {lh}");
            }
        }
    }

    #[test]
    fn counter_samples_are_bounded_and_exported() {
        let mut o = ExpertObs::enabled(1, 2, 64);
        for i in 0..(SAMPLE_CAP + 10) {
            o.sample(i as f64, 1, 3, 1);
        }
        assert_eq!(o.samples().count(), SAMPLE_CAP);
        let events = o.chrome_counter_events();
        assert_eq!(events.len(), SAMPLE_CAP * 2);
        let first = &events[0];
        assert_eq!(first.get("ph").and_then(Json::as_str), Some("C"));
        assert_eq!(first.get("pid").unwrap().as_i64(), Some(2));
        // hit rate 3/4 = 7500 bp
        let rate = events
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("expert_hit_rate_bp"))
            .unwrap();
        assert_eq!(
            rate.get("args").unwrap().get("bp").unwrap().as_i64(),
            Some(7500)
        );
    }

    #[test]
    fn report_carries_cells_curves_and_anchor() {
        let mut o = ExpertObs::enabled(1, 4, 64);
        let device = DeviceMemory::new(100_000, 0, 100);
        let mut m = CacheManager::new(1, 2, 3, device);
        m.set_obs_log(true);
        for &(l, e) in &[(0, 1), (0, 2), (0, 1), (0, 3), (0, 2)] {
            if let CacheEvent::Miss(x) = m.on_demand_use(id(l, e)) {
                m.insert_loaded(x, dummy()).unwrap();
            }
            o.apply_log(&m.take_obs_log(), 1.0);
        }
        let r = o.report(&m.stats, m.cache_k(), 2.0, (5, 3, 2));
        assert_eq!(r.get("type").and_then(Json::as_str), Some("experts"));
        assert_eq!(r.get("enabled").and_then(Json::as_bool), Some(true));
        assert_eq!(r.get("cache_k").unwrap().as_usize(), Some(2));
        assert!(!r.get("experts").unwrap().as_arr().unwrap().is_empty());
        let measured = r.get("curves").unwrap().get("measured").unwrap();
        assert_eq!(measured.get("anchored").and_then(Json::as_bool), Some(true));
        assert_eq!(
            measured.get("hits").unwrap().as_f64(),
            Some(m.stats.hits as f64)
        );
        let lru = r.get("curves").unwrap().get("lru").unwrap().as_arr().unwrap();
        assert_eq!(lru.len(), 4);
        let copy = r.get("copy_engine").unwrap();
        assert_eq!(copy.get("demand_jobs").unwrap().as_f64(), Some(3.0));
        // and the whole thing serializes to valid JSON
        let text = r.to_string();
        assert!(Json::parse(&text).is_ok());
    }

    #[test]
    fn cache_reset_flushes_residency_and_splits_streams() {
        let mut o = ExpertObs::enabled(1, 4, 64);
        o.apply_log(
            &[
                CacheLog::Use(CacheEvent::Miss(id(0, 1))),
                CacheLog::Insert(id(0, 1)),
                CacheLog::SpecInsert(id(0, 2)),
            ],
            1.0,
        );
        o.on_cache_reset(3.0);
        assert!((o.cell(0, 1).resident_s - 2.0).abs() < 1e-12);
        assert_eq!(o.cell(0, 2).prefetch_wasted, 1, "unclaimed prefetch wasted at reset");
        assert_eq!(*o.streams()[0].last().unwrap(), StreamEvent::Reset);
        // post-reset replay starts clean
        o.apply_log(&[CacheLog::Use(CacheEvent::Miss(id(0, 1)))], 4.0);
        assert_eq!(simulate_lru(&o.streams()[0], 2), (0, 1));
    }
}
