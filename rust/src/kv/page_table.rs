//! Per-session page table: sequence positions → physical KV blocks.
//!
//! A sequence owns its KV positions in order, so the table is a dense
//! `Vec<BlockId>` indexed by `position / block_tokens` — logical block `i`
//! covers positions `[i * block_tokens, (i + 1) * block_tokens)` of the
//! stream, across every layer (layers advance in lockstep, so one table
//! serves all of them; the physical block's byte size accounts for all
//! layers' K and V at those positions).

use crate::kv::allocator::BlockId;

/// Dense position → block mapping for one generation stream.
#[derive(Debug)]
pub struct PageTable {
    block_tokens: usize,
    blocks: Vec<BlockId>,
}

impl PageTable {
    pub fn new(block_tokens: usize) -> Self {
        assert!(block_tokens >= 1, "block_tokens must be >= 1");
        PageTable { block_tokens, blocks: Vec::new() }
    }

    /// Blocks needed to back `tokens` sequence positions (ceiling).
    pub fn blocks_for(block_tokens: usize, tokens: usize) -> usize {
        tokens.div_ceil(block_tokens.max(1))
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn mapped_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Positions currently backed by blocks.
    pub fn mapped_tokens(&self) -> usize {
        self.blocks.len() * self.block_tokens
    }

    /// The physical block holding `pos`, if mapped.
    pub fn block_of(&self, pos: usize) -> Option<BlockId> {
        self.blocks.get(pos / self.block_tokens).copied()
    }

    /// Append freshly allocated blocks (they extend the mapped range).
    pub fn push_blocks(&mut self, ids: impl IntoIterator<Item = BlockId>) {
        self.blocks.extend(ids);
    }

    /// Unmap everything, handing the block ids back to the caller (which
    /// returns them to the allocator).
    pub fn take_blocks(&mut self) -> Vec<BlockId> {
        std::mem::take(&mut self.blocks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blocks_for_is_a_ceiling() {
        assert_eq!(PageTable::blocks_for(16, 0), 0);
        assert_eq!(PageTable::blocks_for(16, 1), 1);
        assert_eq!(PageTable::blocks_for(16, 16), 1);
        assert_eq!(PageTable::blocks_for(16, 17), 2);
        assert_eq!(PageTable::blocks_for(1, 7), 7);
    }

    #[test]
    fn positions_map_to_their_block() {
        let mut t = PageTable::new(4);
        assert!(t.block_of(0).is_none());
        t.push_blocks([BlockId(9), BlockId(2)]);
        assert_eq!(t.mapped_blocks(), 2);
        assert_eq!(t.mapped_tokens(), 8);
        assert_eq!(t.block_of(0), Some(BlockId(9)));
        assert_eq!(t.block_of(3), Some(BlockId(9)));
        assert_eq!(t.block_of(4), Some(BlockId(2)));
        assert_eq!(t.block_of(7), Some(BlockId(2)));
        assert!(t.block_of(8).is_none());
    }

    #[test]
    fn take_blocks_unmaps() {
        let mut t = PageTable::new(4);
        t.push_blocks([BlockId(0), BlockId(1)]);
        let ids = t.take_blocks();
        assert_eq!(ids, vec![BlockId(0), BlockId(1)]);
        assert_eq!(t.mapped_blocks(), 0);
        assert!(t.block_of(0).is_none());
    }
}
