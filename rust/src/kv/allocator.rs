//! Fixed-size KV block allocator.
//!
//! The pool is a set of uniform blocks carved out of the device KV budget
//! (see [`crate::kv::KvPool`] for how the byte budget becomes a block
//! count). Allocation is a free-list pop, release is a push — O(1) both
//! ways, no external fragmentation by construction (every block is the
//! same size, like a page frame allocator). The allocator tracks an
//! in-use bitmap so double-allocation and double-free — the classic paging
//! bugs — are hard failures instead of silent accounting drift.
//!
//! Blocks are REFCOUNTED so the prefix cache (see [`crate::prefix`]) can
//! share them at the accounting level: `alloc` hands a block out with one
//! reference, [`BlockAllocator::retain`] adds holders (e.g. a session
//! seeded from a cached prefix plus the radix-tree node that owns it),
//! and [`BlockAllocator::free`] drops one reference — the block returns
//! to the free list exactly when the LAST holder releases it. Unshared
//! blocks (refcount 1 for their whole life) behave exactly as before.

/// Index of one physical KV block inside the pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BlockId(pub u32);

/// Free-list allocator over a fixed pool of uniform KV blocks.
#[derive(Debug)]
pub struct BlockAllocator {
    /// Free block ids, popped/pushed LIFO (hot blocks get reused first,
    /// which is friendlier to a real allocator's residency too).
    free: Vec<u32>,
    /// Double-alloc / double-free guard.
    in_use: Vec<bool>,
    /// Holders per block; 0 for free blocks, bumped by [`Self::retain`].
    refs: Vec<u32>,
    /// Blocks with more than one holder — maintained incrementally so
    /// [`Self::shared_blocks`] is O(1) (it feeds per-tick gauges and the
    /// scheduler's admission gate).
    shared: usize,
    total: usize,
    /// High-water mark of simultaneously allocated blocks.
    pub peak_in_use: usize,
    pub total_allocs: u64,
    pub total_frees: u64,
}

impl BlockAllocator {
    pub fn new(total: usize) -> Self {
        BlockAllocator {
            // reversed so the first alloc hands out block 0
            free: (0..total as u32).rev().collect(),
            in_use: vec![false; total],
            refs: vec![0; total],
            shared: 0,
            total,
            peak_in_use: 0,
            total_allocs: 0,
            total_frees: 0,
        }
    }

    pub fn total_blocks(&self) -> usize {
        self.total
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn in_use_blocks(&self) -> usize {
        self.total - self.free.len()
    }

    /// Blocks currently held by more than one owner (prefix sharing).
    pub fn shared_blocks(&self) -> usize {
        self.shared
    }

    /// Current holder count of a block; 0 when it sits on the free list.
    pub fn refcount(&self, id: BlockId) -> u32 {
        let i = id.0 as usize;
        assert!(i < self.total, "block {i} outside pool of {}", self.total);
        self.refs[i]
    }

    /// Allocate one block (refcount 1), or None when the pool is dry.
    pub fn alloc(&mut self) -> Option<BlockId> {
        let id = self.free.pop()?;
        debug_assert!(!self.in_use[id as usize], "free list handed out a live block");
        self.in_use[id as usize] = true;
        self.refs[id as usize] = 1;
        self.total_allocs += 1;
        self.peak_in_use = self.peak_in_use.max(self.in_use_blocks());
        Some(BlockId(id))
    }

    /// Allocate `n` blocks all-or-nothing: either every block is granted
    /// or the pool is left untouched (so a refused admission never leaks).
    pub fn alloc_n(&mut self, n: usize) -> Option<Vec<BlockId>> {
        if self.free.len() < n {
            return None;
        }
        Some((0..n).map(|_| self.alloc().expect("checked free count")).collect())
    }

    /// Add one holder to a live block (accounting-level sharing: the
    /// prefix cache's tree node and a seeded session both hold the same
    /// block). Panics on a free block — retaining nothing is a bug.
    pub fn retain(&mut self, id: BlockId) {
        let i = id.0 as usize;
        assert!(i < self.total, "block {i} outside pool of {}", self.total);
        assert!(self.in_use[i], "retain of free KV block {i}");
        self.refs[i] += 1;
        if self.refs[i] == 2 {
            self.shared += 1;
        }
    }

    /// Drop one holder; the block returns to the pool when the LAST
    /// holder releases it (returns true in that case). Panics on
    /// double-free or an id from another pool — both are
    /// allocator-invariant violations, not recoverable runtime conditions.
    pub fn free(&mut self, id: BlockId) -> bool {
        let i = id.0 as usize;
        assert!(i < self.total, "block {i} outside pool of {}", self.total);
        assert!(self.in_use[i], "double free of KV block {i}");
        self.refs[i] -= 1;
        if self.refs[i] == 1 {
            self.shared -= 1;
        }
        if self.refs[i] > 0 {
            return false;
        }
        self.in_use[i] = false;
        self.free.push(id.0);
        self.total_frees += 1;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn alloc_until_dry_then_reuse() {
        let mut a = BlockAllocator::new(3);
        let ids: Vec<_> = (0..3).map(|_| a.alloc().unwrap()).collect();
        assert_eq!(a.free_blocks(), 0);
        assert!(a.alloc().is_none());
        a.free(ids[1]);
        assert_eq!(a.free_blocks(), 1);
        let again = a.alloc().unwrap();
        assert_eq!(again, ids[1], "LIFO reuse of the freed block");
        assert_eq!(a.peak_in_use, 3);
    }

    #[test]
    fn alloc_n_is_all_or_nothing() {
        let mut a = BlockAllocator::new(4);
        let _held = a.alloc_n(3).unwrap();
        assert!(a.alloc_n(2).is_none(), "partial grant must not happen");
        assert_eq!(a.free_blocks(), 1, "refused request leaves the pool untouched");
        assert!(a.alloc_n(1).is_some());
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_is_detected() {
        let mut a = BlockAllocator::new(2);
        let id = a.alloc().unwrap();
        a.free(id);
        a.free(id);
    }

    #[test]
    fn refcounts_free_exactly_on_last_release() {
        let mut a = BlockAllocator::new(2);
        let id = a.alloc().unwrap();
        assert_eq!(a.refcount(id), 1);
        assert_eq!(a.shared_blocks(), 0);
        a.retain(id);
        a.retain(id);
        assert_eq!(a.refcount(id), 3);
        assert_eq!(a.shared_blocks(), 1);
        assert!(!a.free(id), "two holders remain");
        assert!(!a.free(id), "one holder remains");
        assert_eq!(a.in_use_blocks(), 1, "shared block stays allocated");
        assert!(a.free(id), "last holder frees the block");
        assert_eq!(a.refcount(id), 0);
        assert_eq!(a.free_blocks(), 2);
        // the freed id is allocatable again with a fresh refcount
        let again = a.alloc().unwrap();
        assert_eq!(a.refcount(again), 1);
    }

    #[test]
    #[should_panic(expected = "retain of free")]
    fn retain_of_free_block_is_detected() {
        let mut a = BlockAllocator::new(1);
        let id = a.alloc().unwrap();
        a.free(id);
        a.retain(id);
    }

    /// Fragmentation stress: random alloc/free interleavings over a small
    /// pool must preserve the accounting invariant (free + in-use = total)
    /// and never hand the same block to two owners.
    #[test]
    fn random_alloc_free_stress_keeps_invariants() {
        let mut rng = Rng::new(0x6b76); // "kv"
        let mut a = BlockAllocator::new(17);
        let mut held: Vec<BlockId> = Vec::new();
        for step in 0..20_000 {
            if rng.f64() < 0.55 {
                if let Some(id) = a.alloc() {
                    assert!(
                        !held.contains(&id),
                        "step {step}: block {id:?} handed out twice"
                    );
                    held.push(id);
                }
            } else if !held.is_empty() {
                let i = rng.below(held.len());
                a.free(held.swap_remove(i));
            }
            assert_eq!(a.free_blocks() + a.in_use_blocks(), a.total_blocks());
            assert_eq!(a.in_use_blocks(), held.len());
        }
        // drain and verify the pool recovers completely
        for id in held.drain(..) {
            a.free(id);
        }
        assert_eq!(a.free_blocks(), 17);
        assert!(a.total_allocs == a.total_frees);
        assert!(a.peak_in_use <= 17);
    }
}
