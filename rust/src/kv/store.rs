//! The shared KV block pool and the per-session paged KV store.
//!
//! [`KvPool`] is the engine-wide side: the block allocator carved out of
//! the device KV budget, plus pool telemetry (occupancy, preemptions).
//! It is shared behind an `Arc` by the engine and every live session, so
//! a dropping session can return its blocks without engine access (the
//! same pattern the live-session counter uses).
//!
//! [`PagedKv`] is the per-session side: the page table plus the per-layer
//! KV *images*. Physically each layer's KV lives in one PJRT literal of
//! the full `[max_seq, n_kv_heads, head_dim]` shape — the AOT-compiled
//! attention modules are fixed-shape, so the literal acts as the
//! sequence's reserved address space while the page table records which
//! token ranges of it are actually *committed* against device memory.
//! Blocks are committed on demand as decode advances and released on
//! reset/drop; preemption swaps the images to host f32 buffers and
//! returns every block to the pool, and resumption is the exact inverse,
//! so a preempted stream continues bit-identically.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use xla::Literal;

use crate::error::{Error, Result};
use crate::kv::allocator::BlockAllocator;
use crate::kv::page_table::PageTable;
use crate::runtime::Runtime;
use crate::tensor::Tensor;

/// Point-in-time pool occupancy + lifetime counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvPoolStats {
    pub total_blocks: usize,
    pub free_blocks: usize,
    pub in_use_blocks: usize,
    pub peak_in_use_blocks: usize,
    /// Blocks with more than one holder (prefix-cache sharing).
    pub shared_blocks: usize,
    /// Sessions swapped out to host since engine start.
    pub preemptions: u64,
}

/// Engine-wide KV block pool: allocator + geometry + telemetry.
pub struct KvPool {
    alloc: Mutex<BlockAllocator>,
    /// Sequence positions covered by one block (across all layers).
    block_tokens: usize,
    /// Device bytes one block accounts for (all layers, K and V), at the
    /// engine's accounting scale.
    block_bytes: u64,
    /// Per-layer KV literal shape: `[max_seq, n_kv_heads, head_dim]`.
    kv_shape: Vec<usize>,
    preemptions: AtomicU64,
}

impl KvPool {
    pub fn new(total_blocks: usize, block_tokens: usize, block_bytes: u64, kv_shape: Vec<usize>) -> Self {
        assert!(block_tokens >= 1);
        assert_eq!(kv_shape.len(), 3, "kv shape is [max_seq, n_kv_heads, head_dim]");
        KvPool {
            alloc: Mutex::new(BlockAllocator::new(total_blocks)),
            block_tokens,
            block_bytes,
            kv_shape,
            preemptions: AtomicU64::new(0),
        }
    }

    /// Carve a pool out of a device byte budget: as many whole blocks as
    /// fit (the engine's construction path).
    pub fn carve(pool_bytes: u64, block_tokens: usize, block_bytes: u64, kv_shape: Vec<usize>) -> Self {
        let total = if block_bytes == 0 { 0 } else { (pool_bytes / block_bytes) as usize };
        Self::new(total, block_tokens, block_bytes, kv_shape)
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn block_bytes(&self) -> u64 {
        self.block_bytes
    }

    /// Total device bytes the pool carve-out accounts for.
    pub fn total_bytes(&self) -> u64 {
        self.stats().total_blocks as u64 * self.block_bytes
    }

    /// Pool capacity in sequence positions.
    pub fn capacity_tokens(&self) -> usize {
        self.stats().total_blocks * self.block_tokens
    }

    pub fn blocks_for(&self, tokens: usize) -> usize {
        PageTable::blocks_for(self.block_tokens, tokens)
    }

    /// Would `tokens` positions fit in the *currently free* blocks?
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.blocks_for(tokens) <= self.alloc.lock().unwrap().free_blocks()
    }

    /// Would `tokens` positions fit in the pool even if it were empty?
    /// (False means the request can never be served — fail it instead of
    /// requeueing forever.)
    pub fn fits(&self, tokens: usize) -> bool {
        self.blocks_for(tokens) <= self.alloc.lock().unwrap().total_blocks()
    }

    pub fn stats(&self) -> KvPoolStats {
        let a = self.alloc.lock().unwrap();
        KvPoolStats {
            total_blocks: a.total_blocks(),
            free_blocks: a.free_blocks(),
            in_use_blocks: a.in_use_blocks(),
            peak_in_use_blocks: a.peak_in_use,
            shared_blocks: a.shared_blocks(),
            preemptions: self.preemptions.load(Ordering::Relaxed),
        }
    }

    /// Current holder count of a block (0 = on the free list).
    pub fn refcount(&self, id: crate::kv::BlockId) -> u32 {
        self.alloc.lock().unwrap().refcount(id)
    }

    /// Allocate a single block (refcount 1), or None when the pool is
    /// dry — the prefix cache's soft allocation path (it evicts or gives
    /// up instead of erroring).
    pub(crate) fn alloc_one(&self) -> Option<crate::kv::BlockId> {
        self.alloc.lock().unwrap().alloc()
    }

    /// Add one holder to every block in `ids` (accounting-level prefix
    /// sharing: tree node + seeded session hold the same block).
    pub(crate) fn retain_all(&self, ids: &[crate::kv::BlockId]) {
        let mut a = self.alloc.lock().unwrap();
        for &id in ids {
            a.retain(id);
        }
    }

    /// Drop one holder of `id`; true when the block actually returned to
    /// the free list (last holder released).
    pub(crate) fn release_one(&self, id: crate::kv::BlockId) -> bool {
        self.alloc.lock().unwrap().free(id)
    }

    pub fn note_preemption(&self) {
        self.preemptions.fetch_add(1, Ordering::Relaxed);
    }

    fn alloc_n(&self, n: usize) -> Result<Vec<crate::kv::BlockId>> {
        let mut a = self.alloc.lock().unwrap();
        a.alloc_n(n).ok_or_else(|| {
            Error::KvPoolExhausted(format!(
                "need {n} KV block(s), {} of {} free",
                a.free_blocks(),
                a.total_blocks()
            ))
        })
    }

    fn free_all(&self, ids: Vec<crate::kv::BlockId>) {
        let mut a = self.alloc.lock().unwrap();
        for id in ids {
            a.free(id);
        }
    }
}

/// One layer's KV swapped to host: (K bytes, V bytes) as f32 rows.
type HostKvLayer = (Vec<f32>, Vec<f32>);

/// Where a session's KV images currently live.
enum Residency {
    /// On-device PJRT literals, one (K, V) pair per layer. `None` means
    /// the layer is still virgin — attention reads the engine's shared
    /// zero template instead, so sessions start (and reset) without
    /// marshalling a single literal.
    Device(Vec<Option<(Literal, Literal)>>),
    /// Swapped out to host f32 buffers (preempted).
    Host(Vec<Option<HostKvLayer>>),
}

/// One session's paged KV: per-layer images + page table + pool handle.
pub struct PagedKv {
    state: Residency,
    table: PageTable,
    pool: Arc<KvPool>,
}

impl PagedKv {
    /// Fresh paged KV: no blocks mapped, every layer virgin. O(1) — no
    /// device allocation happens until the first token needs a block.
    pub fn new(n_layers: usize, pool: Arc<KvPool>) -> Self {
        PagedKv {
            state: Residency::Device((0..n_layers).map(|_| None).collect()),
            table: PageTable::new(pool.block_tokens()),
            pool,
        }
    }

    pub fn is_swapped(&self) -> bool {
        matches!(self.state, Residency::Host(_))
    }

    pub fn mapped_blocks(&self) -> usize {
        self.table.mapped_blocks()
    }

    pub fn page_table(&self) -> &PageTable {
        &self.table
    }

    pub fn pool(&self) -> &Arc<KvPool> {
        &self.pool
    }

    /// Seed a VIRGIN session from cached prefix KV: install per-layer
    /// full-shape `[max_seq, n_kv_heads, head_dim]` host images (prefix
    /// positions filled, the rest zeros — the position mask hides them)
    /// and map the prefix's `blocks` into the page table. The blocks
    /// arrive with a holder reference already added by the prefix cache
    /// (accounting-level sharing: the radix-tree node keeps its own
    /// reference), so this store releases them like any other block on
    /// reset/preempt/drop. Returns the device bytes the seed committed.
    /// On any failure the handed-over references are released and the
    /// session is left untouched (still virgin).
    pub fn seed(
        &mut self,
        layers: Vec<(Vec<f32>, Vec<f32>)>,
        blocks: Vec<crate::kv::BlockId>,
    ) -> Result<u64> {
        let virgin = match &self.state {
            Residency::Device(ls) => ls.iter().all(|s| s.is_none()),
            Residency::Host(_) => false,
        };
        if !virgin || self.table.mapped_blocks() != 0 {
            self.pool.free_all(blocks);
            return Err(Error::Engine(
                "prefix seed requires a virgin session (no KV written, not swapped)".into(),
            ));
        }
        let n_layers = match &self.state {
            Residency::Device(ls) => ls.len(),
            Residency::Host(ls) => ls.len(),
        };
        if layers.len() != n_layers {
            self.pool.free_all(blocks);
            return Err(Error::Engine(format!(
                "prefix seed has {} layers, session has {n_layers}",
                layers.len()
            )));
        }
        let shape = self.pool.kv_shape.clone();
        let built: Result<Vec<Option<(Literal, Literal)>>> = layers
            .into_iter()
            .map(|(k, v)| {
                Ok(Some((
                    Runtime::lit_f32(&Tensor::new(k, shape.clone())?)?,
                    Runtime::lit_f32(&Tensor::new(v, shape.clone())?)?,
                )))
            })
            .collect();
        match built {
            Ok(ls) => {
                let bytes = blocks.len() as u64 * self.pool.block_bytes();
                self.table.push_blocks(blocks);
                self.state = Residency::Device(ls);
                Ok(bytes)
            }
            Err(e) => {
                self.pool.free_all(blocks);
                Err(e)
            }
        }
    }

    /// Commit enough blocks to back `tokens` sequence positions,
    /// allocating on demand (all-or-nothing). Errors with
    /// [`Error::KvPoolExhausted`] when the pool is dry — the caller
    /// (scheduler) turns that into preemption — and with a plain engine
    /// error when the session is swapped out.
    pub fn ensure_tokens(&mut self, tokens: usize) -> Result<()> {
        if self.is_swapped() {
            return Err(Error::Engine(
                "session KV is swapped out to host — resume it before decoding".into(),
            ));
        }
        let needed = self.pool.blocks_for(tokens);
        let have = self.table.mapped_blocks();
        if needed > have {
            let fresh = self.pool.alloc_n(needed - have)?;
            self.table.push_blocks(fresh);
        }
        Ok(())
    }

    /// The layer's KV image, or `default` (the engine's shared zero
    /// template) while the layer is virgin — the single read path both
    /// decode and prefill attention go through.
    pub fn layer_or<'a>(
        &'a self,
        l: usize,
        default: &'a (Literal, Literal),
    ) -> Result<(&'a Literal, &'a Literal)> {
        Ok(match self.layer(l)? {
            Some((k, v)) => (k, v),
            None => (&default.0, &default.1),
        })
    }

    /// The layer's on-device KV image, `None` while the layer is virgin.
    /// Errors when the session is swapped out (decode must not read a
    /// preempted stream).
    pub fn layer(&self, l: usize) -> Result<Option<&(Literal, Literal)>> {
        match &self.state {
            Residency::Device(layers) => Ok(layers[l].as_ref()),
            Residency::Host(_) => Err(Error::Engine(
                "session KV is swapped out to host — resume it before decoding".into(),
            )),
        }
    }

    /// Install the layer's updated KV image (attention is functional: it
    /// returns fresh literals each call).
    pub fn set_layer(&mut self, l: usize, k: Literal, v: Literal) -> Result<()> {
        match &mut self.state {
            Residency::Device(layers) => {
                layers[l] = Some((k, v));
                Ok(())
            }
            Residency::Host(_) => Err(Error::Engine(
                "cannot write KV into a swapped-out session".into(),
            )),
        }
    }

    /// Rewind in place: return every block to the pool and drop the layer
    /// images back to virgin (the next attention call reads the shared
    /// zero template). No literal is re-marshalled — this replaces the
    /// old per-layer `rt.zero_kv()` reallocation.
    pub fn release(&mut self) {
        let n_layers = match &self.state {
            Residency::Device(l) => l.len(),
            Residency::Host(l) => l.len(),
        };
        self.pool.free_all(self.table.take_blocks());
        self.state = Residency::Device((0..n_layers).map(|_| None).collect());
    }

    /// Preemption: copy every layer's KV image to host memory and return
    /// all blocks to the pool. Returns the device bytes released (mapped
    /// blocks × block size — the modeled D2H transfer the engine charges
    /// to the timeline).
    pub fn swap_out(&mut self) -> Result<u64> {
        let layers = match &self.state {
            Residency::Device(layers) => layers,
            Residency::Host(_) => {
                return Err(Error::Engine("session KV already swapped out".into()))
            }
        };
        let mut host = Vec::with_capacity(layers.len());
        for slot in layers {
            host.push(match slot {
                Some((k, v)) => Some((k.to_vec::<f32>()?, v.to_vec::<f32>()?)),
                None => None,
            });
        }
        let bytes = self.table.mapped_blocks() as u64 * self.pool.block_bytes();
        self.pool.free_all(self.table.take_blocks());
        self.state = Residency::Host(host);
        Ok(bytes)
    }

    /// Resumption: re-acquire blocks for `tokens` written positions and
    /// rebuild the device literals from the host copies, bit-exactly.
    /// Errors with [`Error::KvPoolExhausted`] when the pool cannot back
    /// the stream yet. Returns the device bytes re-committed.
    pub fn swap_in(&mut self, tokens: usize) -> Result<u64> {
        let host = match &self.state {
            Residency::Host(host) => host,
            Residency::Device(_) => {
                return Err(Error::Engine("session KV is not swapped out".into()))
            }
        };
        let fresh = self.pool.alloc_n(self.pool.blocks_for(tokens))?;
        let shape = self.pool.kv_shape.clone();
        // rebuild WITHOUT consuming the host copies, so a marshalling
        // failure leaves the session intact (still swapped out, blocks
        // returned) instead of leaking pool capacity and silently
        // degrading already-taken layers to virgin on a retry
        let rebuilt: Result<Vec<Option<(Literal, Literal)>>> = host
            .iter()
            .map(|slot| {
                Ok(match slot {
                    Some((k, v)) => Some((
                        Runtime::lit_f32(&Tensor::new(k.clone(), shape.clone())?)?,
                        Runtime::lit_f32(&Tensor::new(v.clone(), shape.clone())?)?,
                    )),
                    None => None,
                })
            })
            .collect();
        let layers = match rebuilt {
            Ok(layers) => layers,
            Err(e) => {
                self.pool.free_all(fresh);
                return Err(e);
            }
        };
        let bytes = fresh.len() as u64 * self.pool.block_bytes();
        self.table.push_blocks(fresh);
        self.state = Residency::Device(layers);
        Ok(bytes)
    }
}

impl Drop for PagedKv {
    fn drop(&mut self) {
        self.pool.free_all(self.table.take_blocks());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(total_blocks: usize, block_tokens: usize) -> Arc<KvPool> {
        Arc::new(KvPool::new(total_blocks, block_tokens, 1024, vec![64, 2, 8]))
    }

    #[test]
    fn blocks_commit_on_demand_and_release_on_drop() {
        let p = pool(8, 4);
        let mut kv = PagedKv::new(3, Arc::clone(&p));
        assert_eq!(p.stats().in_use_blocks, 0);
        kv.ensure_tokens(1).unwrap();
        assert_eq!(kv.mapped_blocks(), 1);
        kv.ensure_tokens(4).unwrap(); // still inside block 0
        assert_eq!(kv.mapped_blocks(), 1);
        kv.ensure_tokens(5).unwrap(); // crosses into block 1
        assert_eq!(kv.mapped_blocks(), 2);
        assert_eq!(p.stats().in_use_blocks, 2);
        drop(kv);
        assert_eq!(p.stats().in_use_blocks, 0, "drop returns every block");
    }

    #[test]
    fn release_rewinds_without_leaking() {
        let p = pool(4, 2);
        let mut kv = PagedKv::new(2, Arc::clone(&p));
        kv.ensure_tokens(7).unwrap();
        assert_eq!(p.stats().in_use_blocks, 4);
        kv.release();
        assert_eq!(p.stats().in_use_blocks, 0);
        assert_eq!(kv.mapped_blocks(), 0);
        // and the stream can grow again
        kv.ensure_tokens(2).unwrap();
        assert_eq!(p.stats().in_use_blocks, 1);
    }

    #[test]
    fn exhaustion_is_typed_and_all_or_nothing() {
        let p = pool(2, 4);
        let mut a = PagedKv::new(1, Arc::clone(&p));
        let mut b = PagedKv::new(1, Arc::clone(&p));
        a.ensure_tokens(8).unwrap(); // both blocks
        let err = b.ensure_tokens(1).unwrap_err();
        assert!(matches!(err, Error::KvPoolExhausted(_)), "{err}");
        assert_eq!(b.mapped_blocks(), 0, "refused commit must not hold blocks");
        a.release();
        b.ensure_tokens(1).unwrap();
    }

    /// The acceptance-criterion accounting, independent of artifacts: a
    /// pool sized for `k` full-length static sessions admits strictly
    /// more concurrent short sessions under paging.
    #[test]
    fn paged_pool_admits_more_short_sessions_than_static_reservation() {
        let max_seq = 64;
        let block_tokens = 8;
        let static_sessions = 2;
        // same VRAM: exactly the bytes static reservation would pin
        let p = pool(static_sessions * max_seq / block_tokens, block_tokens);
        let prompt_tokens = 16;
        let mut admitted = Vec::new();
        loop {
            let mut kv = PagedKv::new(2, Arc::clone(&p));
            match kv.ensure_tokens(prompt_tokens) {
                Ok(()) => admitted.push(kv),
                Err(Error::KvPoolExhausted(_)) => break,
                Err(e) => panic!("unexpected error: {e}"),
            }
        }
        assert_eq!(admitted.len(), static_sessions * max_seq / prompt_tokens);
        assert!(
            admitted.len() > static_sessions,
            "paged admission ({}) must beat static reservation ({static_sessions})",
            admitted.len()
        );
    }

    #[test]
    fn swap_out_frees_blocks_and_swap_in_recommits() {
        let p = pool(8, 4);
        let mut kv = PagedKv::new(2, Arc::clone(&p));
        kv.ensure_tokens(10).unwrap(); // 3 blocks
        assert_eq!(p.stats().in_use_blocks, 3);

        let out_bytes = kv.swap_out().unwrap();
        assert_eq!(out_bytes, 3 * 1024);
        assert!(kv.is_swapped());
        assert_eq!(p.stats().in_use_blocks, 0, "preemption returns every block");
        assert!(kv.ensure_tokens(11).is_err(), "no decode while swapped out");
        assert!(kv.swap_out().is_err(), "double swap-out refused");

        let in_bytes = kv.swap_in(10).unwrap();
        assert_eq!(in_bytes, 3 * 1024);
        assert!(!kv.is_swapped());
        assert_eq!(kv.mapped_blocks(), 3);
        assert_eq!(p.stats().in_use_blocks, 3);
        assert_eq!(p.stats().preemptions, 0, "pool counter is the engine's to bump");
    }

    #[test]
    fn seed_installs_blocks_with_shared_accounting() {
        let p = pool(4, 4);
        // the "tree" owns one block; the seeded session adds a holder
        let b = p.alloc_one().unwrap();
        p.retain_all(&[b]);
        assert_eq!(p.refcount(b), 2);
        let mut kv = PagedKv::new(1, Arc::clone(&p));
        let rows = 64 * 2 * 8;
        let bytes = kv.seed(vec![(vec![0.0; rows], vec![0.0; rows])], vec![b]).unwrap();
        assert_eq!(bytes, 1024);
        assert_eq!(kv.mapped_blocks(), 1);
        assert_eq!(p.stats().in_use_blocks, 1);
        assert_eq!(p.stats().shared_blocks, 1);
        // a second seed is refused and releases the handed-over reference
        p.retain_all(&[b]);
        assert!(kv.seed(vec![(vec![0.0; rows], vec![0.0; rows])], vec![b]).is_err());
        assert_eq!(p.refcount(b), 2);
        // session release drops its holder; the tree's reference keeps
        // the block allocated until the tree lets go too
        kv.release();
        assert_eq!(p.refcount(b), 1);
        assert_eq!(p.stats().in_use_blocks, 1);
        assert!(p.release_one(b), "last holder frees the block");
        assert_eq!(p.stats().free_blocks, 4);
    }

    #[test]
    fn carve_floors_to_whole_blocks() {
        let shape = vec![64, 2, 8];
        assert_eq!(KvPool::carve(1000, 4, 300, shape.clone()).stats().total_blocks, 3);
        assert_eq!(KvPool::carve(0, 4, 300, shape.clone()).stats().total_blocks, 0);
        assert_eq!(KvPool::carve(1000, 4, 0, shape).stats().total_blocks, 0);
    }

    #[test]
    fn pool_admission_helpers() {
        let p = pool(4, 8); // 32 token capacity
        assert!(p.can_admit(32));
        assert!(!p.can_admit(33));
        assert!(p.fits(32));
        assert!(!p.fits(33));
        assert_eq!(p.capacity_tokens(), 32);
        assert_eq!(p.total_bytes(), 4 * 1024);
        let mut kv = PagedKv::new(1, Arc::clone(&p));
        kv.ensure_tokens(9).unwrap(); // 2 blocks
        assert!(p.can_admit(16));
        assert!(!p.can_admit(17), "free blocks, not total, gate admission");
        assert!(p.fits(32), "fits() ignores current occupancy");
    }
}
