//! Paged KV-cache subsystem: block allocator, per-session page tables,
//! and the paged KV store the attention path reads through.
//!
//! The paper's constraint is scarce accelerator memory; PR 1's scheduler
//! still reserved a full-sequence KV cache per configured session, so
//! VRAM — not compute — capped concurrency. This subsystem makes KV
//! memory elastic, vLLM-style:
//!
//! * [`BlockAllocator`] — the engine carves the KV byte budget out of
//!   [`crate::memory::DeviceMemory`] into uniform blocks of
//!   `kv_block_tokens` sequence positions (all layers, K and V). A free
//!   list hands them out in O(1) with no external fragmentation. Blocks
//!   are REFCOUNTED so the prefix cache ([`crate::prefix`]) can share
//!   them between its radix-tree nodes and seeded sessions: a block
//!   frees exactly when its last holder releases it.
//! * [`PageTable`] — each session maps its sequence positions densely
//!   onto physical blocks; one table serves every layer because layers
//!   advance in lockstep.
//! * [`KvPool`] — the shared side (allocator + geometry + telemetry),
//!   held by the engine and every session behind an `Arc` so dropped
//!   sessions return blocks without engine access.
//! * [`PagedKv`] — the per-session store [`crate::engine::Session`] owns
//!   in place of the old monolithic literal vector. Blocks are committed
//!   on demand as decode advances, released on reset/drop, and swapped
//!   to host (and back, bit-exactly) when the scheduler preempts a
//!   session to let older streams finish.
//!
//! Admission stops being "is a session slot free?" and becomes free-block
//! accounting: a pool sized for N full-length sequences admits strictly
//! more than N concurrent short streams, which is the whole point — see
//! `rust/tests/paged_kv.rs` and the `kv_admission` bench section in
//! `rust/benches/engine_decode.rs`.

pub mod allocator;
pub mod page_table;
pub mod store;

pub use allocator::{BlockAllocator, BlockId};
pub use page_table::PageTable;
pub use store::{KvPool, KvPoolStats, PagedKv};
