//! PJRT runtime: loads the AOT HLO-text artifacts and executes them.
//!
//! One compiled executable per module (embed / attn / gate / expert /
//! expert_q{2,3,4} / lm_head + prefill variants); weights are runtime
//! arguments, so a single executable serves every layer and expert. HLO
//! *text* is the interchange format — see `python/compile/aot.py` and
//! /opt/xla-example/README.md for why serialized protos don't work here.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use xla::{ElementType, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::config::{Manifest, ModelConfig};
use crate::error::{Error, Result};
use crate::memory::device::DeviceExpert;
use crate::model::weights::LayerWeights;
use crate::tensor::{Tensor, TensorU8};

/// Per-module call accounting (wall time is the *host* cost of the call;
/// simulated device timing lives in [`crate::clock::Timeline`]).
#[derive(Debug, Clone, Default)]
pub struct CallStats {
    pub calls: u64,
    pub wall_s: f64,
}

pub struct Runtime {
    exes: BTreeMap<String, PjRtLoadedExecutable>,
    pub cfg: ModelConfig,
    pub stats: BTreeMap<String, CallStats>,
}

/// Pre-converted literals for one layer's device-resident weights — built
/// once at engine start so the hot loop never re-marshals static weights
/// (§Perf optimization 2).
pub struct LayerLits {
    pub attn_ln: Literal,
    pub wq: Literal,
    pub wk: Literal,
    pub wv: Literal,
    pub wo: Literal,
    pub mlp_ln: Literal,
    pub w_gate: Literal,
}

impl LayerLits {
    pub fn new(lw: &LayerWeights) -> Result<Self> {
        Ok(LayerLits {
            attn_ln: Runtime::lit_f32(&lw.attn_ln)?,
            wq: Runtime::lit_f32(&lw.wq)?,
            wk: Runtime::lit_f32(&lw.wk)?,
            wv: Runtime::lit_f32(&lw.wv)?,
            wo: Runtime::lit_f32(&lw.wo)?,
            mlp_ln: Runtime::lit_f32(&lw.mlp_ln)?,
            w_gate: Runtime::lit_f32(&lw.w_gate)?,
        })
    }
}

/// An expert's arguments pre-marshalled as literals (built once when the
/// expert lands on the device; reused for every routed token while it
/// stays cached — §Perf opt 4).
pub struct ExpertLits {
    /// None => fp path; Some(bits) => fused-dequant path.
    pub bits: Option<u8>,
    pub args: Vec<Literal>,
}

impl ExpertLits {
    pub fn new(e: &DeviceExpert) -> Result<Self> {
        match e {
            DeviceExpert::Fp { w1, w3, w2 } => Ok(ExpertLits {
                bits: None,
                args: vec![
                    Runtime::lit_f32(w1)?,
                    Runtime::lit_f32(w3)?,
                    Runtime::lit_f32(w2)?,
                ],
            }),
            DeviceExpert::Quant { bits, q1, s1, z1, q3, s3, z3, q2, s2, z2 } => Ok(ExpertLits {
                bits: Some(*bits),
                args: vec![
                    Runtime::lit_u8(q1)?,
                    Runtime::lit_f32(s1)?,
                    Runtime::lit_f32(z1)?,
                    Runtime::lit_u8(q3)?,
                    Runtime::lit_f32(s3)?,
                    Runtime::lit_f32(z3)?,
                    Runtime::lit_u8(q2)?,
                    Runtime::lit_f32(s2)?,
                    Runtime::lit_f32(z2)?,
                ],
            }),
        }
    }
}

/// Pre-converted literals for the non-layer weights.
pub struct StaticLits {
    pub embed: Literal,
    pub final_ln: Literal,
    pub lm_head: Literal,
    pub layers: Vec<LayerLits>,
    /// Shared zero KV image `[max_seq, n_kv_heads, head_dim]`. Virgin
    /// layers of every session read this one template instead of each
    /// marshalling their own zeros: executables copy argument literals to
    /// device per call, and the position mask hides anything beyond `pos`,
    /// so sharing is bit-safe. This is what lets `Session::new`/`reset`
    /// skip the old per-layer `zero_kv()` reallocation entirely.
    pub zero_kv: (Literal, Literal),
}

impl StaticLits {
    pub fn new(w: &crate::model::ModelWeights) -> Result<Self> {
        let cfg = &w.cfg;
        let zeros = Tensor::zeros(vec![cfg.max_seq, cfg.n_kv_heads, cfg.head_dim]);
        Ok(StaticLits {
            embed: Runtime::lit_f32(&w.embed)?,
            final_ln: Runtime::lit_f32(&w.final_ln)?,
            lm_head: Runtime::lit_f32(&w.lm_head)?,
            layers: w.layers.iter().map(LayerLits::new).collect::<Result<_>>()?,
            zero_kv: (Runtime::lit_f32(&zeros)?, Runtime::lit_f32(&zeros)?),
        })
    }
}

impl Runtime {
    /// Load and compile every artifact listed in the manifest.
    pub fn load(manifest: &Manifest) -> Result<Self> {
        let client = PjRtClient::cpu()?;
        let mut exes = BTreeMap::new();
        for name in manifest.modules.keys() {
            let path = manifest.module_path(name)?;
            let exe = Self::compile_one(&client, &path)?;
            exes.insert(name.clone(), exe);
        }
        Ok(Runtime { exes, cfg: manifest.config.clone(), stats: BTreeMap::new() })
    }

    fn compile_one(client: &PjRtClient, path: &Path) -> Result<PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Artifact(format!("bad path {path:?}")))?,
        )?;
        let comp = XlaComputation::from_proto(&proto);
        Ok(client.compile(&comp)?)
    }

    pub fn has_module(&self, name: &str) -> bool {
        self.exes.contains_key(name)
    }

    /// Like [`call`] but takes borrowed literals (hot path: static weights
    /// are pre-converted once and reused).
    pub fn call_refs(&mut self, name: &str, args: &[&Literal]) -> Result<Vec<Literal>> {
        let t0 = Instant::now();
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| Error::Runtime(format!("no executable '{name}'")))?;
        let result = exe.execute::<&Literal>(args)?[0][0].to_literal_sync()?;
        let out = result.to_tuple()?;
        let entry = self.stats.entry(name.to_string()).or_default();
        entry.calls += 1;
        entry.wall_s += t0.elapsed().as_secs_f64();
        Ok(out)
    }

    /// Execute a module; unwraps the outer tuple the AOT pipeline always
    /// emits (`return_tuple=True`).
    pub fn call(&mut self, name: &str, args: &[Literal]) -> Result<Vec<Literal>> {
        let t0 = Instant::now();
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| Error::Runtime(format!("no executable '{name}'")))?;
        let result = exe.execute::<Literal>(args)?[0][0].to_literal_sync()?;
        let out = result.to_tuple()?;
        let entry = self.stats.entry(name.to_string()).or_default();
        entry.calls += 1;
        entry.wall_s += t0.elapsed().as_secs_f64();
        Ok(out)
    }

    // -- literal conversion helpers -----------------------------------------

    pub fn lit_f32(t: &Tensor) -> Result<Literal> {
        let bytes: Vec<u8> = t.data.iter().flat_map(|x| x.to_le_bytes()).collect();
        Ok(Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &t.shape,
            &bytes,
        )?)
    }

    pub fn lit_u8(t: &TensorU8) -> Result<Literal> {
        Ok(Literal::create_from_shape_and_untyped_data(
            ElementType::U8,
            &t.shape,
            &t.data,
        )?)
    }

    pub fn lit_i32_scalar(v: i32) -> Literal {
        Literal::scalar(v)
    }

    pub fn lit_i32_vec(v: &[i32]) -> Literal {
        Literal::vec1(v)
    }

    pub fn tensor_from(lit: &Literal, shape: Vec<usize>) -> Result<Tensor> {
        let data = lit.to_vec::<f32>()?;
        Tensor::new(data, shape)
    }

    // -- typed module wrappers ----------------------------------------------

    /// embed: token id -> x [1, D]
    pub fn embed(&mut self, token: u32, embed: &Literal) -> Result<Tensor> {
        let tok = Self::lit_i32_vec(&[token as i32]);
        let out = self.call_refs("embed", &[&tok, embed])?;
        Self::tensor_from(&out[0], vec![1, self.cfg.d_model])
    }

    /// attn (decode): returns (x', k_cache', v_cache') — caches stay as
    /// opaque Literals between calls (never round-tripped to host; §Perf
    /// optimization 3).
    pub fn attn(
        &mut self,
        x: &Tensor,
        lits: &LayerLits,
        k_cache: &Literal,
        v_cache: &Literal,
        pos: usize,
    ) -> Result<(Tensor, Literal, Literal)> {
        self.attn_inner("attn", x, lits, k_cache, v_cache, pos)
    }

    /// chunked prefill attention: x is [C, D].
    pub fn prefill_attn(
        &mut self,
        x: &Tensor,
        lits: &LayerLits,
        k_cache: &Literal,
        v_cache: &Literal,
        pos0: usize,
    ) -> Result<(Tensor, Literal, Literal)> {
        self.attn_inner("prefill_attn", x, lits, k_cache, v_cache, pos0)
    }

    fn attn_inner(
        &mut self,
        module: &str,
        x: &Tensor,
        lits: &LayerLits,
        k_cache: &Literal,
        v_cache: &Literal,
        pos: usize,
    ) -> Result<(Tensor, Literal, Literal)> {
        let t = x.shape[0];
        let x_lit = Self::lit_f32(x)?;
        let pos_lit = Self::lit_i32_scalar(pos as i32);
        let args: [&Literal; 9] = [
            &x_lit, &lits.attn_ln, &lits.wq, &lits.wk, &lits.wv, &lits.wo,
            k_cache, v_cache, &pos_lit,
        ];
        let mut out = self.call_refs(module, &args)?;
        let x_out = Self::tensor_from(&out[0], vec![t, self.cfg.d_model])?;
        let v_new = out.pop().expect("attn returns 3 outputs");
        let k_new = out.pop().expect("attn returns 3 outputs");
        Ok((x_out, k_new, v_new))
    }

    /// gate: returns (router logits [T, E], normed hidden h [T, D]).
    pub fn gate(&mut self, x: &Tensor, lits: &LayerLits) -> Result<(Tensor, Tensor)> {
        let module = if x.shape[0] == 1 { "gate" } else { "prefill_gate" };
        let t = x.shape[0];
        let x_lit = Self::lit_f32(x)?;
        let out = self.call_refs(module, &[&x_lit, &lits.mlp_ln, &lits.w_gate])?;
        Ok((
            Self::tensor_from(&out[0], vec![t, self.cfg.n_experts])?,
            Self::tensor_from(&out[1], vec![t, self.cfg.d_model])?,
        ))
    }

    /// expert FFN on normed hidden state h [T, D] (fp or fused-dequant).
    pub fn expert(&mut self, h: &Tensor, e: &DeviceExpert) -> Result<Tensor> {
        let t = h.shape[0];
        let prefix = if t == 1 { "" } else { "prefill_" };
        match e {
            DeviceExpert::Fp { w1, w3, w2 } => {
                let out = self.call(
                    &format!("{prefix}expert"),
                    &[
                        Self::lit_f32(h)?,
                        Self::lit_f32(w1)?,
                        Self::lit_f32(w3)?,
                        Self::lit_f32(w2)?,
                    ],
                )?;
                Self::tensor_from(&out[0], vec![t, self.cfg.d_model])
            }
            DeviceExpert::Quant { bits, q1, s1, z1, q3, s3, z3, q2, s2, z2 } => {
                let out = self.call(
                    &format!("{prefix}expert_q{bits}"),
                    &[
                        Self::lit_f32(h)?,
                        Self::lit_u8(q1)?,
                        Self::lit_f32(s1)?,
                        Self::lit_f32(z1)?,
                        Self::lit_u8(q3)?,
                        Self::lit_f32(s3)?,
                        Self::lit_f32(z3)?,
                        Self::lit_u8(q2)?,
                        Self::lit_f32(s2)?,
                        Self::lit_f32(z2)?,
                    ],
                )?;
                Self::tensor_from(&out[0], vec![t, self.cfg.d_model])
            }
        }
    }

    /// expert FFN via pre-marshalled literals (cached-expert fast path).
    pub fn expert_with_lits(&mut self, h: &Tensor, e: &ExpertLits) -> Result<Tensor> {
        let t = h.shape[0];
        let prefix = if t == 1 { "" } else { "prefill_" };
        let module = match e.bits {
            None => format!("{prefix}expert"),
            Some(bits) => format!("{prefix}expert_q{bits}"),
        };
        let x_lit = Self::lit_f32(h)?;
        let mut args: Vec<&Literal> = Vec::with_capacity(1 + e.args.len());
        args.push(&x_lit);
        args.extend(e.args.iter());
        let out = self.call_refs(&module, &args)?;
        Self::tensor_from(&out[0], vec![t, self.cfg.d_model])
    }

    /// Batched-decode expert FFN over stacked token rows `h: [n, D]`
    /// (one row per routed session in the layer-tick). Returns the
    /// `[n, D]` outputs plus the number of kernel invocations issued.
    ///
    /// `n = 1` uses the decode-shape module — bitwise the sequential
    /// path. For `n > 1` the AOT artifact set has exactly one wide
    /// expert shape, the `[prefill_chunk, D]` prefill module, so rows
    /// are zero-padded up to the chunk width (and chunked in the
    /// unusual case `n > prefill_chunk`). Padding is bit-safe for the
    /// same reason prefill's tail padding is: each output row of the
    /// row-parallel FFN depends only on its own input row, so the valid
    /// rows are unaffected by the zero rows riding along.
    pub fn expert_rows_with_lits(
        &mut self,
        h: &Tensor,
        e: &ExpertLits,
    ) -> Result<(Tensor, u64)> {
        let n = h.shape[0];
        if n == 1 {
            return Ok((self.expert_with_lits(h, e)?, 1));
        }
        let c = self.cfg.prefill_chunk;
        let d = self.cfg.d_model;
        let mut out = Vec::with_capacity(n * d);
        let mut calls = 0u64;
        let mut done = 0usize;
        while done < n {
            let take = (n - done).min(c);
            let mut chunk = vec![0.0f32; c * d];
            chunk[..take * d].copy_from_slice(&h.data[done * d..(done + take) * d]);
            let x = Tensor::new(chunk, vec![c, d])?;
            let o = self.expert_with_lits(&x, e)?;
            out.extend_from_slice(&o.data[..take * d]);
            done += take;
            calls += 1;
        }
        Ok((Tensor::new(out, vec![n, d])?, calls))
    }

    /// lm head: x [T, D] -> logits [T, V].
    pub fn lm_head(&mut self, x: &Tensor, final_ln: &Literal, w: &Literal) -> Result<Tensor> {
        let t = x.shape[0];
        let module = if t == 1 { "lm_head" } else { "prefill_lm_head" };
        let x_lit = Self::lit_f32(x)?;
        let out = self.call_refs(module, &[&x_lit, final_ln, w])?;
        Self::tensor_from(&out[0], vec![t, self.cfg.vocab_size])
    }

    /// Total host wall time spent inside PJRT calls (perf diagnostics).
    pub fn total_wall_s(&self) -> f64 {
        self.stats.values().map(|s| s.wall_s).sum()
    }
}
