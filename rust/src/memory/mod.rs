//! Two-tier memory substrate: host "pinned" expert pool, device budget
//! accounting, staging buffers, and the async copy engine that moves
//! quantized expert bytes across the modeled PCIe link.

pub mod copy_engine;
pub mod device;
pub mod host;

pub use copy_engine::{CopyEngine, TransferTicket};
pub use device::DeviceMemory;
pub use host::{ExpertId, HostExpertPool};
