//! Device-side expert storage: kernel-ready buffers + VRAM budget
//! accounting.
//!
//! A `DeviceExpert` holds an expert in the exact layout the PJRT
//! executables consume (byte-per-code uint8 + f32 scale/zero for the fused
//! dequant kernel, or raw f32 for the fp path). `DeviceMemory` enforces the
//! profile's VRAM budget the way the paper's implementation does: experts
//! are only admitted if the budget (after reserving non-expert weights, KV
//! cache and staging buffers) allows it.

use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::memory::host::{ExpertId, HostExpert};
use crate::quant::bitpack;
use crate::tensor::{Tensor, TensorU8};

/// Kernel-ready expert arguments.
#[derive(Debug, Clone)]
pub enum DeviceExpert {
    Fp {
        w1: Tensor,
        w3: Tensor,
        w2: Tensor,
    },
    Quant {
        bits: u8,
        q1: TensorU8,
        s1: Tensor,
        z1: Tensor,
        q3: TensorU8,
        s3: Tensor,
        z3: Tensor,
        q2: TensorU8,
        s2: Tensor,
        z2: Tensor,
    },
}

impl DeviceExpert {
    /// Unpack a host expert into kernel layout. This is the work the copy
    /// engine's staging threads perform ("GPU-side" unpack in the model).
    pub fn from_host(host: &HostExpert) -> Result<DeviceExpert> {
        match host {
            HostExpert::Fp { w1, w3, w2 } => Ok(DeviceExpert::Fp {
                w1: w1.clone(),
                w3: w3.clone(),
                w2: w2.clone(),
            }),
            HostExpert::Quant { w1, w3, w2 } => {
                let unpack = |m: &crate::quant::QuantizedMatrix| -> Result<(TensorU8, Tensor, Tensor)> {
                    let codes = bitpack::unpack(&m.packed, m.n_in * m.n_out, m.bits)?;
                    Ok((
                        TensorU8::new(codes, vec![m.n_in, m.n_out])?,
                        Tensor::new(m.scale.clone(), vec![m.n_groups(), m.n_out])?,
                        Tensor::new(m.zero.clone(), vec![m.n_groups(), m.n_out])?,
                    ))
                };
                let (q1, s1, z1) = unpack(w1)?;
                let (q3, s3, z3) = unpack(w3)?;
                let (q2, s2, z2) = unpack(w2)?;
                Ok(DeviceExpert::Quant {
                    bits: w1.bits,
                    q1,
                    s1,
                    z1,
                    q3,
                    s3,
                    z3,
                    q2,
                    s2,
                    z2,
                })
            }
        }
    }

    pub fn is_quant(&self) -> bool {
        matches!(self, DeviceExpert::Quant { .. })
    }

    /// Bit-width this copy was staged at (16 for fp). The cache manager
    /// records it per resident expert so a tier change can detect — and
    /// re-stage — a stale-precision copy.
    pub fn quant_bits(&self) -> u8 {
        match self {
            DeviceExpert::Fp { .. } => 16,
            DeviceExpert::Quant { bits, .. } => *bits,
        }
    }
}

/// VRAM budget accounting + resident expert store.
pub struct DeviceMemory {
    budget_bytes: u64,
    reserved_bytes: u64,
    /// Carve-out for the paged KV block pool (see [`crate::kv`]). The
    /// whole carve is pinned here — block-level occupancy within it is
    /// the [`crate::kv::KvPool`] allocator's job — so expert admission
    /// can never starve the KV path of its budget.
    kv_pool_bytes: u64,
    expert_bytes: u64,
    used_bytes: u64,
    resident: HashMap<ExpertId, DeviceExpert>,
    pub peak_bytes: u64,
}

impl DeviceMemory {
    /// `budget` is total VRAM; `reserved` covers non-expert weights,
    /// activations and staging buffers; `expert_bytes` is the device
    /// footprint of one expert (uniform — all experts share shape).
    pub fn new(budget: u64, reserved: u64, expert_bytes: u64) -> Self {
        Self::with_kv_pool(budget, reserved, 0, expert_bytes)
    }

    /// Like [`DeviceMemory::new`] with an explicit KV-pool carve-out on
    /// top of `reserved`.
    pub fn with_kv_pool(budget: u64, reserved: u64, kv_pool: u64, expert_bytes: u64) -> Self {
        DeviceMemory {
            budget_bytes: budget,
            reserved_bytes: reserved,
            kv_pool_bytes: kv_pool,
            expert_bytes,
            used_bytes: reserved + kv_pool,
            resident: HashMap::new(),
            peak_bytes: reserved + kv_pool,
        }
    }

    /// Bytes carved out for the paged KV block pool.
    pub fn kv_pool_bytes(&self) -> u64 {
        self.kv_pool_bytes
    }

    /// How many experts fit on the device at once.
    pub fn expert_capacity(&self) -> usize {
        if self.expert_bytes == 0 {
            return usize::MAX;
        }
        ((self
            .budget_bytes
            .saturating_sub(self.reserved_bytes)
            .saturating_sub(self.kv_pool_bytes))
            / self.expert_bytes) as usize
    }

    pub fn contains(&self, id: ExpertId) -> bool {
        self.resident.contains_key(&id)
    }

    pub fn get(&self, id: ExpertId) -> Option<&DeviceExpert> {
        self.resident.get(&id)
    }

    pub fn insert(&mut self, id: ExpertId, e: DeviceExpert) -> Result<()> {
        if self.resident.contains_key(&id) {
            return Ok(()); // idempotent re-insert
        }
        let new_used = self.used_bytes + self.expert_bytes;
        if new_used > self.budget_bytes {
            return Err(Error::Engine(format!(
                "device OOM inserting {id}: {new_used} > {} (evict first)",
                self.budget_bytes
            )));
        }
        self.used_bytes = new_used;
        self.peak_bytes = self.peak_bytes.max(self.used_bytes);
        self.resident.insert(id, e);
        Ok(())
    }

    /// Evict (paper: the LRU expert is copied back to RAM to preserve
    /// memory parity — host master copies make that a pure drop here).
    pub fn evict(&mut self, id: ExpertId) -> Option<DeviceExpert> {
        let e = self.resident.remove(&id);
        if e.is_some() {
            self.used_bytes -= self.expert_bytes;
        }
        e
    }

    pub fn resident_count(&self) -> usize {
        self.resident.len()
    }

    pub fn used_bytes(&self) -> u64 {
        self.used_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mem(cap_experts: u64) -> DeviceMemory {
        DeviceMemory::new(1000 + cap_experts * 100, 1000, 100)
    }

    fn id(l: usize, e: usize) -> ExpertId {
        ExpertId::new(l, e)
    }

    fn dummy() -> DeviceExpert {
        DeviceExpert::Fp {
            w1: Tensor::zeros(vec![1, 1]),
            w3: Tensor::zeros(vec![1, 1]),
            w2: Tensor::zeros(vec![1, 1]),
        }
    }

    #[test]
    fn capacity_accounting() {
        let m = mem(3);
        assert_eq!(m.expert_capacity(), 3);
    }

    #[test]
    fn insert_until_full_then_oom() {
        let mut m = mem(2);
        m.insert(id(0, 0), dummy()).unwrap();
        m.insert(id(0, 1), dummy()).unwrap();
        assert!(m.insert(id(0, 2), dummy()).is_err());
        assert_eq!(m.resident_count(), 2);
    }

    #[test]
    fn evict_frees_budget() {
        let mut m = mem(1);
        m.insert(id(0, 0), dummy()).unwrap();
        assert!(m.insert(id(0, 1), dummy()).is_err());
        assert!(m.evict(id(0, 0)).is_some());
        m.insert(id(0, 1), dummy()).unwrap();
        assert_eq!(m.resident_count(), 1);
        assert!(m.evict(id(9, 9)).is_none());
    }

    #[test]
    fn reinsert_is_idempotent() {
        let mut m = mem(1);
        m.insert(id(0, 0), dummy()).unwrap();
        m.insert(id(0, 0), dummy()).unwrap();
        assert_eq!(m.used_bytes(), 1100);
    }

    #[test]
    fn kv_pool_carve_reduces_expert_capacity() {
        // 1000 reserved + 200 KV pool + room for 3 experts of 100
        let m = DeviceMemory::with_kv_pool(1500, 1000, 200, 100);
        assert_eq!(m.kv_pool_bytes(), 200);
        assert_eq!(m.expert_capacity(), 3);
        assert_eq!(m.used_bytes(), 1200);
        // without the carve the same budget fits 5
        assert_eq!(DeviceMemory::new(1500, 1000, 100).expert_capacity(), 5);
    }

    #[test]
    fn peak_tracks_high_water() {
        let mut m = mem(2);
        m.insert(id(0, 0), dummy()).unwrap();
        m.insert(id(0, 1), dummy()).unwrap();
        m.evict(id(0, 0));
        assert_eq!(m.peak_bytes, 1200);
        assert_eq!(m.used_bytes(), 1100);
    }
}
