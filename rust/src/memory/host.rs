//! Host-side ("pinned RAM") expert pool.
//!
//! Mirrors the paper's §3.3 layout: every expert's parameters live in one
//! contiguous byte buffer that can be moved with a single host→device copy.
//! For quantized experts the buffer holds bit-packed codes followed by
//! scale/zero metadata for each of the three FFN matrices; for fp16
//! experts it holds raw f32 (accounted at 2 bytes/param on the link).

use std::collections::BTreeMap;

use crate::config::{ModelConfig, QuantScheme};
use crate::error::{Error, Result};
use crate::quant::hqq::{self, HqqConfig, QuantizedMatrix};
use crate::tensor::Tensor;

/// (layer, expert) identifier used across cache / memory / engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ExpertId {
    pub layer: u16,
    pub expert: u16,
}

impl ExpertId {
    pub fn new(layer: usize, expert: usize) -> Self {
        ExpertId { layer: layer as u16, expert: expert as u16 }
    }
}

impl std::fmt::Display for ExpertId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "L{}E{}", self.layer, self.expert)
    }
}

/// One expert's host-resident parameters.
#[derive(Debug, Clone)]
pub enum HostExpert {
    /// Unquantized: raw f32 matrices (w1, w3, w2).
    Fp { w1: Tensor, w3: Tensor, w2: Tensor },
    /// HQQ-quantized matrices.
    Quant {
        w1: QuantizedMatrix,
        w3: QuantizedMatrix,
        w2: QuantizedMatrix,
    },
}

impl HostExpert {
    /// Bytes that cross the host→device link for this expert.
    pub fn transfer_bytes(&self, scheme: QuantScheme) -> u64 {
        match self {
            HostExpert::Fp { w1, w3, w2 } => {
                // fp16 deployment: 2 bytes/param
                let n = w1.len() + w3.len() + w2.len();
                match scheme {
                    QuantScheme::Fp16 => (n * 2) as u64,
                    _ => (n * 2) as u64,
                }
            }
            HostExpert::Quant { w1, w3, w2 } => {
                w1.transfer_bytes() + w3.transfer_bytes() + w2.transfer_bytes()
            }
        }
    }

    /// Bytes the expert occupies resident on the device.
    pub fn device_bytes(&self) -> u64 {
        match self {
            HostExpert::Fp { w1, w3, w2 } => ((w1.len() + w3.len() + w2.len()) * 2) as u64,
            HostExpert::Quant { w1, w3, w2 } => {
                w1.transfer_bytes() + w3.transfer_bytes() + w2.transfer_bytes()
            }
        }
    }
}

/// All experts of the model, host-resident, keyed by (layer, expert).
pub struct HostExpertPool {
    pub scheme: QuantScheme,
    pub experts: BTreeMap<ExpertId, HostExpert>,
    cfg: ModelConfig,
}

impl HostExpertPool {
    /// Build the pool from raw f32 expert weights, quantizing per `scheme`.
    ///
    /// `get_weights(layer, expert)` returns (w1 [D,FF], w3 [D,FF], w2 [FF,D]).
    pub fn build(
        cfg: &ModelConfig,
        scheme: QuantScheme,
        mut get_weights: impl FnMut(usize, usize) -> Result<(Tensor, Tensor, Tensor)>,
    ) -> Result<Self> {
        let mut experts = BTreeMap::new();
        for layer in 0..cfg.n_layers {
            for expert in 0..cfg.n_experts {
                let (w1, w3, w2) = get_weights(layer, expert)?;
                let he = match scheme {
                    QuantScheme::Fp16 => HostExpert::Fp { w1, w3, w2 },
                    QuantScheme::Hqq { bits } => {
                        let g = scheme.group_size(cfg.group_size);
                        let hcfg = HqqConfig::new(bits, g);
                        HostExpert::Quant {
                            w1: hqq::quantize(&w1, &hcfg)?,
                            w3: hqq::quantize(&w3, &hcfg)?,
                            w2: hqq::quantize(&w2, &hcfg)?,
                        }
                    }
                };
                experts.insert(ExpertId::new(layer, expert), he);
            }
        }
        Ok(HostExpertPool { scheme, experts, cfg: cfg.clone() })
    }

    pub fn get(&self, id: ExpertId) -> Result<&HostExpert> {
        self.experts
            .get(&id)
            .ok_or_else(|| Error::Engine(format!("no host expert {id}")))
    }

    /// Transfer size of one (representative) expert.
    pub fn expert_transfer_bytes(&self) -> u64 {
        self.experts
            .values()
            .next()
            .map(|e| e.transfer_bytes(self.scheme))
            .unwrap_or(0)
    }

    /// Total host bytes across all experts.
    pub fn total_bytes(&self) -> u64 {
        self.experts
            .values()
            .map(|e| e.transfer_bytes(self.scheme))
            .sum()
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny_cfg() -> ModelConfig {
        let mut c = ModelConfig::tiny();
        c.n_layers = 2;
        c.n_experts = 2;
        c.d_model = 32;
        c.d_ff = 64;
        c.group_size = 16;
        c
    }

    fn rand_t(rng: &mut Rng, shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor::new((0..n).map(|_| rng.normal() as f32 * 0.1).collect(), shape).unwrap()
    }

    fn build_pool(scheme: QuantScheme) -> HostExpertPool {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(1);
        HostExpertPool::build(&cfg, scheme, |_, _| {
            Ok((
                rand_t(&mut rng, vec![32, 64]),
                rand_t(&mut rng, vec![32, 64]),
                rand_t(&mut rng, vec![64, 32]),
            ))
        })
        .unwrap()
    }

    #[test]
    fn pool_has_all_experts() {
        let pool = build_pool(QuantScheme::Hqq { bits: 4 });
        assert_eq!(pool.experts.len(), 4);
        assert!(pool.get(ExpertId::new(1, 1)).is_ok());
        assert!(pool.get(ExpertId::new(2, 0)).is_err());
    }

    #[test]
    fn quantized_pool_is_smaller_than_fp() {
        let q2 = build_pool(QuantScheme::Hqq { bits: 2 }).total_bytes();
        let q4 = build_pool(QuantScheme::Hqq { bits: 4 }).total_bytes();
        let fp = build_pool(QuantScheme::Fp16).total_bytes();
        assert!(q2 < q4 && q4 < fp, "{q2} {q4} {fp}");
    }

    #[test]
    fn transfer_bytes_matches_scheme_accounting() {
        let pool = build_pool(QuantScheme::Hqq { bits: 3 });
        let per = pool.expert_transfer_bytes();
        // 3 matrices, each n=2048 params, g=16 (2-bit would shrink groups;
        // 3-bit keeps model group 16 here)
        let scheme = QuantScheme::Hqq { bits: 3 };
        let expected = 3 * scheme.bytes_for(2048, 16);
        assert_eq!(per, expected);
    }
}
