//! Host-side ("pinned RAM") expert pool.
//!
//! Mirrors the paper's §3.3 layout: every expert's parameters live in one
//! contiguous byte buffer that can be moved with a single host→device copy.
//! For quantized experts the buffer holds bit-packed codes followed by
//! scale/zero metadata for each of the three FFN matrices; for fp16
//! experts it holds raw f32 (accounted at 2 bytes/param on the link).
//!
//! With a [`TierPolicy`] enabled (see [`crate::quant::tier`]) the pool
//! additionally keeps one packed copy per DISTINCT tier scheme and a
//! mutable per-expert tier assignment: [`HostExpertPool::get`] serves
//! the copy matching the expert's CURRENT tier, so the copy engine's
//! staging threads transparently ship tier-correct bytes, and
//! [`HostExpertPool::set_tier`] re-tiers an expert online (the engine
//! invalidates any resident copy staged at the old precision).

use std::collections::BTreeMap;
use std::sync::RwLock;

use crate::config::{ModelConfig, QuantScheme};
use crate::error::{Error, Result};
use crate::fault::Checksum;
use crate::quant::hqq::{self, HqqConfig, QuantizedMatrix};
use crate::quant::tier::{Tier, TierPolicy};
use crate::tensor::Tensor;

/// (layer, expert) identifier used across cache / memory / engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ExpertId {
    pub layer: u16,
    pub expert: u16,
}

impl ExpertId {
    pub fn new(layer: usize, expert: usize) -> Self {
        ExpertId { layer: layer as u16, expert: expert as u16 }
    }
}

impl std::fmt::Display for ExpertId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "L{}E{}", self.layer, self.expert)
    }
}

/// One expert's host-resident parameters.
#[derive(Debug, Clone)]
pub enum HostExpert {
    /// Unquantized: raw f32 matrices (w1, w3, w2).
    Fp { w1: Tensor, w3: Tensor, w2: Tensor },
    /// HQQ-quantized matrices.
    Quant {
        w1: QuantizedMatrix,
        w3: QuantizedMatrix,
        w2: QuantizedMatrix,
    },
}

impl HostExpert {
    /// Bytes that cross the host→device link for this expert.
    pub fn transfer_bytes(&self, scheme: QuantScheme) -> u64 {
        match self {
            HostExpert::Fp { w1, w3, w2 } => {
                // fp16 deployment: 2 bytes/param
                let n = w1.len() + w3.len() + w2.len();
                match scheme {
                    QuantScheme::Fp16 => (n * 2) as u64,
                    _ => (n * 2) as u64,
                }
            }
            HostExpert::Quant { w1, w3, w2 } => {
                w1.transfer_bytes() + w3.transfer_bytes() + w2.transfer_bytes()
            }
        }
    }

    /// Bytes the expert occupies resident on the device.
    pub fn device_bytes(&self) -> u64 {
        match self {
            HostExpert::Fp { w1, w3, w2 } => ((w1.len() + w3.len() + w2.len()) * 2) as u64,
            HostExpert::Quant { w1, w3, w2 } => {
                w1.transfer_bytes() + w3.transfer_bytes() + w2.transfer_bytes()
            }
        }
    }

    /// FNV-1a over this copy's packed payload — recorded once per packed
    /// copy at pool build and re-verified at staging when fault
    /// injection is enabled (see [`crate::fault`]). Walks the buffers in
    /// place; nothing is materialized.
    pub fn payload_checksum(&self) -> u64 {
        let mut h = Checksum::new();
        match self {
            HostExpert::Fp { w1, w3, w2 } => {
                for t in [w1, w3, w2] {
                    for v in &t.data {
                        h.update(&v.to_le_bytes());
                    }
                }
            }
            HostExpert::Quant { w1, w3, w2 } => {
                for m in [w1, w3, w2] {
                    h.update(&m.packed);
                    for v in &m.scale {
                        h.update(&v.to_le_bytes());
                    }
                    for v in &m.zero {
                        h.update(&v.to_le_bytes());
                    }
                }
            }
        }
        h.finish()
    }
}

/// Pack one expert's raw f32 matrices at `scheme`.
fn pack_expert(
    cfg: &ModelConfig,
    scheme: QuantScheme,
    w1: &Tensor,
    w3: &Tensor,
    w2: &Tensor,
) -> Result<HostExpert> {
    match scheme {
        QuantScheme::Fp16 => Ok(HostExpert::Fp { w1: w1.clone(), w3: w3.clone(), w2: w2.clone() }),
        QuantScheme::Hqq { bits } => {
            let g = scheme.group_size(cfg.group_size);
            let hcfg = HqqConfig::new(bits, g);
            Ok(HostExpert::Quant {
                w1: hqq::quantize(w1, &hcfg)?,
                w3: hqq::quantize(w3, &hcfg)?,
                w2: hqq::quantize(w2, &hcfg)?,
            })
        }
    }
}

/// Per-tier packed copies plus the mutable current-tier assignment.
/// The packed maps are immutable after build; only `current` mutates
/// (behind a lock — the copy engine's staging threads share the pool).
struct TierStore {
    policy: TierPolicy,
    /// Hot-scheme copies; `None` when the hot scheme equals the base
    /// scheme (the base map is shared instead of duplicated).
    hot: Option<BTreeMap<ExpertId, HostExpert>>,
    /// Cold-scheme copies; `None` when the cold scheme equals the base.
    cold: Option<BTreeMap<ExpertId, HostExpert>>,
    /// Build-time payload checksums of the hot/cold copies (same
    /// `None`-means-shared convention as the copies themselves).
    hot_sums: Option<BTreeMap<ExpertId, u64>>,
    cold_sums: Option<BTreeMap<ExpertId, u64>>,
    /// Current tier per expert (unlisted = Warm).
    current: RwLock<BTreeMap<ExpertId, Tier>>,
}

/// All experts of the model, host-resident, keyed by (layer, expert).
pub struct HostExpertPool {
    /// The base (Warm-tier) scheme — the deployment's `expert_quant`.
    pub scheme: QuantScheme,
    /// Base-scheme packed copies (every expert's Warm variant).
    pub experts: BTreeMap<ExpertId, HostExpert>,
    /// Build-time payload checksum of every base copy — the reference
    /// the engine verifies staged copies against when fault injection
    /// is enabled.
    checksums: BTreeMap<ExpertId, u64>,
    cfg: ModelConfig,
    /// Per-tier variants; `None` = uniform pool (tiers disabled).
    tiers: Option<TierStore>,
}

impl HostExpertPool {
    /// Build a uniform pool from raw f32 expert weights, quantizing per
    /// `scheme`.
    ///
    /// `get_weights(layer, expert)` returns (w1 [D,FF], w3 [D,FF], w2 [FF,D]).
    pub fn build(
        cfg: &ModelConfig,
        scheme: QuantScheme,
        mut get_weights: impl FnMut(usize, usize) -> Result<(Tensor, Tensor, Tensor)>,
    ) -> Result<Self> {
        let mut experts = BTreeMap::new();
        let mut checksums = BTreeMap::new();
        for layer in 0..cfg.n_layers {
            for expert in 0..cfg.n_experts {
                let (w1, w3, w2) = get_weights(layer, expert)?;
                let id = ExpertId::new(layer, expert);
                let packed = pack_expert(cfg, scheme, &w1, &w3, &w2)?;
                checksums.insert(id, packed.payload_checksum());
                experts.insert(id, packed);
            }
        }
        Ok(HostExpertPool { scheme, experts, checksums, cfg: cfg.clone(), tiers: None })
    }

    /// Build a TIERED pool: base-scheme copies for every expert plus one
    /// extra packed copy per distinct hot/cold scheme. Every expert
    /// starts Warm — the engine seeds the initial assignment from gate
    /// statistics right after construction. With `policy.enabled` false
    /// this is exactly [`Self::build`] (no extra copies, no lock on the
    /// serving path).
    pub fn build_tiered(
        cfg: &ModelConfig,
        scheme: QuantScheme,
        policy: &TierPolicy,
        mut get_weights: impl FnMut(usize, usize) -> Result<(Tensor, Tensor, Tensor)>,
    ) -> Result<Self> {
        if !policy.enabled {
            return Self::build(cfg, scheme, get_weights);
        }
        let mut experts = BTreeMap::new();
        let mut checksums = BTreeMap::new();
        let mut hot = (policy.hot != scheme).then(BTreeMap::new);
        let mut cold = (policy.cold != scheme).then(BTreeMap::new);
        let mut hot_sums = hot.as_ref().map(|_| BTreeMap::new());
        let mut cold_sums = cold.as_ref().map(|_| BTreeMap::new());
        for layer in 0..cfg.n_layers {
            for expert in 0..cfg.n_experts {
                let (w1, w3, w2) = get_weights(layer, expert)?;
                let id = ExpertId::new(layer, expert);
                let packed = pack_expert(cfg, scheme, &w1, &w3, &w2)?;
                checksums.insert(id, packed.payload_checksum());
                experts.insert(id, packed);
                if let Some(m) = hot.as_mut() {
                    let packed = pack_expert(cfg, policy.hot, &w1, &w3, &w2)?;
                    hot_sums.as_mut().unwrap().insert(id, packed.payload_checksum());
                    m.insert(id, packed);
                }
                if let Some(m) = cold.as_mut() {
                    let packed = pack_expert(cfg, policy.cold, &w1, &w3, &w2)?;
                    cold_sums.as_mut().unwrap().insert(id, packed.payload_checksum());
                    m.insert(id, packed);
                }
            }
        }
        Ok(HostExpertPool {
            scheme,
            experts,
            checksums,
            cfg: cfg.clone(),
            tiers: Some(TierStore {
                policy: *policy,
                hot,
                cold,
                hot_sums,
                cold_sums,
                current: RwLock::new(BTreeMap::new()),
            }),
        })
    }

    /// Whether this pool carries per-tier variants.
    pub fn tiered(&self) -> bool {
        self.tiers.is_some()
    }

    /// The policy this pool's tier variants were packed under (`None` =
    /// uniform pool). The authoritative source for the engine's tier
    /// behavior — guaranteed consistent with the packed copies, unlike
    /// the serving config the weights may not have been built from.
    pub fn tier_policy(&self) -> Option<&TierPolicy> {
        self.tiers.as_ref().map(|t| &t.policy)
    }

    /// The expert's current tier (Warm for uniform pools).
    pub fn tier_of(&self, id: ExpertId) -> Tier {
        // a poisoned assignment map is still a valid map (writers only
        // ever insert/remove whole entries) — recover it rather than
        // cascading a staging thread's panic into the serving thread
        self.tiers
            .as_ref()
            .and_then(|t| {
                t.current
                    .read()
                    .unwrap_or_else(|e| e.into_inner())
                    .get(&id)
                    .copied()
            })
            .unwrap_or(Tier::Warm)
    }

    /// Re-tier an expert; returns the previous tier. A no-op (always
    /// Warm) on uniform pools. The caller — the engine — must invalidate
    /// any device copy staged at the old tier's precision.
    pub fn set_tier(&self, id: ExpertId, tier: Tier) -> Tier {
        let Some(store) = self.tiers.as_ref() else { return Tier::Warm };
        let mut cur = store.current.write().unwrap_or_else(|e| e.into_inner());
        if tier == Tier::Warm {
            cur.remove(&id).unwrap_or(Tier::Warm)
        } else {
            cur.insert(id, tier).unwrap_or(Tier::Warm)
        }
    }

    /// The scheme an expert at `tier` is packed with in THIS pool.
    pub fn scheme_of_tier(&self, tier: Tier) -> QuantScheme {
        match self.tiers.as_ref() {
            Some(t) => t.policy.scheme_for(tier, self.scheme),
            None => self.scheme,
        }
    }

    /// The packed copy matching the expert's CURRENT tier — what the
    /// copy engine ships. Uniform pools skip the tier lookup entirely.
    pub fn get(&self, id: ExpertId) -> Result<&HostExpert> {
        let map = match self.tiers.as_ref() {
            None => &self.experts,
            Some(store) => match self.tier_of(id) {
                Tier::Warm => &self.experts,
                Tier::Hot => store.hot.as_ref().unwrap_or(&self.experts),
                Tier::Cold => store.cold.as_ref().unwrap_or(&self.experts),
            },
        };
        map.get(&id)
            .ok_or_else(|| Error::Engine(format!("no host expert {id}")))
    }

    /// The build-time payload checksum of the copy [`Self::get`] would
    /// serve right now (i.e. at the expert's CURRENT tier) — what the
    /// engine verifies a staged copy against when fault injection is
    /// enabled.
    pub fn expected_checksum(&self, id: ExpertId) -> Result<u64> {
        let map = match self.tiers.as_ref() {
            None => &self.checksums,
            Some(store) => match self.tier_of(id) {
                Tier::Warm => &self.checksums,
                Tier::Hot => store.hot_sums.as_ref().unwrap_or(&self.checksums),
                Tier::Cold => store.cold_sums.as_ref().unwrap_or(&self.checksums),
            },
        };
        map.get(&id)
            .copied()
            .ok_or_else(|| Error::Engine(format!("no host expert {id}")))
    }

    /// Link bytes for one expert at its CURRENT tier.
    pub fn transfer_bytes_of(&self, id: ExpertId) -> Result<u64> {
        let scheme = self.scheme_of_tier(self.tier_of(id));
        Ok(self.get(id)?.transfer_bytes(scheme))
    }

    /// Transfer size of one (representative) expert at the base scheme.
    pub fn expert_transfer_bytes(&self) -> u64 {
        self.experts
            .values()
            .next()
            .map(|e| e.transfer_bytes(self.scheme))
            .unwrap_or(0)
    }

    /// Total host bytes across all experts (base copies only — tier
    /// variants are duplicate capacity in host RAM, not model size).
    pub fn total_bytes(&self) -> u64 {
        self.experts
            .values()
            .map(|e| e.transfer_bytes(self.scheme))
            .sum()
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny_cfg() -> ModelConfig {
        let mut c = ModelConfig::tiny();
        c.n_layers = 2;
        c.n_experts = 2;
        c.d_model = 32;
        c.d_ff = 64;
        c.group_size = 16;
        c
    }

    fn rand_t(rng: &mut Rng, shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor::new((0..n).map(|_| rng.normal() as f32 * 0.1).collect(), shape).unwrap()
    }

    fn build_pool(scheme: QuantScheme) -> HostExpertPool {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(1);
        HostExpertPool::build(&cfg, scheme, |_, _| {
            Ok((
                rand_t(&mut rng, vec![32, 64]),
                rand_t(&mut rng, vec![32, 64]),
                rand_t(&mut rng, vec![64, 32]),
            ))
        })
        .unwrap()
    }

    #[test]
    fn pool_has_all_experts() {
        let pool = build_pool(QuantScheme::Hqq { bits: 4 });
        assert_eq!(pool.experts.len(), 4);
        assert!(pool.get(ExpertId::new(1, 1)).is_ok());
        assert!(pool.get(ExpertId::new(2, 0)).is_err());
    }

    #[test]
    fn quantized_pool_is_smaller_than_fp() {
        let q2 = build_pool(QuantScheme::Hqq { bits: 2 }).total_bytes();
        let q4 = build_pool(QuantScheme::Hqq { bits: 4 }).total_bytes();
        let fp = build_pool(QuantScheme::Fp16).total_bytes();
        assert!(q2 < q4 && q4 < fp, "{q2} {q4} {fp}");
    }

    #[test]
    fn transfer_bytes_matches_scheme_accounting() {
        let pool = build_pool(QuantScheme::Hqq { bits: 3 });
        let per = pool.expert_transfer_bytes();
        // 3 matrices, each n=2048 params, g=16 (2-bit would shrink groups;
        // 3-bit keeps model group 16 here)
        let scheme = QuantScheme::Hqq { bits: 3 };
        let expected = 3 * scheme.bytes_for(2048, 16);
        assert_eq!(per, expected);
    }

    fn build_tiered_pool(policy: &TierPolicy) -> HostExpertPool {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(1);
        HostExpertPool::build_tiered(&cfg, QuantScheme::Hqq { bits: 3 }, policy, |_, _| {
            Ok((
                rand_t(&mut rng, vec![32, 64]),
                rand_t(&mut rng, vec![32, 64]),
                rand_t(&mut rng, vec![64, 32]),
            ))
        })
        .unwrap()
    }

    #[test]
    fn disabled_policy_builds_a_uniform_pool() {
        let pool = build_tiered_pool(&TierPolicy::default());
        assert!(!pool.tiered());
        let id = ExpertId::new(0, 0);
        // set_tier is a no-op and get() serves base bytes
        assert_eq!(pool.set_tier(id, Tier::Hot), Tier::Warm);
        assert_eq!(pool.tier_of(id), Tier::Warm);
        assert_eq!(pool.transfer_bytes_of(id).unwrap(), pool.expert_transfer_bytes());
    }

    #[test]
    fn tiered_pool_serves_tier_matching_bytes() {
        let pool = build_tiered_pool(&TierPolicy::hot_cold());
        assert!(pool.tiered());
        let id = ExpertId::new(0, 1);
        let warm = pool.transfer_bytes_of(id).unwrap();
        assert_eq!(warm, pool.expert_transfer_bytes());

        assert_eq!(pool.set_tier(id, Tier::Hot), Tier::Warm);
        assert_eq!(pool.tier_of(id), Tier::Hot);
        let hot = pool.transfer_bytes_of(id).unwrap();
        let hot_scheme = pool.scheme_of_tier(Tier::Hot);
        assert_eq!(hot, pool.get(id).unwrap().transfer_bytes(hot_scheme));
        assert!(hot > warm, "4-bit hot copy must outweigh the 3-bit base: {hot} vs {warm}");

        assert_eq!(pool.set_tier(id, Tier::Cold), Tier::Hot);
        let cold = pool.transfer_bytes_of(id).unwrap();
        assert!(cold < warm, "2-bit cold copy must undercut the 3-bit base: {cold} vs {warm}");

        // only the re-tiered expert changed; its sibling still serves warm
        assert_eq!(pool.transfer_bytes_of(ExpertId::new(0, 0)).unwrap(), warm);
    }

    #[test]
    fn build_checksums_match_served_copies() {
        let pool = build_pool(QuantScheme::Hqq { bits: 3 });
        for (&id, e) in &pool.experts {
            assert_eq!(pool.expected_checksum(id).unwrap(), e.payload_checksum());
        }
        // distinct experts hash differently (corruption across copies
        // would be caught too)
        let a = pool.expected_checksum(ExpertId::new(0, 0)).unwrap();
        let b = pool.expected_checksum(ExpertId::new(0, 1)).unwrap();
        assert_ne!(a, b);
        assert!(pool.expected_checksum(ExpertId::new(9, 9)).is_err());
    }

    #[test]
    fn tiered_checksums_follow_the_current_tier() {
        let pool = build_tiered_pool(&TierPolicy::hot_cold());
        let id = ExpertId::new(0, 1);
        let warm = pool.expected_checksum(id).unwrap();
        assert_eq!(warm, pool.get(id).unwrap().payload_checksum());

        pool.set_tier(id, Tier::Hot);
        let hot = pool.expected_checksum(id).unwrap();
        assert_eq!(hot, pool.get(id).unwrap().payload_checksum());
        assert_ne!(hot, warm, "4-bit copy must hash differently from 3-bit");

        pool.set_tier(id, Tier::Cold);
        assert_eq!(
            pool.expected_checksum(id).unwrap(),
            pool.get(id).unwrap().payload_checksum()
        );
    }

    #[test]
    fn tier_scheme_matching_base_shares_the_base_copies() {
        // hot == base scheme -> no duplicate hot map; get() must still work
        let policy = TierPolicy {
            hot: QuantScheme::Hqq { bits: 3 },
            ..TierPolicy::hot_cold()
        };
        let pool = build_tiered_pool(&policy);
        let id = ExpertId::new(1, 0);
        let warm = pool.transfer_bytes_of(id).unwrap();
        pool.set_tier(id, Tier::Hot);
        assert_eq!(pool.transfer_bytes_of(id).unwrap(), warm);
    }
}
