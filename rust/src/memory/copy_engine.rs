//! Async copy engine: background staging of experts from the host pool
//! into kernel-ready device buffers.
//!
//! Mirrors the paper's §3.3 design: `b` shared staging buffers (default 4)
//! bound the number of in-flight copies; copies run off the compute thread
//! so speculative loads overlap "GPU" work. Implemented with std threads +
//! channels (tokio is not in the offline crate set, and the workload —
//! few, large, CPU-bound memcpy/unpack jobs — fits a small thread pool
//! better than an async reactor anyway).
//!
//! Virtual *timing* of transfers is not decided here — the engine reserves
//! spans on the [`crate::clock::Timeline`] link resource; this engine does
//! the real data movement and completion signaling.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::error::{Error, Result};
use crate::memory::device::DeviceExpert;
use crate::memory::host::{ExpertId, HostExpertPool};

/// Handle for a submitted transfer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TransferTicket(pub u64);

enum Job {
    Stage { ticket: TransferTicket, id: ExpertId },
    Shutdown,
}

struct Semaphore {
    permits: Mutex<usize>,
    cv: Condvar,
}

impl Semaphore {
    fn new(n: usize) -> Self {
        Semaphore { permits: Mutex::new(n), cv: Condvar::new() }
    }

    // a poisoned permit count is still a valid count — a panicking
    // holder only ever observed it, so recover the inner value instead
    // of cascading the panic into the serving thread
    fn acquire(&self) {
        let mut p = self.permits.lock().unwrap_or_else(|e| e.into_inner());
        while *p == 0 {
            p = self.cv.wait(p).unwrap_or_else(|e| e.into_inner());
        }
        *p -= 1;
    }

    fn release(&self) {
        *self.permits.lock().unwrap_or_else(|e| e.into_inner()) += 1;
        self.cv.notify_one();
    }
}

pub struct CopyEngine {
    job_tx: Sender<Job>,
    done_rx: Receiver<(TransferTicket, ExpertId, Result<DeviceExpert>)>,
    workers: Vec<JoinHandle<()>>,
    staging: Arc<Semaphore>,
    next_ticket: u64,
    /// Completions drained but not yet claimed by the engine.
    ready: HashMap<TransferTicket, (ExpertId, DeviceExpert)>,
    pub staged_jobs: u64,
    /// Jobs submitted on the blocking demand path (includes fault
    /// re-stages and naive layer streaming). `demand + spec == staged`.
    pub demand_jobs: u64,
    /// Jobs submitted by speculative prefetch.
    pub spec_jobs: u64,
}

impl CopyEngine {
    /// `staging_buffers` = the paper's `b` (bounds in-flight copies);
    /// `workers` = staging threads (the paper uses CUDA copy streams; we
    /// use 2 threads so copies genuinely overlap compute).
    pub fn new(pool: Arc<HostExpertPool>, staging_buffers: usize, workers: usize) -> Self {
        let (job_tx, job_rx) = channel::<Job>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let (done_tx, done_rx) = channel();
        let staging = Arc::new(Semaphore::new(staging_buffers.max(1)));

        let mut handles = Vec::new();
        for _ in 0..workers.max(1) {
            let job_rx = Arc::clone(&job_rx);
            let done_tx = done_tx.clone();
            let pool = Arc::clone(&pool);
            let staging = Arc::clone(&staging);
            handles.push(std::thread::spawn(move || loop {
                let job = {
                    let rx = job_rx.lock().unwrap_or_else(|e| e.into_inner());
                    rx.recv()
                };
                match job {
                    Ok(Job::Stage { ticket, id }) => {
                        let result = pool
                            .get(id)
                            .and_then(DeviceExpert::from_host);
                        // buffer stays held until the engine claims the
                        // result; release on send (claim copies out).
                        let _ = done_tx.send((ticket, id, result));
                        staging.release();
                    }
                    Ok(Job::Shutdown) | Err(_) => break,
                }
            }));
        }

        CopyEngine {
            job_tx,
            done_rx,
            workers: handles,
            staging,
            next_ticket: 0,
            ready: HashMap::new(),
            staged_jobs: 0,
            demand_jobs: 0,
            spec_jobs: 0,
        }
    }

    /// Submit a staging job; blocks only if all `b` staging buffers are in
    /// flight (back-pressure, like the paper's shared buffers). Errors —
    /// instead of panicking the serving thread — if the worker pool died,
    /// so the scheduler can fail the one affected request and keep going.
    pub fn submit(&mut self, id: ExpertId) -> Result<TransferTicket> {
        self.submit_kind(id, false)
    }

    /// [`Self::submit`] for speculative prefetches — identical staging,
    /// separate lifetime counter (the expert flight recorder splits link
    /// work by cause).
    pub fn submit_speculative(&mut self, id: ExpertId) -> Result<TransferTicket> {
        self.submit_kind(id, true)
    }

    fn submit_kind(&mut self, id: ExpertId, spec: bool) -> Result<TransferTicket> {
        self.staging.acquire();
        let ticket = TransferTicket(self.next_ticket);
        if self.job_tx.send(Job::Stage { ticket, id }).is_err() {
            // nothing was staged: hand the permit back so repeated
            // submits against a dead pool keep erroring here instead of
            // deadlocking in acquire() once the permits run out
            self.staging.release();
            return Err(Error::Engine("copy engine workers dead".into()));
        }
        self.next_ticket += 1;
        self.staged_jobs += 1;
        if spec {
            self.spec_jobs += 1;
        } else {
            self.demand_jobs += 1;
        }
        Ok(ticket)
    }

    /// Non-blocking drain of finished jobs into the ready set.
    fn drain(&mut self) -> Result<()> {
        while let Ok((ticket, id, result)) = self.done_rx.try_recv() {
            self.ready.insert(ticket, (id, result?));
        }
        Ok(())
    }

    /// Poll: is this ticket done? (drains completions as a side effect)
    pub fn is_ready(&mut self, ticket: TransferTicket) -> Result<bool> {
        self.drain()?;
        Ok(self.ready.contains_key(&ticket))
    }

    /// Block until `ticket` completes and return its expert.
    pub fn wait(&mut self, ticket: TransferTicket) -> Result<(ExpertId, DeviceExpert)> {
        self.drain()?;
        loop {
            if let Some(done) = self.ready.remove(&ticket) {
                return Ok(done);
            }
            let (t, id, result) = self
                .done_rx
                .recv()
                .map_err(|_| Error::Engine("copy engine workers dead".into()))?;
            self.ready.insert(t, (id, result?));
        }
    }

    /// Claim a completed ticket if available without blocking.
    pub fn try_claim(&mut self, ticket: TransferTicket) -> Result<Option<(ExpertId, DeviceExpert)>> {
        self.drain()?;
        Ok(self.ready.remove(&ticket))
    }
}

impl Drop for CopyEngine {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.job_tx.send(Job::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, QuantScheme};
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn pool() -> Arc<HostExpertPool> {
        let mut cfg = ModelConfig::tiny();
        cfg.n_layers = 2;
        cfg.n_experts = 3;
        cfg.d_model = 32;
        cfg.d_ff = 64;
        cfg.group_size = 16;
        let mut rng = Rng::new(3);
        let mut rand_t = move |shape: Vec<usize>| {
            let n: usize = shape.iter().product();
            Tensor::new((0..n).map(|_| rng.normal() as f32 * 0.1).collect(), shape).unwrap()
        };
        Arc::new(
            HostExpertPool::build(&cfg, QuantScheme::Hqq { bits: 3 }, |_, _| {
                Ok((
                    rand_t(vec![32, 64]),
                    rand_t(vec![32, 64]),
                    rand_t(vec![64, 32]),
                ))
            })
            .unwrap(),
        )
    }

    #[test]
    fn stages_and_completes() {
        let mut ce = CopyEngine::new(pool(), 4, 2);
        let t = ce.submit(ExpertId::new(0, 1)).unwrap();
        let (id, expert) = ce.wait(t).unwrap();
        assert_eq!(id, ExpertId::new(0, 1));
        assert!(expert.is_quant());
    }

    #[test]
    fn many_inflight_with_bounded_staging() {
        let mut ce = CopyEngine::new(pool(), 2, 2);
        let tickets: Vec<_> = (0..6)
            .map(|i| ce.submit(ExpertId::new(i % 2, i % 3)).unwrap())
            .collect();
        for t in tickets {
            ce.wait(t).unwrap();
        }
        assert_eq!(ce.staged_jobs, 6);
    }

    #[test]
    fn job_counters_split_by_cause() {
        let mut ce = CopyEngine::new(pool(), 4, 2);
        let a = ce.submit(ExpertId::new(0, 1)).unwrap();
        let b = ce.submit_speculative(ExpertId::new(0, 2)).unwrap();
        let c = ce.submit_speculative(ExpertId::new(1, 0)).unwrap();
        for t in [a, b, c] {
            ce.wait(t).unwrap();
        }
        assert_eq!(ce.staged_jobs, 3);
        assert_eq!(ce.demand_jobs, 1);
        assert_eq!(ce.spec_jobs, 2);
        assert_eq!(ce.demand_jobs + ce.spec_jobs, ce.staged_jobs);
    }

    #[test]
    fn unknown_expert_reports_error() {
        let mut ce = CopyEngine::new(pool(), 2, 1);
        let t = ce.submit(ExpertId::new(9, 9)).unwrap();
        assert!(ce.wait(t).is_err());
    }

    #[test]
    fn try_claim_nonblocking() {
        let mut ce = CopyEngine::new(pool(), 2, 1);
        let t = ce.submit(ExpertId::new(1, 2)).unwrap();
        // eventually claimable without wait()
        let mut claimed = None;
        for _ in 0..1000 {
            if let Some(c) = ce.try_claim(t).unwrap() {
                claimed = Some(c);
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(claimed.unwrap().0, ExpertId::new(1, 2));
    }
}
