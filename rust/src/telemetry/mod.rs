//! Lightweight metrics: counters + streaming histograms for the serving
//! coordinator, and table formatting for the experiment binaries.

use std::collections::BTreeMap;
use std::sync::Mutex;

/// A fixed-boundary histogram (latencies in seconds).
#[derive(Debug, Clone)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    sum: f64,
    n: u64,
    max: f64,
}

impl Histogram {
    /// A histogram with caller-chosen bucket boundaries (ascending,
    /// seconds). There is always one overflow bucket past the last bound.
    pub fn with_bounds(bounds: Vec<f64>) -> Self {
        debug_assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds must ascend");
        let len = bounds.len() + 1;
        Histogram { bounds, counts: vec![0; len], sum: 0.0, n: 0, max: 0.0 }
    }

    pub fn latency() -> Self {
        // 100us .. 100s, log-spaced
        Self::with_bounds((0..13).map(|i| 1e-4 * 3.0f64.powi(i)).collect())
    }

    /// Bounds for virtual-timeline durations. The tiny testbed's per-token
    /// sim times sit well under the 100µs floor of [`Self::latency`] —
    /// every observation would collapse into bucket 0 and quantiles would
    /// all read 100µs. This range (10ns .. ~3.8s, log-spaced) resolves
    /// sub-microsecond compute spans and second-scale Mixtral-geometry
    /// transfers alike.
    pub fn sim_time() -> Self {
        Self::with_bounds((0..20).map(|i| 1e-8 * 3.0f64.powi(i)).collect())
    }

    pub fn observe(&mut self, v: f64) {
        let idx = self.bounds.iter().position(|b| v <= *b).unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.sum += v;
        self.n += 1;
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Approximate quantile from bucket boundaries.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let target = (q * self.n as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return if i < self.bounds.len() { self.bounds[i] } else { self.max };
            }
        }
        self.max
    }
}

/// Process-wide metrics registry (coordinator-facing).
#[derive(Debug, Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, u64>>,
    gauges: Mutex<BTreeMap<String, u64>>,
    histograms: Mutex<BTreeMap<String, Histogram>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    // Every lock below recovers from poisoning instead of propagating
    // the panic: a poisoned map is still a valid map (holders only ever
    // make whole-entry changes), and the metrics registry must never be
    // the thing that takes the serving thread down.
    pub fn inc(&self, name: &str, by: u64) {
        let mut c = self.counters.lock().unwrap_or_else(|e| e.into_inner());
        *c.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn counter(&self, name: &str) -> u64 {
        *self.counters.lock().unwrap_or_else(|e| e.into_inner()).get(name).unwrap_or(&0)
    }

    /// Set a point-in-time gauge (e.g. `active_sessions`).
    pub fn set_gauge(&self, name: &str, v: u64) {
        self.gauges.lock().unwrap_or_else(|e| e.into_inner()).insert(name.to_string(), v);
    }

    pub fn gauge(&self, name: &str) -> u64 {
        *self.gauges.lock().unwrap_or_else(|e| e.into_inner()).get(name).unwrap_or(&0)
    }

    /// Record the KV block pool's occupancy gauges in one shot
    /// (`kv_blocks_total` / `kv_blocks_free` / `kv_blocks_in_use` /
    /// `kv_preemptions`) — the scheduler calls this every tick so the
    /// rendered metrics always show current pool pressure next to
    /// `active_sessions`.
    pub fn record_kv_pool(&self, total: u64, free: u64, in_use: u64, preemptions: u64) {
        let mut g = self.gauges.lock().unwrap_or_else(|e| e.into_inner());
        g.insert("kv_blocks_total".to_string(), total);
        g.insert("kv_blocks_free".to_string(), free);
        g.insert("kv_blocks_in_use".to_string(), in_use);
        g.insert("kv_preemptions".to_string(), preemptions);
    }

    /// Record the prefix cache's footprint and lifetime counters in one
    /// shot (`prefix_cache_blocks` / `prefix_cache_tokens` /
    /// `prefix_hits` / `prefix_misses` / `prefix_tokens_reused` /
    /// `prefix_inserted_blocks` / `prefix_evicted_blocks`) — the
    /// scheduler calls this every tick, mirroring
    /// [`Self::record_kv_pool`].
    #[allow(clippy::too_many_arguments)]
    pub fn record_prefix(
        &self,
        blocks: u64,
        tokens: u64,
        hits: u64,
        misses: u64,
        tokens_reused: u64,
        inserted_blocks: u64,
        evicted_blocks: u64,
    ) {
        let mut g = self.gauges.lock().unwrap_or_else(|e| e.into_inner());
        g.insert("prefix_cache_blocks".to_string(), blocks);
        g.insert("prefix_cache_tokens".to_string(), tokens);
        g.insert("prefix_hits".to_string(), hits);
        g.insert("prefix_misses".to_string(), misses);
        g.insert("prefix_tokens_reused".to_string(), tokens_reused);
        g.insert("prefix_inserted_blocks".to_string(), inserted_blocks);
        g.insert("prefix_evicted_blocks".to_string(), evicted_blocks);
    }

    /// Record the batched-decode gauges in one shot (`batch_occupancy` /
    /// `batched_kernel_calls` / `expert_loads_deduped` /
    /// `batched_ticks` / `mixed_ticks`) — the scheduler calls this every
    /// batched or mixed tick, mirroring [`Self::record_kv_pool`]. The
    /// counters are engine-lifetime totals, published as gauges so
    /// re-recording is idempotent.
    pub fn record_batch(
        &self,
        occupancy: u64,
        ticks: u64,
        kernel_calls: u64,
        loads_deduped: u64,
        mixed_ticks: u64,
    ) {
        let mut g = self.gauges.lock().unwrap_or_else(|e| e.into_inner());
        g.insert("batch_occupancy".to_string(), occupancy);
        g.insert("batched_ticks".to_string(), ticks);
        g.insert("batched_kernel_calls".to_string(), kernel_calls);
        g.insert("expert_loads_deduped".to_string(), loads_deduped);
        g.insert("mixed_ticks".to_string(), mixed_ticks);
    }

    /// Record the adaptive-tier gauges in one shot (`expert_hot_hits` /
    /// `tier_promotions` / `link_bytes_saved`) — the scheduler calls
    /// this every tick from the engine's lifetime [`TierStats`]
    /// (`crate::engine::TierStats`), mirroring [`Self::record_batch`].
    /// All zero for uniform (tiers-off) deployments.
    pub fn record_tiers(&self, hot_hits: u64, promotions: u64, bytes_saved: u64) {
        let mut g = self.gauges.lock().unwrap_or_else(|e| e.into_inner());
        g.insert("expert_hot_hits".to_string(), hot_hits);
        g.insert("tier_promotions".to_string(), promotions);
        g.insert("link_bytes_saved".to_string(), bytes_saved);
    }

    /// Record the fault-injection gauges in one shot (`faults_injected`
    /// / `transfer_retries`) — the scheduler calls this every tick from
    /// the engine's lifetime `FaultStats` (`crate::fault::FaultStats`),
    /// mirroring [`Self::record_tiers`]. The failure-side siblings
    /// (`requests_failed` / `deadline_cancellations`) are plain counters
    /// and deliberately NOT mirrored here: a same-named gauge would make
    /// `render()` emit two lines per name whose values can disagree
    /// between a counter increment and the next tick's mirror. Both
    /// gauges are zero in a faults-off deployment.
    pub fn record_faults(&self, injected: u64, transfer_retries: u64) {
        let mut g = self.gauges.lock().unwrap_or_else(|e| e.into_inner());
        g.insert("faults_injected".to_string(), injected);
        g.insert("transfer_retries".to_string(), transfer_retries);
    }

    /// Record the prefetch-quality gauges in one shot (`spec_recall_bp`
    /// / `spec_precision_bp`, basis points — the paper's Figure-2
    /// quantities, from the cache manager's aggregate
    /// `SpeculativeStats`) — the scheduler calls this every tick,
    /// mirroring [`Self::record_faults`]. Both read 0 until speculation
    /// has issued and resolved anything.
    pub fn record_spec(&self, recall_bp: u64, precision_bp: u64) {
        let mut g = self.gauges.lock().unwrap_or_else(|e| e.into_inner());
        g.insert("spec_recall_bp".to_string(), recall_bp);
        g.insert("spec_precision_bp".to_string(), precision_bp);
    }

    /// Every gauge name currently recorded — the done-event parity test
    /// enumerates these to lock gauges and the server's `done` schema
    /// together (see `coordinator::server::GAUGE_DONE_FIELDS`).
    pub fn gauge_names(&self) -> Vec<String> {
        self.gauges.lock().unwrap_or_else(|e| e.into_inner()).keys().cloned().collect()
    }

    /// Every histogram name currently recorded — the breakdown parity
    /// test enumerates these to lock the per-request breakdown
    /// histograms and the server's `done` schema together (see
    /// `coordinator::server::BREAKDOWN_DONE_FIELDS`).
    pub fn histogram_names(&self) -> Vec<String> {
        self.histograms.lock().unwrap_or_else(|e| e.into_inner()).keys().cloned().collect()
    }

    pub fn observe(&self, name: &str, v: f64) {
        self.histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(Histogram::latency)
            .observe(v);
    }

    /// Observe into a histogram created (on first use) by `make` instead
    /// of the default [`Histogram::latency`] bounds — e.g.
    /// `Histogram::sim_time` for virtual-timeline durations. The factory
    /// only decides the bounds of a *new* histogram; an existing one
    /// keeps its buckets.
    pub fn observe_with(&self, name: &str, v: f64, make: fn() -> Histogram) {
        self.histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(make)
            .observe(v);
    }

    pub fn histogram_mean(&self, name: &str) -> f64 {
        self.histograms
            .lock()
            .unwrap()
            .get(name)
            .map(|h| h.mean())
            .unwrap_or(0.0)
    }

    /// Approximate quantile of a named histogram (0.0 if absent) — the
    /// scrape-side counterpart of [`Histogram::quantile`].
    pub fn histogram_quantile(&self, name: &str, q: f64) -> f64 {
        self.histograms
            .lock()
            .unwrap()
            .get(name)
            .map(|h| h.quantile(q))
            .unwrap_or(0.0)
    }

    pub fn histogram_count(&self, name: &str) -> u64 {
        self.histograms
            .lock()
            .unwrap()
            .get(name)
            .map(|h| h.count())
            .unwrap_or(0)
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap_or_else(|e| e.into_inner()).iter() {
            out.push_str(&format!("{k} {v}\n"));
        }
        for (k, v) in self.gauges.lock().unwrap_or_else(|e| e.into_inner()).iter() {
            out.push_str(&format!("{k} {v}\n"));
        }
        for (k, h) in self.histograms.lock().unwrap_or_else(|e| e.into_inner()).iter() {
            out.push_str(&format!(
                "{k}_mean {:.6}\n{k}_p50 {:.6}\n{k}_p99 {:.6}\n{k}_count {}\n",
                h.mean(),
                h.quantile(0.5),
                h.quantile(0.99),
                h.count()
            ));
        }
        out
    }
}

/// Exact nearest-rank percentile over raw samples, `q` in `[0, 1]` —
/// the load harness's SLO reports quote these instead of
/// [`Histogram::quantile`] because bucket boundaries would round a
/// p99-vs-target comparison in whichever direction the bucket edge
/// fell. Empty input returns 0.0; the result is always one of the
/// samples, and is monotone in `q` (`percentile(xs, 0.5) <=
/// percentile(xs, 0.99)`).
pub fn percentile(samples: &[f64], q: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(f64::total_cmp);
    let q = q.clamp(0.0, 1.0);
    let rank = ((q * sorted.len() as f64).ceil() as usize).max(1);
    sorted[rank.min(sorted.len()) - 1]
}

/// Fixed-width table printer for experiment binaries.
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(c.len());
                } else {
                    widths.push(c.len());
                }
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths.get(i).copied().unwrap_or(c.len())))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = fmt_row(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(out.len().saturating_sub(1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_are_ordered() {
        let mut h = Histogram::latency();
        for i in 1..=1000 {
            h.observe(i as f64 * 1e-3);
        }
        assert!(h.quantile(0.5) <= h.quantile(0.99));
        assert!(h.mean() > 0.0);
        assert_eq!(h.count(), 1000);
    }

    #[test]
    fn custom_bounds_resolve_sub_100us_times() {
        // the latency() bounds start at 100µs: every smaller observation
        // lands in bucket 0 and quantiles flatten to the first bound
        let mut coarse = Histogram::latency();
        let mut fine = Histogram::sim_time();
        for i in 1..=100 {
            let v = i as f64 * 1e-7; // 0.1µs .. 10µs
            coarse.observe(v);
            fine.observe(v);
        }
        assert_eq!(coarse.quantile(0.5), coarse.quantile(0.99), "all in bucket 0");
        assert!(
            fine.quantile(0.99) > fine.quantile(0.5),
            "sim bounds must separate the tail: p50={} p99={}",
            fine.quantile(0.5),
            fine.quantile(0.99)
        );
        assert!(fine.quantile(0.5) < 1e-4);
    }

    #[test]
    fn metrics_histogram_quantile() {
        let m = Metrics::new();
        assert_eq!(m.histogram_quantile("missing", 0.5), 0.0);
        for i in 1..=1000 {
            m.observe("lat", i as f64 * 1e-3);
        }
        let p50 = m.histogram_quantile("lat", 0.5);
        let p99 = m.histogram_quantile("lat", 0.99);
        assert!(p50 > 0.0 && p50 <= p99, "p50={p50} p99={p99}");
        assert!(m.histogram_quantile("lat", 1.0) >= p99);
        assert_eq!(m.histogram_count("lat"), 1000);
        assert_eq!(m.histogram_count("missing"), 0);
    }

    #[test]
    fn observe_with_uses_factory_bounds_once() {
        let m = Metrics::new();
        m.observe_with("sim", 5e-7, Histogram::sim_time);
        m.observe_with("sim", 2e-6, Histogram::sim_time);
        // fine bounds resolve the two observations into different buckets
        assert!(m.histogram_quantile("sim", 0.25) < m.histogram_quantile("sim", 0.99));
        // an existing histogram keeps its buckets even via plain observe
        m.observe("sim", 3e-6);
        assert_eq!(m.histogram_count("sim"), 3);
    }

    #[test]
    fn metrics_counters() {
        let m = Metrics::new();
        m.inc("requests", 1);
        m.inc("requests", 2);
        assert_eq!(m.counter("requests"), 3);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn metrics_gauges_overwrite() {
        let m = Metrics::new();
        m.set_gauge("active_sessions", 3);
        m.set_gauge("active_sessions", 1);
        assert_eq!(m.gauge("active_sessions"), 1);
        assert_eq!(m.gauge("missing"), 0);
        assert!(m.render().contains("active_sessions 1"));
    }

    #[test]
    fn kv_pool_gauges_record_together() {
        let m = Metrics::new();
        m.record_kv_pool(16, 11, 5, 2);
        assert_eq!(m.gauge("kv_blocks_total"), 16);
        assert_eq!(m.gauge("kv_blocks_free"), 11);
        assert_eq!(m.gauge("kv_blocks_in_use"), 5);
        assert_eq!(m.gauge("kv_preemptions"), 2);
        let r = m.render();
        assert!(r.contains("kv_blocks_in_use 5"));
    }

    #[test]
    fn prefix_gauges_record_together() {
        let m = Metrics::new();
        m.record_prefix(4, 128, 3, 1, 96, 6, 2);
        assert_eq!(m.gauge("prefix_cache_blocks"), 4);
        assert_eq!(m.gauge("prefix_cache_tokens"), 128);
        assert_eq!(m.gauge("prefix_hits"), 3);
        assert_eq!(m.gauge("prefix_misses"), 1);
        assert_eq!(m.gauge("prefix_tokens_reused"), 96);
        assert_eq!(m.gauge("prefix_inserted_blocks"), 6);
        assert_eq!(m.gauge("prefix_evicted_blocks"), 2);
        assert!(m.render().contains("prefix_tokens_reused 96"));
    }

    #[test]
    fn batch_gauges_record_together() {
        let m = Metrics::new();
        m.record_batch(4, 10, 120, 36, 7);
        assert_eq!(m.gauge("batch_occupancy"), 4);
        assert_eq!(m.gauge("batched_ticks"), 10);
        assert_eq!(m.gauge("batched_kernel_calls"), 120);
        assert_eq!(m.gauge("expert_loads_deduped"), 36);
        assert_eq!(m.gauge("mixed_ticks"), 7);
        assert!(m.render().contains("expert_loads_deduped 36"));
    }

    #[test]
    fn tier_gauges_record_together() {
        let m = Metrics::new();
        m.record_tiers(42, 3, 9000);
        assert_eq!(m.gauge("expert_hot_hits"), 42);
        assert_eq!(m.gauge("tier_promotions"), 3);
        assert_eq!(m.gauge("link_bytes_saved"), 9000);
        assert!(m.render().contains("link_bytes_saved 9000"));
    }

    #[test]
    fn fault_gauges_record_together() {
        let m = Metrics::new();
        m.record_faults(9, 6);
        assert_eq!(m.gauge("faults_injected"), 9);
        assert_eq!(m.gauge("transfer_retries"), 6);
        assert!(m.render().contains("transfer_retries 6"));
    }

    #[test]
    fn spec_gauges_record_together() {
        let m = Metrics::new();
        m.record_spec(7500, 6000);
        assert_eq!(m.gauge("spec_recall_bp"), 7500);
        assert_eq!(m.gauge("spec_precision_bp"), 6000);
        assert!(m.render().contains("spec_recall_bp 7500"));
    }

    /// The failure counters must never gain gauge mirrors: render()
    /// would emit two lines with the same metric name whose values can
    /// disagree between the counter increment and the next tick's
    /// mirror (every rendered name must be unique).
    #[test]
    fn failure_counters_have_no_gauge_mirrors() {
        let m = Metrics::new();
        m.inc("requests_failed", 2);
        m.inc("deadline_cancellations", 1);
        m.record_faults(9, 6);
        for name in ["requests_failed", "deadline_cancellations"] {
            assert!(
                !m.gauge_names().iter().any(|n| n == name),
                "{name} must stay a counter, not a gauge"
            );
            let rendered = m.render();
            assert_eq!(
                rendered.lines().filter(|l| l.starts_with(&format!("{name} "))).count(),
                1,
                "{name} must render exactly once"
            );
        }
    }

    #[test]
    fn gauge_names_enumerate_recorded_gauges() {
        let m = Metrics::new();
        assert!(m.gauge_names().is_empty());
        m.set_gauge("active_sessions", 1);
        m.record_batch(1, 1, 1, 1, 1);
        let names = m.gauge_names();
        assert!(names.iter().any(|n| n == "active_sessions"));
        assert!(names.iter().any(|n| n == "mixed_ticks"));
        assert_eq!(names.len(), 6);
    }

    #[test]
    fn metrics_render_contains_entries() {
        let m = Metrics::new();
        m.inc("tokens", 5);
        m.observe("latency", 0.01);
        let r = m.render();
        assert!(r.contains("tokens 5"));
        assert!(r.contains("latency_mean"));
    }

    #[test]
    fn percentile_nearest_rank_edges() {
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[3.0], 0.0), 3.0);
        assert_eq!(percentile(&[3.0], 1.0), 3.0);
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.5), 50.0);
        assert_eq!(percentile(&xs, 0.99), 99.0);
        assert_eq!(percentile(&xs, 1.0), 100.0);
        // unsorted input sorts internally; result is always a sample
        let ys = [5.0, 1.0, 9.0, 3.0];
        assert_eq!(percentile(&ys, 0.5), 3.0);
        assert!(ys.contains(&percentile(&ys, 0.75)));
        // monotone in q
        assert!(percentile(&ys, 0.5) <= percentile(&ys, 0.99));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["longer".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("name"));
        assert!(r.lines().count() == 4);
    }
}
