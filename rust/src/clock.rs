//! Virtual timeline for the discrete-event hardware model.
//!
//! Two serial resources model the paper's hardware: the GPU compute stream
//! and the host→device copy stream (PCIe). Work reserved on one resource
//! overlaps freely with the other — exactly the property speculative
//! expert loading exploits (§3.2: transfers hidden behind the previous
//! layer's compute). A third notion, `now`, tracks the sequential decode
//! front: compute for step N+1 cannot begin before its inputs exist.
//!
//! All times are f64 seconds. The timeline is deterministic: timing depends
//! only on the sequence of reservations, never on wall-clock.

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Resource {
    Gpu,
    Link,
}

#[derive(Debug, Clone)]
pub struct Timeline {
    now: f64,
    gpu_free: f64,
    link_free: f64,
    // accounting
    pub gpu_busy: f64,
    pub link_busy: f64,
    pub gpu_ops: u64,
    pub transfers: u64,
}

#[derive(Debug, Clone, Copy)]
pub struct Span {
    pub start: f64,
    pub end: f64,
}

impl Span {
    pub fn dur(&self) -> f64 {
        self.end - self.start
    }

    /// Seconds of this span falling inside `[lo, hi)` — the clipped
    /// overlap the trace analysis sums into utilization windows.
    pub fn overlap(&self, lo: f64, hi: f64) -> f64 {
        (self.end.min(hi) - self.start.max(lo)).max(0.0)
    }
}

impl Default for Timeline {
    fn default() -> Self {
        Self::new()
    }
}

impl Timeline {
    pub fn new() -> Self {
        Timeline {
            now: 0.0,
            gpu_free: 0.0,
            link_free: 0.0,
            gpu_busy: 0.0,
            link_busy: 0.0,
            gpu_ops: 0,
            transfers: 0,
        }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Reserve `dur` seconds on a resource, starting no earlier than
    /// max(resource_free, `not_before`). Returns the span. Does NOT move
    /// `now` — callers decide what the decode front waits on.
    pub fn reserve(&mut self, res: Resource, dur: f64, not_before: f64) -> Span {
        assert!(dur >= 0.0 && dur.is_finite(), "bad duration {dur}");
        let free = match res {
            Resource::Gpu => &mut self.gpu_free,
            Resource::Link => &mut self.link_free,
        };
        let start = free.max(not_before);
        let end = start + dur;
        *free = end;
        match res {
            Resource::Gpu => {
                self.gpu_busy += dur;
                self.gpu_ops += 1;
            }
            Resource::Link => {
                self.link_busy += dur;
                self.transfers += 1;
            }
        }
        Span { start, end }
    }

    /// Reserve GPU work that the decode front depends on: starts at
    /// max(gpu_free, now, extra_dep) and advances `now` to its end.
    pub fn compute(&mut self, dur: f64, extra_dep: f64) -> Span {
        let dep = self.now.max(extra_dep);
        let span = self.reserve(Resource::Gpu, dur, dep);
        self.now = span.end;
        span
    }

    /// Reserve a transfer whose completion others may wait on; `now` is
    /// unaffected (transfers overlap the decode front).
    pub fn transfer(&mut self, dur: f64, not_before: f64) -> Span {
        self.reserve(Resource::Link, dur, not_before.max(self.now_floor()))
    }

    /// Block the decode front until `t` (e.g. waiting for a demand-load).
    pub fn wait_until(&mut self, t: f64) {
        if t > self.now {
            self.now = t;
        }
    }

    fn now_floor(&self) -> f64 {
        // transfers can be issued as soon as the decision is known, which
        // is never later than the decode front
        0.0
    }

    /// Utilization of the link up to `now` (diagnostics).
    pub fn link_utilization(&self) -> f64 {
        if self.now <= 0.0 {
            0.0
        } else {
            (self.link_busy / self.now).min(1.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, ensure};

    #[test]
    fn compute_is_sequential() {
        let mut t = Timeline::new();
        let a = t.compute(1.0, 0.0);
        let b = t.compute(2.0, 0.0);
        assert_eq!(a.end, 1.0);
        assert_eq!(b.start, 1.0);
        assert_eq!(b.end, 3.0);
        assert_eq!(t.now(), 3.0);
    }

    #[test]
    fn transfer_overlaps_compute() {
        let mut t = Timeline::new();
        let c = t.compute(5.0, 0.0);
        let x = t.transfer(2.0, 0.0);
        // transfer runs during the compute span
        assert!(x.start < c.end);
        assert_eq!(t.now(), 5.0); // decode front unaffected by transfer
    }

    #[test]
    fn dependent_compute_waits_for_transfer() {
        let mut t = Timeline::new();
        let x = t.transfer(3.0, 0.0);
        t.wait_until(x.end);
        let c = t.compute(1.0, 0.0);
        assert_eq!(c.start, 3.0);
        assert_eq!(c.end, 4.0);
    }

    #[test]
    fn link_serializes_transfers() {
        let mut t = Timeline::new();
        let a = t.transfer(2.0, 0.0);
        let b = t.transfer(2.0, 0.0);
        assert_eq!(a.end, 2.0);
        assert_eq!(b.start, 2.0);
    }

    #[test]
    fn not_before_is_respected() {
        let mut t = Timeline::new();
        let x = t.transfer(1.0, 10.0);
        assert_eq!(x.start, 10.0);
    }

    #[test]
    fn prop_monotone_and_non_overlapping_per_resource() {
        check(
            "timeline-invariants",
            100,
            |r| {
                (0..30)
                    .map(|_| (r.below(3), r.f64() * 2.0, r.f64() * 5.0))
                    .collect::<Vec<_>>()
            },
            |ops| {
                let mut t = Timeline::new();
                let mut last_gpu_end = 0.0f64;
                let mut last_link_end = 0.0f64;
                let mut last_now = 0.0f64;
                for &(kind, dur, dep) in ops {
                    match kind {
                        0 => {
                            let s = t.compute(dur, dep);
                            ensure(s.start >= last_gpu_end - 1e-12, "gpu overlap")?;
                            last_gpu_end = s.end;
                        }
                        1 => {
                            let s = t.transfer(dur, dep);
                            ensure(s.start >= last_link_end - 1e-12, "link overlap")?;
                            last_link_end = s.end;
                        }
                        _ => t.wait_until(dep),
                    }
                    ensure(t.now() >= last_now - 1e-12, "now went backwards")?;
                    last_now = t.now();
                }
                Ok(())
            },
        );
    }

    #[test]
    fn link_utilization_ratio_and_edge_cases() {
        let mut t = Timeline::new();
        assert_eq!(t.link_utilization(), 0.0, "no time elapsed yet");
        t.compute(4.0, 0.0);
        assert_eq!(t.link_utilization(), 0.0, "no transfers yet");
        t.transfer(1.0, 0.0);
        // 1s of link busy across 4s of decode front
        assert!((t.link_utilization() - 0.25).abs() < 1e-12);
        // a transfer tail past `now` still clamps to 1.0
        t.transfer(100.0, 0.0);
        assert_eq!(t.link_utilization(), 1.0);
    }

    #[test]
    fn reserve_orders_spans_per_resource_only() {
        let mut t = Timeline::new();
        let g1 = t.reserve(Resource::Gpu, 2.0, 0.0);
        let l1 = t.reserve(Resource::Link, 3.0, 0.0);
        let g2 = t.reserve(Resource::Gpu, 1.0, 0.0);
        // same-resource reservations serialize...
        assert_eq!(g1.end, 2.0);
        assert_eq!(g2.start, 2.0);
        // ...but the two resources never queue behind each other
        assert_eq!(l1.start, 0.0);
        assert_eq!(l1.end, 3.0);
        // reserve never moves the decode front
        assert_eq!(t.now(), 0.0);
    }

    #[test]
    fn reserve_not_before_leaves_idle_gap() {
        let mut t = Timeline::new();
        let a = t.reserve(Resource::Link, 1.0, 5.0);
        assert_eq!(a.start, 5.0);
        // the gap is dead time: the next unconstrained reservation starts
        // at the resource's free edge, not back in the gap
        let b = t.reserve(Resource::Link, 1.0, 0.0);
        assert_eq!(b.start, 6.0);
        // busy accounting counts durations, not elapsed span
        assert!((t.link_busy - 2.0).abs() < 1e-12);
        assert_eq!(t.transfers, 2);
    }

    #[test]
    fn dependent_chain_through_not_before() {
        // transfer -> dependent transfer -> dependent compute, linked
        // purely through span ends
        let mut t = Timeline::new();
        let a = t.transfer(2.0, 0.0);
        let b = t.transfer(1.0, a.end + 1.0); // waits past a deliberately
        assert_eq!(b.start, 3.0);
        let c = t.compute(1.0, b.end);
        assert_eq!(c.start, 4.0);
        assert_eq!(t.now(), 5.0);
    }

    #[test]
    fn overlap_accounting_compute_hides_transfer() {
        // the §3.2 shape: a transfer issued under a longer compute span
        // is fully hidden — the decode front never stalls, but link_busy
        // still records the transfer's duration
        let mut t = Timeline::new();
        let c = t.compute(5.0, 0.0);
        let x = t.transfer(2.0, 0.0);
        assert!(x.end <= c.end, "transfer hidden under compute");
        t.wait_until(x.end); // no-op: decode front is already past it
        assert_eq!(t.now(), 5.0);
        assert!((t.gpu_busy - 5.0).abs() < 1e-12);
        assert!((t.link_busy - 2.0).abs() < 1e-12);
        assert!((t.link_utilization() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn span_dur_and_overlap_clip() {
        let s = Span { start: 1.0, end: 4.0 };
        assert_eq!(s.dur(), 3.0);
        assert_eq!(s.overlap(0.0, 10.0), 3.0); // fully inside
        assert_eq!(s.overlap(2.0, 3.0), 1.0); // window inside span
        assert_eq!(s.overlap(0.0, 2.0), 1.0); // clipped left
        assert_eq!(s.overlap(3.5, 9.0), 0.5); // clipped right
        assert_eq!(s.overlap(5.0, 9.0), 0.0); // disjoint
    }

    #[test]
    fn busy_accounting_sums_durations() {
        let mut t = Timeline::new();
        t.compute(1.5, 0.0);
        t.compute(0.5, 0.0);
        t.transfer(2.0, 0.0);
        assert!((t.gpu_busy - 2.0).abs() < 1e-12);
        assert!((t.link_busy - 2.0).abs() < 1e-12);
        assert_eq!(t.gpu_ops, 2);
        assert_eq!(t.transfers, 1);
    }
}
