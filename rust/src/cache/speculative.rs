//! Speculative expert loading statistics (paper §3.2 / Fig 2 right).
//!
//! The guess itself is computed by the engine (it re-runs the *next*
//! layer's gate on the *current* layer's pre-MoE hidden state); this module
//! tracks guess quality: recall = fraction of actually-needed experts that
//! had been speculatively loaded.

#[derive(Debug, Clone, Default)]
pub struct SpeculativeStats {
    /// Experts speculatively fetched.
    pub issued: u64,
    /// Speculative fetches that were already resident / in flight anyway.
    pub redundant: u64,
    /// Needed experts that a speculative fetch made available.
    pub useful: u64,
    /// Needed experts not covered by speculation (demand loads).
    pub missed: u64,
}

impl SpeculativeStats {
    pub fn recall(&self) -> f64 {
        let total = self.useful + self.missed;
        if total == 0 {
            0.0
        } else {
            self.useful as f64 / total as f64
        }
    }

    /// Fraction of issued speculative transfers that turned out useful.
    pub fn precision(&self) -> f64 {
        if self.issued == 0 {
            0.0
        } else {
            self.useful as f64 / self.issued as f64
        }
    }

    pub fn merge(&mut self, other: &SpeculativeStats) {
        self.issued += other.issued;
        self.redundant += other.redundant;
        self.useful += other.useful;
        self.missed += other.missed;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recall_and_precision() {
        let s = SpeculativeStats { issued: 10, redundant: 1, useful: 6, missed: 2 };
        assert!((s.recall() - 0.75).abs() < 1e-12);
        assert!((s.precision() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn empty_stats_are_zero() {
        let s = SpeculativeStats::default();
        assert_eq!(s.recall(), 0.0);
        assert_eq!(s.precision(), 0.0);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = SpeculativeStats { issued: 1, redundant: 0, useful: 1, missed: 0 };
        let b = SpeculativeStats { issued: 3, redundant: 1, useful: 1, missed: 1 };
        a.merge(&b);
        assert_eq!(a.issued, 4);
        assert_eq!(a.useful, 2);
        assert!((a.recall() - 2.0 / 3.0).abs() < 1e-12);
    }
}
