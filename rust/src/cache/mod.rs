//! Expert caching: per-layer LRU (paper §3.1) and the speculative
//! prefetcher (paper §3.2), composed by the cache manager.

pub mod lru;
pub mod manager;
pub mod speculative;

pub use lru::LruSet;
pub use manager::{CacheEvent, CacheManager, CacheStats};
pub use speculative::SpeculativeStats;
