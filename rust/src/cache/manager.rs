//! The expert cache manager — composes the per-layer LRU cache (§3.1),
//! the speculative-load buffers (§3.2) and device memory accounting into
//! the placement policy the engine drives.
//!
//! Placement rules (paper §3.3 "Expert Offloading"):
//! * each MoE layer keeps its own k-way LRU of experts;
//! * speculatively loaded experts land in shared buffers and do NOT evict
//!   cached experts until actually used; when used, they are promoted into
//!   the layer's cache, evicting that layer's LRU entry;
//! * evicting an expert just drops the device copy (host keeps masters);
//! * k = 0 models the cache-less ablation: demand loads are transient and
//!   freed right after use.
//!
//! Batched decode adds tick-scoped *pinning*: an expert staged for the
//! current layer-tick is [`CacheManager::pin`]ned so that no eviction
//! path can drop its device copy before every routed session has
//! consumed it (the mid-tick eviction hazard). A pinned victim keeps its
//! device copy — the bookkeeping eviction is deferred and settled by
//! [`CacheManager::unpin_all`] at the end of the tick. The engine's
//! batched path additionally AVOIDS the hazard structurally (it only
//! batch-stages a union that fits the layer cache, and interleaves
//! load/run otherwise), so the pin is the enforced invariant backing
//! that reasoning: if a future eviction path or placement change does
//! reach a staged-but-unconsumed expert, the batch still computes
//! correctly instead of failing or silently re-staging.

use std::collections::{BTreeMap, HashSet, VecDeque};

use crate::cache::lru::LruSet;
use crate::cache::speculative::SpeculativeStats;
use crate::error::Result;
use crate::memory::device::{DeviceExpert, DeviceMemory};
use crate::memory::host::ExpertId;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lookup {
    /// In the layer's LRU cache.
    InCache,
    /// Resident via an (unclaimed) speculative load.
    InSpec,
    Absent,
}

#[derive(Debug, Clone, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub spec: SpeculativeStats,
    pub evictions: u64,
    /// per-layer (hits, uses)
    pub per_layer: Vec<(u64, u64)>,
    /// Per-layer speculation quality (paper Fig 2: recall/precision vary
    /// strongly by depth). Merges element-wise into the aggregate `spec`.
    pub spec_per_layer: Vec<SpeculativeStats>,
}

impl CacheStats {
    pub fn hit_ratio(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Event log entry (drives Fig 1's cache overlay + Fig 2 evaluations).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheEvent {
    Hit(ExpertId),
    SpecHit(ExpertId),
    Miss(ExpertId),
}

/// One flight-recorder log entry: a residency-affecting cache transition,
/// appended (only while [`CacheManager::set_obs_log`] is on) for the
/// engine to drain into [`crate::obs::ExpertObs`]. `Evict` is a
/// *consequence* of the measured cache size (LRU victim, spec-buffer
/// shed, transient release) and is excluded from the counterfactual
/// replay stream; `Drop` is an exogenous forced drop (tier
/// invalidation) that the simulator replays at every cache size.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheLog {
    /// Routed demand use and its measured outcome.
    Use(CacheEvent),
    /// Demand-loaded residency established ([`CacheManager::insert_loaded`]).
    Insert(ExpertId),
    /// Speculative residency established (redundant inserts excluded).
    SpecInsert(ExpertId),
    /// Bookkeeping eviction — LRU victim, spec shed, or transient free.
    Evict(ExpertId),
    /// Forced drop everywhere ([`CacheManager::drop_expert`]).
    Drop(ExpertId),
}

pub struct CacheManager {
    layers: Vec<LruSet<u16>>,
    /// Unclaimed speculative loads, oldest first (bounded by spec_cap).
    spec_resident: VecDeque<ExpertId>,
    spec_cap: usize,
    /// Experts pinned for the current batched layer-tick: their device
    /// copies may not be dropped until [`Self::unpin_all`].
    pinned: HashSet<ExpertId>,
    /// Device evictions deferred because the victim was pinned; settled
    /// by [`Self::unpin_all`].
    deferred_evict: Vec<ExpertId>,
    /// Bit-width each RESIDENT expert was staged at (16 = fp). The tier
    /// machinery compares this against the expert's current tier to
    /// catch stale-precision copies after a promotion/demotion.
    resident_bits: BTreeMap<ExpertId, u8>,
    /// Flight-recorder log, appended only while `obs_log` is on (off:
    /// every push site is a branch on a bool and the Vec never
    /// allocates). The engine drains it with [`Self::take_obs_log`].
    obs_log: Vec<CacheLog>,
    obs_log_on: bool,
    pub device: DeviceMemory,
    pub stats: CacheStats,
}

impl CacheManager {
    pub fn new(n_layers: usize, cache_k: usize, spec_cap: usize, device: DeviceMemory) -> Self {
        CacheManager {
            layers: (0..n_layers).map(|_| LruSet::new(cache_k)).collect(),
            spec_resident: VecDeque::new(),
            spec_cap,
            pinned: HashSet::new(),
            deferred_evict: Vec::new(),
            resident_bits: BTreeMap::new(),
            obs_log: Vec::new(),
            obs_log_on: false,
            device,
            stats: CacheStats {
                per_layer: vec![(0, 0); n_layers],
                spec_per_layer: vec![SpeculativeStats::default(); n_layers],
                ..Default::default()
            },
        }
    }

    /// Turn the flight-recorder log on/off (off by default; the engine
    /// enables it when `ServingConfig::expert_obs` is set).
    pub fn set_obs_log(&mut self, on: bool) {
        self.obs_log_on = on;
    }

    /// Drain the pending flight-recorder log (empty while logging is off).
    pub fn take_obs_log(&mut self) -> Vec<CacheLog> {
        std::mem::take(&mut self.obs_log)
    }

    #[inline]
    fn log(&mut self, ev: CacheLog) {
        if self.obs_log_on {
            self.obs_log.push(ev);
        }
    }

    pub fn cache_k(&self) -> usize {
        self.layers.first().map(|l| l.capacity()).unwrap_or(0)
    }

    pub fn lookup(&self, id: ExpertId) -> Lookup {
        if self.layers[id.layer as usize].contains(&id.expert) {
            Lookup::InCache
        } else if self.spec_resident.contains(&id) {
            Lookup::InSpec
        } else {
            Lookup::Absent
        }
    }

    /// Record a demand use of `id`. Mutates LRU order / promotes
    /// speculative entries and updates stats. The caller handles `Miss` by
    /// loading the expert and calling [`insert_loaded`].
    pub fn on_demand_use(&mut self, id: ExpertId) -> CacheEvent {
        let li = id.layer as usize;
        self.stats.per_layer[li].1 += 1;
        let ev = match self.lookup(id) {
            Lookup::InCache => {
                self.layers[li].touch(id.expert);
                self.stats.hits += 1;
                self.stats.per_layer[li].0 += 1;
                CacheEvent::Hit(id)
            }
            Lookup::InSpec => {
                // promote: leave device residency, move bookkeeping into
                // the layer cache (paper: replaces that layer's LRU entry)
                self.spec_resident.retain(|x| *x != id);
                self.layers[li].count_use(id.expert, true);
                self.insert_into_layer(id);
                self.stats.spec.useful += 1;
                self.stats.spec_per_layer[li].useful += 1;
                // a spec hit avoided a miss; count as hit for hit-ratio of
                // the *combined* system but track separately too
                self.stats.hits += 1;
                self.stats.per_layer[li].0 += 1;
                CacheEvent::SpecHit(id)
            }
            Lookup::Absent => {
                self.layers[li].count_use(id.expert, false);
                self.stats.misses += 1;
                self.stats.spec.missed += 1;
                self.stats.spec_per_layer[li].missed += 1;
                CacheEvent::Miss(id)
            }
        };
        self.log(CacheLog::Use(ev));
        ev
    }

    /// Install a demand-loaded expert (after the transfer completed).
    pub fn insert_loaded(&mut self, id: ExpertId, e: DeviceExpert) -> Result<()> {
        self.ensure_headroom()?;
        let bits = e.quant_bits();
        self.device.insert(id, e)?;
        self.resident_bits.insert(id, bits);
        self.log(CacheLog::Insert(id));
        self.insert_into_layer(id);
        Ok(())
    }

    /// Install a speculatively loaded expert into the shared buffers.
    /// Oldest unclaimed speculative entry is dropped when full.
    pub fn insert_speculative(&mut self, id: ExpertId, e: DeviceExpert) -> Result<()> {
        let li = id.layer as usize;
        if self.lookup(id) != Lookup::Absent {
            self.stats.spec.redundant += 1;
            self.stats.spec_per_layer[li].redundant += 1;
            return Ok(());
        }
        while self.spec_resident.len() >= self.spec_cap.max(1) {
            if let Some(old) = self.spec_resident.pop_front() {
                self.evict_or_defer(old);
                self.stats.evictions += 1;
                self.log(CacheLog::Evict(old));
            }
        }
        self.ensure_headroom()?;
        let bits = e.quant_bits();
        self.device.insert(id, e)?;
        self.resident_bits.insert(id, bits);
        self.spec_resident.push_back(id);
        self.stats.spec.issued += 1;
        self.stats.spec_per_layer[li].issued += 1;
        self.log(CacheLog::SpecInsert(id));
        Ok(())
    }

    /// For k = 0 (cache-less ablation): free a transiently loaded expert
    /// right after use.
    pub fn release_transient(&mut self, id: ExpertId) {
        let li = id.layer as usize;
        if self.layers[li].capacity() == 0 && !self.spec_resident.contains(&id) {
            self.evict_or_defer(id);
            self.log(CacheLog::Evict(id));
        }
    }

    /// Layer-cache insert + device eviction of whatever LRU fell out.
    fn insert_into_layer(&mut self, id: ExpertId) {
        let li = id.layer as usize;
        if let Some(evicted) = self.layers[li].insert(id.expert) {
            self.evict_or_defer(ExpertId { layer: id.layer, expert: evicted });
            self.stats.evictions += 1;
            self.log(CacheLog::Evict(ExpertId { layer: id.layer, expert: evicted }));
        }
    }

    /// Make sure at least one expert slot is free (spec buffers may be
    /// holding stale entries when device budget is tight).
    fn ensure_headroom(&mut self) -> Result<()> {
        while self.device.resident_count() + 1 > self.device.expert_capacity() {
            match self.spec_resident.pop_front() {
                Some(old) => {
                    self.evict_or_defer(old);
                    self.stats.evictions += 1;
                    self.log(CacheLog::Evict(old));
                }
                None => break, // let device.insert surface the OOM
            }
        }
        Ok(())
    }

    // ---------------------------------------------------------------------
    // tick-scoped pinning (batched decode)
    // ---------------------------------------------------------------------

    /// Pin `id` for the current layer-tick: its device copy survives any
    /// bookkeeping eviction until [`Self::unpin_all`]. The batched decode
    /// path pins the whole routed-expert union right after staging it, so
    /// staging expert B for one batch neighbor can never drop expert A
    /// before another neighbor's rows ran through it.
    pub fn pin(&mut self, id: ExpertId) {
        self.pinned.insert(id);
    }

    pub fn is_pinned(&self, id: ExpertId) -> bool {
        self.pinned.contains(&id)
    }

    /// End the tick: release every pin and settle deferred evictions —
    /// a deferred victim that was not re-admitted meanwhile loses its
    /// device copy now. (Deferral can hold the device over its expert
    /// budget for the tick's duration, bounded by the batch's routed
    /// union; the accounting settles here.)
    pub fn unpin_all(&mut self) {
        self.pinned.clear();
        let deferred = std::mem::take(&mut self.deferred_evict);
        for id in deferred {
            if self.lookup(id) == Lookup::Absent {
                self.device.evict(id);
                self.resident_bits.remove(&id);
            }
        }
    }

    /// Drop `id`'s device copy — unless it is pinned for the current
    /// tick, in which case the drop is deferred to [`Self::unpin_all`].
    /// (Callers count `stats.evictions` themselves, exactly where the
    /// pre-pinning code did, so stats are unchanged when nothing is
    /// pinned.)
    fn evict_or_defer(&mut self, id: ExpertId) {
        if self.pinned.contains(&id) {
            self.deferred_evict.push(id);
        } else {
            self.device.evict(id);
            self.resident_bits.remove(&id);
        }
    }

    // ---------------------------------------------------------------------
    // per-expert precision tiers
    // ---------------------------------------------------------------------

    /// Bit-width `id`'s resident device copy was staged at, if resident.
    /// The engine compares this to the expert's CURRENT tier bits: a
    /// mismatch means a stale-precision copy that must be re-staged.
    pub fn resident_bits_of(&self, id: ExpertId) -> Option<u8> {
        if self.device.contains(id) {
            self.resident_bits.get(&id).copied()
        } else {
            None
        }
    }

    /// Force-drop `id` everywhere: layer LRU, speculative buffers, device
    /// copy, staged-bits record. Used when a tier change invalidates the
    /// resident precision. Callers must not hold tick pins on `id` (the
    /// engine re-tiers only at tick boundaries, after `unpin_all`).
    pub fn drop_expert(&mut self, id: ExpertId) {
        self.layers[id.layer as usize].remove(&id.expert);
        self.spec_resident.retain(|x| *x != id);
        if self.device.evict(id).is_some() {
            self.stats.evictions += 1;
        }
        self.resident_bits.remove(&id);
        self.log(CacheLog::Drop(id));
    }

    /// Lifetime per-expert (hits, routed uses) aggregated from every
    /// layer's LRU counters — the tier policy's online hotness signal.
    /// Eviction-proof: counters persist after the expert leaves the
    /// cache, so rarely-routed experts keep their (low) scores.
    pub fn expert_counters(&self) -> Vec<(ExpertId, u64, u64)> {
        let mut out = Vec::new();
        for (li, layer) in self.layers.iter().enumerate() {
            for (expert, hits, uses) in layer.counters() {
                out.push((ExpertId { layer: li as u16, expert }, hits, uses));
            }
        }
        out
    }

    /// Cached experts of a layer, MRU first (Fig 1 overlay).
    pub fn cached_of_layer(&self, layer: usize) -> Vec<u16> {
        self.layers[layer].iter_mru().copied().collect()
    }

    pub fn spec_resident_ids(&self) -> impl Iterator<Item = &ExpertId> {
        self.spec_resident.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn dummy() -> DeviceExpert {
        DeviceExpert::Fp {
            w1: Tensor::zeros(vec![1, 1]),
            w3: Tensor::zeros(vec![1, 1]),
            w2: Tensor::zeros(vec![1, 1]),
        }
    }

    fn mgr(k: usize, spec_cap: usize, cap_experts: u64) -> CacheManager {
        let device = DeviceMemory::new(cap_experts * 100, 0, 100);
        CacheManager::new(2, k, spec_cap, device)
    }

    fn id(l: usize, e: usize) -> ExpertId {
        ExpertId::new(l, e)
    }

    #[test]
    fn miss_then_hit() {
        let mut m = mgr(2, 4, 16);
        assert_eq!(m.on_demand_use(id(0, 3)), CacheEvent::Miss(id(0, 3)));
        m.insert_loaded(id(0, 3), dummy()).unwrap();
        assert_eq!(m.on_demand_use(id(0, 3)), CacheEvent::Hit(id(0, 3)));
        assert_eq!(m.stats.hits, 1);
        assert_eq!(m.stats.misses, 1);
    }

    #[test]
    fn lru_eviction_frees_device() {
        let mut m = mgr(1, 4, 16);
        m.insert_loaded(id(0, 1), dummy()).unwrap();
        m.insert_loaded(id(0, 2), dummy()).unwrap(); // evicts expert 1
        assert!(!m.device.contains(id(0, 1)));
        assert!(m.device.contains(id(0, 2)));
        assert_eq!(m.lookup(id(0, 1)), Lookup::Absent);
    }

    #[test]
    fn layers_are_independent() {
        let mut m = mgr(1, 4, 16);
        m.insert_loaded(id(0, 1), dummy()).unwrap();
        m.insert_loaded(id(1, 1), dummy()).unwrap();
        assert_eq!(m.lookup(id(0, 1)), Lookup::InCache);
        assert_eq!(m.lookup(id(1, 1)), Lookup::InCache);
    }

    #[test]
    fn speculative_promotion() {
        let mut m = mgr(1, 4, 16);
        m.insert_loaded(id(0, 5), dummy()).unwrap();
        m.insert_speculative(id(0, 7), dummy()).unwrap();
        // spec expert does NOT evict the cached one until used
        assert_eq!(m.lookup(id(0, 5)), Lookup::InCache);
        assert_eq!(m.lookup(id(0, 7)), Lookup::InSpec);
        // using it promotes + evicts the LRU cache entry
        assert_eq!(m.on_demand_use(id(0, 7)), CacheEvent::SpecHit(id(0, 7)));
        assert_eq!(m.lookup(id(0, 7)), Lookup::InCache);
        assert_eq!(m.lookup(id(0, 5)), Lookup::Absent);
        assert_eq!(m.stats.spec.useful, 1);
    }

    #[test]
    fn spec_buffers_bounded() {
        let mut m = mgr(1, 2, 16);
        m.insert_speculative(id(0, 1), dummy()).unwrap();
        m.insert_speculative(id(0, 2), dummy()).unwrap();
        m.insert_speculative(id(0, 3), dummy()).unwrap(); // drops oldest
        assert_eq!(m.lookup(id(0, 1)), Lookup::Absent);
        assert_eq!(m.lookup(id(0, 2)), Lookup::InSpec);
        assert_eq!(m.lookup(id(0, 3)), Lookup::InSpec);
    }

    #[test]
    fn redundant_speculation_is_counted_not_duplicated() {
        let mut m = mgr(1, 4, 16);
        m.insert_loaded(id(0, 1), dummy()).unwrap();
        m.insert_speculative(id(0, 1), dummy()).unwrap();
        assert_eq!(m.stats.spec.redundant, 1);
        assert_eq!(m.device.resident_count(), 1);
    }

    #[test]
    fn k0_transient_release() {
        let mut m = mgr(0, 4, 16);
        assert_eq!(m.on_demand_use(id(0, 2)), CacheEvent::Miss(id(0, 2)));
        m.insert_loaded(id(0, 2), dummy()).unwrap();
        m.release_transient(id(0, 2));
        assert!(!m.device.contains(id(0, 2)));
        // and it never hits
        assert_eq!(m.on_demand_use(id(0, 2)), CacheEvent::Miss(id(0, 2)));
    }

    #[test]
    fn tight_device_budget_sheds_spec_buffers() {
        let mut m = mgr(1, 4, 3); // device fits only 3 experts
        m.insert_loaded(id(0, 1), dummy()).unwrap();
        m.insert_loaded(id(1, 1), dummy()).unwrap();
        m.insert_speculative(id(0, 2), dummy()).unwrap();
        // a new demand load must shed the spec entry, not OOM; layer 1's
        // k=1 LRU also evicts (1,1) when (1,2) is installed.
        m.insert_loaded(id(1, 2), dummy()).unwrap();
        assert_eq!(m.device.resident_count(), 2);
        assert_eq!(m.lookup(id(0, 2)), Lookup::Absent);
        assert_eq!(m.lookup(id(1, 1)), Lookup::Absent);
        assert_eq!(m.lookup(id(1, 2)), Lookup::InCache);
    }

    #[test]
    fn pinned_expert_survives_mid_tick_lru_eviction() {
        // the batched-decode hazard: with cache_k = 1, staging expert 2
        // for session B would evict expert 1 staged moments earlier for
        // session A — before A's rows ran through it. Pinning must keep
        // the device copy alive until the tick ends.
        let mut m = mgr(1, 4, 16);
        m.insert_loaded(id(0, 1), dummy()).unwrap();
        m.pin(id(0, 1));
        m.insert_loaded(id(0, 2), dummy()).unwrap(); // LRU-evicts (0,1)'s slot
        assert_eq!(m.lookup(id(0, 1)), Lookup::Absent, "bookkeeping eviction proceeds");
        assert!(
            m.device.contains(id(0, 1)),
            "pinned expert keeps its device copy until unpin"
        );
        assert!(m.device.contains(id(0, 2)));
        // tick over: the deferred eviction settles
        m.unpin_all();
        assert!(!m.device.contains(id(0, 1)), "deferred eviction lands at unpin");
        assert!(m.device.contains(id(0, 2)));
        assert!(!m.is_pinned(id(0, 1)));
    }

    #[test]
    fn unpin_keeps_a_readmitted_expert() {
        // evicted-while-pinned, then re-admitted before the tick ended:
        // the deferred eviction must NOT tear down the new residency
        let mut m = mgr(1, 4, 16);
        m.insert_loaded(id(0, 1), dummy()).unwrap();
        m.pin(id(0, 1));
        m.insert_loaded(id(0, 2), dummy()).unwrap(); // defers (0,1)
        m.pin(id(0, 2));
        m.insert_loaded(id(0, 1), dummy()).unwrap(); // re-admitted, defers (0,2)
        m.unpin_all();
        assert!(m.device.contains(id(0, 1)), "re-admitted expert survives unpin");
        assert_eq!(m.lookup(id(0, 1)), Lookup::InCache);
        assert!(!m.device.contains(id(0, 2)), "the other deferred victim settles");
    }

    #[test]
    fn pin_without_eviction_is_inert() {
        let mut m = mgr(2, 4, 16);
        m.insert_loaded(id(0, 1), dummy()).unwrap();
        m.pin(id(0, 1));
        m.unpin_all();
        assert!(m.device.contains(id(0, 1)));
        assert_eq!(m.lookup(id(0, 1)), Lookup::InCache);
    }

    #[test]
    fn pinned_transient_release_is_deferred() {
        // k = 0: release_transient normally frees right after use; a pin
        // must hold the copy until the batch's last consumer is done
        let mut m = mgr(0, 4, 16);
        m.insert_loaded(id(0, 2), dummy()).unwrap();
        m.pin(id(0, 2));
        m.release_transient(id(0, 2));
        assert!(m.device.contains(id(0, 2)), "pinned transient survives release");
        m.unpin_all();
        assert!(!m.device.contains(id(0, 2)), "transient freed once unpinned");
    }

    #[test]
    fn resident_bits_follow_residency() {
        let mut m = mgr(1, 4, 16);
        assert_eq!(m.resident_bits_of(id(0, 1)), None);
        m.insert_loaded(id(0, 1), dummy()).unwrap();
        assert_eq!(m.resident_bits_of(id(0, 1)), Some(16));
        m.insert_loaded(id(0, 2), dummy()).unwrap(); // LRU-evicts (0,1)
        assert_eq!(m.resident_bits_of(id(0, 1)), None, "evicted copy has no bits");
        assert_eq!(m.resident_bits_of(id(0, 2)), Some(16));
        // spec path records too
        m.insert_speculative(id(0, 3), dummy()).unwrap();
        assert_eq!(m.resident_bits_of(id(0, 3)), Some(16));
    }

    #[test]
    fn drop_expert_clears_every_record() {
        let mut m = mgr(2, 4, 16);
        m.insert_loaded(id(0, 1), dummy()).unwrap();
        m.insert_speculative(id(0, 2), dummy()).unwrap();
        m.drop_expert(id(0, 1));
        m.drop_expert(id(0, 2));
        for e in [1, 2] {
            assert_eq!(m.lookup(id(0, e)), Lookup::Absent);
            assert!(!m.device.contains(id(0, e)));
            assert_eq!(m.resident_bits_of(id(0, e)), None);
        }
        // dropping settles immediately; a later demand use is a clean miss
        assert_eq!(m.on_demand_use(id(0, 1)), CacheEvent::Miss(id(0, 1)));
    }

    #[test]
    fn expert_counters_aggregate_across_layers() {
        let mut m = mgr(1, 4, 16);
        m.on_demand_use(id(0, 1)); // miss -> routed use
        m.insert_loaded(id(0, 1), dummy()).unwrap();
        m.on_demand_use(id(0, 1)); // hit
        m.on_demand_use(id(1, 3)); // miss in the other layer
        let counts = m.expert_counters();
        assert!(counts.contains(&(id(0, 1), 1, 2)), "{counts:?}");
        assert!(counts.contains(&(id(1, 3), 0, 1)), "{counts:?}");
    }

    #[test]
    fn spec_stats_split_per_layer() {
        let mut m = mgr(1, 4, 16);
        m.insert_speculative(id(0, 1), dummy()).unwrap(); // layer 0 issued
        m.insert_speculative(id(0, 1), dummy()).unwrap(); // layer 0 redundant
        m.insert_speculative(id(1, 2), dummy()).unwrap(); // layer 1 issued
        m.on_demand_use(id(0, 1)); // layer 0 useful
        m.on_demand_use(id(1, 5)); // layer 1 missed
        assert_eq!(m.stats.spec_per_layer[0].issued, 1);
        assert_eq!(m.stats.spec_per_layer[0].redundant, 1);
        assert_eq!(m.stats.spec_per_layer[0].useful, 1);
        assert_eq!(m.stats.spec_per_layer[1].issued, 1);
        assert_eq!(m.stats.spec_per_layer[1].missed, 1);
        // the per-layer split merges back into the aggregate exactly
        let mut merged = SpeculativeStats::default();
        for s in &m.stats.spec_per_layer {
            merged.merge(s);
        }
        assert_eq!(merged.issued, m.stats.spec.issued);
        assert_eq!(merged.redundant, m.stats.spec.redundant);
        assert_eq!(merged.useful, m.stats.spec.useful);
        assert_eq!(merged.missed, m.stats.spec.missed);
    }

    #[test]
    fn obs_log_is_off_by_default_and_records_when_on() {
        let mut m = mgr(1, 4, 16);
        m.on_demand_use(id(0, 1));
        m.insert_loaded(id(0, 1), dummy()).unwrap();
        assert!(m.take_obs_log().is_empty(), "logging is opt-in");

        m.set_obs_log(true);
        m.on_demand_use(id(0, 1)); // hit
        m.insert_speculative(id(0, 2), dummy()).unwrap();
        m.on_demand_use(id(0, 2)); // spec hit: promotes, LRU-evicts (0,1)
        m.drop_expert(id(0, 2));
        let log = m.take_obs_log();
        assert_eq!(
            log,
            vec![
                CacheLog::Use(CacheEvent::Hit(id(0, 1))),
                CacheLog::SpecInsert(id(0, 2)),
                // the promotion's bookkeeping eviction lands before the
                // Use entry (on_demand_use logs its outcome last)
                CacheLog::Evict(id(0, 1)),
                CacheLog::Use(CacheEvent::SpecHit(id(0, 2))),
                CacheLog::Drop(id(0, 2)),
            ]
        );
        assert!(m.take_obs_log().is_empty(), "take drains the log");
    }

    #[test]
    fn obs_log_covers_spec_shed_and_headroom_paths() {
        let mut m = mgr(1, 1, 16); // spec buffer holds one entry
        m.set_obs_log(true);
        m.insert_speculative(id(0, 1), dummy()).unwrap();
        m.insert_speculative(id(0, 2), dummy()).unwrap(); // sheds (0,1)
        let log = m.take_obs_log();
        assert_eq!(
            log,
            vec![
                CacheLog::SpecInsert(id(0, 1)),
                CacheLog::Evict(id(0, 1)),
                CacheLog::SpecInsert(id(0, 2)),
            ]
        );
    }

    #[test]
    fn hit_ratio_math() {
        let mut m = mgr(2, 4, 16);
        m.on_demand_use(id(0, 1)); // miss
        m.insert_loaded(id(0, 1), dummy()).unwrap();
        m.on_demand_use(id(0, 1)); // hit
        m.on_demand_use(id(0, 1)); // hit
        assert!((m.stats.hit_ratio() - 2.0 / 3.0).abs() < 1e-12);
    }
}
