//! A small fixed-capacity LRU set (the per-layer expert cache).
//!
//! The paper keeps the k least-recently-used experts of every MoE layer on
//! the GPU. Capacities are tiny (k ≤ 8 of E = 8 experts), so a VecDeque
//! scan beats hash-map machinery; operations are O(k).
//!
//! Beyond residency, the set keeps PERSISTENT per-item route/hit counters
//! ([`LruSet::counters`]) that survive eviction — the tier policy's
//! hotness signal. Only [`LruSet::touch`] (a routed use) counts;
//! [`LruSet::insert`] (speculative promotion) moves items without
//! inflating the route statistics.

use std::collections::VecDeque;

#[derive(Debug, Clone)]
pub struct LruSet<T: PartialEq + Copy> {
    cap: usize,
    /// Most-recently-used at the front.
    items: VecDeque<T>,
    /// Lifetime (item, hits, routed uses) — assoc list, item counts are
    /// tiny (E = 8 experts per layer). Never pruned on eviction.
    counts: Vec<(T, u64, u64)>,
}

impl<T: PartialEq + Copy> LruSet<T> {
    pub fn new(cap: usize) -> Self {
        LruSet { cap, items: VecDeque::with_capacity(cap), counts: Vec::new() }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn contains(&self, x: &T) -> bool {
        self.items.contains(x)
    }

    /// Mark `x` as used: promote to MRU if present (returns true = hit);
    /// otherwise insert, returning the evicted LRU item via `evicted`.
    /// Counts one routed use for `x` (plus a hit when resident).
    pub fn touch(&mut self, x: T) -> (bool, Option<T>) {
        let (hit, evicted) = self.touch_inner(x);
        self.count_use(x, hit);
        (hit, evicted)
    }

    /// Count a routed use that bypasses [`Self::touch`] — the manager's
    /// miss path (the load lands via `insert`) and spec-promotion path
    /// both route the expert without an LRU touch.
    pub fn count_use(&mut self, x: T, hit: bool) {
        match self.counts.iter_mut().find(|(y, _, _)| *y == x) {
            Some((_, hits, uses)) => {
                *hits += hit as u64;
                *uses += 1;
            }
            None => self.counts.push((x, hit as u64, 1)),
        }
    }

    fn touch_inner(&mut self, x: T) -> (bool, Option<T>) {
        if let Some(pos) = self.items.iter().position(|y| *y == x) {
            let item = self.items.remove(pos).unwrap();
            self.items.push_front(item);
            return (true, None);
        }
        if self.cap == 0 {
            return (false, None); // nothing cached, nothing evicted
        }
        let evicted = if self.items.len() == self.cap {
            self.items.pop_back()
        } else {
            None
        };
        self.items.push_front(x);
        (false, evicted)
    }

    /// Insert without counting as a hit/miss (promotion of a speculative
    /// load into the cache). Returns the evicted LRU item, if any. Does
    /// NOT touch the route counters — speculation is not routing.
    pub fn insert(&mut self, x: T) -> Option<T> {
        let (_, ev) = self.touch_inner(x);
        ev
    }

    /// Lifetime (item, hits, routed uses) triples, eviction-proof —
    /// the raw hotness signal the tier policy re-ranks on.
    pub fn counters(&self) -> impl Iterator<Item = (T, u64, u64)> + '_ {
        self.counts.iter().copied()
    }

    /// Remove a specific item (e.g. the engine invalidating an entry).
    pub fn remove(&mut self, x: &T) -> bool {
        if let Some(pos) = self.items.iter().position(|y| y == x) {
            self.items.remove(pos);
            true
        } else {
            false
        }
    }

    /// LRU→MRU snapshot (for traces / Fig 1's gray squares).
    pub fn iter_mru(&self) -> impl Iterator<Item = &T> {
        self.items.iter()
    }

    pub fn peek_lru(&self) -> Option<&T> {
        self.items.back()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, ensure};

    #[test]
    fn hit_promotes_to_mru() {
        let mut c = LruSet::new(3);
        c.touch(1);
        c.touch(2);
        c.touch(3); // MRU order: 3 2 1
        let (hit, ev) = c.touch(1);
        assert!(hit && ev.is_none());
        assert_eq!(c.iter_mru().copied().collect::<Vec<_>>(), vec![1, 3, 2]);
    }

    #[test]
    fn evicts_lru_when_full() {
        let mut c = LruSet::new(2);
        c.touch(1);
        c.touch(2);
        let (hit, ev) = c.touch(3);
        assert!(!hit);
        assert_eq!(ev, Some(1));
        assert!(c.contains(&2) && c.contains(&3) && !c.contains(&1));
    }

    #[test]
    fn zero_capacity_never_stores() {
        let mut c = LruSet::new(0);
        let (hit, ev) = c.touch(7);
        assert!(!hit && ev.is_none());
        assert!(c.is_empty());
        let (hit, _) = c.touch(7);
        assert!(!hit, "k=0 must never hit");
    }

    #[test]
    fn remove_works() {
        let mut c = LruSet::new(3);
        c.touch(1);
        c.touch(2);
        assert!(c.remove(&1));
        assert!(!c.remove(&1));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn counters_track_routed_uses_and_survive_eviction() {
        let mut c = LruSet::new(1);
        c.touch(1); // miss, use
        c.touch(1); // hit, use
        c.touch(2); // miss, evicts 1
        c.touch(1); // miss again — counters must have survived eviction
        let counts: Vec<_> = c.counters().collect();
        assert!(counts.contains(&(1, 1, 3)), "{counts:?}");
        assert!(counts.contains(&(2, 0, 1)), "{counts:?}");
    }

    #[test]
    fn speculative_insert_does_not_count_as_routing() {
        let mut c = LruSet::new(2);
        c.insert(5);
        c.insert(5);
        assert!(c.contains(&5));
        assert_eq!(c.counters().count(), 0, "insert must not create counters");
        c.touch(5);
        assert_eq!(c.counters().collect::<Vec<_>>(), vec![(5, 1, 1)]);
    }

    #[test]
    fn zero_capacity_still_counts_routed_uses() {
        // k=0 caches nothing, but routing still happened — the tier
        // policy needs the signal regardless of cache capacity
        let mut c = LruSet::new(0);
        c.touch(3);
        c.touch(3);
        assert_eq!(c.counters().collect::<Vec<_>>(), vec![(3, 0, 2)]);
    }

    #[test]
    fn prop_lru_invariants() {
        // 1) size never exceeds cap; 2) no duplicates; 3) a touch of x
        // makes x MRU; 4) evicted item was the LRU.
        check(
            "lru-invariants",
            200,
            |r| {
                let cap = r.below(5);
                let ops: Vec<u8> = (0..60).map(|_| r.below(8) as u8).collect();
                (cap, ops)
            },
            |(cap, ops)| {
                let mut c = LruSet::new(*cap);
                for &x in ops {
                    let before: Vec<u8> = c.iter_mru().copied().collect();
                    let (hit, ev) = c.touch(x);
                    ensure(c.len() <= *cap, "size > cap")?;
                    let mut seen = std::collections::HashSet::new();
                    ensure(c.iter_mru().all(|i| seen.insert(*i)), "duplicates")?;
                    if *cap > 0 {
                        ensure(c.iter_mru().next() == Some(&x), "touched not MRU")?;
                    }
                    ensure(hit == before.contains(&x), "hit flag wrong")?;
                    if let Some(e) = ev {
                        ensure(before.last() == Some(&e), "evicted not LRU")?;
                    }
                }
                // counter invariants: every touch counted exactly one
                // use, and hits never exceed uses
                let total_uses: u64 = c.counters().map(|(_, _, u)| u).sum();
                ensure(total_uses == ops.len() as u64, "uses != touches")?;
                ensure(c.counters().all(|(_, h, u)| h <= u), "hits > uses")?;
                Ok(())
            },
        );
    }

    #[test]
    fn matches_figure1_example_semantics() {
        // paper fig 1: with k=2 the cache holds the union of the last
        // two distinct active experts.
        let mut c = LruSet::new(2);
        for e in [3, 5, 3, 3, 1] {
            c.touch(e);
        }
        assert!(c.contains(&1) && c.contains(&3));
        assert!(!c.contains(&5));
    }
}
