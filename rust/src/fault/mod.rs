//! Deterministic fault injection at the virtual-hardware seams.
//!
//! The engine models hardware the paper's target environment actually
//! misbehaves on — a consumer PCIe link, host RAM under pressure, a
//! desktop GPU — so the failure model is injected exactly where that
//! hardware sits in the virtual machine: transient H2D transfer
//! failures and link-bandwidth *brownout* episodes at the copy-engine
//! staging seams, corrupt expert payloads out of `HostExpertPool`
//! (caught by the per-expert checksum verified at staging), and KV
//! swap/resume failures on the preempt path.
//!
//! Everything is seeded and deterministic: a [`FaultPlan`] plus the
//! engine's own deterministic execution fully determine every injected
//! fault. The injector's RNG is private to it — sampling streams never
//! see a fault draw — which is what makes the transparency property
//! testable: under a *transient-only* plan (failure/corruption/brownout
//! rates set, escalation rates zero) per-session output is bit-identical
//! to the fault-free run; only the virtual timeline (and the `fault_retry`
//! trace spans charging the recovery cost) move.
//!
//! Fault severities, and who reacts:
//!
//! - **Transient, recovered in place** ([`FaultInjector::transfer`],
//!   [`FaultInjector::kv_swap`], [`FaultInjector::corrupt`]): the seam
//!   retries with bounded exponential backoff and always succeeds within
//!   `max_retries`. The failed attempts + backoff are charged to the
//!   link as [`crate::trace::SpanKind::FaultRetry`] spans, so recovery
//!   cost is measurable, and counted in [`FaultStats`].
//! - **Transient, retry budget exhausted** ([`FaultInjector::gate`]
//!   returning [`Error::FaultTransient`]): decided at the *tick-boundary
//!   pre-gate*, before the session's step has touched any shared state,
//!   precisely so a mid-tick batched staging never has to unwind — the
//!   scheduler degrades the session through the existing preempt/requeue
//!   path (bit-identical on resume) and the rest of the batch proceeds
//!   untouched.
//! - **Fatal** ([`Error::FaultFatal`], also from the pre-gate): the
//!   scheduler fails exactly that request with a typed `Event::Failed`;
//!   no panic, no batch poisoning. `fatal_at_gate` targets the Nth gate
//!   check deterministically for drills and tests.
//!
//! `ServingConfig::faults` carries the plan; `enabled: false` (the
//! default) is byte-identical to a build without this module — every
//! injector call is a branch on a bool, asserted bitwise like every
//! other serving knob.

use crate::error::{Error, Result};
use crate::util::rng::Rng;

/// Declarative, seeded chaos plan. All rates are per *opportunity*
/// (staging attempt, swap, or session-step gate check — see each field),
/// all in `[0, 1]`. With `enabled: false` the plan is inert regardless
/// of the other fields, and `validate` accepts anything.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Master switch. Off ⇒ byte-identical serving, zero overhead.
    pub enabled: bool,
    /// Seed for the injector's private RNG stream.
    pub seed: u64,
    /// Per-attempt probability that an expert H2D transfer fails
    /// transiently (recovers within `max_retries`; the failed attempt +
    /// backoff is charged to the link).
    pub transfer_fail_p: f64,
    /// Per-copy probability the staged expert payload reads corrupt —
    /// the per-expert checksum catches it at staging and the copy is
    /// re-staged (one extra attempt charge).
    pub corrupt_p: f64,
    /// Per-swap probability that a KV swap/resume transfer fails
    /// transiently (recovers like `transfer_fail_p`).
    pub kv_fail_p: f64,
    /// Per-session-step probability that a transient fault exhausts its
    /// retry budget: the session degrades through preempt/requeue.
    /// Decided at the tick-boundary gate so the batch is never poisoned.
    pub exhaust_p: f64,
    /// Per-session-step probability of an unrecoverable fault: exactly
    /// that request fails with a typed event.
    pub fatal_p: f64,
    /// Deterministically fail the Nth (0-based, engine-lifetime) gate
    /// check fatally — precise targeting for chaos drills and tests.
    pub fatal_at_gate: Option<u64>,
    /// Retry budget per faulted operation (≥ 1 when any transient rate
    /// is set — a budget of 0 would make every transient fault fatal,
    /// which is what `fatal_p` is for).
    pub max_retries: u32,
    /// First backoff wait in virtual seconds; doubles per retry.
    pub backoff_base_s: f64,
    /// Backoff ceiling in virtual seconds.
    pub backoff_cap_s: f64,
    /// Per-transfer probability that a link brownout episode starts.
    pub brownout_p: f64,
    /// Transfers an episode lasts once started.
    pub brownout_len: u32,
    /// Transfer-duration multiplier during an episode (≥ 1).
    pub brownout_slowdown: f64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            enabled: false,
            seed: 0xFA17,
            transfer_fail_p: 0.0,
            corrupt_p: 0.0,
            kv_fail_p: 0.0,
            exhaust_p: 0.0,
            fatal_p: 0.0,
            fatal_at_gate: None,
            max_retries: 3,
            backoff_base_s: 2e-3,
            backoff_cap_s: 0.25,
            brownout_p: 0.0,
            brownout_len: 8,
            brownout_slowdown: 4.0,
        }
    }
}

impl FaultPlan {
    /// A transient-only smoke plan: every recoverable fault type fires,
    /// nothing escalates — serving output must stay bit-identical while
    /// `transfer_retries` climbs. The chaos workload profile and the CI
    /// smoke step both run this shape.
    pub fn transient_smoke(seed: u64) -> Self {
        FaultPlan {
            enabled: true,
            seed,
            transfer_fail_p: 0.15,
            corrupt_p: 0.05,
            kv_fail_p: 0.10,
            brownout_p: 0.05,
            ..FaultPlan::default()
        }
    }

    /// Checked only when `enabled` — garbage behind the off switch must
    /// not reject an otherwise valid config (the knob idiom every other
    /// `ServingConfig` feature follows).
    pub fn validate(&self) -> Result<()> {
        if !self.enabled {
            return Ok(());
        }
        for (name, p) in [
            ("faults.transfer_fail_p", self.transfer_fail_p),
            ("faults.corrupt_p", self.corrupt_p),
            ("faults.kv_fail_p", self.kv_fail_p),
            ("faults.exhaust_p", self.exhaust_p),
            ("faults.fatal_p", self.fatal_p),
            ("faults.brownout_p", self.brownout_p),
        ] {
            if !p.is_finite() || !(0.0..=1.0).contains(&p) {
                return Err(Error::Config(format!(
                    "{name} must be a probability in [0, 1], got {p}"
                )));
            }
        }
        let transient = self.transfer_fail_p > 0.0
            || self.corrupt_p > 0.0
            || self.kv_fail_p > 0.0;
        if transient && self.max_retries == 0 {
            return Err(Error::Config(
                "faults.max_retries must be >= 1 when a transient rate is set \
                 (use faults.fatal_p for unrecoverable faults)"
                    .into(),
            ));
        }
        if !self.backoff_base_s.is_finite() || self.backoff_base_s <= 0.0 {
            return Err(Error::Config(format!(
                "faults.backoff_base_s must be finite and > 0, got {}",
                self.backoff_base_s
            )));
        }
        if !self.backoff_cap_s.is_finite() || self.backoff_cap_s < self.backoff_base_s {
            return Err(Error::Config(format!(
                "faults.backoff_cap_s must be finite and >= backoff_base_s \
                 ({}), got {}",
                self.backoff_base_s, self.backoff_cap_s
            )));
        }
        if self.brownout_p > 0.0 {
            if self.brownout_len == 0 {
                return Err(Error::Config(
                    "faults.brownout_len must be >= 1 when brownout_p > 0".into(),
                ));
            }
            if !self.brownout_slowdown.is_finite() || self.brownout_slowdown < 1.0 {
                return Err(Error::Config(format!(
                    "faults.brownout_slowdown must be finite and >= 1, got {}",
                    self.brownout_slowdown
                )));
            }
        }
        Ok(())
    }
}

/// Running injection/recovery counters, drained into telemetry gauges
/// and the `done` event by the coordinator.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Every fault injected, all types.
    pub injected: u64,
    /// Failed expert-transfer attempts that were retried.
    pub transfer_retries: u64,
    /// Corrupt expert payloads caught by the staging checksum.
    pub corruptions: u64,
    /// Failed KV swap/resume attempts that were retried.
    pub kv_retries: u64,
    /// Brownout episodes started.
    pub brownouts: u64,
    /// Pre-gate escalations to `Error::FaultTransient` (retry budget
    /// exhausted; session degraded through preempt/requeue).
    pub exhausted: u64,
    /// Pre-gate escalations to `Error::FaultFatal` (request failed).
    pub fatal: u64,
}

/// What the transfer seam must charge for one (eventually successful)
/// staging: `retries` failed attempts worth `extra_s` of link time, and
/// a `slowdown` multiplier on the successful attempt itself (brownout).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TransferOutcome {
    pub retries: u32,
    pub extra_s: f64,
    pub slowdown: f64,
}

impl TransferOutcome {
    const CLEAN: TransferOutcome =
        TransferOutcome { retries: 0, extra_s: 0.0, slowdown: 1.0 };
}

/// The seeded injector the engine owns. All methods are O(retries) and
/// branch out immediately when the plan is disabled.
#[derive(Debug, Clone)]
pub struct FaultInjector {
    plan: FaultPlan,
    rng: Rng,
    /// Transfers left in the current brownout episode.
    brownout_left: u32,
    /// Engine-lifetime count of gate checks (for `fatal_at_gate`).
    gate_checks: u64,
    stats: FaultStats,
}

impl FaultInjector {
    pub fn new(plan: &FaultPlan) -> Self {
        FaultInjector {
            rng: Rng::new(plan.seed),
            plan: plan.clone(),
            brownout_left: 0,
            gate_checks: 0,
            stats: FaultStats::default(),
        }
    }

    /// An injector that never injects — what a disabled plan builds.
    pub fn disabled() -> Self {
        FaultInjector::new(&FaultPlan::default())
    }

    pub fn enabled(&self) -> bool {
        self.plan.enabled
    }

    pub fn stats(&self) -> FaultStats {
        self.stats
    }

    /// The plan's per-operation retry budget.
    pub fn max_retries(&self) -> u32 {
        self.plan.max_retries
    }

    /// Link seconds one corrupt-read re-stage costs: the re-copy attempt
    /// plus the backoff for the (0-based) `restage`-th retry.
    pub fn restage_cost_s(&self, attempt_cost_s: f64, restage: u32) -> f64 {
        attempt_cost_s + self.backoff_s(restage)
    }

    /// Exponential backoff for the i-th (0-based) failed attempt.
    fn backoff_s(&self, attempt: u32) -> f64 {
        let exp = attempt.min(52); // 2^52 < f64 mantissa; beyond it the cap rules anyway
        (self.plan.backoff_base_s * (1u64 << exp) as f64).min(self.plan.backoff_cap_s)
    }

    /// Draw the retry run for one transient-faultable operation: the
    /// number of consecutive failed attempts (clamped to the budget —
    /// the seam always recovers; exhaustion is the gate's job) and the
    /// link seconds they burn, each failure costing one attempt plus
    /// its backoff wait.
    fn retry_run(&mut self, fail_p: f64, attempt_cost_s: f64) -> (u32, f64) {
        if fail_p <= 0.0 {
            return (0, 0.0);
        }
        let mut retries = 0u32;
        let mut extra_s = 0.0;
        while retries < self.plan.max_retries && self.rng.f64() < fail_p {
            extra_s += attempt_cost_s + self.backoff_s(retries);
            retries += 1;
        }
        (retries, extra_s)
    }

    /// Transfer seam (expert staging): advance the brownout state, then
    /// draw the transient-failure retry run. The returned charge always
    /// ends in success — escalation never happens mid-staging.
    pub fn transfer(&mut self, attempt_cost_s: f64) -> TransferOutcome {
        if !self.plan.enabled {
            return TransferOutcome::CLEAN;
        }
        if self.brownout_left == 0
            && self.plan.brownout_p > 0.0
            && self.rng.f64() < self.plan.brownout_p
        {
            self.brownout_left = self.plan.brownout_len;
            self.stats.brownouts += 1;
            self.stats.injected += 1;
        }
        let slowdown = if self.brownout_left > 0 {
            self.brownout_left -= 1;
            self.plan.brownout_slowdown
        } else {
            1.0
        };
        let (retries, extra_s) =
            self.retry_run(self.plan.transfer_fail_p, attempt_cost_s * slowdown);
        self.stats.transfer_retries += retries as u64;
        self.stats.injected += retries as u64;
        TransferOutcome { retries, extra_s, slowdown }
    }

    /// Checksum-verification seam: does this staged copy read corrupt?
    /// The caller re-stages on `true` (charging one more attempt); the
    /// host-side source is intact, so the retry reads clean bytes.
    pub fn corrupt(&mut self) -> bool {
        if !self.plan.enabled || self.plan.corrupt_p <= 0.0 {
            return false;
        }
        let hit = self.rng.f64() < self.plan.corrupt_p;
        if hit {
            self.stats.corruptions += 1;
            self.stats.injected += 1;
        }
        hit
    }

    /// KV swap/resume seam: extra link seconds of transient-failure
    /// recovery to charge (0.0 = clean swap).
    pub fn kv_swap(&mut self, attempt_cost_s: f64) -> f64 {
        if !self.plan.enabled {
            return 0.0;
        }
        let (retries, extra_s) = self.retry_run(self.plan.kv_fail_p, attempt_cost_s);
        self.stats.kv_retries += retries as u64;
        self.stats.injected += retries as u64;
        extra_s
    }

    /// Tick-boundary pre-gate, called once per session-step BEFORE the
    /// step touches any shared state. `Some(err)` means the step must
    /// not run: `FaultTransient` degrades the session via preempt/
    /// requeue, `FaultFatal` fails the request. Deciding here — not
    /// mid-staging — is what keeps a faulted session from poisoning the
    /// batched tick it shares with healthy ones.
    pub fn gate(&mut self, session: u64) -> Option<Error> {
        if !self.plan.enabled {
            return None;
        }
        let n = self.gate_checks;
        self.gate_checks += 1;
        if self.plan.fatal_at_gate == Some(n) {
            self.stats.fatal += 1;
            self.stats.injected += 1;
            return Some(Error::FaultFatal(format!(
                "injected fatal fault at gate check {n} (session {session})"
            )));
        }
        if self.plan.fatal_p > 0.0 && self.rng.f64() < self.plan.fatal_p {
            self.stats.fatal += 1;
            self.stats.injected += 1;
            return Some(Error::FaultFatal(format!(
                "injected fatal fault (session {session})"
            )));
        }
        if self.plan.exhaust_p > 0.0 && self.rng.f64() < self.plan.exhaust_p {
            self.stats.exhausted += 1;
            self.stats.injected += 1;
            return Some(Error::FaultTransient(format!(
                "injected retry-budget exhaustion (session {session})"
            )));
        }
        None
    }
}

/// Streaming FNV-1a — the per-copy checksum computed once at pool build
/// ([`crate::memory::host::HostExpertPool`] records one per packed
/// expert copy) and re-verified at staging when faults are enabled. Not
/// cryptographic; it only has to catch the corruption model
/// (flipped/garbled payload bytes), cheaply, without materializing the
/// payload as one contiguous buffer.
#[derive(Debug, Clone, Copy)]
pub struct Checksum(u64);

impl Checksum {
    pub fn new() -> Self {
        Checksum(0xcbf29ce484222325)
    }

    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Checksum {
    fn default() -> Self {
        Checksum::new()
    }
}

/// One-shot [`Checksum`] over a single buffer.
pub fn checksum(bytes: &[u8]) -> u64 {
    let mut h = Checksum::new();
    h.update(bytes);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn transient_plan() -> FaultPlan {
        FaultPlan::transient_smoke(99)
    }

    #[test]
    fn default_plan_is_off_and_valid() {
        let p = FaultPlan::default();
        assert!(!p.enabled);
        p.validate().unwrap();
    }

    #[test]
    fn garbage_behind_the_off_switch_still_validates() {
        let p = FaultPlan {
            enabled: false,
            transfer_fail_p: f64::NAN,
            backoff_base_s: -1.0,
            brownout_slowdown: 0.0,
            ..FaultPlan::default()
        };
        p.validate().unwrap();
    }

    #[test]
    fn enabled_plan_rejects_bad_fields() {
        let bad = [
            FaultPlan { transfer_fail_p: 1.5, ..transient_plan() },
            FaultPlan { corrupt_p: -0.1, ..transient_plan() },
            FaultPlan { fatal_p: f64::NAN, ..transient_plan() },
            FaultPlan { max_retries: 0, ..transient_plan() },
            FaultPlan { backoff_base_s: 0.0, ..transient_plan() },
            FaultPlan { backoff_cap_s: 1e-9, ..transient_plan() },
            FaultPlan { brownout_len: 0, ..transient_plan() },
            FaultPlan { brownout_slowdown: 0.5, ..transient_plan() },
        ];
        for p in bad {
            assert!(p.validate().is_err(), "{p:?} should not validate");
        }
        transient_plan().validate().unwrap();
    }

    #[test]
    fn disabled_injector_is_free_and_clean() {
        let mut inj = FaultInjector::disabled();
        assert!(!inj.enabled());
        for _ in 0..64 {
            assert_eq!(inj.transfer(1.0), TransferOutcome::CLEAN);
            assert!(!inj.corrupt());
            assert_eq!(inj.kv_swap(1.0), 0.0);
            assert!(inj.gate(1).is_none());
        }
        assert_eq!(inj.stats(), FaultStats::default());
    }

    #[test]
    fn same_plan_same_seed_same_faults() {
        let plan = FaultPlan {
            exhaust_p: 0.05,
            fatal_p: 0.01,
            ..transient_plan()
        };
        let mut a = FaultInjector::new(&plan);
        let mut b = FaultInjector::new(&plan);
        for i in 0..500 {
            assert_eq!(a.transfer(0.01), b.transfer(0.01));
            assert_eq!(a.corrupt(), b.corrupt());
            assert_eq!(a.kv_swap(0.02), b.kv_swap(0.02));
            assert_eq!(
                a.gate(i).map(|e| e.to_string()),
                b.gate(i).map(|e| e.to_string())
            );
        }
        assert_eq!(a.stats(), b.stats());
        assert!(a.stats().injected > 0, "smoke plan must actually inject");
    }

    #[test]
    fn retries_are_bounded_and_charged() {
        let plan = FaultPlan {
            enabled: true,
            transfer_fail_p: 1.0, // every attempt fails → always hits the budget
            max_retries: 3,
            ..FaultPlan::default()
        };
        let mut inj = FaultInjector::new(&plan);
        let out = inj.transfer(0.5);
        assert_eq!(out.retries, 3);
        assert_eq!(out.slowdown, 1.0);
        // 3 failed attempts + backoffs 2ms, 4ms, 8ms
        let want = 3.0 * 0.5 + 2e-3 + 4e-3 + 8e-3;
        assert!((out.extra_s - want).abs() < 1e-12, "{}", out.extra_s);
        assert_eq!(inj.stats().transfer_retries, 3);
    }

    #[test]
    fn backoff_respects_the_cap() {
        let plan = FaultPlan {
            enabled: true,
            kv_fail_p: 1.0,
            max_retries: 20,
            backoff_base_s: 1e-3,
            backoff_cap_s: 4e-3,
            ..FaultPlan::default()
        };
        let mut inj = FaultInjector::new(&plan);
        let extra = inj.kv_swap(0.0);
        // 1, 2, 4 ms then capped at 4 ms for the remaining 17 retries
        let want = 1e-3 + 2e-3 + 18.0 * 4e-3;
        assert!((extra - want).abs() < 1e-12, "{extra}");
        assert_eq!(inj.stats().kv_retries, 20);
    }

    #[test]
    fn brownout_episodes_have_the_declared_length() {
        let plan = FaultPlan {
            enabled: true,
            brownout_p: 1.0, // an episode starts the moment the last ends
            brownout_len: 4,
            brownout_slowdown: 3.0,
            ..FaultPlan::default()
        };
        let mut inj = FaultInjector::new(&plan);
        for _ in 0..8 {
            assert_eq!(inj.transfer(1.0).slowdown, 3.0);
        }
        assert_eq!(inj.stats().brownouts, 2);
    }

    #[test]
    fn fatal_at_gate_targets_exactly_one_check() {
        let plan = FaultPlan {
            enabled: true,
            fatal_at_gate: Some(2),
            ..FaultPlan::default()
        };
        let mut inj = FaultInjector::new(&plan);
        assert!(inj.gate(7).is_none());
        assert!(inj.gate(8).is_none());
        match inj.gate(9) {
            Some(Error::FaultFatal(msg)) => assert!(msg.contains("session 9")),
            other => panic!("expected FaultFatal, got {other:?}"),
        }
        assert!(inj.gate(7).is_none());
        assert_eq!(inj.stats().fatal, 1);
    }

    #[test]
    fn exhaustion_surfaces_as_transient() {
        let plan =
            FaultPlan { enabled: true, exhaust_p: 1.0, ..FaultPlan::default() };
        let mut inj = FaultInjector::new(&plan);
        assert!(matches!(inj.gate(1), Some(Error::FaultTransient(_))));
        assert_eq!(inj.stats().exhausted, 1);
    }

    #[test]
    fn checksum_catches_any_single_byte_flip() {
        let payload: Vec<u8> = (0..255u8).collect();
        let clean = checksum(&payload);
        for i in 0..payload.len() {
            let mut bad = payload.clone();
            bad[i] ^= 0x40;
            assert_ne!(checksum(&bad), clean, "flip at {i} undetected");
        }
        assert_eq!(checksum(&payload), clean);
        assert_ne!(checksum(&[]), checksum(&[0]));
    }
}
