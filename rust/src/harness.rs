//! Shared harness for the experiment binaries and benches: artifact
//! discovery, engine construction, workload loading, and the offline
//! cache/speculation replay used by the Figure 2 evaluations.

use std::path::{Path, PathBuf};

use crate::cache::lru::LruSet;
use crate::config::{
    HardwareProfile, Manifest, OffloadPolicy, QuantScheme, ServingConfig, SimScale,
};
use crate::engine::MoeEngine;
use crate::error::{Error, Result};
use crate::eval;
use crate::model::ModelWeights;

/// Locate the artifacts directory (env override, then ./artifacts).
pub fn artifacts_dir() -> Result<PathBuf> {
    if let Ok(dir) = std::env::var("MOE_OFFLOAD_ARTIFACTS") {
        return Ok(PathBuf::from(dir));
    }
    let candidates = [
        PathBuf::from("artifacts"),
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    ];
    for c in candidates {
        if c.join("manifest.json").exists() {
            return Ok(c);
        }
    }
    Err(Error::Artifact(
        "artifacts/ not found — run `make artifacts` first".into(),
    ))
}

/// Build an engine with the given schemes/policy/profile.
pub fn build_engine(
    dir: &Path,
    attn: QuantScheme,
    expert: QuantScheme,
    policy: OffloadPolicy,
    profile: HardwareProfile,
    scale: SimScale,
) -> Result<MoeEngine> {
    let serving = ServingConfig {
        policy,
        expert_quant: expert,
        attn_quant: attn,
        sim_scale: scale,
        ..Default::default()
    };
    build_engine_with_serving(dir, &serving, profile)
}

/// Build an engine from a full [`ServingConfig`] (KV pool sizing,
/// scheduler width, …) — the benches and paged-KV tests need the knobs
/// `build_engine` doesn't expose.
pub fn build_engine_with_serving(
    dir: &Path,
    serving: &ServingConfig,
    profile: HardwareProfile,
) -> Result<MoeEngine> {
    let manifest = Manifest::load(dir)?;
    let weights = ModelWeights::load_tiered(
        &manifest.config,
        &dir.join("weights.npz"),
        serving.attn_quant,
        serving.expert_quant,
        &serving.expert_tiers,
    )?;
    MoeEngine::new(&manifest, weights, serving, profile)
}

/// Chat workload (OpenAssistant stand-in) from the build corpora.
pub fn chat_tokens(dir: &Path, n: usize) -> Result<Vec<u32>> {
    let corpus = eval::load_corpus(&dir.join("corpus/chat.bin"))?;
    if corpus.len() < n {
        return Ok(corpus);
    }
    Ok(corpus[..n].to_vec())
}

/// Decode `tokens` teacher-forced through the engine (the evaluation mode
/// of §4.1/4.3: run the model over recorded conversations). Returns the
/// session so callers can read its run statistics; when the context
/// window fills, the session restarts in place (warm expert cache, stats
/// preserved).
pub fn run_teacher_forced(engine: &mut MoeEngine, tokens: &[u32]) -> Result<crate::engine::Session> {
    let mut sess = engine.new_session()?;
    for &t in tokens {
        if sess.position() + 1 >= engine.weights.cfg.max_seq {
            sess.reset();
        }
        engine.decode_step(&mut sess, t)?;
    }
    Ok(sess)
}

/// Offline LRU replay over recorded per-layer expert selections: returns
/// the hit ratio for cache size k (Fig 2 left). `selections[t]` is the
/// set of experts active at token t for ONE layer.
pub fn replay_lru(selections: &[Vec<usize>], k: usize) -> f64 {
    let mut cache: LruSet<usize> = LruSet::new(k);
    let mut hits = 0u64;
    let mut total = 0u64;
    for sel in selections {
        for &e in sel {
            let (hit, _) = cache.touch(e);
            hits += hit as u64;
            total += 1;
        }
    }
    if total == 0 {
        0.0
    } else {
        hits as f64 / total as f64
    }
}

/// Offline speculative-recall replay (Fig 2 right): at each token, guess
/// the top-`n_fetch` experts of layer `l+ahead` from layer `l`'s hidden
/// state (the recorded speculative gate probabilities), and measure the
/// fraction of actually-used experts covered.
///
/// `spec_probs[t]` = speculative router distribution recorded at token t;
/// `actual[t]` = experts actually used `ahead` layers later at token t.
pub fn replay_speculative(
    spec_probs: &[Vec<f32>],
    actual: &[Vec<usize>],
    n_fetch: usize,
) -> f64 {
    let mut covered = 0u64;
    let mut total = 0u64;
    for (probs, used) in spec_probs.iter().zip(actual) {
        let guess = crate::tensor::top_k(probs, n_fetch);
        for e in used {
            covered += guess.contains(e) as u64;
            total += 1;
        }
    }
    if total == 0 {
        0.0
    } else {
        covered as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replay_lru_basic() {
        // two experts alternating: k=2 holds both after warmup
        let sels: Vec<Vec<usize>> = (0..10).map(|t| vec![t % 2]).collect();
        let hr2 = replay_lru(&sels, 2);
        let hr0 = replay_lru(&sels, 0);
        assert!(hr2 >= 0.8, "{hr2}"); // 2 cold misses out of 10 uses
        assert_eq!(hr0, 0.0);
        // monotone in k
        let hr1 = replay_lru(&sels, 1);
        assert!(hr1 <= hr2);
    }

    #[test]
    fn replay_speculative_perfect_and_chance() {
        let probs = vec![vec![0.7, 0.1, 0.1, 0.1]; 5];
        let actual_hit = vec![vec![0usize]; 5];
        let actual_miss = vec![vec![3usize]; 5];
        assert_eq!(replay_speculative(&probs, &actual_hit, 1), 1.0);
        assert_eq!(replay_speculative(&probs, &actual_miss, 1), 0.0);
        assert_eq!(replay_speculative(&probs, &actual_miss, 4), 1.0);
    }
}
