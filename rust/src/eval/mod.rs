//! Quality evaluation: held-out perplexity (the paper's WikiText2/C4
//! columns, substituted with the build corpora) and a cloze-completion
//! accuracy task (the MMLU substitute) — see DESIGN.md substitution table.

use std::path::Path;

use crate::engine::MoeEngine;
use crate::error::{Error, Result};
use crate::util::rng::Rng;

/// Load an eval corpus written by `python/compile/data.py`.
pub fn load_corpus(path: &Path) -> Result<Vec<u32>> {
    let bytes = std::fs::read(path)
        .map_err(|e| Error::Config(format!("cannot read corpus {}: {e}", path.display())))?;
    Ok(bytes.into_iter().map(|b| b as u32).collect())
}

/// Perplexity over a corpus, evaluated in independent windows of
/// `window` tokens (each window scored teacher-forced through the engine's
/// chunked-prefill path).
pub fn perplexity(
    engine: &mut MoeEngine,
    corpus: &[u32],
    window: usize,
    n_windows: usize,
) -> Result<f64> {
    if corpus.len() < window + 1 {
        return Err(Error::Config("corpus shorter than eval window".into()));
    }
    let stride = (corpus.len() - window - 1) / n_windows.max(1);
    let mut nll = 0.0f64;
    let mut count = 0usize;
    for w in 0..n_windows {
        let start = w * stride;
        let slice = &corpus[start..start + window];
        let mut sess = engine.new_session()?;
        let lps = engine.score(&mut sess, slice)?;
        nll -= lps.iter().map(|&x| x as f64).sum::<f64>();
        count += lps.len();
    }
    Ok((nll / count as f64).exp())
}

/// A 4-way cloze task: pick the true continuation of a context among three
/// distractors sampled elsewhere from the corpus; scored by total
/// continuation log-prob. Returns accuracy (chance = 0.25).
pub fn cloze_accuracy(
    engine: &mut MoeEngine,
    corpus: &[u32],
    n_items: usize,
    ctx_len: usize,
    cont_len: usize,
    seed: u64,
) -> Result<f64> {
    let item_len = ctx_len + cont_len;
    if corpus.len() < 4 * item_len + 4 {
        return Err(Error::Config("corpus too small for cloze task".into()));
    }
    let mut rng = Rng::new(seed);
    let mut correct = 0usize;
    for _ in 0..n_items {
        let start = rng.below(corpus.len() - item_len - 1);
        let ctx = &corpus[start..start + ctx_len];
        let true_cont = &corpus[start + ctx_len..start + item_len];

        // three distractor continuations from random other positions
        let mut options: Vec<Vec<u32>> = vec![true_cont.to_vec()];
        for _ in 0..3 {
            let s = rng.below(corpus.len() - cont_len - 1);
            options.push(corpus[s..s + cont_len].to_vec());
        }
        let order: Vec<usize> = {
            let mut idx: Vec<usize> = (0..4).collect();
            rng.shuffle(&mut idx);
            idx
        };

        let mut best = (f64::NEG_INFINITY, 0usize);
        for &oi in &order {
            let mut seq = ctx.to_vec();
            seq.extend_from_slice(&options[oi]);
            let mut sess = engine.new_session()?;
            let lps = engine.score(&mut sess, &seq)?;
            // score only the continuation region
            let cont_lp: f64 = lps[ctx_len - 1..].iter().map(|&x| x as f64).sum();
            if cont_lp > best.0 {
                best = (cont_lp, oi);
            }
        }
        if best.1 == 0 {
            correct += 1;
        }
    }
    Ok(correct as f64 / n_items as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_corpus_missing_file_errors() {
        assert!(load_corpus(Path::new("/nonexistent/corpus.bin")).is_err());
    }

    // end-to-end eval tests live in rust/tests/ (they need artifacts)
}
