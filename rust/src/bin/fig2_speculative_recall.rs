//! FIG2 (right): speculative loading recall vs number of experts
//! pre-loaded, at several layer look-aheads — reproduces the right panel
//! of the paper's Figure 2.
//!
//! Method (paper §4.1): while decoding recorded conversations, apply the
//! gate of layer l+a to layer l's hidden state ("guess"), then measure how
//! often the experts actually used at layer l+a were among the top-n
//! guesses. The paper shows a ∈ {1, 2, 10}; the tiny testbed has 6 layers
//! so we use a ∈ {1, 2, 5} — same qualitative message (accuracy decays
//! with distance).

use std::collections::HashMap;

use moe_offload::config::{HardwareProfile, OffloadPolicy, QuantScheme, SimScale};
use moe_offload::engine::SpecProbe;
use moe_offload::harness;
use moe_offload::telemetry::Table;
use moe_offload::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let args = Cli::new(
        "fig2_speculative_recall",
        "Figure 2 right: speculative loading recall",
    )
    .opt("tokens", "160", "chat tokens to trace")
    .parse();

    let dir = harness::artifacts_dir()?;
    let mut engine = harness::build_engine(
        &dir,
        QuantScheme::Hqq { bits: 4 },
        QuantScheme::Hqq { bits: 3 },
        OffloadPolicy::LruOnly { cache_k: 2 },
        HardwareProfile::rtx3060(),
        SimScale::Tiny,
    )?;
    engine.trace.enabled = true;
    let n_layers = engine.weights.cfg.n_layers;
    let aheads: Vec<usize> = vec![1, 2, n_layers - 1];
    engine.spec_probe = Some(SpecProbe { aheads: aheads.clone(), records: Vec::new() });

    let tokens = harness::chat_tokens(&dir, args.get_usize("tokens"))?;
    harness::run_teacher_forced(&mut engine, &tokens)?;

    // actual selections by (token, layer)
    let mut actual: HashMap<(usize, usize), Vec<usize>> = HashMap::new();
    for r in &engine.trace.records {
        actual.insert((r.token_index, r.layer), r.selected.clone());
    }
    let probe = engine.spec_probe.take().unwrap();

    let cfg = &engine.weights.cfg;
    println!("FIG2 (right) — speculative loading recall");
    println!(
        "workload: {} chat tokens; guess = top-n of gate_(l+a)(h_l); recall over\n\
         actually-used experts of layer l+a (top-{} routing, {} experts)\n",
        tokens.len(),
        cfg.top_k,
        cfg.n_experts
    );
    let mut header = vec!["n pre-loaded".to_string()];
    header.extend(aheads.iter().map(|a| format!("{a} layer(s) ahead")));
    let mut table = Table::new(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());

    let mut curves: HashMap<usize, Vec<f64>> = HashMap::new();
    for n_fetch in 1..=cfg.n_experts {
        let mut row = vec![n_fetch.to_string()];
        for &a in &aheads {
            let mut spec = Vec::new();
            let mut act = Vec::new();
            for (tok, l, ahead, probs) in &probe.records {
                if *ahead == a {
                    if let Some(sel) = actual.get(&(*tok, l + a)) {
                        spec.push(probs.clone());
                        act.push(sel.clone());
                    }
                }
            }
            let recall = harness::replay_speculative(&spec, &act, n_fetch);
            curves.entry(a).or_default().push(recall);
            row.push(format!("{recall:.3}"));
        }
        table.row(row);
    }
    println!("{}", table.render());

    // paper's qualitative claims, asserted
    for a in &aheads {
        let c = &curves[a];
        assert!(
            c.windows(2).all(|w| w[1] >= w[0] - 1e-9),
            "recall must be monotone in n"
        );
    }
    let r1 = curves[&aheads[0]][1]; // 1 ahead, n=2
    let rfar = curves[&aheads[2]][1]; // farthest ahead, n=2
    println!(
        "shape check: 1-ahead recall@2 = {r1:.3} > {}-ahead recall@2 = {rfar:.3}  ({})",
        aheads[2],
        if r1 > rfar { "OK — matches paper" } else { "UNEXPECTED" }
    );
    Ok(())
}
