//! TABLE 1: mixed-quantization grid — model size, held-out perplexity on
//! two domains, and cloze accuracy, for attention × expert quantization
//! schemes. Reproduces the paper's Table 1 (with the DESIGN.md
//! substitutions: Wiki2→prose corpus, C4→code corpus, MMLU→cloze task).

use moe_offload::config::{
    HardwareProfile, OffloadPolicy, QuantScheme, ServingConfig, SimScale,
};
use moe_offload::eval;
use moe_offload::harness;
use moe_offload::memory::host::ExpertId;
use moe_offload::quant::TierPolicy;
use moe_offload::telemetry::Table;
use moe_offload::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let args = Cli::new("table1_quant_grid", "Table 1: quantization grid")
        .opt("windows", "3", "perplexity windows per corpus")
        .opt("window", "96", "tokens per perplexity window")
        .opt("cloze-items", "10", "cloze task items")
        .flag("fast", "smaller grid (skip fp16 attention rows)")
        .parse();

    let dir = harness::artifacts_dir()?;
    let prose = eval::load_corpus(&dir.join("corpus/prose_eval.bin"))?;
    let code = eval::load_corpus(&dir.join("corpus/code_eval.bin"))?;

    let attn_schemes: Vec<QuantScheme> = if args.has("fast") {
        vec![QuantScheme::Hqq { bits: 4 }]
    } else {
        vec![
            QuantScheme::Fp16,
            QuantScheme::Hqq { bits: 4 },
            QuantScheme::Hqq { bits: 3 },
            QuantScheme::Hqq { bits: 2 },
        ]
    };
    let expert_schemes = [
        QuantScheme::Fp16,
        QuantScheme::Hqq { bits: 4 },
        QuantScheme::Hqq { bits: 3 },
        QuantScheme::Hqq { bits: 2 },
    ];

    println!("TABLE 1 — mixed quantization: size vs quality");
    println!(
        "substitutions: Wiki2→prose corpus ppl, C4→code corpus ppl, MMLU→4-way cloze acc\n\
         (tiny Mixtral-architecture model; sizes in MiB not GB)\n"
    );
    let mut table = Table::new(&[
        "Attn quant",
        "Experts quant",
        "Size MiB",
        "Prose ppl",
        "Code ppl",
        "Cloze acc",
    ]);

    for &attn in &attn_schemes {
        for &expert in &expert_schemes {
            let mut engine = harness::build_engine(
                &dir,
                attn,
                expert,
                OffloadPolicy::Full { cache_k: 4, spec_n: 2 },
                HardwareProfile::a100_80gb(),
                SimScale::Tiny,
            )?;
            let size_mib = engine.weights.total_bytes() as f64 / (1 << 20) as f64;
            let ppl_prose = eval::perplexity(
                &mut engine,
                &prose,
                args.get_usize("window"),
                args.get_usize("windows"),
            )?;
            let ppl_code = eval::perplexity(
                &mut engine,
                &code,
                args.get_usize("window"),
                args.get_usize("windows"),
            )?;
            let cloze = eval::cloze_accuracy(
                &mut engine,
                &prose,
                args.get_usize("cloze-items"),
                48,
                16,
                17,
            )?;
            table.row(vec![
                attn.label(),
                expert.label(),
                format!("{size_mib:.2}"),
                format!("{ppl_prose:.3}"),
                format!("{ppl_code:.3}"),
                format!("{:.0}%", cloze * 100.0),
            ]);
        }
    }
    println!("{}", table.render());
    println!(
        "expected shape (paper): quality degrades slowly 16→4→3 bit and faster at 2 bit;\n\
         quantizing EXPERTS costs less quality per byte saved than quantizing attention;\n\
         experts dominate total size (≈{:.0}% here, 96.6% for Mixtral-8x7B).",
        expert_fraction(&dir)? * 100.0
    );

    // tier-policy axis: hold the base grid point (attn q4 / experts q3)
    // and sweep hotness-tiered precision — the quality / link-bytes
    // trade the uniform grid above cannot show. "Avg wire KiB" is the
    // mean per-expert transfer size at the statically seeded tiers.
    let tier_axis: [(&str, TierPolicy); 4] = [
        ("uniform (off)", TierPolicy::default()),
        (
            "hot3/cold2",
            TierPolicy {
                enabled: true,
                hot: QuantScheme::Hqq { bits: 3 },
                cold: QuantScheme::Hqq { bits: 2 },
                hot_fraction: 0.25,
                cold_fraction: 0.5,
                ..TierPolicy::hot_cold()
            },
        ),
        ("hot4/warm3/cold2", TierPolicy::hot_cold()),
        (
            "hot4/cold3",
            TierPolicy {
                enabled: true,
                hot: QuantScheme::Hqq { bits: 4 },
                cold: QuantScheme::Hqq { bits: 3 },
                ..TierPolicy::hot_cold()
            },
        ),
    ];
    println!(
        "\nTier-policy axis (attn q4, base experts q3, gate-seeded hot/cold \
         fractions per layer):"
    );
    let mut tier_table = Table::new(&[
        "Tier policy",
        "Avg wire KiB",
        "Prose ppl",
        "Code ppl",
        "Cloze acc",
    ]);
    for (label, tiers) in tier_axis {
        let serving = ServingConfig {
            policy: OffloadPolicy::Full { cache_k: 4, spec_n: 2 },
            expert_quant: QuantScheme::Hqq { bits: 3 },
            attn_quant: QuantScheme::Hqq { bits: 4 },
            sim_scale: SimScale::Tiny,
            expert_tiers: tiers,
            ..Default::default()
        };
        let mut engine =
            harness::build_engine_with_serving(&dir, &serving, HardwareProfile::a100_80gb())?;
        let cfg = engine.weights.cfg.clone();
        let wire_total: u64 = (0..cfg.n_layers)
            .flat_map(|l| (0..cfg.n_experts).map(move |e| ExpertId::new(l, e)))
            .map(|id| {
                let scheme = engine
                    .weights
                    .experts
                    .scheme_of_tier(engine.weights.experts.tier_of(id));
                engine.cost.wire_bytes_of(scheme)
            })
            .sum();
        let avg_kib =
            wire_total as f64 / (cfg.n_layers * cfg.n_experts) as f64 / 1024.0;
        let ppl_prose = eval::perplexity(
            &mut engine,
            &prose,
            args.get_usize("window"),
            args.get_usize("windows"),
        )?;
        let ppl_code = eval::perplexity(
            &mut engine,
            &code,
            args.get_usize("window"),
            args.get_usize("windows"),
        )?;
        let cloze = eval::cloze_accuracy(
            &mut engine,
            &prose,
            args.get_usize("cloze-items"),
            48,
            16,
            17,
        )?;
        tier_table.row(vec![
            label.to_string(),
            format!("{avg_kib:.2}"),
            format!("{ppl_prose:.3}"),
            format!("{ppl_code:.3}"),
            format!("{:.0}%", cloze * 100.0),
        ]);
    }
    println!("{}", tier_table.render());
    println!(
        "expected shape: cold-tier bytes come off the wire almost for free in\n\
         quality (cold experts serve few tokens), while a 4-bit hot tier buys\n\
         back quality on the tokens that matter — the MoBiLE-style trade."
    );
    Ok(())
}

fn expert_fraction(dir: &std::path::Path) -> anyhow::Result<f64> {
    let engine = harness::build_engine(
        dir,
        QuantScheme::Fp16,
        QuantScheme::Fp16,
        OffloadPolicy::OnDemand,
        HardwareProfile::a100_80gb(),
        SimScale::Tiny,
    )?;
    Ok(engine.weights.experts.total_bytes() as f64 / engine.weights.total_bytes() as f64)
}
