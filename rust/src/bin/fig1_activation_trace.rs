//! FIG1: expert activation pattern for select layers, with the LRU-cache
//! (k=2) overlay — reproduces the paper's Figure 1.
//!
//! Output: an ASCII heatmap per layer (tokens × experts; shade = gating
//! weight, `·` = cached by LRU k=2) plus `fig1_trace.json` with the raw
//! data for external plotting.

use moe_offload::config::{HardwareProfile, OffloadPolicy, QuantScheme, SimScale};
use moe_offload::harness;
use moe_offload::util::cli::Cli;
use moe_offload::util::json::Json;

fn main() -> anyhow::Result<()> {
    let args = Cli::new("fig1_activation_trace", "Figure 1: expert activation heatmap")
        .opt("tokens", "48", "number of chat tokens to trace")
        .opt("cache-k", "2", "LRU size for the overlay (paper: k=2)")
        .opt("out", "fig1_trace.json", "JSON output path")
        .parse();

    let dir = harness::artifacts_dir()?;
    let mut engine = harness::build_engine(
        &dir,
        QuantScheme::Hqq { bits: 4 },
        QuantScheme::Hqq { bits: 3 },
        OffloadPolicy::LruOnly { cache_k: args.get_usize("cache-k") },
        HardwareProfile::rtx3060(),
        SimScale::Tiny,
    )?;
    engine.trace.enabled = true;

    let tokens = harness::chat_tokens(&dir, args.get_usize("tokens"))?;
    harness::run_teacher_forced(&mut engine, &tokens)?;

    let n_layers = engine.weights.cfg.n_layers;
    let select = [0usize, n_layers / 2, n_layers - 1];
    println!("FIG1 — expert activation pattern, Mixtral-architecture tiny model");
    println!(
        "(block shade = gating weight; '·' overlay = in LRU cache k={})\n",
        args.get_usize("cache-k")
    );

    for &layer in &select {
        println!("Layer {layer}:");
        println!(
            "  expert    {}",
            (0..engine.weights.cfg.n_experts)
                .map(|e| format!("{e} "))
                .collect::<Vec<_>>()
                .join(" ")
        );
        let recs: Vec<&moe_offload::engine::trace::ActivationRecord> = engine
            .trace
            .records
            .iter()
            .filter(|r| r.layer == layer)
            .collect();
        for r in &recs {
            let mut row = String::new();
            for (e, &p) in r.probs.iter().enumerate() {
                let cached = r.cached_before.contains(&(e as u16));
                let shade = match p {
                    p if p >= 0.45 => '█',
                    p if p >= 0.25 => '▓',
                    p if p >= 0.12 => '▒',
                    p if p >= 0.05 => '░',
                    _ => ' ',
                };
                row.push(shade);
                row.push(if cached { '·' } else { ' ' });
                row.push(' ');
            }
            println!("  tok {:>3}  {row}", r.token_index);
        }
        println!();
    }

    // per-layer locality summary (the regularity §3.1 exploits)
    println!("Locality summary (repeat = expert reused from previous token):");
    for layer in 0..n_layers {
        let sels = engine.trace.layer_selections(layer);
        let mut repeats = 0usize;
        let mut total = 0usize;
        for w in sels.windows(2) {
            for e in &w[1] {
                repeats += w[0].contains(e) as usize;
                total += 1;
            }
        }
        println!(
            "  layer {layer}: {:.1}% of expert uses repeat the previous token",
            100.0 * repeats as f64 / total.max(1) as f64
        );
    }

    let json = Json::obj(vec![
        ("n_experts", engine.weights.cfg.n_experts.into()),
        ("cache_k", args.get_usize("cache-k").into()),
        ("records", engine.trace.to_json()),
    ]);
    std::fs::write(args.get("out"), json.to_string())?;
    println!("\nwrote raw trace to {}", args.get("out"));
    Ok(())
}
