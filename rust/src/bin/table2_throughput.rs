//! TABLE 2: decode throughput (tokens/s) across hardware setups and
//! algorithm variants — reproduces the paper's Table 2.
//!
//! Routing/caching behaviour comes from real tiny-model execution on the
//! chat workload; timing comes from the discrete-event hardware model at
//! Mixtral-8x7B geometry (DESIGN.md substitution table), so the reported
//! numbers are directly comparable to the paper's units.

use moe_offload::config::{HardwareProfile, OffloadPolicy, QuantScheme, SimScale};
use moe_offload::harness;
use moe_offload::telemetry::Table;
use moe_offload::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let args = Cli::new("table2_throughput", "Table 2: tokens/s per hardware × algorithm")
        .opt("tokens", "96", "chat tokens to decode per cell")
        .flag("tiny-scale", "report at tiny-model geometry instead of Mixtral")
        .parse();

    let dir = harness::artifacts_dir()?;
    let tokens = harness::chat_tokens(&dir, args.get_usize("tokens"))?;
    let scale = if args.has("tiny-scale") { SimScale::Tiny } else { SimScale::Mixtral };

    println!("TABLE 2 — inference speed (tokens per second, simulated hardware model)");
    println!(
        "geometry: {}; workload: {} chat tokens, batch 1\n",
        if matches!(scale, SimScale::Mixtral) { "Mixtral-8x7B (paper units)" } else { "tiny testbed" },
        tokens.len()
    );

    for expert_bits in [2u8, 3] {
        let expert = QuantScheme::Hqq { bits: expert_bits };
        let attn = QuantScheme::Hqq { bits: 4 };
        println!("== {expert_bits}-bit experts, 4-bit attention ==");
        let profiles = HardwareProfile::table2_profiles();
        let mut header = vec!["Algorithm".to_string()];
        header.extend(profiles.iter().map(|p| p.name.to_string()));
        let mut table = Table::new(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());

        let mut rows: Vec<Vec<f64>> = Vec::new();
        for variant in 0..4usize {
            let mut cells = Vec::new();
            let mut row_tps = Vec::new();
            for profile in &profiles {
                let k = profile.paper_cache_k;
                let policy = match variant {
                    0 => OffloadPolicy::Full { cache_k: k, spec_n: 2 },
                    1 => OffloadPolicy::LruOnly { cache_k: k },
                    2 => OffloadPolicy::OnDemand,
                    _ => OffloadPolicy::Naive,
                };
                let mut engine = harness::build_engine(
                    &dir, attn, expert, policy, profile.clone(), scale,
                )?;
                let sess = harness::run_teacher_forced(&mut engine, &tokens)?;
                let tps = sess.run.tokens_per_s_sim();
                row_tps.push(tps);
                cells.push(format!("{tps:.3}"));
            }
            let label = match variant {
                0 => "Full algorithm",
                1 => "W/o expert pre-loading",
                2 => "W/o LRU cache & pre-loading",
                _ => "Naive offloading (accelerate)",
            };
            let mut row = vec![label.to_string()];
            row.extend(cells);
            table.row(row);
            rows.push(row_tps);
        }
        println!("{}", table.render());

        // paper shape checks
        let speedup = rows[0][3] / rows[3][3]; // full vs naive on T4
        println!(
            "full-vs-naive speedup on T4: {speedup:.2}x (paper: ~3.2x at 2-bit, ~2.8x at 3-bit)"
        );
        let ordered = (0..profiles.len()).all(|c| {
            rows[0][c] >= rows[1][c] - 1e-9
                && rows[1][c] >= rows[2][c] - 1e-9
                && rows[2][c] > rows[3][c]
        });
        println!(
            "row ordering full ≥ w/o-preload ≥ w/o-cache > naive: {}\n",
            if ordered { "OK — matches paper" } else { "UNEXPECTED" }
        );
    }
    Ok(())
}
