//! Ablations over the design choices DESIGN.md calls out (beyond the
//! paper's own Table 2 rows): LRU size k, speculative fetch width n, and
//! staging-buffer count b — all at Mixtral-8x7B geometry on the RTX 3060
//! profile (the setup where the paper says pre-loading matters most).

use moe_offload::config::{HardwareProfile, OffloadPolicy, QuantScheme, SimScale};
use moe_offload::harness;
use moe_offload::telemetry::Table;
use moe_offload::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let args = Cli::new("ablation_sweeps", "k / spec-n / staging-b ablations")
        .opt("tokens", "64", "chat tokens per cell")
        .parse();
    let dir = harness::artifacts_dir()?;
    let tokens = harness::chat_tokens(&dir, args.get_usize("tokens"))?;
    let attn = QuantScheme::Hqq { bits: 4 };
    let expert = QuantScheme::Hqq { bits: 2 };
    let profile = HardwareProfile::rtx3060();

    let run = |policy: OffloadPolicy| -> anyhow::Result<(f64, f64)> {
        let mut engine = harness::build_engine(
            &dir, attn, expert, policy, profile.clone(), SimScale::Mixtral,
        )?;
        let sess = harness::run_teacher_forced(&mut engine, &tokens)?;
        Ok((sess.run.tokens_per_s_sim(), sess.run.hit_ratio()))
    };

    println!("ABLATIONS — RTX 3060 profile, Mixtral geometry, 2-bit experts\n");

    // 1) cache size k (spec_n fixed at 2)
    let mut t = Table::new(&["cache k", "tokens/s", "hit ratio"]);
    for k in [0usize, 1, 2, 4, 6, 8] {
        let policy = if k == 0 {
            OffloadPolicy::OnDemand
        } else {
            OffloadPolicy::Full { cache_k: k, spec_n: 2 }
        };
        let (tps, hr) = run(policy)?;
        t.row(vec![k.to_string(), format!("{tps:.3}"), format!("{:.1}%", hr * 100.0)]);
    }
    println!("k sweep (spec_n = 2):\n{}", t.render());

    // 2) speculative width n (k fixed at paper's 2 for 3060)
    let mut t = Table::new(&["spec n", "tokens/s", "hit ratio"]);
    for n in [0usize, 1, 2, 3, 4] {
        let policy = if n == 0 {
            OffloadPolicy::LruOnly { cache_k: 2 }
        } else {
            OffloadPolicy::Full { cache_k: 2, spec_n: n }
        };
        let (tps, hr) = run(policy)?;
        t.row(vec![n.to_string(), format!("{tps:.3}"), format!("{:.1}%", hr * 100.0)]);
    }
    println!("spec-n sweep (k = 2; paper uses 1-2):\n{}", t.render());

    println!(
        "expected: tokens/s rises with k (diminishing past top_k·locality) and\n\
         peaks at small spec-n — wide speculation wastes link time on wrong\n\
         guesses that delay demand loads (the paper fetches 1-2)."
    );
    Ok(())
}
