//! FIG2 (left): LRU cache hit ratio vs cache size k — reproduces the left
//! panel of the paper's Figure 2.
//!
//! Method (paper §4.1): run the model over recorded conversations, record
//! which experts each MoE layer activates per token, then replay the
//! per-layer traces through an LRU of size k ∈ {1..E} and report the mean
//! hit ratio ("expert recall").

use moe_offload::config::{HardwareProfile, OffloadPolicy, QuantScheme, SimScale};
use moe_offload::harness;
use moe_offload::telemetry::Table;
use moe_offload::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let args = Cli::new("fig2_cache_recall", "Figure 2 left: LRU hit ratio vs k")
        .opt("tokens", "192", "chat tokens to trace")
        .parse();

    let dir = harness::artifacts_dir()?;
    let mut engine = harness::build_engine(
        &dir,
        QuantScheme::Hqq { bits: 4 },
        QuantScheme::Hqq { bits: 3 },
        OffloadPolicy::LruOnly { cache_k: 2 },
        HardwareProfile::rtx3060(),
        SimScale::Tiny,
    )?;
    engine.trace.enabled = true;
    let tokens = harness::chat_tokens(&dir, args.get_usize("tokens"))?;
    harness::run_teacher_forced(&mut engine, &tokens)?;

    let cfg = engine.weights.cfg.clone();
    let mut table = Table::new(&["cache size k", "hit ratio", "per-layer range"]);
    println!("FIG2 (left) — LRU cache hit ratio vs cache size");
    println!(
        "workload: {} chat tokens, {} layers, {} experts (top-{})\n",
        tokens.len(),
        cfg.n_layers,
        cfg.n_experts,
        cfg.top_k
    );

    let mut prev = 0.0;
    for k in 1..=cfg.n_experts {
        let per_layer: Vec<f64> = (0..cfg.n_layers)
            .map(|l| harness::replay_lru(&engine.trace.layer_selections(l), k))
            .collect();
        let mean = per_layer.iter().sum::<f64>() / per_layer.len() as f64;
        let min = per_layer.iter().cloned().fold(1.0f64, f64::min);
        let max = per_layer.iter().cloned().fold(0.0f64, f64::max);
        table.row(vec![
            k.to_string(),
            format!("{mean:.3}"),
            format!("{min:.3} – {max:.3}"),
        ]);
        assert!(mean + 1e-9 >= prev, "hit ratio must be monotone in k");
        prev = mean;
    }
    println!("{}", table.render());
    println!(
        "expected shape (paper): rises with k, saturates toward 1.0 at k = E={}",
        cfg.n_experts
    );
    Ok(())
}
