//! Owned host tensors used across the coordinator.
//!
//! Deliberately minimal: row-major `Vec<f32>` / `Vec<u8>` plus a shape.
//! The engine moves flat buffers in and out of PJRT literals; nothing in
//! the hot path needs strides or views.

use crate::error::{Error, Result};

#[derive(Debug, Clone, PartialEq, Default)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub shape: Vec<usize>,
}

impl Tensor {
    pub fn new(data: Vec<f32>, shape: Vec<usize>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::Shape(format!(
                "data len {} != shape {:?} product {}",
                data.len(),
                shape,
                n
            )));
        }
        Ok(Tensor { data, shape })
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor { data: vec![0.0; n], shape }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// [rows, cols] accessor for rank-2 tensors.
    pub fn at2(&self, r: usize, c: usize) -> f32 {
        debug_assert_eq!(self.rank(), 2);
        self.data[r * self.shape[1] + c]
    }

    /// Row slice of a rank-2 tensor.
    pub fn row(&self, r: usize) -> &[f32] {
        debug_assert_eq!(self.rank(), 2);
        let w = self.shape[1];
        &self.data[r * w..(r + 1) * w]
    }

    /// Slice along the first axis: returns the flat data of `self[i]`.
    pub fn index0(&self, i: usize) -> Tensor {
        let inner: usize = self.shape[1..].iter().product();
        Tensor {
            data: self.data[i * inner..(i + 1) * inner].to_vec(),
            shape: self.shape[1..].to_vec(),
        }
    }

    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            return Err(Error::Shape(format!(
                "cannot reshape {} elements to {:?}",
                self.data.len(),
                shape
            )));
        }
        self.shape = shape;
        Ok(self)
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        debug_assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct TensorU8 {
    pub data: Vec<u8>,
    pub shape: Vec<usize>,
}

impl TensorU8 {
    pub fn new(data: Vec<u8>, shape: Vec<usize>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::Shape(format!(
                "data len {} != shape {:?} product {}",
                data.len(),
                shape,
                n
            )));
        }
        Ok(TensorU8 { data, shape })
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// Numerically stable softmax over a flat slice (in place).
pub fn softmax(xs: &mut [f32]) {
    let max = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    if sum > 0.0 {
        for x in xs.iter_mut() {
            *x /= sum;
        }
    }
}

/// log-softmax value at a single index (stable; used by the ppl evaluator).
pub fn log_softmax_at(xs: &[f32], idx: usize) -> f32 {
    let max = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse: f32 = xs.iter().map(|x| (x - max).exp()).sum::<f32>().ln() + max;
    xs[idx] - lse
}

/// Indices of the k largest values, descending (ties broken by lower index,
/// matching jnp.argsort(-p) in the python oracle).
pub fn top_k(xs: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| {
        xs[b].partial_cmp(&xs[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    idx.truncate(k);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_validates_shape() {
        assert!(Tensor::new(vec![1.0; 6], vec![2, 3]).is_ok());
        assert!(Tensor::new(vec![1.0; 5], vec![2, 3]).is_err());
    }

    #[test]
    fn indexing() {
        let t = Tensor::new((0..12).map(|x| x as f32).collect(), vec![3, 4]).unwrap();
        assert_eq!(t.at2(1, 2), 6.0);
        assert_eq!(t.row(2), &[8.0, 9.0, 10.0, 11.0]);
        let s = t.index0(1);
        assert_eq!(s.shape, vec![4]);
        assert_eq!(s.data, vec![4.0, 5.0, 6.0, 7.0]);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut xs = vec![1.0, 2.0, 3.0, -1e30];
        softmax(&mut xs);
        let sum: f32 = xs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(xs[3] < 1e-20);
        assert!(xs[2] > xs[1] && xs[1] > xs[0]);
    }

    #[test]
    fn softmax_is_shift_invariant() {
        let mut a = vec![1000.0, 1001.0];
        softmax(&mut a);
        let mut b = vec![0.0, 1.0];
        softmax(&mut b);
        assert!((a[0] - b[0]).abs() < 1e-6);
    }

    #[test]
    fn log_softmax_matches_softmax() {
        let xs = vec![0.3, -1.2, 2.0];
        let mut sm = xs.clone();
        softmax(&mut sm);
        for i in 0..3 {
            assert!((log_softmax_at(&xs, i) - sm[i].ln()).abs() < 1e-5);
        }
    }

    #[test]
    fn top_k_orders_descending() {
        let xs = vec![0.1, 0.9, 0.5, 0.9];
        assert_eq!(top_k(&xs, 3), vec![1, 3, 2]);
    }
}
