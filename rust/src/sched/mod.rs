//! The tick scheduler: token-budgeted planning of mixed prefill + decode
//! ticks (Sarathi-style chunked prefill).
//!
//! Before this subsystem, admission prefilled a prompt *synchronously*:
//! one long prompt stalled every live decode stream for the whole
//! prefill — seconds in the offloaded setting, where each prefill layer
//! streams nearly the full expert set over the PCIe link. The planner
//! breaks that head-of-line blocking by slicing admission into
//! `prefill_chunk_tokens`-sized chunks and scheduling at most one chunk
//! per tick NEXT TO the live decode batch, under a `max_batch_tokens`
//! token budget:
//!
//! * every decoding session contributes exactly one token row per tick —
//!   decode rows are never budgeted out (starving a live stream to feed
//!   a prefill would invert the latency goal);
//! * the OLDEST admission still feeding its prompt gets the leftover
//!   budget, clamped to its remaining prompt and the chunk knob; younger
//!   prefilling admissions wait (FIFO across ticks, one chunk per tick);
//! * when decode rows already meet the budget, the chunk waits a tick —
//!   decode sessions retire within their token budgets, so the prefill
//!   is delayed, never starved.
//!
//! The planner is pure policy: it owns no sessions and touches no engine
//! state, which is what makes the scheduling decisions unit-testable
//! without artifacts. [`crate::engine::MoeEngine::step_mixed`] executes
//! a plan's chunk + decode rows in one fused layer-lockstep walk (one
//! cache resolve and one stacked kernel per distinct expert per
//! layer-tick — decode rows ride the experts the chunk was going to
//! load anyway), and the coordinator turns slot outcomes into the same
//! preempt/retry/finish handling as plain batched decode.
//!
//! With `chunked_prefill` off the planner never schedules a chunk and
//! the coordinator's admission path is byte-identical to the synchronous
//! scheduler.

use crate::config::ServingConfig;

/// One live session's schedulable work, in admission order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkItem {
    /// A decoding session: exactly one token row per tick.
    Decode,
    /// An admission still feeding its prompt: `remaining` prompt
    /// positions are not yet in the KV cache.
    Prefill { remaining: usize },
}

/// The prefill chunk scheduled for one tick.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkPlan {
    /// Index (into the planned items) of the prefilling session.
    pub idx: usize,
    /// Prompt positions to feed this tick (>= 1).
    pub tokens: usize,
}

/// One tick's work assignment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TickPlan {
    /// Indices of the sessions decoding this tick — every
    /// [`WorkItem::Decode`] item, always (see module docs).
    pub decode: Vec<usize>,
    /// At most one prefill chunk per tick.
    pub chunk: Option<ChunkPlan>,
}

/// The tick planner: the serving knobs that govern mixed ticks, plus the
/// pure planning function. Carried by the engine (like
/// `max_concurrent_sessions` and `batched_decode`) so the coordinator's
/// worker needs no side channel to the config.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TickPlanner {
    /// Master switch: off means admission prefills synchronously and no
    /// chunk is ever planned (byte-identical to the pre-chunking
    /// scheduler).
    pub chunked_prefill: bool,
    /// Upper bound on prompt positions fed per tick.
    pub prefill_chunk_tokens: usize,
    /// Token budget for one tick: decode rows (one each) plus the chunk.
    /// `None` bounds the chunk only by `prefill_chunk_tokens`.
    pub max_batch_tokens: Option<usize>,
}

impl TickPlanner {
    pub fn from_serving(s: &ServingConfig) -> Self {
        TickPlanner {
            chunked_prefill: s.chunked_prefill,
            prefill_chunk_tokens: s.prefill_chunk_tokens,
            max_batch_tokens: s.max_batch_tokens,
        }
    }

    /// Assemble one tick's plan from the live set (admission order).
    pub fn plan(&self, items: &[WorkItem]) -> TickPlan {
        let decode: Vec<usize> = items
            .iter()
            .enumerate()
            .filter(|(_, it)| matches!(it, WorkItem::Decode))
            .map(|(i, _)| i)
            .collect();
        let chunk = if self.chunked_prefill {
            self.plan_chunk(items, decode.len())
        } else {
            None
        };
        TickPlan { decode, chunk }
    }

    /// The chunk for this tick: the oldest prefilling session, fed
    /// whatever the budget leaves after the decode rows. `None` when no
    /// prompt is pending or the decode rows already fill the budget.
    fn plan_chunk(&self, items: &[WorkItem], decode_rows: usize) -> Option<ChunkPlan> {
        let (idx, remaining) = items.iter().enumerate().find_map(|(i, it)| match it {
            WorkItem::Prefill { remaining } if *remaining > 0 => Some((i, *remaining)),
            _ => None,
        })?;
        let budget = self
            .max_batch_tokens
            .unwrap_or(usize::MAX)
            .saturating_sub(decode_rows);
        let tokens = self.prefill_chunk_tokens.min(remaining).min(budget);
        if tokens == 0 {
            // budget spent on decode rows: the chunk waits a tick. With
            // no decode rows the budget is whole (validation keeps it
            // >= 1), so an all-prefill tick always makes progress.
            return None;
        }
        Some(ChunkPlan { idx, tokens })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn planner(chunk: usize, budget: Option<usize>) -> TickPlanner {
        TickPlanner {
            chunked_prefill: true,
            prefill_chunk_tokens: chunk,
            max_batch_tokens: budget,
        }
    }

    #[test]
    fn chunked_off_never_schedules_a_chunk() {
        let p = TickPlanner {
            chunked_prefill: false,
            prefill_chunk_tokens: 16,
            max_batch_tokens: None,
        };
        let plan = p.plan(&[WorkItem::Decode, WorkItem::Prefill { remaining: 100 }]);
        assert_eq!(plan.decode, vec![0]);
        assert_eq!(plan.chunk, None, "the off switch must be inert");
    }

    #[test]
    fn empty_live_set_plans_nothing() {
        let plan = planner(16, None).plan(&[]);
        assert!(plan.decode.is_empty() && plan.chunk.is_none());
    }

    #[test]
    fn lone_prefill_gets_a_full_chunk() {
        let plan = planner(16, None).plan(&[WorkItem::Prefill { remaining: 100 }]);
        assert_eq!(plan.chunk, Some(ChunkPlan { idx: 0, tokens: 16 }));
    }

    #[test]
    fn chunk_clamps_to_the_remaining_prompt() {
        let plan = planner(16, None).plan(&[WorkItem::Prefill { remaining: 5 }]);
        assert_eq!(plan.chunk, Some(ChunkPlan { idx: 0, tokens: 5 }));
    }

    #[test]
    fn decode_rows_always_run_and_eat_the_budget_first() {
        // 3 decode rows under a budget of 8 leave 5 for the chunk
        let items = [
            WorkItem::Decode,
            WorkItem::Prefill { remaining: 100 },
            WorkItem::Decode,
            WorkItem::Decode,
        ];
        let plan = planner(16, Some(8)).plan(&items);
        assert_eq!(plan.decode, vec![0, 2, 3]);
        assert_eq!(plan.chunk, Some(ChunkPlan { idx: 1, tokens: 5 }));
    }

    #[test]
    fn saturated_budget_defers_the_chunk_but_never_the_decodes() {
        let items = [
            WorkItem::Decode,
            WorkItem::Decode,
            WorkItem::Prefill { remaining: 100 },
        ];
        let plan = planner(16, Some(2)).plan(&items);
        assert_eq!(plan.decode, vec![0, 1], "decode rows are never budgeted out");
        assert_eq!(plan.chunk, None, "no budget left for the chunk this tick");
        // ...and an over-subscribed tick still decodes everyone
        let plan = planner(16, Some(1)).plan(&items);
        assert_eq!(plan.decode, vec![0, 1]);
        assert_eq!(plan.chunk, None);
    }

    #[test]
    fn oldest_prefill_wins_and_younger_ones_wait() {
        let items = [
            WorkItem::Prefill { remaining: 3 },
            WorkItem::Prefill { remaining: 100 },
        ];
        let plan = planner(16, None).plan(&items);
        assert_eq!(plan.chunk, Some(ChunkPlan { idx: 0, tokens: 3 }));
    }

    #[test]
    fn drained_prefill_items_are_skipped() {
        // remaining == 0 means the session is transitioning this tick —
        // never schedule an empty chunk for it
        let items = [
            WorkItem::Prefill { remaining: 0 },
            WorkItem::Prefill { remaining: 7 },
        ];
        let plan = planner(16, None).plan(&items);
        assert_eq!(plan.chunk, Some(ChunkPlan { idx: 1, tokens: 7 }));
    }

    #[test]
    fn all_prefill_tick_always_makes_progress() {
        // the minimum valid budget still feeds one position when nothing
        // is decoding — a tick can never be planned empty with live work
        let plan = planner(16, Some(1)).plan(&[WorkItem::Prefill { remaining: 100 }]);
        assert_eq!(plan.chunk, Some(ChunkPlan { idx: 0, tokens: 1 }));
    }

    #[test]
    fn from_serving_copies_the_knobs() {
        let s = ServingConfig {
            chunked_prefill: true,
            prefill_chunk_tokens: 24,
            max_batch_tokens: Some(48),
            ..Default::default()
        };
        let p = TickPlanner::from_serving(&s);
        assert!(p.chunked_prefill);
        assert_eq!(p.prefill_chunk_tokens, 24);
        assert_eq!(p.max_batch_tokens, Some(48));
    }
}
