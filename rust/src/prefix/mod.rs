//! Prefix-cache subsystem: radix-tree KV reuse across requests.
//!
//! Serving millions of users means most prompts SHARE long prefixes —
//! system prompts, few-shot templates, chat history — yet a stateless
//! serving path pays full prefill compute for every admission. On the
//! paper's target hardware that cost is doubled: every prefill token
//! re-routes experts and re-stages them over the offload link, so prefill
//! dominates time-to-first-token exactly where VRAM is scarcest. This
//! subsystem turns completed prompts into reusable KV, the same move the
//! paper makes for expert weights (LRU cache, §3.1): never recompute what
//! you can cache.
//!
//! * [`RadixTree`] — cached prefixes indexed at KV-block granularity:
//!   each node owns one block-sized token chunk, its per-layer host KV
//!   rows, and one allocator reference to the KV block accounting for
//!   those positions. Shared trunks are stored once; LRU eviction is
//!   leaf-first so a warm descendant keeps its trunk alive.
//! * [`PrefixCache`] — the manager. On admission it finds the longest
//!   cached match and emits a [`Seed`]: full-shape per-layer KV images
//!   (the fixed-shape AOT attention reads them directly — copy-into-
//!   literal today, physical block sharing when attention goes
//!   block-strided) plus the matched blocks with a holder reference
//!   added for the session ([`crate::kv::PagedKv::seed`] takes them
//!   over; refcounts in [`crate::kv::BlockAllocator`] free a block
//!   exactly when its last holder — tree node or session — releases).
//!   On completion the coordinator inserts the finished stream, dedup'd
//!   against the tree.
//! * **Eviction ordering** — under pool pressure the engine reclaims
//!   cold, unshared prefixes ([`PrefixCache::reclaim`]) BEFORE the
//!   scheduler preempts any live session: dead data always loses to
//!   live streams.
//!
//! The engine seeds a matched session's [`crate::kv::PagedKv`], rewinds
//! its prefill to the first uncached token, and charges the timeline the
//! same H2D transfer a resume pays — skipped prefill tokens also skip
//! expert routing, demand loads and speculation, which is where the
//! latency win comes from. `ServingConfig::prefix_cache` (default off:
//! byte-identical scheduling to the cache-less path) opts a deployment
//! in; `prefix_cache_tokens` caps the cached footprint. Warm admissions
//! decode bit-identically to cold ones — see `rust/tests/prefix_cache.rs`
//! and the `prefix_reuse` bench section in `rust/benches/engine_decode.rs`.

pub mod manager;
pub mod radix;

pub use manager::{PrefixCache, PrefixStats, Seed};
pub use radix::{ChunkKv, RadixTree};
