//! Radix tree over token sequences at KV-block granularity.
//!
//! The tree indexes every cached prefix as a path of CHUNKS — spans of
//! exactly `block_tokens` tokens, the same granularity the
//! [`crate::kv::BlockAllocator`] hands out physical blocks at. Each node
//! owns one chunk: its token span, the KV block accounting for those
//! positions (one allocator reference held by the tree), and the
//! per-layer host KV rows for the span. Two prompts that share a prefix
//! share the nodes (and therefore the blocks) covering it; they diverge
//! at the first differing chunk. Because chunks are fixed-size, children
//! are keyed by exact chunk content — a hash lookup instead of the
//! byte-wise edge splitting of a classic radix tree, with identical
//! sharing behaviour at block granularity (a sub-chunk match could not
//! reuse a block anyway).
//!
//! Longest-prefix match walks chunk by chunk and touches every node on
//! the path with a fresh LRU tick; eviction removes the LEAST RECENTLY
//! USED LEAF, so cold prefixes die tail-first while their shared trunk
//! survives as long as any descendant is warm.

use std::collections::HashMap;

use crate::kv::BlockId;

/// Per-layer host KV rows for one chunk: `(K rows, V rows)`, each
/// `block_tokens * n_kv_heads * head_dim` f32s.
pub type ChunkKv = Vec<(Vec<f32>, Vec<f32>)>;

struct Node {
    chunk: Vec<u32>,
    block: BlockId,
    kv: ChunkKv,
    children: HashMap<Vec<u32>, usize>,
    parent: Option<usize>,
    last_use: u64,
}

/// Chunk-granular radix tree: arena of nodes + root child map.
pub struct RadixTree {
    nodes: Vec<Option<Node>>,
    free_slots: Vec<usize>,
    root_children: HashMap<Vec<u32>, usize>,
    block_tokens: usize,
    n_layers: usize,
    /// Logical LRU clock: bumped once per tree operation; every node an
    /// operation touches gets the operation's tick.
    tick: u64,
    live: usize,
}

impl RadixTree {
    pub fn new(block_tokens: usize, n_layers: usize) -> Self {
        assert!(block_tokens >= 1, "block_tokens must be >= 1");
        assert!(n_layers >= 1, "n_layers must be >= 1");
        RadixTree {
            nodes: Vec::new(),
            free_slots: Vec::new(),
            root_children: HashMap::new(),
            block_tokens,
            n_layers,
            tick: 0,
            live: 0,
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Live nodes ≡ blocks the tree holds a reference to.
    pub fn cached_blocks(&self) -> usize {
        self.live
    }

    /// Cached sequence positions (every chunk is full by construction).
    pub fn cached_tokens(&self) -> usize {
        self.live * self.block_tokens
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// The current LRU tick — nodes touched by the latest operation carry
    /// it; pass it to [`Self::evict_lru_leaf`] as `protect_from` to keep
    /// the path an insert is building on.
    pub fn tick(&self) -> u64 {
        self.tick
    }

    fn node(&self, idx: usize) -> &Node {
        self.nodes[idx].as_ref().expect("live node index")
    }

    pub fn node_block(&self, idx: usize) -> BlockId {
        self.node(idx).block
    }

    pub fn node_kv(&self, idx: usize) -> &ChunkKv {
        &self.node(idx).kv
    }

    #[cfg(test)]
    fn node_chunk(&self, idx: usize) -> &[u32] {
        &self.node(idx).chunk
    }

    /// Every block the tree currently holds a reference to.
    pub fn blocks(&self) -> Vec<BlockId> {
        self.nodes
            .iter()
            .filter_map(|slot| slot.as_ref().map(|n| n.block))
            .collect()
    }

    /// Longest cached prefix of `tokens`, as the node path of matched
    /// chunks (empty = no cached prefix). Bumps the LRU clock and touches
    /// every node on the path. Matched tokens = `path.len() *
    /// block_tokens`, never more than `tokens.len()`.
    pub fn longest_match(&mut self, tokens: &[u32]) -> Vec<usize> {
        self.tick += 1;
        let tick = self.tick;
        let mut path: Vec<usize> = Vec::new();
        for chunk in tokens.chunks_exact(self.block_tokens) {
            let next = match path.last() {
                None => self.root_children.get(chunk).copied(),
                Some(&p) => self.node(p).children.get(chunk).copied(),
            };
            let Some(idx) = next else { break };
            self.nodes[idx].as_mut().expect("live node index").last_use = tick;
            path.push(idx);
        }
        path
    }

    /// Read-only longest match: how many whole chunks of `tokens` are
    /// cached, WITHOUT touching the LRU clock — for admission gates that
    /// probe repeatedly without committing to a seed.
    pub fn match_chunks(&self, tokens: &[u32]) -> usize {
        let mut cur: Option<usize> = None;
        let mut matched = 0usize;
        for chunk in tokens.chunks_exact(self.block_tokens) {
            let next = match cur {
                None => self.root_children.get(chunk).copied(),
                Some(p) => self.node(p).children.get(chunk).copied(),
            };
            let Some(idx) = next else { break };
            cur = Some(idx);
            matched += 1;
        }
        matched
    }

    /// Insert one chunk under `parent` (None = root). The chunk must be
    /// exactly `block_tokens` long, carry KV for every layer, and must
    /// not already exist at that position — callers walk
    /// [`Self::longest_match`] first and only insert the missing tail.
    /// Returns the new node's index.
    pub fn insert_chunk(
        &mut self,
        parent: Option<usize>,
        chunk: &[u32],
        block: BlockId,
        kv: ChunkKv,
    ) -> usize {
        assert_eq!(chunk.len(), self.block_tokens, "chunk must be one full block");
        assert_eq!(kv.len(), self.n_layers, "chunk KV must cover every layer");
        let node = Node {
            chunk: chunk.to_vec(),
            block,
            kv,
            children: HashMap::new(),
            parent,
            last_use: self.tick,
        };
        let idx = match self.free_slots.pop() {
            Some(slot) => {
                self.nodes[slot] = Some(node);
                slot
            }
            None => {
                self.nodes.push(Some(node));
                self.nodes.len() - 1
            }
        };
        let siblings = match parent {
            None => &mut self.root_children,
            Some(p) => {
                &mut self.nodes[p].as_mut().expect("live parent index").children
            }
        };
        let prev = siblings.insert(chunk.to_vec(), idx);
        assert!(prev.is_none(), "duplicate chunk insert under one parent");
        self.live += 1;
        idx
    }

    /// Evict the least-recently-used LEAF whose block passes `eligible`,
    /// skipping nodes with `last_use >= protect_from` (pass
    /// [`Self::tick`] to shield the path the current operation touched,
    /// `u64::MAX` to shield nothing). Returns the evicted node's block —
    /// the caller owns releasing the tree's reference to the allocator.
    pub fn evict_lru_leaf(
        &mut self,
        protect_from: u64,
        eligible: impl Fn(BlockId) -> bool,
    ) -> Option<BlockId> {
        let mut best: Option<(u64, usize)> = None;
        for (i, slot) in self.nodes.iter().enumerate() {
            let Some(n) = slot else { continue };
            if !n.children.is_empty() || n.last_use >= protect_from || !eligible(n.block) {
                continue;
            }
            let better = match best {
                None => true,
                Some((lu, _)) => n.last_use < lu,
            };
            if better {
                best = Some((n.last_use, i));
            }
        }
        let (_, idx) = best?;
        Some(self.remove_leaf(idx))
    }

    fn remove_leaf(&mut self, idx: usize) -> BlockId {
        let node = self.nodes[idx].take().expect("live node index");
        assert!(node.children.is_empty(), "only leaves are evictable");
        let siblings = match node.parent {
            None => &mut self.root_children,
            Some(p) => {
                &mut self.nodes[p].as_mut().expect("live parent index").children
            }
        };
        let removed = siblings.remove(node.chunk.as_slice());
        debug_assert_eq!(removed, Some(idx), "parent must link the evicted leaf");
        self.free_slots.push(idx);
        self.live -= 1;
        node.block
    }

    /// Structural invariants, used by the property tests: every live node
    /// is reachable from the root exactly once, child links and parent
    /// back-pointers agree, chunks are full blocks, KV covers every
    /// layer, and the live counter matches.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut seen = vec![false; self.nodes.len()];
        let mut stack: Vec<(Option<usize>, &HashMap<Vec<u32>, usize>)> =
            vec![(None, &self.root_children)];
        let mut reached = 0usize;
        while let Some((parent, children)) = stack.pop() {
            for (key, &idx) in children {
                let Some(node) = self.nodes.get(idx).and_then(|s| s.as_ref()) else {
                    return Err(format!("child link to dead slot {idx}"));
                };
                if seen[idx] {
                    return Err(format!("node {idx} reachable twice"));
                }
                seen[idx] = true;
                reached += 1;
                if node.parent != parent {
                    return Err(format!("node {idx} parent back-pointer mismatch"));
                }
                if node.chunk.as_slice() != key.as_slice() {
                    return Err(format!("node {idx} keyed under the wrong chunk"));
                }
                if node.chunk.len() != self.block_tokens {
                    return Err(format!("node {idx} chunk is not one full block"));
                }
                if node.kv.len() != self.n_layers {
                    return Err(format!("node {idx} KV does not cover every layer"));
                }
                stack.push((Some(idx), &node.children));
            }
        }
        let live = self.nodes.iter().filter(|s| s.is_some()).count();
        if reached != live || live != self.live {
            return Err(format!(
                "live accounting drift: reached {reached}, arena {live}, counter {}",
                self.live
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kv(n_layers: usize) -> ChunkKv {
        (0..n_layers).map(|_| (vec![0.0; 4], vec![0.0; 4])).collect()
    }

    #[test]
    fn match_walks_shared_trunk_and_stops_at_divergence() {
        let mut t = RadixTree::new(2, 1);
        let a = t.insert_chunk(None, &[1, 2], BlockId(0), kv(1));
        let b = t.insert_chunk(Some(a), &[3, 4], BlockId(1), kv(1));
        let c = t.insert_chunk(Some(a), &[9, 9], BlockId(2), kv(1));
        assert_eq!(t.longest_match(&[1, 2, 3, 4, 5, 6]), vec![a, b]);
        assert_eq!(t.longest_match(&[1, 2, 9, 9]), vec![a, c]);
        assert_eq!(t.longest_match(&[1, 2, 7]), vec![a], "partial chunk never matches");
        assert!(t.longest_match(&[2, 1]).is_empty());
        // the read-only probe agrees with the mutating match
        let tick_before = t.tick();
        assert_eq!(t.match_chunks(&[1, 2, 3, 4, 5]), 2);
        assert_eq!(t.match_chunks(&[2, 1]), 0);
        assert_eq!(t.tick(), tick_before, "probing must not advance the LRU clock");
        assert_eq!(t.cached_blocks(), 3);
        assert_eq!(t.cached_tokens(), 6);
        t.check_invariants().unwrap();
    }

    #[test]
    fn eviction_is_leaf_first_and_lru() {
        let mut t = RadixTree::new(2, 1);
        let a = t.insert_chunk(None, &[1, 2], BlockId(0), kv(1));
        let b = t.insert_chunk(Some(a), &[3, 4], BlockId(1), kv(1));
        let _c = t.insert_chunk(Some(a), &[9, 9], BlockId(2), kv(1));
        // warm the [1,2]→[9,9] path; [3,4] becomes the coldest leaf
        t.longest_match(&[1, 2, 9, 9]);
        let evicted = t.evict_lru_leaf(u64::MAX, |_| true).unwrap();
        assert_eq!(evicted, BlockId(1), "coldest leaf goes first");
        assert!(t.longest_match(&[1, 2, 3, 4]).len() == 1, "only the trunk remains");
        // trunk is not evictable while a child lives
        let evicted = t.evict_lru_leaf(u64::MAX, |_| true).unwrap();
        assert_eq!(evicted, BlockId(2));
        let evicted = t.evict_lru_leaf(u64::MAX, |_| true).unwrap();
        assert_eq!(evicted, BlockId(0), "trunk falls once its children are gone");
        assert!(t.is_empty());
        assert!(t.evict_lru_leaf(u64::MAX, |_| true).is_none());
        t.check_invariants().unwrap();
        // slots are recycled
        let d = t.insert_chunk(None, &[5, 5], BlockId(3), kv(1));
        assert_eq!(t.node_chunk(d), &[5, 5]);
        t.check_invariants().unwrap();
    }

    #[test]
    fn protect_from_shields_the_current_operation() {
        let mut t = RadixTree::new(2, 1);
        let a = t.insert_chunk(None, &[1, 2], BlockId(0), kv(1));
        t.longest_match(&[1, 2]); // touch with the current tick
        assert!(
            t.evict_lru_leaf(t.tick(), |_| true).is_none(),
            "the just-touched path must survive"
        );
        assert!(t.evict_lru_leaf(t.tick() + 1, |_| true).is_some());
        let _ = a;
    }

    #[test]
    fn eligibility_filter_skips_shared_blocks() {
        let mut t = RadixTree::new(2, 1);
        t.insert_chunk(None, &[1, 2], BlockId(0), kv(1));
        t.insert_chunk(None, &[3, 4], BlockId(1), kv(1));
        // pretend block 0 is shared with a live session: ineligible
        let evicted = t.evict_lru_leaf(u64::MAX, |b| b != BlockId(0)).unwrap();
        assert_eq!(evicted, BlockId(1));
        assert!(t.evict_lru_leaf(u64::MAX, |b| b != BlockId(0)).is_none());
    }
}
