//! The prefix-cache manager: the policy layer between the radix tree and
//! the KV block pool.
//!
//! [`PrefixCache`] owns the tree and mediates every block movement:
//!
//! * **lookup** — longest cached match for an incoming prompt, assembled
//!   into full-shape per-layer host KV images plus the matched blocks
//!   with one holder reference added per block (the seeded session's
//!   share — see [`crate::kv::PagedKv::seed`]).
//! * **insert** — a completed stream's prompt+generation KV, chunked at
//!   block granularity and deduplicated against what the tree already
//!   holds. New chunks do NOT allocate: the tree RETAINS the finishing
//!   session's own blocks (one extra holder each), so when the session
//!   drops a moment later the blocks survive as cache instead of dying —
//!   inserting costs zero pool capacity and never competes with live
//!   admissions for free blocks.
//! * **eviction** — LRU leaf-first, in two roles: keeping the cache under
//!   its `prefix_cache_tokens` cap, and [`PrefixCache::reclaim`]ing cold
//!   prefixes when the pool runs dry so the scheduler frees memory from
//!   DEAD data before preempting a LIVE session.

use std::sync::Arc;

use crate::error::Result;
use crate::kv::{BlockId, KvPool};
use crate::prefix::radix::{ChunkKv, RadixTree};

/// A cache hit, ready to seed a virgin session: `layers` are full-shape
/// `[max_seq, n_kv_heads, head_dim]` host images with positions
/// `[0, matched)` filled, and `blocks` carry one holder reference each
/// for the session taking them over.
pub struct Seed {
    /// Prefix positions covered (a multiple of the block size).
    pub matched: usize,
    pub blocks: Vec<BlockId>,
    pub layers: Vec<(Vec<f32>, Vec<f32>)>,
}

/// Lifetime counters, surfaced as coordinator telemetry.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PrefixStats {
    /// Lookups that seeded at least one block.
    pub hits: u64,
    /// Lookups that found no reusable prefix.
    pub misses: u64,
    /// Prefill positions skipped via seeding, summed over hits.
    pub tokens_reused: u64,
    /// Chunks the cache has admitted.
    pub inserted_blocks: u64,
    /// Tree references dropped by eviction (cap pressure + reclaim).
    pub evicted_blocks: u64,
}

/// Radix-tree prefix cache over the shared KV block pool.
pub struct PrefixCache {
    tree: RadixTree,
    pool: Arc<KvPool>,
    /// Cap on cached positions (None = bounded only by the pool).
    max_tokens: Option<usize>,
    max_seq: usize,
    /// f32s per sequence position per layer image: `n_kv_heads * head_dim`.
    kv_rows: usize,
    n_layers: usize,
    stats: PrefixStats,
}

impl PrefixCache {
    pub fn new(
        pool: Arc<KvPool>,
        n_layers: usize,
        max_seq: usize,
        kv_rows: usize,
        max_tokens: Option<usize>,
    ) -> Self {
        let tree = RadixTree::new(pool.block_tokens(), n_layers);
        PrefixCache { tree, pool, max_tokens, max_seq, kv_rows, n_layers, stats: PrefixStats::default() }
    }

    pub fn stats(&self) -> PrefixStats {
        self.stats
    }

    /// Blocks the tree holds a reference to (cached footprint).
    pub fn cached_blocks(&self) -> usize {
        self.tree.cached_blocks()
    }

    pub fn cached_tokens(&self) -> usize {
        self.tree.cached_tokens()
    }

    /// Blocks eviction could return to the free list RIGHT NOW: cached
    /// blocks no live session shares (refcount 1 = tree-only). The
    /// admission gate counts these as available-with-reclaim, every
    /// scheduler tick — so this must stay O(1): holders beyond the first
    /// are only ever added with the block in the tree (lookup shares
    /// tree→session, insert shares session→tree), so shared blocks are
    /// tree-held and tree-only blocks are `cached - shared`. The one
    /// exception — a still-session-shared block whose node was already
    /// evicted — only UNDERcounts (the gate defers, admission retries),
    /// never overpromises.
    pub fn reclaimable_blocks(&self) -> usize {
        self.tree
            .cached_blocks()
            .saturating_sub(self.pool.stats().shared_blocks)
    }

    /// Read-only probe: blocks a seed of `tokens` would take from the
    /// tree instead of the free list (same cap rule as [`Self::lookup`]),
    /// without touching LRU state or refcounts. Admission gates use it
    /// to avoid sizing a warm request as if its whole prompt needed free
    /// blocks.
    pub fn peek_match_blocks(&self, tokens: &[u32], max_usable: usize) -> usize {
        (max_usable / self.tree.block_tokens()).min(self.tree.match_chunks(tokens))
    }

    /// Longest cached prefix of `tokens`, usable up to `max_usable`
    /// positions (the caller passes `tokens.len() - 1` so at least one
    /// position is left to prefill for first-token logits). Returns None
    /// on a miss; on a hit the returned blocks carry one extra holder
    /// reference each — [`crate::kv::PagedKv::seed`] takes them over and
    /// releases them on failure.
    pub fn lookup(&mut self, tokens: &[u32], max_usable: usize) -> Option<Seed> {
        let bt = self.tree.block_tokens();
        let path = self.tree.longest_match(tokens);
        let usable_chunks = (max_usable / bt).min(path.len());
        if usable_chunks == 0 {
            self.stats.misses += 1;
            return None;
        }
        let matched = usable_chunks * bt;
        let row = self.kv_rows;
        let mut layers: Vec<(Vec<f32>, Vec<f32>)> = (0..self.n_layers)
            .map(|_| (vec![0.0; self.max_seq * row], vec![0.0; self.max_seq * row]))
            .collect();
        let mut blocks = Vec::with_capacity(usable_chunks);
        for (ci, &idx) in path[..usable_chunks].iter().enumerate() {
            let off = ci * bt * row;
            for (l, (k, v)) in self.tree.node_kv(idx).iter().enumerate() {
                layers[l].0[off..off + k.len()].copy_from_slice(k);
                layers[l].1[off..off + v.len()].copy_from_slice(v);
            }
            blocks.push(self.tree.node_block(idx));
        }
        self.pool.retain_all(&blocks);
        self.stats.hits += 1;
        self.stats.tokens_reused += matched as u64;
        Some(Seed { matched, blocks, layers })
    }

    /// Insert a completed stream: `tokens` are the positions actually
    /// written to its KV (prompt + generated-and-fed tokens), `blocks[i]`
    /// is the session's block backing positions `[i*bt, (i+1)*bt)` (its
    /// page table in order), and `layer_kv` reads one layer's full-shape
    /// host images. Only whole blocks are cacheable (the tail partial
    /// chunk is dropped) and only chunks the tree is missing copy data;
    /// each new chunk RETAINS the session's block — one extra holder —
    /// instead of allocating, so the cache inherits blocks that were
    /// about to die with the session rather than competing with live
    /// admissions. The session KV is read at most once, and not at all
    /// on a full dedup. Returns the number of chunks admitted — fewer
    /// than offered when the token cap says no (best effort, never an
    /// error).
    pub fn insert(
        &mut self,
        tokens: &[u32],
        blocks: &[BlockId],
        mut layer_kv: impl FnMut(usize) -> Result<(Vec<f32>, Vec<f32>)>,
    ) -> Result<usize> {
        let bt = self.tree.block_tokens();
        let n_chunks = (tokens.len() / bt).min(blocks.len());
        if n_chunks == 0 {
            return Ok(0);
        }
        let path = self.tree.longest_match(&tokens[..n_chunks * bt]);
        if path.len() == n_chunks {
            return Ok(0); // fully cached already — the match refreshed LRU
        }
        let mut full: Vec<(Vec<f32>, Vec<f32>)> = Vec::with_capacity(self.n_layers);
        for l in 0..self.n_layers {
            full.push(layer_kv(l)?);
        }
        // everything the match touched (and everything we add) carries
        // the current tick — cap eviction below must not eat our own path
        let protect = self.tree.tick();
        let mut parent = path.last().copied();
        let mut inserted = 0usize;
        for ci in path.len()..n_chunks {
            if !self.make_room_for_chunk(protect) {
                break;
            }
            let row = self.kv_rows;
            let off = ci * bt * row;
            let kv: ChunkKv = full
                .iter()
                .map(|(k, v)| (k[off..off + bt * row].to_vec(), v[off..off + bt * row].to_vec()))
                .collect();
            let chunk = &tokens[ci * bt..(ci + 1) * bt];
            self.pool.retain_all(&blocks[ci..ci + 1]);
            parent = Some(self.tree.insert_chunk(parent, chunk, blocks[ci], kv));
            inserted += 1;
        }
        self.stats.inserted_blocks += inserted as u64;
        Ok(inserted)
    }

    /// Stay under the token cap, evicting cold leaves if needed. True
    /// when one more chunk fits.
    fn make_room_for_chunk(&mut self, protect: u64) -> bool {
        let bt = self.tree.block_tokens();
        let Some(cap) = self.max_tokens else { return true };
        while self.tree.cached_tokens() + bt > cap {
            let Some(block) = self.tree.evict_lru_leaf(protect, |_| true) else {
                return false;
            };
            self.pool.release_one(block);
            self.stats.evicted_blocks += 1;
        }
        true
    }

    /// Pool-pressure eviction: drop cold UNSHARED prefixes leaf-first
    /// until `needed_blocks` are free or nothing evictable remains.
    /// Returns the number of blocks actually returned to the free list.
    /// The engine calls this before surfacing
    /// [`crate::error::Error::KvPoolExhausted`], so dead cached data is
    /// always reclaimed before any live session is preempted.
    pub fn reclaim(&mut self, needed_blocks: usize) -> usize {
        let mut freed = 0usize;
        while self.pool.stats().free_blocks < needed_blocks {
            let pool = Arc::clone(&self.pool);
            let Some(block) = self.tree.evict_lru_leaf(u64::MAX, |b| pool.refcount(b) == 1)
            else {
                break;
            };
            if self.pool.release_one(block) {
                freed += 1;
            }
            self.stats.evicted_blocks += 1;
        }
        freed
    }
}

impl Drop for PrefixCache {
    fn drop(&mut self) {
        for block in self.tree.blocks() {
            self.pool.release_one(block);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kv::{KvPool, PagedKv};
    use crate::util::prop;

    const MAX_SEQ: usize = 32;
    const KV_ROWS: usize = 4; // 2 heads × 2 dims
    const LAYERS: usize = 2;

    fn pool(total_blocks: usize, block_tokens: usize) -> Arc<KvPool> {
        Arc::new(KvPool::new(total_blocks, block_tokens, 256, vec![MAX_SEQ, 2, 2]))
    }

    fn cache(p: &Arc<KvPool>, cap: Option<usize>) -> PrefixCache {
        PrefixCache::new(Arc::clone(p), LAYERS, MAX_SEQ, KV_ROWS, cap)
    }

    /// Deterministic fake KV: position p of layer l row r = encode(l,p,r).
    fn fake_kv(pos: usize) -> impl FnMut(usize) -> Result<(Vec<f32>, Vec<f32>)> {
        move |l| {
            let mut k = vec![0.0f32; MAX_SEQ * KV_ROWS];
            let mut v = vec![0.0f32; MAX_SEQ * KV_ROWS];
            for p in 0..pos {
                for r in 0..KV_ROWS {
                    k[p * KV_ROWS + r] = (l * 10_000 + p * 100 + r) as f32;
                    v[p * KV_ROWS + r] = -((l * 10_000 + p * 100 + r) as f32);
                }
            }
            Ok((k, v))
        }
    }

    /// Simulate a live session's page table: one block per `block_tokens`
    /// positions, allocated from the pool like `PagedKv::ensure_tokens`.
    fn open_blocks(p: &Arc<KvPool>, tokens: usize) -> Vec<BlockId> {
        (0..p.blocks_for(tokens))
            .map(|_| p.alloc_one().expect("test pool must cover the session"))
            .collect()
    }

    /// Simulate the session dropping: release its holder on every block.
    fn close_blocks(p: &Arc<KvPool>, blocks: Vec<BlockId>) {
        for b in blocks {
            p.release_one(b);
        }
    }

    #[test]
    fn insert_then_lookup_reassembles_the_prefix() {
        let p = pool(8, 4);
        let mut c = cache(&p, None);
        let tokens: Vec<u32> = (0..10).collect(); // 2 full chunks + partial tail
        let sb = open_blocks(&p, 10);
        assert_eq!(c.insert(&tokens, &sb, fake_kv(10)).unwrap(), 2);
        assert_eq!(c.cached_blocks(), 2);
        // the tree RETAINED the session's first two blocks — no allocation
        assert_eq!(p.stats().in_use_blocks, 3);
        assert_eq!(p.stats().shared_blocks, 2);
        assert_eq!(p.refcount(sb[0]), 2);
        assert_eq!(p.refcount(sb[2]), 1, "the partial tail chunk is not cached");
        close_blocks(&p, sb);
        assert_eq!(p.stats().in_use_blocks, 2, "cached blocks outlive the session");
        assert_eq!(p.stats().shared_blocks, 0);

        let seed = c.lookup(&tokens, tokens.len() - 1).unwrap();
        assert_eq!(seed.matched, 8, "match is block-aligned");
        assert_eq!(seed.blocks.len(), 2);
        assert_eq!(seed.layers.len(), LAYERS);
        // the assembled image carries the inserted values for [0, 8)...
        let mut expect = fake_kv(8);
        for (l, (k, v)) in seed.layers.iter().enumerate() {
            let (ek, ev) = expect(l).unwrap();
            assert_eq!(&k[..8 * KV_ROWS], &ek[..8 * KV_ROWS]);
            assert_eq!(&v[..8 * KV_ROWS], &ev[..8 * KV_ROWS]);
            // ...and zeros beyond the matched prefix
            assert!(k[8 * KV_ROWS..].iter().all(|&x| x == 0.0));
        }
        // the hit added one holder per block for the session to take over
        for &b in &seed.blocks {
            assert_eq!(p.refcount(b), 2);
        }
        assert_eq!(p.stats().shared_blocks, 2);
        for b in seed.blocks {
            p.release_one(b);
        }
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.tokens_reused), (1, 0, 8));
    }

    #[test]
    fn lookup_never_swallows_the_whole_prompt() {
        let p = pool(8, 4);
        let mut c = cache(&p, None);
        let tokens: Vec<u32> = (0..8).collect();
        let sb = open_blocks(&p, 8);
        c.insert(&tokens, &sb, fake_kv(8)).unwrap();
        close_blocks(&p, sb);
        // identical prompt: at most len-1 positions may seed, so the
        // match rounds down to one block and leaves 4 tokens to prefill
        let seed = c.lookup(&tokens, tokens.len() - 1).unwrap();
        assert_eq!(seed.matched, 4);
        for b in seed.blocks {
            p.release_one(b);
        }
        // a strict prefix shorter than one block cannot hit at all
        assert!(c.lookup(&tokens[..3], 2).is_none());
        assert_eq!(c.stats().misses, 1);
    }

    #[test]
    fn insert_dedups_against_the_cached_trunk() {
        let p = pool(8, 4);
        let mut c = cache(&p, None);
        let a: Vec<u32> = (0..12).collect();
        let sa = open_blocks(&p, 12);
        assert_eq!(c.insert(&a, &sa, fake_kv(12)).unwrap(), 3);
        close_blocks(&p, sa);
        // same first 8 tokens, divergent tail: only the tail is retained
        let mut b: Vec<u32> = (0..8).collect();
        b.extend([90, 91, 92, 93]);
        let sb = open_blocks(&p, 12);
        assert_eq!(c.insert(&b, &sb, fake_kv(12)).unwrap(), 1);
        assert_eq!(p.refcount(sb[2]), 2, "only the divergent tail chunk is shared");
        assert_eq!(p.refcount(sb[0]), 1, "the deduped trunk keeps the tree's copy");
        assert_eq!(c.cached_blocks(), 4);
        // re-inserting either is a no-op
        assert_eq!(c.insert(&a, &sb, fake_kv(12)).unwrap(), 0);
        assert_eq!(c.insert(&b, &sb, fake_kv(12)).unwrap(), 0);
        close_blocks(&p, sb);
        assert_eq!(p.stats().in_use_blocks, 4);
    }

    #[test]
    fn token_cap_evicts_cold_leaves_to_make_room() {
        let p = pool(8, 4);
        let mut c = cache(&p, Some(8)); // cap: 2 chunks
        let a: Vec<u32> = (0..8).collect();
        let sa = open_blocks(&p, 8);
        c.insert(&a, &sa, fake_kv(8)).unwrap();
        close_blocks(&p, sa);
        assert_eq!(c.cached_tokens(), 8);
        // a disjoint insert must evict the cold prefix to stay capped
        let b: Vec<u32> = (100..108).collect();
        let sb = open_blocks(&p, 8);
        assert_eq!(c.insert(&b, &sb, fake_kv(8)).unwrap(), 2);
        close_blocks(&p, sb);
        assert_eq!(c.cached_tokens(), 8);
        assert!(c.stats().evicted_blocks >= 2);
        assert_eq!(p.stats().in_use_blocks, 2, "evicted blocks went back to the pool");
    }

    #[test]
    fn insert_inherits_session_blocks_even_when_the_pool_is_dry() {
        let p = pool(2, 4);
        let mut c = cache(&p, None);
        let a: Vec<u32> = (0..8).collect();
        let sa = open_blocks(&p, 8);
        assert_eq!(p.stats().free_blocks, 0, "the session holds the whole pool");
        // a dry pool cannot refuse the insert: the tree inherits the
        // session's own blocks instead of allocating
        assert_eq!(c.insert(&a, &sa, fake_kv(8)).unwrap(), 2);
        close_blocks(&p, sa);
        assert_eq!(c.cached_blocks(), 2);
        assert_eq!(p.stats().in_use_blocks, 2);
        let seed = c.lookup(&a, 7).unwrap();
        assert_eq!(seed.matched, 4);
        for b in seed.blocks {
            p.release_one(b);
        }
    }

    #[test]
    fn reclaim_frees_unshared_blocks_only() {
        let p = pool(4, 4);
        let mut c = cache(&p, None);
        let a: Vec<u32> = (0..8).collect();
        let sa = open_blocks(&p, 8);
        c.insert(&a, &sa, fake_kv(8)).unwrap();
        close_blocks(&p, sa);
        let b: Vec<u32> = (100..108).collect();
        let sb = open_blocks(&p, 8);
        c.insert(&b, &sb, fake_kv(8)).unwrap();
        close_blocks(&p, sb);
        assert_eq!(p.stats().free_blocks, 0);
        // a session holds a's prefix: those two blocks are not reclaimable
        let seed = c.lookup(&a, 7).unwrap(); // matches 1 chunk (7/4 = 1)
        assert_eq!(seed.blocks.len(), 1);
        let held = seed.blocks.clone();
        assert_eq!(c.reclaimable_blocks(), 3);
        let freed = c.reclaim(4);
        assert_eq!(freed, 3, "only unshared blocks can be freed");
        assert_eq!(p.stats().free_blocks, 3);
        assert_eq!(c.cached_blocks(), 1, "the shared node survived eviction filters");
        for b in held {
            assert!(
                !p.release_one(b),
                "the tree still holds the surviving shared block"
            );
        }
        // unshared now: one more reclaim pass frees the last cached block
        assert_eq!(c.reclaim(4), 1);
        assert_eq!(p.stats().free_blocks, 4);
        assert!(c.cached_blocks() == 0 && c.stats().evicted_blocks == 4);
    }

    #[test]
    fn drop_releases_every_tree_reference() {
        let p = pool(4, 4);
        {
            let mut c = cache(&p, None);
            let sb = open_blocks(&p, 16);
            c.insert(&(0..16).collect::<Vec<u32>>(), &sb, fake_kv(16)).unwrap();
            close_blocks(&p, sb);
            assert_eq!(p.stats().in_use_blocks, 4);
        }
        assert_eq!(p.stats().free_blocks, 4, "dropping the cache frees its blocks");
    }

    /// Property: random insert/lookup/reclaim traffic keeps tree structure,
    /// pool accounting and refcounts consistent — no dangling block refs,
    /// refcounts hit zero exactly when the last holder releases, match
    /// length never exceeds the query.
    #[test]
    fn prop_random_traffic_keeps_invariants() {
        prop::check(
            "prefix-cache-invariants",
            40,
            |rng| {
                // a batch of prompts over a tiny alphabet so prefixes collide
                let n_ops = 30 + rng.below(40);
                (0..n_ops)
                    .map(|_| {
                        let kind = rng.below(10);
                        let len = 1 + rng.below(MAX_SEQ - 1);
                        let toks: Vec<u32> =
                            (0..len).map(|_| rng.below(3) as u32).collect();
                        (kind, toks)
                    })
                    .collect::<Vec<_>>()
            },
            |ops| {
                let p = pool(6, 4);
                let mut c = cache(&p, Some(16));
                let mut held: Vec<(PagedKv, Vec<BlockId>)> = Vec::new();
                for (kind, toks) in ops.iter() {
                    let kind = *kind;
                    match kind {
                        0..=4 => {
                            // a finishing session: it holds blocks for its
                            // positions, offers them to the cache, then
                            // drops. Skip when the pool cannot even admit
                            // the session (as real admission would).
                            let needed = p.blocks_for(toks.len());
                            let mut sb = Vec::new();
                            while sb.len() < needed {
                                match p.alloc_one() {
                                    Some(b) => sb.push(b),
                                    None => break,
                                }
                            }
                            if sb.len() == needed {
                                c.insert(toks, &sb, fake_kv(toks.len()))
                                    .map_err(|e| format!("insert failed: {e}"))?;
                            }
                            close_blocks(&p, sb);
                        }
                        5..=7 => {
                            if toks.len() < 2 {
                                continue;
                            }
                            if let Some(seed) = c.lookup(toks, toks.len() - 1) {
                                prop::ensure(
                                    seed.matched < toks.len(),
                                    "match length must stay below the query length",
                                )?;
                                prop::ensure(
                                    seed.matched == seed.blocks.len() * 4,
                                    "matched tokens must equal matched blocks",
                                )?;
                                // hand the blocks to a real paged store so
                                // release goes through the session path
                                let mut kv = PagedKv::new(LAYERS, Arc::clone(&p));
                                let ids = seed.blocks.clone();
                                kv.seed(seed.layers, seed.blocks)
                                    .map_err(|e| format!("seed failed: {e}"))?;
                                held.push((kv, ids));
                            }
                        }
                        _ => {
                            if !held.is_empty() && kind == 8 {
                                held.remove(0); // drop a session mid-flight
                            } else {
                                c.reclaim(1 + toks.len() % 3);
                            }
                        }
                    }
                    // invariants after every op
                    c.tree.check_invariants()?;
                    let st = p.stats();
                    prop::ensure(
                        st.free_blocks + st.in_use_blocks == st.total_blocks,
                        "pool accounting must balance",
                    )?;
                    for b in c.tree.blocks() {
                        prop::ensure(
                            p.refcount(b) >= 1,
                            "tree-held block must stay referenced",
                        )?;
                    }
                    for (_, ids) in &held {
                        for &b in ids {
                            prop::ensure(
                                p.refcount(b) >= 1,
                                "session-held block must stay referenced",
                            )?;
                        }
                    }
                }
                // tear down: sessions first, then the cache — the pool
                // must recover completely (refcounts hit zero exactly at
                // the last release)
                held.clear();
                drop(c);
                let st = p.stats();
                prop::ensure(st.free_blocks == st.total_blocks, "pool must fully drain")?;
                prop::ensure(st.shared_blocks == 0, "no shared blocks after teardown")?;
                Ok(())
            },
        );
    }
}
